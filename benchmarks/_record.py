"""Machine-readable benchmark artifacts: ``BENCH_<name>.json``.

Every benchmark main records flat scalar metrics next to its text table
(``Recorder``), so CI can upload them as artifacts and
``tools/check_bench.py`` can gate them against the committed
``benchmarks/baseline.json`` with per-metric tolerances.  The JSON goes
to ``$BENCH_JSON_DIR`` (default: the current directory) as

    {"bench": <name>, "schema": 1, "metrics": {<name>: <number>, ...}}

Metric values must be plain numbers (bools are stored as 0/1) — that is
what keeps the regression gate a dumb, diffable comparison.
"""
from __future__ import annotations

import json
import os
import time


def json_path(name: str) -> str:
    out_dir = os.environ.get("BENCH_JSON_DIR", ".")
    return os.path.join(out_dir, f"BENCH_{name}.json")


class Recorder:
    """Collects metrics for one benchmark and writes its JSON artifact."""

    def __init__(self, name: str):
        self.name = name
        self.t0 = time.time()
        self.metrics: dict[str, float] = {}

    def add(self, **metrics) -> None:
        for key, value in metrics.items():
            self.metrics[key] = float(value)

    def finish(self) -> dict:
        """Stamp wall-clock, write ``BENCH_<name>.json``, return metrics."""
        self.metrics.setdefault("wall_s", time.time() - self.t0)
        path = json_path(self.name)
        payload = {"bench": self.name, "schema": 1, "metrics": self.metrics}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench-json] wrote {path} ({len(self.metrics)} metrics)")
        return self.metrics
