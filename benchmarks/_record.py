"""Machine-readable benchmark artifacts: ``BENCH_<name>.json``.

Every benchmark main records flat scalar metrics next to its text table
(``Recorder``), so CI can upload them as artifacts and
``tools/check_bench.py`` can gate them against the committed
``benchmarks/baseline.json`` with per-metric tolerances.  The JSON goes
to ``$BENCH_JSON_DIR`` (default: the current directory) as

    {"bench": <name>, "schema": 2,
     "metrics": {<name>: <number>, ...},
     "telemetry": {"counters": ..., "gauges": ..., "histograms": ...}}

Metric values must be plain numbers (bools are stored as 0/1) — that is
what keeps the regression gate a dumb, diffable comparison.  Schema 2
adds the OPTIONAL ``telemetry`` sub-object — the ``repro.obs`` registry
snapshot at finish time (solver iterations/residuals, jit retraces per
shape bucket, cache hit/miss, latency percentiles, ...).  The gate
reads ONLY the flat ``metrics`` section; telemetry is observability
payload, never a regression surface.  When any spans were recorded the
Perfetto-loadable Chrome trace goes to ``TRACE_<name>.json`` alongside.
"""
from __future__ import annotations

import json
import os
import time

from repro import obs


def json_path(name: str) -> str:
    out_dir = os.environ.get("BENCH_JSON_DIR", ".")
    return os.path.join(out_dir, f"BENCH_{name}.json")


def trace_path(name: str) -> str:
    out_dir = os.environ.get("BENCH_JSON_DIR", ".")
    return os.path.join(out_dir, f"TRACE_{name}.json")


class Recorder:
    """Collects metrics for one benchmark and writes its JSON artifact.

    Construction enables ``repro.obs`` (wiping any prior state) so the
    benchmark run doubles as the telemetry capture; pass
    ``telemetry=False`` to leave the obs state alone (A/B overhead
    timing does its own enable/disable).
    """

    def __init__(self, name: str, telemetry: bool = True):
        self.name = name
        self.t0 = time.time()
        self.metrics: dict[str, float] = {}
        self.telemetry = telemetry
        if telemetry:
            obs.enable(reset=True)

    def add(self, **metrics) -> None:
        for key, value in metrics.items():
            self.metrics[key] = float(value)

    def finish(self) -> dict:
        """Stamp wall-clock, write ``BENCH_<name>.json`` (and
        ``TRACE_<name>.json`` if any spans were recorded), return
        metrics."""
        self.metrics.setdefault("wall_s", time.time() - self.t0)
        path = json_path(self.name)
        payload = {"bench": self.name, "schema": 2, "metrics": self.metrics}
        if self.telemetry:
            snap = obs.snapshot()
            if any(snap.values()):
                payload["telemetry"] = snap
            if obs.trace_events()["traceEvents"]:
                tpath = obs.write_trace(trace_path(self.name))
                print(f"[bench-trace] wrote {tpath}")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench-json] wrote {path} ({len(self.metrics)} metrics)")
        return self.metrics
