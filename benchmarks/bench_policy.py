"""DTM/DVFS policy shoot-out: Pareto frontiers over the policy axis.

Runs the full `repro.policy` controller family over a small scenario
grid (workloads × machines, closed-loop feedback) and scores every
(scenario, policy) cell on the three axes a thermal-management story
actually trades: **performance** (the DTM slowdown ``mean(1/f)``),
**peak DRAM temperature**, and **energy to solution**
(``StackReport.energy_per_work_J``).  Per scenario it prints the policy
table with its Pareto-optimal rows starred (`repro.policy.pareto`,
minimizing all three axes) and the 85 °C DRAM verdict per row.

The headline metric is ``n_rescued``: scenarios whose verdict FLIPS —
BLOCKED under the default logic-sensed ramp, OK under some other
controller.  The quick grid contains exactly such a point by
construction: ``sort/2^20/dram2`` on the AP runs its DRAM dies to
~95 °C while the logic dies idle at ~87 °C, so every logic-sensed
policy (ramp/step/hysteresis/pid/predictive) is *blind* and never
trips, but the DRAM-sensed per-die controller holds the stack under
the ceiling at a ~5 % slowdown.  ``tools/check_bench.py`` gates
``n_rescued >= 1`` plus the numbers behind that story
(``benchmarks/baseline.json``, section "policy").

``--quick`` is the CI smoke lane (same grid today; the flag keys the
lane split), ``--no-cache`` forces a live replay.  DVFS
operating-point residency counters (``policy/dvfs-22nm/residency/*``)
are printed from the obs registry after a live run.  Metrics land in
``BENCH_policy.json``.
"""
import argparse
import sys
import time

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro import obs
from repro import policy as policy_registry
from repro.policy.pareto import pareto_front
from repro.sweep import SweepSpec, run_sweep


def quick_spec() -> SweepSpec:
    """The CI lane: 2 workloads × 2 machines × every registered policy.

    ``sort`` at 2^20 is the verdict-flip scenario (see module
    docstring); ``dmm`` at 2^20 drives the SIMD hot enough that the
    controllers differentiate into a real Pareto frontier (slowdown
    2–5×, distinct peak/energy trade-offs)."""
    return SweepSpec(workloads=("sort", "dmm"), sizes=(2 ** 20,),
                     n_dram=(2,), fb_modes=("closed",),
                     policies=policy_registry.names(),
                     grid_n=8, n_intervals=16, steps_per_interval=1,
                     n_cg=25)


def full_spec() -> SweepSpec:
    return SweepSpec(workloads=("sort", "dmm", "hist"),
                     sizes=(2 ** 14, 2 ** 20), n_dram=(1, 2),
                     fb_modes=("closed",),
                     policies=policy_registry.names(),
                     grid_n=12, n_intervals=16, steps_per_interval=1,
                     n_cg=30, n_picard=20)


def score(rec) -> tuple[float, float, float]:
    """(slowdown, peak dram °C, energy-per-work J) — minimize all."""
    rep = rec.report
    return (rep.dtm_slowdown, float(rep.dram_peak_C.max()),
            rep.energy_per_work_J)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke lane grid")
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args(argv)

    spec = quick_spec() if args.quick else full_spec()
    rec = Recorder("policy")

    t0 = time.time()
    res = run_sweep(spec, use_cache=not args.no_cache)
    dt = time.time() - t0
    print(f"policy sweep: {spec.n_points} points x {len(spec.machines)} "
          f"machines ({len(spec.policies)} policies: "
          f"{', '.join(spec.policies)}) in {dt:.1f}s"
          f"{' [cache HIT]' if res.from_cache else ''}")
    for r in res.records:
        assert r.report.converged, (r.label, r.report.residual_C.max())
    rec.add(sweep_wall_s=dt, n_cases=len(res.records))

    # ---- group the records into scenarios: one policy table each ----
    scenarios: dict[tuple, dict[str, object]] = {}
    for r in res.records:
        key = (r.point.workload, r.point.size, r.point.n_dram, r.machine)
        scenarios.setdefault(key, {})[r.point.policy] = r

    n_rescued = n_regressed = 0
    rescued_labels = []
    min_pareto = len(spec.policies)
    for (wl, size, n_dram, mc), by_pol in scenarios.items():
        pols = [p for p in spec.policies if p in by_pol]
        pts = [score(by_pol[p]) for p in pols]
        front = set(pareto_front(pts))
        min_pareto = min(min_pareto, len(front))
        print(f"\n== {wl}/N{size}/dram{n_dram} :: {mc} ==")
        print(f"  {'policy':<12}{'slow_x':>8}{'dram_C':>8}"
              f"{'E/work_J':>10}  verdict")
        for i, p in enumerate(pols):
            slow, peak, epw = pts[i]
            ok = by_pol[p].verdict_ok
            star = " *" if i in front else ""
            print(f"  {p:<12}{slow:>8.3f}{peak:>8.1f}{epw:>10.3g}  "
                  f"{'OK' if ok else 'BLOCKED'}{star}")
        ramp_ok = by_pol["ramp"].verdict_ok
        saviors = [p for p in pols
                   if p != "ramp" and by_pol[p].verdict_ok]
        if not ramp_ok and saviors:
            n_rescued += 1
            rescued_labels.append(f"{wl}/N{size}/dram{n_dram}/{mc}")
            print(f"  RESCUED: ramp BLOCKED -> OK under "
                  f"{', '.join(saviors)}")
        if ramp_ok and any(not by_pol[p].verdict_ok for p in pols):
            n_regressed += 1

    print(f"\n# {n_rescued} scenario(s) rescued by a non-default policy"
          f"{': ' + '; '.join(rescued_labels) if rescued_labels else ''}")
    print(f"# {n_regressed} scenario(s) regressed vs ramp; smallest "
          f"Pareto front has {min_pareto} member(s)")
    rec.add(n_scenarios=len(scenarios), n_rescued=n_rescued,
            n_regressed=n_regressed, min_pareto=min_pareto)

    # ---- the gated numbers behind the rescue story (quick grid) ----
    for key, by_pol in scenarios.items():
        wl, size, n_dram, mc = key
        if (wl, mc) != ("sort", "ap"):
            continue
        for pol in ("ramp", "perdie"):
            if pol in by_pol:
                slow, peak, _ = score(by_pol[pol])
                rec.add(**{f"sort_ap_{pol}_dram_peak_C": peak,
                           f"sort_ap_{pol}_slowdown_x": slow})

    # DVFS residency: which operating points the governor actually sat
    # in (counters land under policy/<name>/residency/<op> during the
    # replay — absent on a cache hit, which never runs the controller)
    resid = obs.values_by_prefix("policy/")
    if resid:
        print("# policy residency (intervals):")
        for name, n in resid.items():
            print(f"#   {name} = {n}")
    return rec.finish()


if __name__ == "__main__":
    main(sys.argv[1:])
