"""The workload suite executed on the AP emulator: cycles + accuracy
+ the device-resident scaling study.

Paper §3.1 trio (dmm / fft / blackscholes) plus the suite additions
(sort / spmv / knn / histogram); every row is an exact small instance
checked against its NumPy oracle.  The scaling section times trace
generation for the data-dependent workloads at n_elems in {64, 256,
1024, 2048} on the device-resident path (steady-state: the jit cache is
warmed first, as every driver's repeat instances see it) and measures
the device-vs-eager speedup at n_elems=256 — the per-cycle host-sync
oracle against the one-transfer-per-phase compiled programs.  The
megakernel section times the fused op-group path against the device
path at n=2048 (gated >= 2x: the bulk accounting fold removes the
per-round host replay), captures an exact n=65536 trace (past the old
2048 ``trace_elems`` cap), and checks bitwise shard invariance on 2
forced host devices.  Metrics land in ``BENCH_workloads.json``;
``benchmarks/baseline.json`` gates the speedups at >= 10x.
"""
import argparse
import os
import subprocess
import sys
import time

import numpy as np

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro import obs
from repro.workloads import blackscholes as bs
from repro.workloads import dmm, fft, histogram, knn, registry, sort, spmv

SCALING_WORKLOADS = ("sort", "knn", "hist", "spmv")
SPEEDUP_WORKLOADS = ("sort", "knn", "hist")     # gated >= 10x at n=256
SCALING_NS = (64, 256, 1024, 2048)
QUICK_NS = (64, 256)
MEGA_N = 2048          # megakernel-vs-device speedup point (gated >= 2x)
MEGA_BIG_N = 65536     # lifted-clamp point: exact trace past old 2048 cap


def rows():
    rng = np.random.default_rng(0)

    A = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    B = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    C, ctr = dmm.ap_matmul(A, B, m=6)
    err = float(np.abs(C.astype(np.int64)
                       - dmm.reference(A, B).astype(np.int64)).max())
    yield "dmm", "8x8", ctr["mac_cycles"], ctr["energy"], err

    N = 16
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.4 / np.sqrt(N))
    X, ctr = fft.ap_fft(x, m=16, frac=12)
    rel = float(np.max(np.abs(X - fft.reference(x)))
                / np.max(np.abs(fft.reference(x))))
    yield "fft", N, ctr["cycles"] - ctr["read_cycles"], ctr["energy"], rel

    n = 64
    S = rng.uniform(0.8, 1.6, n)
    K = rng.uniform(0.8, 1.6, n)
    T = rng.uniform(0.3, 2.0, n)
    sig = rng.uniform(0.15, 0.6, n)
    prices, ctr = bs.ap_blackscholes(S, K, T, sig)
    err = float(np.abs(prices - bs.reference(S, K, T, sig)).max())
    yield ("blackscholes", n, ctr["cycles"] - ctr["read_cycles"],
           ctr["energy"], err)

    xs = rng.integers(0, 200, 64, dtype=np.uint64)
    ys, ctr = sort.ap_sort(xs, m=8)
    err = float(np.abs(ys.astype(np.int64)
                       - sort.reference(xs).astype(np.int64)).max())
    yield "sort", 64, ctr["cycles"], ctr["energy"], err

    n_rows, nnz = 8, 24
    r = rng.integers(0, n_rows, nnz)
    c = rng.integers(0, n_rows, nnz)
    v = rng.integers(0, 50, nnz, dtype=np.uint64)
    xv = rng.integers(0, 50, n_rows, dtype=np.uint64)
    y, ctr = spmv.ap_spmv(r, c, v, xv, n_rows, m=6)
    err = float(np.abs(y - spmv.reference(r, c, v, xv, n_rows)).max())
    yield "spmv", f"{nnz}nnz", ctr["cycles"], ctr["energy"], err

    db = rng.integers(0, 16, (64, 4), dtype=np.uint64)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    idx, ctr = knn.ap_knn(db, q, k=5, m=4)
    err = float(np.abs(idx - knn.reference(db, q, 5)).max())
    yield ("knn", "64x4", ctr["cycles"] - ctr["read_cycles"],
           ctr["energy"], err)

    xs = rng.integers(0, 64, 128, dtype=np.uint64)
    h, ctr = histogram.ap_histogram(xs, 8, m=6)
    err = float(np.abs(h - histogram.reference(xs, 8, m=6)).max())
    yield "hist", 128, ctr["cycles"], ctr["energy"], err


def _timed(fn, repeats: int = 3) -> float:
    """Best-of-N wall clock (the jit caches are already warm).

    Best-of damps one-sided scheduler noise on loaded CI runners; the
    gated speedup ratios keep ~2x margin over their 10x floor even for
    the tightest workload (knn), so both sides get multiple samples.
    """
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def scaling_rows(ns, rec: Recorder):
    """Device-resident trace-generation scaling + eager-vs-device speedup.

    Device timings are steady-state (one warm call first — repeat
    instances of a workload shape share the compiled program); the
    eager oracle has no compile step, so it is timed directly.
    """
    print("workload,n_elems,cycles,device_wall_s,cycles_per_s,"
          "eager_wall_s,speedup")
    for name in SCALING_WORKLOADS:
        for n in ns:
            ctr = registry.trace_counters(name, n)      # warm + compile
            t_dev = _timed(lambda: registry.trace_counters(name, n))
            cycles = int(ctr["cycles"])
            rec.add(**{f"device_wall_s_{name}_{n}": t_dev,
                       f"cycles_per_s_{name}_{n}": cycles / t_dev})
            t_eager = speedup = None
            if n == 256 and name in SPEEDUP_WORKLOADS:
                t_eager = _timed(lambda: registry.trace_counters(
                    name, n, mode="eager"), repeats=2)
                speedup = t_eager / t_dev
                rec.add(**{f"eager_wall_s_{name}_{n}": t_eager,
                           f"speedup_{name}_{n}": speedup})
            print(f"{name},{n},{cycles},{t_dev:.4f},{cycles / t_dev:.3e},"
                  f"{'' if t_eager is None else f'{t_eager:.3f}'},"
                  f"{'' if speedup is None else f'{speedup:.1f}'}")
    rec.add(n_scaling_points=len(SCALING_WORKLOADS) * len(ns))


_SHARD_CHECK = r"""
import numpy as np
from repro.workloads import sort
rng = np.random.default_rng(0)
x = rng.integers(0, 256, 2048, dtype=np.uint64)
runs = {ns: sort.ap_sort(x, m=8, mode="megakernel", n_shards=ns)
        for ns in (None, 2)}
v0, c0 = runs[None]
v1, c1 = runs[2]
ok = np.array_equal(v0, v1)
for k in c0:
    a, b = c0[k], c1[k]
    ok = ok and (np.array_equal(a, b) if isinstance(a, np.ndarray)
                 else a == b)
print("SHARD-INVARIANCE", int(ok))
"""


def megakernel_rows(rec: Recorder):
    """Megakernel path: wall-clock vs the device-resident path at
    n=2048, the lifted-clamp n=65536 trace point, and bitwise shard
    invariance (unsharded vs 2 forced host devices, in a subprocess
    because ``--xla_force_host_platform_device_count`` must be set
    before jax initializes).

    Sort is the timing workload — its per-round host replay dominated
    the device path at n=2048, which is exactly what the megakernel's
    bulk accounting fold (engine ``charge_bulk``) removes.
    """
    call_mk = lambda: registry.trace_counters("sort", MEGA_N,
                                              mode="megakernel")
    call_dev = lambda: registry.trace_counters("sort", MEGA_N,
                                               mode="device")
    call_mk(), call_dev()                       # warm + compile
    t_mk = _timed(call_mk)
    t_dev = _timed(call_dev)
    speedup = t_dev / t_mk
    rec.add(megakernel_wall_s_sort_2048=t_mk,
            device_wall_s_vs_mk_sort_2048=t_dev,
            megakernel_speedup_x=speedup)
    print(f"\n# megakernel vs device at n={MEGA_N} (gated >= 2x): "
          f"device={t_dev:.4f}s megakernel={t_mk:.4f}s "
          f"speedup={speedup:.1f}x")

    ctr = registry.trace_counters("sort", MEGA_BIG_N, mode="megakernel")
    t_big = _timed(lambda: registry.trace_counters(
        "sort", MEGA_BIG_N, mode="megakernel"), repeats=2)
    rec.add(megakernel_big_n=float(MEGA_BIG_N),
            megakernel_wall_s_sort_65536=t_big,
            megakernel_cycles_sort_65536=float(ctr["cycles"]))
    print(f"# megakernel n={MEGA_BIG_N}: cycles={int(ctr['cycles'])} "
          f"wall={t_big:.3f}s (old trace_elems cap: 2048)")

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARD_CHECK],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=600)
    ok = proc.returncode == 0 and "SHARD-INVARIANCE 1" in proc.stdout
    if not ok:
        print(proc.stdout[-2000:], proc.stderr[-2000:], file=sys.stderr)
    rec.add(shard_invariance_ok=float(ok))
    print(f"# shard invariance (1 vs 2 devices, bitwise): "
          f"{'OK' if ok else 'FAIL'}")


def obs_overhead(rec: Recorder) -> float:
    """Enabled-vs-disabled telemetry overhead on a warm scaling call.

    Times ``registry.trace_counters("sort", 256)`` (jit cache warm, so
    every obs touch point on the path — retrace counters are trace-time
    only and do NOT fire here — is exercised at steady state) with obs
    off, then on; the ratio is gated ≤ 1.05x in ``baseline.json``.
    """
    registry.trace_counters("sort", 256)            # warm + compile
    call = lambda: registry.trace_counters("sort", 256)
    with obs.scoped(on=False):
        t_off = _timed(call, repeats=5)
    with obs.scoped(on=True):
        t_on = _timed(call, repeats=5)
    ratio = t_on / max(t_off, 1e-9)
    rec.add(obs_overhead_x=ratio)
    print(f"\n# obs overhead: off={t_off:.4f}s on={t_on:.4f}s "
          f"ratio={ratio:.3f}x (gated <= 1.05x)")
    return ratio


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaling sizes {64, 256} only (CI smoke lane)")
    args = ap.parse_args(argv)
    rec = Recorder("workloads")
    print("workload,n,compute_cycles,energy_norm,max_err")
    for name, n, cycles, energy, err in rows():
        print(f"{name},{n},{cycles},{energy:.3e},{err}")
        rec.add(**{f"cycles_{name}": cycles, f"max_err_{name}": err})
    print("\n# device-resident scaling (speedup gated >= 10x at n=256)")
    scaling_rows(QUICK_NS if args.quick else SCALING_NS, rec)
    megakernel_rows(rec)
    obs_overhead(rec)
    return rec.finish()


if __name__ == "__main__":
    main()
