"""Paper §3.1 workloads executed on the AP emulator: cycles + accuracy."""
import numpy as np

from repro.workloads import blackscholes as bs
from repro.workloads import dmm, fft


def main():
    rng = np.random.default_rng(0)
    print("workload,n,compute_cycles,energy_norm,max_err")

    A = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    B = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    C, ctr = dmm.ap_matmul(A, B, m=6)
    err = float(np.abs(C.astype(np.int64)
                       - dmm.reference(A, B).astype(np.int64)).max())
    print(f"dmm,8x8,{ctr['mac_cycles']},{ctr['energy']:.3e},{err}")

    N = 16
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.4 / np.sqrt(N))
    X, ctr = fft.ap_fft(x, m=16, frac=12)
    rel = float(np.max(np.abs(X - fft.reference(x)))
                / np.max(np.abs(fft.reference(x))))
    print(f"fft,{N},{ctr['cycles'] - ctr['read_cycles']},"
          f"{ctr['energy']:.3e},{rel:.4f}")

    n = 64
    S = rng.uniform(0.8, 1.6, n)
    K = rng.uniform(0.8, 1.6, n)
    T = rng.uniform(0.3, 2.0, n)
    sig = rng.uniform(0.15, 0.6, n)
    prices, ctr = bs.ap_blackscholes(S, K, T, sig)
    err = float(np.abs(prices - bs.reference(S, K, T, sig)).max())
    print(f"blackscholes,{n},{ctr['cycles'] - ctr['read_cycles']},"
          f"{ctr['energy']:.3e},{err:.4f}")


if __name__ == "__main__":
    main()
