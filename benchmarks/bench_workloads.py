"""The workload suite executed on the AP emulator: cycles + accuracy.

Paper §3.1 trio (dmm / fft / blackscholes) plus the suite additions
(sort / spmv / knn / histogram); every row is an exact small instance
checked against its NumPy oracle.  Per-workload cycles and max error
land in ``BENCH_workloads.json``.
"""
import argparse

import numpy as np

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.workloads import blackscholes as bs
from repro.workloads import dmm, fft, histogram, knn, sort, spmv


def rows():
    rng = np.random.default_rng(0)

    A = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    B = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    C, ctr = dmm.ap_matmul(A, B, m=6)
    err = float(np.abs(C.astype(np.int64)
                       - dmm.reference(A, B).astype(np.int64)).max())
    yield "dmm", "8x8", ctr["mac_cycles"], ctr["energy"], err

    N = 16
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.4 / np.sqrt(N))
    X, ctr = fft.ap_fft(x, m=16, frac=12)
    rel = float(np.max(np.abs(X - fft.reference(x)))
                / np.max(np.abs(fft.reference(x))))
    yield "fft", N, ctr["cycles"] - ctr["read_cycles"], ctr["energy"], rel

    n = 64
    S = rng.uniform(0.8, 1.6, n)
    K = rng.uniform(0.8, 1.6, n)
    T = rng.uniform(0.3, 2.0, n)
    sig = rng.uniform(0.15, 0.6, n)
    prices, ctr = bs.ap_blackscholes(S, K, T, sig)
    err = float(np.abs(prices - bs.reference(S, K, T, sig)).max())
    yield ("blackscholes", n, ctr["cycles"] - ctr["read_cycles"],
           ctr["energy"], err)

    xs = rng.integers(0, 200, 64, dtype=np.uint64)
    ys, ctr = sort.ap_sort(xs, m=8)
    err = float(np.abs(ys.astype(np.int64)
                       - sort.reference(xs).astype(np.int64)).max())
    yield "sort", 64, ctr["cycles"], ctr["energy"], err

    n_rows, nnz = 8, 24
    r = rng.integers(0, n_rows, nnz)
    c = rng.integers(0, n_rows, nnz)
    v = rng.integers(0, 50, nnz, dtype=np.uint64)
    xv = rng.integers(0, 50, n_rows, dtype=np.uint64)
    y, ctr = spmv.ap_spmv(r, c, v, xv, n_rows, m=6)
    err = float(np.abs(y - spmv.reference(r, c, v, xv, n_rows)).max())
    yield "spmv", f"{nnz}nnz", ctr["cycles"], ctr["energy"], err

    db = rng.integers(0, 16, (64, 4), dtype=np.uint64)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    idx, ctr = knn.ap_knn(db, q, k=5, m=4)
    err = float(np.abs(idx - knn.reference(db, q, 5)).max())
    yield ("knn", "64x4", ctr["cycles"] - ctr["read_cycles"],
           ctr["energy"], err)

    xs = rng.integers(0, 64, 128, dtype=np.uint64)
    h, ctr = histogram.ap_histogram(xs, 8, m=6)
    err = float(np.abs(h - histogram.reference(xs, 8, m=6)).max())
    yield "hist", 128, ctr["cycles"], ctr["energy"], err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for driver uniformity (no-op here)")
    ap.parse_args(argv)
    rec = Recorder("workloads")
    print("workload,n,compute_cycles,energy_norm,max_err")
    for name, n, cycles, energy, err in rows():
        print(f"{name},{n},{cycles},{energy:.3e},{err}")
        rec.add(**{f"cycles_{name}": cycles, f"max_err_{name}": err})
    return rec.finish()


if __name__ == "__main__":
    main()
