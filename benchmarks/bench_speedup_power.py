"""Paper Figs 6 & 7: speedup-vs-area and power-vs-area for BS/FFT/DMM,
plus the same-performance design points and break-even areas."""
import argparse

import numpy as np

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.core import models as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for driver uniformity (no-op here)")
    ap.parse_args(argv)
    rec = Recorder("speedup_power")
    print("== Fig 6/7 curves (area sweep) ==")
    areas = np.geomspace(0.5, 100, 7)
    for name in M.WORKLOADS:
        s_simd, s_ap = M.speedup_vs_area_curves(name, areas)
        p_simd, p_ap = M.power_vs_area_curves(name, areas)
        print(f"workload={name}")
        for i, a in enumerate(areas):
            print(f"  area={a:7.2f}mm2  S_simd={s_simd[i]:8.1f} "
                  f"S_ap={s_ap[i]:8.1f}  P_simd={p_simd[i]:7.3f}W "
                  f"P_ap={p_ap[i]:7.3f}W")
        be = M.break_even_area_mm2(name)
        print(f"  break-even area = {be:.2f} mm^2")
        rec.add(**{f"break_even_mm2_{name}": be})

    print("== same-performance design point (DMM, Fig 6/7 black dots) ==")
    dp = M.paper_design_point("dmm")
    print(f"speedup={dp.speedup:.0f}")
    print(f"AP:   {dp.ap_n_pus} PUs, {dp.ap_area_mm2:.1f} mm^2, "
          f"{dp.ap_power_W:.2f} W")
    print(f"SIMD: {dp.simd_n_pus} PUs, {dp.simd_area_mm2:.1f} mm^2, "
          f"{dp.simd_power_W:.2f} W")
    print(f"power ratio x{dp.power_ratio:.2f} (paper: >2); "
          f"power density ratio x{dp.power_density_ratio:.1f} (paper: ~25)")
    rec.add(dmm_speedup=dp.speedup, dmm_power_ratio=dp.power_ratio,
            dmm_power_density_ratio=dp.power_density_ratio)
    return rec.finish()


if __name__ == "__main__":
    main()
