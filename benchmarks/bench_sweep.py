"""Batched scenario sweep: workloads × dataset sizes × DRAM stack heights
through the cached vmapped closed-loop path (`repro.sweep`).

The default grid is 4 workloads (three of them suite additions beyond
the paper's trio) × 2 dataset sizes × 3 DRAM die counts = 24 scenario
points, each replayed for the AP and the same-performance SIMD in one
vmapped batch per (stack height, feedback mode) group.  Prints the
per-point peak-temperature / seconds-above-85 °C / verdict table; the
result is persisted under the content-hashed sweep cache, so a second
invocation is served bit-identically from disk (the "cached:" line
says which happened).

``--quick`` shrinks the grid for the CI smoke lane; ``--no-cache``
forces a live replay; ``--shards N`` partitions the case batch over N
local devices (`shard_map`); ``--solver mg`` swaps the fixed-cost inner
solve to multigrid V-cycles.  ``--cache-roundtrip`` is the CI cache
check: run the sweep, then run it AGAIN and require the second pass to
be served from disk — one invocation, explicit cold-run/warm-run
semantics (exit 1 on a warm miss).  Metrics land in
``BENCH_sweep.json``.
"""
import argparse
import sys
import time

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.sweep import SweepSpec, run_sweep
from repro.sweep import cache as sweep_cache


def quick_spec(solver: str = "pcg") -> SweepSpec:
    """The CI smoke-lane spec (also keys the CI .sweep_cache entry)."""
    return SweepSpec(workloads=("sort", "hist"), sizes=(4096, 2 ** 20),
                     n_dram=(2,), grid_n=8, n_intervals=8,
                     steps_per_interval=1, n_cg=25, solver=solver)


def full_spec(solver: str = "pcg") -> SweepSpec:
    return SweepSpec(workloads=("dmm", "sort", "knn", "hist"),
                     sizes=(2 ** 14, 2 ** 20), n_dram=(1, 2, 4),
                     grid_n=12, n_intervals=16,
                     steps_per_interval=1, n_cg=30, n_picard=20,
                     solver=solver)


def run_once(spec: SweepSpec, use_cache: bool, n_shards) -> tuple:
    t0 = time.time()
    res = run_sweep(spec, use_cache=use_cache, n_shards=n_shards)
    dt = time.time() - t0
    print(f"sweep: {spec.n_points} points x {len(spec.machines)} machines "
          f"({', '.join(spec.workloads)}; sizes {list(spec.sizes)}; "
          f"DRAM dies {list(spec.n_dram)}; solver {spec.solver}"
          f"{f'; {n_shards} shards' if n_shards else ''}) in {dt:.1f}s")
    print(f"cached: {'HIT (served from disk)' if res.from_cache else 'MISS'}"
          f" key={spec.content_hash()} "
          f"path={sweep_cache.path_for(spec)}")
    return res, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 workloads x 2 sizes x 1 stack (CI smoke lane)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--shards", type=int, default=0,
                    help="partition the case batch over N local devices")
    ap.add_argument("--solver", default="pcg", choices=("pcg", "mg"),
                    help="fixed-cost inner solve per implicit step")
    ap.add_argument("--cache-roundtrip", action="store_true",
                    help="run twice; the second pass MUST hit the disk "
                         "cache (exit 1 otherwise)")
    args = ap.parse_args(argv)

    if args.cache_roundtrip and args.no_cache:
        raise SystemExit("--cache-roundtrip requires the cache")
    spec = quick_spec(args.solver) if args.quick else full_spec(args.solver)
    rec = Recorder("sweep")
    n_shards = args.shards or None

    res, dt = run_once(spec, not args.no_cache, n_shards)
    rec.add(sweep_wall_s=dt, cold_from_cache=res.from_cache)
    print(res.table())
    for r in res.records:
        assert r.report.converged, (r.label, r.report.residual_C.max())
    n_ok = sum(r.verdict_ok for r in res.records)
    print(f"# {n_ok}/{len(res.records)} cases clear the 85C 3D-DRAM "
          f"ceiling")
    rec.add(n_cases=len(res.records), n_ok=n_ok,
            max_logic_peak_C=max(float(r.report.logic_peak_C.max())
                                 for r in res.records),
            max_dram_peak_C=max(float(r.report.dram_peak_C.max())
                                for r in res.records))

    if args.cache_roundtrip:
        res2, dt2 = run_once(spec, True, n_shards)
        rec.add(warm_wall_s=dt2, warm_from_cache=res2.from_cache)
        if not res2.from_cache:
            rec.finish()
            raise SystemExit("cache-roundtrip FAILED: warm run was not "
                             "served from disk")
        print("# cache-roundtrip OK: warm run served from disk")
    return rec.finish()


if __name__ == "__main__":
    main(sys.argv[1:])
