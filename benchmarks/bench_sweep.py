"""Batched scenario sweep: workloads × dataset sizes × DRAM stack heights
through the cached vmapped closed-loop path (`repro.sweep`).

The default grid is 4 workloads (three of them suite additions beyond
the paper's trio) × 2 dataset sizes × 3 DRAM die counts = 24 scenario
points, each replayed for the AP and the same-performance SIMD in one
vmapped batch per (stack height, feedback mode) group.  Prints the
per-point peak-temperature / seconds-above-85 °C / verdict table; the
result is persisted under the content-hashed sweep cache, so a second
invocation is served bit-identically from disk (the "cached:" line
says which happened).

``--quick`` shrinks the grid for the CI smoke lane; ``--no-cache``
forces a live replay.
"""
import argparse
import sys
import time

from repro.sweep import SweepSpec, run_sweep
from repro.sweep import cache as sweep_cache


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 workloads x 2 sizes x 1 stack (CI smoke lane)")
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args(argv if argv is not None else [])

    if args.quick:
        spec = SweepSpec(workloads=("sort", "hist"), sizes=(4096, 2 ** 20),
                         n_dram=(2,), grid_n=8, n_intervals=8,
                         steps_per_interval=1, n_cg=25)
    else:
        spec = SweepSpec(workloads=("dmm", "sort", "knn", "hist"),
                         sizes=(2 ** 14, 2 ** 20), n_dram=(1, 2, 4),
                         grid_n=12, n_intervals=16,
                         steps_per_interval=1, n_cg=30, n_picard=20)

    t0 = time.time()
    res = run_sweep(spec, use_cache=not args.no_cache)
    dt = time.time() - t0
    print(f"sweep: {spec.n_points} points x {len(spec.machines)} machines "
          f"({', '.join(spec.workloads)}; sizes {list(spec.sizes)}; "
          f"DRAM dies {list(spec.n_dram)}) in {dt:.1f}s")
    print(f"cached: {'HIT (served from disk)' if res.from_cache else 'MISS'}"
          f" key={spec.content_hash()} "
          f"path={sweep_cache.path_for(spec)}")
    print(res.table())
    for r in res.records:
        assert r.report.converged, (r.label, r.report.residual_C.max())
    n_ok = sum(r.verdict_ok for r in res.records)
    print(f"# {n_ok}/{len(res.records)} cases clear the 85C 3D-DRAM "
          f"ceiling")


if __name__ == "__main__":
    main(sys.argv[1:])
