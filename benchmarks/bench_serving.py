"""LLM-serving traffic → thermal interval co-simulation (docs/serving.md).

Replays ≥1 h of request traffic against the AP and the
same-performance SIMD 3D stacks for a grid of (model config × traffic
shape) serving scenarios, through the adaptive-coarsening closed loop
(`repro.serving`).  Prints the per-scenario SLA/thermal verdict table
(offered QPS, p50/p99 latency under DTM, peak temperatures,
time-above-85 °C, coarsening ratio) and one throughput-vs-throttle
curve; the coarsening ratio is the gated headline — the adaptive plan
must replay ≥5× fewer solver intervals than the uniform grid while the
property-tested error bound (tests/test_coarsen_replay.py) holds.

``--quick`` is the CI smoke lane: 2 configs × 2 traffic shapes over one
simulated hour.  The full lane adds the constant-QPS shape and a second
simulated hour.  Metrics land in ``BENCH_serving.json``.
"""
import argparse
import sys
import time

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.serving import ServingScenario, TrafficSpec, run_serving_cosim, \
    verdict_table

QUICK_CONFIGS = ("stablelm-1.6b", "deepseek-v2-lite-16b")
QUICK_SHAPES = ("diurnal", "bursty")
FULL_SHAPES = ("diurnal", "bursty", "constant")


def scenarios(quick: bool) -> list[ServingScenario]:
    configs = QUICK_CONFIGS
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    horizon = 3600.0 if quick else 7200.0
    return [
        ServingScenario(
            config=config,
            traffic=TrafficSpec(shape=shape, horizon_s=horizon),
            load=0.7, grid_n=8, coarsen_tol=0.02, pad_quantum=64,
            n_rounds=2 if quick else 3)
        for config in configs for shape in shapes
    ]


def _key(scenario: ServingScenario, machine: str) -> str:
    config = scenario.config.replace("-", "_").replace(".", "_")
    return f"{config}_{scenario.traffic.shape}_{machine}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 configs x 2 shapes x 1h (CI smoke lane)")
    args = ap.parse_args(argv)

    rec = Recorder("serving")
    cases = scenarios(args.quick)
    all_reports: dict[str, dict] = {}
    ratios, ap_residuals, bounds = [], [], []
    for sc in cases:
        t0 = time.time()
        reps = run_serving_cosim(sc)
        dt = time.time() - t0
        all_reports[sc.label] = reps
        r0 = next(iter(reps.values()))
        print(f"{sc.label}: {r0.mean_qps:.3f} qps offered over "
              f"{sc.traffic.horizon_s:.0f}s -> {r0.n_coarse} coarse "
              f"intervals from {r0.n_base} "
              f"({r0.coarsen_ratio:.1f}x, bound "
              f"{r0.error_bound_C:.2f}C) in {dt:.1f}s")
        for machine, r in reps.items():
            ratios.append(r.coarsen_ratio)
            if machine == "ap":     # SIMD may flip a DTM boundary interval
                ap_residuals.append(r.throttle_residual)
            bounds.append(r.error_bound_C)
            rec.add(**{
                f"{_key(sc, machine)}_logic_peak_C":
                    float(r.stack.logic_peak_C.max()),
                f"{_key(sc, machine)}_dram_peak_C":
                    float(r.stack.dram_peak_C.max()),
                f"{_key(sc, machine)}_p99_s": r.p99_s,
                f"{_key(sc, machine)}_dtm_x": r.dtm_slowdown,
                f"{_key(sc, machine)}_above85_s": r.time_above(),
            })

    print()
    print(verdict_table(all_reports))
    first_ap = next(iter(all_reports.values()))["ap"]
    centers, qps, secs = first_ap.throttle_curve()
    print(f"\n# throughput-vs-throttle ({first_ap.label}):")
    for c, q, s in zip(centers, qps, secs):
        print(f"#   f={c:.3f}  served={q:.3f} qps  ({s:.0f}s)")

    n_ap_ok = sum(r["ap"].verdict_ok for r in all_reports.values())
    n_simd_ok = sum(r["simd"].verdict_ok for r in all_reports.values())
    print(f"\n# AP clears the 85C DRAM ceiling in {n_ap_ok}/{len(cases)} "
          f"scenarios; SIMD in {n_simd_ok}/{len(cases)}")
    rec.add(n_cases=len(cases), n_ap_ok=n_ap_ok, n_simd_ok=n_simd_ok,
            min_coarsen_x=min(ratios),
            max_ap_throttle_residual=max(ap_residuals),
            max_error_bound_C=max(bounds))
    return rec.finish()


if __name__ == "__main__":
    main(sys.argv[1:])
