"""Paper §2.2 cycle-count claims: 8m add, O(m^2) multiply, ~4400-cycle FP32
multiply (length-independent), and the three workloads' compute cycles."""
import numpy as np

from repro.core import apfloat, arith, isa
from repro.core.engine import APEngine


def rows():
    out = []
    # --- fixed-point add: 8m cycles ---------------------------------------
    for m in (8, 16, 32):
        eng = APEngine(n_words=256, n_bits=2 * m + 2)
        a = eng.alloc.alloc(m)
        b = eng.alloc.alloc(m)
        c = eng.alloc.alloc(1)
        rng = np.random.default_rng(m)
        eng.load(a, rng.integers(0, 1 << m, 256, dtype=np.uint64))
        eng.load(b, rng.integers(0, 1 << m, 256, dtype=np.uint64))
        c0 = eng.cycles
        isa.run_add(eng, a, b, c)
        out.append((f"add_m{m}", eng.cycles - c0, f"paper 8m = {8 * m}"))

    # --- fixed-point multiply: O(m^2) --------------------------------------
    for m in (8, 16):
        eng = APEngine(n_words=256, n_bits=4 * m + 4)
        a = eng.alloc.alloc(m)
        b = eng.alloc.alloc(m)
        prod = eng.alloc.alloc(2 * m)
        c = eng.alloc.alloc(1)
        rng = np.random.default_rng(m)
        eng.load(a, rng.integers(0, 1 << m, 256, dtype=np.uint64))
        eng.load(b, rng.integers(0, 1 << m, 256, dtype=np.uint64))
        c0 = eng.cycles
        arith.run_mul(eng, a, b, prod, c)
        out.append((f"mul_m{m}", eng.cycles - c0, f"paper O(m^2) ~ {8 * m * m}"))

    # --- fp32 multiply: ~4400 cycles, independent of N ---------------------
    for n in (64, 1024):
        eng = APEngine(n_words=n, n_bits=256)
        x = apfloat.FpField.alloc(eng)
        y = apfloat.FpField.alloc(eng)
        z = apfloat.FpField.alloc(eng)
        s = apfloat.FpScratch.alloc(eng)
        rng = np.random.default_rng(n)
        apfloat.load_fp32(eng, x, rng.normal(size=n).astype(np.float32))
        apfloat.load_fp32(eng, y, rng.normal(size=n).astype(np.float32))
        c0 = eng.cycles
        apfloat.fp_mul(eng, x, y, z, s)
        out.append((f"fp32_mul_N{n}", eng.cycles - c0,
                    "paper 4400, length-independent"))
    return out


def main(argv=None):
    import argparse

    try:                                # python -m benchmarks.run ...
        from benchmarks._record import Recorder
    except ImportError:                 # python benchmarks/bench_*.py
        from _record import Recorder

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for driver uniformity (no-op here)")
    ap.parse_args(argv)
    rec = Recorder("cycles")
    print("name,cycles,reference")
    for name, cycles, ref in rows():
        print(f"{name},{cycles},{ref}")
        rec.add(**{f"cycles_{name}": cycles})
    return rec.finish()


if __name__ == "__main__":
    main()
