"""Benchmark driver — one section per paper table/figure + the roofline
deliverable.

    PYTHONPATH=src python -m benchmarks.run [--quick] [section ...]

Every section's ``main(argv)`` records machine-readable metrics and
writes ``BENCH_<name>.json`` next to its text table
(``benchmarks/_record.py``); ``--quick`` forwards the CI smoke-lane
flag to each section.  ``tools/check_bench.py`` gates the JSON
artifacts against ``benchmarks/baseline.json``.
"""
import argparse
import sys
import time

from benchmarks import (bench_ap_backend, bench_cycles, bench_faults,
                        bench_policy, bench_roofline, bench_serving,
                        bench_speedup_power, bench_stack, bench_sweep,
                        bench_thermal, bench_workloads)

SECTIONS = {
    "cycles": ("§2.2 cycle-count claims", bench_cycles.main),
    "speedup_power": ("Figs 6/7 speedup & power vs area",
                      bench_speedup_power.main),
    "workloads": ("§3.1 workloads on the AP emulator",
                  bench_workloads.main),
    "thermal": ("§4 thermal comparison (Figs 10/12/13) + solver "
                "shoot-out", bench_thermal.main),
    "stack": ("abstract claim: AP+DRAM vs SIMD+DRAM closed-loop "
              "stacks (refresh/leakage/DTM feedback)",
              bench_stack.main),
    "sweep": ("scenario sweep: workloads x sizes x stacks through the "
              "cached vmapped path", bench_sweep.main),
    "policy": ("DTM/DVFS policy shoot-out: Pareto frontiers + verdict "
               "flips over the policy axis", bench_policy.main),
    "serving": ("LLM-serving traffic -> thermal co-simulation "
                "(SLA + coarsening headline)", bench_serving.main),
    "faults": ("fault injection: sensor faults vs GuardedPolicy, "
               "power spikes, solver fallback chain", bench_faults.main),
    "roofline": ("§Roofline per-cell terms (dry-run artifacts)",
                 bench_roofline.main),
    "ap_backend": ("paper-technique x assigned archs (AP vs TPU)",
                   bench_ap_backend.main),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="forward the CI smoke-lane flag to every section")
    ap.add_argument("sections", nargs="*", choices=[[]] + list(SECTIONS),
                    help="sections to run (default: all)")
    args = ap.parse_args(argv)
    wanted = args.sections or list(SECTIONS)
    section_argv = ["--quick"] if args.quick else []
    for name in wanted:
        title, fn = SECTIONS[name]
        print(f"\n===== {name}: {title} =====", flush=True)
        t0 = time.time()
        fn(section_argv)
        print(f"----- {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
