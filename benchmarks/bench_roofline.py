"""§Roofline deliverable: per-(arch x shape) terms from the dry-run
artifacts (single-pod table + multi-pod check)."""
import argparse
import json
import pathlib

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

ART = pathlib.Path("artifacts/dryrun")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for driver uniformity (no-op here)")
    ap.parse_args(argv)
    rec = Recorder("roofline")
    d = ART / "pod16x16"
    if not d.exists():
        print("no dry-run artifacts found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --arch all "
              "--shape all --mesh both")
        rec.add(n_cells=0)
        return rec.finish()
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_flop_ratio,mem_GiB_per_dev")
    recs = [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]
    recs.sort(key=lambda r: (r["shape"], r["arch"]))
    for r in recs:
        rf = r["roofline"]
        print(f"{r['arch']},{r['shape']},{rf['compute_s']:.3e},"
              f"{rf['memory_s']:.3e},{rf['collective_s']:.3e},"
              f"{rf['dominant']},{rf['useful_flop_ratio']:.2f},"
              f"{r['memory']['peak_bytes_per_device'] / 2**30:.2f}")
    multi = sorted((ART / "pod2x16x16").glob("*.json"))
    print(f"multi-pod cells compiled: {len(multi)}")
    rec.add(n_cells=len(recs), n_multi_pod_cells=len(multi))
    return rec.finish()


if __name__ == "__main__":
    main()
