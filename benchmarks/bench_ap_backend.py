"""Paper-technique x assigned-architecture integration surface: map each
(arch x shape) cell's useful FLOPs onto the AP cost model (cycles via
bit-serial op costs, power via eq 17) and contrast with the TPU v5e
roofline bound from the dry-run.

This is the honest comparison the paper invites: the AP is 'compute in
memory' — zero weight-streaming traffic — but bit-serial: ~5500 cycles per
fp32 MAC.  For MAC-dominated LM steps the v5e wins on raw throughput by
orders of magnitude; the AP's regime is the memory-/collective-bound corner
(decode) and, per the paper, the THERMAL envelope: W per result at equal
area (see DESIGN.md §4)."""
import argparse
import json
import pathlib

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.core import models as M

ART = pathlib.Path("artifacts/dryrun/pod16x16")


def main(argv=None):
    parser = argparse.ArgumentParser()   # "ap" is taken by the estimate
    parser.add_argument("--quick", action="store_true",
                        help="accepted for driver uniformity (no-op here)")
    parser.parse_args(argv)
    rec = Recorder("ap_backend")
    if not ART.exists():
        print("run the dry-run first")
        rec.add(n_cells=0)
        return rec.finish()
    n_cells = 0
    print("arch,shape,tpu_bound_s,ap_seconds,ap_joules,tpu_advantage_x")
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        rf = r["roofline"]
        tpu_bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        # global useful flops for the step
        flops = rf["model_flops_per_device"] * r["n_chips"]
        ap = M.ap_backend_estimate(flops)      # one 2^20-PU AP
        adv = ap["seconds"] / tpu_bound if tpu_bound > 0 else float("inf")
        print(f"{r['arch']},{r['shape']},{tpu_bound:.3e},"
              f"{ap['seconds']:.3e},{ap['joules']:.3e},{adv:.1e}")
        n_cells += 1
    rec.add(n_cells=n_cells)
    return rec.finish()


if __name__ == "__main__":
    main()
