"""Robustness shoot-out: the policy family under sensor faults.

Replays a small scenario grid (the PR-9 verdict-flip point ``sort/ap``
plus the hot ``dmm/simd`` stack, closed loop, dram2) under three
sensing regimes — perfect sensors, a stuck-at primary sensor, and
heavy dropout — once with the naive DRAM-sensing per-die controller
and once with its :class:`repro.faults.GuardedPolicy` wrapper
(median-of-3 fusion, last-good hold, fail-safe floor).

The headline metrics tell the graceful-degradation story end to end:

- ``n_guard_rescued`` — (scenario × fault) cells where the NAIVE
  policy violates the 85 °C DRAM ceiling (or NaNs out entirely) while
  the guarded wrapper holds the ceiling under the *same* fault.  The
  stuck-at cell is the canonical case: the primary sensor latches at
  ~ambient, the naive per-die controller never trips, and the DRAM
  runs to ~95 °C — the guard's median still sees the true temperature
  and throttles exactly like the fault-free replay.
- ``n_naive_lost`` — naive replays whose temperatures go non-finite
  (dropout NaN readings propagate through the duty into the physics).
- ``fallback_attempts`` / ``fallback_recovered`` — a forced-divergence
  steady solve (``poison_solver("mg")``) demonstrably recovered by the
  ``core/thermal.py`` fallback chain, retry counters in the obs
  telemetry (``thermal/fallback/*`` in ``BENCH_faults.json``).
- a transient power-spike injection (``PowerFaultSpec``) on the
  ``sort/ap`` trace, showing the input-fault path raises the peak.

``tools/check_bench.py`` gates ``n_guard_rescued >= 1`` and the
numbers behind the stuck-sensor story (``baseline.json``, section
"faults").  Metrics land in ``BENCH_faults.json``.
"""
import argparse
import sys
import time

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

import numpy as np

from repro.core import cosim, thermal
from repro.core import models as M
from repro.faults import (GuardedPolicy, PowerFaultSpec, SensorFaultSpec,
                          inject_power_spikes, poison_solver)
from repro.policy import PerDiePolicy
from repro.stack import feedback
from repro.stack.spec import PAPER_STACK, dram_on_logic

GRID_N = 8
N_INTERVALS = 16
N_CG = 25
T_END = 0.25

#: the swept sensing regimes (None = perfect sensors, the reference)
FAULTS: dict[str, SensorFaultSpec | None] = {
    "none": None,
    "stuck": SensorFaultSpec(seed=0, n_sensors=3, n_stuck=1),
    "dropout": SensorFaultSpec(seed=0, n_sensors=3, p_dropout=0.4),
}


def _cases(margin: int, spec):
    """The two quick scenarios, as pre-assembled replay cases."""
    out = []
    for wl, mc in (("sort", "ap"), ("dmm", "simd")):
        dp = cosim.comparable_design_point(wl, 2 ** 20)
        w = M.WORKLOADS[wl]
        trace = cosim.ap_workload_trace(
            wl, N_INTERVALS, cosim.trace_elems(2 ** 20)) \
            if mc == "ap" else cosim.simd_phase_trace(w, dp, N_INTERVALS)
        out.append((f"{wl}/{mc}", feedback.assemble_case(
            dp, wl, mc, spec, PAPER_STACK, GRID_N, trace, margin)))
    return out


def _verdict(rep) -> str:
    if not np.isfinite(rep.peak_C).all():
        return "FAILED"
    return "OK" if rep.dram_time_above_limit_s == 0.0 else "BLOCKED"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke lane (same grid today; keys the lane)")
    args = ap.parse_args(argv)
    del args

    rec = Recorder("faults")
    spec = dram_on_logic(2, PAPER_STACK)
    margin = GRID_N // 4
    interval_dt = T_END / N_INTERVALS
    cases = _cases(margin, spec)
    policies = {"naive": PerDiePolicy(),
                "guarded": GuardedPolicy(inner=PerDiePolicy())}

    t0 = time.time()
    results: dict[tuple[str, str, str], object] = {}
    for fname, fspec in FAULTS.items():
        for pname, pol in policies.items():
            fb = feedback.FeedbackParams(policy=pol, faults=fspec)
            reps = feedback.replay_cases(
                cases, spec, fb, GRID_N, interval_dt,
                steps_per_interval=1, n_cg=N_CG, margin=margin)
            for label, rep in reps.items():
                results[(label, fname, pname)] = rep
    scenarios = [label for label, _ in cases]
    print(f"faults sweep: {len(scenarios)} scenarios x {len(FAULTS)} "
          f"sensing regimes x {len(policies)} policies in "
          f"{time.time() - t0:.1f}s")

    print(f"\n  {'scenario':<10}{'fault':<9}{'policy':<9}"
          f"{'dram_C':>8}{'slow_x':>8}  verdict")
    n_rescued = n_lost = 0
    for label in scenarios:
        for fname in FAULTS:
            verdicts = {}
            for pname in policies:
                rep = results[(label, fname, pname)]
                v = _verdict(rep)
                verdicts[pname] = v
                if pname == "naive" and v == "FAILED":
                    n_lost += 1
                peak = float(rep.dram_peak_C.max())
                slow = rep.dtm_slowdown
                print(f"  {label:<10}{fname:<9}{pname:<9}"
                      f"{peak:>8.1f}{slow:>8.3f}  {v}")
            if fname != "none" and verdicts["naive"] != "OK" \
                    and verdicts["guarded"] == "OK":
                n_rescued += 1
                print(f"  RESCUED: {label} under {fname}: naive "
                      f"{verdicts['naive']} -> guarded OK")
    print(f"\n# {n_rescued} (scenario x fault) cell(s) rescued by the "
          f"guard; {n_lost} naive replay(s) lost to NaN")
    rec.add(n_scenarios=len(scenarios), n_faults=len(FAULTS),
            n_guard_rescued=n_rescued, n_naive_lost=n_lost)

    # ---- the gated numbers behind the stuck-sensor story ----
    for pname in policies:
        rep = results[("sort/ap", "stuck", pname)]
        rec.add(**{f"sort_ap_stuck_{pname}_dram_peak_C":
                   float(rep.dram_peak_C.max()),
                   f"sort_ap_stuck_{pname}_slowdown_x": rep.dtm_slowdown})

    # ---- transient power-spike injection on the input trace ----
    label, leaves = cases[0]                       # sort/ap
    dyn, l0, r0, lm, F, cap3 = leaves
    spiked = inject_power_spikes(
        dyn, PowerFaultSpec(seed=0, n_spikes=2, magnitude=3.0))
    fb = feedback.FeedbackParams(policy=policies["naive"])
    base, bump = (feedback.replay_cases(
        [(label, (d, l0, r0, lm, F, cap3))], spec, fb, GRID_N,
        interval_dt, steps_per_interval=1, n_cg=N_CG,
        margin=margin)[label] for d in (dyn, spiked))
    delta = float(bump.dram_peak_C.max() - base.dram_peak_C.max())
    print(f"# power spike (2 intervals x3): sort/ap dram peak "
          f"{base.dram_peak_C.max():.1f} -> {bump.dram_peak_C.max():.1f} C"
          f" (+{delta:.1f})")
    rec.add(spike_peak_delta_C=delta)

    # ---- solver fallback chain: forced divergence, then recovery ----
    g = thermal.Grid(die_w=3e-3, ny=16, nx=16, margin=4)
    p = np.zeros((g.n_die_layers, 16, 16), np.float32)
    p[0, 4:12, 4:12] = 0.05
    _, healthy = thermal.steady_state_stats(p, g, solver="mg")
    with poison_solver("mg"):
        _, stats = thermal.steady_state_stats(p, g, solver="mg")
    print(f"# fallback: mg poisoned -> solved_by={stats['solved_by']} "
          f"after {stats['attempts']} attempts "
          f"(rel_residual {stats['rel_residual']:.2g}; healthy run: "
          f"{healthy['attempts']} attempt)")
    rec.add(fallback_attempts=stats["attempts"],
            fallback_recovered=int(stats["solved_by"] != "mg"
                                   and stats["rel_residual"]
                                   <= thermal.HEALTH_RTOL),
            healthy_attempts=healthy["attempts"])
    return rec.finish()


if __name__ == "__main__":
    main(sys.argv[1:])
