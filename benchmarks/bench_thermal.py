"""Paper §4 / Figs 10, 12, 13: AP vs SIMD 4-layer-stack thermal comparison."""
from repro.core.floorplan import thermal_comparison


def main():
    res = thermal_comparison(grid_ap=128, grid_simd=64, workload="dmm")
    dp = res["design_point"]
    print(f"design point: S={dp.speedup:.0f}  "
          f"AP {dp.ap_power_W:.2f}W/layer @{dp.ap_area_mm2:.1f}mm^2  "
          f"SIMD {dp.simd_power_W:.2f}W/layer @{dp.simd_area_mm2:.1f}mm^2")
    print("layer,ap_peak_C,ap_span_C,simd_peak_C,simd_min_C")
    for l in range(4):
        print(f"{l},{res['ap']['peak_C'][l]:.1f},{res['ap']['span_C'][l]:.2f},"
              f"{res['simd']['peak_C'][l]:.1f},{res['simd']['min_C'][l]:.1f}")
    ap_ok = max(res["ap"]["peak_C"]) < 85.0
    simd_ok = res["simd"]["min_C"][0] < 85.0
    print(f"3D-DRAM (85C limit): AP {'OK' if ap_ok else 'BLOCKED'} / "
          f"SIMD {'OK' if simd_ok else 'BLOCKED'}   "
          f"(paper: AP 55C OK, SIMD 98-128C blocked)")


if __name__ == "__main__":
    main()
