"""Paper §4 / Figs 10, 12, 13: AP vs SIMD 4-layer-stack thermal comparison.

Three sections:

1. steady state (the paper's own experiment),
2. solver shoot-out — the same fine-grid steady solve through every
   backend in ``thermal.SOLVERS`` (Jacobi-PCG, stand-alone multigrid,
   MG-preconditioned CG) with wall-clock, iteration counts and
   cross-backend agreement; run at >= 256^2 so the asymptotic gap is
   visible (the multigrid acceptance evidence, ISSUE 4), and
3. transient co-simulation — per-workload power traces replayed through
   the implicit stepper, reporting time-resolved peaks and the per-layer
   time spent above the 85 °C 3D-DRAM ceiling, plus the implicit
   solver's step-count advantage over the explicit oracle.

``--quick`` shrinks the steady/transient grids for the CI smoke lane
(the solver section keeps its 256^2 grid — that IS the point).  Metrics
land in ``BENCH_thermal.json`` (see ``benchmarks/_record.py``).
"""
import argparse
import time

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.core.floorplan import thermal_comparison


def steady_section(rec: Recorder, grid_ap: int, grid_simd: int) -> None:
    res = thermal_comparison(grid_ap=grid_ap, grid_simd=grid_simd,
                             workload="dmm")
    dp = res["design_point"]
    print(f"design point: S={dp.speedup:.0f}  "
          f"AP {dp.ap_power_W:.2f}W/layer @{dp.ap_area_mm2:.1f}mm^2  "
          f"SIMD {dp.simd_power_W:.2f}W/layer @{dp.simd_area_mm2:.1f}mm^2")
    print("layer,ap_peak_C,ap_span_C,simd_peak_C,simd_min_C")
    for l in range(4):
        print(f"{l},{res['ap']['peak_C'][l]:.1f},{res['ap']['span_C'][l]:.2f},"
              f"{res['simd']['peak_C'][l]:.1f},{res['simd']['min_C'][l]:.1f}")
    ap_ok = max(res["ap"]["peak_C"]) < 85.0
    simd_ok = res["simd"]["min_C"][0] < 85.0
    print(f"3D-DRAM (85C limit): AP {'OK' if ap_ok else 'BLOCKED'} / "
          f"SIMD {'OK' if simd_ok else 'BLOCKED'}   "
          f"(paper: AP 55C OK, SIMD 98-128C blocked)")
    rec.add(ap_peak_C=max(res["ap"]["peak_C"]),
            ap_span_C=res["ap"]["span_C"][0],
            simd_peak_C=res["simd"]["peak_C"][0],
            simd_min_C=res["simd"]["min_C"][0],
            ap_dram_ok=ap_ok, simd_dram_blocked=not simd_ok)


def solver_section(rec: Recorder, n: int) -> None:
    """PCG vs multigrid vs MG-CG on one fine-grid steady solve."""
    import numpy as np

    from repro.core import thermal
    from repro.stack.spec import dram_on_logic

    print()
    print(f"steady-state solver shoot-out ({n}x{n} die grid + margin, "
          f"2xDRAM-on-logic stack)")
    spec = dram_on_logic(2)
    grid = thermal.Grid(die_w=5e-3, ny=n, nx=n, margin=n // 4, spec=spec)
    power = np.zeros((grid.n_die_layers, n, n), np.float32)
    # 40 W over the LOGIC dies (they sit below the stacked DRAM)
    power[list(spec.logic_layers)] = 40.0 / (len(spec.logic_layers) * n * n)

    results = {}
    print("solver,iterations,wall_s,peak_C,maxdiff_vs_pcg_C,rel_residual")
    for solver in thermal.SOLVERS:
        T, stats = thermal.steady_state_stats(power, grid, solver=solver)
        T.block_until_ready()               # compile outside the timing
        t0 = time.time()
        T, stats = thermal.steady_state_stats(power, grid, solver=solver)
        T.block_until_ready()
        wall = time.time() - t0
        results[solver] = (np.asarray(T), stats["iterations"], wall)
        diff = float(np.abs(np.asarray(T) - results["pcg"][0]).max())
        print(f"{solver},{stats['iterations']},{wall:.3f},"
              f"{float(T.max()):.2f},{diff:.2e},"
              f"{stats['rel_residual']:.2e}")
        rec.add(**{f"steady_{solver}_iters_{n}": stats["iterations"],
                   f"steady_{solver}_wall_s_{n}": wall,
                   f"steady_{solver}_maxdiff_C_{n}": diff,
                   f"steady_{solver}_relres_{n}": stats["rel_residual"]})
    wall_pcg = results["pcg"][2]
    for solver in ("mg", "mgcg"):
        speedup = wall_pcg / results[solver][2]
        print(f"# {solver} speedup over pcg at {n}^2: {speedup:.1f}x")
        rec.add(**{f"steady_{solver}_speedup_{n}": speedup})


def cosim_section(rec: Recorder, grid_n: int, n_intervals: int,
                  workloads) -> None:
    import math

    from repro.core import cosim, thermal
    from repro.core.floorplan import MM
    from repro.sweep import SweepSpec, run_sweep

    print()
    print(f"transient co-simulation (grid {grid_n}, {n_intervals} intervals, "
          f"implicit theta-scheme)")
    t_end = 0.25
    steps_per_interval = 2
    # the bare 4-layer logic stack, open loop, as one declarative sweep
    spec = SweepSpec(workloads=tuple(workloads), sizes=(2 ** 20,),
                     n_dram=(0,), fb_modes=("open",), grid_n=grid_n,
                     n_intervals=n_intervals, t_end=t_end,
                     steps_per_interval=steps_per_interval)
    res = run_sweep(spec, use_cache=False)
    # implicit step-count advantage vs the CFL-bound explicit oracle, on
    # the exact grids simulated (the AP and SIMD dies of the first workload)
    dp = cosim.comparable_design_point(workloads[0])
    n_imp = n_intervals * steps_per_interval
    for machine, area in (("ap", dp.ap_area_mm2), ("simd", dp.simd_area_mm2)):
        grid = thermal.Grid(die_w=math.sqrt(area) * MM, ny=grid_n, nx=grid_n,
                            margin=grid_n // 4)
        n_exp = max(int(t_end / thermal.explicit_dt(grid)), 1)
        print(f"steps ({workloads[0]}/{machine} die): explicit oracle "
              f"{n_exp}, implicit {n_imp} ({n_exp / n_imp:.0f}x fewer)")
        rec.add(**{f"implicit_step_advantage_{machine}": n_exp / n_imp})
    # one host-stepped implicit solve through the instrumented scan so the
    # telemetry snapshot carries per-step true residuals
    # (thermal/transient/*); the vmapped sweep replay above is fully
    # device-resident and records interval counts only
    import numpy as np
    probe = np.zeros((1, grid_n, grid_n), np.float32)
    probe[0, grid_n // 2, grid_n // 2] = 0.5
    _, pk = thermal.transient_solve_implicit(probe, grid, t_end=t_end,
                                             n_steps=n_imp, n_cg=40)
    rec.add(transient_probe_peak_C=float(pk[-1].max()))
    print("workload,machine,layer,peak_max_C,peak_final_C,span_max_C,"
          "time_above_85C_s")
    for r_ in res.records:
        r = r_.report
        above = r.time_above()
        for l in range(r.peak_C.shape[1]):
            print(f"{r_.point.workload},{r_.machine},{l},"
                  f"{r.peak_C[:, l].max():.1f},{r.peak_C[-1, l]:.1f},"
                  f"{r.span_C[:, l].max():.2f},{above[l]:.3f}")
    for w in workloads:
        by_mc = {r_.machine: r_ for r_ in res.records
                 if r_.point.workload == w}
        print(f"# {w}: AP above-85C {by_mc['ap'].time_above_limit_s:.3f}s / "
              f"SIMD above-85C {by_mc['simd'].time_above_limit_s:.3f}s "
              f"of {t_end:.2f}s")
        rec.add(**{f"cosim_{w}_ap_above85_s":
                   by_mc["ap"].time_above_limit_s,
                   f"cosim_{w}_simd_above85_s":
                   by_mc["simd"].time_above_limit_s,
                   f"cosim_{w}_ap_peak_C":
                   float(by_mc["ap"].report.peak_C.max())})
    rec.add(cosim_cases=len(res.records))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grids/intervals (CI smoke lane)")
    ap.add_argument("--solver-grid", type=int, default=256,
                    help="grid for the solver shoot-out (>= 256 is the "
                         "acceptance evidence)")
    args = ap.parse_args(argv)
    rec = Recorder("thermal")
    if args.quick:
        steady_section(rec, grid_ap=64, grid_simd=32)
        solver_section(rec, n=args.solver_grid)
        cosim_section(rec, grid_n=16, n_intervals=24,
                      workloads=("dmm", "fft"))
    else:
        steady_section(rec, grid_ap=128, grid_simd=64)
        solver_section(rec, n=args.solver_grid)
        cosim_section(rec, grid_n=32, n_intervals=64,
                      workloads=("dmm", "fft"))
    return rec.finish()


if __name__ == "__main__":
    main()
