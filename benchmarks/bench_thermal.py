"""Paper §4 / Figs 10, 12, 13: AP vs SIMD 4-layer-stack thermal comparison.

Two sections:

1. steady state (the paper's own experiment), and
2. transient co-simulation — per-workload power traces replayed through the
   implicit stepper (core/cosim.py), reporting time-resolved peaks and the
   per-layer time spent above the 85 °C 3D-DRAM ceiling, plus the implicit
   solver's step-count advantage over the explicit oracle.

``--quick`` shrinks grids/intervals for the CI smoke lane.
"""
import argparse

from repro.core.floorplan import thermal_comparison


def steady_section(grid_ap: int, grid_simd: int) -> None:
    res = thermal_comparison(grid_ap=grid_ap, grid_simd=grid_simd,
                             workload="dmm")
    dp = res["design_point"]
    print(f"design point: S={dp.speedup:.0f}  "
          f"AP {dp.ap_power_W:.2f}W/layer @{dp.ap_area_mm2:.1f}mm^2  "
          f"SIMD {dp.simd_power_W:.2f}W/layer @{dp.simd_area_mm2:.1f}mm^2")
    print("layer,ap_peak_C,ap_span_C,simd_peak_C,simd_min_C")
    for l in range(4):
        print(f"{l},{res['ap']['peak_C'][l]:.1f},{res['ap']['span_C'][l]:.2f},"
              f"{res['simd']['peak_C'][l]:.1f},{res['simd']['min_C'][l]:.1f}")
    ap_ok = max(res["ap"]["peak_C"]) < 85.0
    simd_ok = res["simd"]["min_C"][0] < 85.0
    print(f"3D-DRAM (85C limit): AP {'OK' if ap_ok else 'BLOCKED'} / "
          f"SIMD {'OK' if simd_ok else 'BLOCKED'}   "
          f"(paper: AP 55C OK, SIMD 98-128C blocked)")


def cosim_section(grid_n: int, n_intervals: int, workloads) -> None:
    import math

    from repro.core import cosim, thermal
    from repro.core.floorplan import MM
    from repro.sweep import SweepSpec, run_sweep

    print()
    print(f"transient co-simulation (grid {grid_n}, {n_intervals} intervals, "
          f"implicit theta-scheme)")
    t_end = 0.25
    steps_per_interval = 2
    # the bare 4-layer logic stack, open loop, as one declarative sweep
    spec = SweepSpec(workloads=tuple(workloads), sizes=(2 ** 20,),
                     n_dram=(0,), fb_modes=("open",), grid_n=grid_n,
                     n_intervals=n_intervals, t_end=t_end,
                     steps_per_interval=steps_per_interval)
    res = run_sweep(spec, use_cache=False)
    # implicit step-count advantage vs the CFL-bound explicit oracle, on
    # the exact grids simulated (the AP and SIMD dies of the first workload)
    dp = cosim.comparable_design_point(workloads[0])
    n_imp = n_intervals * steps_per_interval
    for machine, area in (("ap", dp.ap_area_mm2), ("simd", dp.simd_area_mm2)):
        grid = thermal.Grid(die_w=math.sqrt(area) * MM, ny=grid_n, nx=grid_n,
                            margin=grid_n // 4)
        n_exp = max(int(t_end / thermal.explicit_dt(grid)), 1)
        print(f"steps ({workloads[0]}/{machine} die): explicit oracle "
              f"{n_exp}, implicit {n_imp} ({n_exp / n_imp:.0f}x fewer)")
    print("workload,machine,layer,peak_max_C,peak_final_C,span_max_C,"
          "time_above_85C_s")
    for rec in res.records:
        r = rec.report
        above = r.time_above()
        for l in range(r.peak_C.shape[1]):
            print(f"{rec.point.workload},{rec.machine},{l},"
                  f"{r.peak_C[:, l].max():.1f},{r.peak_C[-1, l]:.1f},"
                  f"{r.span_C[:, l].max():.2f},{above[l]:.3f}")
    for w in workloads:
        by_mc = {rec.machine: rec for rec in res.records
                 if rec.point.workload == w}
        print(f"# {w}: AP above-85C {by_mc['ap'].time_above_limit_s:.3f}s / "
              f"SIMD above-85C {by_mc['simd'].time_above_limit_s:.3f}s "
              f"of {t_end:.2f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grids/intervals (CI smoke lane)")
    args = ap.parse_args()
    if args.quick:
        steady_section(grid_ap=64, grid_simd=32)
        cosim_section(grid_n=16, n_intervals=24, workloads=("dmm", "fft"))
    else:
        steady_section(grid_ap=128, grid_simd=64)
        cosim_section(grid_n=32, n_intervals=64, workloads=("dmm", "fft"))


if __name__ == "__main__":
    main()
