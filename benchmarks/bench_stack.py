"""AP+DRAM vs SIMD+DRAM closed-loop stack sweep — the paper's abstract
claim ("AP's flat thermal profile makes DRAM-on-logic stacking viable")
as a quantitative table.

Declared as a `repro.sweep.SweepSpec` over workload × DRAM-die-count and
lowered to one vmapped closed-loop batch per die count (the feedback
path: JEDEC refresh bins, exponential leakage, DTM throttling).
Reported per case: logic/DRAM peak temperature, DRAM span, refresh-power
overhead (× the cool-DRAM 1× level), DTM-throttled runtime inflation,
DRAM seconds above the 85 °C ceiling, and the final Picard residual.

``--quick`` shrinks grids/intervals/die counts for the CI smoke lane.
"""
import argparse
import sys

try:                                    # python -m benchmarks.run ...
    from benchmarks._record import Recorder
except ImportError:                     # python benchmarks/bench_*.py
    from _record import Recorder

from repro.core.constants import DRAM_LIMIT_C
from repro.stack import feedback
from repro.sweep import SweepSpec, run_sweep

WORKLOADS = ("dmm", "fft", "bs")


def sweep(rec: Recorder, dram_counts, grid_n: int, n_intervals: int,
          t_end: float, steps_per_interval: int, n_cg: int) -> None:
    fb = feedback.FeedbackParams()
    spec = SweepSpec(workloads=WORKLOADS, sizes=(2 ** 20,),
                     n_dram=tuple(dram_counts), fb_modes=("closed",),
                     grid_n=grid_n, n_intervals=n_intervals, t_end=t_end,
                     steps_per_interval=steps_per_interval, n_cg=n_cg)
    print(f"closed-loop stack sweep: grid {grid_n}, {n_intervals} intervals "
          f"over {t_end:.2f}s, Picard x{fb.n_picard} "
          f"(tol {fb.picard_tol_C:.2g}C), DTM trip {fb.dtm_trip_C:.0f}C")
    res = run_sweep(spec, use_cache=False)
    print("workload,machine,n_dram,logic_peak_C,dram_peak_C,dram_span_C,"
          "refresh_overhead_x,dtm_slowdown_x,dram_above_85C_s,"
          "picard_residual_C")
    for record in res.records:
        r = record.report
        p = record.point
        dram_span = r.span_C[:, list(r.spec.dram_layers)].max()
        print(f"{p.workload},{record.machine},{p.n_dram},"
              f"{r.logic_peak_C.max():.1f},{r.dram_peak_C.max():.1f},"
              f"{dram_span:.2f},{r.refresh_overhead:.3f},"
              f"{r.dtm_slowdown:.3f},{r.dram_time_above_limit_s:.3f},"
              f"{r.residual_C.max():.2g}")
        assert r.converged, (record.label, r.residual_C.max())
    n_ok = 0
    for n_dram in dram_counts:
        for w in WORKLOADS:
            ok = {record.machine: record.verdict_ok
                  for record in res.records
                  if record.point.workload == w
                  and record.point.n_dram == n_dram}
            n_ok += ok["ap"] + ok["simd"]
            print(f"# {w} x{n_dram} DRAM ({DRAM_LIMIT_C:.0f}C ceiling): "
                  f"AP {'OK' if ok['ap'] else 'BLOCKED'} / "
                  f"SIMD {'OK' if ok['simd'] else 'BLOCKED'}")
    rec.add(n_cases=len(res.records), n_ok=n_ok,
            max_logic_peak_C=max(float(r.report.logic_peak_C.max())
                                 for r in res.records),
            max_dram_peak_C=max(float(r.report.dram_peak_C.max())
                                for r in res.records),
            max_refresh_overhead_x=max(r.report.refresh_overhead
                                       for r in res.records),
            max_dtm_slowdown_x=max(r.report.dtm_slowdown
                                   for r in res.records))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grids/intervals (CI smoke lane)")
    args = ap.parse_args(argv)
    rec = Recorder("stack")
    if args.quick:
        sweep(rec, dram_counts=(1, 2), grid_n=12, n_intervals=16,
              t_end=0.25, steps_per_interval=1, n_cg=30)
    else:
        sweep(rec, dram_counts=(1, 2, 4), grid_n=24, n_intervals=48,
              t_end=0.25, steps_per_interval=2, n_cg=40)
    return rec.finish()


if __name__ == "__main__":
    main(sys.argv[1:])
