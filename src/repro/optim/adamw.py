"""AdamW with fp32 moments over (possibly bf16) params + global-norm clip.

Pure-pytree implementation (no optax dependency): states shard exactly like
their parameters (ZeRO-style — the param PartitionSpecs apply verbatim to
m/v), which the dry-run's memory analysis depends on.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moments_dtype: Any = jnp.float32   # bf16 halves optimizer HBM (m is
    #   robust in bf16; v benefits from the f32 bias-corrected math below —
    #   the deploy option that fits deepseek-236b training on 16 GB chips)


def adamw_init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moments_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (params', state', metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step.astype(jnp.float32), cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:      # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mf.astype(m.dtype), vf.astype(v.dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return params, {"m": m, "v": v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
