"""Gradient compression: int8 error-feedback quantization for the DP
all-reduce path.

``ef_compress``/``ef_decompress`` implement per-tensor symmetric int8 with
an error-feedback residual (Seide et al. / EF-SGD): the quantization error
is carried to the next step, so compression bias vanishes over time.

``compressed_psum`` demonstrates the wire-level path with shard_map: the
int8 payload (4x smaller than f32) is what crosses the 'data' axis; scales
travel separately (one f32 per tensor).  The trainer exposes this as an
optional hook (off by default — on TPU the native bf16 all-reduce is often
already bandwidth-optimal; the EF-int8 path targets DCN-limited multi-pod
gradient exchange, where 4x fewer bytes is a direct win on the 'pod' axis).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def ef_compress(g: jax.Array, residual: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def ef_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, residuals: Any) -> tuple[Any, Any, Any]:
    qs, scales, res = {}, {}, {}
    flat, tdef = jax.tree_util.tree_flatten(grads)
    rflat = jax.tree_util.tree_leaves(residuals)
    out = [ef_compress(g, r) for g, r in zip(flat, rflat)]
    unf = lambda xs: jax.tree_util.tree_unflatten(tdef, xs)
    return unf([o[0] for o in out]), unf([o[1] for o in out]), \
        unf([o[2] for o in out])


def init_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, residual: jax.Array, axis_name: str
                    ) -> tuple[jax.Array, jax.Array]:
    """Mean over ``axis_name`` with int8 payload + error feedback.

    Must run inside shard_map with ``axis_name`` bound.  A SHARED scale is
    agreed first (pmax of one scalar — negligible wire) so the summed int8
    payloads are commensurable; the payload psum itself carries int32 —
    4x narrower than f32 on the wire.
    """
    gf = x.astype(jnp.float32) + residual
    local_max = jnp.max(jnp.abs(gf))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_res = gf - q.astype(jnp.float32) * scale
    n = jax.lax.psum(1, axis_name)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = qsum.astype(jnp.float32) * scale / n
    return mean, new_res
