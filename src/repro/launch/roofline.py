"""Roofline terms from a compiled dry-run artifact (no real hardware).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``cost_analysis()`` of the SPMD-partitioned module is
per-device, so the three terms are:

    compute    = flops / peak_flops
    memory     = bytes_accessed / hbm_bw
    collective = wire_bytes / ici_bw

wire_bytes applies per-op ring formulas to every collective in the
partitioned HLO (result-shape R, group size n):
    all-gather       R * (n-1)/n
    all-reduce       2R * (n-1)/n
    reduce-scatter   R * (n-1)        (R is the scattered shard)
    all-to-all       R * (n-1)/n
    collective-permute  R
These are bandwidth-optimal schedules on a ring; a single-link bandwidth is
assumed (conservative — v5e has 4 ICI links/chip, so the true collective
term can be up to ~4x smaller for well-routed traffic; we report the
conservative number and note the factor).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

# `%x = f32[8,128]{1,0} all-gather(...)` or tuple `= (f32[..], ..) all-reduce(`
_LINE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[.\w-]*\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_NEW = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_NEW.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, default_group: int = 16) -> dict:
    """Sum wire bytes per collective kind over the partitioned module."""
    out = {k: 0.0 for k in _COLL}
    counts = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        m = _LINE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        R = _shape_bytes(type_str)
        n = max(_group_size(line, default_group), 2)
        if op == "all-gather":
            wire = R * (n - 1) / n
        elif op == "all-reduce":
            wire = 2.0 * R * (n - 1) / n
        elif op == "reduce-scatter":
            wire = R * (n - 1)
        elif op == "all-to-all":
            wire = R * (n - 1) / n
        else:  # collective-permute
            wire = R
        out[op] += wire
        counts[op] += 1
    out["total_wire_bytes"] = sum(out[k] for k in _COLL)
    out["counts"] = counts
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    wire_bytes: float
    model_flops: float          # 6*N*D train / 2*N*D inference (per device)
    useful_ratio: float         # model_flops / hlo_flops

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term is to being the ONLY cost: the ideal
        step time is max(terms) assuming perfect overlap; the 'roofline
        fraction' we report is compute_s / bound_s (1.0 = compute-bound at
        peak; <1 = paying for memory/collectives)."""
        return self.compute_s / self.bound_s if self.bound_s > 0 else 0.0


def count_params(params_sds) -> int:
    import jax
    return sum(int(_prod(l.shape)) for l in jax.tree_util.tree_leaves(
        params_sds))


def count_active_params(cfg, params_sds) -> int:
    """MoE: experts count at top_k/n_routed utilization."""
    import jax.tree_util as jtu
    total = 0
    for path, leaf in jtu.tree_leaves_with_path(params_sds):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        n = int(_prod(leaf.shape))
        if cfg.moe is not None and "experts" in keys:
            n = int(n * cfg.moe.top_k / cfg.moe.n_routed)
        total += n
    return total


def _prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def model_flops_per_device(cfg, cell, params_sds, n_chips: int) -> float:
    """Reference 'useful' FLOPs per device per step."""
    n_active = count_active_params(cfg, params_sds)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / n_chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = cell.global_batch            # one token / sequence
    return 2.0 * n_active * tokens / n_chips


def roofline(cost: dict, coll: dict, model_flops: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    ba = float(cost.get("bytes accessed", 0.0))
    wire = float(coll["total_wire_bytes"])
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=ba / HBM_BW,
        collective_s=wire / ICI_BW,
        flops=flops, bytes_accessed=ba, wire_bytes=wire,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0)
