"""Step builders: jitted train / prefill / decode with explicit shardings.

Each builder returns (jitted_fn, example_args) where example_args are
ShapeDtypeStructs — ``jitted.lower(*example_args)`` is the dry-run contract
(no device allocation).  The same builders drive the real train.py/serve.py
with concrete arrays.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import model as M
from repro.models import serve as SV
from repro.models.layers import Sharder
from repro.models.model import PerfConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import cache_specs, param_specs


def _axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def make_sharder(mesh, multi_pod: bool, tiny_batch: bool = False,
                 parallelism: str = "2d") -> Sharder:
    data = _axes(multi_pod)
    if tiny_batch:
        # B < data width: shard sequence/state over the whole mesh instead
        seq = (("pod", "data", "model") if multi_pod else ("data", "model"))
        return Sharder(mesh=mesh, data_axes=None, model_axes="model",
                       seq_axes=seq)
    if parallelism == "fsdp":
        # pure ZeRO-3: batch over the whole mesh, activations unsharded on
        # features (weights stay 256-way sharded via param_specs)
        whole = (("pod", "data", "model") if multi_pod
                 else ("data", "model"))
        return Sharder(mesh=mesh, data_axes=whole, model_axes=None)
    return Sharder(mesh=mesh, data_axes=data, model_axes="model")


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def params_sds(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def _batch_extras_sds(cfg: ArchConfig, lead: tuple, dtype, data):
    sds, specs = {}, {}
    if cfg.family == "encdec":
        sds["audio_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.enc_seq, cfg.d_model), dtype)
        specs["audio_embeds"] = P(*([None] * (len(lead) - 1)), data,
                                  None, None)
    if cfg.n_prefix_embeds:
        sds["prefix_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.n_prefix_embeds, cfg.d_model), dtype)
        specs["prefix_embeds"] = P(*([None] * (len(lead) - 1)), data,
                                   None, None)
    return sds, specs


# ===========================================================================
# train
# ===========================================================================

def make_train_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                    perf: PerfConfig = PerfConfig(),
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    multi_pod: bool = False, dtype=jnp.bfloat16):
    shd = make_sharder(mesh, multi_pod, parallelism=perf.parallelism)
    data = shd.data_axes
    if perf.opt_moments == "bf16":
        import dataclasses as _dc
        opt_cfg = _dc.replace(opt_cfg, moments_dtype=jnp.bfloat16)
    psds = params_sds(cfg, dtype)
    pspecs = param_specs(cfg, psds, multi_pod)
    osds = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), psds)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}

    accum = perf.accum_steps
    Bm = cell.global_batch // accum
    lead = (accum, Bm)
    batch_sds = {
        "tokens": jax.ShapeDtypeStruct(lead + (cell.seq_len,), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (cell.seq_len,), jnp.int32),
    }
    batch_specs = {
        "tokens": P(None, data, None),
        "labels": P(None, data, None),
    }
    ex_sds, ex_specs = _batch_extras_sds(cfg, lead, dtype, data)
    batch_sds.update(ex_sds)
    batch_specs.update(ex_specs)

    def train_step(params, opt, batch):
        def micro(gsum, mb):
            (loss, met), g = jax.value_and_grad(
                M.loss_fn, has_aux=True)(params, mb, cfg, shd, perf)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return gsum, loss

        gz = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, losses = jax.lax.scan(micro, gz, batch)
        gsum = jax.tree_util.tree_map(lambda g: g / accum, gsum)
        params, opt, metrics = adamw_update(params, gsum, opt, opt_cfg)
        metrics["loss"] = losses.mean()
        return params, opt, metrics

    met_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
    jt = jax.jit(
        train_step,
        in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                      _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                       _named(mesh, met_specs)),
        donate_argnums=(0, 1))
    return jt, (psds, osds, batch_sds)


# ===========================================================================
# prefill
# ===========================================================================

def make_prefill_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                      perf: PerfConfig = PerfConfig(),
                      multi_pod: bool = False, dtype=jnp.bfloat16):
    data = _axes(multi_pod)
    tiny = cell.global_batch < 16
    shd = make_sharder(mesh, multi_pod, tiny_batch=tiny)
    psds = params_sds(cfg, dtype)
    pspecs = param_specs(cfg, psds, multi_pod)

    B, S = cell.global_batch, cell.seq_len
    batch_sds = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch_specs = {"tokens": P(shd.data_axes, None)}
    ex_sds, ex_specs = _batch_extras_sds(cfg, (B,), dtype, shd.data_axes)
    batch_sds.update(ex_sds)
    batch_specs.update(ex_specs)

    csds = jax.eval_shape(
        functools.partial(SV.init_caches, cfg, B, S, dtype,
                          kv_quant=perf.kv_quant))
    cspecs = cache_specs(cfg, csds, multi_pod)
    cspecs = _retarget_cache_specs(cspecs, shd)

    def prefill_step(params, batch):
        return SV.prefill(params, batch, cfg, shd, perf, max_seq=S)

    jt = jax.jit(
        prefill_step,
        in_shardings=(_named(mesh, pspecs), _named(mesh, batch_specs)),
        out_shardings=(NamedSharding(mesh, P(shd.data_axes, "model")),
                       _named(mesh, cspecs)))
    return jt, (psds, batch_sds)


# ===========================================================================
# decode
# ===========================================================================

def make_decode_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                     perf: PerfConfig = PerfConfig(),
                     multi_pod: bool = False, dtype=jnp.bfloat16):
    tiny = cell.global_batch < 16
    shd = make_sharder(mesh, multi_pod, tiny_batch=tiny)
    psds = params_sds(cfg, dtype)
    pspecs = param_specs(cfg, psds, multi_pod)

    B, S = cell.global_batch, cell.seq_len
    csds = jax.eval_shape(
        functools.partial(SV.init_caches, cfg, B, S, dtype,
                          kv_quant=perf.kv_quant))
    cspecs = cache_specs(cfg, csds, multi_pod)
    cspecs = _retarget_cache_specs(cspecs, shd)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, tokens, caches, pos):
        # unrolled layer loop: straight-line cache updates alias in place
        # (scan-carry aliasing keeps a full cache copy on some backends)
        return SV.decode_step(params, tokens, caches, pos, cfg, shd,
                              unroll=not perf.scan_layers,
                              moe_groups=perf.moe_groups)

    jt = jax.jit(
        decode_fn,
        in_shardings=(_named(mesh, pspecs),
                      NamedSharding(mesh, P(shd.data_axes, None)),
                      _named(mesh, cspecs),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(shd.data_axes, "model")),
                       _named(mesh, cspecs)),
        donate_argnums=(2,))
    return jt, (psds, tok_sds, csds, pos_sds)


def _retarget_cache_specs(cspecs, shd: Sharder):
    """Rewrite cache specs onto the sharder's (data_axes, seq_axes)."""
    import jax.tree_util as jtu

    def one(path, spec):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        if name in ("k", "v", "k_q", "v_q"):
            return P(None, shd.data_axes, shd.seq_axes, None, None)
        if name in ("k_s", "v_s"):
            return P(None, shd.data_axes, shd.seq_axes, None)
        if name in ("cross_k", "cross_v"):
            return P(None, shd.data_axes, None, None, None)
        if name in ("c_kv", "k_rope"):
            return P(None, shd.data_axes, shd.seq_axes, None)
        if name == "conv":
            return P(None, shd.data_axes, None, shd.seq_axes)
        if name == "h":
            return P(None, shd.data_axes, shd.seq_axes, None)
        return spec

    return jtu.tree_map_with_path(one, cspecs,
                                  is_leaf=lambda x: isinstance(x, P))
