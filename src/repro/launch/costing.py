"""HLO cost reconstruction for scanned programs.

XLA's HloCostAnalysis visits each instruction ONCE — a lax.scan (while
loop) body is counted a single time regardless of trip count, so the full
step's ``cost_analysis()`` massively undercounts flops/bytes/collectives.
(Verified empirically: stablelm train_4k full-step flops == one layer x one
microbatch + embed/head + optimizer.)

Reconstruction: compile each *block* separately — with the SAME shardings,
remat policy and microbatch shapes as the real step — read its HLO cost,
and multiply by the true trip counts:

    train:   total = A * (emb + sum_i L_i * body_i) + opt
    serve:   total = head + sum_i L_i * body_i

where emb/head is recovered from the full step's (scan-once) cost by
subtracting each body counted the number of times it appears ONCE-PER-SCAN
in the traced program.  Block backward costs come from jax.vjp around the
jax.checkpoint'd block, so remat recompute IS included.  Collective wire
bytes are reconstructed with the same multipliers.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch import roofline as RF
from repro.launch.steps import _named, make_sharder, params_sds
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import serve as SV
from repro.models import ssm as ssm_mod
from repro.models.layers import gelu_mlp, swiglu
from repro.models.model import (PerfConfig, _dense_block, _mla_dense_block,
                                _moe_block, _norm, _remat, _shared_attn_block,
                                _ssm_block)
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _cost_of(jitted, args) -> dict:
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jaxlib: one dict per program
        cost = cost[0] if cost else {}
    coll = RF.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": float(coll["total_wire_bytes"])}


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "wire": 0.0}


def _add(a, b, k=1.0):
    return {key: a[key] + k * b[key] for key in a}


def _sub_clamped(a, b, k=1.0):
    return {key: max(a[key] - k * b[key], 0.0) for key in a}


def _layer_specs(pspecs_sub):
    """Drop the stacked-layer leading axis from a spec subtree."""
    return jax.tree_util.tree_map(
        lambda s: P(*s[1:]), pspecs_sub,
        is_leaf=lambda x: isinstance(x, P))


def _layer_sds(psds_sub):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), psds_sub)


class ComponentCoster:
    """Compiles per-block costs for one (cfg, cell, mesh) under a perf cfg."""

    def __init__(self, cfg: ArchConfig, cell: ShapeCell, mesh, perf: PerfConfig,
                 multi_pod: bool = False, dtype=jnp.bfloat16,
                 pspecs=None, psds=None):
        self.cfg = cfg
        self.cell = cell
        self.mesh = mesh
        self.perf = perf
        self.multi_pod = multi_pod
        self.dtype = dtype
        tiny = cell.kind != "train" and cell.global_batch < 16
        self.shd = make_sharder(mesh, multi_pod, tiny_batch=tiny,
                                parallelism=perf.parallelism)
        self.psds = psds if psds is not None else params_sds(cfg, dtype)
        from repro.parallel.sharding import param_specs
        self.pspecs = pspecs if pspecs is not None \
            else param_specs(cfg, self.psds, multi_pod)
        if cell.kind == "train":
            self.Bm = cell.global_batch // perf.accum_steps
        else:
            self.Bm = cell.global_batch
        self.S = cell.seq_len if cell.kind != "decode" else 1
        self.x_spec = P(self.shd.data_axes, None, None)
        self.x_sds = jax.ShapeDtypeStruct(
            (self.Bm, self.S, cfg.d_model), dtype)
        self.positions = None  # built lazily inside block fns

    # ---------------------------------------------------- train-block costs
    def _train_block_cost(self, block_fn: Callable, lp_sds, lp_specs,
                          has_aux: bool = False, extra_sds=(), extra_specs=()):
        cfg, shd, perf = self.cfg, self.shd, self.perf
        S = self.S

        def fwd(lp, x, *extra):
            import jax.numpy as jnp
            B = x.shape[0]
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            return block_fn(lp, x, positions, *extra)

        blk = _remat(fwd, perf.remat)

        def cost_fn(lp, x, *extra):
            y, pull = jax.vjp(blk, lp, x, *extra)
            if has_aux:
                ct = (y[0], jnp.ones((), jnp.float32))
            else:
                ct = y
            return pull(ct)

        jt = jax.jit(cost_fn, in_shardings=(
            _named(self.mesh, lp_specs),
            NamedSharding(self.mesh, self.x_spec),
            *[NamedSharding(self.mesh, s) for s in extra_specs]))
        return _cost_of(jt, (lp_sds, self.x_sds, *extra_sds))

    def _serve_block_cost(self, fn: Callable, in_specs, in_sds):
        jt = jax.jit(fn, in_shardings=in_specs)
        return _cost_of(jt, in_sds)

    def _opt_cost(self):
        ocfg = AdamWConfig(
            moments_dtype=jnp.bfloat16 if self.perf.opt_moments == "bf16"
            else jnp.float32)
        osds = jax.eval_shape(
            functools.partial(adamw_init, cfg=ocfg), self.psds)
        gsds = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), self.psds)
        ospecs = {"m": self.pspecs, "v": self.pspecs, "step": P()}

        def opt_fn(params, grads, opt):
            p, o, m = adamw_update(params, grads, opt, ocfg)
            return p, o
        jt = jax.jit(opt_fn, in_shardings=(
            _named(self.mesh, self.pspecs), _named(self.mesh, self.pspecs),
            _named(self.mesh, ospecs)))
        return _cost_of(jt, (self.psds, gsds, osds))

    # ---------------------------------------------------------- public API
    def bodies(self) -> dict[str, tuple[dict, int, int]]:
        """-> {name: (cost, count_in_traced_program, true_count_per_micro)}"""
        cfg = self.cfg
        shd, perf = self.shd, self.perf
        chunk = perf.attn_chunk
        out = {}
        if self.cell.kind == "train":
            mk = self._train_block_cost
            if cfg.family == "dense":
                lp_sds = _layer_sds(self.psds["layers"])
                lp_specs = _layer_specs(self.pspecs["layers"])
                fn = functools.partial(_dense_block, cfg=cfg, shd=shd,
                                       chunk=chunk)
                fn2 = lambda lp, x, pos: fn(lp, x, pos)
                out["block"] = (mk(fn2, lp_sds, lp_specs), 1, cfg.n_layers)
            elif cfg.family == "moe":
                nd = cfg.moe.first_dense
                dsds = _layer_sds(self.psds["dense_layers"])
                dspecs = _layer_specs(self.pspecs["dense_layers"])
                msds = _layer_sds(self.psds["layers"])
                mspecs = _layer_specs(self.pspecs["layers"])
                fd = functools.partial(_mla_dense_block, cfg=cfg, shd=shd,
                                       chunk=chunk)
                fm = functools.partial(_moe_block, cfg=cfg, shd=shd,
                                       chunk=chunk,
                                       groups=self.perf.moe_groups)
                out["dense_block"] = (
                    mk(lambda lp, x, pos: fd(lp, x, pos), dsds, dspecs),
                    1, nd)
                out["moe_block"] = (
                    mk(lambda lp, x, pos: fm(lp, x, pos), msds, mspecs,
                       has_aux=True), 1, cfg.n_layers - nd)
            elif cfg.family == "ssm":
                lp_sds = _layer_sds(self.psds["layers"])
                lp_specs = _layer_specs(self.pspecs["layers"])
                out["block"] = (
                    mk(lambda lp, x, pos: _ssm_block(lp, x, cfg, shd),
                       lp_sds, lp_specs), 1, cfg.n_layers)
            elif cfg.family == "hybrid":
                per = cfg.attn_every
                n_seg = max(cfg.n_layers // per, 1)
                n_scans = n_seg + (1 if cfg.n_layers % per else 0)
                lp_sds = _layer_sds(self.psds["layers"])
                lp_specs = _layer_specs(self.pspecs["layers"])
                sp_sds = self.psds["shared_block"]
                sp_specs = self.pspecs["shared_block"]
                fs = functools.partial(_shared_attn_block, cfg=cfg, shd=shd,
                                       chunk=chunk)
                out["shared_block"] = (
                    mk(lambda sp, x, pos: fs(sp, x, pos), sp_sds, sp_specs),
                    n_seg, n_seg)
                out["mamba_block"] = (
                    mk(lambda lp, x, pos: _ssm_block(lp, x, cfg, shd),
                       lp_sds, lp_specs), n_scans, cfg.n_layers)
            elif cfg.family == "encdec":
                from repro.models.model import _dec_block
                esds = _layer_sds(self.psds["enc_layers"])
                especs = _layer_specs(self.pspecs["enc_layers"])
                dsds = _layer_sds(self.psds["layers"])
                dspecs = _layer_specs(self.pspecs["layers"])
                enc_sds = jax.ShapeDtypeStruct(
                    (self.Bm, cfg.enc_seq, cfg.d_model), self.dtype)
                enc_spec = P(self.shd.data_axes, None, None)

                def enc_fn(lp, x, pos):
                    h = attn_mod.attn_train(
                        lp["attn"], _norm(x, lp["ln1"], cfg), pos, cfg, shd,
                        causal=False)
                    x = x + h
                    return x + gelu_mlp(lp["mlp"], _norm(x, lp["ln2"], cfg),
                                        shd)

                def dec_fn(lp, x, pos, enc_out):
                    import jax.numpy as jnp
                    F = enc_out.shape[1]
                    enc_pos = jnp.broadcast_to(
                        jnp.arange(F)[None], (x.shape[0], F))
                    return _dec_block(lp, x, enc_out, pos, enc_pos, cfg,
                                      shd, chunk)
                # encoder blocks see enc_seq-long x
                old_S, old_sds = self.S, self.x_sds
                self.S = cfg.enc_seq
                self.x_sds = enc_sds
                out["enc_block"] = (mk(enc_fn, esds, especs),
                                    1, cfg.n_enc_layers)
                self.S, self.x_sds = old_S, old_sds
                out["dec_block"] = (
                    mk(dec_fn, dsds, dspecs, extra_sds=(enc_sds,),
                       extra_specs=(enc_spec,)), 1, cfg.n_layers)
        else:
            out.update(self._serve_bodies())
        return out

    # ------------------------------------------------------- serve bodies
    def _serve_bodies(self):
        cfg, shd, perf, cell = self.cfg, self.shd, self.perf, self.cell
        B = cell.global_batch
        S = cell.seq_len
        decode = cell.kind == "decode"
        chunk = perf.attn_chunk
        out = {}
        csds_full = jax.eval_shape(
            functools.partial(SV.init_caches, cfg, B, S, self.dtype,
                              kv_quant=perf.kv_quant))
        from repro.launch.steps import _retarget_cache_specs
        from repro.parallel.sharding import cache_specs
        cspecs_full = _retarget_cache_specs(
            cache_specs(cfg, csds_full, self.multi_pod), shd)

        x_sds = jax.ShapeDtypeStruct((B, 1 if decode else S, cfg.d_model),
                                     self.dtype)
        x_spec = NamedSharding(self.mesh, P(shd.data_axes, None, None))
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        pos_spec = NamedSharding(self.mesh, P())

        def attn_layer_fns(pkey, ckey, mla=False, with_moe=False,
                           with_mlp=True):
            lp_sds = _layer_sds(self.psds[pkey])
            lp_specs = _named(self.mesh, _layer_specs(self.pspecs[pkey]))
            c_sds = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                csds_full[ckey])
            c_specs = _named(self.mesh, jax.tree_util.tree_map(
                lambda s: P(*s[1:]), cspecs_full[ckey],
                is_leaf=lambda x: isinstance(x, P)))

            if decode:
                def fn(lp, x, cache, pos):
                    if mla:
                        h, cache = mla_mod.mla_decode(
                            lp["attn"], _norm(x, lp["ln1"], cfg), cache,
                            pos, cfg, shd)
                    else:
                        h, cache = attn_mod.attn_decode(
                            lp["attn"], _norm(x, lp["ln1"], cfg), cache,
                            pos, cfg, shd)
                    x = x + h
                    if with_moe:
                        y, _ = moe_mod.moe_ffn(
                            lp["moe"], _norm(x, lp["ln2"], cfg), cfg, shd,
                            groups=self.perf.moe_groups)
                        x = x + y
                    elif with_mlp:
                        x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg),
                                       shd)
                    return x, cache
                jt = jax.jit(fn, in_shardings=(lp_specs, x_spec, c_specs,
                                               pos_spec),
                             donate_argnums=(2,))
                return _cost_of(jt, (lp_sds, x_sds, c_sds, pos_sds))
            else:
                def fn(lp, x, cache):
                    import jax.numpy as jnp
                    positions = jnp.broadcast_to(
                        jnp.arange(S)[None], (B, S))
                    if mla:
                        h, cache = mla_mod.mla_prefill(
                            lp["attn"], _norm(x, lp["ln1"], cfg), positions,
                            cfg, shd, cache, chunk=chunk)
                    else:
                        h, cache = attn_mod.prefill_into_cache(
                            lp["attn"], _norm(x, lp["ln1"], cfg), positions,
                            cfg, shd, cache, chunk=chunk)
                    x = x + h
                    if with_moe:
                        y, _ = moe_mod.moe_ffn(
                            lp["moe"], _norm(x, lp["ln2"], cfg), cfg, shd,
                            groups=self.perf.moe_groups)
                        x = x + y
                    elif with_mlp:
                        x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg),
                                       shd)
                    return x, cache
                jt = jax.jit(fn, in_shardings=(lp_specs, x_spec, c_specs),
                             donate_argnums=(2,))
                return _cost_of(jt, (lp_sds, x_sds, c_sds))

        if cfg.family == "dense":
            out["block"] = (attn_layer_fns("layers", "layers"),
                            1, cfg.n_layers)
        elif cfg.family == "moe":
            nd = cfg.moe.first_dense
            out["dense_block"] = (
                attn_layer_fns("dense_layers", "dense_layers", mla=True),
                1, nd)
            out["moe_block"] = (
                attn_layer_fns("layers", "layers", mla=True, with_moe=True),
                1, cfg.n_layers - nd)
        elif cfg.family in ("ssm", "hybrid"):
            lp_sds = _layer_sds(self.psds["layers"])
            lp_specs = _named(self.mesh, _layer_specs(self.pspecs["layers"]))
            st_sds = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                csds_full["layers"])
            st_specs = _named(self.mesh, jax.tree_util.tree_map(
                lambda s: P(*s[1:]), cspecs_full["layers"],
                is_leaf=lambda x: isinstance(x, P)))
            if decode:
                def fn(lp, x, st):
                    h, st = ssm_mod.ssm_decode(
                        lp["ssm"], _norm(x, lp["ln"], cfg), st, cfg, shd)
                    return x + h, st
            else:
                def fn(lp, x, st):
                    from repro.models.serve import _ssm_prefill_block
                    return _ssm_prefill_block(lp, x, cfg, shd)
            jt = jax.jit(fn, in_shardings=(lp_specs, x_spec, st_specs),
                         donate_argnums=(2,))
            cost = _cost_of(jt, (lp_sds, x_sds, st_sds))
            if cfg.family == "ssm":
                out["block"] = (cost, 1, cfg.n_layers)
            else:
                per = cfg.attn_every
                n_seg = max(cfg.n_layers // per, 1)
                # python loops in serve: every layer traced individually
                out["mamba_block"] = (cost, cfg.n_layers, cfg.n_layers)
                sc_sds = jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                    csds_full["shared"])
                sc_specs = _named(self.mesh, jax.tree_util.tree_map(
                    lambda s: P(*s[1:]), cspecs_full["shared"],
                    is_leaf=lambda x: isinstance(x, P)))
                sp_specs = _named(self.mesh, self.pspecs["shared_block"])
                if decode:
                    def sfn(sp, x, cache, pos):
                        h, cache = attn_mod.attn_decode(
                            sp["attn"], _norm(x, sp["ln1"], cfg), cache,
                            pos, cfg, shd)
                        x = x + h
                        x = x + swiglu(sp["mlp"], _norm(x, sp["ln2"], cfg),
                                       shd)
                        return x, cache
                    jt = jax.jit(sfn, in_shardings=(
                        sp_specs, x_spec, sc_specs, pos_spec),
                                 donate_argnums=(2,))
                    scost = _cost_of(jt, (self.psds["shared_block"], x_sds,
                                          sc_sds, pos_sds))
                else:
                    def sfn(sp, x, cache):
                        import jax.numpy as jnp
                        positions = jnp.broadcast_to(
                            jnp.arange(S)[None], (B, S))
                        h, cache = attn_mod.prefill_into_cache(
                            sp["attn"], _norm(x, sp["ln1"], cfg), positions,
                            cfg, shd, cache, chunk=chunk)
                        x = x + h
                        x = x + swiglu(sp["mlp"], _norm(x, sp["ln2"], cfg),
                                       shd)
                        return x, cache
                    jt = jax.jit(sfn, in_shardings=(
                        sp_specs, x_spec, sc_specs),
                                 donate_argnums=(2,))
                    scost = _cost_of(jt, (self.psds["shared_block"], x_sds,
                                          sc_sds))
                out["shared_block"] = (scost, n_seg, n_seg)
        elif cfg.family == "encdec":
            # decoder self+cross blocks; encoder runs once at prefill
            out["block"] = (self._encdec_serve_block(
                csds_full, cspecs_full, x_sds, x_spec, pos_sds, pos_spec,
                decode), 1, cfg.n_layers)
            if not decode:
                out["enc_block"] = (self._encdec_encoder_block(),
                                    1, cfg.n_enc_layers)
        return out

    def _encdec_serve_block(self, csds_full, cspecs_full, x_sds, x_spec,
                            pos_sds, pos_spec, decode):
        cfg, shd = self.cfg, self.shd
        B, S = self.cell.global_batch, self.cell.seq_len
        dh = cfg.head_dim
        lp_sds = _layer_sds(self.psds["layers"])
        lp_specs = _named(self.mesh, _layer_specs(self.pspecs["layers"]))
        c_sds = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
            csds_full["layers"])
        c_specs = _named(self.mesh, jax.tree_util.tree_map(
            lambda s: P(*s[1:]), cspecs_full["layers"],
            is_leaf=lambda x: isinstance(x, P)))
        ck_sds = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.n_kv_heads, dh), self.dtype)
        ck_spec = NamedSharding(self.mesh, P(shd.data_axes, None, None, None))

        if decode:
            def fn(lp, x, cache, ck, cv, pos):
                import jax.numpy as jnp
                h, cache = attn_mod.attn_decode(
                    lp["self_attn"], _norm(x, lp["ln1"], cfg), cache, pos,
                    cfg, shd)
                x = x + h
                xq = _norm(x, lp["ln2"], cfg)
                hkv = cfg.n_kv_heads
                rep = cfg.n_heads // hkv
                q = (xq @ lp["cross_attn"]["wq"]).reshape(
                    B, 1, cfg.n_heads, dh)
                qf = q.astype(jnp.float32).reshape(B, hkv, rep, dh)
                s = jnp.einsum("bhrd,bkhd->bhrk", qf,
                               ck.astype(jnp.float32)) * dh ** -0.5
                p = jax.nn.softmax(s, axis=-1)
                o = jnp.einsum("bhrk,bkhd->bhrd", p, cv.astype(jnp.float32))
                o = o.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype) \
                    @ lp["cross_attn"]["wo"]
                x = x + o
                x = x + gelu_mlp(lp["mlp"], _norm(x, lp["ln3"], cfg), shd)
                return x, cache
            jt = jax.jit(fn, in_shardings=(lp_specs, x_spec, c_specs,
                                           ck_spec, ck_spec, pos_spec),
                         donate_argnums=(2,))
            return _cost_of(jt, (lp_sds, x_sds, c_sds, ck_sds, ck_sds,
                                 pos_sds))
        else:
            from repro.models.model import _cross_attn, _dec_block
            enc_sds = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), self.dtype)
            enc_spec = NamedSharding(self.mesh,
                                     P(shd.data_axes, None, None))

            def fn(lp, x, cache, enc_out):
                import jax.numpy as jnp
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
                h, cache = attn_mod.prefill_into_cache(
                    lp["self_attn"], _norm(x, lp["ln1"], cfg), positions,
                    cfg, shd, cache, chunk=self.perf.attn_chunk)
                x = x + h
                xq = _norm(x, lp["ln2"], cfg)
                F = enc_out.shape[1]
                enc_pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
                x = x + _cross_attn(lp["cross_attn"], xq, enc_out,
                                    positions, enc_pos, cfg, shd)
                x = x + gelu_mlp(lp["mlp"], _norm(x, lp["ln3"], cfg), shd)
                return x, cache
            jt = jax.jit(fn, in_shardings=(lp_specs, x_spec, c_specs,
                                           enc_spec),
                         donate_argnums=(2,))
            return _cost_of(jt, (lp_sds, x_sds, c_sds, enc_sds))

    def _encdec_encoder_block(self):
        cfg, shd = self.cfg, self.shd
        B = self.cell.global_batch
        lp_sds = _layer_sds(self.psds["enc_layers"])
        lp_specs = _named(self.mesh, _layer_specs(self.pspecs["enc_layers"]))
        x_sds = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                     self.dtype)
        x_spec = NamedSharding(self.mesh, P(shd.data_axes, None, None))

        def fn(lp, x):
            import jax.numpy as jnp
            pos = jnp.broadcast_to(jnp.arange(cfg.enc_seq)[None],
                                   (B, cfg.enc_seq))
            h = attn_mod.attn_train(lp["attn"], _norm(x, lp["ln1"], cfg),
                                    pos, cfg, shd, causal=False)
            x = x + h
            return x + gelu_mlp(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
        jt = jax.jit(fn, in_shardings=(lp_specs, x_spec))
        return _cost_of(jt, (lp_sds, x_sds))

    # ------------------------------------------------------ reconstruction
    def reconstruct(self, full_cost: dict, full_wire: float) -> dict:
        """full_cost: {'flops','bytes_accessed'} of the FULL step compile."""
        bodies = self.bodies()
        c_full = {"flops": full_cost["flops"],
                  "bytes": full_cost["bytes_accessed"],
                  "wire": full_wire}
        opt = self._opt_cost() if self.cell.kind == "train" else _zero()

        emb = dict(c_full)
        for name, (cost, n_traced, n_true) in bodies.items():
            # a fully-unrolled program traces every layer individually
            if not self.perf.scan_layers:
                n_traced = n_true
            emb = _sub_clamped(emb, cost, n_traced)
        emb = _sub_clamped(emb, opt)

        A = self.perf.accum_steps if self.cell.kind == "train" else 1
        total = _zero()
        total = _add(total, emb, A)
        for name, (cost, n_traced, n_true) in bodies.items():
            total = _add(total, cost, A * n_true)
        total = _add(total, opt)
        return {
            "total": total,
            "per_component": {
                name: {"cost": cost, "traced": n_traced, "true": n_true}
                for name, (cost, n_traced, n_true) in bodies.items()},
            "embed_head": emb,
            "optimizer": opt,
        }
