import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on placeholder devices; record memory/cost/collective analysis.

The two lines above MUST stay the first statements in this file — jax locks
the device count at first init, and every import below may pull jax in.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out artifacts/dryrun
  (per-cell JSON is cached; --force recompiles)
"""
import argparse
import json
import pathlib
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, perf_override=None, tag: str = "") -> dict:
    from repro.configs import SHAPES, get_config
    from repro.launch import roofline as RF
    from repro.launch.cells import perf_for
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_decode_step, make_prefill_step,
                                    make_train_step, params_sds)

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out = pathlib.Path(out_dir) / mesh_name
    out.mkdir(parents=True, exist_ok=True)
    fname = out / f"{arch}__{shape_name}{tag}.json"
    if fname.exists() and not force:
        return json.loads(fname.read_text())

    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    data_width = 32 if multi_pod else 16
    perf = perf_override or perf_for(arch, shape_name, data_width)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256

    t0 = time.time()
    if cell.kind == "train":
        jt, args = make_train_step(cfg, cell, mesh, perf=perf,
                                   multi_pod=multi_pod)
    elif cell.kind == "prefill":
        jt, args = make_prefill_step(cfg, cell, mesh, perf=perf,
                                     multi_pod=multi_pod)
    else:
        jt, args = make_decode_step(cfg, cell, mesh, perf=perf,
                                    multi_pod=multi_pod)
    lowered = jt.lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jaxlib: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = RF.parse_collectives(hlo)

    # XLA cost analysis counts scan bodies ONCE -> reconstruct true totals
    # from per-component compiles x trip counts (see costing.py)
    from repro.launch.costing import ComponentCoster
    coster = ComponentCoster(cfg, cell, mesh, perf, multi_pod=multi_pod)
    t0 = time.time()
    recon = coster.reconstruct(
        {"flops": float(cost.get("flops", 0.0)),
         "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        float(coll["total_wire_bytes"]))
    t_cost = time.time() - t0
    total = recon["total"]

    mf = RF.model_flops_per_device(cfg, cell, params_sds(cfg), n_chips)
    terms = RF.roofline(
        {"flops": total["flops"], "bytes accessed": total["bytes"]},
        {"total_wire_bytes": total["wire"]}, mf)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "n_chips": n_chips,
        "perf": {"remat": perf.remat, "attn_chunk": perf.attn_chunk,
                 "accum_steps": perf.accum_steps},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost_raw_scan_once": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "cost": {"flops": total["flops"], "bytes_accessed": total["bytes"],
                 "wire_bytes": total["wire"], "costing_s": round(t_cost, 1)},
        "cost_components": {
            name: {"flops": c["cost"]["flops"], "bytes": c["cost"]["bytes"],
                   "wire": c["cost"]["wire"], "true_count": c["true"]}
            for name, c in recon["per_component"].items()},
        "collectives": {k: (v if isinstance(v, dict) else float(v))
                        for k, v in coll.items()},
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops_per_device": terms.model_flops,
            "useful_flop_ratio": terms.useful_ratio,
            "compute_fraction_of_bound": terms.roofline_fraction,
        },
    }
    fname.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    from repro.configs import SHAPES, cell_is_runnable, get_config, \
        list_configs
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, why = cell_is_runnable(cfg, SHAPES[shape])
            if not ok:
                print(f"SKIP  {arch:24s} {shape:12s} ({why})")
                continue
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                try:
                    rec = run_cell(arch, shape, mp, args.out,
                                   force=args.force)
                    r = rec["roofline"]
                    print(f"OK    {arch:24s} {shape:12s} {mesh_name:11s} "
                          f"compile={rec['compile_s']:7.1f}s "
                          f"mem/dev={rec['memory']['peak_bytes_per_device']/2**30:6.2f}GiB "
                          f"[C {r['compute_s']:.2e} M {r['memory_s']:.2e} "
                          f"N {r['collective_s']:.2e}] dom={r['dominant']}",
                          flush=True)
                except Exception as e:
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"FAIL  {arch:24s} {shape:12s} {mesh_name:11s} "
                          f"{type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + "; ".join(f"{a}/{s}/{m}" for a, s, m, _ in failures))
    print("ALL CELLS PASSED")


if __name__ == "__main__":
    main()
