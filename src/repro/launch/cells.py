"""Cell enumeration + per-cell performance defaults (the hillclimb surface).

A *cell* is (architecture x input shape).  ``default_perf`` holds the
baseline knobs recorded in EXPERIMENTS.md §Roofline; ``PERF_OVERRIDES``
carries the hillclimbed settings for the three chosen cells (§Perf).
"""
from __future__ import annotations

from repro.configs import SHAPES, ArchConfig, ShapeCell, cell_is_runnable, \
    get_config, list_configs
from repro.models.model import PerfConfig

DATA_AXIS = 16          # per-pod data-parallel width


def runnable_cells() -> list[tuple[str, str]]:
    out = []
    for arch in list_configs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_is_runnable(cfg, shape)
            if ok:
                out.append((arch, shape.name))
    return out


def default_perf(cfg: ArchConfig, cell: ShapeCell,
                 data_width: int = DATA_AXIS) -> PerfConfig:
    moe_groups = data_width if cfg.moe is not None else 1
    if cell.kind == "train":
        # microbatch = one sequence per data shard; f32 grad accumulation.
        # data_width is 16 single-pod, 32 multi-pod ('pod' x 'data').
        accum = max(1, cell.global_batch // data_width)
        return PerfConfig(remat="full", accum_steps=accum,
                          attn_chunk=512 if cell.seq_len > 8192 else None,
                          moe_groups=moe_groups)
    if cell.kind == "prefill":
        return PerfConfig(remat="none", attn_chunk=1024,
                          moe_groups=moe_groups)
    # decode: scan-carry cache updates (in-place on TPU; the CPU backend's
    # memory analysis charges one conservative carry copy — see DESIGN.md)
    return PerfConfig(remat="none", scan_layers=True,
                      moe_groups=moe_groups)


# hillclimbed overrides, keyed (arch, shape, data_width) — EXPERIMENTS.md §Perf
PERF_OVERRIDES: dict[tuple[str, str, int], PerfConfig] = {
    # pure-FSDP: no TP activation all-reduces for a 1.6B model
    # (bound 5.31s -> 2.89s; collective term 13.8x down).  Single-pod only:
    # ZeRO-3 over the whole mesh needs global_batch >= chip count (256 ok
    # for 256 chips; the 512-chip multi-pod falls back to the 2D default —
    # hierarchical FSDP over (data, model) with pod-DP would need batch 512)
    ("stablelm-1.6b", "train_4k", 16):
        PerfConfig(remat="full", accum_steps=1, parallelism="fsdp"),
    # group-local MoE dispatch + (G, E)-parallel expert GEMMs + accum tune
    # (bound 310s -> 13.7s; 22.6x)
    ("deepseek-v2-lite-16b", "train_4k", 16):
        PerfConfig(remat="full", accum_steps=4, moe_groups=16),
    ("deepseek-v2-lite-16b", "train_4k", 32):
        PerfConfig(remat="full", accum_steps=8, moe_groups=32),
    # int8 KV cache (KIVI-style): memory term 3.3x down, fits 5.0 GiB/dev
    ("codeqwen1.5-7b", "decode_32k", 16):
        PerfConfig(remat="none", kv_quant=True),
    ("codeqwen1.5-7b", "decode_32k", 32):
        PerfConfig(remat="none", kv_quant=True),
}


def perf_for(arch: str, shape_name: str,
             data_width: int = DATA_AXIS) -> PerfConfig:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    return PERF_OVERRIDES.get((arch, shape_name, data_width),
                              default_perf(cfg, cell, data_width))
