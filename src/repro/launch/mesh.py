"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.

Target: TPU v5e, 256 chips/pod (16x16 ICI torus mapped as data x model),
2 pods over DCN for the multi-pod configuration ('pod' extends the data
axis; gradient all-reduce is hierarchical: reduce-scatter over ICI 'data',
all-reduce over DCN 'pod').
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
