"""Sharded, atomic, async checkpointing with elastic re-mesh restore.

Layout:  <dir>/step_000123/
            manifest.json     — step, leaf paths, shapes, dtypes, mesh info
            host00.npz        — this host's shard of every leaf (flattened)

Write protocol: stage into ``step_XXX.tmp`` then ``os.rename`` (atomic on
POSIX) — a crash mid-save never corrupts the newest complete checkpoint;
``latest_step`` only trusts directories with a manifest.  Saves can run on a
background thread (async) with an explicit ``wait()`` barrier.

Elastic restore: leaves are loaded as host arrays and ``device_put`` with
the TARGET mesh's NamedSharding — a checkpoint saved on mesh M restores
onto any M' (resharding is jax's lazy slice-placement; tested 8 -> 4
devices in tests/test_runtime.py).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
         extra: Optional[dict] = None, host_index: int = 0,
         flat: Optional[dict] = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    if flat is None:
        flat = _flatten(tree)
    np.savez(tmp / f"host{host_index:02d}.npz", **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and not d.name.endswith(".tmp") \
                and (d / "manifest.json").exists():
            steps.append(int(d.name[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, step: int, target: Any,
            mesh=None, specs: Any = None, host_index: int = 0) -> Any:
    """Restore into the structure of ``target`` (pytree of arrays/SDS).

    With (mesh, specs): device_put each leaf with the NamedSharding of the
    TARGET mesh — this is the elastic re-mesh path.
    """
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / f"host{host_index:02d}.npz")
    flat_specs = None
    if specs is not None:
        flat_specs = {}
        for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)):
            key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                           for k in path)
            flat_specs[key] = s

    def rebuild(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} != target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if mesh is not None and flat_specs is not None:
            return jax.device_put(
                arr, jax.sharding.NamedSharding(mesh, flat_specs[key]))
        return jax.device_put(arr)

    return jax.tree_util.tree_map_with_path(rebuild, target)


class CheckpointManager:
    """Keep-last-k manager with optional async saves."""

    def __init__(self, ckpt_dir: str | pathlib.Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # materialize to HOST memory synchronously: the caller's next train
        # step DONATES these buffers, so the async thread must never touch
        # device arrays (only the file write runs in the background)
        flat = _flatten(tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, flat, extra),
                daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, flat, extra)

    def _save_and_gc(self, step, flat, extra):
        save(self.dir, step, None, extra, flat=flat)
        steps = sorted(
            int(d.name[5:]) for d in self.dir.iterdir()
            if d.name.startswith("step_") and not d.name.endswith(".tmp")
            and (d / "manifest.json").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.dir)

    def restore(self, step: int, target: Any, mesh=None, specs=None) -> Any:
        return restore(self.dir, step, target, mesh, specs)
