"""qwen2-vl-72b [vlm]: 80L, d=8192, 64H GQA(kv=8), d_ff=29568, vocab=152064.

[arXiv:2409.12191; hf].  M-RoPE (t/h/w sections 16/24/24 of the 64 rotary
half-dims) + QKV bias.  Vision frontend is a STUB: input_specs() supplies
precomputed patch embeddings for the first n_prefix_embeds positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), frontend="vision", n_prefix_embeds=256,
)
