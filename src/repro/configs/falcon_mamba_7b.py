"""falcon-mamba-7b [ssm]: 64L pure Mamba-1, d=4096, state=16, vocab=65024.

[arXiv:2410.05355].  Attention-free; d_inner=8192 (expand=2), d_conv=4.
"""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm=SSMCfg(version=1, d_state=16, d_conv=4, expand=2),
)
