"""Architecture configuration schema + shape cells for the assigned pool.

Every assigned architecture gets one ``configs/<id>.py`` defining ``CONFIG``
(exact public numbers) — the registry in ``configs/__init__`` collects them.
``ArchConfig.reduced()`` returns the smoke-test scale of the same family
(small layers/width/experts/vocab) used by per-arch CPU tests; the FULL
configs are only ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    first_dense: int = 1          # leading dense layers (DeepSeek-V2 style)
    d_ff_dense: int = 0           # d_ff of those dense layers (0 => 4*d)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    q_lora: Optional[int] = None  # None => direct q projection (V2-Lite)
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    version: int = 1              # 1 = Mamba, 2 = Mamba-2 (SSD)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 => ceil(d_model / 16)
    headdim: int = 64             # mamba2 head dim
    chunk: int = 256              # chunked-scan chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 => d_model // n_heads
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple] = None   # qwen2-vl M-RoPE (t, h, w) split
    sliding_window: Optional[int] = None
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    n_enc_layers: int = 0         # encdec only
    enc_seq: int = 1500           # whisper audio frames after conv stem
    attn_every: int = 0           # hybrid: shared attn block period
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    qkv_bias: bool = False        # qwen-style attention input biases
    frontend: Optional[str] = None  # 'audio' | 'vision' (stub embeddings)
    n_prefix_embeds: int = 0      # vlm: leading positions fed by the stub

    @property
    def head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.qk_nope + self.mla.qk_rope
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (bounded state per token)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 6),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, int(round(4 * self.n_kv_heads / max(self.n_heads, 1)))),
            d_ff=256,
            vocab=512,
            d_head=32,
            sliding_window=64 if self.sliding_window else None,
            enc_seq=32,
            n_enc_layers=2 if self.n_enc_layers else 0,
            attn_every=3 if self.attn_every else 0,
            n_prefix_embeds=8 if self.n_prefix_embeds else 0,
        )
        if self.mrope_sections is not None:
            changes["mrope_sections"] = (4, 6, 6)   # sums to d_head/2 = 16
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_routed=8, n_shared=min(self.moe.n_shared, 2),
                top_k=2, d_expert=64, first_dense=min(self.moe.first_dense, 1),
                d_ff_dense=256)
        if self.mla is not None:
            changes["mla"] = dataclasses.replace(
                self.mla, kv_lora=64,
                q_lora=(96 if self.mla.q_lora else None),
                qk_nope=32, qk_rope=16, v_dim=32)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=8, headdim=16, chunk=16)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# shape cells (assigned): every LM arch x these four
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Apply the assignment's skip rules; returns (runnable, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic"
    return True, ""
