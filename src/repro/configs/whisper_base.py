"""whisper-base [audio]: enc-dec, 6L+6L, d=512, 8H, d_ff=2048, vocab=51865.

[arXiv:2212.04356].  Audio conv frontend is a STUB per the assignment:
input_specs() supplies precomputed frame embeddings [B, 1500, 512].
Decoder uses RoPE in this implementation (deviation from Whisper's learned
absolute embeddings, noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    enc_seq=1500, norm_type="layernorm", frontend="audio",
)
