"""zamba2-1.2b [hybrid]: 38L Mamba-2 backbone + shared attn block, d=2048.

[arXiv:2411.15242; hf].  ssm_state=64, headdim=64; ONE shared attention+MLP
block (d_ff=8192, 32H) re-applied every 6 mamba layers (weight re-use, the
Zamba signature).
"""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, attn_every=6,
    ssm=SSMCfg(version=2, d_state=64, d_conv=4, expand=2, headdim=64),
)
