"""Config registry: one module per assigned architecture (+ paper AP config).

``get_config(name)`` accepts the assignment ids (e.g. 'deepseek-v2-lite-16b').
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg, SSMCfg, \
    SHAPES, ShapeCell, cell_is_runnable  # noqa: F401

from repro.configs import (codeqwen1_5_7b, deepseek_v2_236b,  # noqa: E402
                           deepseek_v2_lite_16b, falcon_mamba_7b,
                           h2o_danube_3_4b, phi3_medium_14b, qwen2_vl_72b,
                           stablelm_1_6b, whisper_base, zamba2_1_2b)

_ALL = [
    whisper_base.CONFIG,
    deepseek_v2_236b.CONFIG,
    deepseek_v2_lite_16b.CONFIG,
    stablelm_1_6b.CONFIG,
    phi3_medium_14b.CONFIG,
    codeqwen1_5_7b.CONFIG,
    h2o_danube_3_4b.CONFIG,
    qwen2_vl_72b.CONFIG,
    zamba2_1_2b.CONFIG,
    falcon_mamba_7b.CONFIG,
]
REGISTRY = {c.name: c for c in _ALL}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs() -> list[str]:
    return [c.name for c in _ALL]
