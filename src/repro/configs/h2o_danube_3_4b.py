"""h2o-danube-3-4b [dense]: 24L, d=3840, 32H GQA(kv=8), d_ff=10240, SWA.

[arXiv:2401.16818].  llama+mistral mix with sliding-window attention
(window 4096) -> the KV ring buffer keeps long_500k decode O(W).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, sliding_window=4096,
)
