"""deepseek-v2-lite-16b [moe]: 27L, d=2048, 16H MLA, MoE 64e top-6 + 2 shared.

[arXiv:2405.04434; hf].  MLA kv_lora=512 without q-LoRA; d_expert=1408;
first layer dense (d_ff=10944).
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    mla=MLACfg(kv_lora=512, q_lora=None, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoECfg(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
               first_dense=1, d_ff_dense=10944),
)
