"""codeqwen1.5-7b [dense]: 32L, d=4096, 32H MHA, d_ff=13440, vocab=92416.

[hf:Qwen/CodeQwen1.5-7B].  Qwen1.5 arch: QKV bias + RoPE + SwiGLU.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, qkv_bias=True, rope_theta=1e6,
)
