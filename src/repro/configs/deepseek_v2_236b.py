"""deepseek-v2-236b [moe]: 60L, d=5120, 128H MLA, MoE 160e top-6 + 2 shared.

[arXiv:2405.04434; hf].  MLA kv_lora=512, q_lora=1536, qk_nope=128,
qk_rope=64, v=128.  First layer dense (d_ff=12288), remaining 59 MoE with
d_expert=1536.
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    mla=MLACfg(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoECfg(n_routed=160, n_shared=2, top_k=6, d_expert=1536,
               first_dense=1, d_ff_dense=12288),
)
