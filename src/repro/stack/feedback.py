"""Closed-loop temperature↔power co-simulation for heterogeneous stacks.

The open-loop replay (``core/cosim.py``) treats power as a fixed input
trace.  This module closes the loop inside the ``lax.scan`` over trace
intervals through three temperature couplings —

1. **DRAM refresh** — JEDEC bins (``stack.dram.refresh_multiplier``):
   refresh power doubles above 85 °C and doubles again above 95 °C,
   evaluated per cell so a hot bank refreshes harder than a cool one.
2. **Leakage** — exponential in temperature,
   ``leak0 * exp(beta (T − T_ref))``, applied to every die layer.
3. **DTM/DVFS policy** — a sampled controller from the
   ``repro.policy`` family (linear ramp, step trip, hysteresis, PID,
   per-die throttling, discrete DVFS stepping, model-predictive; see
   docs/policies.md).  Each interval the policy reads the measured
   per-layer hot spots and sets a *power* duty (scalar or per-die) that
   scales the dynamic power, plus a *performance* duty f ∈ (0, 1]
   recorded per interval so lost cycles can be accounted as a runtime
   slowdown (mean 1/f).  The default policy is the historical linear
   ramp off ``dtm_trip_C``/``dtm_ramp_C``/``dtm_floor`` — bit-identical
   to the pre-policy-engine throttle (tests/test_policy.py) — and the
   controller state (hysteresis latch, PID integral, DVFS operating
   point) threads through the scan carry, vmapping per design point.

Refresh and leakage are *instantaneous physics*, so they are solved
implicitly by **Picard iteration**: iterate k evaluates them at iterate
k−1's end-of-interval temperature and re-integrates the interval with the
unconditionally-stable theta steps from PR 1 (``thermal.pcg_fixed`` inner
solves).  These couplings are weak over one interval, so the recorded
fixed-point residual ``max |T_k − T_{k−1}|`` contracts below
``picard_tol_C`` (0.05 °C) on EVERY interval — including the violent DTM
bang-bang transients with 80 °C intra-interval swings — within the
default ``n_picard = 6`` (tests and the bench assert it; regime residuals
are ~1e-4…1e-3 °C, the 0.05 °C bar absorbs refresh-bin boundary cells
flipping 2×↔4× between iterates during those transients).  The DTM throttle is deliberately NOT in the fixed point: it is a
sampled controller actuating on the start-of-interval (measured)
temperature — iterating a gain≳1 bang-bang actuator on the unknown end
state has no contractive fixed point and Picard limit-cycles.  The whole
replay is one ``lax.scan`` and vmaps over a batch of (workload × machine)
design points.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import cosim
from repro.core import models as M
from repro.core import thermal
from repro.core.constants import AMBIENT_C, DRAM_LIMIT_C
from repro.core.floorplan import MM, APFloorplan, SIMDFloorplan
from repro.faults.models import SensorFaultSpec
from repro.policy import Policy, PolicyContext, RampPolicy
from repro.stack import dram
from repro.stack.spec import (DRAM, LOGIC, PAPER_STACK, StackParams,
                              StackSpec, dram_on_logic)


@dataclasses.dataclass(frozen=True)
class FeedbackParams:
    """Feedback-loop constants (hashable -> usable as a jit static arg).

    ``policy`` selects the DTM/DVFS controller (``repro.policy``); None
    resolves to the classic linear ramp built from the ``dtm_*`` fields
    below, which therefore keep their historical meaning (and their
    bit-identical trajectories)."""
    leak_beta: float = 0.012     # 1/K exponential leakage slope (~2x / 60 K)
    t_ref_C: float = AMBIENT_C   # leakage reference temperature
    n_picard: int = 6            # fixed Picard iterations per interval
    picard_tol_C: float = 0.05   # documented per-step residual bar [°C]
    dtm_trip_C: float = 95.0     # logic hot-spot trip temperature
    dtm_ramp_C: float = 10.0     # °C over which power ramps down to floor
    dtm_floor: float = 0.25      # minimum DTM duty factor
    refresh_feedback: bool = True   # False -> refresh pinned at 1x
    policy: Policy | None = None    # None -> ramp from the dtm_* fields
    faults: SensorFaultSpec | None = None   # None -> perfect sensing;
    #   a spec injects sensor faults into the temperatures the policy
    #   reads (repro.faults; fault state rides the scan carry).  None
    #   keeps the traced program bit-identical to the pre-faults replay
    #   (tests/test_faults.py pins the jaxpr).

    def __post_init__(self):
        if not (0.0 < self.dtm_floor <= 1.0):
            raise ValueError("dtm_floor must lie in (0, 1] (0 breaks the "
                             "mean(1/f) slowdown accounting, > 1 is not "
                             f"a floor); got {self.dtm_floor!r}")
        if math.isnan(self.dtm_trip_C) or self.dtm_trip_C == -math.inf:
            raise ValueError("dtm_trip_C must be a real temperature or "
                             "math.inf (= DTM never trips); got "
                             f"{self.dtm_trip_C!r}")
        if self.dtm_ramp_C < 0:
            raise ValueError("dtm_ramp_C must be >= 0 (0 = step trip); "
                             f"got {self.dtm_ramp_C!r}")

    def resolved_policy(self) -> Policy:
        """The controller the replay actually runs."""
        if self.policy is not None:
            return self.policy
        return RampPolicy(trip_C=self.dtm_trip_C, ramp_C=self.dtm_ramp_C,
                          floor=self.dtm_floor)

    @classmethod
    def disabled(cls) -> "FeedbackParams":
        """Open-loop limit: constant leakage, 1x refresh, no DTM.

        ``n_picard = 2`` (not 1): with temperature-independent power the
        second iterate reproduces the first exactly, so the recorded
        residual is a true fixed-point defect (0) rather than the full
        interval temperature swing a single pass would report.
        """
        return cls(leak_beta=0.0, n_picard=2, dtm_trip_C=math.inf,
                   refresh_feedback=False)


# ---------------------------------------------------------------------------
# closed-loop replay core (scan over intervals; vmappable over design points)
# ---------------------------------------------------------------------------

def _closed_loop(dyn_frames, leak0, refresh0, logic_mask, F, cap3,
                 interval_dt, theta, t_amb, *, fb: FeedbackParams,
                 steps_per_interval: int, n_cg: int, n_die: int,
                 margin: int, die_n: int, use_pallas: bool,
                 solver: str = "pcg", n_mg: int = 3, dt_scale=None):
    if use_pallas:
        from repro.kernels.thermal_stencil import ops as _ops
        A = lambda v: _ops.apply_operator_fields(v, F)
    else:
        A = lambda v: thermal.apply_operator_fields(v, F)
    if dt_scale is None:
        dt = interval_dt / steps_per_interval
        # fixed-cost inner solve for the theta-scheme LHS: n_cg PCG
        # iterations or n_mg multigrid V-cycles (hierarchy built once,
        # here)
        solve = thermal.implicit_lhs_solver(A, F, cap3, dt, theta,
                                            solver=solver, n_cg=n_cg,
                                            n_mg=n_mg, use_pallas=use_pallas)
        solve_for = lambda _scale: solve
    else:
        # variable-dt replay (coarsened serving traces): the step size is
        # a traced per-interval quantity, so the theta-scheme LHS and its
        # Jacobi preconditioner are rebuilt inside the scan body.  The
        # multigrid hierarchy is assembled for ONE dt, hence PCG only.
        if solver != "pcg":
            raise ValueError("variable-dt replay (dt_scale) requires "
                             "solver='pcg'; the multigrid hierarchy is "
                             "built for a fixed step")
        diagA = thermal._diag_fields(F)

        def solve_for(scale):
            dt = interval_dt * scale / steps_per_interval
            lhs = lambda v: cap3 / dt * v + theta * A(v)
            Minv = 1.0 / (cap3 / dt + theta * diagA)
            return lambda rhs: thermal.pcg_fixed(lhs, Minv, rhs, n_cg)
    lm3 = logic_mask[:, None, None]
    # DRAM layers are exactly the refresh-bearing ones (base refresh is
    # strictly positive on every DRAM die) — derived here so per-die
    # policies need no extra replay argument
    dram_mask = (jnp.sum(refresh0, axis=(1, 2)) > 0).astype(
        logic_mask.dtype)
    policy = fb.resolved_policy()
    fspec = fb.faults
    n_layers = int(logic_mask.shape[0])

    def interval(carry, xs):
        # fspec is STATIC (a FeedbackParams field), so the fault-free
        # branch keeps today's carry/body verbatim — a replay without a
        # fault spec traces zero additional operations
        if fspec is None:
            dTc, pstate = carry
        else:
            dTc, pstate, fstate = carry
        P_dyn, scale = xs
        solve = solve_for(scale)
        # The policy actuates on the MEASURED (start-of-interval) hot
        # spots — a real DTM controller reads the previous temperature
        # sample.  Iterating it on the end-of-interval state instead
        # couples a gain->1 bang-bang controller into the fixed point
        # and Picard limit-cycles (~40 C swings); sampled actuation
        # keeps only the weak, contractive couplings (refresh bins,
        # leakage) implicit.
        layer_T = jnp.max(dTc, axis=(1, 2)) + t_amb
        sensor_T = None
        if fspec is not None:
            # what the controller SENSES is the faulted readings: the
            # primary (row 0) replaces layer_T, the full [K, L] array is
            # exposed for hardened policies (GuardedPolicy)
            fstate, sensor_T = fspec.read(fstate, layer_T)
            layer_T = sensor_T[0]
        predict = cosim.interval_forecaster(A, solve, lm3, t_amb)
        ctx = PolicyContext(
            layer_T=layer_T, logic_mask=logic_mask, dram_mask=dram_mask,
            predict_hot=predict(dTc, P_dyn, leak0 + refresh0),
            sensor_T=sensor_T)
        pstate, f_power, f = policy.act(pstate, ctx)
        fp3 = f_power if jnp.ndim(f_power) == 0 else f_power[:, None, None]
        P_base = fp3 * P_dyn

        def picard(_, st):
            dTk, _res, _aux = st
            T = dTk + t_amb
            p_leak = leak0 * jnp.exp(fb.leak_beta * (T - fb.t_ref_C))
            p_ref = refresh0 * dram.refresh_multiplier(T) \
                if fb.refresh_feedback else refresh0
            P = P_base + p_leak + p_ref

            def one(d, _):
                rhs = P - A(d)
                return d + solve(rhs), None

            dTn, _ = jax.lax.scan(one, dTc, None,
                                  length=steps_per_interval)
            return dTn, jnp.max(jnp.abs(dTn - dTk)), \
                (jnp.sum(p_ref), jnp.sum(p_leak))

        init = (dTc, jnp.float32(jnp.inf),
                (jnp.float32(0.0), jnp.float32(0.0)))
        dTn, res, (ref_W, leak_W) = jax.lax.fori_loop(
            0, fb.n_picard, picard, init)
        die = dTn[:n_die, margin:margin + die_n, margin:margin + die_n]
        carry = (dTn, pstate) if fspec is None else (dTn, pstate, fstate)
        return carry, (
            jnp.max(die, axis=(1, 2)), jnp.min(die, axis=(1, 2)),
            res, f, ref_W, leak_W, jnp.sum(P_base))

    dT0 = jnp.zeros_like(dyn_frames[0])
    init = (dT0, policy.init_state(n_layers)) if fspec is None \
        else (dT0, policy.init_state(n_layers), fspec.init_state(n_layers))
    scales = jnp.ones(dyn_frames.shape[0], dyn_frames.dtype) \
        if dt_scale is None else jnp.asarray(dt_scale, dyn_frames.dtype)
    (dT_end, *_), (mx, mn, res, f, ref_W, leak_W, dyn_W) = \
        jax.lax.scan(interval, init, (dyn_frames, scales))
    return (dT_end + t_amb, mx + t_amb, mn + t_amb, res, f, ref_W,
            leak_W, dyn_W)


_STATIC = ("fb", "steps_per_interval", "n_cg", "n_die", "margin", "die_n",
           "use_pallas", "solver", "n_mg")


@partial(jax.jit, static_argnames=_STATIC)
def closed_loop_replay(dyn_frames, leak0, refresh0, logic_mask, F: dict,
                       cap3, interval_dt, theta: float = 1.0,
                       t_amb: float = AMBIENT_C, *, fb: FeedbackParams,
                       die_n: int, n_die: int, steps_per_interval: int = 2,
                       n_cg: int = 40, margin: int = 0,
                       use_pallas: bool = False, solver: str = "pcg",
                       n_mg: int = 3, dt_scale=None):
    """Replay one frame stack with temperature feedback.

    dyn_frames [T, L, NY, NX]: trace-modulated *dynamic* power (logic
    switching + DRAM activate/IO) — NO leakage or refresh baked in;
    leak0 / refresh0 [L, NY, NX]: leakage at ``fb.t_ref_C`` and 1× refresh
    power; logic_mask [L]: 1.0 on layers whose hot spot trips the DTM.
    ``solver`` picks the fixed-cost inner solve: ``n_cg`` PCG iterations
    ("pcg") or ``n_mg`` multigrid V-cycles ("mg").

    ``dt_scale`` [T] (optional) stretches interval i to
    ``interval_dt * dt_scale[i]`` — the variable-step replay coarsened
    serving traces use (``cosim.CoarsePlan.dt_scale``).  PCG only: the
    step size becomes a traced quantity, which the fixed multigrid
    hierarchy cannot follow.  The DTM controller then samples at the
    coarsened boundaries (its reaction time follows the local step).

    Returns (T_end [L,NY,NX], peak_C [T,n_die], min_C [T,n_die],
    residual_C [T], throttle [T], refresh_W [T], leak_W [T],
    dyn_W [T]).  ``throttle`` is the policy's *performance* duty (what
    scales runtime); ``dyn_W`` is the policy-scaled dynamic power
    actually dissipated, so refresh + leak + dyn is the stack's total
    draw per interval (the energy axis of the policy Pareto bench).
    """
    return _closed_loop(dyn_frames, leak0, refresh0, logic_mask, F, cap3,
                        interval_dt, theta, t_amb, fb=fb,
                        steps_per_interval=steps_per_interval, n_cg=n_cg,
                        n_die=n_die, margin=margin, die_n=die_n,
                        use_pallas=use_pallas, solver=solver, n_mg=n_mg,
                        dt_scale=dt_scale)


@partial(jax.jit, static_argnames=_STATIC)
def closed_loop_batch(dyn_frames, leak0, refresh0, logic_mask, F: dict,
                      cap3, interval_dt, theta: float = 1.0,
                      t_amb: float = AMBIENT_C, *, fb: FeedbackParams,
                      die_n: int, n_die: int, steps_per_interval: int = 2,
                      n_cg: int = 40, margin: int = 0,
                      use_pallas: bool = False, solver: str = "pcg",
                      n_mg: int = 3):
    """vmapped closed-loop replay over a leading design-point batch."""
    fn = partial(_closed_loop, fb=fb,
                 steps_per_interval=steps_per_interval, n_cg=n_cg,
                 n_die=n_die, margin=margin, die_n=die_n,
                 use_pallas=use_pallas, solver=solver, n_mg=n_mg)
    return jax.vmap(
        lambda fr, l0, r0, lm, Fb, cb: fn(fr, l0, r0, lm, Fb, cb,
                                          interval_dt, theta, t_amb)
    )(dyn_frames, leak0, refresh0, logic_mask, F, cap3)


# ---------------------------------------------------------------------------
# power-input assembly for one (machine, stack) case
# ---------------------------------------------------------------------------

def stack_power_inputs(spec: StackSpec, grid: thermal.Grid,
                       trace: cosim.PowerTrace, logic_pmap: np.ndarray,
                       logic_leak_W: float, dram_fp: dram.DRAMFloorplan,
                       traffic_bytes_per_s: float):
    """Build (dyn_frames, leak0, refresh0, logic_mask) for one stack.

    Logic layers carry the floorplan's dynamic map modulated by the trace
    (the §4 convention: every logic layer the same map); DRAM layers carry
    the traffic-driven activate map modulated by the SAME trace (memory
    traffic follows compute activity) plus their leakage/refresh statics.
    """
    gn = logic_pmap.shape[0]
    L, NY, NX, m = grid.n_layers, grid.dom_ny, grid.dom_nx, grid.margin
    Tn = trace.n_intervals
    act = trace.activity.astype(np.float32)[:, None, None]

    dyn = np.zeros((Tn, L, NY, NX), np.float32)
    leak0 = np.zeros((L, NY, NX), np.float32)
    refresh0 = np.zeros((L, NY, NX), np.float32)

    leak_cell = logic_leak_W / gn ** 2
    dyn_logic = (logic_pmap - leak_cell).astype(np.float32)
    n_dram = len(spec.dram_layers)
    act_map = dram_fp.activate_map(gn) \
        * dram.activate_io_W(traffic_bytes_per_s, n_dram)
    ref_map = dram_fp.refresh_map(gn) * dram_fp.base_refresh_W()
    dram_leak_cell = dram_fp.leakage_W() / gn ** 2

    win = (slice(m, m + gn), slice(m, m + gn))
    for l, layer in enumerate(spec.layers[:-1]):
        if layer.kind == LOGIC:
            dyn[(slice(None), l) + win] = act * dyn_logic
            leak0[(l,) + win] = leak_cell
        elif layer.kind == DRAM:
            dyn[(slice(None), l) + win] = act * act_map
            leak0[(l,) + win] = dram_leak_cell
            refresh0[(l,) + win] = ref_map
    return dyn, leak0, refresh0, spec.layer_mask(LOGIC)


def stack_power_frames(spec: StackSpec, grid: thermal.Grid,
                       activity: np.ndarray, logic_pmap: np.ndarray,
                       logic_leak_W: float, dram_fp: dram.DRAMFloorplan,
                       traffic_bytes_per_s):
    """:func:`stack_power_inputs` for externally-computed interval signals.

    ``activity`` [T] is a raw utilization trace (serving busy fraction;
    NOT mean-normalized like a :class:`~repro.core.cosim.PowerTrace`) —
    logic layers draw ``activity[t] *`` their dynamic map.  DRAM activate
    power follows ``traffic_bytes_per_s``: a scalar is modulated by the
    same activity (the `stack_power_inputs` convention, traffic tracks
    compute), while an array [T] is taken as the per-interval traffic
    verbatim (the serving lowering varies it with the decode batch's
    arithmetic intensity).  Returns the same
    (dyn, leak0, refresh0, logic_mask) tuple.
    """
    gn = logic_pmap.shape[0]
    L, NY, NX, m = grid.n_layers, grid.dom_ny, grid.dom_nx, grid.margin
    act = np.asarray(activity, np.float32)
    if act.ndim != 1:
        raise ValueError("activity must be a 1-D interval signal")
    Tn = act.shape[0]
    n_dram = len(spec.dram_layers)
    traffic = np.asarray(traffic_bytes_per_s, np.float64)
    if traffic.ndim == 0:
        io_W_t = act * dram.activate_io_W(float(traffic), n_dram)
    elif traffic.shape == (Tn,):
        io_W_t = np.array([dram.activate_io_W(float(b), n_dram)
                           for b in traffic], np.float32)
    else:
        raise ValueError("traffic_bytes_per_s must be a scalar or match "
                         "the activity length")

    dyn = np.zeros((Tn, L, NY, NX), np.float32)
    leak0 = np.zeros((L, NY, NX), np.float32)
    refresh0 = np.zeros((L, NY, NX), np.float32)

    leak_cell = logic_leak_W / gn ** 2
    dyn_logic = (logic_pmap - leak_cell).astype(np.float32)
    act_shape = dram_fp.activate_map(gn)
    ref_map = dram_fp.refresh_map(gn) * dram_fp.base_refresh_W()
    dram_leak_cell = dram_fp.leakage_W() / gn ** 2

    win = (slice(m, m + gn), slice(m, m + gn))
    for l, layer in enumerate(spec.layers[:-1]):
        if layer.kind == LOGIC:
            dyn[(slice(None), l) + win] = \
                act[:, None, None] * dyn_logic
            leak0[(l,) + win] = leak_cell
        elif layer.kind == DRAM:
            dyn[(slice(None), l) + win] = \
                io_W_t[:, None, None] * act_shape
            leak0[(l,) + win] = dram_leak_cell
            refresh0[(l,) + win] = ref_map
    return dyn, leak0, refresh0, spec.layer_mask(LOGIC)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackReport:
    """Time-resolved closed-loop summary of one stack replay."""
    label: str
    interval_s: float
    spec: StackSpec
    peak_C: np.ndarray          # [T, n_die]
    min_C: np.ndarray           # [T, n_die]
    residual_C: np.ndarray      # [T] final Picard residual per interval
    throttle: np.ndarray        # [T] DTM duty factor in (0, 1]
    refresh_W: np.ndarray       # [T] total DRAM refresh power
    leak_W: np.ndarray          # [T] total leakage power
    base_refresh_W: float       # 1x refresh total of all DRAM dies
    tol_C: float = FeedbackParams.picard_tol_C   # the run's residual bar
    dyn_W: np.ndarray | None = None   # [T] policy-scaled dynamic power

    @property
    def times(self) -> np.ndarray:
        return self.interval_s * np.arange(1, self.peak_C.shape[0] + 1)

    @property
    def span_C(self) -> np.ndarray:
        return self.peak_C - self.min_C

    def _layer_peak(self, idx: tuple[int, ...]) -> np.ndarray:
        if not idx:
            return np.zeros(self.peak_C.shape[0], self.peak_C.dtype)
        return self.peak_C[:, list(idx)].max(axis=1)

    @property
    def dram_peak_C(self) -> np.ndarray:
        """[T] hottest DRAM cell per interval (zeros if no DRAM dies)."""
        return self._layer_peak(self.spec.dram_layers)

    @property
    def logic_peak_C(self) -> np.ndarray:
        return self._layer_peak(self.spec.logic_layers)

    @property
    def refresh_overhead(self) -> float:
        """Mean refresh power / the 1× (cool-DRAM) refresh power."""
        if self.base_refresh_W <= 0:
            return 1.0
        return float(self.refresh_W.mean() / self.base_refresh_W)

    @property
    def dtm_slowdown(self) -> float:
        """Runtime inflation from throttling: mean(1/f) >= 1."""
        return float(np.mean(1.0 / self.throttle))

    @property
    def energy_J(self) -> float:
        """Total energy over the replay window (dynamic + leak + refresh).

        Requires a replay that recorded ``dyn_W`` (every post-policy-engine
        replay does); older pickled reports raise."""
        if self.dyn_W is None:
            raise ValueError("this report predates dyn_W recording")
        return float(self.interval_s
                     * (self.dyn_W + self.leak_W + self.refresh_W).sum())

    @property
    def energy_per_work_J(self) -> float:
        """Energy divided by the fraction of full-speed work completed —
        the energy-to-solution axis of the policy Pareto bench.  A policy
        that halves power but quarters throughput scores WORSE here."""
        return self.energy_J / float(np.mean(self.throttle))

    def time_above(self, limit_C: float = DRAM_LIMIT_C,
                   layers: tuple[int, ...] | None = None) -> np.ndarray:
        """Seconds each selected layer's peak spent above ``limit_C``."""
        sel = list(layers) if layers is not None \
            else list(range(self.peak_C.shape[1]))
        return self.interval_s * (self.peak_C[:, sel] > limit_C).sum(axis=0)

    @property
    def dram_time_above_limit_s(self) -> float:
        if not self.spec.dram_layers:
            return 0.0
        return float(self.time_above(layers=self.spec.dram_layers).max())

    @property
    def converged(self) -> bool:
        """Did EVERY interval's Picard iteration meet the residual bar?"""
        return bool(self.residual_C.max() <= self.tol_C)


# ---------------------------------------------------------------------------
# per-case assembly (shared by run_stack_cosim and repro.sweep.engine)
# ---------------------------------------------------------------------------

def check_finite_power(what: str, **arrays) -> None:
    """Raise ``ValueError`` if any power input carries non-finite cells.

    NaN/inf power silently propagates into every temperature of a
    replay and from there into verdict tables (NaN compares False
    against the 85 °C ceiling, i.e. reads as OK) — fail at assembly
    instead, naming the offending input.
    """
    for name, arr in arrays.items():
        arr = np.asarray(arr)
        if not np.isfinite(arr).all():
            n_bad = int((~np.isfinite(arr)).sum())
            raise ValueError(
                f"{what}: power input {name!r} has {n_bad} non-finite "
                f"cell(s) (shape {arr.shape}); refusing to replay — "
                "NaN temperatures would silently pass the 85C verdict")


def assemble_case(dp: M.DesignPoint, workload: str, machine: str,
                  spec: StackSpec, params: StackParams, grid_n: int,
                  trace: cosim.PowerTrace, margin: int):
    """Build the closed-loop replay inputs for one (workload, machine) case.

    Returns (dyn, leak0, refresh0, logic_mask, F, cap3) — exactly the
    per-case leaves :func:`closed_loop_batch` stacks over its leading
    batch axis.  ``machine`` is "ap" or "simd"; the DRAM traffic figure
    is shared by construction (``models.mem_traffic_bytes_per_s``).
    """
    wl = M.WORKLOADS[workload]
    traffic = M.mem_traffic_bytes_per_s(workload, dp.ap_n_pus)
    if machine == "ap":
        fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
        pmap = fp.power_map(grid_n, dp.ap_power_W)
        leak_W = fp.leakage_W()
    elif machine == "simd":
        fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))
        pmap = fp.power_map(grid_n, dp)
        leak_W = fp.leakage_W(dp)
    else:
        raise ValueError(f"unknown machine {machine!r}")
    del wl  # the SIMD trace is built by the caller (needs n_intervals)
    grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=grid_n, nx=grid_n,
                        params=params, spec=spec, margin=margin)
    dfp = dram.DRAMFloorplan(die_w_mm=fp.die_w_mm)
    dyn, l0, r0, lm = stack_power_inputs(spec, grid, trace, pmap, leak_W,
                                         dfp, traffic)
    check_finite_power(f"assemble_case({workload}/{machine})",
                       dyn_frames=dyn, leak0=l0, refresh0=r0)
    return dyn, l0, r0, lm, grid.fields(), grid.capacity_field()


def closed_loop_sharded(dyn_frames, leak0, refresh0, logic_mask, F: dict,
                        cap3, interval_dt, theta: float = 1.0,
                        t_amb: float = AMBIENT_C, *, fb: FeedbackParams,
                        die_n: int, n_die: int,
                        steps_per_interval: int = 2, n_cg: int = 40,
                        margin: int = 0, use_pallas: bool = False,
                        solver: str = "pcg", n_mg: int = 3,
                        n_shards: int | None = None):
    """:func:`closed_loop_batch` partitioned over local devices.

    The case batch is padded to a multiple of the mesh size (repeating
    the last case; padding rows are dropped from every output) and run
    through ``shard_map`` over a 1D 'cases' mesh
    (``repro.parallel.sharding``).  Each device executes the identical
    per-case program on its slice, so results are bitwise those of the
    unsharded vmap for ANY device count — the property the sweep cache
    relies on (tests/test_shard_sweep.py).
    """
    from repro.parallel import sharding as shardlib
    mesh = shardlib.sweep_mesh(n_shards)
    batch = (dyn_frames, leak0, refresh0, logic_mask, F, cap3)
    batch, n_cases = shardlib.pad_case_batch(batch, mesh.shape["cases"])

    def fn(tree):
        return closed_loop_batch(
            *tree, interval_dt, theta, t_amb, fb=fb, die_n=die_n,
            n_die=n_die, steps_per_interval=steps_per_interval,
            n_cg=n_cg, margin=margin, use_pallas=use_pallas,
            solver=solver, n_mg=n_mg)

    out = shardlib.shard_case_batch(fn, mesh)(batch)
    return shardlib.unpad_case_batch(out, n_cases)


def replay_cases(cases, spec: StackSpec, fb: FeedbackParams, grid_n: int,
                 interval_dt: float, *, theta: float = 1.0,
                 steps_per_interval: int = 2, n_cg: int = 40,
                 margin: int | None = None, use_pallas: bool = False,
                 solver: str = "pcg", n_mg: int = 3,
                 n_shards: int | None = None) -> dict[str, "StackReport"]:
    """Replay pre-assembled cases as ONE vmapped closed-loop batch.

    ``cases``: sequence of (label, :func:`assemble_case` leaves) — every
    case must share the stack ``spec`` and grid shape.  Returns
    {label: StackReport}.  This is the single lowering both
    :func:`run_stack_cosim` and ``repro.sweep.engine`` go through.
    ``n_shards`` routes through :func:`closed_loop_sharded` (0/None =
    plain vmap on one device).
    """
    margin = grid_n // 4 if margin is None else margin
    labels = [label for label, _ in cases]
    dyns, leaks, refs, masks, Fs, caps = zip(*(leaves for _, leaves in cases))
    Fb = {k: jnp.stack([F[k] for F in Fs]) for k in Fs[0]}
    replay = closed_loop_batch if not n_shards else partial(
        closed_loop_sharded, n_shards=n_shards)
    with obs.span("feedback/replay", cases=len(labels), grid_n=grid_n,
                  solver=solver, n_shards=n_shards or 0):
        _, peaks, mins, res, thr, ref_W, leak_W, dyn_W = replay(
            jnp.asarray(np.stack(dyns)), jnp.asarray(np.stack(leaks)),
            jnp.asarray(np.stack(refs)), jnp.asarray(np.stack(masks)), Fb,
            jnp.stack(caps), interval_dt, theta, fb=fb, die_n=grid_n,
            n_die=spec.n_die_layers, steps_per_interval=steps_per_interval,
            n_cg=n_cg, margin=margin, use_pallas=use_pallas, solver=solver,
            n_mg=n_mg)
    if obs.is_enabled():
        res_h, thr_h = np.asarray(res, np.float64), np.asarray(thr,
                                                               np.float64)
        n_int = res_h.shape[-1] if res_h.ndim else 0
        obs.count("feedback/intervals", len(labels) * n_int)
        obs.count("feedback/picard_iterations",
                  len(labels) * n_int * fb.n_picard)
        obs.count("feedback/throttled_intervals",
                  int((thr_h < 1.0).sum()))
        obs.observe_many("feedback/picard_residual_C",
                         res_h.reshape(len(labels), -1).max(axis=1))
        obs.observe_many("feedback/throttle_duty",
                         thr_h.reshape(len(labels), -1).mean(axis=1))
        pol = fb.resolved_policy()
        obs.observe_many(f"policy/{pol.name}/duty", thr_h.ravel())
        resid = pol.residency(thr_h)
        for op, n in (resid or {}).items():
            obs.count(f"policy/{pol.name}/residency/{op}", n)
    base_ref = dram.DRAMFloorplan(die_w_mm=1.0).base_refresh_W() \
        * len(spec.dram_layers)
    return {
        label: StackReport(
            label=label, interval_s=interval_dt, spec=spec,
            peak_C=np.asarray(peaks[i]), min_C=np.asarray(mins[i]),
            residual_C=np.asarray(res[i]), throttle=np.asarray(thr[i]),
            refresh_W=np.asarray(ref_W[i]), leak_W=np.asarray(leak_W[i]),
            base_refresh_W=base_ref, tol_C=fb.picard_tol_C,
            dyn_W=np.asarray(dyn_W[i]))
        for i, label in enumerate(labels)}


# ---------------------------------------------------------------------------
# top-level driver: batched AP+DRAM vs SIMD+DRAM closed-loop co-simulation
# ---------------------------------------------------------------------------

def run_stack_cosim(workloads=("dmm", "fft", "bs"), n_dram: int = 2,
                    grid_n: int = 16, n_intervals: int = 32,
                    t_end: float = 0.25, steps_per_interval: int = 2,
                    n_cg: int = 40, theta: float = 1.0,
                    fb: FeedbackParams = FeedbackParams(),
                    params: StackParams = PAPER_STACK,
                    use_pallas: bool = False, solver: str = "pcg",
                    n_mg: int = 3, n_shards: int | None = None) -> dict:
    """The paper's abstract claim, quantified: for each workload replay the
    AP and the same-performance SIMD under ``n_dram`` stacked DRAM dies
    with closed-loop refresh/leakage/DTM feedback, in ONE vmapped batch.

    Returns ``{workload: {"ap": StackReport, "simd": StackReport},
    "design_points": {...}, "spec": StackSpec, ...}``.
    """
    spec = dram_on_logic(n_dram, params)
    margin = grid_n // 4
    interval_dt = t_end / n_intervals
    n_small = cosim.trace_elems(M.N_DATA)    # shared trace-sizing rule

    cases, dps = [], {}
    for w in workloads:
        dp = cosim.comparable_design_point(w)
        dps[w] = dp
        wl = M.WORKLOADS[w]
        pair = (("ap", cosim.ap_workload_trace(w, n_intervals, n_small)),
                ("simd", cosim.simd_phase_trace(wl, dp, n_intervals)))
        for machine, trace in pair:
            cases.append((f"{w}/{machine}", assemble_case(
                dp, w, machine, spec, params, grid_n, trace, margin)))

    reports = replay_cases(cases, spec, fb, grid_n, interval_dt,
                           theta=theta,
                           steps_per_interval=steps_per_interval,
                           n_cg=n_cg, margin=margin, use_pallas=use_pallas,
                           solver=solver, n_mg=n_mg, n_shards=n_shards)
    out: dict = {"design_points": dps, "spec": spec,
                 "interval_s": interval_dt, "t_end": t_end, "fb": fb}
    for label, rep in reports.items():
        w, machine = label.split("/")
        out.setdefault(w, {})[machine] = rep
    return out
