"""DRAM die floorplan + power model for memory-on-logic stacks.

A stacked DRAM die is modeled as a bank array split by a central IO/TSV
spine (the vault/channel periphery of TSV-stacked parts).  Three power
components (DESIGN.md §7.4):

1. **Activate/IO** — driven by the workload's memory-traffic estimate
   (``core/models.mem_traffic_bytes_per_s``): each moved bit costs
   ``E_ACT_PJ_PER_BIT``; a fixed share lands in the IO spine, the rest
   spreads over the banks.  Traffic is striped across the DRAM dies of a
   stack, so per-die activate power is the stack total / n_dies.
2. **Refresh** — temperature-dependent with JEDEC-style bins: the refresh
   interval halves above 85 °C and again above 95 °C, so
   :func:`refresh_multiplier` steps 1× → 2× → 4×.  This is the positive
   feedback the closed loop resolves: hot DRAM burns more refresh power
   exactly where it is already hot.
3. **Static leakage** — DRAM processes leak far less than logic; a reduced
   area density (``GAMMA_DRAM_W_MM2``).

All maps conserve wattage exactly at any grid resolution (cell counts
normalize each region), which `tests/test_stack.py` pins as a property.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.constants import DRAM_LIMIT_C

# power-model constants (DESIGN.md §7.4)
E_ACT_PJ_PER_BIT = 8.0        # activate+IO energy per bit moved, TSV-era
REFRESH_W_PER_GBIT = 0.008    # time-averaged 1x refresh power per Gbit
GAMMA_DRAM_W_MM2 = 1e-2       # DRAM static leakage density [W/mm^2]
REFRESH_BIN2_C = 95.0         # second derating bin (first is DRAM_LIMIT_C)


def refresh_multiplier(T_C):
    """JEDEC-style refresh-rate multiplier vs temperature (elementwise).

    1× below 85 °C, 2× in [85, 95) °C, 4× at and above 95 °C.  jnp-traced
    so it can sit inside the closed-loop ``lax.scan`` with T a tracer.
    """
    T_C = jnp.asarray(T_C)
    m = jnp.ones_like(T_C)
    m = jnp.where(T_C >= DRAM_LIMIT_C, 2.0, m)
    return jnp.where(T_C >= REFRESH_BIN2_C, 4.0, m)


def activate_io_W(traffic_bytes_per_s: float, n_dies: int = 1) -> float:
    """Per-die activate/IO wattage for a stack moving ``traffic`` bytes/s."""
    return traffic_bytes_per_s * 8.0 * E_ACT_PJ_PER_BIT * 1e-12 \
        / max(n_dies, 1)


@dataclasses.dataclass(frozen=True)
class DRAMFloorplan:
    """One DRAM die: bank array split by a central IO/TSV spine."""
    die_w_mm: float
    banks_per_edge: int = 4       # 4x4 banks (structure only; refresh and
    #   activate densities are uniform within the bank array)
    io_frac: float = 0.08         # spine height as a fraction of the die
    io_power_share: float = 0.35  # activate/IO share landing in the spine
    capacity_Gbit: float = 8.0

    def leakage_W(self) -> float:
        return GAMMA_DRAM_W_MM2 * self.die_w_mm ** 2

    def base_refresh_W(self) -> float:
        """1× (below-85 °C) time-averaged refresh power of the die."""
        return REFRESH_W_PER_GBIT * self.capacity_Gbit

    def _spine(self, grid_n: int) -> tuple[int, int]:
        h = max(1, int(round(self.io_frac * grid_n)))
        y0 = (grid_n - h) // 2
        return y0, y0 + h

    def bank_mask(self, grid_n: int) -> np.ndarray:
        """[grid_n, grid_n] 1.0 where bank cells live (outside the spine)."""
        mask = np.ones((grid_n, grid_n))
        if grid_n >= 4:
            y0, y1 = self._spine(grid_n)
            mask[y0:y1, :] = 0.0
        return mask

    def activate_map(self, grid_n: int) -> np.ndarray:
        """Normalized (sums to 1) spatial distribution of activate/IO."""
        bank = self.bank_mask(grid_n)
        n_bank = bank.sum()
        if n_bank == 0 or n_bank == bank.size:   # too coarse: uniform
            return np.full((grid_n, grid_n), 1.0 / bank.size)
        spine = 1.0 - bank
        return (self.io_power_share * spine / spine.sum()
                + (1.0 - self.io_power_share) * bank / n_bank)

    def refresh_map(self, grid_n: int) -> np.ndarray:
        """Normalized distribution of refresh power (banks only)."""
        bank = self.bank_mask(grid_n)
        n_bank = bank.sum()
        if n_bank == 0:
            return np.full((grid_n, grid_n), 1.0 / bank.size)
        return bank / n_bank

    def power_map(self, grid_n: int, act_W: float,
                  ref_W: float | None = None,
                  leak_W: float | None = None) -> np.ndarray:
        """[grid_n, grid_n] watts per cell; conserves the requested total."""
        if ref_W is None:
            ref_W = self.base_refresh_W()
        if leak_W is None:
            leak_W = self.leakage_W()
        return (act_W * self.activate_map(grid_n)
                + ref_W * self.refresh_map(grid_n)
                + np.full((grid_n, grid_n), leak_W / grid_n ** 2))
