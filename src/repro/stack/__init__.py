"""Heterogeneous 3D DRAM-on-logic stack subsystem.

- :mod:`repro.stack.spec` — declarative :class:`StackSpec` of ordered
  dies/interfaces; ``core/thermal.py`` builds its operators from a spec.
- :mod:`repro.stack.dram` — DRAM die floorplan + power model (bank grid,
  traffic-driven activate/IO, JEDEC temperature-binned refresh).
- :mod:`repro.stack.feedback` — closed-loop replay coupling temperature
  back into power (Picard-iterated refresh + leakage, DTM throttling).

Only ``spec`` is imported eagerly: ``core/thermal.py`` depends on it, so
pulling in ``feedback`` (which depends on ``thermal``) here would create
an import cycle; ``dram``/``feedback`` load lazily on first attribute
access (PEP 562).
"""
from repro.stack.spec import (DRAM, LOGIC, PAPER_SPEC, SPREADER, Interface,
                              Layer, StackSpec, dram_on_logic,
                              spec_from_params)

__all__ = [
    "DRAM", "LOGIC", "SPREADER", "PAPER_SPEC", "Interface", "Layer",
    "StackSpec", "dram_on_logic", "spec_from_params", "spec", "dram",
    "feedback",
]


def __getattr__(name):
    if name in ("dram", "feedback", "spec"):
        import importlib
        return importlib.import_module(f"repro.stack.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
