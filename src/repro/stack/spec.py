"""Declarative heterogeneous 3D-stack specifications.

The thermal solver used to hard-code one stack shape — four identical
silicon logic dies over a TIM and a copper spreader (``StackParams``).
This module generalizes that to an ordered :class:`StackSpec` of dies and
interfaces (top → bottom, spreader last): AP logic layers, a SIMD die,
thinned DRAM dies, die-bond / TIM / TSV interface layers, each with its
own thickness / conductivity / heat capacity.  ``core/thermal.py`` builds
both the steady-state CG operator and the implicit transient stepper from
a spec; the legacy ``StackParams`` path is converted through
:func:`spec_from_params`, so ``PAPER_STACK`` is now just one named spec
(``PAPER_SPEC``) and reproduces the pre-refactor numbers exactly.

Everything here is plain numpy/float math (no JAX): specs are static
geometry evaluated once per grid, then handed to the jitted solvers as
arrays.  Constants are documented in DESIGN.md §7.2 (logic stack) and
§7.4 (DRAM dies).
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

# layer kinds
LOGIC = "logic"
DRAM = "dram"
SPREADER = "spreader"

# DRAM die defaults (DESIGN.md §7.4): thinned for TSV stacking, slightly
# below bulk-Si conductivity (metallization layers), F2F/TSV micro-bump
# interface resistance below an organic die-bond.
T_DRAM = 50e-6          # thinned DRAM die thickness [m]
K_DRAM = 100.0          # W/(m K)
C_DRAM = 1.75e6         # volumetric heat capacity [J/(m^3 K)]
R_TSV = 0.5e-6          # TSV/F2F bond interface resistance [m^2 K / W]


@dataclasses.dataclass(frozen=True)
class StackParams:
    """Legacy homogeneous-stack constants (one set for AP and SIMD).

    Kept as the compact parameterization of the paper's 4×Si + spreader
    stack; :func:`spec_from_params` expands it into a :class:`StackSpec`.
    """
    n_si_layers: int = 4
    t_si: float = 250e-6         # 3D die thickness [m] (2013-era stacking)
    k_si: float = 110.0          # silicon W/(m K)
    r_bond: float = 0.7e-6       # die-bond interface resistance [m^2 K / W]
    t_tim: float = 12e-6
    k_tim: float = 4.0
    t_spreader: float = 1e-3
    k_spreader: float = 400.0    # copper, resolved as a grid layer
    spreader_w: float = 30e-3
    t_sink: float = 6.9e-3
    k_sink: float = 400.0
    sink_w: float = 60e-3
    r_convec: float = 0.14       # total sink->ambient convective R [K/W]
    spread_beta: float = 1.0     # effective source growth through the
    #   spreader annulus beyond the die edge (the grid models the spreader
    #   only under the die footprint; heat keeps spreading laterally in the
    #   30 mm copper plate — source edge grows by beta * t_spreader per
    #   side before entering the sink; calibrated once, see DESIGN.md §7.2)
    c_si: float = 1.75e6         # volumetric heat capacity [J/(m^3 K)]
    c_cu: float = 3.45e6

    @property
    def n_layers(self) -> int:
        return self.n_si_layers + 1          # + spreader layer


PAPER_STACK = StackParams()


@dataclasses.dataclass(frozen=True)
class Layer:
    """One grid-resolved layer of the stack."""
    name: str
    kind: str                # LOGIC | DRAM | SPREADER
    t: float                 # thickness [m]
    k: float                 # thermal conductivity [W/(m K)]
    c: float                 # volumetric heat capacity [J/(m^3 K)]

    def __post_init__(self):
        if self.kind not in (LOGIC, DRAM, SPREADER):
            raise ValueError(f"unknown layer kind {self.kind!r}")
        if self.t <= 0 or self.k <= 0 or self.c <= 0:
            raise ValueError(f"layer {self.name!r}: t/k/c must be positive")


@dataclasses.dataclass(frozen=True)
class Interface:
    """Vertical interface between two adjacent layers.

    ``r`` is the *additional* area resistance [m^2 K / W] on top of the
    two half-layer conduction terms (die-bond glue, TIM, TSV micro-bumps).
    """
    name: str
    r: float

    def __post_init__(self):
        if self.r < 0:
            raise ValueError(f"interface {self.name!r}: r must be >= 0")


@dataclasses.dataclass(frozen=True)
class StackSpec:
    """Ordered die stack, top → bottom; the last layer is the spreader.

    ``interfaces[i]`` sits between ``layers[i]`` and ``layers[i+1]``.
    Die layers (everything but the spreader) exist only over the die
    footprint; the spreader spans the full grid domain (die + margin).
    The package path below the spreader (sink conduction + spreading +
    convection) stays a lumped resistance, same as before.
    """
    name: str
    layers: tuple[Layer, ...]
    interfaces: tuple[Interface, ...]
    # package path below the bottom (spreader) layer
    spreader_w: float = 30e-3
    t_sink: float = 6.9e-3
    k_sink: float = 400.0
    sink_w: float = 60e-3
    r_convec: float = 0.14
    spread_beta: float = 1.0

    def __post_init__(self):
        if len(self.layers) < 2:
            raise ValueError("a stack needs at least one die + the spreader")
        if len(self.interfaces) != len(self.layers) - 1:
            raise ValueError(
                f"{len(self.layers)} layers need {len(self.layers) - 1} "
                f"interfaces, got {len(self.interfaces)}")
        if self.layers[-1].kind != SPREADER:
            raise ValueError("the bottom layer must be the spreader")
        if any(l.kind == SPREADER for l in self.layers[:-1]):
            raise ValueError("only the bottom layer may be a spreader")

    # ---------------------------------------------------------- structure
    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_die_layers(self) -> int:
        """Layers carrying devices (everything above the spreader)."""
        return len(self.layers) - 1

    @property
    def dram_layers(self) -> tuple[int, ...]:
        return tuple(i for i, l in enumerate(self.layers) if l.kind == DRAM)

    @property
    def logic_layers(self) -> tuple[int, ...]:
        return tuple(i for i, l in enumerate(self.layers) if l.kind == LOGIC)

    def layer_mask(self, kind: str) -> np.ndarray:
        """[n_layers] float mask selecting layers of ``kind``."""
        return np.array([1.0 if l.kind == kind else 0.0
                         for l in self.layers], np.float32)

    # ------------------------------------------------------- conductances
    def lateral_conductances(self) -> np.ndarray:
        """Per-layer lateral sheet conductance g = k * t, [n_layers]."""
        return np.array([l.k * l.t for l in self.layers])

    def vertical_resistances(self) -> np.ndarray:
        """Per-interface area resistance [m^2 K / W], [n_layers - 1].

        Half-layer conduction on each side plus the interface term:
        r_i = t_i / (2 k_i) + r_if + t_{i+1} / (2 k_{i+1}).
        """
        out = np.empty(len(self.interfaces))
        for i, iface in enumerate(self.interfaces):
            a, b = self.layers[i], self.layers[i + 1]
            out[i] = 0.5 * a.t / a.k + iface.r + 0.5 * b.t / b.k
        return out

    def vertical_conductances(self, cell_area: float) -> np.ndarray:
        """Per-interface per-cell conductance [W/K], [n_layers - 1]."""
        return cell_area / self.vertical_resistances()

    def capacities(self, cell_area: float) -> np.ndarray:
        """Per-layer per-cell heat capacity [J/K], [n_layers]."""
        return np.array([l.c * cell_area * l.t for l in self.layers])

    def package_resistance(self, source_area_m2: float) -> float:
        """Lumped R from the spreader underside to ambient [K/W].

        The spreader plate itself is grid-resolved; its footprint under
        the die feeds the sink through spreading in the sink base.
        """
        spreader = self.layers[-1]
        a_sink = self.sink_w ** 2
        h_sink_eff = 1.0 / (self.r_convec * a_sink)
        # effective source: the copper plate keeps spreading beyond the
        # die edge (outside the grid-resolved footprint)
        src_w = min(math.sqrt(source_area_m2)
                    + 2 * self.spread_beta * spreader.t,
                    self.spreader_w)
        r_sp = spreading_resistance(src_w ** 2, a_sink, self.t_sink,
                                    self.k_sink, h_sink_eff)
        r_cond_sink = self.t_sink / (self.k_sink * a_sink)
        return r_sp + r_cond_sink + self.r_convec


def spreading_resistance(a_source: float, a_plate: float, t: float,
                         k: float, h: float) -> float:
    """Lee/Song/Au closed-form constriction/spreading resistance."""
    r1 = math.sqrt(a_source / math.pi)
    r2 = math.sqrt(a_plate / math.pi)
    eps = r1 / r2
    tau = t / r2
    Bi = h * r2 / k
    lam = math.pi + 1.0 / (math.sqrt(math.pi) * eps)
    phi = (math.tanh(lam * tau) + lam / Bi) / (1.0 + lam / Bi * math.tanh(lam * tau))
    psi = (eps * tau / math.sqrt(math.pi)
           + (1.0 - eps) * phi / math.sqrt(math.pi))
    return psi / (k * r1 * math.sqrt(math.pi))


# ---------------------------------------------------------------------------
# named specs / builders
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def spec_from_params(p: StackParams = PAPER_STACK) -> StackSpec:
    """Expand the legacy homogeneous parameterization into a spec.

    Reproduces the pre-refactor conductances exactly: Si|Si interfaces are
    half-Si + bond + half-Si = t_si/k_si + r_bond, and the bottom die
    couples to the spreader through half-Si + TIM + half-spreader.
    """
    n = p.n_si_layers
    layers = tuple(Layer(f"si_{n - i}", LOGIC, p.t_si, p.k_si, p.c_si)
                   for i in range(n))
    layers += (Layer("spreader", SPREADER, p.t_spreader, p.k_spreader,
                     p.c_cu),)
    interfaces = tuple(Interface("bond", p.r_bond) for _ in range(n - 1))
    interfaces += (Interface("tim", p.t_tim / p.k_tim),)
    return StackSpec(
        name=f"{n}xSi+spreader", layers=layers, interfaces=interfaces,
        spreader_w=p.spreader_w, t_sink=p.t_sink, k_sink=p.k_sink,
        sink_w=p.sink_w, r_convec=p.r_convec, spread_beta=p.spread_beta)


PAPER_SPEC = spec_from_params(PAPER_STACK)


def dram_on_logic(n_dram: int, params: StackParams = PAPER_STACK, *,
                  t_dram: float = T_DRAM, k_dram: float = K_DRAM,
                  c_dram: float = C_DRAM, r_tsv: float = R_TSV,
                  name: str | None = None) -> StackSpec:
    """``n_dram`` thinned DRAM dies stacked ON TOP of the logic stack.

    Top → bottom: DRAM_n .. DRAM_1 | logic dies | spreader — the paper's
    memory-on-logic configuration.  Heat flows down to the sink, so the
    DRAM sits on the hot side of the logic stack and its floor temperature
    is set by the top logic die.  ``n_dram = 0`` returns the bare logic
    spec (== :func:`spec_from_params`).
    """
    if n_dram < 0:
        raise ValueError("n_dram must be >= 0")
    base = spec_from_params(params)
    if n_dram == 0:
        return base
    dram = tuple(Layer(f"dram_{n_dram - i}", DRAM, t_dram, k_dram, c_dram)
                 for i in range(n_dram))
    tsv = tuple(Interface("tsv", r_tsv) for _ in range(n_dram))
    return dataclasses.replace(
        base, name=name or f"{n_dram}xDRAM+{base.name}",
        layers=dram + base.layers, interfaces=tsv + base.interfaces)
