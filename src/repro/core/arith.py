"""Fixed-point word-parallel arithmetic on the AP: mul / mac / div.

Multiplication and division follow the paper (§2.2): long multiplication /
long division as series of (conditional) add/subtract with free shifts,
bit-serial but word-parallel — O(m^2) cycles regardless of vector length.

The per-row multiplier bit enters the COMPARE key as an extra column, so a
"conditional add" pass is the full-adder pass with the condition column
prepended — still 4 passes per bit position.
"""
from __future__ import annotations

from repro.core.bitplane import Field
from repro.core.engine import APEngine, PassSchedule
from repro.core import isa


def cond_full_adder_passes(cond: int, c: int, b: int, a: int) -> list:
    """b,c <- a + b + c where row bit ``cond``==1; no action elsewhere."""
    def fa(bits):
        cnd, cc, bb, aa = bits
        if not cnd:
            return (cc, bb)
        s = aa + bb + cc
        return (s >> 1, s & 1)
    return isa.compile_table([cond, c, b, a], [c, b], fa)


def cond_half_adder_passes(cond: int, c: int, b: int) -> list:
    """b,c <- b + c where cond==1 (zero addend; absorbs carry propagation)."""
    def ha(bits):
        cnd, cc, bb = bits
        if not cnd:
            return (cc, bb)
        s = bb + cc
        return (s >> 1, s & 1)
    return isa.compile_table([cond, c, b], [c, b], ha)


def cond_add(a: Field, b: Field, carry: Field, cond: Field) -> PassSchedule:
    """b <- a + b where cond==1.  4 passes/bit, carry pre-cleared by caller."""
    passes = []
    for i in range(a.width):
        passes += cond_full_adder_passes(cond.col(0), carry.col(0),
                                         b.col(i), a.col(i))
    return isa.schedule(passes)


def cond_full_subtractor_passes(cond: int, br: int, b: int, a: int) -> list:
    """b,br <- b - a - br where row bit ``cond``==1; no action elsewhere."""
    def fs(bits):
        cnd, rr, bb, aa = bits
        if not cnd:
            return (rr, bb)
        d = bb - aa - rr
        return (1 if d < 0 else 0, d & 1)
    return isa.compile_table([cond, br, b, a], [br, b], fs)


def cond_sub(a: Field, b: Field, borrow: Field, cond: Field) -> PassSchedule:
    """b <- b - a where cond==1.  4 passes/bit, borrow pre-cleared by caller."""
    passes = []
    for i in range(a.width):
        passes += cond_full_subtractor_passes(cond.col(0), borrow.col(0),
                                              b.col(i), a.col(i))
    return isa.schedule(passes)


def negate(f: Field, carry: Field) -> list[PassSchedule]:
    """f <- -f (two's complement): bitwise NOT then +1.  Returns schedules."""
    return [isa.logic_not(f, f), isa.const_add(f, 1, carry)]


def cond_negate(eng: APEngine, f: Field, cond: Field, carry: Field,
                z: Field) -> None:
    """f <- -f where cond==1 (conditional two's-complement negate).

    An in-place bit toggle has no conflict-free pass order (the two passes
    map rows into each other's input patterns), so each bit is staged
    through the 1-column marker ``z``: copy f_i -> z, then write ~z back
    into f_i where cond.  4 passes/bit, single fused schedule.
    """
    passes = []
    for i in range(f.width):
        passes += isa.compile_table([f.col(i)], [z.col(0)],
                                    lambda b: (b[0],))
        passes += [([cond.col(0), z.col(0)], [1, 1], [f.col(i)], [0]),
                   ([cond.col(0), z.col(0)], [1, 0], [f.col(i)], [1])]
    eng.run(isa.schedule(passes))
    # +1 where cond: seed carry from cond, then conditional half-adder ripple
    eng.clear(carry)
    inc = []
    inc += isa.compile_table([cond.col(0), carry.col(0)], [carry.col(0)],
                             lambda b: (b[0],))
    for i in range(f.width):
        def ha(bits):
            cc, bb = bits
            s = bb + cc
            return (s >> 1, s & 1)
        inc += isa.compile_table([carry.col(0), f.col(i)],
                                 [carry.col(0), f.col(i)], ha)
    eng.run(isa.schedule(inc))


def run_signed_mul(eng: APEngine, a: Field, b: Field, prod: Field,
                   carry: Field, sa: Field, sb: Field, z: Field) -> None:
    """prod <- a * b for two's-complement a, b (sign-magnitude internally).

    sa/sb/z are 1-column scratch.  a and b are restored (magnitude negated
    back) after the multiply; prod is two's complement of full width.
    The minimum value -2^(m-1) is not representable as a magnitude and must
    be avoided by callers (standard Q-format contract).
    """
    # extract signs, take magnitudes
    for f, s in ((a, sa), (b, sb)):
        eng.run(isa.copy(s, f.slice(f.width - 1, 1)))
        cond_negate(eng, f, s, carry, z)
    run_mul(eng, a, b, prod, carry)
    # product sign = sa XOR sb (XOR in-place on sa is conflict-free via z)
    _xor_into(eng, sa, sb, z)
    cond_negate(eng, prod, sa, carry, z)
    # restore operands: sa ^= sb gives back a's sign
    _xor_into(eng, sa, sb, z)
    cond_negate(eng, a, sa, carry, z)
    cond_negate(eng, b, sb, carry, z)


def _xor_into(eng: APEngine, dst: Field, src: Field, z: Field) -> None:
    """dst <- dst XOR src (1-bit fields), staged through marker z."""
    passes = isa.compile_table([dst.col(0)], [z.col(0)], lambda b: (b[0],))
    passes += [([src.col(0), z.col(0)], [1, 1], [dst.col(0)], [0]),
               ([src.col(0), z.col(0)], [1, 0], [dst.col(0)], [1])]
    eng.run(isa.schedule(passes))


def mul_schedules(a: Field, b: Field, prod: Field, carry: Field
                  ) -> list[PassSchedule]:
    """prod <- a * b (unsigned).  prod width must be >= a.width + b.width.

    Long multiplication, LSB-first (shift = column offset, zero cycles):
    for each multiplier bit b_j, conditionally add ``a`` into prod[j : j+m+1]
    (the +1 column absorbs the carry; bits above are provably 0).
    Cycles: b.width * (8*(a.width+1) + 2) ~ 8*m^2  ==> O(m^2) (paper §2.2).

    Returns one schedule per multiplier bit (caller clears carry between).
    """
    m = a.width
    if prod.width < a.width + b.width:
        raise ValueError("product field too narrow")
    scheds = []
    for j in range(b.width):
        cond = b.col(j)
        passes = []
        for i in range(m):
            passes += cond_full_adder_passes(cond, carry.col(0),
                                             prod.col(j + i), a.col(i))
        # absorb the final carry into prod[j+m] (zero addend)
        passes += cond_half_adder_passes(cond, carry.col(0), prod.col(j + m))
        scheds.append(isa.schedule(passes))
    return scheds


def run_mul(eng: APEngine, a: Field, b: Field, prod: Field, carry: Field) -> None:
    """Execute prod <- a*b, clearing prod and managing the carry column."""
    eng.clear(prod)
    for sched in mul_schedules(a, b, prod, carry):
        eng.clear(carry)
        eng.run(sched)


def run_mac(eng: APEngine, a: Field, b: Field, acc: Field, carry: Field) -> None:
    """acc += a*b  (acc must be wide enough to never overflow: the caller's

    responsibility, e.g. width >= a.width + b.width + log2(#accumulations)).
    Same pass structure as mul but without clearing acc; the carry ripple
    above position j+m is handled by extending propagation to the top of acc.
    """
    m = a.width
    for j in range(b.width):
        cond = b.col(j)
        passes = []
        for i in range(m):
            passes += cond_full_adder_passes(cond, carry.col(0),
                                             acc.col(j + i), a.col(i))
        # ripple the carry through the remaining accumulator bits
        for i in range(j + m, acc.width):
            passes += cond_half_adder_passes(cond, carry.col(0), acc.col(i))
        eng.clear(carry)
        eng.run(isa.schedule(passes))


def run_div(eng: APEngine, a: Field, b: Field, quot: Field, wide: Field,
            trial: Field, borrow: Field, qbit: Field) -> None:
    """quot <- a // b (unsigned restoring long division, in-place remainder).

    Scratch:  wide  — 2m+1 columns (dividend low, remainder window walks up)
              trial — m+1 columns, borrow/qbit — 1 column each.
    After the call the remainder a % b sits in wide[0:m].
    Cycles ~ m * (12m + O(1))  ==> O(m^2) (paper §2.2).
    """
    m = a.width
    if wide.width < 2 * m + 1 or trial.width < m + 1 or quot.width < m:
        raise ValueError("scratch fields too narrow")
    eng.clear(wide)
    eng.clear(quot)
    eng.run(isa.copy(wide.slice(0, m), a))

    for i in reversed(range(m)):
        win = wide.slice(i, m + 1)              # remainder window (free shift)
        # trial = window - b  (b zero-extended by 1)
        eng.run(isa.copy(trial, win))
        eng.clear(borrow)
        eng.run(_sub_zext(b, trial, borrow))
        # q_i = ~borrow ; where q_i: window <- trial
        eng.clear(qbit)
        eng.compare([borrow.col(0)], [0])
        eng.write([qbit.col(0), quot.col(i)], [1, 1])
        eng.run(isa.cond_copy(win, trial, qbit))


def _sub_zext(a: Field, b: Field, borrow: Field) -> PassSchedule:
    """b <- b - zext(a): subtract a (narrower) from b, borrow rippling up."""
    passes = []
    for i in range(b.width):
        if i < a.width:
            passes += isa.full_subtractor_passes(borrow.col(0), b.col(i), a.col(i))
        else:
            # a_i = 0: only the borrow ripples:  b,br <- b - br
            def fs0(bits):
                rr, bb = bits
                d = bb - rr
                return (1 if d < 0 else 0, d & 1)
            passes += isa.compile_table([borrow.col(0), b.col(i)],
                                        [borrow.col(0), b.col(i)], fs0)
    return isa.schedule(passes)
