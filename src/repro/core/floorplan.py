"""Floorplans and power maps for the thermal analysis (paper Figs 8 & 11).

AP (Fig 8):  7.33 x 7.33 mm die, 8x8 banks, each 8x8 blocks; each block is a
256x256 associative array with KEY/MASK registers on top and TAG on the right.
Power is distributed by region with relative densities derived from the
paper's constants (Table 3 + '2% of flip-flops switching' §4.1):

  array   : eq-17 dynamic bracket / (2 area units per cell)
  KEY/MASK: 2% activity x P_RFo per bit / (3 area units per FF)
  TAG     : same flip-flop treatment as KEY/MASK

Region powers are exact (weights x true areas, normalized to the layer
power); strip cells are grid-quantized so sub-cell strips smear over one grid
row — total power is conserved (DESIGN.md §7.2).

SIMD (Fig 11): 2.3 x 2.3 mm die; 12 processor tiles (64 PUs + RF + L1) in two
side columns of six, shared L2 as the central band (matches Fig 5's
12-processor reference and Fig 12's hot-PU / cool-L2 pattern).  Execution
power lands in the PU arrays, synchronization power in the caches, leakage
everywhere in proportion to area (eq 14's decomposition).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import models as M

MM = 1e-3


# ---------------------------------------------------------------------------
# AP floorplan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class APFloorplan:
    die_w_mm: float = 7.33
    banks: int = 8          # banks per edge (8x8 = 64)
    blocks: int = 8         # blocks per bank edge (8x8 = 64)
    words_per_block: int = 256
    bits_per_word: int = 256
    reg_activity: float = 0.02  # §4.1: 2% of flip-flops switch per cycle

    @property
    def blocks_per_edge(self) -> int:
        return self.banks * self.blocks  # 64

    def leakage_W(self) -> float:
        """Static leakage of one layer (same gamma model as power_map)."""
        return M.GAMMA_W_MM2 * self.die_w_mm ** 2

    def region_weights(self) -> dict:
        """Relative power densities (per normalized area unit)."""
        # per bit-cell area unit: eq-17 bracket is per PU (256-bit row) per cycle
        arr_density = M.ap_dynamic_power_per_pu_norm() * self.words_per_block \
            / (self.words_per_block * self.bits_per_word * M.A_AP_BIT)
        ff_density = self.reg_activity * M.P_RF_BIT / M.A_RF_BIT
        return {"array": arr_density, "regs": ff_density, "tag": ff_density}

    def region_areas(self) -> dict:
        """True areas per block in normalized units."""
        n_cells = self.words_per_block * self.bits_per_word
        a_array = n_cells * M.A_AP_BIT
        a_regs = 2 * self.bits_per_word * M.A_RF_BIT   # KEY + MASK rows
        a_tag = self.words_per_block * M.A_RF_BIT      # TAG column
        return {"array": a_array, "regs": a_regs, "tag": a_tag}

    def power_map(self, grid_n: int, p_layer_W: float) -> np.ndarray:
        """[grid_n, grid_n] watts per cell; leakage uniform, dynamic by region."""
        w = self.region_weights()
        a = self.region_areas()
        nb = self.blocks_per_edge ** 2
        dyn_total = sum(w[r] * a[r] for r in w) * nb
        leak_W = self.leakage_W()
        dyn_W = p_layer_W - leak_W
        region_W = {r: dyn_W * (w[r] * a[r] * nb / dyn_total) for r in w}

        pmap = np.zeros((grid_n, grid_n))
        bpe = self.blocks_per_edge
        cells_per_block = grid_n / bpe
        if cells_per_block < 3:
            # too coarse to resolve register strips: uniform dynamic + leakage
            return np.full((grid_n, grid_n), p_layer_W / grid_n ** 2)

        # rasterize block sub-regions
        cpb = int(round(cells_per_block))
        if cpb * bpe != grid_n:
            raise ValueError(f"grid_n must be a multiple of {bpe}")
        reg_rows = max(1, int(round(0.01 * cpb)))   # KEY/MASK strip (top)
        tag_cols = max(1, int(round(0.01 * cpb)))   # TAG strip (right)
        block = np.zeros((cpb, cpb))
        arr_cells = cpb * cpb - reg_rows * cpb - tag_cols * (cpb - reg_rows)
        block[reg_rows:, :cpb - tag_cols] = (region_W["array"] / nb) / arr_cells
        block[:reg_rows, :] = (region_W["regs"] / nb) / (reg_rows * cpb)
        block[reg_rows:, cpb - tag_cols:] = (region_W["tag"] / nb) \
            / (tag_cols * (cpb - reg_rows))
        pmap = np.tile(block, (bpe, bpe))
        pmap += leak_W / grid_n ** 2
        return pmap


# ---------------------------------------------------------------------------
# SIMD floorplan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SIMDFloorplan:
    die_w_mm: float = 2.3
    n_cores: int = 12
    l1_frac_of_cache: float = 0.125   # L1s sit inside core tiles; L2 central

    def leakage_W(self, dp: "M.DesignPoint") -> float:
        """Static leakage of one layer (same gamma model as power_map)."""
        return M.GAMMA_W_MM2 * dp.simd_area_mm2

    def power_map(self, grid_n: int, dp: "M.DesignPoint",
                  wl: "M.Workload | None" = None) -> np.ndarray:
        # unregistered workloads (e.g. the serving cost model's derived
        # per-config entries) must pass their Workload instance explicitly
        wl = M.WORKLOADS[dp.workload] if wl is None else wl
        n = dp.simd_n_pus
        # eq (14) decomposition (normalized -> watts)
        p_exec_W, p_sync_W, _ = M.simd_phase_powers(wl, n)
        p_leak_W = self.leakage_W(dp)

        # geometry (fractions of die area)
        a_pu_mm2 = n * M.simd_pu_area() * M.A_SRAM_UM2 * 1e-6
        a_cache_mm2 = M.simd_cache_area() * M.A_SRAM_UM2 * 1e-6
        die_mm2 = self.die_w_mm ** 2
        a_l1 = self.l1_frac_of_cache * a_cache_mm2
        core_col_frac = (a_pu_mm2 + a_l1) / die_mm2 / 2.0   # two side columns

        pmap = np.zeros((grid_n, grid_n))
        col_w = max(1, int(round(core_col_frac * grid_n)))
        core_h = grid_n // (self.n_cores // 2)
        pu_frac_in_tile = a_pu_mm2 / (a_pu_mm2 + a_l1)
        pu_w = max(1, int(round(col_w * pu_frac_in_tile)))

        dens = np.zeros((grid_n, grid_n))  # relative dynamic density map
        pu_cells = 0
        l1_cells = 0
        for side in (0, 1):
            x0 = 0 if side == 0 else grid_n - col_w
            for c in range(self.n_cores // 2):
                y0, y1 = c * core_h, (c + 1) * core_h
                if side == 0:
                    pu_x = (x0, x0 + pu_w)
                    l1_x = (x0 + pu_w, x0 + col_w)
                else:
                    pu_x = (x0 + col_w - pu_w, x0 + col_w)
                    l1_x = (x0, x0 + col_w - pu_w)
                dens[y0:y1, pu_x[0]:pu_x[1]] = 1.0
                pu_cells += (y1 - y0) * (pu_x[1] - pu_x[0])
                dens[y0:y1, l1_x[0]:l1_x[1]] = 2.0
                l1_cells += (y1 - y0) * (l1_x[1] - l1_x[0])
        l2_cells = grid_n * grid_n - pu_cells - l1_cells

        if pu_cells == 0 or l2_cells == 0:
            # grid too coarse to rasterize the tile columns AND a central
            # band: uniform map keeps total wattage conserved
            total_W = p_exec_W + p_sync_W + p_leak_W
            return np.full((grid_n, grid_n), total_W / grid_n ** 2)
        pmap[dens == 1.0] = p_exec_W / pu_cells
        # sync traffic: half in L1s, half in L2 — when the grid is too
        # coarse to rasterize any L1 cells, their share falls through to
        # L2 so total wattage is conserved at every resolution
        sync_l1_W = 0.5 * p_sync_W if l1_cells else 0.0
        pmap[dens == 2.0] = sync_l1_W / max(l1_cells, 1)
        pmap[dens == 0.0] = (p_sync_W - sync_l1_W) / l2_cells
        pmap += p_leak_W / grid_n ** 2
        return pmap


# ---------------------------------------------------------------------------
# AP block zoom (paper Fig 10(c)): one block at fine resolution
# ---------------------------------------------------------------------------

def ap_block_zoom(fp: APFloorplan, p_layer_W: float, grid_n: int = 64,
                  stack=None) -> dict:
    """Thermal map of one AP block near the die center (Fig 10(c)).

    Symmetry argument: a block surrounded by identical blocks sees adiabatic
    lateral boundaries, so solving ONE block footprint with the full stack
    reproduces the infinite-array interior exactly.  The KEY/MASK register
    strip (top) and TAG strip (right) get their share of the block power at
    their true (small) areas — resolving the local hot strip that the
    die-level grid quantizes away.
    """
    from repro.core import thermal

    spec = _as_spec(stack)
    w = fp.region_weights()
    a = fp.region_areas()
    nb = fp.blocks_per_edge ** 2
    block_w_mm = fp.die_w_mm / fp.blocks_per_edge
    dyn_total = sum(w[r] * a[r] for r in w) * nb
    leak_W = fp.leakage_W()
    dyn_W = p_layer_W - leak_W
    region_W = {r: dyn_W * (w[r] * a[r] / dyn_total) for r in w}   # per block
    leak_block = leak_W / nb

    # geometry: register strip height / tag strip width as true area shares
    a_block = sum(a.values())
    reg_frac = a["regs"] / a_block
    tag_frac = a["tag"] / a_block
    reg_rows = max(1, int(round(reg_frac * grid_n)))
    tag_cols = max(1, int(round(tag_frac * grid_n)))

    pmap = np.zeros((grid_n, grid_n))
    arr_cells = grid_n * grid_n - reg_rows * grid_n \
        - tag_cols * (grid_n - reg_rows)
    pmap[reg_rows:, : grid_n - tag_cols] = region_W["array"] / arr_cells
    pmap[:reg_rows, :] = region_W["regs"] / (reg_rows * grid_n)
    pmap[reg_rows:, grid_n - tag_cols:] = region_W["tag"] \
        / (tag_cols * (grid_n - reg_rows))
    pmap += leak_block / grid_n ** 2

    grid = thermal.Grid(die_w=block_w_mm * MM, ny=grid_n, nx=grid_n,
                        spec=spec,
                        pkg_area=(fp.die_w_mm * MM) ** 2)
    L = grid.n_die_layers
    power = _logic_power(pmap, spec)
    T = np.asarray(thermal.steady_state(power, grid))
    return {"T": T, "power_map": pmap,
            "peak_C": [float(T[l].max()) for l in range(L)],
            "min_C": [float(T[l].min()) for l in range(L)],
            "span_C": [float(T[l].max() - T[l].min()) for l in range(L)]}


# ---------------------------------------------------------------------------
# paper §4 comparison driver
# ---------------------------------------------------------------------------

def _as_spec(stack):
    """Accept a StackSpec, a legacy StackParams, or None (paper default)."""
    from repro.stack.spec import StackSpec, spec_from_params

    if stack is None:
        from repro.core import thermal
        stack = thermal.PAPER_STACK
    return stack if isinstance(stack, StackSpec) else spec_from_params(stack)


def _logic_power(pmap: np.ndarray, spec) -> np.ndarray:
    """[n_die, ny, nx] power with ``pmap`` on every LOGIC layer (the §4
    convention) and zeros on DRAM layers."""
    power = np.zeros((spec.n_die_layers, *pmap.shape), pmap.dtype)
    for l in spec.logic_layers:
        power[l] = pmap
    return power


def t_cut(T: np.ndarray) -> np.ndarray:
    """Horizontal center-line profile of one layer (paper Fig 13 'T-Cut')."""
    return np.asarray(T)[T.shape[0] // 2, :]


def thermal_comparison(grid_ap: int = 64, grid_simd: int = 64,
                       workload: str = "dmm", use_pallas: bool = False,
                       stack=None) -> dict:
    """Run the full §4 experiment: same-performance AP vs SIMD, 4-layer
    stacks by default; pass a heterogeneous ``StackSpec`` (e.g.
    ``repro.stack.spec.dram_on_logic``) to put unpowered DRAM dies on top."""
    from repro.core import thermal

    spec = _as_spec(stack)
    dp = M.paper_design_point(workload)
    ap_fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
    simd_fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))

    results = {}
    for name, fp, p_layer in (
            ("ap", ap_fp, dp.ap_power_W),
            ("simd", simd_fp, dp.simd_power_W)):
        if name == "ap":
            pmap = fp.power_map(grid_ap, p_layer)
        else:
            pmap = fp.power_map(grid_simd, dp)
        L = spec.n_die_layers
        power = _logic_power(pmap, spec)
        grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=pmap.shape[0],
                            nx=pmap.shape[1], spec=spec,
                            margin=pmap.shape[0] // 4)
        T = np.asarray(thermal.steady_state(power, grid, use_pallas=use_pallas))
        results[name] = {
            "T": T,
            "power_map": pmap,
            "p_layer_W": float(pmap.sum()),
            "peak_C": [float(T[l].max()) for l in range(L)],
            "min_C": [float(T[l].min()) for l in range(L)],
            "span_C": [float(T[l].max() - T[l].min()) for l in range(L)],
            "t_cut": [t_cut(T[l]) for l in range(L)],
        }
    results["design_point"] = dp
    return results
