"""IEEE-754 single-precision arithmetic on the AP, bit-serial word-parallel.

The paper (§2.2) claims a direct FP32 vector multiply implementation takes
~4400 cycles *regardless of vector length*.  We implement FP32 multiply and
add from the pass primitives and measure the actual cycle counts; the
benchmark (bench_cycles) reports ours next to the paper's constant.

Representation: a packed fp32 "value" is three adjacent fields of one word:
    sign (1 col) | exp (8 cols, biased) | mant (23 cols)
Denormals are flushed to zero on load; rounding is truncation (documented
deviation — adds <=1 ulp vs round-to-nearest; tests use 2-ulp tolerance).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bitplane import Field
from repro.core.engine import APEngine
from repro.core import isa, arith


@dataclasses.dataclass(frozen=True)
class FpField:
    """An fp32 vector resident in the associative array."""
    sign: Field
    exp: Field
    mant: Field

    @staticmethod
    def alloc(eng: APEngine) -> "FpField":
        return FpField(eng.alloc.alloc(1, "s"), eng.alloc.alloc(8, "e"),
                       eng.alloc.alloc(23, "m"))


def load_fp32(eng: APEngine, f: FpField, values: np.ndarray) -> None:
    v = np.asarray(values, np.float32)
    bits = v.view(np.uint32).astype(np.uint64)
    exp = (bits >> 23) & 0xFF
    denorm = exp == 0
    eng.load(f.sign, (bits >> 31) & 1)
    eng.load(f.exp, np.where(denorm, 0, exp))
    eng.load(f.mant, np.where(denorm, 0, bits & 0x7FFFFF))


def read_fp32(eng: APEngine, f: FpField) -> np.ndarray:
    s = eng.peek(f.sign)
    e = eng.peek(f.exp)
    m = eng.peek(f.mant)
    bits = (s.astype(np.uint32) << 31) | (e.astype(np.uint32) << 23) \
        | m.astype(np.uint32)
    return bits.view(np.float32)


@dataclasses.dataclass
class FpScratch:
    """Scratch columns shared by the fp routines (allocate once per engine)."""
    ma: Field      # 24-bit mantissa with hidden bit
    mb: Field      # 24-bit mantissa with hidden bit
    prod: Field    # 49-bit product
    ext: Field     # 10-bit extended exponent
    carry: Field
    cond: Field
    cond2: Field

    @staticmethod
    def alloc(eng: APEngine) -> "FpScratch":
        a = eng.alloc
        return FpScratch(a.alloc(24, "ma"), a.alloc(25, "mb"), a.alloc(49, "prod"),
                         a.alloc(10, "eext"), a.alloc(1, "c"), a.alloc(1, "cd"),
                         a.alloc(1, "cd2"))


def _add_zext(a: Field, b: Field, carry: Field):
    """b <- b + zext(a): ripple the carry through b's extra high bits."""
    passes = []
    for i in range(b.width):
        if i < a.width:
            passes += isa.full_adder_passes(carry.col(0), b.col(i), a.col(i))
        else:
            def ha(bits):
                cc, bb = bits
                s = bb + cc
                return (s >> 1, s & 1)
            passes += isa.compile_table([carry.col(0), b.col(i)],
                                        [carry.col(0), b.col(i)], ha)
    return isa.schedule(passes)


def _seeded_inc(b: Field, seed: Field, carry: Field):
    """b <- b + seed (seed is 1 bit): carry <- seed, then ripple half-adders."""
    passes = isa.compile_table([seed.col(0), carry.col(0)], [carry.col(0)],
                               lambda bits: (bits[0],))
    for i in range(b.width):
        def ha(bits):
            cc, bb = bits
            s = bb + cc
            return (s >> 1, s & 1)
        passes += isa.compile_table([carry.col(0), b.col(i)],
                                    [carry.col(0), b.col(i)], ha)
    return isa.schedule(passes)


def fp_mul(eng: APEngine, x: FpField, y: FpField, out: FpField,
           s: FpScratch) -> None:
    """out <- x * y, word-parallel.  ~4800 measured cycles for the direct

    implementation (paper's optimized figure: 4400; same O(m^2) structure).
    """
    # 1. sign: out.s = x.s XOR y.s  (2 passes)
    eng.run(isa.schedule(isa.compile_table(
        [x.sign.col(0), y.sign.col(0), out.sign.col(0)], [out.sign.col(0)],
        lambda b: (b[0] ^ b[1],))))

    # 2. exponent: ext = x.e + y.e - 127 (10-bit, wraps are caller's concern)
    eng.clear(s.ext)
    eng.run(isa.copy(s.ext.slice(0, 8), x.exp))
    eng.clear(s.carry)
    eng.run(_add_zext(y.exp, s.ext, s.carry))
    eng.clear(s.carry)
    eng.run(isa.const_add(s.ext, (1 << s.ext.width) - 127, s.carry))

    # 3. mantissas with hidden bit
    eng.run(isa.copy(s.ma.slice(0, 23), x.mant))
    eng.set_bits(s.ma.slice(23, 1), 1)
    eng.run(isa.copy(s.mb.slice(0, 23), y.mant))
    eng.set_bits(s.mb.slice(23, 1), 1)
    eng.clear(s.mb.slice(24, 1))

    # 4. 24x24 long multiply -> 48-bit product (the O(m^2) core)
    eng.clear(s.prod)
    for sched in arith.mul_schedules(s.ma, s.mb.slice(0, 24), s.prod, s.carry):
        eng.clear(s.carry)
        eng.run(sched)

    # 5. normalize: product in [2^46, 2^48); cond = bit 47
    eng.run(isa.copy(s.cond, s.prod.slice(47, 1)))
    eng.run(isa.copy(out.mant, s.prod.slice(23, 23)))
    eng.run(isa.cond_copy(out.mant, s.prod.slice(24, 23), s.cond))
    eng.clear(s.carry)
    eng.run(_seeded_inc(s.ext, s.cond, s.carry))

    # 6. exponent writeback (top 2 ext bits are overflow guards; ignored here)
    eng.run(isa.copy(out.exp, s.ext.slice(0, 8)))

    # 7. zero inputs -> zero output (x.e==0 or y.e==0)
    _propagate_zero(eng, x, y, out, s)


def _propagate_zero(eng: APEngine, x: FpField, y: FpField, out: FpField,
                    s: FpScratch) -> None:
    """If either input is (flushed) zero, force out to +/-0."""
    for src in (x, y):
        eng.compare(src.exp.cols(), [0] * 8)
        eng.write(out.exp.cols() + out.mant.cols(), [0] * (8 + 23))


def fp_add(eng: APEngine, x: FpField, y: FpField, out: FpField,
           s: FpScratch, max_shift: int = 25) -> None:
    """out <- x + y (any signs), word-parallel.

    Algorithm (all steps data-parallel over rows):
      1. order operands so |big| has the larger (exp, mant): big/small into
         scratch via cond_copy (magnitude compare on the packed exp|mant bits)
      2. align: small.mant >>= (big.e - small.e) via per-shift tagged copies
      3. same sign -> 25-bit add; opposite -> subtract (big - small)
      4. renormalize: carry-out -> shift right 1; else leading-zero scan
         (priority passes) shifting left by k and exp -= k
    Costs ~6-7k cycles — O(m) passes per step with constant factors from the
    variable-shift LUT loops; reported by bench_cycles.
    """
    a = eng.alloc
    if not hasattr(eng, "_fpadd_scratch"):
        eng._fpadd_scratch = {
            "eb": a.alloc(8, "eb"), "es": a.alloc(8, "es"),
            "mb": a.alloc(26, "mbig"), "ms": a.alloc(26, "msmall"),
            "sb": a.alloc(1, "sbig"), "ss": a.alloc(1, "ssmall"),
            "d": a.alloc(8, "d"), "br": a.alloc(1, "br2"),
            "sdif": a.alloc(1, "sdif"), "done": a.alloc(1, "done"),
        }
    t = eng._fpadd_scratch
    eb, es, mb, ms = t["eb"], t["es"], t["mb"], t["ms"]
    sb, ss, d, br = t["sb"], t["ss"], t["d"], t["br"]
    sdif, done = t["sdif"], t["done"]

    # -- 1. magnitude order: cond = |y| > |x| on (exp,mant) lexicographic
    eng.clear(s.cond)
    eng.clear(s.cond2)
    # compare 31-bit magnitudes MSB-first: exp bits then mant bits
    xcols = list(reversed(x.exp.cols())) + list(reversed(x.mant.cols()))
    ycols = list(reversed(y.exp.cols())) + list(reversed(y.mant.cols()))
    passes = []
    for xc, yc in zip(xcols, ycols):
        passes += [
            ([s.cond2.col(0), yc, xc], [0, 1, 0],
             [s.cond.col(0), s.cond2.col(0)], [1, 1]),
            ([s.cond2.col(0), yc, xc], [0, 0, 1], [s.cond2.col(0)], [1]),
        ]
    eng.run(isa.schedule(passes))

    # big = cond ? y : x ; small = cond ? x : y   (with hidden bits)
    for dst_e, dst_m, dst_s, hi, lo in ((eb, mb, sb, y, x), (es, ms, ss, x, y)):
        eng.run(isa.copy(dst_e, lo.exp))
        eng.run(isa.cond_copy(dst_e, hi.exp, s.cond))
        eng.clear(dst_m)
        eng.run(isa.copy(dst_m.slice(1, 23), lo.mant))
        eng.run(isa.cond_copy(dst_m.slice(1, 23), hi.mant, s.cond))
        eng.set_bits(dst_m.slice(24, 1), 1)
        # flushed-zero operand: mantissa is truly 0, not 1.0 x 2^-127
        eng.compare(dst_e.cols(), [0] * dst_e.width)
        eng.write(dst_m.cols(), [0] * dst_m.width)
        eng.run(isa.copy(dst_s, lo.sign))
        eng.run(isa.cond_copy(dst_s, hi.sign, s.cond))

    # -- 2. align small: d = eb - es; for each shift 1..max, cond-copy
    eng.run(isa.copy(d, eb))
    eng.clear(br)
    eng.run(isa.sub(es, d, br))
    for k in range(1, max_shift):
        eng.clear(s.cond2)
        eng.compare(d.cols(), [(k >> i) & 1 for i in range(8)])
        eng.write([s.cond2.col(0)], [1])
        # small >>= k : copy ms[k:25] -> ms[0:25-k], zero the top k bits
        eng.run(isa.cond_copy(ms.slice(0, 25 - k), ms.slice(k, 25 - k), s.cond2))
        _cond_clear(eng, ms.slice(25 - k, k), s.cond2)
    # shifts >= max_shift: small flushes to 0
    eng.clear(s.cond2)
    eng.clear(t["done"])
    _tag_ge(eng, d, max_shift, s.cond2)
    _cond_clear(eng, ms, s.cond2)

    # -- 3. add or subtract mantissas (26-bit: guard high bit for carry)
    eng.run(isa.schedule(isa.compile_table(
        [sb.col(0), ss.col(0), sdif.col(0)], [sdif.col(0)],
        lambda b: (b[0] ^ b[1],))))
    # subtract where signs differ (small <= big by construction)
    eng.clear(br)
    msub = isa.sub(ms.slice(0, 25), mb.slice(0, 25), br)
    # conditionalize: prepend sdif=1 to each pass
    eng.run(_conditionalize(msub, sdif.col(0), 1))
    # add where same sign
    eng.clear(br)
    madd = _add_zext(ms.slice(0, 25), mb, br)
    eng.run(_conditionalize(madd, sdif.col(0), 0))

    # -- 4. renormalize into out
    eng.run(isa.copy(out.sign, sb))
    eng.run(isa.copy(out.exp, eb))
    eng.clear(done)
    # 4a. carry-out (bit 25): shift right one, exp += 1
    eng.run(isa.copy(s.cond, mb.slice(25, 1)))
    eng.run(isa.cond_copy(mb.slice(0, 25), mb.slice(1, 25), s.cond))
    _cond_clear(eng, mb.slice(25, 1), s.cond)
    eng.clear(s.carry)
    eng.run(_seeded_inc(out.exp, s.cond, s.carry))
    _cond_set(eng, done, s.cond)
    # 4b. leading-zero scan: rows whose leading 1 sits at bit 24-k shift
    # left by k and subtract k from the exponent (conditionalized passes).
    for k in range(0, 25):
        eng.clear(s.cond2)
        eng.compare([done.col(0), mb.col(24 - k)], [0, 1])
        eng.write([s.cond2.col(0)], [1])
        if k > 0:
            eng.run(isa.cond_copy(mb.slice(k, 25 - k), mb.slice(0, 25 - k),
                                  s.cond2, reverse=True))
            _cond_clear(eng, mb.slice(0, k), s.cond2)
            eng.clear(s.carry)
            dec = isa.const_add(out.exp, (1 << 8) - k, s.carry)
            eng.run(_conditionalize(dec, s.cond2.col(0), 1))
        _cond_set(eng, done, s.cond2)
    # rows never tagged have a zero mantissa: result is +/-0
    eng.compare([done.col(0)], [0])
    eng.write(out.exp.cols() + mb.cols(), [0] * (8 + mb.width))
    eng.run(isa.copy(out.mant, mb.slice(1, 23)))


def _conditionalize(sched, cond_col: int, cond_val: int):
    """Prepend a condition column to every pass of a schedule."""
    import numpy as np
    from repro.core.engine import PassSchedule
    P = sched.n_passes
    cc = np.concatenate([np.full((P, 1), cond_col, np.int32), sched.cmp_cols], 1)
    ck = np.concatenate([np.full((P, 1), cond_val, np.uint32), sched.cmp_key], 1)
    return PassSchedule(cc, ck, sched.w_cols, sched.w_key,
                        sched.kc + 1, sched.kw)


def _cond_clear(eng: APEngine, f: Field, cond: Field) -> None:
    """f <- 0 where cond: per-column pass (cond=1, f_i=1) -> f_i=0."""
    passes = [([cond.col(0), f.col(i)], [1, 1], [f.col(i)], [0])
              for i in range(f.width)]
    eng.run(isa.schedule(passes))


def _cond_set(eng: APEngine, f: Field, cond: Field) -> None:
    passes = [([cond.col(0), f.col(0)], [1, 0], [f.col(0)], [1])]
    eng.run(isa.schedule(passes))


def _tag_ge(eng: APEngine, f: Field, const: int, out_col: Field) -> None:
    """out_col <- (f >= const) for an 8-bit field, via tagged compares."""
    # tag rows where f >= const by enumerating matching prefixes (MSB logic):
    # f >= c iff for some bit position i: f[hi..i+1]==c[hi..i+1], f_i=1, c_i=0,
    # or f == c.
    m = f.width
    cbits = [(const >> i) & 1 for i in range(m)]
    for i in range(m):
        if cbits[i] == 0:
            cols = [f.col(j) for j in range(i, m)]
            key = [1] + [cbits[j] for j in range(i + 1, m)]
            eng.compare(cols, key)
            eng.write([out_col.col(0)], [1])
    eng.compare(f.cols(), cbits)
    eng.write([out_col.col(0)], [1])
