"""Analytic area / performance / power models — paper §3, equations (2)-(17).

All areas are normalized to one SRAM bit cell (0.1 um^2); all powers to one
SRAM cell write (0.5 uW).  Table 2 / Table 3 constants are module-level
defaults; everything is plain float math (no JAX needed) so the models can be
called from benchmarks, tests and the thermal floorplanner alike.

Workload calibration (paper gives anchors, not tables — see DESIGN.md §7.3):

* DMM: the paper pins S_AP(n_AP=2^20) = 350  =>  s_APU(DMM) = 350 / 2^20,
  and S_SIMD(n=768) = 350  =>  I_s(DMM) = 1/350 - 1/768.
* FFT / BS: Fig 4 orders arithmetic intensity BS >> FFT > DMM; synchronization
  intensity is inversely proportional to arithmetic intensity (§3.1).  We use
  the canonical operational intensities of the three kernels at N = 2^20
  (BS ~ O(100) flop/byte, FFT ~ O(log N) ~ 20, DMM blocked ~ O(sqrt(cache)))
  to scale I_s relative to the DMM anchor, and s_APU from bit-serial cycle
  counts (4400-cycle fp32 mul as the unit, paper's lower bound 1/4400).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# --------------------------------------------------------------------------
# Table 2 — area model parameters (normalized to SRAM cell = 1; 0.1 um^2)
# --------------------------------------------------------------------------
A_SRAM_UM2 = 0.1          # um^2 per normalized area unit
A_PU_BIT = 20.0           # SIMD PU bit-cell area (A_PUo)
A_RF_BIT = 3.0            # register-file flip-flop area (A_RFo)
A_AP_BIT = 2.0            # AP bit-cell area (A_APo)
M_BITS = 32               # data word length m
K_WORDS = 8               # temporary storage words per PU (k)
S_APU_LB = 1.0 / 4400.0   # AP PU speedup lower bound vs SIMD PU (fp32 mul)

# --------------------------------------------------------------------------
# Table 3 — power model parameters (normalized to SRAM write = 1; 0.5 uW)
# --------------------------------------------------------------------------
P_SRAM_UW = 0.5
P_PU_BIT = 40.0           # P_PUo
P_RF_BIT = 5.0            # P_RFo
P_SYNC_BIT = 200.0        # P_So
P_MISWRITE = 0.1          # p_mw
P_MATCH = 0.1             # p_m
P_MISMATCH = 0.75         # p_mm
GAMMA_W_MM2 = 5e-2        # leakage [W / mm^2]

N_DATA = 2 ** 20          # workload data-set size (paper: N = 2^20)
BYTES_PER_WORD = 4        # m = 32-bit data words

# Canonical operational (arithmetic) intensities at N = 2^20 [flop/word] —
# the Fig 4 ordering anchor for the paper trio, extended to the suite
# workloads (DESIGN.md §3.2 for the derivations).  Used both to scale
# synchronization intensity (inversely, §3.1) and as the
# compute-to-traffic ratio for the DRAM activate-power estimate
# (:func:`mem_traffic_bytes_per_s`).
ARITH_INTENSITY = {
    "dmm": 45.0, "fft": 10.0, "bs": 150.0,
    # suite additions: streaming / search kernels are traffic-dominated
    "sort": 2.0,     # compare-exchange streams, ~2 ops per word touched
    "spmv": 4.0,     # 2 flops per nonzero over index + value traffic
    "knn": 3.0,      # d |x-q| accumulations over d streamed words
    "hist": 1.5,     # one bin op per streamed word
}

# AP per-PU speedups for the suite workloads, from bit-serial cycle
# counts pinned by tests/test_new_workloads.py (DESIGN.md §3.2):
# sort: a min-extraction retires one distinct value in ~3m cycles vs one
#   SIMD compare/cycle; spmv: mul-bound like DMM with a 2x tag-masked
#   reduction overhead (filled in by _calibrate); knn: d-feature LUT
#   distance ~d*2^m cycles vs 2d SIMD MACs; hist: one response-counted
#   COMPARE per bin vs ~1 op/word, blended over paper-scale bin counts.
_S_APU_SUITE = {"sort": 1.0 / 96.0, "knn": 1.0 / 128.0, "hist": 1.0 / 100.0}


def _norm_area_to_mm2(a_norm: float) -> float:
    return a_norm * A_SRAM_UM2 * 1e-6


def _mm2_to_norm_area(a_mm2: float) -> float:
    return a_mm2 / (A_SRAM_UM2 * 1e-6)


# --------------------------------------------------------------------------
# workloads
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    """A paper workload with its calibrated model constants."""
    name: str
    i_s: float      # synchronization intensity  (T_S / T_1), SIMD-side
    s_apu: float    # AP PU speedup relative to a SIMD PU

    def __post_init__(self):
        if self.i_s < 0 or self.s_apu <= 0:
            raise ValueError("bad workload constants")


def _calibrate() -> dict[str, Workload]:
    # --- DMM anchors (paper Fig. 6 black dots) ------------------------------
    s_star, n_simd_star, n_ap_star = 350.0, 768.0, float(N_DATA)
    i_s_dmm = 1.0 / s_star - 1.0 / n_simd_star           # from eq (3)
    s_apu_dmm = s_star / n_ap_star                       # from eq (8)

    # --- relative arithmetic intensities at N = 2^20 (Fig 4 ordering) ------
    # I_s is inversely proportional to arithmetic intensity (§3.1).
    # DMM blocked in an L1-sized tile: AI ~ 45 flop/word-ish (reference);
    # FFT: AI ~ log2(N)/2 = 10; BS: AI ~ 150 (compute-dominated, ~no sync).
    ai_dmm, ai_fft, ai_bs = (ARITH_INTENSITY[w] for w in ("dmm", "fft", "bs"))
    i_s_fft = i_s_dmm * ai_dmm / ai_fft
    i_s_bs = i_s_dmm * ai_dmm / ai_bs

    # --- AP per-PU speedups from bit-serial cycle counts --------------------
    # fp32 mul = 4400 cycles (paper's unit).  DMM is mul+add per MAC on both
    # machines; the paper's DMM anchor implies the blended value below. FFT
    # butterflies are mul/add balanced but pay serial inter-PU communication
    # (~2x); BS is division/exp/log-heavy: LUT-based AP flow runs closer to
    # the fp-mul bound.
    s_apu_fft = s_apu_dmm / 2.0
    s_apu_bs = S_APU_LB * 1.5

    out = {
        "dmm": Workload("dmm", i_s_dmm, s_apu_dmm),
        "fft": Workload("fft", i_s_fft, s_apu_fft),
        "bs": Workload("bs", i_s_bs, s_apu_bs),
    }
    # --- suite workloads: same inverse-AI scaling off the DMM anchor ------
    for name, s_apu in {**_S_APU_SUITE, "spmv": s_apu_dmm / 2.0}.items():
        i_s = i_s_dmm * ai_dmm / ARITH_INTENSITY[name]
        out[name] = Workload(name, i_s, s_apu)
    return out


WORKLOADS = _calibrate()


def derived_workload(name: str, arith_intensity: float,
                     s_apu: float | None = None) -> Workload:
    """Anchor a NEW workload off the DMM calibration (§3.1 scaling).

    Synchronization intensity is inversely proportional to arithmetic
    intensity, so any workload with a known AI (flop/word) inherits
    ``i_s = i_s_dmm * AI_dmm / AI`` — the same rule ``_calibrate`` uses
    for the suite workloads, exposed here so callers (e.g. the serving
    cost model, which derives an AI per LLM config) can mint comparable
    Workload instances without registering them in ``WORKLOADS``.
    ``s_apu`` defaults to the DMM (MAC-dominated) per-PU speedup.
    """
    if arith_intensity <= 0:
        raise ValueError("arith_intensity must be > 0")
    base = WORKLOADS["dmm"]
    i_s = base.i_s * ARITH_INTENSITY["dmm"] / arith_intensity
    return Workload(name, i_s, base.s_apu if s_apu is None else s_apu)


# --------------------------------------------------------------------------
# SIMD processor model — eqs (2)-(6), (11)-(14)
# --------------------------------------------------------------------------

CACHE_OVERHEAD = 1.1  # tag arrays + decoders/periphery on top of N*m data cells
                      # (calibrated so A_SIMD(768 PUs) = 5.3 mm^2, the paper's
                      # own figure; data cells alone give 4.99 mm^2)


def simd_cache_area(n_data: int = N_DATA, m: int = M_BITS) -> float:
    """A_C: L1+L2 of total size >= N data words (normalized units)."""
    return float(n_data) * m * CACHE_OVERHEAD


def simd_pu_area(m: int = M_BITS, k: int = K_WORDS) -> float:
    return A_PU_BIT * m * m + A_RF_BIT * k * m


def simd_n_pus(area_norm: float, n_data: int = N_DATA) -> float:
    """eq (6): number of PUs for a total (normalized) area budget."""
    usable = area_norm - simd_cache_area(n_data)
    return max(usable, 0.0) / simd_pu_area()


def simd_area(n_pus: float, n_data: int = N_DATA) -> float:
    """eq (4), normalized units."""
    return n_pus * simd_pu_area() + simd_cache_area(n_data)


def simd_speedup(n_pus: float, wl: Workload) -> float:
    """eq (3)."""
    if n_pus <= 0:
        return 0.0
    return 1.0 / (1.0 / n_pus + wl.i_s)


def simd_power_norm(n_pus: float, wl: Workload, m: int = M_BITS,
                    k: int = K_WORDS) -> float:
    """eq (14) in normalized power units (excluding absolute leakage)."""
    if n_pus <= 0:
        return 0.0
    p_exec_per_pu = P_PU_BIT * m * m + P_RF_BIT * k * m
    # eq (14) numerator: per-PU exec power + I_s * P_So * m (all normalized)
    num = p_exec_per_pu + wl.i_s * P_SYNC_BIT * m
    den = 1.0 / n_pus + wl.i_s
    return num / den


def simd_power_W(n_pus: float, wl: Workload, n_data: int = N_DATA) -> float:
    """Total SIMD power in watts: eq (14) dynamic + gamma * area leakage."""
    dyn = simd_power_norm(n_pus, wl) * P_SRAM_UW * 1e-6
    leak = GAMMA_W_MM2 * _norm_area_to_mm2(simd_area(n_pus, n_data))
    return dyn + leak


def simd_phase_powers(wl: Workload, n_pus: float, m: int = M_BITS,
                      k: int = K_WORDS) -> tuple[float, float, float]:
    """Eq (14) split into its two phases: (p_exec_W, p_sync_W, f_run).

    p_exec_W / p_sync_W are time-AVERAGED watts of the execute and
    synchronize components; f_run = (1/n) / (1/n + I_s) is the fraction of
    time spent executing.  Shared by the SIMD floorplan's spatial split and
    the co-sim phase trace so both always use the same decomposition.
    """
    f_run = (1.0 / n_pus) / (1.0 / n_pus + wl.i_s)
    p_exec_W = n_pus * (P_PU_BIT * m * m + P_RF_BIT * k * m) \
        * f_run * P_SRAM_UW * 1e-6
    p_sync_W = (wl.i_s * P_SYNC_BIT * m / (1.0 / n_pus + wl.i_s)) \
        * P_SRAM_UW * 1e-6
    return p_exec_W, p_sync_W, f_run


# --------------------------------------------------------------------------
# AP model — eqs (7)-(10), (15)-(17)
# --------------------------------------------------------------------------

def ap_pu_area(m: int = M_BITS, k: int = K_WORDS) -> float:
    return A_AP_BIT * k * m


def ap_n_pus(area_norm: float) -> float:
    """eq (10)."""
    return area_norm / ap_pu_area()


def ap_area(n_pus: float) -> float:
    """eq (9), normalized units."""
    return n_pus * ap_pu_area()


def ap_speedup(n_pus: float, wl: Workload) -> float:
    """eq (8)."""
    return wl.s_apu * n_pus


def ap_dynamic_power_per_pu_norm() -> float:
    """eq (17) dynamic bracket: 1/8 + 7/8 p_mw + 3/16 p_m + 21/16 p_mm.

    Derivation (eq 16): a pass writes 2 bits (P(write) = 1/8 per row) and
    compares 3 bits (P(match) = 1/8); averaged over the compare and write
    halves of the cycle.
    """
    return (2.0 * (1.0 / 8.0 + 7.0 / 8.0 * P_MISWRITE)
            + 3.0 * (1.0 / 8.0 * P_MATCH + 7.0 / 8.0 * P_MISMATCH)) / 2.0


def ap_power_W(n_pus: float) -> float:
    """eq (17): dynamic + leakage, watts."""
    dyn = n_pus * ap_dynamic_power_per_pu_norm() * P_SRAM_UW * 1e-6
    leak = GAMMA_W_MM2 * _norm_area_to_mm2(ap_area(n_pus))
    return dyn + leak


# --------------------------------------------------------------------------
# derived comparisons (Fig 6 / Fig 7 and §4 inputs)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """A same-performance AP/SIMD pair, the input to the thermal analysis."""
    workload: str
    speedup: float
    ap_n_pus: int
    ap_area_mm2: float
    ap_power_W: float
    simd_n_pus: int
    simd_area_mm2: float
    simd_power_W: float

    @property
    def power_ratio(self) -> float:
        return self.simd_power_W / self.ap_power_W

    @property
    def power_density_ratio(self) -> float:
        return (self.simd_power_W / self.simd_area_mm2) / \
               (self.ap_power_W / self.ap_area_mm2)


def design_point(wl: Workload, n_ap: int = N_DATA) -> DesignPoint:
    """Same-performance AP/SIMD pair for an arbitrary Workload instance.

    The §3/§4 construction: AP sized to ``n_ap`` PUs, SIMD sized to
    yield the same speedup (inverting eq 3).  Raises ValueError when the
    AP speedup exceeds the SIMD synchronization ceiling 1/I_s, i.e. when
    no same-performance SIMD exists."""
    s = ap_speedup(n_ap, wl)
    if s * wl.i_s >= 1.0:
        raise ValueError(f"SIMD cannot reach speedup {s} for {wl.name} "
                         f"(I_s bound {1/wl.i_s:.1f})")
    n_simd = 1.0 / (1.0 / s - wl.i_s)  # invert eq (3)
    return DesignPoint(
        workload=wl.name,
        speedup=s,
        ap_n_pus=n_ap,
        ap_area_mm2=_norm_area_to_mm2(ap_area(n_ap)),
        ap_power_W=ap_power_W(n_ap),
        simd_n_pus=int(round(n_simd)),
        simd_area_mm2=_norm_area_to_mm2(simd_area(n_simd)),
        simd_power_W=simd_power_W(n_simd, wl),
    )


def paper_design_point(workload: str = "dmm",
                       n_ap: int = N_DATA) -> DesignPoint:
    """The §3/§4 comparison point: AP sized to the data set (n_AP = N = 2^20),

    SIMD sized to yield the same speedup."""
    return design_point(WORKLOADS[workload], n_ap)


def break_even_area_mm2(workload: str) -> float:
    """Area at which AP speedup overtakes SIMD speedup (Fig 6 crossing)."""
    wl = WORKLOADS[workload]
    lo, hi = 1e4, 1e12  # normalized area search window
    f = lambda a: ap_speedup(ap_n_pus(a), wl) - simd_speedup(simd_n_pus(a), wl)
    if f(hi) < 0:
        return math.inf
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        if f(mid) < 0:
            lo = mid
        else:
            hi = mid
    return _norm_area_to_mm2(hi)


def speedup_vs_area_curves(workload: str, areas_mm2: np.ndarray):
    """Fig 6: (area, S_SIMD, S_AP) arrays for one workload."""
    wl = WORKLOADS[workload]
    a_norm = np.array([_mm2_to_norm_area(a) for a in areas_mm2])
    s_simd = np.array([simd_speedup(simd_n_pus(a), wl) for a in a_norm])
    s_ap = np.array([ap_speedup(ap_n_pus(a), wl) for a in a_norm])
    return s_simd, s_ap


def power_vs_area_curves(workload: str, areas_mm2: np.ndarray):
    """Fig 7: (P_SIMD, P_AP) in watts for one workload."""
    wl = WORKLOADS[workload]
    a_norm = np.array([_mm2_to_norm_area(a) for a in areas_mm2])
    p_simd = np.array([simd_power_W(simd_n_pus(a), wl) for a in a_norm])
    p_ap = np.array([ap_power_W(ap_n_pus(a)) for a in a_norm])
    return p_simd, p_ap


# --------------------------------------------------------------------------
# AP-backend estimate for the assigned LM architectures (DESIGN.md §4):
# maps a cell's FLOP count onto AP bit-serial cycle costs so the roofline
# report can contrast the paper's architecture with TPU v5e.
# --------------------------------------------------------------------------

AP_CYCLES_PER_FP32_MUL = 4400.0   # paper §2.2
AP_CYCLES_PER_FP32_ADD = 1100.0   # ~8m + alignment overheads, model constant
AP_CLOCK_HZ = 1e9                 # 1 GHz-class CAM cycle (paper-era assumption)


def ap_flops_per_s(n_pus: int = N_DATA) -> float:
    """Sustained MAC-rate of one AP in flop/s (every PU in parallel).

    A MAC = one fp32 mul + one fp32 add = 5500 bit-serial cycles; all
    ``n_pus`` rows advance together, so flop/s = 2 * n_pus * f / 5500.
    """
    macs_per_s = n_pus * AP_CLOCK_HZ \
        / (AP_CYCLES_PER_FP32_MUL + AP_CYCLES_PER_FP32_ADD)
    return 2.0 * macs_per_s


def mem_traffic_bytes_per_s(workload: str, n_pus: int = N_DATA) -> float:
    """Off-chip (DRAM) traffic estimate for a design point [bytes/s].

    traffic = compute rate / arithmetic intensity: each AI flops of work
    stream one m-bit word to or from memory (DESIGN.md §7.4).  Evaluated
    at the AP's compute rate — the same-performance SIMD pair sustains the
    same flop/s by construction, so ONE traffic figure drives the DRAM
    activate power of both machines' stacks and the thermal comparison
    stays apples-to-apples.
    """
    if workload not in ARITH_INTENSITY:
        raise ValueError(f"unknown workload {workload!r}; expected one of "
                         f"{sorted(ARITH_INTENSITY)}")
    return traffic_bytes_per_s(ARITH_INTENSITY[workload], n_pus)


def traffic_bytes_per_s(arith_intensity: float,
                        n_pus: int = N_DATA) -> float:
    """`mem_traffic_bytes_per_s` for an AI not in ``ARITH_INTENSITY`` —
    e.g. the per-batch decode AI the serving cost model derives."""
    if arith_intensity <= 0:
        raise ValueError("arith_intensity must be > 0")
    return ap_flops_per_s(n_pus) / arith_intensity * BYTES_PER_WORD


def ap_backend_estimate(total_flops: float, n_pus: int = N_DATA) -> dict:
    """Time/energy for running `total_flops` MAC-dominated work on one AP.

    A MAC = one fp32 mul + one fp32 add = 5500 cycles on every PU in
    parallel.  Returns seconds and joules under the eq-(17) power model.
    """
    macs = total_flops / 2.0
    cycles = (macs / n_pus) * (AP_CYCLES_PER_FP32_MUL + AP_CYCLES_PER_FP32_ADD)
    seconds = cycles / AP_CLOCK_HZ
    watts = ap_power_W(n_pus)
    return {"cycles": cycles, "seconds": seconds, "watts": watts,
            "joules": watts * seconds, "n_pus": n_pus}
