"""Core of the reproduction: the exact bit-serial AP machine model
(`bitplane`, `engine`, `isa`, `arith`, `apfloat`), the paper's analytic
area/performance/power models (`models`), die floorplans (`floorplan`),
the HotSpot-equivalent 3D RC thermal solver (`thermal`), the
power-trace → transient co-simulation engine (`cosim`), and shared
thermal constants (`constants`)."""
