"""Power-trace -> transient thermal co-simulation (the CoMeT / Sniper+HotSpot
pattern, arXiv:2109.12405, applied to the paper's AP-vs-SIMD §4 study).

The steady-state comparison (`floorplan.thermal_comparison`) answers "where
does each die settle"; this module answers "what does each die *do on the
way there*" — per-workload hot-spot dynamics, thermal cycling, and the
time-resolved 85 °C 3D-DRAM verdict.

Pipeline (mirrors the performance-simulator -> thermal-model split of CoMeT):

1. **Trace capture** — `APEngine` meters every compare/write pass with its
   exact matched-row energy accounting; `engine.power_trace(n)` bins those
   events into n equal cycle windows (energy-conserving).  The SIMD
   reference gets an analytic two-phase trace from the eq-(14) execute/sync
   decomposition (its instantaneous power alternates between the exec and
   sync levels at the model's duty cycle).
2. **Frame synthesis** — each interval's total dynamic power modulates the
   floorplan's *spatial* power map (leakage stays constant), producing a
   [T, L, NY, NX] power-frame stack over the thermal grid domain.
3. **Replay** — an implicit theta-scheme stepper (`thermal.pcg_fixed` inner
   solves, unconditionally stable, so the step is set by the trace interval
   rather than the explicit CFL bound) scans the frames and records
   per-layer peak/min per interval.  The whole replay is one `lax.scan`
   and vmaps over a batch of (workload x machine) design points.

Time base: small AP kernel instances run in microseconds of engine time
while package thermal constants are ~0.1 s, so the replay *dilates* the
trace onto a configurable `t_end` — the trace supplies the activity
profile's shape, the design point supplies its mean wattage (documented in
README §co-simulation; same epoch-replay convention as HotSpot ptrace).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as M
from repro.core import thermal
from repro.core.constants import DRAM_LIMIT_C
from repro.core.floorplan import MM, APFloorplan, SIMDFloorplan


# ---------------------------------------------------------------------------
# power traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerTrace:
    """Per-interval dynamic activity of one die layer (dimensionless).

    ``activity`` has mean 1.0 over the trace, so scaling by a design
    point's per-layer dynamic wattage preserves its time-averaged power.
    ``native_s`` is the engine time the trace actually spans (cycles at
    ``M.AP_CLOCK_HZ``) before replay dilation, 0 for analytic traces.
    """
    activity: np.ndarray
    source: str = ""
    native_s: float = 0.0

    @property
    def n_intervals(self) -> int:
        return int(self.activity.shape[0])


def trace_from_counters(counters: dict, n_intervals: int,
                        source: str = "") -> PowerTrace:
    """Bin a workload's engine events (``counters['trace_*']``) into an
    activity profile.  Energy-conserving: mean(activity) == 1 exactly."""
    from repro.core.engine import bin_energy_trace

    total_cycles = max(int(counters["cycles"]), 1)
    _, bins = bin_energy_trace(counters["trace_cycles"],
                               counters["trace_energy"],
                               total_cycles, n_intervals)
    mean = bins.mean()
    if mean <= 0.0:
        return PowerTrace(np.ones(n_intervals), source,
                          total_cycles / M.AP_CLOCK_HZ)
    return PowerTrace(bins / mean, source, total_cycles / M.AP_CLOCK_HZ)


def trace_elems(size: int) -> int:
    """Small-instance element count for a dataset size: sqrt(N) clamped
    to [32, 2^20].  The lower bound keeps per-phase structure; the upper
    bound has been lifted twice (256 -> 2048 -> 2^20) as the execution
    model sped up: first the device-resident programs removed the
    per-cycle host sync, then the megakernel path's fused op groups and
    bulk host-side accounting (kernels/ap_megakernel, engine
    ``charge_bulk``) made even million-element exact traces tractable —
    the clamp now only bounds trace memory, and binds at dataset sizes
    past 2^40.  The ONE sizing rule shared by every driver (run_cosim,
    run_stack_cosim, repro.sweep) so the same nominal scenario always
    replays the same trace."""
    return int(min(max(math.sqrt(size), 32), 1 << 20))


@functools.lru_cache(maxsize=None)
def ap_workload_trace(workload: str, n_intervals: int = 64,
                      n_elems: int = 64,
                      mode: str = "device") -> PowerTrace:
    """Run a small instance of the named AP workload (any registry entry)
    and bin its measured energy events.  Small instances keep the
    per-phase structure (MAC sweeps, FFT stages, sort extractions) that
    sets the activity shape; ``n_elems`` scales the instance.  ``mode``
    picks the execution path ("device" / "eager" / "megakernel") —
    all three are bit-identical, so it only affects capture speed."""
    from repro.workloads import registry

    ctr = registry.trace_counters(workload, n_elems, mode=mode)
    return trace_from_counters(ctr, n_intervals, source=f"ap:{workload}")


def simd_phase_trace(wl: M.Workload, dp: M.DesignPoint,
                     n_intervals: int = 64,
                     period_intervals: int = 8) -> PowerTrace:
    """Analytic SIMD trace: eq (14) splits runtime into execute and
    synchronize phases; instantaneous dynamic power alternates between the
    two levels at the duty cycle f_run = (1/n) / (1/n + I_s)."""
    p_exec_W, p_sync_W, f_run = M.simd_phase_powers(wl, dp.simd_n_pus)
    # instantaneous levels: average / phase-time-fraction (only the
    # exec:sync ratio matters; the final mean-1 normalization calibrates)
    lvl_exec = p_exec_W / max(f_run, 1e-9)
    lvl_sync = p_sync_W / max(1.0 - f_run, 1e-9)
    act = np.empty(n_intervals)
    for i in range(n_intervals):
        phase = (i % period_intervals) / period_intervals
        act[i] = lvl_exec if phase < f_run else lvl_sync
    return PowerTrace(act / act.mean(), source=f"simd:{wl.name}")


# ---------------------------------------------------------------------------
# frame synthesis
# ---------------------------------------------------------------------------

def power_frames(trace: PowerTrace, pmap: np.ndarray, leak_W: float,
                 grid: thermal.Grid) -> np.ndarray:
    """[T, L, NY, NX] power frames over the full thermal domain.

    ``pmap`` is a floorplan layer map (leakage included, as produced by
    ``*Floorplan.power_map``); leakage stays constant per interval while
    the dynamic remainder is modulated by the trace activity.  Every
    LOGIC layer carries the same map (the §4 convention); DRAM layers of
    a heterogeneous spec, the spreader layer, and the margin ring get
    zero (DRAM power needs its own model —
    ``repro.stack.feedback.stack_power_inputs``).
    """
    grid_n = pmap.shape[0]
    leak_map = np.full_like(pmap, leak_W / pmap.size)
    dyn_map = pmap - leak_map
    frames_2d = leak_map[None] + trace.activity[:, None, None] * dyn_map[None]
    T = trace.n_intervals
    L = grid.n_layers
    m = grid.margin
    out = np.zeros((T, L, grid.dom_ny, grid.dom_nx), np.float32)
    for l in grid.stack.logic_layers:
        out[:, l, m:m + grid_n, m:m + grid_n] = frames_2d
    return out


# ---------------------------------------------------------------------------
# adaptive interval coarsening (multi-hour serving horizons)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoarsePlan:
    """A merge of consecutive base intervals into variable-length coarse
    intervals: ``reps[i]`` base intervals fold into coarse interval i.

    Built by :func:`coarsen_plan` so that the activity range inside each
    run is bounded by the plan's tolerance; the merged power is the run
    MEAN, which conserves energy exactly (equal-length base intervals).
    The replay consumes ``dt_scale`` as the per-interval step multiplier
    (``stack.feedback.closed_loop_replay(..., dt_scale=...)``).
    """
    reps: np.ndarray            # [Tc] int, each >= 1, sum == n_base

    def __post_init__(self):
        reps = np.asarray(self.reps, np.int64)
        if reps.ndim != 1 or reps.size == 0 or (reps < 1).any():
            raise ValueError("reps must be a non-empty 1-D array of "
                             "positive run lengths")
        object.__setattr__(self, "reps", reps)

    @property
    def n_coarse(self) -> int:
        return int(self.reps.size)

    @property
    def n_base(self) -> int:
        return int(self.reps.sum())

    @property
    def ratio(self) -> float:
        """Solver-interval saving vs uniform stepping (>= 1)."""
        return self.n_base / self.n_coarse

    def dt_scale(self) -> np.ndarray:
        """Per-coarse-interval duration in units of the base interval."""
        return self.reps.astype(np.float32)

    def _edges(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.reps)])

    def merge(self, x: np.ndarray) -> np.ndarray:
        """Mean of ``x`` (leading axis = base intervals) over each run —
        the energy-conserving lowering of a base-resolution signal."""
        x = np.asarray(x)
        if x.shape[0] != self.n_base:
            raise ValueError(f"signal has {x.shape[0]} base intervals, "
                             f"plan covers {self.n_base}")
        e = self._edges()
        return np.stack([x[e[i]:e[i + 1]].mean(axis=0)
                         for i in range(self.n_coarse)])

    def expand(self, y: np.ndarray) -> np.ndarray:
        """Inverse resampling: repeat each coarse value over its run."""
        y = np.asarray(y)
        if y.shape[0] != self.n_coarse:
            raise ValueError(f"signal has {y.shape[0]} coarse intervals, "
                             f"plan has {self.n_coarse}")
        return np.repeat(y, self.reps, axis=0)

    def pad_to(self, n: int) -> "CoarsePlan":
        """Split the largest runs until the plan has ``n`` coarse
        intervals (clamped to ``n_base``).  Splitting only ever SHRINKS
        within-run activity ranges, so the plan's error bound still
        holds; use it to bucket plans onto a few lengths so jitted
        replays of different scenarios share compiled programs."""
        n = min(n, self.n_base)
        reps = list(self.reps)
        while len(reps) < n:
            i = int(np.argmax(reps))
            if reps[i] < 2:
                break
            half = reps[i] // 2
            reps[i:i + 1] = [reps[i] - half, half]
        return CoarsePlan(np.asarray(reps, np.int64))


def coarsen_plan(activity: np.ndarray, tol: float,
                 max_merge: int = 64) -> CoarsePlan:
    """Greedy run-merging of a base-resolution activity signal.

    Consecutive intervals join the current run while the run's
    max-min activity range (including the candidate) stays <= ``tol``
    and the run is shorter than ``max_merge`` intervals.  With the
    merged power set to the run mean (:meth:`CoarsePlan.merge`), the
    instantaneous power error of the coarsened trace is bounded by
    ``tol`` activity units, so the replay's temperature error is
    bounded by ``tol`` x the DC thermal gain of the modulated power
    map (:func:`dc_peak_rise_C`; DESIGN.md §9.3) — the linear-RC bound
    the coarsening property test checks.

    ``activity`` may be [T] or [T, K] (K signals coarsened jointly, the
    range criterion applied to the worst signal — e.g. logic utilization
    and DRAM traffic of one serving scenario).
    """
    act = np.asarray(activity, np.float64)
    if act.ndim == 1:
        act = act[:, None]
    if act.ndim != 2 or act.shape[0] == 0:
        raise ValueError("activity must be [T] or [T, K] with T >= 1")
    if tol < 0:
        raise ValueError("tol must be >= 0")
    if max_merge < 1:
        raise ValueError("max_merge must be >= 1")

    reps = []
    run = 1
    lo = act[0].copy()
    hi = act[0].copy()
    for t in range(1, act.shape[0]):
        nlo = np.minimum(lo, act[t])
        nhi = np.maximum(hi, act[t])
        if run < max_merge and float((nhi - nlo).max()) <= tol:
            run += 1
            lo, hi = nlo, nhi
        else:
            reps.append(run)
            run = 1
            lo = act[t].copy()
            hi = act[t].copy()
    reps.append(run)
    return CoarsePlan(np.asarray(reps, np.int64))


def dc_peak_rise_C(frame, F: dict) -> float:
    """Peak steady-state temperature rise of ONE power frame [L,NY,NX].

    The DC gain of the passive RC network: for a linear (open-loop)
    replay, substituting power within a window by a value that deviates
    at most dP pointwise moves the temperature trajectory by at most the
    steady response to dP.  ``tol * dc_peak_rise_C(worst_frame, F)`` is
    therefore a rigorous bound on the coarsened-replay temperature error
    at activity tolerance ``tol`` (coarsen_plan docstring; tested in
    tests/test_coarsen_replay.py)."""
    dT, _ = thermal._solve_fields(jnp.asarray(frame, jnp.float32), F,
                                  solver="pcg", use_pallas=False)
    return float(jnp.max(dT))


def interval_forecaster(A, solve, logic_mask3, t_amb):
    """One-substep RC forecast of the logic hot spot, affine in the duty.

    Built per interval inside the replay scan and handed to predictive
    DTM policies as ``PolicyContext.predict_hot``
    (``repro.policy.PredictivePolicy``): the returned
    ``predict(dT, P_dyn, P_stat)`` closes over the interval's implicit
    step operator and yields ``hot(cands)`` — for duty candidates
    ``cands [K]``, the forecast end-of-substep logic hot spots [K] under
    power ``f·P_dyn + P_stat``.  The theta-step response is affine in
    ``f``, so ALL candidates cost two inner solves:

        dT(f) = dT + solve(P_stat − A dT) + f · solve(P_dyn)

    ``solve`` is the interval's implicit-LHS inner solve (the same
    fixed-cost PCG/multigrid object the replay steps with), so the
    forecast horizon equals one replay substep and the forecast model IS
    the replay's own thermal RC operator — no second model to calibrate.
    """
    def predict(dT, P_dyn, P_stat):
        def hot(cands):
            # solves run lazily, on first call: a replay whose policy
            # never forecasts traces no forecast ops at all
            base = dT + solve(P_stat - A(dT))
            gain = solve(P_dyn)
            fields = base[None] + cands[:, None, None, None] * gain
            return jnp.max(
                jnp.where(logic_mask3 > 0, fields + t_amb, -jnp.inf),
                axis=(1, 2, 3))
        return hot
    return predict


# ---------------------------------------------------------------------------
# implicit replay core (scan over frames; vmappable over design points)
# ---------------------------------------------------------------------------

def _replay(frames, F, cap3, interval_dt, theta, t_amb, *,
            steps_per_interval: int, n_cg: int, n_si: int, margin: int,
            die_n: int, use_pallas: bool):
    if use_pallas:
        from repro.kernels.thermal_stencil import ops as _ops
        A = lambda v: _ops.apply_operator_fields(v, F)
    else:
        A = lambda v: thermal.apply_operator_fields(v, F)
    dt = interval_dt / steps_per_interval
    lhs = lambda v: cap3 / dt * v + theta * A(v)
    Minv = 1.0 / (cap3 / dt + theta * thermal._diag_fields(F))

    def interval(dTc, P):
        def one(d, _):
            rhs = P - A(d)
            return d + thermal.pcg_fixed(lhs, Minv, rhs, n_cg), None
        dTn, _ = jax.lax.scan(one, dTc, None, length=steps_per_interval)
        die = dTn[:n_si, margin:margin + die_n, margin:margin + die_n]
        return dTn, (jnp.max(die, axis=(1, 2)), jnp.min(die, axis=(1, 2)))

    dT0 = jnp.zeros_like(frames[0])
    dT_end, (mx, mn) = jax.lax.scan(interval, dT0, frames)
    return dT_end + t_amb, mx + t_amb, mn + t_amb


@partial(jax.jit, static_argnames=("steps_per_interval", "n_cg", "n_si",
                                   "margin", "die_n", "use_pallas"))
def cosim_transient(frames, F: dict, cap3, interval_dt,
                    theta: float = 1.0, t_amb: float = thermal.AMBIENT_C, *,
                    die_n: int, steps_per_interval: int = 2, n_cg: int = 40,
                    n_si: int = 4, margin: int = 0,
                    use_pallas: bool = False):
    """Replay one frame stack.  Returns (T_end [L,NY,NX],
    peak_C [T,n_si], min_C [T,n_si]) — peaks/mins over the die footprint
    of the silicon layers only."""
    return _replay(frames, F, cap3, interval_dt, theta, t_amb,
                   steps_per_interval=steps_per_interval, n_cg=n_cg,
                   n_si=n_si, margin=margin, die_n=die_n,
                   use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("steps_per_interval", "n_cg", "n_si",
                                   "margin", "die_n", "use_pallas"))
def cosim_transient_batch(frames, F: dict, cap3, interval_dt,
                          theta: float = 1.0,
                          t_amb: float = thermal.AMBIENT_C, *,
                          die_n: int, steps_per_interval: int = 2,
                          n_cg: int = 40, n_si: int = 4, margin: int = 0,
                          use_pallas: bool = False):
    """vmapped replay over a leading batch of design points.

    frames [B,T,L,NY,NX]; each leaf of F and cap3 batched [B,...] (the
    batch shares one grid shape; conductances/capacities differ per die).
    """
    fn = partial(_replay, steps_per_interval=steps_per_interval, n_cg=n_cg,
                 n_si=n_si, margin=margin, die_n=die_n,
                 use_pallas=use_pallas)
    return jax.vmap(lambda fr, Fb, cb: fn(fr, Fb, cb, interval_dt, theta,
                                          t_amb))(frames, F, cap3)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CosimReport:
    """Time-resolved thermal summary of one replay."""
    label: str
    interval_s: float
    peak_C: np.ndarray          # [T, n_si]
    min_C: np.ndarray           # [T, n_si]

    @property
    def times(self) -> np.ndarray:
        return self.interval_s * np.arange(1, self.peak_C.shape[0] + 1)

    @property
    def span_C(self) -> np.ndarray:
        return self.peak_C - self.min_C

    @property
    def final_peak_C(self) -> np.ndarray:
        return self.peak_C[-1]

    def time_above(self, limit_C: float = DRAM_LIMIT_C) -> np.ndarray:
        """Seconds each layer spent above ``limit_C`` (per-interval
        granularity, counted on the layer's peak cell)."""
        return self.interval_s * (self.peak_C > limit_C).sum(axis=0)

    def crossing_time(self, limit_C: float = DRAM_LIMIT_C
                      ) -> np.ndarray:
        """First time [s] each layer's peak exceeds ``limit_C`` (inf if
        it never does)."""
        above = self.peak_C > limit_C
        first = np.where(above.any(axis=0), above.argmax(axis=0), -1)
        t = self.times
        return np.where(first >= 0, t[np.maximum(first, 0)], np.inf)


# ---------------------------------------------------------------------------
# top-level driver: batched AP-vs-SIMD per-workload co-simulation
# ---------------------------------------------------------------------------

def comparable_design_point(workload: str | M.Workload,
                            n_ap_start: int = M.N_DATA) -> M.DesignPoint:
    """Largest same-performance AP/SIMD pair that exists for a workload.

    A SIMD can only match AP speedups below its synchronization ceiling
    1/I_s (eq 3).  For dmm/bs the paper's full-size AP (n = 2^20) is
    comparable; for fft and the low-arithmetic-intensity suite workloads
    it is not, so the AP is halved from ``n_ap_start`` (the dataset
    size, paper sizing n_AP = N) until the comparison point exists —
    same-performance remains the invariant.  ``workload`` may be a
    registered name or any :class:`~repro.core.models.Workload` instance
    (e.g. one minted by ``models.derived_workload`` for a serving AI).
    """
    if isinstance(workload, M.Workload):
        wl = workload
    elif workload in M.WORKLOADS:
        wl = M.WORKLOADS[workload]
    else:
        raise ValueError(f"unknown workload {workload!r}; expected one of "
                         f"{sorted(M.WORKLOADS)}")
    n_ap = n_ap_start
    while n_ap >= 1024:
        try:
            return M.design_point(wl, n_ap)
        except ValueError:
            n_ap //= 2
    raise ValueError(f"no comparable design point for {wl.name!r}")

def run_cosim(workloads=("dmm", "fft"), grid_n: int = 32,
              n_intervals: int = 64, t_end: float = 0.25,
              steps_per_interval: int = 2, n_cg: int = 40,
              theta: float = 1.0, stack: thermal.StackParams | None = None,
              use_pallas: bool = False) -> dict:
    """The §4 comparison, transient: for each workload, replay the AP's
    measured trace and the SIMD reference's analytic trace through the
    same stack in ONE vmapped batch.  Returns
    ``{workload: {"ap": CosimReport, "simd": CosimReport},
    "design_points": {...}}``.
    """
    stack = stack or thermal.PAPER_STACK
    margin = grid_n // 4
    interval_dt = t_end / n_intervals

    labels, all_frames, all_F, all_cap = [], [], [], []
    dps = {}
    for w in workloads:
        dp = comparable_design_point(w)
        dps[w] = dp
        wl = M.WORKLOADS[w]
        ap_fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
        simd_fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))
        cases = (
            (f"{w}/ap", ap_fp.power_map(grid_n, dp.ap_power_W),
             ap_fp.leakage_W(), ap_fp.die_w_mm,
             ap_workload_trace(w, n_intervals, trace_elems(M.N_DATA))),
            (f"{w}/simd", simd_fp.power_map(grid_n, dp),
             simd_fp.leakage_W(dp), simd_fp.die_w_mm,
             simd_phase_trace(wl, dp, n_intervals)),
        )
        for label, pmap, leak_W, die_w_mm, trace in cases:
            grid = thermal.Grid(die_w=die_w_mm * MM, ny=grid_n, nx=grid_n,
                                params=stack, margin=margin)
            labels.append(label)
            all_frames.append(power_frames(trace, pmap, leak_W, grid))
            all_F.append(grid.fields())
            all_cap.append(grid.capacity_field())

    frames = jnp.asarray(np.stack(all_frames))
    Fb = {k: jnp.stack([F[k] for F in all_F]) for k in all_F[0]}
    capb = jnp.stack(all_cap)
    _, peaks, mins = cosim_transient_batch(
        frames, Fb, capb, interval_dt, theta,
        steps_per_interval=steps_per_interval, n_cg=n_cg,
        n_si=stack.n_si_layers, margin=margin, die_n=grid_n,
        use_pallas=use_pallas)
    peaks = np.asarray(peaks)
    mins = np.asarray(mins)

    out: dict = {"design_points": dps, "interval_s": interval_dt,
                 "t_end": t_end}
    for i, label in enumerate(labels):
        w, machine = label.split("/")
        out.setdefault(w, {})[machine] = CosimReport(
            label=label, interval_s=interval_dt,
            peak_C=peaks[i], min_C=mins[i])
    return out
