"""Packed bit-plane representation of the Associative Processing Array.

The AP (paper Fig. 1) is an array of ``n_words`` rows x ``n_bits`` columns of
associative bit cells.  A word-row is a Processing Unit (PU).  Compare and
tagged-write operate on *columns* (selected by MASK) across *all rows* at once,
so the natural TPU/JAX layout is **column-major bit planes**:

    planes : uint32[n_bits, n_words // 32]

plane ``i`` holds bit-column ``i`` for every word, packed 32 words per lane.
One AP pass (a 3-column compare + a 2-column tagged write) is then a handful of
bitwise VPU ops over contiguous lanes — the same re-blocking a TPU port of the
CAM would use (HBM->VMEM streaming over the word axis, all active bit-columns
resident; see kernels/ap_match).

The TAG register is a packed ``uint32[n_words // 32]`` vector.
"""
from __future__ import annotations

import dataclasses
from functools import partial, reduce

import jax
import jax.numpy as jnp
import numpy as np

LANE = 32  # words packed per uint32 lane
_U32 = jnp.uint32
FULL = jnp.uint32(0xFFFFFFFF)


def n_lanes(n_words: int) -> int:
    if n_words % LANE != 0:
        raise ValueError(f"n_words must be a multiple of {LANE}, got {n_words}")
    return n_words // LANE


def alloc_planes(n_bits: int, n_words: int) -> jax.Array:
    """All-zero associative array."""
    return jnp.zeros((n_bits, n_lanes(n_words)), dtype=_U32)


# ---------------------------------------------------------------------------
# host <-> bitplane conversion
# ---------------------------------------------------------------------------

def pack_words(values: np.ndarray | jax.Array, n_bits: int) -> jax.Array:
    """Pack integer words ``values[n_words]`` into bit planes [n_bits, n_words/32].

    Bit ``i`` of word ``w`` lands in ``planes[i, w // 32]`` at lane-bit ``w % 32``.
    Host-side (numpy) so >32-bit fields work without jax_enable_x64.
    """
    if n_bits > 64:
        raise ValueError(
            f"fields wider than 64 bits cannot be packed from uint64 host "
            f"words (got width {n_bits}); split the value across fields")
    values = np.asarray(jax.device_get(values)).astype(np.uint64)
    n_words = values.shape[0]
    nl = n_lanes(n_words)
    bits = (values[None, :] >> np.arange(n_bits, dtype=np.uint64)[:, None]) & 1
    bits = bits.astype(np.uint32).reshape(n_bits, nl, LANE)
    shifts = np.arange(LANE, dtype=np.uint32)
    packed = (bits << shifts[None, None, :]).sum(axis=-1, dtype=np.uint32)
    return jnp.asarray(packed)


def unpack_words(planes: jax.Array, out_dtype=np.uint64) -> np.ndarray:
    """Inverse of :func:`pack_words` -> integer words [n_words] (host numpy)."""
    pl = np.asarray(jax.device_get(planes))
    n_bits, nl = pl.shape
    shifts = np.arange(LANE, dtype=np.uint32)
    bits = (pl[:, :, None] >> shifts[None, None, :]) & 1  # [bits, nl, LANE]
    bits = bits.reshape(n_bits, nl * LANE).astype(out_dtype)
    weights = (out_dtype(1) << np.arange(n_bits, dtype=out_dtype))
    return (bits * weights[:, None]).sum(axis=0, dtype=out_dtype)


def pack_bits(bitvec: np.ndarray | jax.Array) -> jax.Array:
    """Pack a boolean vector [n_words] into a packed tag row [n_words/32]."""
    bitvec = jnp.asarray(bitvec).astype(_U32)
    nl = n_lanes(bitvec.shape[0])
    bits = bitvec.reshape(nl, LANE)
    shifts = jnp.arange(LANE, dtype=_U32)
    return (bits << shifts[None, :]).sum(axis=-1, dtype=_U32)


def unpack_bits(row: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_bits` -> bool [n_words]."""
    shifts = jnp.arange(LANE, dtype=_U32)
    bits = (row[:, None] >> shifts[None, :]) & 1
    return bits.reshape(-1).astype(jnp.bool_)


def popcount(row: jax.Array) -> jax.Array:
    """Number of set word-bits in a packed row (e.g. matched PUs in TAG)."""
    return jax.lax.population_count(row).astype(jnp.int32).sum()


# ---------------------------------------------------------------------------
# the three silicon primitives: COMPARE, tagged WRITE, broadcast WRITE
# Each is ONE AP cycle regardless of the number of active columns (columns act
# in parallel on the match line / word line) — cycle cost lives in the engine.
# ---------------------------------------------------------------------------

def compare(planes: jax.Array, cols: jax.Array, key: jax.Array,
            tag_in: jax.Array | None = None) -> jax.Array:
    """Match ``key`` against columns ``cols`` of every word -> packed TAG.

    cols : int32[K] column indices (the unmasked columns)
    key  : uint32[K] key bits (0/1) for those columns
    tag_in : optional packed row; if given the result is ANDed into it
             (models compare restricted to previously tagged rows).
    """
    sel = planes[cols]                                    # [K, nl] gather
    keyb = (key.astype(_U32) * FULL)[:, None]             # 0x0 / 0xFFFFFFFF
    eq = ~(sel ^ keyb)                                    # per-bit XNOR
    tag = reduce(jnp.bitwise_and, [eq[i] for i in range(eq.shape[0])])
    if tag_in is not None:
        tag = tag & tag_in
    return tag


def tagged_write(planes: jax.Array, tag: jax.Array, cols: jax.Array,
                 key: jax.Array) -> jax.Array:
    """Parallel write of ``key`` into columns ``cols`` of all tagged words."""
    keyb = (key.astype(_U32) * FULL)[:, None]
    old = planes[cols]
    new = (old & ~tag[None, :]) | (keyb & tag[None, :])
    return planes.at[cols].set(new)


def broadcast_write(planes: jax.Array, cols: jax.Array, key: jax.Array) -> jax.Array:
    """Write ``key`` into columns ``cols`` of ALL words (tag = all ones)."""
    keyb = (key.astype(_U32) * FULL)[:, None]
    nl = planes.shape[1]
    return planes.at[cols].set(jnp.broadcast_to(keyb, (cols.shape[0], nl)))


def write_column_bits(planes: jax.Array, col: int, bits: jax.Array) -> jax.Array:
    """Host-side load of a full per-word bit column (data load, not an AP op)."""
    return planes.at[col].set(bits)


@partial(jax.jit, static_argnames=("start",))
def set_field_planes(planes: jax.Array, sub: jax.Array,
                     start: int) -> jax.Array:
    """Store packed field planes ``sub`` at bit-column ``start`` (jitted:
    an un-jitted scatter dispatch costs ~1 ms per field load on CPU)."""
    return jax.lax.dynamic_update_slice(planes, sub, (start, 0))


# ---------------------------------------------------------------------------
# Field: a named range of bit-columns.  Shifts are free on the AP — "shift is
# implemented by activating different bit columns" (§2.2) — so a shifted view
# is just a new Field with offset column indices.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Field:
    start: int
    width: int

    def col(self, i: int) -> int:
        if not 0 <= i < self.width:
            raise IndexError(f"bit {i} out of field width {self.width}")
        return self.start + i

    def cols(self) -> list[int]:
        return list(range(self.start, self.start + self.width))

    def bit(self, i: int) -> "Field":
        return Field(self.col(i), 1)

    def slice(self, lo: int, width: int) -> "Field":
        if lo + width > self.width:
            raise IndexError("slice outside field")
        return Field(self.start + lo, width)

    def shifted(self, k: int) -> "Field":
        """View of this field shifted left by k columns (zero-cost AP shift)."""
        return Field(self.start + k, self.width)


class FieldAllocator:
    """Trivial bump allocator for bit-columns of the associative word."""

    def __init__(self, n_bits: int):
        self.n_bits = n_bits
        self._next = 0

    def alloc(self, width: int, name: str = "") -> Field:
        if self._next + width > self.n_bits:
            raise MemoryError(
                f"associative word overflow allocating {width} cols for {name!r}: "
                f"{self._next}/{self.n_bits} used")
        f = Field(self._next, width)
        self._next += width
        return f

    @property
    def used(self) -> int:
        return self._next
