"""Geometric multigrid for the face-conductance thermal operator.

The steady-state system ``G T = P`` and every implicit transient step
``(C/dt + theta G) delta = r`` share one operator family: a 7-point
face-conductance stencil (``thermal.apply_operator_fields``) plus an
optional extra diagonal (the capacity term).  Jacobi-PCG solves them in
O(n) iterations per digit — the cost wall every sweep scenario bottoms
out in (ISSUE 4).  This module adds the asymptotically right tool:

**Hierarchy.**  Levels coarsen the *lateral* grid only (2x2 cell
aggregation; the few-layer stack axis stays resolved — classic
semi-coarsening, correct here because lateral sheet conductance
dominates the thinned-die vertical coupling at fine grids).  The coarse
operator is the **Galerkin product** ``R G P`` with piecewise-constant
prolongation ``P`` (inject the coarse value into its 2x2 fine cells) and
restriction ``R = P^T`` (sum the 2x2 residuals).  For a conductance
stencil that product stays *in the family*: the coarse face conductance
is the sum of the fine faces crossing the coarse interface, the coarse
diagonal terms (package lump, capacity) are 2x2 sums — so one stencil
implementation serves every level, and void margin cells coarsen to
void coarse cells for free (zero faces stay zero).  The identity
``G_c v = R (G (P v))`` is pinned by ``tests/test_multigrid.py``; the
*deployed* hierarchy additionally halves the lateral sums back to the
true 2h spec-built stencil (see :func:`coarsen`).

**Smoother.**  Red-black *z-line* Gauss-Seidel: cells are colored by
in-plane parity ``(y + x) % 2`` (all lateral neighbors of a red cell are
black), and each half-sweep solves every colored column's vertical
tridiagonal system *exactly* (Thomas; the stack axis is 5-9 layers, so
the solve is a short unrolled loop).  Line relaxation in z keeps the
smoother robust when the vertical coupling grows relative to the
aggregated lateral faces on coarse levels.  The Pallas kernel path lives
in ``kernels/mg_smooth`` (this module is its jnp oracle).

**Cycles.**  ``v_cycle`` is the symmetric V(nu1, nu2) cycle: pre-smooth
red->black, post-smooth black->red, and an exact (dense-Cholesky)
coarsest-level solve — required because the stack couples to ambient
only through the tiny package conductance, leaving a near-null global
mode that relaxation alone cannot contract.  The cycle is therefore a
fixed SPD linear operator usable two ways:

- ``mg_solve_fields`` — stand-alone V-cycle iteration to a residual
  tolerance (``mg_fixed``/``iterate_fixed``: fixed cycle count,
  scannable/vmappable — the implicit transient stepper's inner solve);
- ``mgcg_solve_fields`` — V-cycle-preconditioned CG (``thermal.pcg``
  accepts a callable preconditioner) for the steady solve.

``thermal.steady_state(solver=...)`` selects between "pcg", "mg" and
"mgcg"; DESIGN.md §7.5 documents the selection guidance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import obs

#: stop coarsening below this in-plane size (the coarsest level is
#: relaxed with palindromic red-black line sweeps, which is exact in the
#: limit of a 1x1 plane and near-exact at 4x4)
MIN_COARSE_N = 4

#: red-black line sweeps on the coarsest level (palindromic: k pairs
#: red->black then k pairs black->red, keeping the cycle symmetric)
N_COARSE_SWEEPS = 8

_FACES = ("gx_lf", "gx_rt", "gy_up", "gy_dn", "gz_up", "gz_dn", "g_pkg")


def operator(v: jax.Array, F: dict, d_extra) -> jax.Array:
    """(G + diag(d_extra)) @ v for one level's face fields."""
    from repro.core.thermal import apply_operator_fields
    return apply_operator_fields(v, F) + d_extra * v


def diagonal(F: dict, d_extra) -> jax.Array:
    """Exact diagonal of the level operator (0-safe for void cells)."""
    d = (F["gx_lf"] + F["gx_rt"] + F["gy_up"] + F["gy_dn"]
         + F["gz_up"] + F["gz_dn"] + F["g_pkg"] + d_extra)
    return d


# ---------------------------------------------------------------------------
# Galerkin (aggregation) coarsening — stays in the face-conductance family
# ---------------------------------------------------------------------------

def coarsen(F: dict, d_extra: jax.Array, rescale_lateral: bool = False
            ) -> tuple[dict, jax.Array]:
    """One 2x2 lateral aggregation level: ``(R G P, R d_extra P)``.

    Coarse face = sum of the fine faces crossing the coarse interface;
    coarse diagonal couplings (vertical, package, extra) = 2x2 sums.
    Interior fine faces cancel in the Galerkin product (they couple
    cells of the same aggregate), so they simply do not appear.

    ``rescale_lateral`` halves the lateral face sums afterwards.  The
    raw Galerkin product over-stiffens lateral coupling: summing the
    two crossing faces gives ``2 g`` where the true 2h discretization of
    the same sheet conductance (``k t``, scale-invariant in-plane) is
    ``g`` — the classic factor-2 defect of piecewise-constant
    aggregation in 2D.  Halving recovers the spec-built coarse-grid
    stencil exactly (vertical and package terms scale with cell AREA,
    so their 4x sums are already correct), which is what turns the
    V-cycle from a ~0.87/cycle crawl into a ~0.2/cycle solver
    (DESIGN.md §7.5).  ``build_levels`` applies it by default;
    ``tests/test_multigrid.py`` pins the raw product against the
    explicit ``R G P`` identity.
    """
    L, NY, NX = F["g_pkg"].shape
    if NY % 2 or NX % 2:
        raise ValueError(f"cannot 2x2-coarsen odd grid {NY}x{NX}")

    def sum4(x):                       # all four cells of the aggregate
        return x.reshape(L, NY // 2, 2, NX // 2, 2).sum(axis=(2, 4))

    def sum_rows(x):                   # row pairs at a fixed fine column
        return x.reshape(L, NY // 2, 2, x.shape[2]).sum(axis=2)

    def sum_cols(x):                   # column pairs at a fixed fine row
        return x.reshape(L, x.shape[1], NX // 2, 2).sum(axis=3)

    lat = 0.5 if rescale_lateral else 1.0
    Fc = {
        # left faces of the aggregate's left column (fine x = 2X)
        "gx_lf": lat * sum_rows(F["gx_lf"][:, :, 0::2]),
        # right faces of the right column (fine x = 2X + 1)
        "gx_rt": lat * sum_rows(F["gx_rt"][:, :, 1::2]),
        # top faces of the top row (fine y = 2Y)
        "gy_up": lat * sum_cols(F["gy_up"][:, 0::2, :]),
        # bottom faces of the bottom row (fine y = 2Y + 1)
        "gy_dn": lat * sum_cols(F["gy_dn"][:, 1::2, :]),
        "gz_up": sum4(F["gz_up"]),
        "gz_dn": sum4(F["gz_dn"]),
        "g_pkg": sum4(F["g_pkg"]),
    }
    return Fc, sum4(d_extra)


def restrict(r: jax.Array) -> jax.Array:
    """R = P^T: sum each 2x2 fine block into its coarse cell."""
    L, NY, NX = r.shape
    return r.reshape(L, NY // 2, 2, NX // 2, 2).sum(axis=(2, 4))


def prolong(e: jax.Array) -> jax.Array:
    """P: inject each coarse value into its 2x2 fine cells."""
    return jnp.repeat(jnp.repeat(e, 2, axis=1), 2, axis=2)


def build_levels(F: dict, d_extra, min_n: int = MIN_COARSE_N) -> list:
    """The hierarchy [(F_0, d_0), (F_1, d_1), ...], finest first.

    Every level is the rescaled Galerkin coarsening of the one above
    (see :func:`coarsen`), so every level stays a spec-built
    face-conductance stencil.  Coarsening stops when either in-plane
    dimension goes odd or drops below ``min_n``.  Shapes are static, so
    the list is built at trace time and the recursion over it unrolls
    into one jitted program.
    """
    d_extra = jnp.broadcast_to(jnp.asarray(d_extra, jnp.float32),
                               F["g_pkg"].shape)
    levels = [(F, d_extra)]
    while True:
        _, ny, nx = levels[-1][0]["g_pkg"].shape
        if ny % 2 or nx % 2 or min(ny, nx) // 2 < min_n:
            # hierarchy construction happens at trace time when called
            # from a jitted driver, so these count builds-per-compile
            obs.count("mg/hierarchies_built")
            obs.count(f"mg/hierarchies_built[levels={len(levels)}]")
            return levels
        levels.append(coarsen(*levels[-1], rescale_lateral=True))


# ---------------------------------------------------------------------------
# red-black z-line Gauss-Seidel smoother (jnp oracle; kernels/mg_smooth
# mirrors this exactly)
# ---------------------------------------------------------------------------

def line_solve(rhs: jax.Array, F: dict, d_extra) -> jax.Array:
    """Solve every (y, x) column's vertical tridiagonal system exactly.

    System per column:  diag[l] u[l] - gz_up[l] u[l-1] - gz_dn[l] u[l+1]
    = rhs[l]  — the operator restricted to the column with lateral
    neighbors frozen.  Void cells (all-zero rows over the margin ring)
    reduce to ``1 * u = 0``.  Thomas algorithm, unrolled over the small
    static layer count.
    """
    L = rhs.shape[0]
    d = diagonal(F, d_extra)
    d = jnp.where(d > 0, d, 1.0)
    lo = -F["gz_up"]            # coupling to layer l-1 (zero at l = 0)
    up = -F["gz_dn"]            # coupling to layer l+1 (zero at l = L-1)

    # forward elimination
    cp = [up[0] / d[0]]
    dp = [rhs[0] / d[0]]
    for l in range(1, L):
        denom = d[l] - lo[l] * cp[-1]
        denom = jnp.where(jnp.abs(denom) > 0, denom, 1.0)
        cp.append(up[l] / denom)
        dp.append((rhs[l] - lo[l] * dp[-1]) / denom)

    # back substitution
    u = [dp[-1]]
    for l in range(L - 2, -1, -1):
        u.append(dp[l] - cp[l] * u[-1])
    return jnp.stack(u[::-1], axis=0)


def _parity(ny: int, nx: int) -> jax.Array:
    yy = jax.lax.broadcasted_iota(jnp.int32, (ny, nx), 0)
    xx = jax.lax.broadcasted_iota(jnp.int32, (ny, nx), 1)
    return (yy + xx) % 2


def rb_line_sweep(T: jax.Array, b: jax.Array, F: dict, d_extra,
                  color: int) -> jax.Array:
    """One half-sweep: update the columns whose in-plane parity is
    ``color`` by their exact z-line solve, lateral neighbors frozen at
    the current iterate (their parity is ``1 - color``, so red->black is
    a true Gauss-Seidel ordering)."""
    t_lf = jnp.concatenate([T[:, :, :1], T[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([T[:, :, 1:], T[:, :, -1:]], axis=2)
    t_up = jnp.concatenate([T[:, :1], T[:, :-1]], axis=1)
    t_dn = jnp.concatenate([T[:, 1:], T[:, -1:]], axis=1)
    lateral = (F["gx_lf"] * t_lf + F["gx_rt"] * t_rt
               + F["gy_up"] * t_up + F["gy_dn"] * t_dn)
    u = line_solve(b + lateral, F, d_extra)
    mask = (_parity(T.shape[1], T.shape[2]) == color)[None]
    return jnp.where(mask, u, T)


def _smooth(T, b, F, d_extra, colors, sweep_fn):
    for c in colors:
        T = sweep_fn(T, b, F, d_extra, c)
    return T


# ---------------------------------------------------------------------------
# the symmetric V-cycle
# ---------------------------------------------------------------------------

def coarse_factorization(levels: list):
    """Dense Cholesky factorization of the coarsest-level operator.

    Relaxation alone cannot resolve the stack's near-null global mode
    (the whole grid couples to ambient only through the tiny package
    conductance, so the constant vector has an eigenvalue orders of
    magnitude below the rest) — a V-cycle whose coarsest level merely
    smooths stalls on exactly that mode.  The coarsest system is a few
    hundred unknowns, so we materialize it by applying the operator to
    the identity, symmetrically Jacobi-scale it for float32 conditioning,
    pin void rows to identity, and Cholesky-factor ONCE per hierarchy;
    every cycle then solves the coarsest level exactly (a symmetric
    operation, so the preconditioner property is preserved).
    """
    F, d_extra = levels[-1]
    L, ny, nx = F["g_pkg"].shape
    n = L * ny * nx
    eye = jnp.eye(n, dtype=jnp.float32)
    cols = jax.vmap(
        lambda v: operator(v.reshape(L, ny, nx), F, d_extra).ravel())(eye)
    A = cols.T
    d = jnp.diagonal(A)
    void = d <= 0
    A = A + jnp.diag(jnp.where(void, 1.0, 0.0))     # void cells: u = 0
    s = 1.0 / jnp.sqrt(jnp.where(void, 1.0, d))     # Jacobi scaling
    As = s[:, None] * A * s[None, :]
    return jax.scipy.linalg.cho_factor(As), s


def coarse_solve_fn(levels: list):
    """Exact coarsest-level solve closure (see
    :func:`coarse_factorization`)."""
    cf, s = coarse_factorization(levels)
    shape = levels[-1][0]["g_pkg"].shape

    def solve(b):
        y = jax.scipy.linalg.cho_solve(cf, s * b.ravel())
        return (s * y).reshape(shape)

    return solve


def v_cycle(levels: list, b: jax.Array, nu1: int = 1, nu2: int = 1,
            lvl: int = 0, sweep_fn=rb_line_sweep,
            prolong_fn=prolong, coarse_solve=None) -> jax.Array:
    """One V(nu1, nu2) cycle for ``A e = b`` from a zero initial guess.

    Pre-smoothing sweeps red->black, post-smoothing black->red, and the
    coarsest level is solved exactly (``coarse_solve``; falls back to a
    palindromic block of line sweeps when None) — so with the default
    injection prolongation (the restriction's transpose) the cycle, as a
    linear operator on ``b``, is symmetric positive definite and
    therefore a valid CG preconditioner (``mgcg_solve_fields``).
    """
    F, d_extra = levels[lvl]
    T = jnp.zeros_like(b)
    if lvl == len(levels) - 1:
        if coarse_solve is not None:
            return coarse_solve(b)
        for _ in range(N_COARSE_SWEEPS):
            T = _smooth(T, b, F, d_extra, (0, 1), sweep_fn)
        for _ in range(N_COARSE_SWEEPS):
            T = _smooth(T, b, F, d_extra, (1, 0), sweep_fn)
        return T
    for _ in range(nu1):
        T = _smooth(T, b, F, d_extra, (0, 1), sweep_fn)
    r = b - operator(T, F, d_extra)
    e = v_cycle(levels, restrict(r), nu1, nu2, lvl + 1, sweep_fn,
                prolong_fn, coarse_solve)
    T = T + prolong_fn(e)
    for _ in range(nu2):
        T = _smooth(T, b, F, d_extra, (1, 0), sweep_fn)
    return T


def _resolve_sweep(use_pallas: bool):
    if use_pallas:
        from repro.kernels.mg_smooth import ops as _ops
        return _ops.rb_line_sweep
    return rb_line_sweep


# ---------------------------------------------------------------------------
# solver drivers
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_cycles", "nu1", "nu2",
                                   "use_pallas"))
def mg_solve_fields(b: jax.Array, F: dict, d_extra=0.0, tol: float = 1e-8,
                    max_cycles: int = 200, nu1: int = 1, nu2: int = 1,
                    use_pallas: bool = False):
    """Stand-alone V-cycle iteration:  x += V(b - A x)  until the
    residual drops below ``tol * ||b||`` or stops contracting.  The
    TRUE residual is recomputed every cycle, so in float32 it floors
    near machine precision well above a 1e-8 relative target — the
    stagnation guard (< 10% reduction over a cycle) stops the loop at
    that floor instead of spinning to ``max_cycles``.  Returns
    ``(x, n_cycles)``."""
    sweep_fn = _resolve_sweep(use_pallas)
    levels = build_levels(F, d_extra)
    coarse = coarse_solve_fn(levels)
    Fd, dd = levels[0]
    bnorm = jnp.linalg.norm(b)

    def cond(state):
        _, r, it, prev = state
        res = jnp.linalg.norm(r)
        converged = res <= tol * bnorm
        stalled = (it >= 2) & (res > 0.9 * prev)
        # health guard: a non-finite residual means the cycle diverged —
        # every comparison above is False on NaN, so without this the
        # loop would spin NaN through all max_cycles before returning
        return ~(converged | stalled) & jnp.isfinite(res) & (it < max_cycles)

    def body(state):
        x, r, it, _ = state
        e = v_cycle(levels, r, nu1, nu2, sweep_fn=sweep_fn,
                    coarse_solve=coarse)
        x = x + e
        return (x, b - operator(x, Fd, dd), it + 1,
                jnp.linalg.norm(r))

    x, _, it, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros_like(b), b, jnp.int32(0),
                     jnp.float32(jnp.inf)))
    return x, it


def iterate_fixed(levels: list, b: jax.Array, n_cycles: int,
                  nu1: int = 1, nu2: int = 1, sweep_fn=rb_line_sweep,
                  coarse_solve=None) -> jax.Array:
    """Fixed-cycle-count V-cycle iteration (``fori_loop``) on a
    pre-built hierarchy: uniform cost per call, so transient steps scan
    and sweep batches vmap — the MG counterpart of
    :func:`thermal.pcg_fixed`.  Build ``levels`` AND ``coarse_solve``
    once OUTSIDE any scan (``thermal.implicit_lhs_solver`` does) so the
    coarse operators and the coarsest factorization are constants of
    the compiled step."""
    Fd, dd = levels[0]

    def body(_, state):
        x, r = state
        e = v_cycle(levels, r, nu1, nu2, sweep_fn=sweep_fn,
                    coarse_solve=coarse_solve)
        x = x + e
        return x, r - operator(e, Fd, dd)

    x, _ = jax.lax.fori_loop(0, n_cycles, body, (jnp.zeros_like(b), b))
    return x


@partial(jax.jit, static_argnames=("n_cycles", "nu1", "nu2", "use_pallas"))
def mg_fixed(b: jax.Array, F: dict, d_extra=0.0, n_cycles: int = 3,
             nu1: int = 1, nu2: int = 1,
             use_pallas: bool = False) -> jax.Array:
    """Jitted convenience wrapper over :func:`iterate_fixed`."""
    levels = build_levels(F, d_extra)
    return iterate_fixed(levels, b, n_cycles, nu1, nu2,
                         _resolve_sweep(use_pallas),
                         coarse_solve_fn(levels))


@partial(jax.jit, static_argnames=("max_iter", "nu1", "nu2", "use_pallas"))
def mgcg_solve_fields(b: jax.Array, F: dict, d_extra=0.0, tol: float = 1e-8,
                      max_iter: int = 500, nu1: int = 1, nu2: int = 1,
                      use_pallas: bool = False):
    """V-cycle-preconditioned CG (the symmetric cycle is SPD, so plain
    PCG theory applies).  Returns ``(x, n_iterations)``."""
    from repro.core.thermal import pcg
    sweep_fn = _resolve_sweep(use_pallas)
    levels = build_levels(F, d_extra)
    coarse = coarse_solve_fn(levels)
    Fd, dd = levels[0]
    A = lambda v: operator(v, Fd, dd)
    Minv = lambda r: v_cycle(levels, r, nu1, nu2, sweep_fn=sweep_fn,
                             coarse_solve=coarse)
    return pcg(A, Minv, b, tol, max_iter)


__all__ = ["coarsen", "restrict", "prolong", "build_levels", "operator",
           "diagonal", "line_solve", "rb_line_sweep", "v_cycle",
           "coarse_factorization", "coarse_solve_fn", "iterate_fixed",
           "mg_solve_fields", "mg_fixed", "mgcg_solve_fields"]
