"""The Associative Processor machine model.

Implements the three silicon operations of the paper's AP (§2.1):

* COMPARE  — key/mask match against all rows, result into TAG (1 cycle)
* WRITE    — parallel write of key into masked columns of all TAGGED rows (1 cycle)
* BWRITE   — broadcast write into masked columns of ALL rows (1 cycle)

plus sequential row read (1 cycle / row, §2.1).

A *pass* = COMPARE cycle followed by WRITE cycle (paper Table 1 footnote).
Arithmetic routines (isa.py / arith.py / apfloat.py) compile to *pass
schedules* — static tables of (compare cols/key, write cols/key) — which this
engine executes in one fused `lax.scan`.

Bookkeeping (exact, not statistical):

* cycles     — host-side Python ints; the pass count is static so this is exact.
* energy     — per-pass matched-row counts are measured on device and folded
               into the paper's per-event energies (Table 3):
               E_cmp  = k_cmp * (p_m * matched + p_mm * (n - matched))
               E_wr   = k_wr  * (1.0 * matched + p_mw * (n - matched))
               normalized to one SRAM-cell write = 1 (§3.2, eq 16).
  This generalizes eq (16): with the adder's 1/8 match probability the
  expectation of our measured count equals the paper's closed form — tested in
  tests/test_paper_models.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane as bp
from repro.core.bitplane import Field, FieldAllocator


def bin_energy_trace(cycles: np.ndarray, energy: np.ndarray,
                     total_cycles: int, n_intervals: int
                     ) -> tuple[float, np.ndarray]:
    """Bin (cycle, energy) events into equal windows over [0, total_cycles].

    ``cycles`` holds 1-based completion cycles.  Energy-conserving: the
    returned bins sum to ``energy.sum()`` exactly.  Shared by
    :meth:`APEngine.power_trace` and ``cosim.trace_from_counters``.
    """
    interval = max(int(total_cycles), 1) / n_intervals
    bins = np.zeros(n_intervals, np.float64)
    cycles = np.asarray(cycles, np.int64)
    if cycles.size:
        idx = np.minimum(((cycles - 1) / interval).astype(np.int64),
                         n_intervals - 1)
        np.add.at(bins, idx, np.asarray(energy, np.float64))
    return interval, bins


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Table 3 of the paper (normalized to SRAM-cell write power = 1)."""
    p_sram_cell_uW: float = 0.5   # absolute anchor: 1 unit = 0.5 uW
    p_m: float = 0.1              # per-bit energy, matched row, compare
    p_mm: float = 0.75            # per-bit energy, mismatched row (line discharge)
    p_mw: float = 0.1             # per-bit energy, miswrite (untagged row)
    p_w: float = 1.0              # per-bit energy, true write (the unit)


PAPER_POWER = PowerParams()


@dataclasses.dataclass
class PassSchedule:
    """A static table of AP passes (compare + tagged write per row).

    Columns are padded (by repetition) to the table-wide max K; ``kc``/``kw``
    keep the true active-column counts for energy accounting.
    """
    cmp_cols: np.ndarray   # int32 [P, Kc]
    cmp_key: np.ndarray    # uint32 [P, Kc]
    w_cols: np.ndarray     # int32 [P, Kw]
    w_key: np.ndarray      # uint32 [P, Kw]
    kc: np.ndarray         # int32 [P]  true compare-column counts
    kw: np.ndarray         # int32 [P]  true write-column counts

    @property
    def n_passes(self) -> int:
        return int(self.cmp_cols.shape[0])

    @staticmethod
    def build(passes: Sequence[tuple[Sequence[int], Sequence[int],
                                     Sequence[int], Sequence[int]]]
              ) -> "PassSchedule":
        """passes: list of (cmp_cols, cmp_key, w_cols, w_key) per pass."""
        if not passes:
            raise ValueError("empty pass schedule")
        kc = np.array([len(p[0]) for p in passes], np.int32)
        kw = np.array([len(p[2]) for p in passes], np.int32)
        Kc, Kw = int(kc.max()), int(kw.max())

        def pad(vals, K):
            vals = list(vals)
            return vals + [vals[0]] * (K - len(vals))

        cc = np.array([pad(p[0], Kc) for p in passes], np.int32)
        ck = np.array([pad(p[1], Kc) for p in passes], np.uint32)
        wc = np.array([pad(p[2], Kw) for p in passes], np.int32)
        wk = np.array([pad(p[3], Kw) for p in passes], np.uint32)
        return PassSchedule(cc, ck, wc, wk, kc, kw)

    @staticmethod
    def concat(schedules: Sequence["PassSchedule"]) -> "PassSchedule":
        Kc = max(s.cmp_cols.shape[1] for s in schedules)
        Kw = max(s.w_cols.shape[1] for s in schedules)

        def padcat(arrs, K):
            out = []
            for a in arrs:
                if a.shape[1] < K:
                    a = np.concatenate(
                        [a, np.repeat(a[:, :1], K - a.shape[1], axis=1)], axis=1)
                out.append(a)
            return np.concatenate(out, axis=0)

        return PassSchedule(
            padcat([s.cmp_cols for s in schedules], Kc),
            padcat([s.cmp_key for s in schedules], Kc),
            padcat([s.w_cols for s in schedules], Kw),
            padcat([s.w_key for s in schedules], Kw),
            np.concatenate([s.kc for s in schedules]),
            np.concatenate([s.kw for s in schedules]),
        )


@partial(jax.jit, donate_argnums=(0,))
def _run_schedule(planes: jax.Array, cmp_cols, cmp_key, w_cols, w_key):
    """Execute a pass schedule; returns planes and per-pass matched counts."""

    def body(planes, xs):
        cc, ck, wc, wk = xs
        tag = bp.compare(planes, cc, ck)
        matched = jax.lax.population_count(tag).astype(jnp.int32).sum()
        planes = bp.tagged_write(planes, tag, wc, wk)
        return planes, matched

    planes, matched = jax.lax.scan(body, planes, (cmp_cols, cmp_key, w_cols, w_key))
    return planes, matched


class APEngine:
    """One Associative Processing array: n_words PUs x n_bits columns."""

    def __init__(self, n_words: int, n_bits: int = 256,
                 power: PowerParams = PAPER_POWER, collect_stats: bool = True,
                 backend: str = "jnp"):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.n_words = n_words
        self.n_bits = n_bits
        self.power = power
        self.collect_stats = collect_stats
        self.backend = backend
        self.planes = bp.alloc_planes(n_bits, n_words)
        self.tag = jnp.zeros(bp.n_lanes(n_words), jnp.uint32)
        self.alloc = FieldAllocator(n_bits)
        self.reset_counters()

    # ----------------------------------------------------------------- state
    def reset_counters(self):
        self.cycles = 0
        self.compare_cycles = 0
        self.write_cycles = 0
        self.bwrite_cycles = 0
        self.read_cycles = 0
        self.energy = 0.0             # normalized (SRAM write = 1)
        self.events = {"match": 0, "mismatch": 0, "write": 0, "miswrite": 0}
        # power trace: per accounted event, the cycle it completed on and its
        # energy (exact same accounting as `energy` — binned by cosim.py)
        self._trace_cycles: list = []     # ints or int64 arrays
        self._trace_energy: list = []     # floats or float64 arrays

    def counters(self) -> dict:
        out = dict(cycles=self.cycles, compare_cycles=self.compare_cycles,
                   write_cycles=self.write_cycles, bwrite_cycles=self.bwrite_cycles,
                   read_cycles=self.read_cycles, energy=self.energy)
        out.update(self.events)
        return out

    # ------------------------------------------------------------- data I/O
    def load(self, field: Field, values) -> None:
        """Host-side load of per-word integer values into a field (not an AP op)."""
        vals = np.asarray(values, np.uint64)
        if vals.shape != (self.n_words,):
            raise ValueError(f"expected ({self.n_words},), got {vals.shape}")
        sub = bp.pack_words(vals, field.width)
        self.planes = self.planes.at[field.start:field.start + field.width].set(sub)

    def read(self, field: Field, signed: bool = False) -> np.ndarray:
        """Host-side readback of a field for all words (charges n read cycles)."""
        self.read_cycles += self.n_words
        self.cycles += self.n_words
        sub = self.planes[field.start:field.start + field.width]
        vals = np.asarray(bp.unpack_words(sub))
        if signed and field.width < 64:
            sign = vals >> (field.width - 1)
            vals = vals.astype(np.int64) - (sign.astype(np.int64) << field.width)
        return vals

    def peek(self, field: Field) -> np.ndarray:
        """Readback WITHOUT charging cycles (debug / test oracle only)."""
        sub = self.planes[field.start:field.start + field.width]
        return np.asarray(bp.unpack_words(sub))

    def read_tagged(self, field: Field) -> tuple[np.ndarray, np.ndarray]:
        """Sequential readout of ``field`` for the currently TAGGED rows.

        Charges 1 read cycle per tagged row (§2.1) — the associative
        "read responders" loop.  Returns (row_indices, values), both
        host numpy, ordered by row index.
        """
        rows = np.where(np.asarray(bp.unpack_bits(self.tag)))[0]
        self.read_cycles += len(rows)
        self.cycles += len(rows)
        sub = self.planes[field.start:field.start + field.width]
        vals = np.asarray(bp.unpack_words(sub))[rows]
        return rows, vals

    # ------------------------------------------------------ silicon ops
    def compare(self, cols: Sequence[int], key: Sequence[int],
                restrict_to_tag: bool = False) -> None:
        """COMPARE: one cycle; TAG <- match(key @ cols) [& TAG]."""
        tag_in = self.tag if restrict_to_tag else None
        self.tag = bp.compare(self.planes, jnp.asarray(cols, jnp.int32),
                              jnp.asarray(key, jnp.uint32), tag_in)
        self.cycles += 1
        self.compare_cycles += 1
        if self.collect_stats:
            matched = int(bp.popcount(self.tag))
            self._account_compare(len(cols), matched)

    def write(self, cols: Sequence[int], key: Sequence[int]) -> None:
        """WRITE: one cycle; key -> masked cols of all TAGGED rows."""
        self.planes = bp.tagged_write(self.planes, self.tag,
                                      jnp.asarray(cols, jnp.int32),
                                      jnp.asarray(key, jnp.uint32))
        self.cycles += 1
        self.write_cycles += 1
        if self.collect_stats:
            matched = int(bp.popcount(self.tag))
            self._account_write(len(cols), matched)

    def bwrite(self, cols: Sequence[int], key: Sequence[int]) -> None:
        """Broadcast write (all rows): one cycle."""
        self.planes = bp.broadcast_write(self.planes, jnp.asarray(cols, jnp.int32),
                                         jnp.asarray(key, jnp.uint32))
        self.cycles += 1
        self.bwrite_cycles += 1
        if self.collect_stats:
            self._account_write(len(cols), self.n_words)

    def clear(self, field: Field) -> None:
        self.bwrite(field.cols(), [0] * field.width)

    def set_bits(self, field: Field, value: int) -> None:
        """Broadcast an immediate constant into a field (1 cycle)."""
        key = [(value >> i) & 1 for i in range(field.width)]
        self.bwrite(field.cols(), key)

    def load_tag_column(self, col: int) -> None:
        """TAG <- column ``col`` (a 1-column compare against key=1)."""
        self.compare([col], [1])

    def tag_count(self) -> int:
        return int(bp.popcount(self.tag))

    # ------------------------------------------------------ fused schedules
    def run(self, sched: PassSchedule) -> None:
        """Execute a static pass schedule as one fused scan on device."""
        if self.backend == "pallas":
            from repro.kernels.ap_match import ops as _ap_ops
            self.planes, matched = _ap_ops.run_schedule(
                self.planes, sched.cmp_cols, sched.cmp_key,
                sched.w_cols, sched.w_key, backend="pallas")
        else:
            self.planes, matched = _run_schedule(
                self.planes,
                jnp.asarray(sched.cmp_cols), jnp.asarray(sched.cmp_key),
                jnp.asarray(sched.w_cols), jnp.asarray(sched.w_key))
        P = sched.n_passes
        self.cycles += 2 * P           # each pass = compare + write
        self.compare_cycles += P
        self.write_cycles += P
        if self.collect_stats:
            m = np.asarray(matched, np.int64)
            n = self.n_words
            kc = sched.kc.astype(np.float64)
            kw = sched.kw.astype(np.float64)
            mf = m.astype(np.float64)
            pw = self.power
            e_pass = kc * (pw.p_m * mf + pw.p_mm * (n - mf)) \
                + kw * (pw.p_w * mf + pw.p_mw * (n - mf))
            self.energy += float(e_pass.sum())
            self._trace_cycles.append(
                self.cycles - 2 * P + 2 * np.arange(1, P + 1, dtype=np.int64))
            self._trace_energy.append(e_pass)
            self.events["match"] += int(m.sum())
            self.events["mismatch"] += int(P) * n - int(m.sum())
            self.events["write"] += int((kw * mf).sum())
            self.events["miswrite"] += int((kw * (n - mf)).sum())

    # ------------------------------------------------------ energy helpers
    def _account_compare(self, k: int, matched: int) -> None:
        n = self.n_words
        pw = self.power
        e = k * (pw.p_m * matched + pw.p_mm * (n - matched))
        self.energy += e
        self._trace_cycles.append(self.cycles)
        self._trace_energy.append(e)
        self.events["match"] += matched
        self.events["mismatch"] += n - matched

    def _account_write(self, k: int, matched: int) -> None:
        n = self.n_words
        pw = self.power
        e = k * (pw.p_w * matched + pw.p_mw * (n - matched))
        self.energy += e
        self._trace_cycles.append(self.cycles)
        self._trace_energy.append(e)
        self.events["write"] += k * matched
        self.events["miswrite"] += k * (n - matched)

    # ------------------------------------------------------ power trace
    def trace_events(self) -> tuple[np.ndarray, np.ndarray]:
        """All accounted energy events so far: (cycle, energy) arrays.

        ``cycle`` is the 1-based cycle each event completed on; ``energy``
        is normalized (SRAM write = 1) and sums exactly to ``self.energy``.
        Cycle spans with no events (host loads, sequential reads) simply
        contribute zero-energy intervals when binned.
        """
        if not self._trace_cycles:
            return (np.zeros(0, np.int64), np.zeros(0, np.float64))
        cyc = np.concatenate([np.atleast_1d(np.asarray(c, np.int64))
                              for c in self._trace_cycles])
        e = np.concatenate([np.atleast_1d(np.asarray(v, np.float64))
                            for v in self._trace_energy])
        return cyc, e

    def power_trace(self, n_intervals: int) -> tuple[float, np.ndarray]:
        """Bin the event trace into ``n_intervals`` equal cycle windows.

        Returns (interval_cycles, energy_per_interval[n_intervals]); the
        bins cover [0, self.cycles] and conserve total energy exactly.
        """
        cyc, e = self.trace_events()
        return bin_energy_trace(cyc, e, self.cycles, n_intervals)

    # ------------------------------------------------------ reporting
    def energy_uJ(self) -> float:
        """Absolute energy in microjoules, using the Table 3 SRAM anchor.

        1 normalized unit = P_sram-cell * 1 cycle.  With the paper's ~0.5 uW
        at ~1 GHz-class operation this is ~0.5 fJ/bit-event; we report
        energy = events * 0.5e-9 uJ (documented anchor, used consistently).
        """
        return self.energy * self.power.p_sram_cell_uW * 1e-3  # 1 ns cycles
