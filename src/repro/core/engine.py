"""The Associative Processor machine model.

Implements the three silicon operations of the paper's AP (§2.1):

* COMPARE  — key/mask match against all rows, result into TAG (1 cycle)
* WRITE    — parallel write of key into masked columns of all TAGGED rows (1 cycle)
* BWRITE   — broadcast write into masked columns of ALL rows (1 cycle)

plus sequential row read (1 cycle / row, §2.1).

A *pass* = COMPARE cycle followed by WRITE cycle (paper Table 1 footnote).
Arithmetic routines (isa.py / arith.py / apfloat.py) compile to *pass
schedules* — static tables of (compare cols/key, write cols/key) — which this
engine executes in one fused `lax.scan`.

Bookkeeping (exact, not statistical):

* cycles     — host-side Python ints; the pass count is static so this is exact.
* energy     — per-pass matched-row counts are measured on device and folded
               into the paper's per-event energies (Table 3):
               E_cmp  = k_cmp * (p_m * matched + p_mm * (n - matched))
               E_wr   = k_wr  * (1.0 * matched + p_mw * (n - matched))
               normalized to one SRAM-cell write = 1 (§3.2, eq 16).
  This generalizes eq (16): with the adder's 1/8 match probability the
  expectation of our measured count equals the paper's closed form — tested in
  tests/test_paper_models.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitplane as bp
from repro.core.bitplane import Field, FieldAllocator


def bin_energy_trace(cycles: np.ndarray, energy: np.ndarray,
                     total_cycles: int, n_intervals: int
                     ) -> tuple[float, np.ndarray]:
    """Bin (cycle, energy) events into equal windows over [0, total_cycles].

    ``cycles`` holds 1-based completion cycles.  Energy-conserving: the
    returned bins sum to ``energy.sum()`` exactly.  Shared by
    :meth:`APEngine.power_trace` and ``cosim.trace_from_counters``.
    """
    interval = max(int(total_cycles), 1) / n_intervals
    bins = np.zeros(n_intervals, np.float64)
    cycles = np.asarray(cycles, np.int64)
    if cycles.size:
        idx = np.minimum(((cycles - 1) / interval).astype(np.int64),
                         n_intervals - 1)
        np.add.at(bins, idx, np.asarray(energy, np.float64))
    return interval, bins


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Table 3 of the paper (normalized to SRAM-cell write power = 1)."""
    p_sram_cell_uW: float = 0.5   # absolute anchor: 1 unit = 0.5 uW
    p_m: float = 0.1              # per-bit energy, matched row, compare
    p_mm: float = 0.75            # per-bit energy, mismatched row (line discharge)
    p_mw: float = 0.1             # per-bit energy, miswrite (untagged row)
    p_w: float = 1.0              # per-bit energy, true write (the unit)


PAPER_POWER = PowerParams()


@dataclasses.dataclass
class PassSchedule:
    """A static table of AP passes (compare + tagged write per row).

    Columns are padded (by repetition) to the table-wide max K; ``kc``/``kw``
    keep the true active-column counts for energy accounting.
    """
    cmp_cols: np.ndarray   # int32 [P, Kc]
    cmp_key: np.ndarray    # uint32 [P, Kc]
    w_cols: np.ndarray     # int32 [P, Kw]
    w_key: np.ndarray      # uint32 [P, Kw]
    kc: np.ndarray         # int32 [P]  true compare-column counts
    kw: np.ndarray         # int32 [P]  true write-column counts

    @property
    def n_passes(self) -> int:
        return int(self.cmp_cols.shape[0])

    @staticmethod
    def build(passes: Sequence[tuple[Sequence[int], Sequence[int],
                                     Sequence[int], Sequence[int]]]
              ) -> "PassSchedule":
        """passes: list of (cmp_cols, cmp_key, w_cols, w_key) per pass."""
        if not passes:
            raise ValueError("empty pass schedule")
        kc = np.array([len(p[0]) for p in passes], np.int32)
        kw = np.array([len(p[2]) for p in passes], np.int32)
        Kc, Kw = int(kc.max()), int(kw.max())

        def pad(vals, K):
            vals = list(vals)
            return vals + [vals[0]] * (K - len(vals))

        cc = np.array([pad(p[0], Kc) for p in passes], np.int32)
        ck = np.array([pad(p[1], Kc) for p in passes], np.uint32)
        wc = np.array([pad(p[2], Kw) for p in passes], np.int32)
        wk = np.array([pad(p[3], Kw) for p in passes], np.uint32)
        return PassSchedule(cc, ck, wc, wk, kc, kw)

    @staticmethod
    def concat(schedules: Sequence["PassSchedule"]) -> "PassSchedule":
        if not schedules:
            raise ValueError("empty schedule list")
        Kc = max(s.cmp_cols.shape[1] for s in schedules)
        Kw = max(s.w_cols.shape[1] for s in schedules)

        def padcat(arrs, K):
            out = []
            for a in arrs:
                if a.shape[1] < K:
                    a = np.concatenate(
                        [a, np.repeat(a[:, :1], K - a.shape[1], axis=1)], axis=1)
                out.append(a)
            return np.concatenate(out, axis=0)

        return PassSchedule(
            padcat([s.cmp_cols for s in schedules], Kc),
            padcat([s.cmp_key for s in schedules], Kc),
            padcat([s.w_cols for s in schedules], Kw),
            padcat([s.w_key for s in schedules], Kw),
            np.concatenate([s.kc for s in schedules]),
            np.concatenate([s.kw for s in schedules]),
        )


# ---------------------------------------------------------------------------
# functional core: APState + pure ops.  Device-resident workload programs
# (workloads/_device.py) thread an APState through lax.scan / lax.while_loop
# bodies so entire data-dependent inner loops run as ONE compiled program —
# per-pass matched counts ride along as scan outputs and cross to the host
# exactly once per workload phase.
# ---------------------------------------------------------------------------

#: APState.counters layout (int32): on-device totals mirroring the host
#: counters an eager replay would accumulate (match = matched-row compare
#: events).  Cross-checked against the host accounting in
#: tests/test_device_workloads.py.
CTR_CYCLES, CTR_COMPARE, CTR_WRITE, CTR_READ, CTR_MATCH = range(5)
N_COUNTERS = 5


@partial(jax.tree_util.register_dataclass,
         data_fields=("planes", "tag", "counters"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class APState:
    """Functional snapshot of one AP array: a pytree that scans/vmaps.

    ``counters`` is a packed int32[N_COUNTERS] accumulator updated on
    device by the ``state_*`` ops, so a device-resident program carries
    its cycle/event totals with it instead of syncing per cycle.
    """
    planes: jax.Array       # uint32[n_bits, n_lanes]
    tag: jax.Array          # uint32[n_lanes]
    counters: jax.Array     # int32[N_COUNTERS]


def state_init(n_bits: int, n_words: int) -> APState:
    return APState(bp.alloc_planes(n_bits, n_words),
                   jnp.zeros(bp.n_lanes(n_words), jnp.uint32),
                   jnp.zeros(N_COUNTERS, jnp.int32))


def select_state(pred, a: APState, b: APState) -> APState:
    """``a`` where pred else ``b`` — masks a whole op inside a scan body
    (the device-program version of an eager host-side branch)."""
    return jax.tree_util.tree_map(partial(jnp.where, pred), a, b)


def state_compare(state: APState, cols, key,
                  restrict_to_tag: bool = False) -> tuple[APState, jax.Array]:
    """COMPARE: one cycle; returns (state', matched responder count)."""
    tag = bp.compare(state.planes, cols, key,
                     state.tag if restrict_to_tag else None)
    matched = bp.popcount(tag)
    ctr = state.counters.at[CTR_CYCLES].add(1).at[CTR_COMPARE].add(1) \
        .at[CTR_MATCH].add(matched)
    return APState(state.planes, tag, ctr), matched


def state_write(state: APState, cols, key) -> tuple[APState, jax.Array]:
    """WRITE into tagged rows: one cycle; returns (state', matched)."""
    planes = bp.tagged_write(state.planes, state.tag, cols, key)
    matched = bp.popcount(state.tag)
    ctr = state.counters.at[CTR_CYCLES].add(1).at[CTR_WRITE].add(1)
    return APState(planes, state.tag, ctr), matched


def state_read_charge(state: APState, n_rows) -> APState:
    """Charge ``n_rows`` sequential read cycles (read_tagged on device:
    the data itself is already host-resident or rides the final ys)."""
    ctr = state.counters.at[CTR_CYCLES].add(n_rows).at[CTR_READ].add(n_rows)
    return APState(state.planes, state.tag, ctr)


def state_run(state: APState, cmp_cols, cmp_key, w_cols,
              w_key) -> tuple[APState, jax.Array]:
    """Run a static pass table functionally; returns (state', matched[P]).

    Mirrors :meth:`APEngine.run`: the TAG register is left untouched
    (the fused scan keeps its per-pass tags internal).
    """
    planes, matched = _run_schedule_body(state.planes, cmp_cols, cmp_key,
                                         w_cols, w_key)
    P = cmp_cols.shape[0]
    ctr = state.counters.at[CTR_CYCLES].add(2 * P).at[CTR_COMPARE].add(P) \
        .at[CTR_WRITE].add(P).at[CTR_MATCH].add(matched.sum())
    return APState(planes, state.tag, ctr), matched


def _run_schedule_body(planes, cmp_cols, cmp_key, w_cols, w_key):
    def body(planes, xs):
        cc, ck, wc, wk = xs
        tag = bp.compare(planes, cc, ck)
        matched = jax.lax.population_count(tag).astype(jnp.int32).sum()
        planes = bp.tagged_write(planes, tag, wc, wk)
        return planes, matched

    return jax.lax.scan(body, planes, (cmp_cols, cmp_key, w_cols, w_key))


@partial(jax.jit, donate_argnums=(0,))
def _run_schedule(planes: jax.Array, cmp_cols, cmp_key, w_cols, w_key):
    """Execute a pass schedule; returns planes and per-pass matched counts.

    The ``obs`` counters increment at TRACE time only — one per compiled
    shape bucket, never per execution — so ``engine/retrace/run_schedule``
    counts distinct compiles (the compiles-once test pins a bucket hit
    against it; per-bucket variants carry the ``[P=..,Kc=..,Kw=..]``
    label suffix)."""
    obs.count("engine/retrace/run_schedule")
    obs.count(f"engine/retrace/run_schedule[P={cmp_cols.shape[0]},"
              f"Kc={cmp_cols.shape[1]},Kw={w_cols.shape[1]}]")
    return _run_schedule_body(planes, cmp_cols, cmp_key, w_cols, w_key)


def _next_pow2(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()


#: jitted broadcast write — the un-jitted scatter dispatch costs ~1 ms
#: per call on CPU, which dominated field clears between fused schedules
@jax.jit
def _broadcast_write_jit(planes, cols, key):
    obs.count("engine/retrace/bwrite")
    return bp.broadcast_write(planes, cols, key)


def bucket_schedule(sched: "PassSchedule"
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad a schedule's (P, Kc, Kw) to power-of-two buckets so nearby
    schedule shapes share one compiled program instead of retracing.

    Extra key columns repeat column 0 — idempotent for both compare
    (re-ANDing an identical XNOR term) and write (re-storing the same
    value).  Extra passes are no-ops: compare column 0 against key 0,
    then write 0 back into column 0 of the rows that matched — the
    planes are unchanged whatever they hold.  Padded passes' matched
    counts are sliced off before accounting, so they contribute zero
    energy and zero events.
    """
    cc, ck, wc, wk = sched.cmp_cols, sched.cmp_key, sched.w_cols, sched.w_key
    P, Kc = cc.shape
    Kw = wc.shape[1]
    if P == 0:
        raise ValueError(
            "empty pass schedule (P=0): nothing to bucket — build "
            "schedules via PassSchedule.build, which rejects empty input")
    Kc2, Kw2, P2 = _next_pow2(Kc), _next_pow2(Kw), _next_pow2(P)

    def pad_cols(a, K2):
        if a.shape[1] == K2:
            return a
        return np.concatenate(
            [a, np.repeat(a[:, :1], K2 - a.shape[1], axis=1)], axis=1)

    cc, ck = pad_cols(cc, Kc2), pad_cols(ck, Kc2)
    wc, wk = pad_cols(wc, Kw2), pad_cols(wk, Kw2)
    if P2 != P:
        cc = np.concatenate([cc, np.zeros((P2 - P, Kc2), cc.dtype)])
        ck = np.concatenate([ck, np.zeros((P2 - P, Kc2), ck.dtype)])
        wc = np.concatenate([wc, np.zeros((P2 - P, Kw2), wc.dtype)])
        wk = np.concatenate([wk, np.zeros((P2 - P, Kw2), wk.dtype)])
    return cc, ck, wc, wk


class APEngine:
    """One Associative Processing array: n_words PUs x n_bits columns."""

    BACKENDS = ("jnp", "pallas", "megakernel", "megakernel_pallas")

    def __init__(self, n_words: int, n_bits: int = 256,
                 power: PowerParams = PAPER_POWER, collect_stats: bool = True,
                 backend: str = "jnp", n_shards: int | None = None):
        if backend not in self.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if n_shards is not None:
            if backend != "megakernel":
                raise ValueError(
                    "n_shards requires backend='megakernel' (lane sharding "
                    "is a megakernel execution mode)")
            if bp.n_lanes(n_words) % n_shards != 0:
                raise ValueError(
                    f"n_lanes={bp.n_lanes(n_words)} not divisible by "
                    f"n_shards={n_shards}; pick n_words a multiple of "
                    f"{bp.LANE * n_shards}")
        self.n_words = n_words
        self.n_bits = n_bits
        self.power = power
        self.collect_stats = collect_stats
        self.backend = backend
        self.n_shards = n_shards
        self.planes = bp.alloc_planes(n_bits, n_words)
        self.tag = jnp.zeros(bp.n_lanes(n_words), jnp.uint32)
        self.alloc = FieldAllocator(n_bits)
        self.reset_counters()

    @property
    def mesh(self):
        """The 1D 'lanes' device mesh when sharded, else None (cached
        per shard count so jitted sharded runners are reused)."""
        if self.n_shards is None:
            return None
        from repro.parallel.sharding import ap_mesh
        return ap_mesh(self.n_shards)

    # ----------------------------------------------------------------- state
    def reset_counters(self):
        self.cycles = 0
        self.compare_cycles = 0
        self.write_cycles = 0
        self.bwrite_cycles = 0
        self.read_cycles = 0
        self.energy = 0.0             # normalized (SRAM write = 1)
        self.events = {"match": 0, "mismatch": 0, "write": 0, "miswrite": 0}
        # power trace: per accounted event, the cycle it completed on and its
        # energy (exact same accounting as `energy` — binned by cosim.py)
        self._trace_cycles: list = []     # ints or int64 arrays
        self._trace_energy: list = []     # floats or float64 arrays

    def counters(self) -> dict:
        out = dict(cycles=self.cycles, compare_cycles=self.compare_cycles,
                   write_cycles=self.write_cycles, bwrite_cycles=self.bwrite_cycles,
                   read_cycles=self.read_cycles, energy=self.energy)
        out.update(self.events)
        return out

    # ------------------------------------------------------------- data I/O
    def load(self, field: Field, values) -> None:
        """Host-side load of per-word integer values into a field (not an AP op)."""
        if field.width > 64:
            raise ValueError(
                f"cannot load a {field.width}-bit field from uint64 host "
                f"words (max 64); split the value across fields")
        vals = np.asarray(values, np.uint64)
        if vals.shape != (self.n_words,):
            raise ValueError(f"expected ({self.n_words},), got {vals.shape}")
        sub = bp.pack_words(vals, field.width)
        self.planes = bp.set_field_planes(self.planes, sub, field.start)

    def read(self, field: Field, signed: bool = False) -> np.ndarray:
        """Host-side readback of a field for all words (charges n read cycles)."""
        self.charge_read(self.n_words)
        sub = self.planes[field.start:field.start + field.width]
        vals = np.asarray(bp.unpack_words(sub))
        if signed and field.width < 64:
            sign = vals >> (field.width - 1)
            vals = vals.astype(np.int64) - (sign.astype(np.int64) << field.width)
        return vals

    def peek(self, field: Field) -> np.ndarray:
        """Readback WITHOUT charging cycles (debug / test oracle only)."""
        sub = self.planes[field.start:field.start + field.width]
        return np.asarray(bp.unpack_words(sub))

    def read_tagged(self, field: Field) -> tuple[np.ndarray, np.ndarray]:
        """Sequential readout of ``field`` for the currently TAGGED rows.

        Charges 1 read cycle per tagged row (§2.1) — the associative
        "read responders" loop.  Returns (row_indices, values), both
        host numpy, ordered by row index.
        """
        rows = np.where(np.asarray(bp.unpack_bits(self.tag)))[0]
        self.charge_read(len(rows))
        sub = self.planes[field.start:field.start + field.width]
        vals = np.asarray(bp.unpack_words(sub))[rows]
        return rows, vals

    # ------------------------------------------------------ silicon ops
    def compare(self, cols: Sequence[int], key: Sequence[int],
                restrict_to_tag: bool = False) -> None:
        """COMPARE: one cycle; TAG <- match(key @ cols) [& TAG].

        Eager (per-cycle host sync when stats are on) — the oracle path.
        Data-dependent inner loops should run device-resident instead
        (``workloads/_device.py``) and replay through ``charge_*``.
        """
        tag_in = self.tag if restrict_to_tag else None
        self.tag = bp.compare(self.planes, jnp.asarray(cols, jnp.int32),
                              jnp.asarray(key, jnp.uint32), tag_in)
        matched = int(bp.popcount(self.tag)) if self.collect_stats else 0
        self.charge_compare(len(cols), matched)

    def write(self, cols: Sequence[int], key: Sequence[int]) -> None:
        """WRITE: one cycle; key -> masked cols of all TAGGED rows."""
        self.planes = bp.tagged_write(self.planes, self.tag,
                                      jnp.asarray(cols, jnp.int32),
                                      jnp.asarray(key, jnp.uint32))
        matched = int(bp.popcount(self.tag)) if self.collect_stats else 0
        self.charge_write(len(cols), matched)

    def bwrite(self, cols: Sequence[int], key: Sequence[int]) -> None:
        """Broadcast write (all rows): one cycle."""
        self.planes = _broadcast_write_jit(
            self.planes, jnp.asarray(cols, jnp.int32),
            jnp.asarray(key, jnp.uint32))
        self.cycles += 1
        self.bwrite_cycles += 1
        if self.collect_stats:
            self._account_write(len(cols), self.n_words)

    # ----------------------------------------- accounting without executing
    # Device-resident programs compute per-pass matched counts on device,
    # transfer them ONCE per workload phase, and replay them through these
    # chargers — producing cycle/energy/event/trace accounting bit-identical
    # to the eager per-cycle path (tests/test_device_workloads.py).

    def charge_compare(self, k: int, matched: int) -> None:
        """Account one COMPARE cycle (k active columns, matched rows)."""
        self.cycles += 1
        self.compare_cycles += 1
        if self.collect_stats:
            self._account_compare(int(k), int(matched))

    def charge_write(self, k: int, matched: int) -> None:
        """Account one tagged-WRITE cycle (k active columns, matched rows)."""
        self.cycles += 1
        self.write_cycles += 1
        if self.collect_stats:
            self._account_write(int(k), int(matched))

    def charge_read(self, n_rows: int) -> None:
        """Account ``n_rows`` sequential read cycles (1 cycle/row, §2.1)."""
        self.read_cycles += int(n_rows)
        self.cycles += int(n_rows)

    def charge_run(self, sched: PassSchedule, matched) -> None:
        """Account a full pass schedule from its per-pass matched counts."""
        P = sched.n_passes
        self.cycles += 2 * P           # each pass = compare + write
        self.compare_cycles += P
        self.write_cycles += P
        if self.collect_stats:
            m = np.asarray(matched, np.int64)
            n = self.n_words
            kc = sched.kc.astype(np.float64)
            kw = sched.kw.astype(np.float64)
            mf = m.astype(np.float64)
            pw = self.power
            e_pass = kc * (pw.p_m * mf + pw.p_mm * (n - mf)) \
                + kw * (pw.p_w * mf + pw.p_mw * (n - mf))
            self.energy += float(e_pass.sum())
            self._trace_cycles.append(
                self.cycles - 2 * P + 2 * np.arange(1, P + 1, dtype=np.int64))
            self._trace_energy.append(e_pass)
            self.events["match"] += int(m.sum())
            self.events["mismatch"] += int(P) * n - int(m.sum())
            self.events["write"] += int((kw * mf).sum())
            self.events["miswrite"] += int((kw * (n - mf)).sum())

    def charge_bulk(self, *, cycles: int = 0, compare_cycles: int = 0,
                    write_cycles: int = 0, read_cycles: int = 0,
                    energy_terms=None, trace_cycles=None, trace_energy=None,
                    match: int = 0, mismatch: int = 0, write: int = 0,
                    miswrite: int = 0) -> None:
        """Fold a precomputed bulk replay block into the accounting.

        The vectorized counterpart of a ``charge_*`` call sequence
        (megakernel replay uses it to retire thousands of events in one
        call).  Bit-identity contract the callers uphold and the
        property harness enforces:

        * ``energy_terms`` (float64[n]) lists the scalar values the
          equivalent charge sequence would have added to ``energy``, in
          order — one term per scalar event, one PRE-SUMMED term per
          ``charge_run`` chunk (``np.sum`` is pairwise, so chunk sums
          must be taken per chunk, never globally).  The fold here is a
          seeded ``np.cumsum``, which accumulates float64 strictly
          sequentially — identical to the scalar ``+=`` loop.
        * ``trace_cycles``/``trace_energy`` are the absolute-cycle /
          per-event energy arrays in eager append order; they land as
          ONE trace chunk, which concatenates to the same flat arrays.
        * counter/event deltas are exact ints.
        """
        self.cycles += int(cycles)
        self.compare_cycles += int(compare_cycles)
        self.write_cycles += int(write_cycles)
        self.read_cycles += int(read_cycles)
        if not self.collect_stats:
            return
        if energy_terms is not None and len(energy_terms):
            self.energy = float(np.cumsum(np.concatenate(
                [[self.energy], np.asarray(energy_terms, np.float64)]))[-1])
        if trace_cycles is not None and len(trace_cycles):
            self._trace_cycles.append(np.asarray(trace_cycles, np.int64))
            self._trace_energy.append(np.asarray(trace_energy, np.float64))
        self.events["match"] += int(match)
        self.events["mismatch"] += int(mismatch)
        self.events["write"] += int(write)
        self.events["miswrite"] += int(miswrite)

    def clear(self, field: Field) -> None:
        self.bwrite(field.cols(), [0] * field.width)

    def set_bits(self, field: Field, value: int) -> None:
        """Broadcast an immediate constant into a field (1 cycle)."""
        key = [(value >> i) & 1 for i in range(field.width)]
        self.bwrite(field.cols(), key)

    def load_tag_column(self, col: int) -> None:
        """TAG <- column ``col`` (a 1-column compare against key=1)."""
        self.compare([col], [1])

    def tag_count(self) -> int:
        return int(bp.popcount(self.tag))

    # ------------------------------------------------------ fused schedules
    def run(self, sched: PassSchedule) -> None:
        """Execute a static pass schedule as one fused scan on device.

        The schedule shape is padded to a power-of-two bucket
        (:func:`bucket_schedule`) so two schedules of nearby shapes share
        one compiled program; the padded no-op passes' matched counts are
        sliced off before accounting.
        """
        P = sched.n_passes
        cc, ck, wc, wk = bucket_schedule(sched)
        if self.backend == "pallas":
            from repro.kernels.ap_match import ops as _ap_ops
            self.planes, matched = _ap_ops.run_schedule(
                self.planes, cc, ck, wc, wk, backend="pallas")
        elif self.backend in ("megakernel", "megakernel_pallas"):
            from repro.kernels.ap_megakernel import OpGroup, ops as _mk_ops
            mk_backend = ("pallas" if self.backend == "megakernel_pallas"
                          else "jnp")
            self.planes, self.tag, matched = _mk_ops.run_group(
                self.planes, self.tag,
                OpGroup.from_schedule(cc, ck, wc, wk),
                backend=mk_backend, mesh=self.mesh)
        else:
            self.planes, matched = _run_schedule(
                self.planes, jnp.asarray(cc), jnp.asarray(ck),
                jnp.asarray(wc), jnp.asarray(wk))
        self.charge_run(sched, matched[:P])

    # -------------------------------------------------- functional bridge
    def state(self) -> APState:
        """Snapshot (planes, tag, zeroed counters) for a device program."""
        return APState(self.planes, self.tag,
                       jnp.zeros(N_COUNTERS, jnp.int32))

    def adopt(self, state: APState) -> None:
        """Adopt a device program's final array state.

        Counters are NOT folded in: the caller replays its per-pass
        matched counts through the ``charge_*`` methods so energy/event/
        trace accounting stays event-exact (the device-side
        ``state.counters`` exist to cross-check those replays).
        """
        self.planes = state.planes
        self.tag = state.tag

    # ------------------------------------------------------ energy helpers
    def _account_compare(self, k: int, matched: int) -> None:
        n = self.n_words
        pw = self.power
        e = k * (pw.p_m * matched + pw.p_mm * (n - matched))
        self.energy += e
        self._trace_cycles.append(self.cycles)
        self._trace_energy.append(e)
        self.events["match"] += matched
        self.events["mismatch"] += n - matched

    def _account_write(self, k: int, matched: int) -> None:
        n = self.n_words
        pw = self.power
        e = k * (pw.p_w * matched + pw.p_mw * (n - matched))
        self.energy += e
        self._trace_cycles.append(self.cycles)
        self._trace_energy.append(e)
        self.events["write"] += k * matched
        self.events["miswrite"] += k * (n - matched)

    # ------------------------------------------------------ power trace
    def trace_events(self) -> tuple[np.ndarray, np.ndarray]:
        """All accounted energy events so far: (cycle, energy) arrays.

        ``cycle`` is the 1-based cycle each event completed on; ``energy``
        is normalized (SRAM write = 1) and sums exactly to ``self.energy``.
        Cycle spans with no events (host loads, sequential reads) simply
        contribute zero-energy intervals when binned.
        """
        if not self._trace_cycles:
            return (np.zeros(0, np.int64), np.zeros(0, np.float64))
        cyc = np.concatenate([np.atleast_1d(np.asarray(c, np.int64))
                              for c in self._trace_cycles])
        e = np.concatenate([np.atleast_1d(np.asarray(v, np.float64))
                            for v in self._trace_energy])
        return cyc, e

    def power_trace(self, n_intervals: int) -> tuple[float, np.ndarray]:
        """Bin the event trace into ``n_intervals`` equal cycle windows.

        Returns (interval_cycles, energy_per_interval[n_intervals]); the
        bins cover [0, self.cycles] and conserve total energy exactly.
        """
        cyc, e = self.trace_events()
        return bin_energy_trace(cyc, e, self.cycles, n_intervals)

    # ------------------------------------------------------ reporting
    def energy_uJ(self) -> float:
        """Absolute energy in microjoules, using the Table 3 SRAM anchor.

        1 normalized unit = P_sram-cell * 1 cycle.  With the paper's ~0.5 uW
        at ~1 GHz-class operation this is ~0.5 fJ/bit-event; we report
        energy = events * 0.5e-9 uJ (documented anchor, used consistently).
        """
        return self.energy * self.power.p_sram_cell_uW * 1e-3  # 1 ns cycles
