"""HotSpot-equivalent 3D RC thermal model of the paper's die stack (Fig 9).

Stack (top -> bottom):  Si_4 | Si_3 | Si_2 | Si_1 | TIM | heat spreader |
heat sink -> convection to ambient.

Discretization: the four silicon layers AND the copper heat spreader are a
regular ny x nx grid over the die footprint (HotSpot's grid mode resolves
the spreader laterally too — essential: lateral spreading through ~1 mm of
copper is what flattens small hot dies; a lumped spreader misses it and
wildly overestimates both the peak and the span of the 2.3 mm SIMD die).
Below the spreader a lumped path models the sink:

    R_pkg = R_spread(spreader->sink) + R_cond(sink) + R_convec

applied as a uniform per-cell conductance to ambient.  Each layer has its
own lateral sheet conductance g_lat[l] = k_l * t_l and each interface its
own vertical conductance (die-bond between Si layers; TIM between Si_1 and
the spreader).

The steady-state system  G T = P  is SPD and solved matrix-free with
Jacobi-preconditioned CG; the stencil application is the Pallas kernel
``kernels/thermal_stencil`` (the jnp implementation here is the oracle).
Constants are ONE documented set used for both the AP and the SIMD dies
(DESIGN.md §7.2) so the comparison is apples-to-apples, as in the paper.

Heterogeneous stacks: every operator here is built from a declarative
``repro.stack.spec.StackSpec`` (ordered dies + interfaces, spreader last).
The legacy ``StackParams`` shorthand is converted through
``spec_from_params`` — ``PAPER_STACK`` is now just the named spec
``PAPER_SPEC`` and reproduces the pre-refactor numbers exactly; DRAM-on-
logic stacks come from ``repro.stack.spec.dram_on_logic``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.constants import AMBIENT_C
from repro.stack.spec import (PAPER_SPEC, PAPER_STACK, StackParams,
                              StackSpec, spec_from_params)

__all__ = [  # re-exports kept for callers of the pre-refactor module
    "AMBIENT_C", "PAPER_SPEC", "PAPER_STACK", "StackParams", "StackSpec",
    "spec_from_params", "Grid", "package_resistance", "steady_state",
    "steady_state_stats", "SOLVERS", "HEALTH_RTOL", "fallback_chain",
    "apply_operator", "apply_operator_fields", "pcg", "pcg_fixed",
    "transient", "transient_solve", "explicit_dt", "transient_implicit",
    "transient_implicit_fields", "transient_solve_implicit",
]

#: selectable linear-solver backends for the fields operator: Jacobi-PCG
#: (the original), stand-alone geometric multigrid V-cycles, and
#: V-cycle-preconditioned CG (see ``core/multigrid.py``, DESIGN.md §7.5)
SOLVERS = ("pcg", "mg", "mgcg")

#: TRUE-relative-residual bar for "this steady solve is healthy".
#: Deliberately loose: converged solves stop at the float32 residual
#: floor rather than their nominal tol, and that floor grows with the
#: grid (measured ~6e-3 for mgcg on the 256^2 shoot-out stack), so the
#: bar must sit well above it — yet orders of magnitude below any
#: diverged (non-finite) or genuinely stagnated solve, which is what
#: the fallback chain catches.
HEALTH_RTOL = 2e-2


def package_resistance(die_area_m2: float, p: StackParams = PAPER_STACK
                       ) -> float:
    """Lumped R from the spreader underside to ambient [K/W].

    Thin compatibility wrapper over
    :meth:`repro.stack.spec.StackSpec.package_resistance`.
    """
    return spec_from_params(p).package_resistance(die_area_m2)


# ---------------------------------------------------------------------------
# grid conductances (per layer / per interface)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Grid:
    die_w: float                # die edge [m] (square dies, as in the paper)
    ny: int                     # cells across the DIE footprint
    nx: int
    params: StackParams = PAPER_STACK
    pkg_area: float = 0.0       # area feeding the package lump [m^2];
    #   0 -> the spreader footprint (die + margin).  Sub-die zooms (one AP
    #   block under tiling symmetry) pass the FULL die area so each cell
    #   carries the same package conductance share as the die-level solve.
    margin: int = 0             # extra spreader-only cells per side: the
    #   copper plate extends beyond the die, so die edges couple to cooler
    #   outer spreader — the source of the paper's ~3C center-to-edge span.
    spec: StackSpec | None = None   # heterogeneous stack; None -> the
    #   homogeneous ``params`` expanded through ``spec_from_params``.

    @property
    def stack(self) -> StackSpec:
        """The StackSpec every operator on this grid is built from."""
        return self.spec if self.spec is not None \
            else spec_from_params(self.params)

    @property
    def n_layers(self) -> int:
        return self.stack.n_layers

    @property
    def n_die_layers(self) -> int:
        """Device layers (logic + DRAM) — everything above the spreader."""
        return self.stack.n_die_layers

    @property
    def cell_w(self) -> float:
        return self.die_w / self.nx

    @property
    def cell_area(self) -> float:
        return self.cell_w * (self.die_w / self.ny)

    @property
    def dom_ny(self) -> int:
        return self.ny + 2 * self.margin

    @property
    def dom_nx(self) -> int:
        return self.nx + 2 * self.margin

    def conductances(self) -> dict:
        """g_lat [L], g_vert [L-1] (interfaces, top->bottom), g_pkg scalar."""
        s = self.stack
        g_lat = s.lateral_conductances()
        g_vert = s.vertical_conductances(self.cell_area)
        dom_area = self.dom_ny * self.dom_nx * self.cell_area
        a_pkg = self.pkg_area or dom_area
        r_pkg = s.package_resistance(a_pkg)
        # per-cell share: cell_area / (r_pkg * A) — reduces to
        # 1/(r_pkg * ncells) when the grid covers the package source area
        g_pkg = self.cell_area / (r_pkg * a_pkg)
        return {"g_lat": jnp.asarray(g_lat, jnp.float32),
                "g_vert": jnp.asarray(g_vert, jnp.float32),
                "g_pkg": float(g_pkg), "r_pkg": float(r_pkg)}

    def fields(self) -> dict:
        """Per-face conductance fields over the (die + margin) domain.

        Die layers (logic and DRAM) exist only over the die footprint
        (faces outside it are zero = adiabatic); the spreader layer spans
        the full domain.  Returns seven [L, NY, NX] arrays: gx_lf, gx_rt,
        gy_up, gy_dn (lateral faces), gz_up, gz_dn (interfaces), g_pkg
        (bottom lump).
        """
        g = self.conductances()
        L = self.n_layers
        NY, NX, m = self.dom_ny, self.dom_nx, self.margin
        mask = np.zeros((L, NY, NX), np.float32)
        mask[:-1, m:m + self.ny, m:m + self.nx] = 1.0   # dies: footprint only
        mask[-1] = 1.0                                  # spreader: everywhere
        g_cell = np.asarray(g["g_lat"])[:, None, None] * mask

        def face(a, b):  # harmonic mean of cell conductances (0-safe)
            s = a + b
            return np.where(s > 0, 2 * a * b / np.maximum(s, 1e-30), 0.0)

        gx = face(g_cell[:, :, :-1], g_cell[:, :, 1:])   # [L, NY, NX-1]
        gy = face(g_cell[:, :-1, :], g_cell[:, 1:, :])   # [L, NY-1, NX]
        z = np.zeros((L, NY, 1), np.float32)
        gx_lf = np.concatenate([z, gx], axis=2)
        gx_rt = np.concatenate([gx, z], axis=2)
        zy = np.zeros((L, 1, NX), np.float32)
        gy_up = np.concatenate([zy, gy], axis=1)
        gy_dn = np.concatenate([gy, zy], axis=1)
        # vertical: interface exists where BOTH layers have material
        gv = np.asarray(g["g_vert"])[:, None, None] \
            * mask[:-1] * mask[1:]                       # [L-1, NY, NX]
        zl = np.zeros((1, NY, NX), np.float32)
        gz_up = np.concatenate([zl, gv], axis=0)
        gz_dn = np.concatenate([gv, zl], axis=0)
        g_pkg = np.zeros((L, NY, NX), np.float32)
        g_pkg[-1] = g["g_pkg"]
        return {k: jnp.asarray(v, jnp.float32) for k, v in dict(
            gx_lf=gx_lf, gx_rt=gx_rt, gy_up=gy_up, gy_dn=gy_dn,
            gz_up=gz_up, gz_dn=gz_dn, g_pkg=g_pkg).items()}

    def capacities(self) -> jax.Array:
        return jnp.asarray(self.stack.capacities(self.cell_area),
                           jnp.float32)

    def capacity_field(self) -> jax.Array:
        """Per-cell heat capacity [J/K] over the full domain, [L, NY, NX].

        Void cells (die layers over the margin ring) keep the die value:
        they have zero conductance and zero power, so they simply stay at
        their initial temperature; a nonzero capacity keeps the implicit
        system's diagonal well conditioned.
        """
        c = np.asarray(self.capacities())
        return jnp.asarray(
            np.broadcast_to(c[:, None, None],
                            (self.n_layers, self.dom_ny, self.dom_nx)),
            jnp.float32)

    def pad_power(self, power) -> jax.Array:
        """[n_die, ny, nx] die power -> [L, ny, nx] (spreader heatless)."""
        power = jnp.asarray(power, jnp.float32)
        if power.shape[0] == self.n_layers:
            return power
        pad = jnp.zeros((self.n_layers - power.shape[0],) +
                        power.shape[1:], jnp.float32)
        return jnp.concatenate([power, pad], axis=0)


# ---------------------------------------------------------------------------
# stencil operator (jnp reference; kernels/thermal_stencil mirrors this)
# ---------------------------------------------------------------------------

def _vectors(L: int, g_lat, g_vert, g_pkg):
    """Normalize scalar-or-vector conductances to per-layer vectors."""
    g_lat = jnp.broadcast_to(jnp.asarray(g_lat, jnp.float32), (L,))
    g_vert = jnp.broadcast_to(jnp.asarray(g_vert, jnp.float32),
                              (max(L - 1, 1),))[: L - 1]
    gv_u = jnp.concatenate([jnp.zeros((1,), jnp.float32), g_vert])
    gv_d = jnp.concatenate([g_vert, jnp.zeros((1,), jnp.float32)])
    g_pkg_vec = jnp.zeros((L,), jnp.float32).at[-1].set(g_pkg)
    return g_lat, gv_u, gv_d, g_pkg_vec


def apply_operator(T: jax.Array, g_lat, g_vert, g_pkg) -> jax.Array:
    """y = G @ T.  T: [L, ny, nx] (layer 0 = TOP die, layer L-1 = spreader).

    g_lat: scalar or [L]; g_vert: scalar or [L-1]; g_pkg: scalar (bottom
    layer to ambient).  Adiabatic side/top boundaries.
    """
    L = T.shape[0]
    g_lat, gv_u, gv_d, g_pkg_vec = _vectors(L, g_lat, g_vert, g_pkg)
    gl = g_lat[:, None, None]
    t_up = jnp.concatenate([T[:, :1], T[:, :-1]], axis=1)
    t_dn = jnp.concatenate([T[:, 1:], T[:, -1:]], axis=1)
    t_lf = jnp.concatenate([T[:, :, :1], T[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([T[:, :, 1:], T[:, :, -1:]], axis=2)
    y = gl * (4.0 * T - t_up - t_dn - t_lf - t_rt)
    l_up = jnp.concatenate([T[:1], T[:-1]], axis=0)
    l_dn = jnp.concatenate([T[1:], T[-1:]], axis=0)
    y = y + gv_u[:, None, None] * (T - l_up) \
          + gv_d[:, None, None] * (T - l_dn) \
          + g_pkg_vec[:, None, None] * T
    return y


def _diag(shape, g_lat, g_vert, g_pkg):
    """Diagonal of G (for Jacobi preconditioning)."""
    L, ny, nx = shape
    g_lat, gv_u, gv_d, g_pkg_vec = _vectors(L, g_lat, g_vert, g_pkg)
    d = jnp.broadcast_to((4.0 * g_lat)[:, None, None], shape)
    edge_y = jnp.zeros((ny, 1)).at[0].set(1).at[-1].set(1)
    edge_x = jnp.zeros((1, nx)).at[:, 0].set(1).at[:, -1].set(1)
    d = d - g_lat[:, None, None] * (edge_y + edge_x)[None]
    d = d + (gv_u + gv_d + g_pkg_vec)[:, None, None]
    return d


# ---------------------------------------------------------------------------
# generic preconditioned CG (shared by every solver in this repo: the jnp and
# Pallas steady-state paths, and the implicit transient steppers below)
# ---------------------------------------------------------------------------

def _as_precond(Minv):
    """Normalize a preconditioner to a closure: an inverse-diagonal
    array (Jacobi) or a callable (e.g. one multigrid V-cycle)."""
    return Minv if callable(Minv) else (lambda r: Minv * r)


def pcg(A, Minv, b, tol=1e-8, max_iter=6000):
    """Preconditioned CG for the SPD system A x = b.

    ``A`` is a matvec closure; ``Minv`` is either the inverse diagonal
    (array, Jacobi) or a callable applying any fixed SPD preconditioner
    (``multigrid.v_cycle``).  Tolerance-based ``while_loop`` termination;
    see :func:`pcg_fixed` for the fixed-cost variant used inside
    vmapped/scanned transient stepping.  Returns ``(x, n_iterations)``.
    """
    apply_Minv = _as_precond(Minv)
    x = jnp.zeros_like(b)
    r = b
    z = apply_Minv(r)
    p = z
    rz = jnp.vdot(r, z)
    bnorm = jnp.linalg.norm(b)

    def cond(state):
        x, r, p, rz, it = state
        return (jnp.linalg.norm(r) > tol * bnorm) & (it < max_iter)

    def body(state):
        x, r, p, rz, it = state
        Ap = A(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_Minv(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return x, r, p, rz_new, it + 1

    x, r, p, rz, it = jax.lax.while_loop(
        cond, body, (x, r, p, rz, jnp.int32(0)))
    return x, it


def pcg_fixed(A, Minv, b, n_iter: int):
    """PCG with a fixed iteration count (``fori_loop``).

    Uniform cost per call, so a batch of solves vmaps without masking and a
    scan over time steps stays one compiled program.  Guarded against a zero
    right-hand side (alpha would be 0/0): the update is suppressed when the
    residual has already vanished.
    """
    apply_Minv = _as_precond(Minv)
    x = jnp.zeros_like(b)
    r = b
    z = apply_Minv(r)
    p = z
    rz = jnp.vdot(r, z)

    def body(_, state):
        x, r, p, rz = state
        Ap = A(p)
        pAp = jnp.vdot(p, Ap)
        ok = pAp > 0.0
        alpha = jnp.where(ok, rz / jnp.where(ok, pAp, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        z = apply_Minv(r)
        rz_new = jnp.vdot(r, z)
        beta = jnp.where(ok, rz_new / jnp.where(rz > 0, rz, 1.0), 0.0)
        p = z + beta * p
        return x, r, p, rz_new

    x, *_ = jax.lax.fori_loop(0, n_iter, body, (x, r, p, rz))
    return x


@partial(jax.jit, static_argnames=("max_iter",))
def _cg_solve(b, diag, g_lat, g_vert, g_pkg, tol=1e-8, max_iter=6000):
    """Jacobi-preconditioned conjugate gradient for G T = b."""
    A = lambda v: apply_operator(v, g_lat, g_vert, g_pkg)
    return pcg(A, 1.0 / diag, b, tol, max_iter)[0]


# ---------------------------------------------------------------------------
# heterogeneous (face-conductance-field) operator — the production solver
# ---------------------------------------------------------------------------

def apply_operator_fields(T: jax.Array, F: dict) -> jax.Array:
    """y = G @ T with per-face conductances (zero faces = adiabatic)."""
    t_lf = jnp.concatenate([T[:, :, :1], T[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([T[:, :, 1:], T[:, :, -1:]], axis=2)
    t_up = jnp.concatenate([T[:, :1], T[:, :-1]], axis=1)
    t_dn = jnp.concatenate([T[:, 1:], T[:, -1:]], axis=1)
    l_up = jnp.concatenate([T[:1], T[:-1]], axis=0)
    l_dn = jnp.concatenate([T[1:], T[-1:]], axis=0)
    return (F["gx_lf"] * (T - t_lf) + F["gx_rt"] * (T - t_rt)
            + F["gy_up"] * (T - t_up) + F["gy_dn"] * (T - t_dn)
            + F["gz_up"] * (T - l_up) + F["gz_dn"] * (T - l_dn)
            + F["g_pkg"] * T)


def _diag_fields(F: dict) -> jax.Array:
    d = (F["gx_lf"] + F["gx_rt"] + F["gy_up"] + F["gy_dn"]
         + F["gz_up"] + F["gz_dn"] + F["g_pkg"])
    return jnp.where(d > 0, d, 1.0)     # void cells: identity rows


@partial(jax.jit, static_argnames=("max_iter",))
def _cg_solve_fields_stats(b, F, tol=1e-8, max_iter=8000):
    A = lambda v: apply_operator_fields(v, F)
    return pcg(A, 1.0 / _diag_fields(F), b, tol, max_iter)


def _cg_solve_fields(b, F, tol=1e-8, max_iter=8000):
    return _cg_solve_fields_stats(b, F, tol, max_iter)[0]


def _solve_fields(b, F, solver: str, use_pallas: bool, tol: float = 1e-8):
    """Route one fields solve ``G dT = b`` to the selected backend.

    Returns ``(dT, n_iterations)`` — CG iterations or V-cycles.  With
    ``use_pallas`` the PCG backend runs the Pallas stencil matvec and
    the multigrid backends run the Pallas red-black line smoother
    (``kernels/mg_smooth``).
    """
    from repro.core import multigrid
    if solver == "mg":
        return multigrid.mg_solve_fields(b, F, 0.0, tol,
                                         use_pallas=use_pallas)
    if solver == "mgcg":
        return multigrid.mgcg_solve_fields(b, F, 0.0, tol,
                                           use_pallas=use_pallas)
    if solver != "pcg":
        raise ValueError(f"unknown solver {solver!r}; expected {SOLVERS}")
    if use_pallas:
        from repro.kernels.thermal_stencil import ops as _ops
        return _ops.cg_solve_fields_stats(b, F, tol)
    return _cg_solve_fields_stats(b, F, tol)


def fallback_chain(solver: str) -> tuple[tuple[str, float], ...]:
    """Attempt list for one guarded fields solve: (backend, tol scale).

    Starts at the requested backend, continues down the remaining of
    the ``mg -> mgcg -> pcg`` ladder (each rung trades speed for
    robustness), and always ends with a tightened-tolerance Jacobi-PCG
    — the slowest but most unconditionally dependable backend here.
    """
    order = ("mg", "mgcg", "pcg")
    if solver not in order:
        raise ValueError(f"unknown solver {solver!r}; expected {SOLVERS}")
    tail = order[order.index(solver):]
    return tuple((s, 1.0) for s in tail) + (("pcg", 0.1),)


def _solve_fields_guarded(b, F, solver: str, use_pallas: bool,
                          tol: float = 1e-8):
    """:func:`_solve_fields` hardened by health checks + fallback.

    After each attempt the TRUE relative residual ``||b - G x||/||b||``
    is recomputed; a non-finite or ``> HEALTH_RTOL`` residual (a
    diverged or stagnated solve — or a backend forced down by
    ``repro.faults.inject.poison_solver``) advances to the next rung of
    :func:`fallback_chain`.  Returns ``(dT, iterations, stats)`` with
    ``stats = {"attempts", "solved_by", "rel_residual"}``; retries are
    counted in ``obs`` under ``thermal/fallback/*``.
    """
    from repro.faults import inject
    bnorm = float(jnp.linalg.norm(b))
    if bnorm == 0.0 or not math.isfinite(bnorm):
        # zero RHS: x = 0 is exact.  A non-finite RHS no backend can fix
        # — report it honestly rather than looping the chain.
        resid = 0.0 if bnorm == 0.0 else math.inf
        return jnp.zeros_like(b), 0, {"attempts": 1, "solved_by": solver,
                                      "rel_residual": resid}
    last = None
    for i, (s, scale) in enumerate(fallback_chain(solver)):
        if inject.solver_poisoned(s):
            dT, iters = jnp.full_like(b, jnp.nan), 0
        else:
            dT, iters = _solve_fields(b, F, s, use_pallas, tol * scale)
        resid = float(jnp.linalg.norm(b - apply_operator_fields(dT, F))
                      / bnorm)
        last = (dT, int(iters), {"attempts": i + 1, "solved_by": s,
                                 "rel_residual": resid})
        if math.isfinite(resid) and resid <= HEALTH_RTOL:
            if i:
                obs.count("thermal/fallback/recovered")
            return last
        if i == 0:
            obs.count("thermal/fallback/engaged")
        obs.count("thermal/fallback/retries")
        obs.count(f"thermal/fallback/unhealthy[{s}]")
    obs.count("thermal/fallback/exhausted")
    return last


def steady_state_stats(power: np.ndarray | jax.Array, grid: Grid,
                       t_amb: float = AMBIENT_C, use_pallas: bool = False,
                       solver: str = "pcg", tol: float = 1e-8
                       ) -> tuple[jax.Array, dict]:
    """:func:`steady_state` plus solver statistics.

    Returns ``(T_die, stats)`` with ``stats = {"iterations", "solver",
    "rel_residual", "attempts", "solved_by"}``: ``iterations`` counts
    CG iterations (pcg/mgcg) or V-cycles (mg), and ``rel_residual`` is
    the TRUE relative residual ``||b - G x|| / ||b||`` recomputed after
    the solve — the honest convergence signal (the mg backend in
    particular stops at the float32 residual floor rather than the
    nominal ``tol``, and a pathological hierarchy could stall earlier).
    An unhealthy solve (non-finite or ``> HEALTH_RTOL`` residual)
    automatically retries down :func:`fallback_chain`; ``attempts`` and
    ``solved_by`` record how far it had to go (``solver`` stays the
    REQUESTED backend).  Non-finite power maps raise ``ValueError`` up
    front.
    """
    with obs.span("thermal/steady", solver=solver,
                  shape=f"{grid.n_layers}x{grid.dom_ny}x{grid.dom_nx}"):
        F = grid.fields()
        power = grid.pad_power(power)
        if not bool(jnp.isfinite(power).all()):
            raise ValueError(
                "steady_state: power map has non-finite cells; refusing "
                "to solve — NaN temperatures would silently poison every "
                "downstream verdict")
        m = grid.margin
        if m:
            power = jnp.pad(power, ((0, 0), (m, m), (m, m)))
        dT, iters, fstats = _solve_fields_guarded(power, F, solver,
                                                  use_pallas, tol)
        n_die = grid.n_die_layers
        if m:
            dT = dT[:n_die, m:m + grid.ny, m:m + grid.nx]
        else:
            dT = dT[:n_die]
        stats = {"iterations": iters, "solver": solver,
                 "rel_residual": fstats["rel_residual"],
                 "attempts": fstats["attempts"],
                 "solved_by": fstats["solved_by"]}
    obs.count("thermal/steady/solves")
    obs.observe(f"thermal/steady/iterations[{solver}]", stats["iterations"])
    obs.observe("thermal/steady/rel_residual", stats["rel_residual"])
    return dT + t_amb, stats


def steady_state(power: np.ndarray | jax.Array, grid: Grid,
                 t_amb: float = AMBIENT_C, use_pallas: bool = False,
                 solver: str = "pcg") -> jax.Array:
    """Steady-state temperatures [C] of the DIE layers over the DIE.

    power: [n_die_layers, ny, nx] watts per cell of the die footprint (the
    spreader layer and margin ring are handled internally and stripped).
    ``solver`` selects the linear backend (:data:`SOLVERS`): Jacobi-PCG,
    stand-alone multigrid V-cycles, or V-cycle-preconditioned CG.
    """
    T, _ = steady_state_stats(power, grid, t_amb, use_pallas, solver)
    return T


@partial(jax.jit, static_argnames=("n_steps",))
def transient(T0, power, g_lat, g_vert, g_pkg, cap, dt, n_steps: int,
              t_amb: float = AMBIENT_C):
    """Explicit transient:  C dT/dt = P - G (T - Tamb).  Returns T(t_end)."""

    def step(T, _):
        dT = T - t_amb
        dTdt = (power - apply_operator(dT, g_lat, g_vert, g_pkg)) \
            / cap[:, None, None]
        return T + dt * dTdt, jnp.max(T)

    T, peaks = jax.lax.scan(step, T0, None, length=n_steps)
    return T, peaks


def transient_solve(power, grid: Grid, t_end: float,
                    t_amb: float = AMBIENT_C) -> tuple[jax.Array, jax.Array]:
    """Convenience wrapper: start from ambient, integrate to t_end seconds."""
    g = grid.conductances()
    cap = grid.capacities()
    power = grid.pad_power(power)
    dt = explicit_dt(grid)
    n = max(int(t_end / dt), 1)
    T0 = jnp.full(power.shape, t_amb, jnp.float32)
    return transient(T0, power, g["g_lat"], g["g_vert"], g["g_pkg"],
                     cap, dt, n, t_amb)


def explicit_dt(grid: Grid) -> float:
    """The explicit scheme's stability-bound time step (0.5x CFL margin)."""
    g = grid.conductances()
    cap = grid.capacities()
    gmax = float(4 * jnp.max(g["g_lat"]) + 2 * jnp.max(g["g_vert"])
                 + g["g_pkg"])
    return 0.5 * float(jnp.min(cap)) / gmax


# ---------------------------------------------------------------------------
# implicit (theta-scheme) transient: unconditionally stable, so the step size
# is set by accuracy, not the explicit CFL bound — the co-simulation engine's
# stepper (cosim.py replays per-interval power traces through it)
# ---------------------------------------------------------------------------

def _implicit_scan(dT0, power, A, solve, n_steps: int, lhs=None):
    """theta-scheme steps in excess-temperature space  C dT/dt = P - G dT.

    Solves for the increment:  (C/dt + theta G) delta = P - G dT_n,  then
    dT_{n+1} = dT_n + delta  (exact for any theta; backward Euler theta=1,
    Crank-Nicolson theta=0.5).  The LHS is SPD; ``solve`` is a fixed-cost
    closure for it (fixed-iteration PCG or fixed-cycle multigrid,
    :func:`implicit_lhs_solver`) so the whole integration is one scan —
    scannable and vmappable.

    With ``lhs`` (the theta-scheme LHS closure) given, the per-step ys
    also carry the TRUE relative linear residual of each inner solve,
    ``||rhs - lhs(delta)|| / ||rhs||`` — one extra matvec per step, paid
    only on the telemetry path (``obs`` enabled), never in the default
    compiled program.
    """

    def step(dTc, _):
        rhs = power - A(dTc)
        delta = solve(rhs)
        # emit the PRE-step max, matching the explicit transient()'s peaks
        peak = jnp.max(dTc)
        if lhs is not None:
            res = jnp.linalg.norm(rhs - lhs(delta)) \
                / jnp.maximum(jnp.linalg.norm(rhs), 1e-30)
            return dTc + delta, (peak, res)
        return dTc + delta, peak

    return jax.lax.scan(step, dT0, None, length=n_steps)


def implicit_lhs_solver(A, F, cap3, dt, theta, *, solver: str = "pcg",
                        n_cg: int = 50, n_mg: int = 3,
                        use_pallas: bool = False):
    """Fixed-cost solve closure for the theta-scheme LHS
    ``(C/dt + theta G) delta = rhs`` over the fields operator.

    "pcg": ``n_cg`` Jacobi-PCG iterations on the closure ``A`` (which may
    be the Pallas stencil).  "mg": ``n_mg`` V-cycles on the Galerkin
    hierarchy of the theta-scaled fields — built ONCE here, outside any
    scan, so coarse operators are constants of the compiled step.
    """
    lhs = lambda v: cap3 / dt * v + theta * A(v)
    if solver == "mg":
        from repro.core import multigrid
        F_lhs = {k: theta * v for k, v in F.items()}
        levels = multigrid.build_levels(F_lhs, cap3 / dt)
        sweep_fn = multigrid._resolve_sweep(use_pallas)
        coarse = multigrid.coarse_solve_fn(levels)
        return lambda rhs: multigrid.iterate_fixed(
            levels, rhs, n_mg, sweep_fn=sweep_fn, coarse_solve=coarse)
    if solver != "pcg":
        raise ValueError(f"unknown solver {solver!r}; expected "
                         f"('pcg', 'mg')")
    Minv = 1.0 / (cap3 / dt + theta * _diag_fields(F))
    return lambda rhs: pcg_fixed(lhs, Minv, rhs, n_cg)


@partial(jax.jit, static_argnames=("n_steps", "n_cg", "with_residuals"))
def transient_implicit(T0, power, g_lat, g_vert, g_pkg, cap, dt,
                       n_steps: int, theta: float = 1.0,
                       t_amb: float = AMBIENT_C, n_cg: int = 50,
                       with_residuals: bool = False):
    """Implicit counterpart of :func:`transient` (same contract/returns).

    ``with_residuals=True`` (static) appends per-step relative linear
    residuals to the return — ``(T, peaks, res)`` — for telemetry; the
    default keeps the historical 2-tuple and compiled program.
    """
    L = T0.shape[0]
    diag = _diag(T0.shape, g_lat, g_vert, g_pkg)
    cap3 = jnp.broadcast_to(jnp.asarray(cap, jnp.float32), (L,))[:, None, None]
    A = lambda v: apply_operator(v, g_lat, g_vert, g_pkg)
    lhs = lambda v: cap3 / dt * v + theta * A(v)
    Minv = 1.0 / (cap3 / dt + theta * diag)
    solve = lambda rhs: pcg_fixed(lhs, Minv, rhs, n_cg)
    if with_residuals:
        dT, (peaks, res) = _implicit_scan(T0 - t_amb, power, A, solve,
                                          n_steps, lhs=lhs)
        return dT + t_amb, peaks + t_amb, res
    dT, peaks = _implicit_scan(T0 - t_amb, power, A, solve, n_steps)
    return dT + t_amb, peaks + t_amb


@partial(jax.jit, static_argnames=("n_steps", "n_cg", "solver", "n_mg",
                                   "use_pallas", "with_residuals"))
def transient_implicit_fields(T0, power, F: dict, cap3, dt, n_steps: int,
                              theta: float = 1.0, t_amb: float = AMBIENT_C,
                              n_cg: int = 50, solver: str = "pcg",
                              n_mg: int = 3, use_pallas: bool = False,
                              with_residuals: bool = False):
    """Implicit theta-scheme on the heterogeneous (production) operator.

    T0/power: [L, NY, NX] over the full (die + margin) domain; cap3 the
    per-cell capacity field (``Grid.capacity_field()``).  ``solver``
    selects the fixed-cost inner solve: ``n_cg`` PCG iterations or
    ``n_mg`` multigrid V-cycles per step.  ``with_residuals=True``
    (static) appends per-step relative linear residuals:
    ``(T, peaks, res)``.
    """
    obs.count("thermal/retrace/transient_fields")
    A = lambda v: apply_operator_fields(v, F)
    solve = implicit_lhs_solver(A, F, cap3, dt, theta, solver=solver,
                                n_cg=n_cg, n_mg=n_mg,
                                use_pallas=use_pallas)
    if with_residuals:
        lhs = lambda v: cap3 / dt * v + theta * A(v)
        dT, (peaks, res) = _implicit_scan(T0 - t_amb, power, A, solve,
                                          n_steps, lhs=lhs)
        return dT + t_amb, peaks + t_amb, res
    dT, peaks = _implicit_scan(T0 - t_amb, power, A, solve, n_steps)
    return dT + t_amb, peaks + t_amb


def transient_solve_implicit(power, grid: Grid, t_end: float,
                             n_steps: int, theta: float = 1.0,
                             t_amb: float = AMBIENT_C, n_cg: int = 50,
                             solver: str = "pcg", n_mg: int = 3
                             ) -> tuple[jax.Array, jax.Array]:
    """Implicit counterpart of :func:`transient_solve` with a chosen step
    count (the point: n_steps can be 10-1000x below the explicit bound).
    ``solver="mg"`` runs the multigrid inner solve on the fields form of
    the same stack.

    With ``obs`` enabled the per-step inner-solve residuals are computed
    on device (one extra matvec per step) and recorded under
    ``thermal/transient/*``; the public return stays the 2-tuple.
    """
    wres = obs.is_enabled()
    power = grid.pad_power(power)
    dt = t_end / n_steps
    T0 = jnp.full(power.shape, t_amb, jnp.float32)
    with obs.span("thermal/transient", solver=solver, n_steps=n_steps):
        if solver == "mg":
            F = grid.fields()
            cap3 = grid.capacity_field()
            out = transient_implicit_fields(T0, power, F, cap3, dt,
                                            n_steps, theta, t_amb, n_cg,
                                            solver="mg", n_mg=n_mg,
                                            with_residuals=wres)
        else:
            g = grid.conductances()
            cap = grid.capacities()
            out = transient_implicit(T0, power, g["g_lat"], g["g_vert"],
                                     g["g_pkg"], cap, dt, n_steps, theta,
                                     t_amb, n_cg, with_residuals=wres)
    if wres:
        T, peaks, res = out
        obs.count("thermal/transient/solves")
        obs.count("thermal/transient/steps", n_steps)
        obs.count("thermal/transient/inner_iterations",
                  n_steps * (n_mg if solver == "mg" else n_cg))
        obs.observe_many("thermal/transient/step_rel_residual",
                         np.asarray(res, np.float64))
        return T, peaks
    return out
