"""Associative ISA: truth-table pass compiler + basic word-parallel ops.

The paper (§2.2, Table 1) implements arithmetic as sequences of *passes*:
each pass COMPAREs one truth-table input pattern against a set of bit-columns
and WRITEs the output pattern into the tagged rows.  Two subtleties the
compiler handles:

1. "No action" skipping — entries whose write would not change the row are
   dropped (Table 1 keeps only 4 of 8 full-adder entries).
2. Ordering — because outputs overwrite inputs, a pass must not transform a
   row INTO a pattern that a *later* pass matches (Table 1's 1st..4th pass
   annotation).  We derive a valid order by topological sort of the
   "p's result equals q's input ⇒ q before p" constraint graph.
"""
from __future__ import annotations

import itertools
from typing import Callable, Sequence

from repro.core.bitplane import Field
from repro.core.engine import APEngine, PassSchedule


# ---------------------------------------------------------------------------
# truth-table compiler
# ---------------------------------------------------------------------------

def compile_table(in_cols: Sequence[int], out_cols: Sequence[int],
                  fn: Callable[[tuple[int, ...]], tuple[int, ...]],
                  assume_out_cleared: bool = False) -> list:
    """Compile a truth table into an ordered list of passes.

    fn maps an input bit-tuple (over in_cols) to an output bit-tuple (over
    out_cols).  Returns [(cmp_cols, cmp_key, w_cols, w_key), ...] in a valid
    execution order.  Raises if no order exists (caller must restructure).
    """
    in_cols = list(in_cols)
    out_cols = list(out_cols)
    n_in = len(in_cols)
    overlap = {c: i for i, c in enumerate(in_cols)}  # col -> index in input

    entries = []  # (in_pattern, out_pattern)
    for pattern in itertools.product((0, 1), repeat=n_in):
        out = tuple(fn(pattern))
        if len(out) != len(out_cols):
            raise ValueError("fn output arity mismatch")
        # "No action" check: does the write change anything?
        changed = False
        for oc, ov in zip(out_cols, out):
            if oc in overlap:
                if pattern[overlap[oc]] != ov:
                    changed = True
            elif assume_out_cleared:
                if ov != 0:
                    changed = True
            else:
                changed = True  # unknown current value -> must write
        if changed:
            entries.append((pattern, out))

    # result pattern over in_cols after the write (for ordering constraints)
    def result_pattern(entry):
        pattern, out = entry
        r = list(pattern)
        for oc, ov in zip(out_cols, out):
            if oc in overlap:
                r[overlap[oc]] = ov
        return tuple(r)

    # edge q -> p  means  q must run before p
    n = len(entries)
    before = [set() for _ in range(n)]  # before[p] = set of q that must precede p
    for p in range(n):
        rp = result_pattern(entries[p])
        for q in range(n):
            if p != q and rp == entries[q][0]:
                before[p].add(q)

    order, placed = [], set()
    while len(order) < n:
        progress = False
        for p in range(n):
            if p not in placed and before[p] <= placed:
                order.append(p)
                placed.add(p)
                progress = True
        if not progress:
            raise ValueError("truth table has no conflict-free pass order; "
                             "use a separate output field")

    passes = []
    for p in order:
        pattern, out = entries[p]
        passes.append((in_cols, list(pattern), out_cols, list(out)))
    return passes


def schedule(passes: list) -> PassSchedule:
    return PassSchedule.build(passes)


# ---------------------------------------------------------------------------
# elementary word-parallel routines.  Each returns a PassSchedule (static);
# callers execute with eng.run(...).  Cycle costs are 2 x n_passes.
# ---------------------------------------------------------------------------

def full_adder_passes(c: int, b: int, a: int) -> list:
    """One single-bit addition b,c <- a + b + c (4 passes; paper Table 1)."""
    def fa(bits):
        cc, bb, aa = bits
        s = aa + bb + cc
        return (s >> 1, s & 1)
    return compile_table([c, b, a], [c, b], fa)


def add(a: Field, b: Field, carry: Field) -> PassSchedule:
    """b <- a + b (mod 2^m), carry-out in ``carry`` (must be pre-cleared).

    Exactly 4 passes per bit = 8m cycles (paper §2.2).
    """
    if a.width != b.width:
        raise ValueError("width mismatch")
    passes = []
    for i in range(a.width):
        passes += full_adder_passes(carry.col(0), b.col(i), a.col(i))
    return schedule(passes)


def full_subtractor_passes(br: int, b: int, a: int) -> list:
    """One single-bit subtraction b,br <- b - a - br."""
    def fs(bits):
        rr, bb, aa = bits
        d = bb - aa - rr
        return (1 if d < 0 else 0, d & 1)
    return compile_table([br, b, a], [br, b], fs)


def sub(a: Field, b: Field, borrow: Field) -> PassSchedule:
    """b <- b - a (mod 2^m), borrow-out in ``borrow`` (pre-cleared). 8m cycles."""
    if a.width != b.width:
        raise ValueError("width mismatch")
    passes = []
    for i in range(a.width):
        passes += full_subtractor_passes(borrow.col(0), b.col(i), a.col(i))
    return schedule(passes)


def const_add(b: Field, const: int, carry: Field) -> PassSchedule:
    """b <- b + const (mod 2^m). 2 passes/bit = 4m cycles (constant folds into key)."""
    passes = []
    for i in range(b.width):
        k = (const >> i) & 1
        def ha(bits, k=k):
            cc, bb = bits
            s = bb + cc + k
            return (s >> 1, s & 1)
        passes += compile_table([carry.col(0), b.col(i)], [carry.col(0), b.col(i)], ha)
    return schedule(passes)


def copy(dst: Field, src: Field) -> PassSchedule:
    """dst <- src. 2 passes/bit (no pre-clear needed)."""
    if dst.width != src.width:
        raise ValueError("width mismatch")
    passes = []
    for i in range(src.width):
        passes += compile_table([src.col(i), dst.col(i)], [dst.col(i)],
                                lambda bits: (bits[0],))
    return schedule(passes)


def cond_copy(dst: Field, src: Field, cond: Field,
              reverse: bool = False) -> PassSchedule:
    """dst <- src where cond==1; untouched elsewhere. 2 passes/bit.

    For overlapping src/dst (free-shift copies): ascending bit order is safe
    for right shifts (dst below src); pass ``reverse=True`` for left shifts
    (dst above src) so high bits are written before their sources are read.
    """
    if dst.width != src.width:
        raise ValueError("width mismatch")
    passes = []
    order = reversed(range(src.width)) if reverse else range(src.width)
    for i in order:
        passes += compile_table([cond.col(0), src.col(i), dst.col(i)], [dst.col(i)],
                                lambda bits: (bits[1],) if bits[0] else (bits[2],))
    return schedule(passes)


def logic_not(dst: Field, src: Field) -> PassSchedule:
    passes = []
    for i in range(src.width):
        passes += compile_table([src.col(i), dst.col(i)], [dst.col(i)],
                                lambda bits: (1 - bits[0],))
    return schedule(passes)


def eq_flag(a: Field, b: Field, flag: Field) -> PassSchedule:
    """flag <- (a == b).  flag must be pre-set to 1 (eng.set_bits(flag, 1)).

    2 passes/bit: clear flag where bits differ.
    """
    passes = []
    for i in range(a.width):
        passes += [
            ([flag.col(0), a.col(i), b.col(i)], [1, 1, 0], [flag.col(0)], [0]),
            ([flag.col(0), a.col(i), b.col(i)], [1, 0, 1], [flag.col(0)], [0]),
        ]
    return schedule(passes)


def gt_flag(a: Field, b: Field, gt: Field, decided: Field) -> PassSchedule:
    """gt <- (a > b) unsigned.  gt and decided must be pre-cleared.

    MSB-first scan, 2 passes/bit.
    """
    passes = []
    for i in reversed(range(a.width)):
        passes += [
            ([decided.col(0), a.col(i), b.col(i)], [0, 1, 0],
             [gt.col(0), decided.col(0)], [1, 1]),
            ([decided.col(0), a.col(i), b.col(i)], [0, 0, 1],
             [decided.col(0)], [1]),
        ]
    return schedule(passes)


def lut(arg: Field, out: Field, fn: Callable[[int], int]) -> PassSchedule:
    """out <- fn(arg) by exhaustive LUT matching (paper §2.2, O(2^m) passes).

    ``out`` must be pre-cleared; entries with fn(x) == 0 are skipped, the rest
    take one pass each — worst case 2^m passes / 2^(m+1) cycles.
    """
    passes = []
    in_cols = arg.cols()
    out_cols = out.cols()
    for x in range(1 << arg.width):
        y = fn(x) & ((1 << out.width) - 1)
        if y == 0:
            continue  # out pre-cleared
        ikey = [(x >> i) & 1 for i in range(arg.width)]
        okey = [(y >> i) & 1 for i in range(out.width)]
        passes.append((in_cols, ikey, out_cols, okey))
    if not passes:  # fn == 0 everywhere; nothing to do, emit a no-op pass
        passes.append((in_cols, [0] * arg.width, out_cols, [0] * out.width))
    return schedule(passes)


# convenience: run a routine end-to-end on an engine ------------------------

def run_add(eng: APEngine, a: Field, b: Field, carry: Field) -> None:
    eng.clear(carry)
    eng.run(add(a, b, carry))


def run_sub(eng: APEngine, a: Field, b: Field, borrow: Field) -> None:
    eng.clear(borrow)
    eng.run(sub(a, b, borrow))
