"""Shared thermal constants (single source of truth).

``AMBIENT_C`` and the 85 °C 3D-DRAM ceiling used to be defined
independently in ``core/thermal.py`` and ``core/cosim.py``; every module
(including the ``repro.stack`` subsystem) now imports them from here so a
calibration change cannot de-synchronize the solvers from the reports.
"""

AMBIENT_C = 45.0        # HotSpot default ambient [C]

DRAM_LIMIT_C = 85.0     # §4.3: max operating temperature of commercial
#   DRAM.  Also the first JEDEC refresh derating bin: above this the
#   refresh interval halves (see repro.stack.dram.refresh_multiplier).
