"""The policy family: sampled DTM/DVFS controllers for the closed loop.

Every controller here implements the :class:`~repro.policy.base.Policy`
protocol and is registered by name in ``repro.policy`` — that name is
what :class:`~repro.sweep.spec.SweepSpec` sweeps over.  All of them
actuate on the *measured* start-of-interval hot spots (see ``base.py``
for the protocol and why that sampling discipline is load-bearing).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.policy.base import (Policy, PolicyContext, check_floor,
                               check_trip, masked_hot, ramp_duty)
from repro.policy.dvfs import DVFSTable, build_dvfs_table


@dataclasses.dataclass(frozen=True)
class RampPolicy(Policy):
    """The classic linear throttle: duty ramps from 1 at ``trip_C`` down
    to ``floor`` over ``ramp_C`` degrees, sensed on the logic hot spot.

    This is the pre-policy-engine DTM controller verbatim — a default
    :class:`~repro.stack.feedback.FeedbackParams` resolves to it, and
    the replay trajectories are pinned bit-identical to the historical
    sampled ramp (``tests/test_policy.py``).  ``ramp_C = 0`` is a step
    trip (legal; see :func:`~repro.policy.base.ramp_duty`).
    """
    trip_C: float = 95.0
    ramp_C: float = 10.0
    floor: float = 0.25

    def __post_init__(self):
        check_trip(self.trip_C)
        check_floor(self.floor)
        if self.ramp_C < 0:
            raise ValueError(f"ramp_C must be >= 0; got {self.ramp_C!r}")

    def act(self, state, ctx: PolicyContext):
        t = masked_hot(ctx.layer_T, ctx.logic_mask)
        f = ramp_duty(t, self.trip_C, self.ramp_C, self.floor)
        return state, f, f


@dataclasses.dataclass(frozen=True)
class HysteresisPolicy(Policy):
    """Bang-bang throttle with a release band.

    Trips to ``floor`` when the logic hot spot exceeds ``trip_C`` and
    releases back to full duty only once it has cooled below
    ``trip_C - band_C`` — inside the band the controller HOLDS its
    previous decision, so the duty cannot chatter while the temperature
    dwells between the two thresholds (one decision per interval, and a
    decision flips only on a genuine threshold crossing).
    """
    trip_C: float = 95.0
    band_C: float = 5.0
    floor: float = 0.25

    def __post_init__(self):
        check_trip(self.trip_C)
        check_floor(self.floor)
        if self.band_C < 0:
            raise ValueError(f"band_C must be >= 0; got {self.band_C!r}")

    def init_state(self, n_layers: int | None = None):
        return jnp.float32(0.0)          # 1.0 while throttled

    def act(self, state, ctx: PolicyContext):
        t = masked_hot(ctx.layer_T, ctx.logic_mask)
        on = jnp.where(t > self.trip_C, jnp.float32(1.0),
                       jnp.where(t < self.trip_C - self.band_C,
                                 jnp.float32(0.0), state))
        f = jnp.where(on > 0, jnp.float32(self.floor), jnp.float32(1.0))
        return on, f, f


@dataclasses.dataclass(frozen=True)
class PIDPolicy(Policy):
    """PID regulation of the logic hot spot onto ``target_C``.

    Duty = ``clip(1 - (kp·e + ki·∫e + kd·Δe), floor, 1)`` with
    ``e = T_hot - target_C``.  The integral is clamped to
    ``[0, (1 - floor)/ki]`` (anti-windup: it can neither push the duty
    past the floor nor bank negative error while cool).
    """
    target_C: float = 90.0
    kp: float = 0.10
    ki: float = 0.02
    kd: float = 0.05
    floor: float = 0.25

    def __post_init__(self):
        check_trip(self.target_C, "target_C")
        check_floor(self.floor)
        if min(self.kp, self.ki, self.kd) < 0:
            raise ValueError("PID gains must be >= 0")

    def init_state(self, n_layers: int | None = None):
        return (jnp.float32(0.0), jnp.float32(0.0))   # (∫e, prev e)

    def act(self, state, ctx: PolicyContext):
        integ, prev = state
        err = masked_hot(ctx.layer_T, ctx.logic_mask) - self.target_C
        err = jnp.maximum(err, jnp.float32(-1e6))     # -inf-safe (no logic)
        i_max = (1.0 - self.floor) / self.ki if self.ki > 0 else 0.0
        integ = jnp.clip(integ + err, 0.0, i_max)
        u = self.kp * err + self.ki * integ + self.kd * (err - prev)
        f = jnp.clip(1.0 - u, self.floor, 1.0)
        return (integ, err), f, f


@dataclasses.dataclass(frozen=True)
class PerDiePolicy(Policy):
    """Independent per-die throttling for heterogeneous stacks.

    Each die kind runs its own ramp controller off its own hot-spot
    sensor: DRAM dies throttle their activate/IO power on the DRAM
    sensor (tripping at the retention-critical ``dram_trip_C``), logic
    dies throttle on their own sensor AND honor the DRAM ceiling — a
    compute die must back off when the memory stacked on it overheats,
    because most of the DRAM's heat arrives from below.  ``f_power`` is
    therefore a per-layer vector; the performance duty is the logic
    dies' (compute sets the runtime).  Layers that are neither (the
    spreader) stay at full power.
    """
    logic_trip_C: float = 95.0
    logic_ramp_C: float = 10.0
    dram_trip_C: float = 83.0
    dram_ramp_C: float = 3.0
    floor: float = 0.10

    def __post_init__(self):
        check_trip(self.logic_trip_C, "logic_trip_C")
        check_trip(self.dram_trip_C, "dram_trip_C")
        check_floor(self.floor)
        if min(self.logic_ramp_C, self.dram_ramp_C) < 0:
            raise ValueError("ramp widths must be >= 0")

    def act(self, state, ctx: PolicyContext):
        t_logic = masked_hot(ctx.layer_T, ctx.logic_mask)
        t_dram = masked_hot(ctx.layer_T, ctx.dram_mask)
        f_dram = ramp_duty(t_dram, self.dram_trip_C, self.dram_ramp_C,
                           self.floor)
        f_logic = jnp.minimum(
            ramp_duty(t_logic, self.logic_trip_C, self.logic_ramp_C,
                      self.floor),
            f_dram)
        f_power = (ctx.logic_mask * f_logic + ctx.dram_mask * f_dram
                   + (1.0 - ctx.logic_mask - ctx.dram_mask))
        return state, f_power, f_logic


@dataclasses.dataclass(frozen=True)
class DVFSPolicy(Policy):
    """Discrete DVFS stepping over a technology-node table.

    One OP step per interval: above ``trip_C`` (sensed on the hottest
    die of any kind — DVFS guards the whole stack) step down one OP;
    below ``trip_C - band_C`` step back up; inside the band hold.
    Power scales with the OP's ``f·V²`` factor while performance scales
    with ``f`` only — the split :mod:`repro.policy.dvfs` quantifies and
    the Pareto bench exploits.
    """
    table: DVFSTable = dataclasses.field(
        default_factory=lambda: build_dvfs_table("22nm"))
    trip_C: float = 85.0
    band_C: float = 4.0

    def __post_init__(self):
        check_trip(self.trip_C)
        if self.band_C < 0:
            raise ValueError(f"band_C must be >= 0; got {self.band_C!r}")

    @property
    def name(self) -> str:
        return f"dvfs-{self.table.node}"

    def init_state(self, n_layers: int | None = None):
        return jnp.int32(self.table.n_ops - 1)        # start at top OP

    def act(self, state, ctx: PolicyContext):
        t = jnp.maximum(masked_hot(ctx.layer_T, ctx.logic_mask),
                        masked_hot(ctx.layer_T, ctx.dram_mask))
        step = jnp.where(t > self.trip_C, jnp.int32(-1),
                         jnp.where(t < self.trip_C - self.band_C,
                                   jnp.int32(1), jnp.int32(0)))
        idx = jnp.clip(state + step, 0, self.table.n_ops - 1)
        f_power = jnp.asarray(self.table.power_scales(),
                              jnp.float32)[idx]
        f_perf = jnp.asarray(self.table.perf_scales(), jnp.float32)[idx]
        return idx, f_power, f_perf

    def residency(self, duty) -> dict[str, float]:
        """Intervals spent at each OP, attributed by nearest perf scale
        (the recorded duty trace IS the per-interval ``f/f₀``)."""
        perf = np.asarray(self.table.perf_scales())
        idx = np.abs(np.asarray(duty, np.float64)[..., None]
                     - perf).argmin(axis=-1)
        labels = self.table.labels()
        return {labels[i]: int((idx == i).sum())
                for i in range(self.table.n_ops) if (idx == i).any()}


@dataclasses.dataclass(frozen=True)
class PredictivePolicy(Policy):
    """Model-predictive throttle: pick the highest duty whose *forecast*
    hot spot stays under ``trip_C``.

    The forecast is the closed loop's own thermal RC operator advanced
    one implicit substep under each candidate duty
    (``ctx.predict_hot``; built by ``cosim.interval_forecaster`` — the
    response is affine in the duty, so all candidates cost two inner
    solves total).  Because it acts on where the temperature is GOING
    rather than where it is, it shaves the overshoot a reactive ramp
    pays at every trip.
    """
    trip_C: float = 95.0
    floor: float = 0.25
    n_cands: int = 8

    def __post_init__(self):
        check_trip(self.trip_C)
        check_floor(self.floor)
        if self.n_cands < 2:
            raise ValueError("n_cands must be >= 2")

    def act(self, state, ctx: PolicyContext):
        cands = jnp.linspace(jnp.float32(self.floor), jnp.float32(1.0),
                             self.n_cands)
        hot = ctx.predict_hot(cands)
        # trip_C = inf compares True against any finite forecast
        ok = hot <= self.trip_C if math.isfinite(self.trip_C) \
            else jnp.ones_like(hot, bool)
        f = jnp.max(jnp.where(ok, cands, jnp.float32(self.floor)))
        return state, f, f
