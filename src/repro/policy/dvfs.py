"""Technology-node DVFS frequency/voltage tables.

A :class:`DVFSTable` is a sorted set of discrete operating points
(frequency, voltage) for one technology node — the ``build_dvfs_table``
structure of the snipersim-hotspot integration: the node names a table,
each row is an OP the controller may sit at, and scaling follows the
classic CMOS dynamic-power law

    P_dyn ∝ f · V²     (per OP: ``power_scale = (f/f₀)(V/V₀)²``),

normalized to the table's top OP ``(f₀, V₀)``, while *performance* only
follows frequency (``perf_scale = f/f₀``).  That split is why DVFS
Pareto-dominates plain duty-cycling on the energy axis: stepping an OP
down buys a super-linear power cut for a linear slowdown.

Tables are frozen dataclasses of tuples, so a policy carrying one stays
hashable (jit-static).  Voltages follow published near-threshold-to-
nominal ranges per node; the exact figures are calibration constants in
the DESIGN.md §10 sense, not measurements.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One DVFS step: core frequency [MHz] and supply voltage [V]."""
    f_mhz: float
    v: float

    def __post_init__(self):
        if self.f_mhz <= 0 or self.v <= 0:
            raise ValueError("operating points need positive f and V; "
                             f"got ({self.f_mhz}, {self.v})")

    @property
    def label(self) -> str:
        return f"{self.f_mhz:g}MHz@{self.v:g}V"


@dataclasses.dataclass(frozen=True)
class DVFSTable:
    """Discrete operating points of one technology node, slowest first."""
    node: str
    points: tuple[OperatingPoint, ...]

    def __post_init__(self):
        if len(self.points) < 2:
            raise ValueError("a DVFS table needs >= 2 operating points")
        freqs = [p.f_mhz for p in self.points]
        if freqs != sorted(freqs) or len(set(freqs)) != len(freqs):
            raise ValueError("operating points must be strictly "
                             "frequency-sorted, slowest first")

    @property
    def n_ops(self) -> int:
        return len(self.points)

    @property
    def top(self) -> OperatingPoint:
        return self.points[-1]

    def power_scales(self) -> tuple[float, ...]:
        """Dynamic-power factor per OP (f·V², normalized to the top OP)."""
        f0, v0 = self.top.f_mhz, self.top.v
        return tuple((p.f_mhz / f0) * (p.v / v0) ** 2 for p in self.points)

    def perf_scales(self) -> tuple[float, ...]:
        """Performance (frequency) factor per OP, normalized likewise."""
        f0 = self.top.f_mhz
        return tuple(p.f_mhz / f0 for p in self.points)

    def labels(self) -> tuple[str, ...]:
        return tuple(p.label for p in self.points)


#: per-node (f [MHz], V) rows, slowest first — the snipersim-hotspot
#: table structure with voltage ranges typical of each node's datasheets
_NODE_ROWS: dict[str, tuple[tuple[float, float], ...]] = {
    "45nm": ((800, 0.85), (1200, 0.95), (1600, 1.05), (2000, 1.15),
             (2400, 1.25)),
    "32nm": ((800, 0.80), (1300, 0.90), (1800, 1.00), (2300, 1.10),
             (2800, 1.20)),
    "22nm": ((800, 0.70), (1400, 0.80), (2000, 0.90), (2600, 1.00),
             (3200, 1.10)),
    "14nm": ((600, 0.60), (1300, 0.70), (2000, 0.80), (2700, 0.95),
             (3400, 1.05)),
}


def nodes() -> tuple[str, ...]:
    return tuple(_NODE_ROWS)


def build_dvfs_table(node: str = "22nm") -> DVFSTable:
    """The operating-point table of a technology node.

    >>> t = build_dvfs_table("22nm")
    >>> t.n_ops, t.top.label
    (5, '3200MHz@1.1V')
    >>> [round(s, 3) for s in t.power_scales()][:2]
    [0.101, 0.231]
    """
    if node not in _NODE_ROWS:
        raise ValueError(f"unknown technology node {node!r}; "
                         f"expected one of {nodes()}")
    return DVFSTable(node, tuple(OperatingPoint(f, v)
                                 for f, v in _NODE_ROWS[node]))
