"""The DVFS/DTM policy protocol and shared controller math.

A *policy* is the sampled controller that turns measured start-of-interval
temperatures into a power/performance operating point for the next
interval of the closed-loop replay (``repro.stack.feedback``).  Policies
are **frozen dataclasses** (hashable, so a
:class:`~repro.stack.feedback.FeedbackParams` carrying one stays a valid
jit static argument) whose :meth:`Policy.act` is traced into the replay's
``lax.scan`` body — the method must therefore be pure jax: no Python
branching on traced values, fixed-shape state, no host syncs.

Contract (one call per trace interval, per design point):

``init_state(n_layers=None)``
    The controller's carry pytree (fixed-shape jnp leaves; ``()`` for
    stateless controllers).  It threads through the scan carry and vmaps
    over the case batch, so every design point owns an independent
    controller state.  ``n_layers`` (the static stack height) is passed
    by the replay so per-layer state (``GuardedPolicy``'s last-good
    hold) can be shaped; scalar-state controllers ignore it.

``act(state, ctx) -> (state', f_power, f_perf)``
    ``ctx`` is a :class:`PolicyContext` of *measured* (start-of-interval)
    quantities.  ``f_power`` scales the interval's dynamic power — a
    scalar (all layers together, the classic throttle) or an ``[L]``
    vector (per-die control for heterogeneous stacks).  ``f_perf`` is the
    scalar performance duty in ``(0, 1]`` the runtime-slowdown accounting
    uses (``mean(1/f_perf)``); for duty-cycling throttles the two
    coincide, for DVFS they split (power falls with ``f·V²``, performance
    only with ``f``).

Actuating on the measured sample — never the unknown end-of-interval
state — is what keeps the controller OUT of the replay's Picard fixed
point; see the ``stack/feedback.py`` module docstring for why iterating
a gain ≳ 1 bang-bang actuator there limit-cycles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PolicyContext(NamedTuple):
    """Measured inputs handed to :meth:`Policy.act` each interval.

    ``layer_T`` [L]: per-layer hot-spot temperature (°C) at the interval
    start; ``logic_mask``/``dram_mask`` [L]: 1.0 on layers of that kind;
    ``predict_hot``: duty candidates [K] → forecast logic hot spots [K]
    at the end of one replay substep under each candidate (the thermal
    RC one-step forecaster, ``cosim.interval_forecaster``).

    ``sensor_T`` [K, L]: ALL redundant sensor readings when the replay
    runs under a :class:`~repro.faults.models.SensorFaultSpec` (then
    ``layer_T`` is row 0, the primary sensor — possibly faulted), else
    ``None`` (fault-free: ``layer_T`` is the true measurement).  Only
    hardened controllers (``repro.faults.guard.GuardedPolicy``) look at
    it; naive policies sense the primary alone, by design.
    """
    layer_T: jax.Array
    logic_mask: jax.Array
    dram_mask: jax.Array
    predict_hot: Callable[[jax.Array], jax.Array]
    sensor_T: jax.Array | None = None


def masked_hot(layer_T: jax.Array, mask: jax.Array) -> jax.Array:
    """Hot spot over the masked layers (−inf when the mask is empty)."""
    return jnp.max(jnp.where(mask > 0, layer_T, -jnp.inf))


def ramp_duty(t_C, trip_C: float, ramp_C: float, floor: float):
    """The linear throttle law: duty 1 below ``trip_C``, ramping to
    ``floor`` over ``ramp_C`` degrees.  ``ramp_C == 0`` is a legal step
    trip (duty drops straight to the floor above ``trip_C``) — the
    guarded form of the historical ``1 - (t - trip)/ramp`` expression,
    which divided by the ramp width."""
    if ramp_C == 0.0:
        return jnp.where(t_C > trip_C, jnp.float32(floor),
                         jnp.float32(1.0))
    return jnp.clip(1.0 - (t_C - trip_C) / ramp_C, floor, 1.0)


def check_trip(trip_C: float, name: str = "trip_C") -> None:
    """Trip temperatures must be real or +inf (= never trips)."""
    if math.isnan(trip_C) or trip_C == -math.inf:
        raise ValueError(f"{name} must be a real temperature or math.inf "
                         f"(never trips); got {trip_C!r}")


def check_floor(floor: float, name: str = "floor") -> None:
    """Duty floors must sit in (0, 1] — 0 would make the slowdown
    accounting ``mean(1/f)`` divide by zero, above 1 is not a floor."""
    if not (0.0 < floor <= 1.0):
        raise ValueError(f"{name} must lie in (0, 1]; got {floor!r}")


@dataclasses.dataclass(frozen=True)
class Policy:
    """Base class: a no-op controller (always full power).

    Subclasses override :meth:`act` (and :meth:`init_state` when they
    carry state).  The base class doubles as the explicit "no DTM"
    policy.
    """

    @property
    def name(self) -> str:
        return type(self).__name__.removesuffix("Policy").lower()

    def init_state(self, n_layers: int | None = None):
        return ()

    def act(self, state, ctx: PolicyContext):
        one = jnp.float32(1.0)
        return state, one, one

    def residency(self, duty) -> dict[str, float] | None:
        """Optional post-hoc residency attribution for a recorded duty
        trace (``None`` = no discrete operating points to attribute)."""
        return None
