"""``repro.policy`` — the DVFS/DTM policy engine.

A *policy* is a sampled dynamic-thermal-management controller behind the
common :class:`~repro.policy.base.Policy` protocol: it reads measured
start-of-interval hot spots and sets the next interval's power and
performance duty.  The closed-loop replay (``repro.stack.feedback``)
threads the policy state through its ``lax.scan`` jit-compatibly, and
``SweepSpec.policies`` sweeps the registered names below as a
first-class scenario axis.  ``benchmarks/bench_policy.py`` scores the
family on performance × peak-temperature × energy Pareto frontiers
(helpers in :mod:`repro.policy.pareto`); docs/policies.md is the
doctested tour.
"""
from typing import Callable

from repro.policy.base import Policy, PolicyContext, masked_hot, ramp_duty
from repro.policy.controllers import (DVFSPolicy, HysteresisPolicy,
                                      PerDiePolicy, PIDPolicy,
                                      PredictivePolicy, RampPolicy)
from repro.policy.dvfs import (DVFSTable, OperatingPoint,
                               build_dvfs_table, nodes)
from repro.policy.pareto import dominates, pareto_front

def _guarded_perdie() -> Policy:
    # lazy: repro.faults.guard imports repro.policy.base, so importing
    # it at this module's load time would cycle.  The registry entry
    # wraps the DRAM-sensing per-die controller — the family's verdict
    # rescuer — in the sensor-fault hardening wrapper (docs/faults.md).
    from repro.faults.guard import GuardedPolicy
    return GuardedPolicy(inner=PerDiePolicy())


#: name -> zero-argument factory for the sweepable policy family; the
#: names are SweepSpec.policies values and the `policy/<name>/*`
#: telemetry prefixes (docs/observability.md)
POLICIES: dict[str, Callable[[], Policy]] = {
    "ramp": RampPolicy,
    "step": lambda: RampPolicy(ramp_C=0.0),
    "hysteresis": HysteresisPolicy,
    "pid": PIDPolicy,
    "perdie": PerDiePolicy,
    "dvfs": DVFSPolicy,
    "predictive": PredictivePolicy,
    "guarded": _guarded_perdie,
}


def names() -> tuple[str, ...]:
    """Registered policy names, registration order."""
    return tuple(POLICIES)


def get(name: str) -> Policy:
    """Instantiate a registered policy by name (fresh instance)."""
    try:
        factory = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; expected one of "
                         f"{names()}") from None
    return factory()


__all__ = [
    "Policy", "PolicyContext", "masked_hot", "ramp_duty",
    "RampPolicy", "HysteresisPolicy", "PIDPolicy", "PerDiePolicy",
    "DVFSPolicy", "PredictivePolicy",
    "DVFSTable", "OperatingPoint", "build_dvfs_table", "nodes",
    "dominates", "pareto_front",
    "POLICIES", "names", "get",
]
