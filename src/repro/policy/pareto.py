"""Pareto-frontier arithmetic for policy sweeps.

The policy bench scores every (scenario, machine, policy) run on three
minimized axes — runtime slowdown, peak temperature, energy-to-solution
— and reports the non-dominated set per (scenario, machine).  The math
is generic and tiny, so it lives here where both the bench and the docs
walkthrough (docs/policies.md) can import it.
"""
from __future__ import annotations

from typing import Sequence


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True when ``a`` is at least as good on every axis and strictly
    better on one (all axes minimized).

    >>> dominates((1.0, 80.0), (1.2, 85.0))
    True
    >>> dominates((1.0, 90.0), (1.2, 85.0))   # trades temp for speed
    False
    >>> dominates((1.0, 80.0), (1.0, 80.0))   # equal points don't
    False
    """
    if len(a) != len(b):
        raise ValueError("points must share a dimension")
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


def pareto_front(points: Sequence[Sequence[float]]) -> tuple[int, ...]:
    """Indices of the non-dominated points, in input order.

    Duplicated coordinates are all kept (none dominates its twin):

    >>> pareto_front([(1.0, 95.0), (2.5, 70.0), (2.6, 96.0), (1.0, 95.0)])
    (0, 1, 3)
    """
    return tuple(i for i, p in enumerate(points)
                 if not any(dominates(q, p) for j, q in enumerate(points)
                            if j != i))
