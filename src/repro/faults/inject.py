"""Deterministic failure injection for the solver fallback chain.

``core/thermal._solve_fields_guarded`` walks a fallback chain of linear
backends and advances past any attempt whose TRUE relative residual is
non-finite or above the health bar.  Testing/benchmarking that path
needs a way to make a backend fail ON DEMAND without perturbing the
physics — :func:`poison_solver` is that hook: inside the context the
named backends return a NaN solution (the signature of a diverged
solve), so the health check fires exactly as it would on a genuine
divergence and the chain retries down the list.

The poison set is process-local host state consulted OUTSIDE any jit
(at dispatch time, in the guarded driver), so it composes with
compiled solves and costs nothing when empty.
"""
from __future__ import annotations

import contextlib

_POISONED: set[str] = set()


def solver_poisoned(name: str) -> bool:
    """Is ``name`` currently forced to diverge?  (host-side check)"""
    return name in _POISONED


@contextlib.contextmanager
def poison_solver(*names: str):
    """Force the named solver backends ("pcg"/"mg"/"mgcg") to return a
    NaN solution inside the context — a deterministic stand-in for
    divergence that exercises the real detection + fallback path."""
    added = set(names) - _POISONED
    _POISONED.update(added)
    try:
        yield
    finally:
        _POISONED.difference_update(added)


__all__ = ["poison_solver", "solver_poisoned"]
