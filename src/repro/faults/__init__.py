"""``repro.faults`` — fault injection & graceful degradation.

Three pieces (docs/faults.md is the doctested tour):

- :mod:`repro.faults.models` — deterministic, jit/vmap-compatible
  sensor-fault models (:class:`SensorFaultSpec`) threaded through the
  closed-loop scan carry via ``FeedbackParams.faults``, plus host-side
  power-spike injection (:class:`PowerFaultSpec`).
- :mod:`repro.faults.guard` — :class:`GuardedPolicy`, hardening any
  registered DTM controller with median-of-K sensor fusion, last-good
  hold, and a fail-safe floor duty (registered as ``"guarded"``).
- :mod:`repro.faults.inject` — :func:`poison_solver`, the deterministic
  forced-divergence hook behind the solver fallback chain.
"""
from repro.faults.guard import GuardedPolicy
from repro.faults.inject import poison_solver, solver_poisoned
from repro.faults.models import (FaultState, PowerFaultSpec,
                                 SensorFaultSpec, inject_power_spikes)

__all__ = [
    "SensorFaultSpec", "FaultState", "PowerFaultSpec",
    "inject_power_spikes", "GuardedPolicy", "poison_solver",
    "solver_poisoned",
]
