"""Graceful degradation for DTM controllers: the :class:`GuardedPolicy`.

Any registered policy senses ``PolicyContext.layer_T`` — under a
:class:`~repro.faults.models.SensorFaultSpec` that is the (possibly
stuck, noisy, or NaN) PRIMARY sensor, and a naive controller inherits
every one of its failure modes: a stuck-at-ambient sensor never trips
the throttle, a dropout NaN propagates straight into the duty and from
there into every temperature of the replay.

``GuardedPolicy`` wraps an inner policy with three layers of hardening,
in order:

1. **median-of-K** over the redundant sensors
   (``PolicyContext.sensor_T``, NaN-skipping) — rejects any minority of
   stuck/outlier sensors per layer;
2. **plausibility + last-good hold** — a fused reading must be finite,
   inside ``[lo_C, hi_C]``, and within ``max_step_C`` of the last
   accepted value; otherwise the guard holds the last good reading for
   that layer;
3. **fail-safe floor** — after ``hold_max`` consecutive implausible
   intervals on any die layer the guard stops trusting its held value
   and clamps both duties to ``floor`` (thermal safety beats
   throughput when the stack is flying blind).

The wrapper is itself a frozen-dataclass :class:`Policy`, so it nests
anywhere a policy goes (``FeedbackParams.policy``, the sweep policy
axis as ``"guarded"``) and its state — ``(inner state, last-good [L],
consecutive-bad count [L])`` — threads through the scan carry like any
controller's.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.constants import AMBIENT_C
from repro.policy.base import Policy, PolicyContext, check_floor


@dataclasses.dataclass(frozen=True)
class GuardedPolicy(Policy):
    """Median-of-K + last-good-hold + fail-safe floor around ``inner``."""
    inner: Policy = dataclasses.field(default_factory=Policy)
    lo_C: float = -20.0          # plausible sensor range (DTS span)
    hi_C: float = 150.0
    max_step_C: float = 60.0     # max credible interval-to-interval jump
    hold_max: int = 3            # consecutive bad intervals before panic
    floor: float = 0.25          # fail-safe duty once panicked

    def __post_init__(self):
        check_floor(self.floor)
        if not (math.isfinite(self.lo_C) and math.isfinite(self.hi_C)
                and self.lo_C < self.hi_C):
            raise ValueError("need finite lo_C < hi_C; got "
                             f"({self.lo_C!r}, {self.hi_C!r})")
        if not (math.isfinite(self.max_step_C) and self.max_step_C > 0):
            raise ValueError("max_step_C must be finite and > 0; got "
                             f"{self.max_step_C!r}")
        if self.hold_max < 1:
            raise ValueError(f"hold_max must be >= 1; got {self.hold_max!r}")

    @property
    def name(self) -> str:
        return f"guarded-{self.inner.name}"

    def init_state(self, n_layers: int | None = None):
        if n_layers is None:
            raise ValueError("GuardedPolicy.init_state needs n_layers "
                             "(its last-good hold is per layer)")
        return (self.inner.init_state(n_layers),
                jnp.full((n_layers,), AMBIENT_C, jnp.float32),
                jnp.zeros((n_layers,), jnp.int32))

    def act(self, state, ctx: PolicyContext):
        inner_state, last_good, bad = state
        readings = ctx.sensor_T
        if readings is None:         # fault-free replay: one true sensor
            readings = ctx.layer_T[None, :]
        fused = jnp.nanmedian(readings, axis=0)
        plausible = (jnp.isfinite(fused)
                     & (fused >= self.lo_C) & (fused <= self.hi_C)
                     & (jnp.abs(fused - last_good) <= self.max_step_C))
        T_used = jnp.where(plausible, fused, last_good)
        bad = jnp.where(plausible, jnp.int32(0), bad + 1)
        inner_state, f_power, f_perf = self.inner.act(
            inner_state, ctx._replace(layer_T=T_used, sensor_T=None))
        # panic only on DIE layers the verdict cares about: a spreader
        # sensor going dark must not floor the whole stack
        die = (ctx.logic_mask + ctx.dram_mask) > 0
        panic = jnp.any(die & (bad >= self.hold_max))
        f_floor = jnp.float32(self.floor)
        f_power = jnp.where(panic, jnp.minimum(f_power, f_floor), f_power)
        f_perf = jnp.where(panic, jnp.minimum(f_perf, f_floor), f_perf)
        return (inner_state, T_used, bad), f_power, f_perf


__all__ = ["GuardedPolicy"]
