"""Deterministic, jit/vmap-compatible fault models for the closed loop.

Real 3D thermal sensors are not the oracle the DTM controllers in
``repro.policy`` assume: they are noisy, biased, quantized to the DTS
step, occasionally latch (stuck-at), and sometimes return garbage
(dropout).  A :class:`SensorFaultSpec` is a frozen, hashable description
of that sensing regime — it rides on
:class:`~repro.stack.feedback.FeedbackParams` as a jit static argument,
and its :meth:`SensorFaultSpec.read` is traced straight into the
replay's ``lax.scan`` body with the fault state (PRNG key, interval
counter, stuck-at latches) threaded through the scan carry exactly like
policy state.  Everything is seeded ``jax.random``, so a replay under
faults is bitwise reproducible (and device-count-invariant under
``closed_loop_sharded``; ``tests/test_faults.py``).

Sub-faults whose knob is zero are compile-time dead: ``read`` branches
on the (static) spec fields in Python, so a disabled sub-fault adds
ZERO traced operations — and a replay with no spec at all
(``FeedbackParams.faults = None``) is bit-identical to the fault-free
program (pinned by a jaxpr-equality test).

:class:`PowerFaultSpec` is the host-side counterpart for the *input*
trace: deterministic transient power spikes injected on selected
intervals of the dynamic-power frames before assembly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class FaultState(NamedTuple):
    """Per-design-point fault carry (fixed-shape jnp leaves).

    ``key``: the spec's PRNG chain; ``t``: interval counter (drives
    drift); ``latch`` [K, L]: stuck-at sensors' frozen readings (NaN =
    not yet latched); ``offset`` [K]: per-sensor static bias drawn once
    at init from the seed.
    """
    key: jax.Array
    t: jax.Array
    latch: jax.Array
    offset: jax.Array


def _check_finite_nonneg(name: str, v: float) -> None:
    if not (math.isfinite(v) and v >= 0):
        raise ValueError(f"{name} must be finite and >= 0; got {v!r}")


@dataclasses.dataclass(frozen=True)
class SensorFaultSpec:
    """One deterministic sensing regime for the per-layer hot-spot DTS.

    The replay reads ``n_sensors`` redundant sensors per layer; naive
    policies see sensor 0 (``PolicyContext.layer_T``), hardened ones
    see all K (``PolicyContext.sensor_T``,
    :class:`~repro.faults.guard.GuardedPolicy`).  Per reading, in order:

    - ``offset_C``: per-sensor static bias ~ N(0, offset_C), drawn once
      from the seed (sensor 0 included — calibration error).
    - ``drift_C``: common-mode linear drift, ``drift_C`` °C per
      interval (uncompensated aging; median-of-K cannot reject it, the
      guard's range check eventually does).
    - ``noise_C``: white Gaussian read noise, sigma per reading.
    - ``quant_C``: DTS quantization step (round-to-nearest).
    - ``n_stuck``: sensors ``[0, n_stuck)`` latch their FIRST reading
      forever (deterministic stuck-at; sensor 0 first, so one stuck
      sensor blinds exactly the naive policies).
    - ``p_dropout``: per reading per interval, probability the sample
      is lost and returned as NaN.
    """
    seed: int = 0
    n_sensors: int = 3
    noise_C: float = 0.0
    offset_C: float = 0.0
    drift_C: float = 0.0
    quant_C: float = 0.0
    n_stuck: int = 0
    p_dropout: float = 0.0

    def __post_init__(self):
        if self.n_sensors < 1:
            raise ValueError("n_sensors must be >= 1; got "
                             f"{self.n_sensors!r}")
        for name in ("noise_C", "offset_C", "quant_C"):
            _check_finite_nonneg(name, getattr(self, name))
        if not math.isfinite(self.drift_C):
            raise ValueError(f"drift_C must be finite; got {self.drift_C!r}")
        if not 0 <= self.n_stuck <= self.n_sensors:
            raise ValueError("n_stuck must lie in [0, n_sensors]; got "
                             f"{self.n_stuck!r}")
        if not (math.isfinite(self.p_dropout)
                and 0.0 <= self.p_dropout <= 1.0):
            raise ValueError("p_dropout must lie in [0, 1]; got "
                             f"{self.p_dropout!r}")

    @property
    def randomized(self) -> bool:
        """Does any enabled sub-fault consume PRNG randomness?"""
        return self.noise_C > 0 or self.p_dropout > 0

    def init_state(self, n_layers: int) -> FaultState:
        """The scan-carry pytree for one design point (L = n_layers)."""
        key = jax.random.PRNGKey(self.seed)
        K = self.n_sensors
        if self.offset_C > 0:
            key, sub = jax.random.split(key)
            offset = self.offset_C * jax.random.normal(sub, (K,))
        else:
            offset = jnp.zeros((K,), jnp.float32)
        latch = jnp.full((K, n_layers), jnp.nan, jnp.float32)
        return FaultState(key=key, t=jnp.int32(0), latch=latch,
                          offset=offset.astype(jnp.float32))

    def read(self, state: FaultState,
             true_T: jax.Array) -> tuple[FaultState, jax.Array]:
        """Sample all K sensors once: ``true_T`` [L] -> readings [K, L].

        Pure jax, fixed shapes; every ``if`` below is on a STATIC spec
        field, so disabled sub-faults are absent from the traced
        program.  Returns ``(state', readings)``.
        """
        key, latch = state.key, state.latch
        K = self.n_sensors
        r = jnp.broadcast_to(true_T.astype(jnp.float32),
                             (K,) + true_T.shape)
        if self.offset_C > 0:
            r = r + state.offset[:, None]
        if self.drift_C != 0.0:
            r = r + self.drift_C * state.t.astype(jnp.float32)
        if self.noise_C > 0:
            key, sub = jax.random.split(key)
            r = r + self.noise_C * jax.random.normal(sub, r.shape)
        if self.quant_C > 0:
            r = jnp.round(r / self.quant_C) * self.quant_C
        if self.n_stuck > 0:
            latch = jnp.where(jnp.isnan(latch), r, latch)
            stuck = (jnp.arange(K) < self.n_stuck)[:, None]
            r = jnp.where(stuck, latch, r)
        if self.p_dropout > 0:
            key, sub = jax.random.split(key)
            drop = jax.random.uniform(sub, r.shape) < self.p_dropout
            r = jnp.where(drop, jnp.nan, r)
        return FaultState(key=key, t=state.t + 1, latch=latch,
                          offset=state.offset), r


# ---------------------------------------------------------------------------
# input-trace faults: transient power spikes (host-side, pre-assembly)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerFaultSpec:
    """Deterministic transient power spikes on an interval trace.

    ``n_spikes`` intervals (chosen by the seeded generator, without
    replacement) have their dynamic-power frame scaled by
    ``magnitude``; each spike extends over ``width`` consecutive
    intervals.  Applied host-side by :func:`inject_power_spikes`
    BEFORE case assembly, so the replay itself is untouched — the
    spike is an input perturbation, not a model change.
    """
    seed: int = 0
    n_spikes: int = 1
    magnitude: float = 2.0
    width: int = 1

    def __post_init__(self):
        if self.n_spikes < 0:
            raise ValueError(f"n_spikes must be >= 0; got {self.n_spikes!r}")
        if self.width < 1:
            raise ValueError(f"width must be >= 1; got {self.width!r}")
        _check_finite_nonneg("magnitude", self.magnitude)


def inject_power_spikes(dyn_frames: np.ndarray,
                        spec: PowerFaultSpec) -> np.ndarray:
    """Scale ``spec.n_spikes`` seeded intervals of ``dyn_frames`` [T, ...]
    by ``spec.magnitude`` (each spike ``spec.width`` intervals long).
    Returns a new array; the input is not modified."""
    out = np.array(dyn_frames, copy=True)
    T = out.shape[0]
    if spec.n_spikes == 0 or T == 0:
        return out
    rng = np.random.default_rng(spec.seed)
    starts = rng.choice(T, size=min(spec.n_spikes, T), replace=False)
    for s in starts:
        out[s:s + spec.width] *= spec.magnitude
    return out


__all__ = ["SensorFaultSpec", "FaultState", "PowerFaultSpec",
           "inject_power_spikes"]
