"""Device-resident programs for the data-dependent workload inner loops.

The eager :class:`~repro.core.engine.APEngine` path performs a blocking
host sync (``int(bp.popcount(tag))``) after every compare/write cycle,
so data-dependent workloads (sort, knn, spmv, hist) used to run
thousands of sequential device round-trips.  The two programs here keep
the whole inner loop resident (the CoMeT interval-simulation lesson,
arXiv:2109.12405 applied at the engine layer):

* :func:`min_extract_rounds` — the MSB-first CAM min-extraction idiom
  shared by ``workloads/sort.py`` and ``workloads/knn.py``, compiled as
  ONE ``lax.scan`` over extraction rounds.  The eager "did any candidate
  respond?" branch becomes an on-device :func:`~repro.core.engine.select_state`;
  rounds after the (data-dependent) termination point are masked no-ops.
* :func:`count_probes` — a batch of response-counter COMPAREs (the
  per-bin counting of ``histogram.py``, the per-(row, bit) tag-count
  accumulation of ``spmv.py``) as one scanned program.

Both transfer their per-pass matched counts to the host ONCE per
workload phase and replay them through the engine's ``charge_*``
accounting, which makes cycles / energy / events / trace arrays
bit-identical to the eager per-cycle oracle
(tests/test_device_workloads.py pins this for every workload).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitplane as bp
from repro.core import isa
from repro.core import engine as E
from repro.core.bitplane import Field
from repro.core.engine import APEngine, PassSchedule, _next_pow2
from repro.kernels.ap_megakernel import ref as mk_ref
from repro.kernels.ap_megakernel import ops as mk_ops


# ---------------------------------------------------------------------------
# shared min-extraction scan (sort + knn)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MinExtractTrace:
    """Per-round matched counts of one device min-extraction program.

    Arrays are [rounds, ...]; narrowing axes run MSB -> LSB (the eager
    iteration order).  ``masked[r]`` is True for rounds after the
    data-dependent termination point (device no-ops the host never
    replays).  ``device_counters`` are the program's own on-device
    :data:`~repro.core.engine.APState` counter totals, cross-checked
    against the host replay in the tests.
    """
    copy_sched: PassSchedule
    copy_matched: np.ndarray   # [R, P_copy] per-pass counts of cand<-active
    m1: np.ndarray             # [R, m] responders of the 0-probe compare
    m2: np.ndarray             # [R, m] responders of the retire compare
    take: np.ndarray           # [R, m] bool: the eager branch was taken
    count: np.ndarray          # [R] tie-group size of the extracted min
    tie_tag: np.ndarray        # [R, n_lanes] packed tie-group TAG
    masked: np.ndarray         # [R] bool: round ran as a masked no-op
    device_counters: np.ndarray  # int32[N_COUNTERS]


@partial(jax.jit, static_argnames=("val_cols", "active_col", "cand_col",
                                   "rounds", "readout"))
def _min_extract_program(state, copy_cc, copy_ck, copy_wc, copy_wk,
                         remaining, *, val_cols, active_col, cand_col,
                         rounds, readout):
    obs.count("workloads/retrace/min_extract")
    obs.count(f"workloads/retrace/min_extract[m={len(val_cols)},"
              f"rounds={rounds},readout={readout}]")
    cand = jnp.array([cand_col], jnp.int32)
    active = jnp.array([active_col], jnp.int32)
    one = jnp.array([1], jnp.uint32)
    zero = jnp.array([0], jnp.uint32)

    def body(carry, _):
        st0, done, rem = carry
        st, copy_m = E.state_run(st0, copy_cc, copy_ck, copy_wc, copy_wk)
        m1s, m2s, takes = [], [], []
        for i in reversed(range(len(val_cols))):
            cv = jnp.array([cand_col, val_cols[i]], jnp.int32)
            st_c, m1 = E.state_compare(st, cv, jnp.array([1, 0], jnp.uint32))
            # the eager branch: if any candidate has a 0 here, retire the
            # 1-candidates — on device both arms run, one is selected
            st_b, m2 = E.state_compare(st_c, cv, jnp.array([1, 1], jnp.uint32))
            st_b, _ = E.state_write(st_b, cand, zero)
            take = m1 > 0
            st = E.select_state(take, st_b, st_c)
            m1s.append(m1)
            m2s.append(m2)
            takes.append(take)
        st, count = E.state_compare(st, cand, one)
        tie_tag = st.tag
        if readout:
            # knn: sequential responder readout + re-compare + retire
            st = E.state_read_charge(st, count)
            st, _ = E.state_compare(st, cand, one)
            st, _ = E.state_write(st, active, zero)
        else:
            # sort: retire the tie group unless the active set was empty
            st_r, _ = E.state_write(st, active, zero)
            st = E.select_state(count > 0, st_r, st)
        new_rem = rem - count
        st_out = E.select_state(done, st0, st)
        rem_out = jnp.where(done, rem, new_rem)
        done_out = done | (count == 0) | (new_rem <= 0)
        ys = (copy_m, jnp.stack(m1s), jnp.stack(m2s), jnp.stack(takes),
              count, tie_tag, done)
        return (st_out, done_out, rem_out), ys

    init = (state, jnp.bool_(False), jnp.asarray(remaining, jnp.int32))
    (state, _, _), ys = jax.lax.scan(body, init, None, length=rounds)
    return state, ys


def min_extract_rounds(eng: APEngine, val: Field, active: Field, cand: Field,
                       rounds: int, remaining: int,
                       readout: bool = False) -> MinExtractTrace:
    """Run up to ``rounds`` min-extractions over ``active`` rows on device.

    One compiled program, one host transfer.  The engine adopts the final
    array state; NO cycles/energy are charged here — the caller replays
    the returned counts through :func:`replay_extract` + ``charge_*`` in
    eager order.  ``remaining`` is the termination budget (elements left
    to emit: n for sort, k for knn); ``readout`` adds knn's per-round
    responder readout + re-compare + retire to the program.
    """
    copy_sched = isa.copy(cand, active)
    state, ys = _min_extract_program(
        eng.state(),
        jnp.asarray(copy_sched.cmp_cols), jnp.asarray(copy_sched.cmp_key),
        jnp.asarray(copy_sched.w_cols), jnp.asarray(copy_sched.w_key),
        remaining,
        val_cols=tuple(val.cols()), active_col=active.col(0),
        cand_col=cand.col(0), rounds=rounds, readout=readout)
    copy_m, m1, m2, take, count, tie_tag, masked = jax.device_get(ys)
    ctr = np.asarray(jax.device_get(state.counters))
    eng.adopt(state)
    return MinExtractTrace(copy_sched, np.asarray(copy_m), np.asarray(m1),
                           np.asarray(m2), np.asarray(take),
                           np.asarray(count), np.asarray(tie_tag),
                           np.asarray(masked), ctr)


def replay_extract(eng: APEngine, tr: MinExtractTrace, r: int,
                   m: int) -> tuple[int, int]:
    """Charge round ``r``'s extraction events in eager order.

    Mirrors ``sort.extract_min`` exactly: the fused candidate copy, the
    MSB-first narrowing (second compare + retire write only where the
    branch was taken), and the final tie-group compare.  Returns
    (min_value, tie_count).
    """
    eng.charge_run(tr.copy_sched, tr.copy_matched[r])
    v = 0
    for pos, i in enumerate(reversed(range(m))):
        eng.charge_compare(2, tr.m1[r, pos])
        if tr.take[r, pos]:
            eng.charge_compare(2, tr.m2[r, pos])
            eng.charge_write(1, tr.m2[r, pos])
        else:
            v |= 1 << i
    eng.charge_compare(1, tr.count[r])
    return v, int(tr.count[r])


def tagged_rows(tag_row: np.ndarray) -> np.ndarray:
    """Row indices set in a packed TAG row (host-side unpack)."""
    shifts = np.arange(bp.LANE, dtype=np.uint32)
    bits = (np.asarray(tag_row, np.uint32)[:, None] >> shifts[None, :]) & 1
    return np.where(bits.reshape(-1))[0]


# ---------------------------------------------------------------------------
# batched response counting (hist + spmv)
# ---------------------------------------------------------------------------

@jax.jit
def _count_probes_program(state, cols, keys, real):
    obs.count("workloads/retrace/count_probes")
    obs.count(f"workloads/retrace/count_probes[n={cols.shape[0]},"
              f"k={cols.shape[1]}]")

    def body(st0, xs):
        cc, kk, is_real = xs
        st, matched = E.state_compare(st0, cc, kk)
        st = E.select_state(is_real, st, st0)
        return st, matched

    return jax.lax.scan(body, state, (cols, keys, real))


def count_probes(eng: APEngine, cols, keys) -> np.ndarray:
    """Run a batch of COMPAREs as one device program; return responder
    counts [n_probes] (int64).

    The probe shape is padded to power-of-two buckets (padded probes are
    masked on device and sliced off here), so nearby probe batches share
    one compiled program.  The engine adopts the final state — TAG holds
    the LAST probe's responders, as after the eager loop — and every
    probe's compare cycle is charged in order.
    """
    cols = np.atleast_2d(np.asarray(cols, np.int32))
    keys = np.atleast_2d(np.asarray(keys, np.uint32))
    n_probes, k = cols.shape
    np2, k2 = _next_pow2(n_probes), _next_pow2(k)

    def pad(a):
        if k2 != k:
            a = np.concatenate(
                [a, np.repeat(a[:, :1], k2 - k, axis=1)], axis=1)
        if np2 != n_probes:
            a = np.concatenate(
                [a, np.repeat(a[-1:], np2 - n_probes, axis=0)], axis=0)
        return a

    real = np.arange(np2) < n_probes
    state, counts = _count_probes_program(
        eng.state(), jnp.asarray(pad(cols)), jnp.asarray(pad(keys)),
        jnp.asarray(real))
    counts = np.asarray(jax.device_get(counts))[:n_probes].astype(np.int64)
    eng.adopt(state)
    for i in range(n_probes):
        eng.charge_compare(k, counts[i])
    return counts


# ---------------------------------------------------------------------------
# megakernel mode: op-group device programs + bulk (vectorized) host replay
# ---------------------------------------------------------------------------
#
# The device programs above already run resident; at n_elems >= ~2048 the
# wall-clock is dominated by the *host* side — per-event charge_* Python
# loops and per-scalar trace appends.  The megakernel mode attacks both
# ends: one fused op-group program per phase on device (the whole
# min-extraction round is a single OpGroup executed by the megakernel,
# optionally shard_map-ed over the lane axis), and ONE vectorized
# charge_bulk fold on the host, built to be bit-identical to the eager
# per-event replay (see APEngine.charge_bulk for the contract; the
# property harness enforces it sample by sample).


def engine_backend(backend: str, mode: str) -> str:
    """Map a workload (backend, mode) pair to the APEngine backend.

    ``mode="megakernel"`` lowers the engine's schedule path through the
    megakernel too: jnp -> 'megakernel' (fused scan, shardable),
    pallas -> 'megakernel_pallas' (the Pallas kernel)."""
    if mode != "megakernel":
        return backend
    if backend in ("jnp", "megakernel"):
        return "megakernel"
    if backend in ("pallas", "megakernel_pallas"):
        return "megakernel_pallas"
    raise ValueError(f"unknown backend {backend!r}")


def _min_extract_group(copy_sched: PassSchedule, val: Field, active: Field,
                       cand: Field, readout: bool) -> mk_ref.OpGroup:
    """One min-extraction round as a static op group.

    Table layout (indices the trace decoder below relies on):
    [0, P_copy)            PASS     the cand <- active copy schedule
    P_copy + 3*pos + 0     CMP      probe (cand, val_bit)==(1, 0) -> m1
    P_copy + 3*pos + 1     CMP      retire probe ==(1, 1), iff m1 > 0
    P_copy + 3*pos + 2     WRITE    cand <- 0,              iff m1 > 0
    P_copy + 3*m           CMP      tie group (cand == 1) -> count
    then sort: WRITE active <- 0 iff count > 0
    or   knn: CMP cand == 1; WRITE active <- 0 (both unconditional;
    the sequential responder read rides the scan wrapper's counters).
    """
    ops = []
    for p in range(copy_sched.n_passes):
        ops.append((mk_ref.OP_PASS, 0,
                    copy_sched.cmp_cols[p].tolist(),
                    copy_sched.cmp_key[p].tolist(),
                    copy_sched.w_cols[p].tolist(),
                    copy_sched.w_key[p].tolist()))
    c0 = cand.col(0)
    for i in reversed(range(val.width)):
        cv = [c0, val.col(i)]
        ops.append((mk_ref.OP_CMP, 0, cv, [1, 0], [], []))
        ops.append((mk_ref.OP_CMP, 1, cv, [1, 1], [], []))
        ops.append((mk_ref.OP_WRITE, 2, [], [], [c0], [0]))
    ops.append((mk_ref.OP_CMP, 0, [c0], [1], [], []))
    if readout:
        ops.append((mk_ref.OP_CMP, 0, [c0], [1], [], []))
        ops.append((mk_ref.OP_WRITE, 0, [], [], [active.col(0)], [0]))
    else:
        ops.append((mk_ref.OP_WRITE, 1, [], [], [active.col(0)], [0]))
    return mk_ref.OpGroup.build(ops)


def _mk_rounds_impl(state, op, cond, cc, ck, wc, wk, remaining, rounds,
                    readout, axis_name):
    """Scan ``rounds`` op-group executions with the same termination /
    masking semantics as ``_min_extract_program`` (shard_map-able)."""
    count_idx = op.shape[0] - (3 if readout else 2)
    enabled = jnp.ones(op.shape[0], jnp.bool_)

    def body(carry, _):
        st0, done, rem = carry
        planes, tag, matched, executed = mk_ref.group_scan(
            st0.planes, st0.tag, (op, cond, cc, ck, wc, wk), enabled,
            axis_name)
        delta = mk_ref.counter_delta(op, matched, executed)
        count = matched[count_idx]
        if readout:
            delta = delta.at[E.CTR_CYCLES].add(count) \
                .at[E.CTR_READ].add(count)
        st = E.APState(planes, tag, st0.counters + delta)
        new_rem = rem - count
        st_out = E.select_state(done, st0, st)
        rem_out = jnp.where(done, rem, new_rem)
        done_out = done | (count == 0) | (new_rem <= 0)
        ys = (matched, tag, done)
        return (st_out, done_out, rem_out), ys

    init = (state, jnp.bool_(False), jnp.asarray(remaining, jnp.int32))
    (state, _, _), ys = jax.lax.scan(body, init, None, length=rounds)
    return state, ys


@partial(jax.jit, static_argnames=("rounds", "readout"))
def _mk_rounds_program(state, op, cond, cc, ck, wc, wk, remaining, *,
                       rounds, readout):
    obs.count("workloads/retrace/min_extract_mk")
    obs.count(f"workloads/retrace/min_extract_mk[P={op.shape[0]},"
              f"rounds={rounds},readout={readout}]")
    return _mk_rounds_impl(state, op, cond, cc, ck, wc, wk, remaining,
                           rounds, readout, axis_name=None)


@functools.lru_cache(maxsize=None)
def _mk_rounds_sharded(mesh, rounds, readout):
    """jit(shard_map(...)) of the rounds program over the 'lanes' axis,
    cached per (mesh, shape) so re-runs reuse the compiled program."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    st_spec = E.APState(P(None, "lanes"), P("lanes"), P())
    rep = P()

    def body(state, op, cond, cc, ck, wc, wk, remaining):
        return _mk_rounds_impl(state, op, cond, cc, ck, wc, wk, remaining,
                               rounds, readout, axis_name="lanes")

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(st_spec, rep, rep, rep, rep, rep, rep, rep),
        out_specs=(st_spec, (rep, P(None, "lanes"), rep)),
        check_rep=False)

    @jax.jit
    def run(state, op, cond, cc, ck, wc, wk, remaining):
        obs.count("workloads/retrace/min_extract_mk_sharded")
        return mapped(state, op, cond, cc, ck, wc, wk, remaining)

    return run


def min_extract_rounds_mk(eng: APEngine, val: Field, active: Field,
                          cand: Field, rounds: int, remaining: int,
                          readout: bool = False) -> MinExtractTrace:
    """Megakernel counterpart of :func:`min_extract_rounds`: each round
    is ONE fused op-group execution (sharded over lanes when the engine
    has ``n_shards``), returning the identical :class:`MinExtractTrace`
    so the replay layer is shared."""
    copy_sched = isa.copy(cand, active)
    group = _min_extract_group(copy_sched, val, active, cand, readout)
    obs.count("kernels/launch/ap_megakernel")
    obs.count("kernels/launch/ap_megakernel/min_extract_rounds")
    tables = tuple(jnp.asarray(t) for t in group.tables())
    if eng.mesh is not None:
        state, ys = _mk_rounds_sharded(eng.mesh, rounds, readout)(
            eng.state(), *tables, jnp.asarray(remaining, jnp.int32))
    else:
        state, ys = _mk_rounds_program(eng.state(), *tables, remaining,
                                       rounds=rounds, readout=readout)
    matched, tie_tag, masked = (np.asarray(a) for a in jax.device_get(ys))
    ctr = np.asarray(jax.device_get(state.counters))
    eng.adopt(state)
    Pc = copy_sched.n_passes
    m = val.width
    base = Pc + 3 * np.arange(m)
    m1 = matched[:, base]
    m2 = matched[:, base + 1]
    return MinExtractTrace(copy_sched, matched[:, :Pc], m1, m2, m1 > 0,
                           matched[:, Pc + 3 * m], tie_tag, masked, ctr)


def replay_extract_bulk(eng: APEngine, tr: MinExtractTrace, m: int,
                        budget: int, readout: bool = False
                        ) -> tuple[np.ndarray, np.ndarray, int]:
    """Charge every replayed round's events in ONE bulk fold.

    Replays exactly the rounds (and the per-round tails) the eager
    per-round loop would — sort: conditional tie-group retire, stop on
    a zero count; knn (``readout=True``): responder reads + re-compare
    + retire, stop when ``budget`` indices have been emitted — and
    folds them through :meth:`APEngine.charge_bulk`.  Returns
    (min_values[r_used], tie_counts[r_used], r_used); values follow
    from the recorded branch decisions (bit i of the round's minimum is
    1 iff the 0-probe at bit i had no responders).
    """
    counts = tr.count.astype(np.int64)
    R = counts.shape[0]
    r_used, out_len, tail = 0, 0, []
    if readout:
        while out_len < budget:
            out_len += min(int(counts[r_used]), budget - out_len)
            tail.append(True)
            r_used += 1
    else:
        while out_len < budget and r_used < R:
            c = int(counts[r_used])
            tail.append(c > 0)
            r_used += 1
            if c == 0:
                break
            out_len += c
    if r_used == 0:
        return np.zeros(0, np.uint64), counts[:0], 0

    Ru = r_used
    n = eng.n_words
    pw = eng.power
    sched = tr.copy_sched
    Pc = sched.n_passes
    take = tr.take[:Ru]                              # [Ru, m] bool
    cnt = counts[:Ru]
    tailp = np.asarray(tail, bool)

    # --- per-round scalar slots after the copy chunk:
    #     [cmp1, cmp2?, wr?] x m, count_cmp, then the tail
    S = 3 * m + (4 if readout else 2)
    present = np.zeros((Ru, S), bool)
    e_scal = np.zeros((Ru, S), np.float64)
    is_trace = np.ones(S, bool)
    c1, c2, wr = (3 * np.arange(m) + d for d in (0, 1, 2))
    present[:, c1] = True
    present[:, c2] = take
    present[:, wr] = take
    ci = 3 * m
    present[:, ci] = True
    if readout:
        rd, rc, rt = ci + 1, ci + 2, ci + 3
        present[:, rd:] = True
        is_trace[rd] = False                         # reads carry no event
    else:
        rt = ci + 1
        present[:, rt] = tailp
    delta = present.astype(np.int64)                 # cycles per slot
    if readout:
        delta[:, rd] = np.where(present[:, rd], cnt, 0)

    m1f = tr.m1[:Ru].astype(np.float64)
    m2f = tr.m2[:Ru].astype(np.float64)
    cf = cnt.astype(np.float64)
    e_scal[:, c1] = 2 * (pw.p_m * m1f + pw.p_mm * (n - m1f))
    e_scal[:, c2] = 2 * (pw.p_m * m2f + pw.p_mm * (n - m2f))
    e_scal[:, wr] = 1 * (pw.p_w * m2f + pw.p_mw * (n - m2f))
    e_scal[:, ci] = 1 * (pw.p_m * cf + pw.p_mm * (n - cf))
    if readout:
        e_scal[:, rc] = 1 * (pw.p_m * cf + pw.p_mm * (n - cf))
    e_scal[:, rt] = 1 * (pw.p_w * cf + pw.p_mw * (n - cf))

    # --- the copy chunk: per-pass energies exactly as charge_run
    kc = sched.kc.astype(np.float64)
    kw = sched.kw.astype(np.float64)
    mf = tr.copy_matched[:Ru].astype(np.float64)     # [Ru, Pc]
    e_pass = kc[None, :] * (pw.p_m * mf + pw.p_mm * (n - mf)) \
        + kw[None, :] * (pw.p_w * mf + pw.p_mw * (n - mf))
    chunk = e_pass.sum(axis=1)    # row-wise: identical to charge_run's 1D sum

    # --- absolute event cycles (post-increment, as eager appends them)
    round_delta = 2 * Pc + delta.sum(axis=1)
    c_start = eng.cycles + np.concatenate(
        [[0], np.cumsum(round_delta)[:-1]]).astype(np.int64)
    pass_cyc = c_start[:, None] + 2 * np.arange(1, Pc + 1, dtype=np.int64)
    scal_cyc = c_start[:, None] + 2 * Pc + np.cumsum(delta, axis=1)

    ev_present = present & is_trace[None, :]
    all_present = np.hstack([np.ones((Ru, Pc), bool), ev_present])
    trace_c = np.hstack([pass_cyc, scal_cyc])[all_present]
    trace_e = np.hstack([e_pass, e_scal])[all_present]
    terms = np.hstack([chunk[:, None], e_scal])[
        np.hstack([np.ones((Ru, 1), bool), ev_present])]

    m1s = tr.m1[:Ru].astype(np.int64)
    m2s = tr.m2[:Ru].astype(np.int64)
    n_cmp = int(present[:, c1].sum() + present[:, c2].sum()
                + present[:, ci].sum()
                + (present[:, rc].sum() if readout else 0))
    n_wr_ev = int(present[:, wr].sum() + present[:, rt].sum())
    match_sc = int(m1s.sum() + m2s[take].sum() + cnt.sum()
                   + (cnt.sum() if readout else 0))
    write_sc = int(m2s[take].sum() + cnt[tailp].sum())
    eng.charge_bulk(
        cycles=int(round_delta.sum()),
        compare_cycles=Pc * Ru + n_cmp,
        write_cycles=Pc * Ru + n_wr_ev,
        read_cycles=int(cnt.sum()) if readout else 0,
        energy_terms=terms, trace_cycles=trace_c, trace_energy=trace_e,
        match=int(mf.sum()) + match_sc,
        mismatch=(Pc * Ru + n_cmp) * n - (int(mf.sum()) + match_sc),
        write=int((kw[None, :] * mf).sum()) + write_sc,
        miswrite=int((kw[None, :] * (n - mf)).sum())
        + (n_wr_ev * n - write_sc))

    weights = np.uint64(1) << (m - 1 - np.arange(m, dtype=np.uint64))
    values = ((~take) * weights[None, :]).sum(axis=1, dtype=np.uint64)
    return values, cnt, r_used


def count_probes_mk(eng: APEngine, cols, keys) -> np.ndarray:
    """Megakernel counterpart of :func:`count_probes`: the whole probe
    batch is ONE op-group launch (CMP ops, padded probes disabled via
    the ``enabled`` mask; sharded over lanes when the engine has
    ``n_shards``), and all compare cycles are charged in one bulk fold.
    """
    cols = np.atleast_2d(np.asarray(cols, np.int32))
    keys = np.atleast_2d(np.asarray(keys, np.uint32))
    n_probes, k = cols.shape
    np2, k2 = _next_pow2(n_probes), _next_pow2(k)

    def pad(a):
        if k2 != k:
            a = np.concatenate(
                [a, np.repeat(a[:, :1], k2 - k, axis=1)], axis=1)
        if np2 != n_probes:
            a = np.concatenate(
                [a, np.repeat(a[-1:], np2 - n_probes, axis=0)], axis=0)
        return a

    group = mk_ref.OpGroup.probes(pad(cols), pad(keys))
    enabled = np.arange(np2) < n_probes
    eng.planes, eng.tag, matched = mk_ops.run_group(
        eng.planes, eng.tag, group, enabled, mesh=eng.mesh)
    counts = np.asarray(jax.device_get(matched))[:n_probes].astype(np.int64)

    cf = counts.astype(np.float64)
    e = k * (eng.power.p_m * cf + eng.power.p_mm * (eng.n_words - cf))
    eng.charge_bulk(
        cycles=n_probes, compare_cycles=n_probes,
        energy_terms=e,
        trace_cycles=eng.cycles + np.arange(1, n_probes + 1, dtype=np.int64),
        trace_energy=e,
        match=int(counts.sum()),
        mismatch=n_probes * eng.n_words - int(counts.sum()))
    return counts
