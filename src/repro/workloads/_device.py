"""Device-resident programs for the data-dependent workload inner loops.

The eager :class:`~repro.core.engine.APEngine` path performs a blocking
host sync (``int(bp.popcount(tag))``) after every compare/write cycle,
so data-dependent workloads (sort, knn, spmv, hist) used to run
thousands of sequential device round-trips.  The two programs here keep
the whole inner loop resident (the CoMeT interval-simulation lesson,
arXiv:2109.12405 applied at the engine layer):

* :func:`min_extract_rounds` — the MSB-first CAM min-extraction idiom
  shared by ``workloads/sort.py`` and ``workloads/knn.py``, compiled as
  ONE ``lax.scan`` over extraction rounds.  The eager "did any candidate
  respond?" branch becomes an on-device :func:`~repro.core.engine.select_state`;
  rounds after the (data-dependent) termination point are masked no-ops.
* :func:`count_probes` — a batch of response-counter COMPAREs (the
  per-bin counting of ``histogram.py``, the per-(row, bit) tag-count
  accumulation of ``spmv.py``) as one scanned program.

Both transfer their per-pass matched counts to the host ONCE per
workload phase and replay them through the engine's ``charge_*``
accounting, which makes cycles / energy / events / trace arrays
bit-identical to the eager per-cycle oracle
(tests/test_device_workloads.py pins this for every workload).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitplane as bp
from repro.core import isa
from repro.core import engine as E
from repro.core.bitplane import Field
from repro.core.engine import APEngine, PassSchedule, _next_pow2


# ---------------------------------------------------------------------------
# shared min-extraction scan (sort + knn)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MinExtractTrace:
    """Per-round matched counts of one device min-extraction program.

    Arrays are [rounds, ...]; narrowing axes run MSB -> LSB (the eager
    iteration order).  ``masked[r]`` is True for rounds after the
    data-dependent termination point (device no-ops the host never
    replays).  ``device_counters`` are the program's own on-device
    :data:`~repro.core.engine.APState` counter totals, cross-checked
    against the host replay in the tests.
    """
    copy_sched: PassSchedule
    copy_matched: np.ndarray   # [R, P_copy] per-pass counts of cand<-active
    m1: np.ndarray             # [R, m] responders of the 0-probe compare
    m2: np.ndarray             # [R, m] responders of the retire compare
    take: np.ndarray           # [R, m] bool: the eager branch was taken
    count: np.ndarray          # [R] tie-group size of the extracted min
    tie_tag: np.ndarray        # [R, n_lanes] packed tie-group TAG
    masked: np.ndarray         # [R] bool: round ran as a masked no-op
    device_counters: np.ndarray  # int32[N_COUNTERS]


@partial(jax.jit, static_argnames=("val_cols", "active_col", "cand_col",
                                   "rounds", "readout"))
def _min_extract_program(state, copy_cc, copy_ck, copy_wc, copy_wk,
                         remaining, *, val_cols, active_col, cand_col,
                         rounds, readout):
    obs.count("workloads/retrace/min_extract")
    obs.count(f"workloads/retrace/min_extract[m={len(val_cols)},"
              f"rounds={rounds},readout={readout}]")
    cand = jnp.array([cand_col], jnp.int32)
    active = jnp.array([active_col], jnp.int32)
    one = jnp.array([1], jnp.uint32)
    zero = jnp.array([0], jnp.uint32)

    def body(carry, _):
        st0, done, rem = carry
        st, copy_m = E.state_run(st0, copy_cc, copy_ck, copy_wc, copy_wk)
        m1s, m2s, takes = [], [], []
        for i in reversed(range(len(val_cols))):
            cv = jnp.array([cand_col, val_cols[i]], jnp.int32)
            st_c, m1 = E.state_compare(st, cv, jnp.array([1, 0], jnp.uint32))
            # the eager branch: if any candidate has a 0 here, retire the
            # 1-candidates — on device both arms run, one is selected
            st_b, m2 = E.state_compare(st_c, cv, jnp.array([1, 1], jnp.uint32))
            st_b, _ = E.state_write(st_b, cand, zero)
            take = m1 > 0
            st = E.select_state(take, st_b, st_c)
            m1s.append(m1)
            m2s.append(m2)
            takes.append(take)
        st, count = E.state_compare(st, cand, one)
        tie_tag = st.tag
        if readout:
            # knn: sequential responder readout + re-compare + retire
            st = E.state_read_charge(st, count)
            st, _ = E.state_compare(st, cand, one)
            st, _ = E.state_write(st, active, zero)
        else:
            # sort: retire the tie group unless the active set was empty
            st_r, _ = E.state_write(st, active, zero)
            st = E.select_state(count > 0, st_r, st)
        new_rem = rem - count
        st_out = E.select_state(done, st0, st)
        rem_out = jnp.where(done, rem, new_rem)
        done_out = done | (count == 0) | (new_rem <= 0)
        ys = (copy_m, jnp.stack(m1s), jnp.stack(m2s), jnp.stack(takes),
              count, tie_tag, done)
        return (st_out, done_out, rem_out), ys

    init = (state, jnp.bool_(False), jnp.asarray(remaining, jnp.int32))
    (state, _, _), ys = jax.lax.scan(body, init, None, length=rounds)
    return state, ys


def min_extract_rounds(eng: APEngine, val: Field, active: Field, cand: Field,
                       rounds: int, remaining: int,
                       readout: bool = False) -> MinExtractTrace:
    """Run up to ``rounds`` min-extractions over ``active`` rows on device.

    One compiled program, one host transfer.  The engine adopts the final
    array state; NO cycles/energy are charged here — the caller replays
    the returned counts through :func:`replay_extract` + ``charge_*`` in
    eager order.  ``remaining`` is the termination budget (elements left
    to emit: n for sort, k for knn); ``readout`` adds knn's per-round
    responder readout + re-compare + retire to the program.
    """
    copy_sched = isa.copy(cand, active)
    state, ys = _min_extract_program(
        eng.state(),
        jnp.asarray(copy_sched.cmp_cols), jnp.asarray(copy_sched.cmp_key),
        jnp.asarray(copy_sched.w_cols), jnp.asarray(copy_sched.w_key),
        remaining,
        val_cols=tuple(val.cols()), active_col=active.col(0),
        cand_col=cand.col(0), rounds=rounds, readout=readout)
    copy_m, m1, m2, take, count, tie_tag, masked = jax.device_get(ys)
    ctr = np.asarray(jax.device_get(state.counters))
    eng.adopt(state)
    return MinExtractTrace(copy_sched, np.asarray(copy_m), np.asarray(m1),
                           np.asarray(m2), np.asarray(take),
                           np.asarray(count), np.asarray(tie_tag),
                           np.asarray(masked), ctr)


def replay_extract(eng: APEngine, tr: MinExtractTrace, r: int,
                   m: int) -> tuple[int, int]:
    """Charge round ``r``'s extraction events in eager order.

    Mirrors ``sort.extract_min`` exactly: the fused candidate copy, the
    MSB-first narrowing (second compare + retire write only where the
    branch was taken), and the final tie-group compare.  Returns
    (min_value, tie_count).
    """
    eng.charge_run(tr.copy_sched, tr.copy_matched[r])
    v = 0
    for pos, i in enumerate(reversed(range(m))):
        eng.charge_compare(2, tr.m1[r, pos])
        if tr.take[r, pos]:
            eng.charge_compare(2, tr.m2[r, pos])
            eng.charge_write(1, tr.m2[r, pos])
        else:
            v |= 1 << i
    eng.charge_compare(1, tr.count[r])
    return v, int(tr.count[r])


def tagged_rows(tag_row: np.ndarray) -> np.ndarray:
    """Row indices set in a packed TAG row (host-side unpack)."""
    shifts = np.arange(bp.LANE, dtype=np.uint32)
    bits = (np.asarray(tag_row, np.uint32)[:, None] >> shifts[None, :]) & 1
    return np.where(bits.reshape(-1))[0]


# ---------------------------------------------------------------------------
# batched response counting (hist + spmv)
# ---------------------------------------------------------------------------

@jax.jit
def _count_probes_program(state, cols, keys, real):
    obs.count("workloads/retrace/count_probes")
    obs.count(f"workloads/retrace/count_probes[n={cols.shape[0]},"
              f"k={cols.shape[1]}]")

    def body(st0, xs):
        cc, kk, is_real = xs
        st, matched = E.state_compare(st0, cc, kk)
        st = E.select_state(is_real, st, st0)
        return st, matched

    return jax.lax.scan(body, state, (cols, keys, real))


def count_probes(eng: APEngine, cols, keys) -> np.ndarray:
    """Run a batch of COMPAREs as one device program; return responder
    counts [n_probes] (int64).

    The probe shape is padded to power-of-two buckets (padded probes are
    masked on device and sliced off here), so nearby probe batches share
    one compiled program.  The engine adopts the final state — TAG holds
    the LAST probe's responders, as after the eager loop — and every
    probe's compare cycle is charged in order.
    """
    cols = np.atleast_2d(np.asarray(cols, np.int32))
    keys = np.atleast_2d(np.asarray(keys, np.uint32))
    n_probes, k = cols.shape
    np2, k2 = _next_pow2(n_probes), _next_pow2(k)

    def pad(a):
        if k2 != k:
            a = np.concatenate(
                [a, np.repeat(a[:, :1], k2 - k, axis=1)], axis=1)
        if np2 != n_probes:
            a = np.concatenate(
                [a, np.repeat(a[-1:], np2 - n_probes, axis=0)], axis=0)
        return a

    real = np.arange(np2) < n_probes
    state, counts = _count_probes_program(
        eng.state(), jnp.asarray(pad(cols)), jnp.asarray(pad(keys)),
        jnp.asarray(real))
    counts = np.asarray(jax.device_get(counts))[:n_probes].astype(np.int64)
    eng.adopt(state)
    for i in range(n_probes):
        eng.charge_compare(k, counts[i])
    return counts
