"""Black-Scholes option pricing on the AP (paper §3.1 workload 1).

One PU per option pair; everything below is word-parallel over all N PUs, so
cycle counts are independent of N — the paper's "embarrassingly parallel, no
inter-PU communication" exemplar.

    C = S * PHI(d1) - K * e^{-rT} * PHI(d2)
    d1 = (ln(S/K) + (r + sigma^2/2) T) / (sigma sqrt(T));  d2 = d1 - sigma sqrt(T)

Numerics: signed Q6.10 fixed point (16-bit).  Transcendentals (ln, sqrt,
exp, PHI) use the paper's LUT idiom (§2.2): a 10-bit argument matched
exhaustively — O(2^10) compare+write passes per function, with the function
values carried in the instruction stream.  Division is restoring long
division, O(m^2).  Expected accuracy ~1e-2 absolute in price units
(dominated by the Q6.10 quantization of PHI and ln) — tests assert against
the float64 reference with that tolerance.

The transcendental LUT schedules all land in one power-of-two shape
bucket (`engine.bucket_schedule`), so the pricing pipeline compiles a
few programs total instead of one per LUT.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import arith, isa
from repro.core.apfloat import _tag_ge
from repro.core.bitplane import Field
from repro.core.engine import APEngine

M = 16          # word length
FRAC = 10       # fraction bits (Q6.10)
LUT_BITS = 10   # transcendental LUT argument width
ONE = 1 << FRAC


def _q(x) -> np.ndarray:
    v = np.round(np.asarray(x, np.float64) * ONE).astype(np.int64)
    v = np.clip(v, -(1 << (M - 1)), (1 << (M - 1)) - 1)
    return (v & ((1 << M) - 1)).astype(np.uint64)


def _unq(u) -> np.ndarray:
    u = np.asarray(u, np.int64)
    sign = u >> (M - 1)
    return (u - (sign << M)).astype(np.float64) / ONE


@dataclasses.dataclass
class _Fields:
    S: Field
    K: Field
    T: Field
    sig: Field
    num: Field
    den: Field
    d1: Field
    d2: Field
    phi1: Field
    phi2: Field
    disc: Field
    t1: Field
    t2: Field
    arg: Field
    prod: Field
    div_a: Field
    quot: Field
    wide: Field
    trial: Field
    carry: Field
    borrow: Field
    qbit: Field
    sa: Field
    sb: Field
    flag: Field
    z: Field


def _alloc(eng: APEngine) -> _Fields:
    a = eng.alloc
    dm = M + FRAC  # division dividend width
    return _Fields(
        S=a.alloc(M, "S"), K=a.alloc(M, "K"), T=a.alloc(M, "T"),
        sig=a.alloc(M, "sig"), num=a.alloc(M, "num"), den=a.alloc(M, "den"),
        d1=a.alloc(M, "d1"), d2=a.alloc(M, "d2"),
        phi1=a.alloc(M, "phi1"), phi2=a.alloc(M, "phi2"),
        disc=a.alloc(M, "disc"), t1=a.alloc(M, "t1"), t2=a.alloc(M, "t2"),
        arg=a.alloc(LUT_BITS, "arg"), prod=a.alloc(2 * M, "prod"),
        div_a=a.alloc(dm, "diva"), quot=a.alloc(dm, "quot"),
        wide=a.alloc(2 * dm + 1, "wide"), trial=a.alloc(dm + 1, "trial"),
        carry=a.alloc(1, "c"), borrow=a.alloc(1, "br"), qbit=a.alloc(1, "qb"),
        sa=a.alloc(1, "sa"), sb=a.alloc(1, "sb"), flag=a.alloc(1, "fl"),
        z=a.alloc(1, "z"))


def _smul(eng: APEngine, f: _Fields, dst: Field, a: Field, b: Field) -> None:
    """dst <- (a * b) >> FRAC, signed Q-format."""
    arith.run_signed_mul(eng, a, b, f.prod, f.carry, f.sa, f.sb, f.z)
    eng.run(isa.copy(dst, f.prod.slice(FRAC, M)))


def _sdiv(eng: APEngine, f: _Fields, dst: Field, num: Field,
          den: Field) -> None:
    """dst <- (num << FRAC) / den, num signed, den positive Q-format."""
    eng.run(isa.copy(f.sa, num.slice(M - 1, 1)))
    arith.cond_negate(eng, num, f.sa, f.carry, f.z)
    eng.clear(f.div_a)
    eng.run(isa.copy(f.div_a.slice(FRAC, M), num))
    arith.run_div(eng, f.div_a, den, f.quot, f.wide, f.trial,
                  f.borrow, f.qbit)
    eng.run(isa.copy(dst, f.quot.slice(0, M)))
    arith.cond_negate(eng, dst, f.sa, f.carry, f.z)
    arith.cond_negate(eng, num, f.sa, f.carry, f.z)   # restore argument


def _lut16(eng: APEngine, f: _Fields, dst: Field, src: Field, lo_bit: int,
           fn) -> None:
    """dst <- LUT(fn)(src bits [lo_bit : lo_bit+10]), out Q6.10 unsigned."""
    eng.run(isa.copy(f.arg, src.slice(lo_bit, LUT_BITS)))
    eng.clear(dst)
    eng.run(isa.lut(f.arg, dst, fn))


def _clamp_phi_arg(eng: APEngine, f: _Fields, src: Field) -> None:
    """src <- clip(src + 4.0, 0, 8.0 - eps) in place (PHI LUT domain)."""
    eng.clear(f.carry)
    eng.run(isa.const_add(src, 4 * ONE, f.carry))
    # negative (sign bit set) -> 0
    eng.compare([src.col(M - 1)], [1])
    eng.write(src.cols(), [0] * M)
    # >= 8.0 -> 8.0 - 1ulp
    eng.clear(f.flag)
    _tag_ge(eng, src, 8 * ONE, f.flag)
    hi = 8 * ONE - 1
    eng.compare([f.flag.col(0)], [1])
    eng.write(src.cols(), [(hi >> i) & 1 for i in range(M)])


def _phi(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def ap_blackscholes(S, K, T, sigma, r: float = 0.05,
                    backend: str = "jnp") -> tuple[np.ndarray, dict]:
    """Call prices for option vectors (word-parallel on one AP)."""
    S, K, T, sigma = (np.asarray(v, np.float64) for v in (S, K, T, sigma))
    n = S.shape[0]
    n_words = max(((n + 31) // 32) * 32, 32)
    eng = APEngine(n_words=n_words, n_bits=448, backend=backend)
    f = _alloc(eng)

    def load(field: Field, vals: np.ndarray) -> None:
        buf = np.zeros(n_words, np.uint64)
        buf[:n] = _q(vals)
        eng.load(field, buf)

    load(f.S, S)
    load(f.K, K)
    load(f.T, T)
    load(f.sig, sigma)

    # ---- num = ln(S/K) + (r + sig^2/2) T
    _sdiv(eng, f, f.t1, f.S, f.K)                     # t1 = S/K  (Q6.10 > 0)
    # ln LUT: arg = ratio bits [2:12] => value/4 in [0,1) * 1024
    _lut16(eng, f, f.num, f.t1, 2,
           lambda a: int(np.clip(round(math.log(max(a, 1) * 4.0 / (1 << LUT_BITS))
                                       * ONE), -(1 << (M - 1)), (1 << (M - 1)) - 1))
           & ((1 << M) - 1))
    _smul(eng, f, f.t1, f.sig, f.sig)                 # t1 = sig^2
    # t1 = r + sig^2/2 : halve by field shift, then add constant r
    eng.run(isa.copy(f.t2, f.t1.shifted(1)))          # t2 = t1 >> 1 (free shift)
    eng.clear(f.t2.slice(M - 1, 1))
    eng.clear(f.carry)
    eng.run(isa.const_add(f.t2, int(round(r * ONE)), f.carry))
    _smul(eng, f, f.t1, f.t2, f.T)                    # t1 = (r + s^2/2) T
    eng.clear(f.carry)
    eng.run(isa.add(f.t1, f.num, f.carry))            # num += t1

    # ---- den = sig * sqrt(T)
    # sqrt LUT: arg = T bits [2:12] => value/4 in [0,1) * 1024
    _lut16(eng, f, f.t1, f.T, 2,
           lambda a: int(round(math.sqrt(a * 4.0 / (1 << LUT_BITS)) * ONE)))
    _smul(eng, f, f.den, f.sig, f.t1)

    # ---- d1 = num / den ; d2 = d1 - den
    _sdiv(eng, f, f.d1, f.num, f.den)
    eng.run(isa.copy(f.d2, f.d1))
    eng.clear(f.borrow)
    eng.run(isa.sub(f.den, f.d2, f.borrow))

    # ---- PHI(d1), PHI(d2): clamp to [-4, 4), LUT on (x+4)/8 * 1024
    for d, phi in ((f.d1, f.phi1), (f.d2, f.phi2)):
        eng.run(isa.copy(f.t1, d))
        _clamp_phi_arg(eng, f, f.t1)
        _lut16(eng, f, phi, f.t1, 3,
               lambda a: int(round(_phi(a * 8.0 / (1 << LUT_BITS) - 4.0) * ONE)))

    # ---- disc = e^{-rT}: LUT on rT bits [0:10] (rT < 1)
    eng.clear(f.t2)
    eng.clear(f.carry)
    eng.run(isa.const_add(f.t2, int(round(r * ONE)), f.carry))
    _smul(eng, f, f.t1, f.t2, f.T)                    # t1 = r T
    _lut16(eng, f, f.disc, f.t1, 0,
           lambda a: int(round(math.exp(-a / ONE) * ONE)))

    # ---- C = S*phi1 - K*disc*phi2
    _smul(eng, f, f.t1, f.S, f.phi1)
    _smul(eng, f, f.t2, f.K, f.disc)
    _smul(eng, f, f.t2, f.t2, f.phi2)
    eng.clear(f.borrow)
    eng.run(isa.sub(f.t2, f.t1, f.borrow))            # t1 = t1 - t2

    prices = _unq(eng.read(f.t1)[:n])
    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["n"] = n
    return prices, counters


def reference(S, K, T, sigma, r: float = 0.05) -> np.ndarray:
    S, K, T, sigma = (np.asarray(v, np.float64) for v in (S, K, T, sigma))
    d1 = (np.log(S / K) + (r + sigma ** 2 / 2) * T) / (sigma * np.sqrt(T))
    d2 = d1 - sigma * np.sqrt(T)
    phi = np.vectorize(_phi)
    return S * phi(d1) - K * np.exp(-r * T) * phi(d2)
