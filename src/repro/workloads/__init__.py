"""The paper's three benchmark workloads (§3.1), implemented word-parallel
bit-serial on the AP: Black-Scholes (BS), FFT, Dense Matrix Multiply (DMM)."""
