"""Exact word-parallel bit-serial AP workloads.

The paper's §3.1 trio — Black-Scholes (``blackscholes``), FFT (``fft``),
dense matrix multiply (``dmm``) — plus the suite additions: associative
sort (``sort``, min-extraction idiom), sparse matrix-vector multiply
(``spmv``, tag-masked accumulation), k-NN search (``knn``, the
CAM-native workload) and histogram (``histogram``, response-counter
binning).  Every workload emits exact ``(cycle, energy)`` trace events
through the :class:`~repro.core.engine.APEngine` accounting and is bound
to its calibrated analytic model entry by :mod:`.registry`.
"""
from repro.workloads import registry  # noqa: F401  (self-registers the suite)
