"""k-nearest-neighbour search on the AP — the CAM-native workload.

The database lives in the CAM, one PU per point; the query never touches
memory.  L1 distance, exact, in two phases:

1. *distance* — per feature f the constant |x_f - q_f| map is applied by
   the paper's LUT idiom (``isa.lut``: one pass per nonzero table entry,
   the query folds into the compare keys) and added into a distance
   accumulator — word-parallel over all points, O(d * 2^m) cycles;
2. *select* — k rounds of the MSB-first min-extraction from
   ``workloads.sort``; each round's winners read out their resident index
   field sequentially (1 cycle/responder, §2.1) and retire.

    cycles = O(d * 2^m + k * m)     independent of the database size,

which is why associative memories were built for this search in the
first place.  Ties are broken by ascending row order, matching the
NumPy oracle.
"""
from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.engine import APEngine
from repro.workloads import _device
from repro.workloads.sort import extract_min


def plan_bits(d: int, m: int, n: int) -> int:
    """Bit columns: d features + |diff| scratch + distance acc + index
    + active/cand markers + carry."""
    acc_w = m + max(1, int(np.ceil(np.log2(max(d, 2)))))
    idx_w = max(1, int(np.ceil(np.log2(max(n, 2)))))
    return d * m + m + acc_w + idx_w + 3


def ap_knn(db: np.ndarray, q: np.ndarray, k: int, m: int = 4,
           backend: str = "jnp", mode: str = "device",
           n_shards: int | None = None) -> tuple[np.ndarray, dict]:
    """Indices of the k nearest rows of ``db`` to ``q`` (L1, ascending).

    db: uint [n, d] with entries < 2^m; q: uint [d].  Returns
    (indices[k], engine counters).  Exact; ties by row order.
    ``mode="device"`` runs the k min-extraction rounds (including the
    responder readout) as one compiled program; ``mode="eager"`` is the
    per-cycle oracle; ``mode="megakernel"`` fuses each round into one
    op-group launch with bulk accounting (``n_shards`` shards lanes).
    """
    if mode not in ("device", "eager", "megakernel"):
        raise ValueError(f"unknown mode {mode!r}")
    db = np.asarray(db, np.uint64)
    q = np.asarray(q, np.uint64)
    n, d = db.shape
    if (db >= (1 << m)).any() or (q >= (1 << m)).any():
        raise ValueError(f"entries must fit in {m} bits")
    if not 1 <= k <= n:
        raise ValueError("k out of range")

    acc_w = m + max(1, int(np.ceil(np.log2(max(d, 2)))))
    idx_w = max(1, int(np.ceil(np.log2(max(n, 2)))))
    n_words = max(((n + 31) // 32) * 32, 32)
    eng = APEngine(n_words=n_words, n_bits=plan_bits(d, m, n),
                   backend=_device.engine_backend(backend, mode),
                   n_shards=n_shards)
    a = eng.alloc
    feat = [a.alloc(m, f"f{j}") for j in range(d)]
    diff = a.alloc(m, "diff")
    acc = a.alloc(acc_w, "acc")
    idx = a.alloc(idx_w, "idx")
    active = a.alloc(1, "active")
    cand = a.alloc(1, "cand")
    carry = a.alloc(1, "carry")

    def pad(v, fill=0):
        buf = np.full(n_words, fill, np.uint64)
        buf[:n] = v
        return buf

    for j in range(d):
        eng.load(feat[j], pad(db[:, j]))
    eng.load(idx, pad(np.arange(n)))
    eng.load(active, pad(np.ones(n)))

    # distance accumulation: acc += |f_j - q_j| via the LUT idiom
    eng.clear(acc)
    for j in range(d):
        qj = int(q[j])
        eng.clear(diff)
        eng.run(isa.lut(feat[j], diff, lambda v, qj=qj: abs(v - qj)))
        eng.clear(carry)
        eng.run(_add_zext(diff, acc, carry))

    # k min-extractions; winners read out their index field
    out: list[int] = []
    if mode == "megakernel":
        idx_vals = pad(np.arange(n))
        tr = _device.min_extract_rounds_mk(eng, acc, active, cand, rounds=k,
                                           remaining=k, readout=True)
        _, _, r_used = _device.replay_extract_bulk(eng, tr, acc.width,
                                                   budget=k, readout=True)
        for r in range(r_used):
            rows = _device.tagged_rows(tr.tie_tag[r])
            out.extend(int(v) for v in idx_vals[rows][:k - len(out)])
    elif mode == "device":
        idx_vals = pad(np.arange(n))            # idx field is never written
        tr = _device.min_extract_rounds(eng, acc, active, cand, rounds=k,
                                        remaining=k, readout=True)
        r = 0
        while len(out) < k:
            _, count = _device.replay_extract(eng, tr, r, acc.width)
            rows = _device.tagged_rows(tr.tie_tag[r])   # TAG = the tie group
            eng.charge_read(len(rows))
            ids = idx_vals[rows]
            out.extend(int(v) for v in ids[:k - len(out)])
            eng.charge_compare(1, count)
            eng.charge_write(1, count)          # retire the whole group
            r += 1
    else:
        while len(out) < k:
            _, count = extract_min(eng, acc, active, cand)
            rows, ids = eng.read_tagged(idx)    # TAG = the tie group
            out.extend(int(v) for v in ids[:k - len(out)])
            eng.compare([cand.col(0)], [1])
            eng.write([active.col(0)], [0])     # retire the whole group

    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["n"] = n
    counters["d"] = d
    counters["m"] = m
    return np.asarray(out, np.int64), counters


def _add_zext(a, b, carry):
    """b <- b + zext(a): add a (narrower) into b, carry rippling up."""
    passes = []
    for i in range(b.width):
        if i < a.width:
            passes += isa.full_adder_passes(carry.col(0), b.col(i), a.col(i))
        else:
            def ha(bits):
                cc, bb = bits
                s = bb + cc
                return (s >> 1, s & 1)
            passes += isa.compile_table([carry.col(0), b.col(i)],
                                        [carry.col(0), b.col(i)], ha)
    return isa.schedule(passes)


def reference(db: np.ndarray, q: np.ndarray, k: int) -> np.ndarray:
    dist = np.abs(np.asarray(db, np.int64)
                  - np.asarray(q, np.int64)[None, :]).sum(axis=1)
    return np.argsort(dist, kind="stable")[:k].astype(np.int64)
