"""Dense Matrix Multiplication on the AP (paper §3.1 workload 3).

Layout: C = A @ B with n x n operands; PU (i,j) computes c_ij and holds
row i of A and column j of B *resident* (the paper's central point: storage
== compute, so there is no caches-to-PU synchronization term, eq (7)).

The inner product is n sequential MACs, each word-parallel over all n^2 PUs:

    cycles = n * O(m^2)     independent of the number of PUs.

The "shift" between successive k terms is free — each MAC simply activates
the bit-columns of the k-th resident operand pair (§2.2: "shift is
implemented by activating different bit columns").

The n per-term MAC schedules differ only in their operand columns, so
the engine's shape-bucketed runner (`engine.bucket_schedule`) compiles
ONE program for the whole sweep instead of retracing per schedule.
"""
from __future__ import annotations

import numpy as np

from repro.core import arith
from repro.core.engine import APEngine


def plan_bits(n: int, m: int) -> int:
    """Bit columns needed: n A-words + n B-words + accumulator + carry."""
    acc_w = 2 * m + max(1, int(np.ceil(np.log2(max(n, 2)))))
    return 2 * n * m + acc_w + 1


def ap_matmul(A: np.ndarray, B: np.ndarray, m: int = 8,
              backend: str = "jnp") -> tuple[np.ndarray, dict]:
    """C = A @ B on one AP; A, B: uint [n, n] with entries < 2^m.

    Returns (C, engine counters).  Exact (integer) result.
    """
    A = np.asarray(A, np.uint64)
    B = np.asarray(B, np.uint64)
    n = A.shape[0]
    if A.shape != (n, n) or B.shape != (n, n):
        raise ValueError("square operands only")
    if (A >= (1 << m)).any() or (B >= (1 << m)).any():
        raise ValueError(f"entries must fit in {m} bits")

    n_words = max(((n * n + 31) // 32) * 32, 32)   # round up to lane width
    n_bits = plan_bits(n, m)
    eng = APEngine(n_words=n_words, n_bits=n_bits, backend=backend)

    a_f = [eng.alloc.alloc(m, f"a{k}") for k in range(n)]
    b_f = [eng.alloc.alloc(m, f"b{k}") for k in range(n)]
    acc_w = 2 * m + max(1, int(np.ceil(np.log2(max(n, 2)))))
    acc = eng.alloc.alloc(acc_w, "acc")
    carry = eng.alloc.alloc(1, "carry")

    # resident data: PU (i,j) holds A[i, :] and B[:, j]
    ii, jj = np.divmod(np.arange(n * n), n)
    for k in range(n):
        av = np.zeros(n_words, np.uint64)
        bv = np.zeros(n_words, np.uint64)
        av[: n * n] = A[ii, k]
        bv[: n * n] = B[k, jj]
        eng.load(a_f[k], av)
        eng.load(b_f[k], bv)

    data_cycles_before = eng.cycles  # loads charge nothing (host DMA)
    for k in range(n):
        arith.run_mac(eng, a_f[k], b_f[k], acc, carry)
    mac_cycles = eng.cycles - data_cycles_before

    C = eng.read(acc)[: n * n].reshape(n, n)
    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["mac_cycles"] = mac_cycles
    counters["n"] = n
    counters["m"] = m
    return C.astype(np.uint64), counters


def reference(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    return (np.asarray(A, np.uint64) @ np.asarray(B, np.uint64))
