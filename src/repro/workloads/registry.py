"""Workload registry: one place that binds a workload name to (a) its
exact bit-serial AP implementation for trace capture and (b) its
calibrated analytic :class:`repro.core.models.Workload` entry.

Every registered workload provides ``run_small(n)`` — run an n-element
instance on the :class:`~repro.core.engine.APEngine` and return the
engine counters *including* the ``trace_cycles`` / ``trace_energy``
event arrays — so any consumer (co-sim trace capture, the sweep engine,
benchmarks) can treat the whole suite uniformly.  Names are unique;
:func:`register` rejects duplicates so two modules can never silently
shadow each other's calibration.  The paper's §3.1 trio and the four
suite additions self-register on import of :mod:`repro.workloads`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import models as M

_REGISTRY: dict[str, "WorkloadDef"] = {}


@dataclasses.dataclass(frozen=True)
class WorkloadDef:
    """One registered workload.

    ``run_small(n, mode)`` executes an ~n-element instance and returns
    engine counters with trace events; ``paper`` marks the original
    §3.1 trio.  ``mode`` selects device-resident execution ("device",
    the default), the per-cycle eager oracle ("eager"), or the fused
    megakernel path ("megakernel") for the data-dependent workloads —
    the schedule-driven trio is device-resident either way and ignores
    it.
    """
    name: str
    title: str
    run_small: Callable[..., dict]
    paper: bool = False

    @property
    def model(self) -> M.Workload:
        """The calibrated analytic entry (eqs (2)-(17) constants)."""
        return M.WORKLOADS[self.name]


def register(wd: WorkloadDef) -> WorkloadDef:
    if wd.name in _REGISTRY:
        raise ValueError(f"workload {wd.name!r} already registered")
    if wd.name not in M.WORKLOADS:
        raise ValueError(f"workload {wd.name!r} has no calibrated "
                         f"models.Workload entry")
    _REGISTRY[wd.name] = wd
    return wd


def get(name: str) -> WorkloadDef:
    if name not in _REGISTRY:
        raise ValueError(f"unknown workload {name!r}; registered: "
                         f"{names()}")
    return _REGISTRY[name]


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def trace_counters(name: str, n_elems: int = 64,
                   mode: str = "device") -> dict:
    """Run the named workload's ~n_elems-element instance for its trace."""
    return get(name).run_small(n_elems, mode=mode)


# ---------------------------------------------------------------------------
# suite registrations.  Each runner sizes a small exact instance off
# ``n`` so the captured activity profile keeps its per-phase structure
# (README §co-simulation: the co-sim dilates the shape onto package
# time scales; only the shape matters).
# ---------------------------------------------------------------------------

def _run_dmm(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import dmm
    side = max(4, int(np.sqrt(n)) // 2 * 2)
    A = rng.integers(0, 64, (side, side), dtype=np.uint64)
    B = rng.integers(0, 64, (side, side), dtype=np.uint64)
    _, ctr = dmm.ap_matmul(A, B, m=6)
    return ctr


def _run_fft(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import fft
    N = 1 << max(3, int(np.log2(max(n, 8))) // 2 + 2)
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.3 / np.sqrt(N))
    _, ctr = fft.ap_fft(x, m=12, frac=9)
    return ctr


def _run_bs(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import blackscholes as bs
    k = max(n, 32)
    _, ctr = bs.ap_blackscholes(rng.uniform(0.9, 1.4, k),
                                rng.uniform(0.9, 1.4, k),
                                rng.uniform(0.5, 1.5, k),
                                rng.uniform(0.2, 0.5, k))
    return ctr


def _run_sort(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import sort
    _, ctr = sort.ap_sort(rng.integers(0, 256, max(n, 32),
                                       dtype=np.uint64), m=8, mode=mode)
    return ctr


def _run_spmv(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import spmv
    n_rows = max(8, int(np.sqrt(max(n, 16))))
    nnz = max(n, 16)
    r = rng.integers(0, n_rows, nnz)
    c = rng.integers(0, n_rows, nnz)
    v = rng.integers(0, 50, nnz, dtype=np.uint64)
    x = rng.integers(0, 50, n_rows, dtype=np.uint64)
    _, ctr = spmv.ap_spmv(r, c, v, x, n_rows, m=6, mode=mode)
    return ctr


def _run_knn(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import knn
    rows = max(n, 32)
    # k scales with the database (capped) so the min-extraction phase
    # keeps its per-round structure at larger trace instances instead
    # of staying a fixed 5-round tail behind the LUT distance sweep
    k = min(64, max(5, rows // 8))
    db = rng.integers(0, 16, (rows, 4), dtype=np.uint64)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    _, ctr = knn.ap_knn(db, q, k=min(k, rows), m=4, mode=mode)
    return ctr


def hist_bins(n: int) -> int:
    """Bin count for a histogram trace instance: more bins at larger
    instances keep the per-bin activity structure (and the bin-probe
    phase from degenerating to a handful of cycles), capped at one bin
    per value (2^6 for the m=6 trace instances).  Power of two, as
    ``ap_histogram`` requires."""
    return 1 << int(np.log2(max(8, min(64, n // 4))))


def _run_hist(n: int, mode: str = "device") -> dict:
    rng = np.random.default_rng(0)
    from repro.workloads import histogram
    _, ctr = histogram.ap_histogram(
        rng.integers(0, 64, max(n, 32), dtype=np.uint64),
        n_bins=hist_bins(n), m=6, mode=mode)
    return ctr


for _wd in (
    WorkloadDef("dmm", "dense matrix multiply (§3.1)", _run_dmm, paper=True),
    WorkloadDef("fft", "radix-2 FFT (§3.1)", _run_fft, paper=True),
    WorkloadDef("bs", "Black-Scholes (§3.1)", _run_bs, paper=True),
    WorkloadDef("sort", "associative sort (min-extraction)", _run_sort),
    WorkloadDef("spmv", "sparse matrix-vector multiply", _run_spmv),
    WorkloadDef("knn", "k-nearest-neighbour search", _run_knn),
    WorkloadDef("hist", "histogram (response-counter binning)", _run_hist),
):
    register(_wd)
