"""Histogram on the AP (response-counter binning).

Binning by the top ``log2(n_bins)`` value bits is free on the AP —
"shift is implemented by activating different bit columns" (§2.2), so a
bin id is just a COMPARE key over the high columns.  One COMPARE per bin
tags every word in that bin at once and the response counter (the same
popcount the engine's energy accounting meters) reads the bin count:

    cycles = n_bins     independent of the number of data words,

the extreme point of the word-parallel scaling the paper models.  The
data never moves; energy is dominated by the mismatching rows' line
discharges (p_mm), making this the cheapest-per-word workload in the
suite.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import APEngine
from repro.workloads import _device


def plan_bits(m: int) -> int:
    """Bit columns needed: just the resident values."""
    return m


def ap_histogram(x: np.ndarray, n_bins: int, m: int = 8,
                 backend: str = "jnp", mode: str = "device",
                 n_shards: int | None = None) -> tuple[np.ndarray, dict]:
    """Histogram of unsigned ``x`` (< 2^m) into ``n_bins`` equal bins.

    ``n_bins`` must be a power of two dividing 2^m.  Returns
    (counts[n_bins], engine counters).  Exact.  ``mode="device"`` runs
    all bin probes as one compiled program (one host transfer);
    ``mode="eager"`` is the per-bin-sync oracle; ``mode="megakernel"``
    runs the probe batch as one fused op-group launch with bulk
    accounting (``n_shards`` shards the bitplanes over lanes).
    """
    if mode not in ("device", "eager", "megakernel"):
        raise ValueError(f"unknown mode {mode!r}")
    x = np.asarray(x, np.uint64)
    n = x.shape[0]
    if (x >= (1 << m)).any():
        raise ValueError(f"entries must fit in {m} bits")
    b = int(np.log2(max(n_bins, 1)))
    if n_bins < 2 or (1 << b) != n_bins or b > m:
        raise ValueError("n_bins must be a power of two in [2, 2^m]")

    n_words = max(((n + 31) // 32) * 32, 32)
    eng = APEngine(n_words=n_words, n_bits=plan_bits(m),
                   backend=_device.engine_backend(backend, mode),
                   n_shards=n_shards)
    val = eng.alloc.alloc(m, "val")
    buf = np.zeros(n_words, np.uint64)
    # padding rows hold the value 2^m - 1 shifted out of every bin probe?
    # no spare columns — instead park padding in the LAST bin and correct
    # the count host-side (the controller knows its own padding).
    pad = (1 << m) - 1
    buf[:n] = x
    buf[n:] = pad
    eng.load(val, buf)

    counts = np.zeros(n_bins, np.int64)
    cols = [val.col(i) for i in range(m - b, m)]   # top b columns
    keys = [[(k >> i) & 1 for i in range(b)] for k in range(n_bins)]
    if mode == "megakernel":
        counts[:] = _device.count_probes_mk(
            eng, np.tile(np.asarray(cols, np.int32), (n_bins, 1)),
            np.asarray(keys, np.uint32))
    elif mode == "device":
        counts[:] = _device.count_probes(
            eng, np.tile(np.asarray(cols, np.int32), (n_bins, 1)),
            np.asarray(keys, np.uint32))
    else:
        for k in range(n_bins):
            eng.compare(cols, keys[k])
            counts[k] = eng.tag_count()
    counts[n_bins - 1] -= n_words - n              # remove padding rows

    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["n"] = n
    counters["m"] = m
    return counts, counters


def reference(x: np.ndarray, n_bins: int, m: int = 8) -> np.ndarray:
    x = np.asarray(x, np.int64)
    return np.bincount(x >> (m - int(np.log2(n_bins))),
                       minlength=n_bins).astype(np.int64)
