"""N-point radix-2 FFT on the AP (paper §3.1 workload 2).

One PU per point; fixed-point complex data (two's complement, Q-format).
Each of the log2(N) stages is:

  1. *exchange*      — every PU obtains its butterfly partner's (re, im)
                       through the Interconnect (paper §2.1/§2.2).  Two
                       models: ``parallel`` (circuit-switched network: one
                       transfer cycle per active bit-column) and ``serial``
                       (memory reads/writes: 2 cycles per word), both charged
                       to the engine's cycle counter.
  2. *twiddle bcast* — stage-s twiddles take 2^s distinct values; each is
                       broadcast by an index-matched compare + tagged write
                       (the paper's LUT idiom, constants carried in the
                       instruction stream).  Sum over stages: 2(N-1) passes.
  3. *butterfly*     — word-parallel: val = lower ? self : partner;
                       t = w * val (4 signed muls + add/sub, O(m^2));
                       out = upper ? base+t : base-t via conditional
                       add/subtract pass schedules.

Total: O(m^2 log N) compute cycles — length-independent per stage, the
core AP advantage the paper models with s_APU.

Per-stage butterfly/twiddle schedules vary slightly in pass count and
column fan-in; the engine's shape-bucketed runner
(`engine.bucket_schedule`) folds them onto a handful of compiled
programs instead of retracing per stage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import arith, isa
from repro.core.bitplane import Field
from repro.core.engine import APEngine


def _to_fixed(x: np.ndarray, frac: int, m: int) -> np.ndarray:
    v = np.round(np.asarray(x, np.float64) * (1 << frac)).astype(np.int64)
    lim = 1 << (m - 1)
    v = np.clip(v, -lim, lim - 1)
    return v & ((1 << m) - 1)


def _from_fixed(u: np.ndarray, frac: int, m: int) -> np.ndarray:
    u = np.asarray(u, np.int64)
    sign = u >> (m - 1)
    return (u - (sign << m)).astype(np.float64) / (1 << frac)


@dataclasses.dataclass
class _Plan:
    re: Field
    im: Field
    pre: Field
    pim: Field
    vre: Field
    vim: Field
    prod: Field
    t_re: Field
    t_im: Field
    wre: Field
    wim: Field
    idx: Field
    lower: Field
    carry: Field
    sa: Field
    sb: Field
    z: Field


def _interconnect_exchange(eng: APEngine, src: Field, dst: Field,
                           perm: np.ndarray, mode: str) -> None:
    """dst[p] <- src[perm[p]] for all PUs, charging interconnect cycles."""
    vals = eng.peek(src)          # host mediates the transfer model
    eng.load(dst, vals[perm])
    if mode == "parallel":
        # circuit-switched: all PUs move one bit-column per cycle
        eng.cycles += 2 * src.width            # read-out + write-in per column
    elif mode == "serial":
        # associative read + write per word (paper's serial option)
        eng.cycles += 2 * eng.n_words
        eng.read_cycles += eng.n_words
    else:
        raise ValueError(mode)


def _broadcast_twiddles(eng: APEngine, plan: _Plan, stage: int, n: int,
                        frac: int, m: int) -> None:
    """Write stage twiddles by index-matched compare+write (LUT idiom)."""
    half = 1 << stage
    step = n // (2 * half)
    for t in range(half):
        w = np.exp(-2j * np.pi * (t * step) / n)
        wre = int(_to_fixed(np.array([w.real]), frac, m)[0])
        wim = int(_to_fixed(np.array([w.imag]), frac, m)[0])
        cols = [plan.idx.col(b) for b in range(stage)]  # idx mod half == t
        key = [(t >> b) & 1 for b in range(stage)]
        if not cols:  # stage 0: all PUs share w = 1
            eng.bwrite(plan.wre.cols() + plan.wim.cols(),
                       [(wre >> i) & 1 for i in range(m)]
                       + [(wim >> i) & 1 for i in range(m)])
            continue
        eng.compare(cols, key)
        eng.write(plan.wre.cols() + plan.wim.cols(),
                  [(wre >> i) & 1 for i in range(m)]
                  + [(wim >> i) & 1 for i in range(m)])


def ap_fft(x: np.ndarray, m: int = 16, frac: int = 12,
           interconnect: str = "parallel", backend: str = "jnp"
           ) -> tuple[np.ndarray, dict]:
    """FFT of complex vector x (|x| <= 1 advisable) on an N-PU AP.

    Returns (X as complex128 from the fixed-point result, counters).
    """
    x = np.asarray(x, np.complex128)
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError("N must be a power of two")
    stages = int(np.log2(n))
    n_words = max(n, 32)

    # columns: data + partner + operand + product + t + w + idx + flags
    n_bits = (2 + 2 + 2 + 0 + 2 + 2) * m + 2 * m + stages + 6
    eng = APEngine(n_words=n_words, n_bits=n_bits, backend=backend)
    a = eng.alloc
    plan = _Plan(
        re=a.alloc(m, "re"), im=a.alloc(m, "im"),
        pre=a.alloc(m, "pre"), pim=a.alloc(m, "pim"),
        vre=a.alloc(m, "vre"), vim=a.alloc(m, "vim"),
        prod=a.alloc(2 * m, "prod"),
        t_re=a.alloc(m, "tre"), t_im=a.alloc(m, "tim"),
        wre=a.alloc(m, "wre"), wim=a.alloc(m, "wim"),
        idx=a.alloc(max(stages, 1), "idx"),
        lower=a.alloc(1, "lower"), carry=a.alloc(1, "carry"),
        sa=a.alloc(1, "sa"), sb=a.alloc(1, "sb"), z=a.alloc(1, "z"))

    # bit-reversed input order (standard iterative DIT)
    rev = np.array([int(format(i, f"0{stages}b")[::-1], 2) for i in range(n)])
    re0 = np.zeros(n_words, np.uint64)
    im0 = np.zeros(n_words, np.uint64)
    re0[:n] = _to_fixed(x.real[rev], frac, m)
    im0[:n] = _to_fixed(x.imag[rev], frac, m)
    eng.load(plan.re, re0)
    eng.load(plan.im, im0)
    idxs = np.zeros(n_words, np.uint64)
    idxs[:n] = np.arange(n)
    eng.load(plan.idx, idxs)

    def smul(dst: Field, af: Field, bf: Field):
        """dst <- (af * bf) >> frac  (signed Q-format multiply)."""
        arith.run_signed_mul(eng, af, bf, plan.prod, plan.carry,
                             plan.sa, plan.sb, plan.z)
        eng.run(isa.copy(dst, plan.prod.slice(frac, m)))

    for s in range(stages):
        half = 1 << s
        # 1. exchange with butterfly partner (i XOR half)
        perm = (np.arange(n_words) ^ half) % n_words
        perm[n:] = np.arange(n, n_words)
        _interconnect_exchange(eng, plan.re, plan.pre, perm, interconnect)
        _interconnect_exchange(eng, plan.im, plan.pim, perm, interconnect)
        # lower flag = bit s of index (1 => this PU is x[j], j = i + half)
        eng.run(isa.copy(plan.lower, plan.idx.bit(s)))
        # 2. twiddles
        _broadcast_twiddles(eng, plan, s, n, frac, m)
        # 3. operand select: val = lower ? self : partner
        eng.run(isa.copy(plan.vre, plan.pre))
        eng.run(isa.cond_copy(plan.vre, plan.re, plan.lower))
        eng.run(isa.copy(plan.vim, plan.pim))
        eng.run(isa.cond_copy(plan.vim, plan.im, plan.lower))
        # t = w * val  (complex):  t_re = wr*vr - wi*vi ; t_im = wr*vi + wi*vr
        smul(plan.t_re, plan.wre, plan.vre)
        smul(plan.t_im, plan.wre, plan.vim)
        smul(plan.vre, plan.wim, plan.vre)   # vre <- wi*vr (vre consumed last)
        smul(plan.vim, plan.wim, plan.vim)   # vim <- wi*vi
        eng.clear(plan.carry)
        eng.run(isa.sub(plan.vim, plan.t_re, plan.carry))   # t_re -= wi*vi
        eng.clear(plan.carry)
        eng.run(isa.add(plan.vre, plan.t_im, plan.carry))   # t_im += wi*vr
        # 4. base = lower ? partner : self, then out = base +/- t
        eng.run(isa.cond_copy(plan.re, plan.pre, plan.lower))
        eng.run(isa.cond_copy(plan.im, plan.pim, plan.lower))
        for val_f, t_f in ((plan.re, plan.t_re), (plan.im, plan.t_im)):
            eng.clear(plan.carry)
            eng.run(arith.cond_sub(t_f, val_f, plan.carry, plan.lower))
            # upper: add (condition = NOT lower, via inverted compare key)
            eng.clear(plan.carry)
            sched = arith.cond_add(t_f, val_f, plan.carry, plan.lower)
            # flip the condition key bit: passes matched on lower==1 -> ==0
            flip = sched.cmp_key.copy()
            flip[:, 0] = 1 - flip[:, 0]
            sched.cmp_key = flip
            eng.run(sched)

    re = _from_fixed(eng.read(plan.re)[:n], frac, m)
    im = _from_fixed(eng.read(plan.im)[:n], frac, m)
    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["n"] = n
    counters["m"] = m
    return re + 1j * im, counters


def reference(x: np.ndarray) -> np.ndarray:
    return np.fft.fft(np.asarray(x, np.complex128))
