"""Sparse matrix-vector multiply on the AP (tag-masked accumulation).

y = A @ x with A sparse: one PU per stored nonzero, holding the triple
(row index, a_ij, x_j) resident — the gather of x_j happens at load time
(host DMA), so the irregular access pattern that cripples a cached SIMD
costs the AP nothing.  Two phases:

1. *products* — prod = a * x word-parallel over every nonzero at once
   (``arith.run_mul``, O(m^2) cycles total, the eq-(7) advantage);
2. *reduction* — tag-masked accumulation: for output row i and product
   bit b, one COMPARE tags the nonzeros with ``row == i`` and bit b set;
   the response counter contributes ``count << b`` to y_i host-side
   (the CAM's population count is the adder tree).

    cycles = O(m^2) + O(n_rows * 2m)    independent of nnz.

Exact (integer) result; energy through the engine's matched-row
accounting.
"""
from __future__ import annotations

import numpy as np

from repro.core import arith
from repro.core.engine import APEngine
from repro.workloads import _device


def plan_bits(n_rows: int, m: int) -> int:
    """Bit columns: row index + a + x + product + carry."""
    r_w = max(1, int(np.ceil(np.log2(max(n_rows, 2)))))
    return r_w + 2 * m + 2 * m + 1


def ap_spmv(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
            x: np.ndarray, n_rows: int, m: int = 8,
            backend: str = "jnp", mode: str = "device",
            n_shards: int | None = None) -> tuple[np.ndarray, dict]:
    """y = A @ x for A in COO form (rows, cols, vals); entries < 2^m.

    Returns (y[n_rows], engine counters).  Exact (integer).
    ``mode="device"`` runs the whole per-(row, bit) tag-count reduction
    as one compiled program; ``mode="eager"`` is the per-probe oracle;
    ``mode="megakernel"`` fuses the probe batch into one op-group
    launch with bulk accounting (``n_shards`` shards the lanes).
    """
    if mode not in ("device", "eager", "megakernel"):
        raise ValueError(f"unknown mode {mode!r}")
    rows = np.asarray(rows, np.uint64)
    cols = np.asarray(cols, np.uint64)
    vals = np.asarray(vals, np.uint64)
    x = np.asarray(x, np.uint64)
    nnz = vals.shape[0]
    if (vals >= (1 << m)).any() or (x >= (1 << m)).any():
        raise ValueError(f"entries must fit in {m} bits")
    if nnz == 0:
        raise ValueError("empty matrix")

    r_w = max(1, int(np.ceil(np.log2(max(n_rows, 2)))))
    n_words = max(((nnz + 31) // 32) * 32, 32)
    eng = APEngine(n_words=n_words, n_bits=plan_bits(n_rows, m),
                   backend=_device.engine_backend(backend, mode),
                   n_shards=n_shards)
    row_f = eng.alloc.alloc(r_w, "row")
    a_f = eng.alloc.alloc(m, "a")
    x_f = eng.alloc.alloc(m, "x")
    prod = eng.alloc.alloc(2 * m, "prod")
    carry = eng.alloc.alloc(1, "carry")

    def pad(v, fill=0):
        buf = np.full(n_words, fill, np.uint64)
        buf[:nnz] = v
        return buf

    # padding rows get row index n_rows-1 but a = x = 0 => zero products
    eng.load(row_f, pad(rows, fill=n_rows - 1))
    eng.load(a_f, pad(vals))
    eng.load(x_f, pad(x[cols]))          # the load-time gather

    arith.run_mul(eng, a_f, x_f, prod, carry)

    y = np.zeros(n_rows, np.int64)
    row_cols = row_f.cols()
    if mode in ("device", "megakernel"):
        probe_cols = np.asarray([row_cols + [prod.col(b)]
                                 for i in range(n_rows)
                                 for b in range(2 * m)], np.int32)
        probe_keys = np.asarray([[(i >> rb) & 1 for rb in range(r_w)] + [1]
                                 for i in range(n_rows)
                                 for _ in range(2 * m)], np.uint32)
        probe = (_device.count_probes_mk if mode == "megakernel"
                 else _device.count_probes)
        counts = probe(eng, probe_cols, probe_keys)
        for i in range(n_rows):
            for b in range(2 * m):
                y[i] += int(counts[i * 2 * m + b]) << b
    else:
        for i in range(n_rows):
            key = [(i >> b) & 1 for b in range(r_w)]
            for b in range(2 * m):
                eng.compare(row_cols + [prod.col(b)], key + [1])
                y[i] += eng.tag_count() << b

    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["nnz"] = nnz
    counters["n_rows"] = n_rows
    counters["m"] = m
    return y, counters


def reference(rows, cols, vals, x, n_rows: int) -> np.ndarray:
    y = np.zeros(n_rows, np.int64)
    np.add.at(y, np.asarray(rows, np.int64),
              np.asarray(vals, np.int64) * np.asarray(x, np.int64)[cols])
    return y
