"""Associative sort on the AP (min-extraction idiom, CAM folklore).

The classic CAM sort: keep an *active* marker column, and repeatedly
extract the minimum of the active rows by an MSB-first candidate
narrowing — for each bit position, COMPARE selects the candidates with a
0 at that bit; if any respond (response counter > 0) the 1-candidates
are retired with a tagged WRITE, otherwise the minimum's bit is 1 and
the candidate set is unchanged.  After the LSB the surviving candidates
all hold the minimum, its value is known host-side from the bit
decisions, and the whole tie group is retired at once, so the cost is

    cycles = O(distinct_values * m)     independent of the PU count,

the word-parallel advantage eq (7) models.  Energy flows through the
engine's exact matched-row accounting like every other workload.

Two execution modes, same bit-exact results and accounting:

* ``mode="device"`` (default) — the whole extraction loop runs as ONE
  compiled program (``_device.min_extract_rounds``), with the response-
  counter branch as an on-device select and one host transfer total;
* ``mode="eager"`` — the original per-cycle loop, kept as the oracle
  (tests/test_device_workloads.py pins device == eager exactly).
"""
from __future__ import annotations

import numpy as np

from repro.core import isa
from repro.core.bitplane import Field
from repro.core.engine import APEngine
from repro.workloads import _device


def plan_bits(m: int) -> int:
    """Bit columns needed: value + active marker + candidate marker."""
    return m + 2


def extract_min(eng: APEngine, val: Field, active: Field,
                cand: Field) -> tuple[int, int]:
    """One CAM min-extraction over the rows with ``active`` == 1.

    MSB-first narrowing of the candidate set (copied from ``active``);
    leaves TAG selecting the minimum's tie group.  Returns
    (min_value, tie_count); tie_count == 0 means no row was active.
    """
    eng.run(isa.copy(cand, active))
    v = 0
    for i in reversed(range(val.width)):
        eng.compare([cand.col(0), val.col(i)], [1, 0])
        if eng.tag_count() > 0:
            # some candidate has a 0 here: retire the 1-candidates
            eng.compare([cand.col(0), val.col(i)], [1, 1])
            eng.write([cand.col(0)], [0])
        else:
            v |= 1 << i
    eng.compare([cand.col(0)], [1])
    return v, eng.tag_count()


def ap_sort(x: np.ndarray, m: int = 8, backend: str = "jnp",
            mode: str = "device", n_shards: int | None = None
            ) -> tuple[np.ndarray, dict]:
    """Sort unsigned integers ``x`` (< 2^m) ascending on an n-PU AP.

    Returns (sorted array, engine counters).  Exact.
    ``mode="megakernel"`` runs each extraction round as one fused
    op-group launch plus a single bulk accounting fold (bit-identical
    to both other modes); ``n_shards`` (megakernel only) shards the
    bitplanes over that many devices.
    """
    if mode not in ("device", "eager", "megakernel"):
        raise ValueError(f"unknown mode {mode!r}")
    x = np.asarray(x, np.uint64)
    n = x.shape[0]
    if (x >= (1 << m)).any():
        raise ValueError(f"entries must fit in {m} bits")

    n_words = max(((n + 31) // 32) * 32, 32)
    eng = APEngine(n_words=n_words, n_bits=plan_bits(m),
                   backend=_device.engine_backend(backend, mode),
                   n_shards=n_shards)
    val = eng.alloc.alloc(m, "val")
    active = eng.alloc.alloc(1, "active")
    cand = eng.alloc.alloc(1, "cand")

    buf = np.zeros(n_words, np.uint64)
    buf[:n] = x
    eng.load(val, buf)
    mask = np.zeros(n_words, np.uint64)
    mask[:n] = 1
    eng.load(active, mask)

    out: list[int] = []
    if mode == "megakernel":
        rounds = min(n, 1 << m)
        tr = _device.min_extract_rounds_mk(eng, val, active, cand, rounds,
                                           remaining=n)
        vals, cnts, _ = _device.replay_extract_bulk(eng, tr, m, budget=n)
        out = np.repeat(vals, cnts)[:n].tolist()
    elif mode == "device":
        # at most one extraction per distinct value; rounds past the
        # data-dependent end run as masked no-ops on device
        rounds = min(n, 1 << m)
        tr = _device.min_extract_rounds(eng, val, active, cand, rounds,
                                        remaining=n)
        r = 0
        while len(out) < n and r < rounds:
            v, count = _device.replay_extract(eng, tr, r, m)
            if count == 0:
                break
            out.extend([v] * count)
            eng.charge_write(1, count)      # retire the tie group
            r += 1
    else:
        while len(out) < n:
            v, count = extract_min(eng, val, active, cand)
            if count == 0:  # defensive: active set exhausted early
                break
            out.extend([v] * count)
            eng.write([active.col(0)], [0])  # TAG still holds the tie group

    counters = eng.counters()
    counters["trace_cycles"], counters["trace_energy"] = eng.trace_events()
    counters["n"] = n
    counters["m"] = m
    return np.asarray(out[:n], np.uint64), counters


def reference(x: np.ndarray) -> np.ndarray:
    return np.sort(np.asarray(x, np.uint64))
