"""Lower a :class:`~repro.sweep.spec.SweepSpec` to batched replays.

Scenario points that share a stack height, feedback mode, and DTM
policy share one jitted program, so the engine groups the grid by
``(n_dram, fb_mode, policy)``
and replays each group as a SINGLE vmapped ``closed_loop_batch`` call
over every (point × machine) case — the same path
``stack/feedback.run_stack_cosim`` uses, now fed from the declarative
spec instead of hand-rolled benchmark loops.  Results come back as
:class:`SweepRecord`s wrapping the familiar
:class:`~repro.stack.feedback.StackReport`, in deterministic
``spec.points() × spec.machines`` order, and are persisted through the
content-hashed cache (``repro.sweep.cache``) so a repeat invocation is
served bit-identically from disk.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro import obs
from repro.core import cosim
from repro.core import models as M
from repro.core.constants import DRAM_LIMIT_C
from repro import policy as policy_registry
from repro.stack import feedback
from repro.stack.spec import PAPER_STACK, StackParams, dram_on_logic
from repro.sweep.spec import SweepPoint, SweepSpec


def resolve_fb(mode: str, n_picard: int = 6,
               policy: str = "ramp") -> feedback.FeedbackParams:
    """Map a spec-level (feedback mode, policy name) to FeedbackParams.

    ``n_picard`` applies to the implicit-coupling modes; "open" keeps
    the fixed 2-iterate count of :meth:`FeedbackParams.disabled`.
    ``policy`` (a ``repro.policy`` registry name) selects the DTM/DVFS
    controller in "closed" mode only — "nodtm" and "open" disable DTM
    by definition, so the policy axis is inert there (the sweep grid
    still enumerates the combination; it is served from the same
    replay)."""
    if mode == "closed":
        pol = None if policy == "ramp" else policy_registry.get(policy)
        return feedback.FeedbackParams(n_picard=n_picard, policy=pol)
    if mode == "nodtm":
        return feedback.FeedbackParams(dtm_trip_C=math.inf,
                                       n_picard=n_picard)
    if mode == "open":
        return feedback.FeedbackParams.disabled()
    raise ValueError(f"unknown fb_mode {mode!r}")


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    """One (scenario point, machine) outcome."""
    point: SweepPoint
    machine: str
    report: feedback.StackReport

    @property
    def label(self) -> str:
        return f"{self.point.label}/{self.machine}"

    @property
    def limit_layers(self) -> tuple[int, ...]:
        """Layers the 85 °C verdict is judged on: the DRAM dies when the
        stack has any, else every die layer (bare-logic stacking case)."""
        spec = self.report.spec
        return spec.dram_layers or tuple(range(spec.n_die_layers))

    @property
    def time_above_limit_s(self) -> float:
        return float(self.report.time_above(
            layers=self.limit_layers).max())

    @property
    def failed(self) -> bool:
        """Did this case's replay yield non-finite results?  (NaN/inf
        temperatures, residuals, or duties — a diverged solve, faulted
        controller, or a group whose replay raised.)  Failed records
        are isolated per case: they mark FAILED in the table and never
        read as a passing verdict (NaN > 85 is False)."""
        return not (np.isfinite(self.report.peak_C).all()
                    and np.isfinite(self.report.residual_C).all()
                    and np.isfinite(self.report.throttle).all())

    @property
    def verdict_ok(self) -> bool:
        """May this die sit under (or be) 3D DRAM?  (§4.3 ceiling)"""
        return not self.failed and self.time_above_limit_s == 0.0


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """All records of one sweep, in spec.points() × spec.machines order."""
    spec: SweepSpec
    records: tuple[SweepRecord, ...]
    from_cache: bool = False

    def __iter__(self):
        return iter(self.records)

    def get(self, point: SweepPoint, machine: str) -> SweepRecord:
        for r in self.records:
            if r.point == point and r.machine == machine:
                return r
        raise KeyError((point, machine))

    def table(self) -> str:
        """Per-point verdict table (CSV-ish, one row per record)."""
        lines = ["workload,size,n_dram,fb,policy,machine,logic_peak_C,"
                 "dram_peak_C,refresh_x,dtm_x,above_85C_s,resid_C,verdict"]
        for r in self.records:
            p, rep = r.point, r.report
            dram_pk = rep.dram_peak_C.max() if rep.spec.dram_layers else 0.0
            lines.append(
                f"{p.workload},{p.size},{p.n_dram},{p.fb_mode},"
                f"{p.policy},{r.machine},"
                f"{rep.logic_peak_C.max():.1f},{dram_pk:.1f},"
                f"{rep.refresh_overhead:.3f},{rep.dtm_slowdown:.3f},"
                f"{r.time_above_limit_s:.3f},{rep.residual_C.max():.2g},"
                f"{'FAILED' if r.failed else 'OK' if r.verdict_ok else 'BLOCKED'}")
        return "\n".join(lines)

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.records if r.failed)


# ---------------------------------------------------------------------------
# the lowering
# ---------------------------------------------------------------------------

def _run_group(spec: SweepSpec, points: list[SweepPoint], n_dram: int,
               fb_mode: str, policy: str, params: StackParams,
               n_shards: int | None = None
               ) -> dict[tuple[SweepPoint, str], SweepRecord]:
    """Replay one (n_dram, fb_mode, policy) group as a single vmapped
    batch, optionally partitioned over local devices (``n_shards``)."""
    stack_spec = dram_on_logic(n_dram, params)
    fb = resolve_fb(fb_mode, spec.n_picard, policy)
    margin = spec.grid_n // 4
    interval_dt = spec.t_end / spec.n_intervals

    with obs.span("sweep/assemble", n_dram=n_dram, fb=fb_mode,
                  policy=policy, points=len(points)):
        keys, cases = [], []
        for p in points:
            dp = cosim.comparable_design_point(p.workload, p.size)
            wl = M.WORKLOADS[p.workload]
            for mc in spec.machines:
                trace = cosim.ap_workload_trace(
                    p.workload, spec.n_intervals, spec.trace_elems(p.size),
                    mode=spec.ap_backend) \
                    if mc == "ap" else \
                    cosim.simd_phase_trace(wl, dp, spec.n_intervals)
                keys.append((p, mc))
                cases.append((f"{p.label}/{mc}", feedback.assemble_case(
                    dp, p.workload, mc, stack_spec, params, spec.grid_n,
                    trace, margin)))
    obs.count("sweep/cases", len(cases))

    with obs.span("sweep/replay", n_dram=n_dram, fb=fb_mode,
                  policy=policy, cases=len(cases)):
        reports = feedback.replay_cases(
            cases, stack_spec, fb, spec.grid_n, interval_dt,
            theta=spec.theta, steps_per_interval=spec.steps_per_interval,
            n_cg=spec.n_cg, margin=margin, solver=spec.solver,
            n_mg=spec.n_mg, n_shards=n_shards)
    return {(p, mc): SweepRecord(point=p, machine=mc,
                                 report=reports[f"{p.label}/{mc}"])
            for p, mc in keys}


def _failed_group(spec: SweepSpec, points: list[SweepPoint], n_dram: int,
                  fb_mode: str, policy: str, params: StackParams,
                  reason: str
                  ) -> dict[tuple[SweepPoint, str], SweepRecord]:
    """NaN-filled placeholder records for a group whose replay raised.

    Shapes match a live replay's, every value is NaN, so each record
    reports ``failed`` and the table row reads FAILED — the rest of the
    sweep is unaffected (per-group failure isolation)."""
    stack_spec = dram_on_logic(n_dram, params)
    fb = resolve_fb(fb_mode, spec.n_picard, policy)
    nanT = np.full((spec.n_intervals, stack_spec.n_die_layers), np.nan,
                   np.float32)
    nan1 = np.full(spec.n_intervals, np.nan, np.float32)
    out = {}
    for p in points:
        for mc in spec.machines:
            rep = feedback.StackReport(
                label=f"{p.label}/{mc}",
                interval_s=spec.t_end / spec.n_intervals, spec=stack_spec,
                peak_C=nanT, min_C=nanT, residual_C=nan1, throttle=nan1,
                refresh_W=nan1, leak_W=nan1, base_refresh_W=0.0,
                tol_C=fb.picard_tol_C, dyn_W=nan1)
            out[(p, mc)] = SweepRecord(point=p, machine=mc, report=rep)
    print(f"sweep: group dram{n_dram}/{fb_mode}/{policy} FAILED "
          f"({reason}); {len(out)} case(s) isolated")
    return out


def run_sweep(spec: SweepSpec, cache_dir=None, use_cache: bool = True,
              params: StackParams = PAPER_STACK,
              n_shards: int | None = None) -> SweepResult:
    """Run (or load) a sweep.  With ``use_cache`` the content-hashed
    on-disk entry is consulted first and written after a live run, so a
    second invocation of the same spec is served bit-identically from
    disk.

    ``n_shards`` partitions every group's case batch over that many
    local devices (``shard_map`` over a 'cases' mesh; None/0 = plain
    single-device vmap).  It is an EXECUTION knob, not part of the
    spec: per-case results are bitwise identical for any shard count,
    so cache keys and cached artifacts do not depend on it.
    """
    from repro.sweep import cache
    if params != PAPER_STACK:
        use_cache = False       # cache keys don't cover custom stack params
    if use_cache:
        hit = cache.load(spec, cache_dir)
        if hit is not None:
            return hit

    # "nodtm"/"open" ignore the policy axis entirely, so their points
    # collapse onto one replay group per (n_dram, fb_mode) regardless of
    # the spec's policy list — no duplicate physics for inert labels
    by_group: dict[tuple[int, str, str], list[SweepPoint]] = \
        defaultdict(list)
    for p in spec.points():
        pol = p.policy if p.fb_mode == "closed" else "ramp"
        by_group[(p.n_dram, p.fb_mode, pol)].append(p)

    results: dict[tuple[SweepPoint, str], SweepRecord] = {}
    with obs.span("sweep/run", groups=len(by_group)):
        for (n_dram, fb_mode, pol), pts in sorted(by_group.items()):
            with obs.span("sweep/group", n_dram=n_dram, fb=fb_mode,
                          policy=pol, points=len(pts)):
                # per-group failure isolation: one group raising (bad
                # power inputs, a faulted replay, a solver blow-up)
                # must not kill the other groups' results — it is
                # demoted to NaN placeholder records marked FAILED
                try:
                    results.update(_run_group(spec, pts, n_dram, fb_mode,
                                              pol, params, n_shards))
                except (ValueError, FloatingPointError) as e:
                    obs.count("sweep/groups_failed")
                    results.update(_failed_group(
                        spec, pts, n_dram, fb_mode, pol, params, str(e)))

    records = tuple(results[(p, mc)] for p in spec.points()
                    for mc in spec.machines)
    out = SweepResult(spec=spec, records=records)
    # never persist failures: a cached FAILED row would keep serving
    # the placeholder after the underlying cause is fixed
    if use_cache and not out.n_failed:
        cache.store(out, cache_dir)
    return out


__all__ = ["SweepRecord", "SweepResult", "run_sweep", "resolve_fb",
           "DRAM_LIMIT_C"]
