"""Batched scenario-sweep subsystem.

Declares a scenario grid — workloads × dataset sizes × DRAM stack
heights × feedback/DTM modes — as a :class:`~repro.sweep.spec.SweepSpec`
(``spec.py``), lowers it to vmapped closed-loop replays over the
``stack/feedback`` path (``engine.py``), and serves repeat invocations
bit-identically from a content-hashed on-disk cache (``cache.py``).
This is the substrate the benchmarks drive and later scaling PRs
(sharding, multi-backend) plug into.
"""
from repro.sweep.spec import SweepPoint, SweepSpec  # noqa: F401
from repro.sweep.engine import SweepRecord, SweepResult, run_sweep  # noqa: F401
