"""Declarative sweep specifications and their content hash.

A :class:`SweepSpec` names a full scenario grid — registered workloads ×
dataset sizes × DRAM die counts × feedback modes × DTM/DVFS policies
(× machines) — plus the
replay resolution (grid, intervals, horizon, solver knobs).  It is pure
data: :meth:`SweepSpec.points` enumerates the Cartesian product and
:meth:`SweepSpec.content_hash` digests the *canonical JSON* of every
field (plus a schema version) into the cache key, so any field
perturbation — one more workload, a different DTM mode, a finer grid —
misses the cache while the identical spec always hits it
(DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json

# Bump when the result schema or replay semantics change: a new schema
# must never be served stale results from an old cache entry.
# 2: solver/n_mg fields (selectable multigrid inner solve, ISSUE 4).
# 3: device-resident AP engine — trace_elems clamp 256 -> 2048 and
#    instance-scaled histogram bins re-derive every workload trace.
# 4: ap_backend field (megakernel trace capture) and trace_elems clamp
#    2048 -> 2^20; traces at sizes past 2048^2 change element counts.
# 5: policy axis (DTM/DVFS policy engine) and the dyn_W energy array in
#    every record; pre-policy entries lack both.
CACHE_SCHEMA = 5

#: trace-capture execution paths for the AP workloads (all bit-exact;
#: the field exists so a spec records how its traces were captured)
AP_BACKENDS = ("device", "eager", "megakernel")

#: inner-solver axis for the implicit replay steps (engine.py resolves
#: it through ``thermal.implicit_lhs_solver``): fixed-iteration
#: Jacobi-PCG or fixed-cycle geometric multigrid
SOLVERS = ("pcg", "mg")

#: feedback-mode axis -> FeedbackParams factory (resolved in engine.py)
FB_MODES = ("closed", "nodtm", "open")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One scenario: a (workload, size, stack, feedback, policy) tuple."""
    workload: str
    size: int            # dataset size N (the AP is sized to it, §3)
    n_dram: int          # DRAM dies stacked on the logic stack
    fb_mode: str         # one of FB_MODES
    policy: str = "ramp"     # DTM/DVFS controller (repro.policy names);
    # only "closed" mode runs it — "nodtm"/"open" disable DTM entirely

    @property
    def label(self) -> str:
        return (f"{self.workload}/N{self.size}/dram{self.n_dram}/"
                f"{self.fb_mode}/{self.policy}")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A scenario grid and the resolution to replay it at."""
    workloads: tuple[str, ...]
    sizes: tuple[int, ...] = (2 ** 20,)
    n_dram: tuple[int, ...] = (2,)
    fb_modes: tuple[str, ...] = ("closed",)
    policies: tuple[str, ...] = ("ramp",)   # repro.policy registry names
    machines: tuple[str, ...] = ("ap", "simd")
    grid_n: int = 16
    n_intervals: int = 24
    t_end: float = 0.25
    steps_per_interval: int = 2
    n_cg: int = 40
    theta: float = 1.0
    n_picard: int = 6     # Picard iterations for the implicit couplings;
    # the documented 0.05 °C/interval bar needs ~20 in the most violent
    # sweep regimes (refresh 4x + leakage much above trip) — "open" mode
    # keeps its own fixed count (FeedbackParams.disabled)
    solver: str = "pcg"   # inner solve per implicit step (SOLVERS);
    # results depend on it (different fixed-cost approximations), so it
    # is part of the spec and the cache key — unlike the shard count,
    # which is a pure execution detail and deliberately NOT a field
    n_mg: int = 3         # V-cycles per step when solver == "mg"
    ap_backend: str = "device"   # AP trace-capture path (AP_BACKENDS);
    # every path is pinned bit-identical by the differential tests, so
    # this cannot change results — it is a spec field (and thus part of
    # the cache key) anyway so a cache entry records exactly how its
    # traces were produced, and because the schema-4 megakernel path is
    # what makes the lifted trace_elems clamp affordable

    def __post_init__(self):
        from repro.workloads import registry
        for w in self.workloads:
            registry.get(w)                      # raises on unknown names
        for mode in self.fb_modes:
            if mode not in FB_MODES:
                raise ValueError(f"unknown fb_mode {mode!r}; "
                                 f"expected one of {FB_MODES}")
        from repro import policy as policy_registry
        for pol in self.policies:
            policy_registry.get(pol)             # raises on unknown names
        for mc in self.machines:
            if mc not in ("ap", "simd"):
                raise ValueError(f"unknown machine {mc!r}")
        if any(s < 1024 for s in self.sizes):
            raise ValueError("dataset sizes below 1024 have no "
                             "comparable design point")
        if any(n < 0 for n in self.n_dram):
            raise ValueError("n_dram must be >= 0")
        if self.n_picard < 1:
            raise ValueError("n_picard must be >= 1")
        if self.solver not in SOLVERS:
            raise ValueError(f"unknown solver {self.solver!r}; "
                             f"expected one of {SOLVERS}")
        if self.n_mg < 1:
            raise ValueError("n_mg must be >= 1")
        if self.ap_backend not in AP_BACKENDS:
            raise ValueError(f"unknown ap_backend {self.ap_backend!r}; "
                             f"expected one of {AP_BACKENDS}")

    # -------------------------------------------------------------- points
    def points(self) -> tuple[SweepPoint, ...]:
        """The Cartesian scenario grid, in deterministic order."""
        return tuple(SweepPoint(w, s, d, f, p) for w, s, d, f, p
                     in itertools.product(self.workloads, self.sizes,
                                          self.n_dram, self.fb_modes,
                                          self.policies))

    @property
    def n_points(self) -> int:
        return (len(self.workloads) * len(self.sizes) * len(self.n_dram)
                * len(self.fb_modes) * len(self.policies))

    def trace_elems(self, size: int) -> int:
        """Small-instance element count for a dataset size — delegates
        to the shared sizing rule (`cosim.trace_elems`) so sweeps and
        the standalone drivers replay identical traces for identical
        scenarios."""
        from repro.core import cosim
        return cosim.trace_elems(size)

    # --------------------------------------------------------------- hash
    def canonical(self) -> dict:
        """Canonical JSON form (the hash input): tuples become lists so
        the dict compares equal after any JSON round-trip."""
        d = dataclasses.asdict(self)
        d["schema"] = CACHE_SCHEMA
        return json.loads(json.dumps(d))

    def content_hash(self) -> str:
        blob = json.dumps(self.canonical(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:20]
