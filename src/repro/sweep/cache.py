"""Content-hashed on-disk result cache for sweeps.

One sweep = one ``sweep_<hash>.npz`` under the cache directory
(``$REPRO_SWEEP_CACHE`` or ``.sweep_cache/``), where ``<hash>`` is
:meth:`SweepSpec.content_hash` — a SHA-256 digest of the spec's
canonical JSON plus a schema version (DESIGN.md §8).  The npz holds the
per-record result arrays verbatim (float32/float64, so reloads are
bit-identical) and a JSON manifest with the full canonical spec, which
:func:`load` verifies against the requesting spec so a truncated-hash
collision can never serve wrong results.  Stack geometry is NOT stored:
it is deterministic from the point (``dram_on_logic(n_dram)``) and is
rebuilt on load.

A corrupt or truncated cache file (interrupted writer on a different
filesystem, disk-full, bit rot) is treated as a MISS, not an error: the
sweep recomputes and overwrites it.  Hits, misses, corrupt files, and
stores are counted under ``sweep/cache/*`` when :mod:`repro.obs` is
enabled.
"""
from __future__ import annotations

import json
import os
import zipfile
import zlib
from pathlib import Path

import numpy as np

from repro import obs
from repro.stack import dram, feedback
from repro.stack.spec import dram_on_logic
from repro.sweep.engine import SweepRecord, SweepResult, resolve_fb
from repro.sweep.spec import SweepPoint, SweepSpec

_ARRAYS = ("peak_C", "min_C", "residual_C", "throttle", "refresh_W",
           "leak_W", "dyn_W")

#: everything a damaged npz can throw while being opened/read: not a
#: zip at all, zip ok but members truncated/absent, manifest not JSON
_CORRUPT_ERRORS = (zipfile.BadZipFile, zlib.error, KeyError, ValueError,
                   EOFError, OSError, json.JSONDecodeError)


def default_cache_dir() -> Path:
    return Path(os.environ.get("REPRO_SWEEP_CACHE", ".sweep_cache"))


def path_for(spec: SweepSpec, cache_dir=None) -> Path:
    base = Path(cache_dir) if cache_dir is not None else default_cache_dir()
    return base / f"sweep_{spec.content_hash()}.npz"


def store(result: SweepResult, cache_dir=None) -> Path:
    """Persist a sweep result; returns the written path."""
    path = path_for(result.spec, cache_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload: dict[str, np.ndarray] = {}
    for i, rec in enumerate(result.records):
        for name in _ARRAYS:
            payload[f"r{i}_{name}"] = getattr(rec.report, name)
    manifest = {
        "spec": result.spec.canonical(),
        "records": [{"machine": r.machine,
                     "point": [r.point.workload, r.point.size,
                               r.point.n_dram, r.point.fb_mode,
                               r.point.policy]}
                    for r in result.records],
    }
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, manifest=np.array(json.dumps(manifest)), **payload)
    os.replace(tmp, path)
    obs.count("sweep/cache/store")
    if obs.is_enabled():
        obs.count("sweep/cache/bytes_written", path.stat().st_size)
    return path


def load(spec: SweepSpec, cache_dir=None) -> SweepResult | None:
    """Load a cached sweep for ``spec``; None on miss, manifest mismatch
    (hash-collision guard), or a corrupt/truncated file (recompute and
    overwrite rather than fail the sweep)."""
    path = path_for(spec, cache_dir)
    if not path.exists():
        obs.count("sweep/cache/miss")
        return None
    try:
        result = _read(spec, path)
    except _CORRUPT_ERRORS:
        obs.count("sweep/cache/corrupt")
        obs.count("sweep/cache/miss")
        return None
    if result is None:
        obs.count("sweep/cache/miss")
        return None
    obs.count("sweep/cache/hit")
    if obs.is_enabled():
        obs.count("sweep/cache/bytes_read", path.stat().st_size)
    return result


def _read(spec: SweepSpec, path: Path) -> SweepResult | None:
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        if manifest["spec"] != spec.canonical():
            return None
        interval_dt = spec.t_end / spec.n_intervals
        records = []
        for i, meta in enumerate(manifest["records"]):
            w, size, n_dram, fb_mode, policy = meta["point"]
            point = SweepPoint(w, int(size), int(n_dram), fb_mode,
                               policy)
            stack_spec = dram_on_logic(int(n_dram))
            base_ref = dram.DRAMFloorplan(die_w_mm=1.0).base_refresh_W() \
                * int(n_dram)
            arrays = {name: z[f"r{i}_{name}"] for name in _ARRAYS}
            report = feedback.StackReport(
                label=f"{point.label}/{meta['machine']}",
                interval_s=interval_dt, spec=stack_spec,
                base_refresh_W=base_ref,
                tol_C=resolve_fb(fb_mode, policy=policy).picard_tol_C,
                **arrays)
            records.append(SweepRecord(point=point,
                                       machine=meta["machine"],
                                       report=report))
    return SweepResult(spec=spec, records=tuple(records), from_cache=True)
