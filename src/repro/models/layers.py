"""Shared layers: norms, projections, SwiGLU MLP, embeddings, Sharder."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Sharder: activation sharding constraints, no-op off-mesh (CPU smoke tests)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Sharder:
    """Applies with_sharding_constraint when a mesh is active.

    Axis names: 'data' (DP/FSDP), 'model' (TP/EP/SP); 'pod' extends data.
    ``data_axes`` lets the launcher map batch to ('pod','data') multi-pod.
    ``seq_axes`` is the cache-sequence shard axis — 'model' by default
    (flash-decoding layout); for tiny-batch cells (long_500k, B=1) the
    launcher sets data_axes=None and seq_axes=('data','model') so the whole
    mesh shards the sequence/state instead of idling on an unsplittable
    batch axis.
    """
    mesh: Any = None
    data_axes: Any = "data"
    model_axes: Any = "model"
    seq_axes: Any = None          # defaults to model_axes

    def __post_init__(self):
        if self.seq_axes is None:
            self.seq_axes = self.model_axes

    def _c(self, x, spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    # common activation layouts
    def btd(self, x):        # [batch, seq, d_model]
        return self._c(x, P(self.data_axes, None, None))

    def bthd(self, x):       # [batch, seq, heads, head_dim]
        return self._c(x, P(self.data_axes, None, self.model_axes, None))

    def btf(self, x):        # [batch, seq, d_ff-sharded]
        return self._c(x, P(self.data_axes, None, self.model_axes))

    def btv(self, x):        # logits [batch, seq, vocab-sharded]
        return self._c(x, P(self.data_axes, None, self.model_axes))

    def bv(self, x):         # last-position logits [batch, vocab-sharded]
        return self._c(x, P(self.data_axes, self.model_axes))

    def kv_cache(self, x):   # [batch, seq, kv_heads, head_dim] seq-sharded
        return self._c(x, P(self.data_axes, self.seq_axes, None, None))

    def latent_cache(self, x):  # MLA compressed cache [batch, seq, lora]
        return self._c(x, P(self.data_axes, self.seq_axes, None))

    def ssm_state(self, x):  # [batch, d_inner-sharded, state]
        return self._c(x, P(self.data_axes, self.seq_axes, None))

    def expert_buf(self, x):  # [groups, experts, capacity, d]
        # G over 'data' (group-local GShard dispatch) and E over 'model'
        # (expert parallelism): the whole mesh computes the expert GEMMs.
        # Without the group split the data axis either REPLICATES the
        # expert FLOPs (16x bloat) or all-gathers the scatter operands —
        # both measured in EXPERIMENTS.md §Perf.
        return self._c(x, P(self.data_axes, self.model_axes, None, None))


NOSHARD = Sharder(mesh=None)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# norms (computed in f32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rmsnorm_init(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (LLaMA-style); GELU MLP (whisper)
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu(params: dict, x: jax.Array, shd: Sharder = NOSHARD) -> jax.Array:
    g = shd.btf(x @ params["w_gate"])
    u = shd.btf(x @ params["w_up"])
    h = jax.nn.silu(g) * u
    return shd.btd(h @ params["w_down"])


def gelu_mlp_init(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d, dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def gelu_mlp(params: dict, x: jax.Array, shd: Sharder = NOSHARD) -> jax.Array:
    h = shd.btf(jax.nn.gelu(x @ params["w_up"] + params["b_up"]))
    return shd.btd(h @ params["w_down"] + params["b_down"])
