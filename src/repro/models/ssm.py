"""Selective state-space layers: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Training uses a **chunked selective scan**: jax.lax.scan over sequence chunks
carrying the [B, d_inner, N] state; inside each chunk an associative scan
materializes only [B, chunk, d_inner, N] — peak activation memory is
O(L/chunk) smaller than the naive full-sequence associative scan, which is
what makes the 4k-train and 500k-decode cells fit.

Mamba-2 is run through the same per-channel scan by broadcasting its
per-head scalar decay to the head's channels (SSD's state update is the
diagonal special case — mathematically identical, the per-head structure is
only a parameterization).  Simplification vs the reference implementation:
the short causal conv is applied to x only (not B/C); noted in DESIGN.md.

Decode carries {conv window, ssm state} — O(1) per token, which is why the
SSM/hybrid archs run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import NOSHARD, Sharder, dense_init, rmsnorm, \
    rmsnorm_init


def _dt_rank(cfg: ArchConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def ssm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    din = d_inner(cfg)
    N = s.d_state
    keys = jax.random.split(key, 8)
    if s.version == 1:
        r = _dt_rank(cfg)
        kx, kz = jax.random.split(keys[0])
        p = {
            # split x/z projections so the TP shard axis is clean (no
            # cross-shard slicing of a fused in_proj output)
            "in_proj_x": dense_init(kx, d, din, dtype),
            "in_proj_z": dense_init(kz, d, din, dtype),
            "conv_w": (jax.random.normal(keys[1], (s.d_conv, din), jnp.float32)
                       * (s.d_conv * din) ** -0.5).astype(dtype),
            "conv_b": jnp.zeros((din,), dtype),
            "x_proj": dense_init(keys[2], din, r + 2 * N, dtype),
            "dt_proj": dense_init(keys[3], r, din, dtype),
            "dt_bias": jnp.full((din,), -4.6, jnp.float32),  # softplus ~ 0.01
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, N + 1, dtype=jnp.float32), (din, N))).copy(),
            "D": jnp.ones((din,), jnp.float32),
            "out_proj": dense_init(keys[4], din, d, dtype,
                                   scale=din ** -0.5),
        }
    else:  # mamba2 / SSD
        H = din // s.headdim
        kx, kz, kbc, kdt = jax.random.split(keys[0], 4)
        p = {
            "in_proj_x": dense_init(kx, d, din, dtype),
            "in_proj_z": dense_init(kz, d, din, dtype),
            "in_proj_bc": dense_init(kbc, d, 2 * N, dtype),
            "in_proj_dt": dense_init(kdt, d, H, dtype),
            "conv_w": (jax.random.normal(keys[1], (s.d_conv, din), jnp.float32)
                       * (s.d_conv * din) ** -0.5).astype(dtype),
            "conv_b": jnp.zeros((din,), dtype),
            "dt_bias": jnp.full((H,), -4.6, jnp.float32),
            "A_log": jnp.zeros((H,), jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "norm_w": rmsnorm_init(din, dtype),
            "out_proj": dense_init(keys[4], din, d, dtype,
                                   scale=din ** -0.5),
        }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B, L, D], w [K, D] -> [B, L, D]."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    L = x.shape[1]
    y = sum(pad[:, k:k + L] * w[k] for k in range(K))
    return y + b


def _scan_chunks(h0, x1, dt, Bm, Cm, A, chunk: int):
    """Chunked selective scan.

    h0 [B, D, N]; x1/dt [B, L, D]; Bm/Cm [B, L, N]; A [D, N] (positive decay
    rates).  Returns (y [B, L, D], h_last).
    """
    Bsz, L, D = x1.shape
    N = Bm.shape[-1]
    nc = max(L // chunk, 1)
    ck = L // nc
    xs = (
        jnp.moveaxis(x1.reshape(Bsz, nc, ck, D), 1, 0),
        jnp.moveaxis(dt.reshape(Bsz, nc, ck, D), 1, 0),
        jnp.moveaxis(Bm.reshape(Bsz, nc, ck, N), 1, 0),
        jnp.moveaxis(Cm.reshape(Bsz, nc, ck, N), 1, 0),
    )

    def body(h, xs_c):
        xc, dtc, Bc, Cc = (v.astype(jnp.float32) for v in xs_c)
        decay = jnp.exp(-dtc[..., None] * A)              # [B, ck, D, N]
        inp = (dtc * xc)[..., None] * Bc[:, :, None, :]   # [B, ck, D, N]

        def comb(a, b):
            da, ia = a
            db, ib = b
            return da * db, ib + db * ia

        dcum, icum = jax.lax.associative_scan(comb, (decay, inp), axis=1)
        states = dcum * h[:, None] + icum                 # [B, ck, D, N]
        y = (states * Cc[:, :, None, :]).sum(-1)          # [B, ck, D]
        return states[:, -1], y

    h_last, ys = jax.lax.scan(body, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, D)
    return y, h_last


def _split_m2(params, x, cfg: ArchConfig):
    N = cfg.ssm.d_state
    z = x @ params["in_proj_z"]
    x1 = x @ params["in_proj_x"]
    bc = x @ params["in_proj_bc"]
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt_h = x @ params["in_proj_dt"]
    return z, x1, Bm, Cm, dt_h


def ssm_train(params: dict, x: jax.Array, cfg: ArchConfig,
              shd: Sharder = NOSHARD) -> jax.Array:
    """Full-sequence forward: x [B, L, d] -> [B, L, d]."""
    s = cfg.ssm
    din = d_inner(cfg)
    N = s.d_state
    if s.version == 1:
        x1 = x @ params["in_proj_x"]
        z = x @ params["in_proj_z"]
        x1 = jax.nn.silu(_causal_conv(x1, params["conv_w"], params["conv_b"]))
        x1 = shd.btf(x1)
        r = _dt_rank(cfg)
        dbc = x1 @ params["x_proj"]
        dt = jax.nn.softplus(
            dbc[..., :r] @ params["dt_proj"] + params["dt_bias"])
        Bm, Cm = dbc[..., r:r + N], dbc[..., r + N:]
        A = jnp.exp(params["A_log"])
        D = params["D"]
    else:
        z, x1, Bm, Cm, dt_h = _split_m2(params, x, cfg)
        x1 = jax.nn.silu(_causal_conv(x1, params["conv_w"], params["conv_b"]))
        x1 = shd.btf(x1)
        dt_h = jax.nn.softplus(dt_h + params["dt_bias"])          # [B, L, H]
        dt = jnp.repeat(dt_h, s.headdim, axis=-1)                 # [B, L, D]
        A = jnp.broadcast_to(
            jnp.repeat(jnp.exp(params["A_log"]), s.headdim)[:, None], (din, N))
        D = jnp.repeat(params["D"], s.headdim)

    h0 = jnp.zeros((x.shape[0], din, N), jnp.float32)
    y, _ = _scan_chunks(h0, x1, dt, Bm, Cm, A, s.chunk)
    y = y + D * x1.astype(jnp.float32)
    if s.version == 1:
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = y.astype(x.dtype)
    else:
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        y = rmsnorm(y, params["norm_w"], cfg.norm_eps)
    return shd.btd(y @ params["out_proj"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    din = d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, din), dtype),
        "h": jnp.zeros((batch, din, s.d_state), jnp.float32),
    }


def ssm_decode(params: dict, x: jax.Array, state: dict, cfg: ArchConfig,
               shd: Sharder = NOSHARD) -> tuple[jax.Array, dict]:
    """One token: x [B, 1, d] -> ([B, 1, d], state')."""
    s = cfg.ssm
    din = d_inner(cfg)
    N = s.d_state
    if s.version == 1:
        x1 = x @ params["in_proj_x"]
        z = x @ params["in_proj_z"]
    else:
        z, x1, Bm, Cm, dt_h = _split_m2(params, x, cfg)

    # conv window update
    window = jnp.concatenate([state["conv"], x1.astype(state["conv"].dtype)],
                             axis=1)                       # [B, K, din]
    xc = (window * params["conv_w"]).sum(axis=1, keepdims=True) \
        + params["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = window[:, 1:]

    if s.version == 1:
        r = _dt_rank(cfg)
        dbc = xc @ params["x_proj"]
        dt = jax.nn.softplus(
            dbc[..., :r] @ params["dt_proj"] + params["dt_bias"])
        Bm, Cm = dbc[..., r:r + N], dbc[..., r + N:]
        A = jnp.exp(params["A_log"])
        D = params["D"]
    else:
        dt_h = jax.nn.softplus(dt_h + params["dt_bias"])
        dt = jnp.repeat(dt_h, s.headdim, axis=-1)
        A = jnp.broadcast_to(
            jnp.repeat(jnp.exp(params["A_log"]), s.headdim)[:, None], (din, N))
        D = jnp.repeat(params["D"], s.headdim)

    dtf = dt[:, 0].astype(jnp.float32)                     # [B, din]
    xf = xc[:, 0].astype(jnp.float32)
    decay = jnp.exp(-dtf[..., None] * A)                   # [B, din, N]
    inp = (dtf * xf)[..., None] * Bm[:, 0, None, :].astype(jnp.float32)
    h = shd.ssm_state(decay * state["h"] + inp)
    y = (h * Cm[:, 0, None, :].astype(jnp.float32)).sum(-1)  # [B, din]
    y = y + D * xf
    y = (y[:, None] * jax.nn.silu(z.astype(jnp.float32)))
    if s.version == 2:
        y = rmsnorm(y.astype(x.dtype), params["norm_w"], cfg.norm_eps)
    else:
        y = y.astype(x.dtype)
    out = shd.btd(y @ params["out_proj"])
    return out, {"conv": new_conv, "h": h}
