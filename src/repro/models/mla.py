"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed into a rank-``kv_lora`` latent c_kv plus a shared
``qk_rope``-dim decoupled rotary key.  Training expands K/V and runs
standard attention; decode uses the *absorbed* form — w_uk folds into the
query and w_uv into the output — so the per-token cache is only
(kv_lora + qk_rope) floats, MLA's entire point:

    score_t = q_nope^T W_uk c_t + q_rope^T k_rope_t
    out     = (sum_t p_t c_t) W_uv

The compressed cache is sharded over 'model' on the SEQUENCE axis (as in
attention.py): with one latent head, sequence sharding is the only option —
and exactly what flash-decoding wants.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import rope as rope_mod
from repro.models.layers import NOSHARD, Sharder, dense_init, rmsnorm, \
    rmsnorm_init

NEG = -1e30


def mla_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    keys = jax.random.split(key, 6)
    p = {}
    if m.q_lora:
        p["wq_a"] = dense_init(keys[0], d, m.q_lora, dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora, dtype)
        p["wq_b"] = dense_init(keys[1], m.q_lora,
                               H * (m.qk_nope + m.qk_rope), dtype)
    else:
        p["wq"] = dense_init(keys[0], d, H * (m.qk_nope + m.qk_rope), dtype)
    p["wkv_a"] = dense_init(keys[2], d, m.kv_lora + m.qk_rope, dtype)
    p["kv_norm"] = rmsnorm_init(m.kv_lora, dtype)
    p["wkv_b"] = dense_init(keys[3], m.kv_lora, H * (m.qk_nope + m.v_dim),
                            dtype)
    p["wo"] = dense_init(keys[4], H * m.v_dim, d, dtype,
                         scale=(H * m.v_dim) ** -0.5)
    return p


def _queries(params, x, positions, cfg: ArchConfig, shd: Sharder):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if m.q_lora:
        cq = rmsnorm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
        q = cq @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = shd.btf(q).reshape(B, S, H, m.qk_nope + m.qk_rope)
    q_nope = q[..., :m.qk_nope]
    q_rope = rope_mod.apply_rope(q[..., m.qk_nope:], positions,
                                 cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, x, positions, cfg: ArchConfig):
    m = cfg.mla
    kv = x @ params["wkv_a"]                           # [B, S, lora+rope]
    c_kv = rmsnorm(kv[..., :m.kv_lora], params["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora:][:, :, None, :]        # single shared head
    k_rope = rope_mod.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_train(params, x, positions, cfg: ArchConfig, shd: Sharder = NOSHARD,
              *, chunk: Optional[int] = None):
    """Expanded-KV attention (training / prefill compute path)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(params, x, positions, cfg, shd)
    c_kv, k_rope = _latents(params, x, positions, cfg)
    kv = (c_kv @ params["wkv_b"]).reshape(B, S, H, m.qk_nope + m.v_dim)
    k_nope = kv[..., :m.qk_nope]
    v = kv[..., m.qk_nope:]

    scale = (m.qk_nope + m.qk_rope) ** -0.5
    qf = jnp.concatenate([q_nope, q_rope], -1).astype(jnp.float32)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], k_nope.shape[:3]
                                  + (m.qk_rope,))], -1).astype(jnp.float32)
    if chunk is not None and S % chunk == 0 and S > chunk:
        out = _chunked_mla(qf, kf, v.astype(jnp.float32), scale, chunk)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
        qi = jnp.arange(S)
        mask = qi[None, :] <= qi[:, None]
        s = jnp.where(mask[None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, S, H * m.v_dim) @ params["wo"]
    return shd.btd(out)


def _chunked_mla(qf, kf, vf, scale, chunk):
    """Online-softmax over KV chunks (same recurrence as attention.py)."""
    B, S, H, dk = qf.shape
    dv = vf.shape[-1]
    nc = S // chunk
    kc = jnp.moveaxis(kf.reshape(B, nc, chunk, H, dk), 1, 0)
    vc = jnp.moveaxis(vf.reshape(B, nc, chunk, H, dv), 1, 0)
    qi = jnp.arange(S)
    qs = qf * scale

    def body(carry, xs):
        mx, l, acc = carry
        kb, vb, ci = xs
        kj = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qs, kb)
        mask = kj[None, :] <= qi[:, None]
        s = jnp.where(mask[None, None], s, NEG)
        m_new = jnp.maximum(mx, s.max(axis=-1))
        p = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(mx - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), NEG, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, dv), jnp.float32)
    (mx, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                   (kc, vc, jnp.arange(nc)))
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return jnp.moveaxis(out, 1, 2)                     # [B, S, H, dv]


# ---------------------------------------------------------------------------
# compressed cache: prefill + absorbed decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.float32
               ) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.qk_rope), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_prefill(params, x, positions, cfg: ArchConfig,
                shd: Sharder = NOSHARD, cache: Optional[dict] = None,
                chunk: Optional[int] = None):
    out = mla_train(params, x, positions, cfg, shd, chunk=chunk)
    if cache is not None:
        S = x.shape[1]
        c_kv, k_rope = _latents(params, x, positions, cfg)
        cache = {
            "c_kv": shd.latent_cache(jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, 1)),
            "k_rope": shd.latent_cache(jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, 1)),
            "len": jnp.asarray(S, jnp.int32),
        }
    return out, cache


def mla_decode(params, x, cache: dict, pos, cfg: ArchConfig,
               shd: Sharder = NOSHARD):
    """Absorbed one-token step on the compressed cache."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
    q_nope, q_rope = _queries(params, x, pos_b, cfg, shd)   # [B,1,H,*]
    c_new, kr_new = _latents(params, x, pos_b, cfg)

    S = cache["c_kv"].shape[1]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
        jnp.asarray(pos, jnp.int32), 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
        jnp.asarray(pos, jnp.int32), 1)
    c_kv = shd.latent_cache(c_kv)
    k_rope = shd.latent_cache(k_rope)

    # absorb: q_nope' = q_nope @ W_uk  (per head, into latent space)
    w_b = params["wkv_b"].reshape(m.kv_lora, H, m.qk_nope + m.v_dim)
    w_uk = w_b[..., :m.qk_nope]                       # [lora, H, nope]
    w_uv = w_b[..., m.qk_nope:]                       # [lora, H, v]
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))      # [B, H, lora]

    scale = (m.qk_nope + m.qk_rope) ** -0.5
    s = (jnp.einsum("bhl,bsl->bhs", q_lat, c_kv.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None], s, NEG)
    mx = s.max(axis=-1, keepdims=True)
    p = jnp.where(valid[None, None], jnp.exp(s - mx), 0.0)
    lat = jnp.einsum("bhs,bsl->bhl", p, c_kv.astype(jnp.float32))
    lat = lat / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhl,lhv->bhv", lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_dim).astype(x.dtype) @ params["wo"]
    new_cache = {"c_kv": c_kv, "k_rope": k_rope,
                 "len": jnp.asarray(pos, jnp.int32) + 1}
    return shd.btd(out), new_cache
