"""Model assembly: init / train / prefill / decode for all 10 architectures.

Families:
  dense   — pre-norm GQA transformer (stablelm, phi3, codeqwen, danube, qwen2-vl)
  moe     — DeepSeek-V2(-lite): MLA attention + shared/routed MoE FFN
  ssm     — falcon-mamba: pure Mamba-1 stack
  hybrid  — zamba2: Mamba-2 backbone + ONE shared attn+MLP block re-applied
            every ``attn_every`` layers (weight re-use, as in the paper)
  encdec  — whisper: bidirectional encoder (stub audio embeddings) +
            causal decoder with cross attention

All layer stacks are jax.lax.scan'd over stacked parameters so the traced
HLO is one-layer-sized, with jax.checkpoint (remat) around the block body.
Vision/audio frontends are STUBS per the assignment: ``prefix_embeds`` /
``audio_embeds`` arrive as precomputed activations from input_specs().
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (NOSHARD, Sharder, dense_init, embed_init,
                                 gelu_mlp, gelu_mlp_init, layernorm, rmsnorm,
                                 rmsnorm_init, swiglu, swiglu_init)


@dataclasses.dataclass(frozen=True)
class PerfConfig:
    """Per-cell performance knobs (the hillclimbing surface)."""
    remat: str = "full"           # none | full | dots
    attn_chunk: Optional[int] = None   # kv-chunked attention block size
    accum_steps: int = 1          # gradient accumulation microbatches
    scan_layers: bool = True
    parallelism: str = "2d"       # 2d   = TP over 'model' + DP/FSDP 'data'
    #                               fsdp = pure ZeRO-3 over the WHOLE mesh
    #                               (batch over data x model; no TP
    #                               activation all-reduces — wins for models
    #                               whose layers are too small to shard)
    moe_groups: int = 1           # GShard dispatch groups (= data width on
    #                               the production mesh; 1 = global routing)
    kv_quant: bool = False        # int8 KV cache (KIVI-style, dense archs)
    opt_moments: str = "f32"      # bf16 halves optimizer-state HBM


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "dots_nb":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


def _norm(x, p, cfg: ArchConfig):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def _norm_init(d, cfg: ArchConfig, dtype):
    if cfg.norm_type == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": rmsnorm_init(d, dtype)}


def _stacked(init_one, key, n: int):
    return jax.vmap(init_one)(jax.random.split(key, n))


def vocab_padded(cfg: ArchConfig) -> int:
    """Embedding/vocab dim padded to a multiple of 256 so the vocab axis
    shards evenly on any mesh axis (whisper's 51865 is the only assigned
    vocab that needs it).  Labels never index the padding; the padded
    logits are real (trainable) rows, which is standard practice."""
    return -(-cfg.vocab // 256) * 256


# ===========================================================================
# init
# ===========================================================================

def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    vp = vocab_padded(cfg)
    p: dict = {
        "embed": embed_init(keys[0], vp, d, dtype),
        "lm_head": dense_init(keys[1], d, vp, dtype),
        "final_norm": _norm_init(d, cfg, dtype),
    }
    if cfg.family == "dense":
        def one(k):
            ks = jax.random.split(k, 2)
            return {
                "attn": attn_mod.attn_init(ks[0], cfg, dtype),
                "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype),
                "ln1": _norm_init(d, cfg, dtype),
                "ln2": _norm_init(d, cfg, dtype),
            }
        p["layers"] = _stacked(one, keys[2], cfg.n_layers)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense
        d_ff_dense = cfg.moe.d_ff_dense or 4 * d

        def one_dense(k):
            ks = jax.random.split(k, 2)
            return {
                "attn": mla_mod.mla_init(ks[0], cfg, dtype),
                "mlp": swiglu_init(ks[1], d, d_ff_dense, dtype),
                "ln1": _norm_init(d, cfg, dtype),
                "ln2": _norm_init(d, cfg, dtype),
            }

        def one_moe(k):
            ks = jax.random.split(k, 2)
            return {
                "attn": mla_mod.mla_init(ks[0], cfg, dtype),
                "moe": moe_mod.moe_init(ks[1], cfg, dtype),
                "ln1": _norm_init(d, cfg, dtype),
                "ln2": _norm_init(d, cfg, dtype),
            }
        p["dense_layers"] = _stacked(one_dense, keys[2], nd)
        p["layers"] = _stacked(one_moe, keys[3], cfg.n_layers - nd)
    elif cfg.family == "ssm":
        def one(k):
            return {
                "ssm": ssm_mod.ssm_init(k, cfg, dtype),
                "ln": _norm_init(d, cfg, dtype),
            }
        p["layers"] = _stacked(one, keys[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        def one(k):
            return {
                "ssm": ssm_mod.ssm_init(k, cfg, dtype),
                "ln": _norm_init(d, cfg, dtype),
            }
        p["layers"] = _stacked(one, keys[2], cfg.n_layers)
        ks = jax.random.split(keys[3], 2)
        p["shared_block"] = {
            "attn": attn_mod.attn_init(ks[0], cfg, dtype),
            "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype),
            "ln1": _norm_init(d, cfg, dtype),
            "ln2": _norm_init(d, cfg, dtype),
        }
    elif cfg.family == "encdec":
        def one_enc(k):
            ks = jax.random.split(k, 2)
            return {
                "attn": attn_mod.attn_init(ks[0], cfg, dtype),
                "mlp": gelu_mlp_init(ks[1], d, cfg.d_ff, dtype),
                "ln1": _norm_init(d, cfg, dtype),
                "ln2": _norm_init(d, cfg, dtype),
            }

        def one_dec(k):
            ks = jax.random.split(k, 3)
            return {
                "self_attn": attn_mod.attn_init(ks[0], cfg, dtype),
                "cross_attn": attn_mod.attn_init(ks[1], cfg, dtype),
                "mlp": gelu_mlp_init(ks[2], d, cfg.d_ff, dtype),
                "ln1": _norm_init(d, cfg, dtype),
                "ln2": _norm_init(d, cfg, dtype),
                "ln3": _norm_init(d, cfg, dtype),
            }
        p["enc_layers"] = _stacked(one_enc, keys[2], cfg.n_enc_layers)
        p["layers"] = _stacked(one_dec, keys[3], cfg.n_layers)
        p["enc_norm"] = _norm_init(d, cfg, dtype)
    else:
        raise ValueError(cfg.family)
    return p


# ===========================================================================
# blocks (train/prefill path)
# ===========================================================================

def _dense_block(lp, x, positions, cfg, shd, chunk):
    h = attn_mod.attn_train(lp["attn"], _norm(x, lp["ln1"], cfg), positions,
                            cfg, shd, chunk=chunk)
    x = x + h
    x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
    return x


def _mla_dense_block(lp, x, positions, cfg, shd, chunk):
    h = mla_mod.mla_train(lp["attn"], _norm(x, lp["ln1"], cfg), positions,
                          cfg, shd, chunk=chunk)
    x = x + h
    x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
    return x


def _moe_block(lp, x, positions, cfg, shd, chunk, groups=1):
    h = mla_mod.mla_train(lp["attn"], _norm(x, lp["ln1"], cfg), positions,
                          cfg, shd, chunk=chunk)
    x = x + h
    y, aux = moe_mod.moe_ffn(lp["moe"], _norm(x, lp["ln2"], cfg), cfg, shd,
                             groups=groups)
    return x + y, aux


def _ssm_block(lp, x, cfg, shd):
    return x + ssm_mod.ssm_train(lp["ssm"], _norm(x, lp["ln"], cfg), cfg, shd)


def _shared_attn_block(sp, x, positions, cfg, shd, chunk):
    h = attn_mod.attn_train(sp["attn"], _norm(x, sp["ln1"], cfg), positions,
                            cfg, shd, chunk=chunk)
    x = x + h
    x = x + swiglu(sp["mlp"], _norm(x, sp["ln2"], cfg), shd)
    return x


def _whisper_sinusoid(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos * jnp.exp(-i * jnp.log(10000.0) / (d // 2 - 1))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(params, audio_embeds, cfg: ArchConfig, shd: Sharder = NOSHARD,
           perf: PerfConfig = PerfConfig()) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B, F, d]."""
    B, F, d = audio_embeds.shape
    x = audio_embeds + _whisper_sinusoid(F, d, audio_embeds.dtype)
    pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(x, lp):
        def blk(lp, x):
            h = attn_mod.attn_train(lp["attn"], _norm(x, lp["ln1"], cfg),
                                    pos, cfg, shd, causal=False)
            x = x + h
            return x + gelu_mlp(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
        return _remat(blk, perf.remat)(lp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _norm(x, params["enc_norm"], cfg)


def _dec_block(lp, x, enc_out, positions, enc_pos, cfg, shd, chunk):
    h = attn_mod.attn_train(lp["self_attn"], _norm(x, lp["ln1"], cfg),
                            positions, cfg, shd, chunk=chunk)
    x = x + h
    # cross attention: queries from decoder, K/V from encoder output
    xq = _norm(x, lp["ln2"], cfg)
    h = _cross_attn(lp["cross_attn"], xq, enc_out, positions, enc_pos,
                    cfg, shd)
    x = x + h
    return x + gelu_mlp(lp["mlp"], _norm(x, lp["ln3"], cfg), shd)


def _cross_attn(p, xq, enc_out, positions, enc_pos, cfg, shd):
    B, S, _ = xq.shape
    F = enc_out.shape[1]
    dh = cfg.head_dim
    q = (xq @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = (enc_out @ p["wk"]).reshape(B, F, cfg.n_kv_heads, dh)
    v = (enc_out @ p["wv"]).reshape(B, F, cfg.n_kv_heads, dh)
    hkv = cfg.n_kv_heads
    rep = cfg.n_heads // hkv
    qf = q.astype(jnp.float32).reshape(B, S, hkv, rep, dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k.astype(jnp.float32))
    s = s * dh ** -0.5
    pp = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", pp, v.astype(jnp.float32))
    out = out.reshape(B, S, cfg.n_heads * dh).astype(xq.dtype) @ p["wo"]
    return shd.btd(out)


# ===========================================================================
# forward (train): tokens -> logits, aux
# ===========================================================================

def forward(params: dict, batch: dict, cfg: ArchConfig,
            shd: Sharder = NOSHARD, perf: PerfConfig = PerfConfig()
            ) -> tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]
    if cfg.n_prefix_embeds and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, cfg.n_prefix_embeds:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = shd.btd(x)
    aux = jnp.zeros((), jnp.float32)
    chunk = perf.attn_chunk

    if cfg.family in ("dense",):
        def body(carry, lp):
            x, = carry
            blk = _remat(functools.partial(
                _dense_block, positions=positions, cfg=cfg, shd=shd,
                chunk=chunk), perf.remat)
            return (blk(lp, x),), None
        (x,), _ = jax.lax.scan(body, (x,), params["layers"])
    elif cfg.family == "moe":
        def body_d(carry, lp):
            x, = carry
            blk = _remat(functools.partial(
                _mla_dense_block, positions=positions, cfg=cfg, shd=shd,
                chunk=chunk), perf.remat)
            return (blk(lp, x),), None
        (x,), _ = jax.lax.scan(body_d, (x,), params["dense_layers"])

        def body_m(carry, lp):
            x, aux = carry
            blk = _remat(functools.partial(
                _moe_block, positions=positions, cfg=cfg, shd=shd,
                chunk=chunk, groups=perf.moe_groups), perf.remat)
            y, a = blk(lp, x)
            return (y, aux + a), None
        (x, aux), _ = jax.lax.scan(body_m, (x, aux), params["layers"])
    elif cfg.family == "ssm":
        def body(carry, lp):
            x, = carry
            blk = _remat(functools.partial(_ssm_block, cfg=cfg, shd=shd),
                         perf.remat)
            return (blk(lp, x),), None
        (x,), _ = jax.lax.scan(body, (x,), params["layers"])
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, x, positions, cfg, shd, perf)
    elif cfg.family == "encdec":
        enc_out = encode(params, batch["audio_embeds"], cfg, shd, perf)
        enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None],
                                   enc_out.shape[:2])

        def body(carry, lp):
            x, = carry
            blk = _remat(functools.partial(
                _dec_block, enc_out=enc_out, positions=positions,
                enc_pos=enc_pos, cfg=cfg, shd=shd, chunk=chunk), perf.remat)
            return (blk(lp, x),), None
        (x,), _ = jax.lax.scan(body, (x,), params["layers"])
    else:
        raise ValueError(cfg.family)

    x = _norm(x, params["final_norm"], cfg)
    logits = shd.btv(x @ params["lm_head"])
    return logits, aux


def _hybrid_forward(params, x, positions, cfg, shd, perf):
    """Zamba2: shared attn block every ``attn_every`` mamba layers."""
    L = cfg.n_layers
    per = cfg.attn_every
    n_seg = max(L // per, 1)
    layers = params["layers"]

    def seg_slice(i):
        return jax.tree_util.tree_map(lambda a: a[i * per:(i + 1) * per],
                                      layers)

    for seg in range(n_seg):
        blk = _remat(functools.partial(
            _shared_attn_block, positions=positions, cfg=cfg, shd=shd,
            chunk=perf.attn_chunk), perf.remat)
        x = blk(params["shared_block"], x)

        def body(carry, lp):
            x, = carry
            b = _remat(functools.partial(_ssm_block, cfg=cfg, shd=shd),
                       perf.remat)
            return (b(lp, x),), None
        (x,), _ = jax.lax.scan(body, (x,), seg_slice(seg))
    # trailing layers if L % per != 0
    rem = L - n_seg * per
    if rem:
        tail = jax.tree_util.tree_map(lambda a: a[n_seg * per:], layers)

        def body(carry, lp):
            x, = carry
            b = _remat(functools.partial(_ssm_block, cfg=cfg, shd=shd),
                       perf.remat)
            return (b(lp, x),), None
        (x,), _ = jax.lax.scan(body, (x,), tail)
    return x


# ===========================================================================
# loss
# ===========================================================================

def loss_fn(params: dict, batch: dict, cfg: ArchConfig,
            shd: Sharder = NOSHARD, perf: PerfConfig = PerfConfig()
            ) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, batch, cfg, shd, perf)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}
