"""LM model substrate: the 10 assigned architectures as composable JAX
modules (pure functions over parameter pytrees; sharding via a Sharder)."""
