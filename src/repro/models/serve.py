"""Serving path: cache init, prefill, single-token decode for all families.

Layer caches are stacked along a leading layer axis.  The layer loop is a
lax.scan whose CARRY holds the full stacked cache, updated in place with
``dynamic_update_index_in_dim`` — carried buffers alias across loop
iterations, so a donated multi-GiB KV cache is updated without the 2x
double-buffering that scan xs->ys staging would cost (verified via
``memory_analysis`` in the dry-run; this is the MaxText decode pattern).

Decode contract: one new token per sequence, a shared scalar position
``pos``, KV caches sharded over 'model' on the sequence axis.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import NOSHARD, Sharder, gelu_mlp, swiglu
from repro.models.model import PerfConfig, _cross_attn, _norm, encode


def _stack_caches(make_one, n: int):
    one = make_one()
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), one)


def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.float32, kv_quant: bool = False) -> dict:
    c: dict = {}
    if cfg.family == "dense":
        c["layers"] = _stack_caches(
            lambda: attn_mod.init_cache(cfg, batch, max_seq, dtype,
                                        quantized=kv_quant),
            cfg.n_layers)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense
        c["dense_layers"] = _stack_caches(
            lambda: mla_mod.init_cache(cfg, batch, max_seq, dtype), nd)
        c["layers"] = _stack_caches(
            lambda: mla_mod.init_cache(cfg, batch, max_seq, dtype),
            cfg.n_layers - nd)
    elif cfg.family == "ssm":
        c["layers"] = _stack_caches(
            lambda: ssm_mod.init_state(cfg, batch, dtype), cfg.n_layers)
    elif cfg.family == "hybrid":
        n_seg = max(cfg.n_layers // cfg.attn_every, 1)
        c["layers"] = _stack_caches(
            lambda: ssm_mod.init_state(cfg, batch, dtype), cfg.n_layers)
        c["shared"] = _stack_caches(
            lambda: attn_mod.init_cache(cfg, batch, max_seq, dtype), n_seg)
    elif cfg.family == "encdec":
        dh = cfg.head_dim
        c["layers"] = _stack_caches(
            lambda: attn_mod.init_cache(cfg, batch, max_seq, dtype),
            cfg.n_layers)
        c["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, dh), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    else:
        raise ValueError(cfg.family)
    return c


def _scan_layers_with_cache(body_fn: Callable, x, layer_params, caches,
                            unroll: bool = False):
    """Walk stacked layer params; caches live in the CARRY (in-place).

    body_fn(lp, x, cache_i) -> (x', new_cache_i)

    ``unroll=True`` emits a straight-line python loop instead of lax.scan:
    the chain of ``.at[i].set`` updates on a donated cache aliases with no
    temp copy (XLA's while-loop carry aliasing is conservative on some
    backends and keeps one full cache copy) — used by the decode step where
    the KV cache dominates HBM.
    """
    if unroll:
        L = jax.tree_util.tree_leaves(layer_params)[0].shape[0]
        for i in range(L):
            lp = jax.tree_util.tree_map(lambda a: a[i], layer_params)
            cache_i = jax.tree_util.tree_map(lambda a: a[i], caches)
            x, new_i = body_fn(lp, x, cache_i)
            caches = jax.tree_util.tree_map(
                lambda a, u: a.at[i].set(u.astype(a.dtype)), caches, new_i)
        return x, caches

    def body(carry, lp):
        x, caches, i = carry
        cache_i = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            caches)
        x, new_i = body_fn(lp, x, cache_i)
        caches = jax.tree_util.tree_map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(
                a, u.astype(a.dtype), i, 0), caches, new_i)
        return (x, caches, i + 1), None

    (x, caches, _), _ = jax.lax.scan(
        body, (x, caches, jnp.int32(0)), layer_params)
    return x, caches


# ===========================================================================
# prefill
# ===========================================================================

def prefill(params: dict, batch: dict, cfg: ArchConfig,
            shd: Sharder = NOSHARD, perf: PerfConfig = PerfConfig(),
            max_seq: int = 0) -> tuple[jax.Array, dict]:
    """Prompt pass; returns (last-position logits [B, vocab_p], caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    dtype = params["embed"].dtype
    caches = init_caches(cfg, B, max_seq, dtype, kv_quant=perf.kv_quant)
    x = params["embed"][tokens]
    if cfg.n_prefix_embeds and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, cfg.n_prefix_embeds:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = shd.btd(x)
    chunk = perf.attn_chunk

    if cfg.family == "dense":
        def body(lp, x, cache):
            h, cache = attn_mod.prefill_into_cache(
                lp["attn"], _norm(x, lp["ln1"], cfg), positions, cfg, shd,
                cache, chunk=chunk)
            x = x + h
            x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
            return x, cache
        x, caches["layers"] = _scan_layers_with_cache(
            body, x, params["layers"], caches["layers"])
    elif cfg.family == "moe":
        def body_d(lp, x, cache):
            h, cache = mla_mod.mla_prefill(
                lp["attn"], _norm(x, lp["ln1"], cfg), positions, cfg, shd,
                cache, chunk=chunk)
            x = x + h
            x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
            return x, cache
        x, caches["dense_layers"] = _scan_layers_with_cache(
            body_d, x, params["dense_layers"], caches["dense_layers"])

        def body_m(lp, x, cache):
            h, cache = mla_mod.mla_prefill(
                lp["attn"], _norm(x, lp["ln1"], cfg), positions, cfg, shd,
                cache, chunk=chunk)
            x = x + h
            y, _ = moe_mod.moe_ffn(lp["moe"], _norm(x, lp["ln2"], cfg),
                                   cfg, shd, groups=perf.moe_groups)
            return x + y, cache
        x, caches["layers"] = _scan_layers_with_cache(
            body_m, x, params["layers"], caches["layers"])
    elif cfg.family == "ssm":
        def body(lp, x, st):
            return _ssm_prefill_block(lp, x, cfg, shd)
        x, caches["layers"] = _scan_layers_with_cache(
            body, x, params["layers"], caches["layers"])
    elif cfg.family == "hybrid":
        x, caches = _hybrid_prefill(params, x, positions, caches, cfg, shd,
                                    perf)
    elif cfg.family == "encdec":
        enc_out = encode(params, batch["audio_embeds"], cfg, shd, perf)
        dh = cfg.head_dim
        F = enc_out.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

        def body(lp, x, cache_i):
            cache, _, _ = cache_i
            h, cache = attn_mod.prefill_into_cache(
                lp["self_attn"], _norm(x, lp["ln1"], cfg), positions, cfg,
                shd, cache, chunk=chunk)
            x = x + h
            xq = _norm(x, lp["ln2"], cfg)
            x = x + _cross_attn(lp["cross_attn"], xq, enc_out, positions,
                                enc_pos, cfg, shd)
            x = x + gelu_mlp(lp["mlp"], _norm(x, lp["ln3"], cfg), shd)
            ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(
                B, F, cfg.n_kv_heads, dh)
            cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(
                B, F, cfg.n_kv_heads, dh)
            return x, (cache, ck, cv)
        x, (caches["layers"], caches["cross_k"], caches["cross_v"]) = \
            _scan_layers_with_cache(
                body, x, params["layers"],
                (caches["layers"], caches["cross_k"], caches["cross_v"]))
    else:
        raise ValueError(cfg.family)

    x = _norm(x[:, -1:], params["final_norm"], cfg)
    logits = shd.bv((x @ params["lm_head"])[:, 0])
    return logits, caches


def _ssm_prefill_block(lp, x, cfg, shd):
    """Run the ssm block over the prompt and capture (conv window, state)."""
    s = cfg.ssm
    xn = _norm(x, lp["ln"], cfg)
    y = ssm_mod.ssm_train(lp["ssm"], xn, cfg, shd)
    # final conv window: last (d_conv - 1) pre-conv activations
    x1 = xn @ lp["ssm"]["in_proj_x"]
    conv = x1[:, -(s.d_conv - 1):]
    h = _final_state(lp["ssm"], xn, cfg)
    return x + y, {"conv": conv.astype(x.dtype), "h": h}


def _final_state(pp, xn, cfg):
    """Recompute the SSM final state for the prompt (prefill bookkeeping)."""
    s = cfg.ssm
    din = ssm_mod.d_inner(cfg)
    N = s.d_state
    if s.version == 1:
        x1 = jax.nn.silu(ssm_mod._causal_conv(xn @ pp["in_proj_x"],
                                              pp["conv_w"], pp["conv_b"]))
        r = ssm_mod._dt_rank(cfg)
        dbc = x1 @ pp["x_proj"]
        dt = jax.nn.softplus(dbc[..., :r] @ pp["dt_proj"] + pp["dt_bias"])
        Bm, Cm = dbc[..., r:r + N], dbc[..., r + N:r + 2 * N]
        A = jnp.exp(pp["A_log"])
    else:
        z, x1, Bm, Cm, dt_h = ssm_mod._split_m2(pp, xn, cfg)
        x1 = jax.nn.silu(ssm_mod._causal_conv(x1, pp["conv_w"], pp["conv_b"]))
        dt = jnp.repeat(jax.nn.softplus(dt_h + pp["dt_bias"]), s.headdim, -1)
        A = jnp.broadcast_to(
            jnp.repeat(jnp.exp(pp["A_log"]), s.headdim)[:, None], (din, N))
    h0 = jnp.zeros((xn.shape[0], din, N), jnp.float32)
    _, h = ssm_mod._scan_chunks(h0, x1, dt, Bm, Cm, A, s.chunk)
    return h


def _hybrid_prefill(params, x, positions, caches, cfg, shd, perf):
    L, per = cfg.n_layers, cfg.attn_every
    n_seg = max(L // per, 1)
    shared = caches["shared"]
    states = caches["layers"]
    for seg in range(n_seg):
        sp = params["shared_block"]
        cache = jax.tree_util.tree_map(lambda a: a[seg], shared)
        h, cache = attn_mod.prefill_into_cache(
            sp["attn"], _norm(x, sp["ln1"], cfg), positions, cfg, shd,
            cache, chunk=perf.attn_chunk)
        x = x + h
        x = x + swiglu(sp["mlp"], _norm(x, sp["ln2"], cfg), shd)
        shared = jax.tree_util.tree_map(
            lambda a, u: a.at[seg].set(u.astype(a.dtype)), shared, cache)
        for i in range(seg * per, (seg + 1) * per):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, st = _ssm_prefill_block(lp, x, cfg, shd)
            states = jax.tree_util.tree_map(
                lambda a, u: a.at[i].set(u.astype(a.dtype)), states, st)
    for i in range(n_seg * per, L):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        x, st = _ssm_prefill_block(lp, x, cfg, shd)
        states = jax.tree_util.tree_map(
            lambda a, u: a.at[i].set(u.astype(a.dtype)), states, st)
    caches["shared"] = shared
    caches["layers"] = states
    return x, caches


# ===========================================================================
# decode
# ===========================================================================

def decode_step(params: dict, tokens: jax.Array, caches: dict, pos,
                cfg: ArchConfig, shd: Sharder = NOSHARD,
                unroll: bool = False, moe_groups: int = 1
                ) -> tuple[jax.Array, dict]:
    """tokens [B, 1] int32; pos scalar int32. Returns (logits [B, Vp], caches')."""
    B = tokens.shape[0]
    x = shd.btd(params["embed"][tokens])

    if cfg.family == "dense":
        def body(lp, x, cache):
            h, cache = attn_mod.attn_decode(
                lp["attn"], _norm(x, lp["ln1"], cfg), cache, pos, cfg, shd)
            x = x + h
            x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
            return x, cache
        x, caches["layers"] = _scan_layers_with_cache(
            body, x, params["layers"], caches["layers"], unroll)
    elif cfg.family == "moe":
        def body_d(lp, x, cache):
            h, cache = mla_mod.mla_decode(
                lp["attn"], _norm(x, lp["ln1"], cfg), cache, pos, cfg, shd)
            x = x + h
            x = x + swiglu(lp["mlp"], _norm(x, lp["ln2"], cfg), shd)
            return x, cache
        x, caches["dense_layers"] = _scan_layers_with_cache(
            body_d, x, params["dense_layers"], caches["dense_layers"],
            unroll)

        def body_m(lp, x, cache):
            h, cache = mla_mod.mla_decode(
                lp["attn"], _norm(x, lp["ln1"], cfg), cache, pos, cfg, shd)
            x = x + h
            y, _ = moe_mod.moe_ffn(lp["moe"], _norm(x, lp["ln2"], cfg),
                                   cfg, shd, groups=moe_groups)
            return x + y, cache
        x, caches["layers"] = _scan_layers_with_cache(
            body_m, x, params["layers"], caches["layers"], unroll)
    elif cfg.family == "ssm":
        def body(lp, x, st):
            h, st = ssm_mod.ssm_decode(lp["ssm"], _norm(x, lp["ln"], cfg),
                                       st, cfg, shd)
            return x + h, st
        x, caches["layers"] = _scan_layers_with_cache(
            body, x, params["layers"], caches["layers"], unroll)
    elif cfg.family == "hybrid":
        L, per = cfg.n_layers, cfg.attn_every
        n_seg = max(L // per, 1)
        shared = caches["shared"]
        states = caches["layers"]
        for seg in range(n_seg):
            sp = params["shared_block"]
            cache = jax.tree_util.tree_map(lambda a: a[seg], shared)
            h, cache = attn_mod.attn_decode(
                sp["attn"], _norm(x, sp["ln1"], cfg), cache, pos, cfg, shd)
            x = x + h
            x = x + swiglu(sp["mlp"], _norm(x, sp["ln2"], cfg), shd)
            shared = jax.tree_util.tree_map(
                lambda a, u: a.at[seg].set(u.astype(a.dtype)), shared, cache)
            for i in range(seg * per, (seg + 1) * per):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                st = jax.tree_util.tree_map(lambda a: a[i], states)
                h, st = ssm_mod.ssm_decode(lp["ssm"], _norm(x, lp["ln"], cfg),
                                           st, cfg, shd)
                x = x + h
                states = jax.tree_util.tree_map(
                    lambda a, u: a.at[i].set(u.astype(a.dtype)), states, st)
        for i in range(n_seg * per, L):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            st = jax.tree_util.tree_map(lambda a: a[i], states)
            h, st = ssm_mod.ssm_decode(lp["ssm"], _norm(x, lp["ln"], cfg),
                                       st, cfg, shd)
            x = x + h
            states = jax.tree_util.tree_map(
                lambda a, u: a.at[i].set(u.astype(a.dtype)), states, st)
        caches["shared"] = shared
        caches["layers"] = states
    elif cfg.family == "encdec":
        dh = cfg.head_dim
        hkv = cfg.n_kv_heads
        rep = cfg.n_heads // hkv

        def body(lp, x, cache_i):
            cache, ck, cv = cache_i
            h, cache = attn_mod.attn_decode(
                lp["self_attn"], _norm(x, lp["ln1"], cfg), cache, pos, cfg,
                shd)
            x = x + h
            xq = _norm(x, lp["ln2"], cfg)
            q = (xq @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.n_heads, dh)
            qf = q.astype(jnp.float32).reshape(B, hkv, rep, dh)
            s = jnp.einsum("bhrd,bkhd->bhrk", qf,
                           ck.astype(jnp.float32)) * dh ** -0.5
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhrk,bkhd->bhrd", p, cv.astype(jnp.float32))
            o = o.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype) \
                @ lp["cross_attn"]["wo"]
            x = x + shd.btd(o)
            x = x + gelu_mlp(lp["mlp"], _norm(x, lp["ln3"], cfg), shd)
            return x, (cache, ck, cv)
        x, (caches["layers"], caches["cross_k"], caches["cross_v"]) = \
            _scan_layers_with_cache(
                body, x, params["layers"],
                (caches["layers"], caches["cross_k"], caches["cross_v"]),
                unroll)
    else:
        raise ValueError(cfg.family)

    x = _norm(x, params["final_norm"], cfg)
    logits = shd.bv((x @ params["lm_head"])[:, 0])
    return logits, caches
