"""GQA attention: train/prefill (full or kv-chunked flash-style) + decode.

Decode keeps a KV cache sharded over the 'model' axis on the SEQUENCE dim
(flash-decoding layout): softmax max/sum and the weighted-V contraction
reduce over the sharded axis, which GSPMD turns into small all-reduces —
this scales to kv_heads < model-axis size (e.g. 8 KV heads on 16-way TP),
where head sharding cannot.

Sliding-window attention uses a ring-buffer cache of window size W with an
explicit per-slot position vector, so long_500k decodes with O(W) state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import rope as rope_mod
from repro.models.layers import NOSHARD, Sharder, dense_init

NEG = -1e30


def attn_init(key, cfg: ArchConfig, dtype=jnp.float32, d_model: int = 0
              ) -> dict:
    d = d_model or cfg.d_model
    dh = cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * dh, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ko, cfg.n_heads * dh, d, dtype,
                         scale=(cfg.n_heads * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), dtype)
    return p


def _project_qkv(params, x, cfg: ArchConfig, shd: Sharder):
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    # constrain the FLAT projection (always divisible by the model axis even
    # when n_heads is not, e.g. phi3's 40 heads on 16-way TP); GSPMD
    # propagates a layout through the reshape
    q = shd.btf(q).reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, S, cfg.n_kv_heads, dh)
    v = v.reshape(B, S, cfg.n_kv_heads, dh)
    return q, k, v


def _rope(x, positions, cfg: ArchConfig):
    if cfg.mrope_sections is not None:
        pos3 = positions if positions.ndim == 3 \
            else rope_mod.text_positions3(positions)
        return rope_mod.apply_mrope(x, pos3, cfg.mrope_sections,
                                    cfg.rope_theta)
    return rope_mod.apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _full_attention(q, k, v, cfg: ArchConfig, causal: bool):
    B, S, H, dh = q.shape
    hkv = k.shape[2]
    rep = H // hkv
    qf = q.astype(jnp.float32).reshape(B, S, hkv, rep, dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k.astype(jnp.float32))
    scores *= dh ** -0.5
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kj <= qi
    if cfg.sliding_window is not None:
        mask &= kj > qi - cfg.sliding_window
    scores = jnp.where(mask[None, None, None], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def _chunked_attention(q, k, v, cfg: ArchConfig, chunk: int):
    """Flash-style online softmax over KV chunks (jnp; XLA-compiled path).

    Memory O(B * H * S * chunk) instead of O(B * H * S^2) — this is what the
    32k prefill cells lower; the Pallas kernel is the TPU-native equivalent.
    """
    B, S, H, dh = q.shape
    hkv = k.shape[2]
    rep = H // hkv
    n_chunks = S // chunk
    qf = q.astype(jnp.float32).reshape(B, S, hkv, rep, dh) * dh ** -0.5
    kc = k.astype(jnp.float32).reshape(B, n_chunks, chunk, hkv, dh)
    vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, hkv, dh)
    kc = jnp.moveaxis(kc, 1, 0)                  # [nc, B, chunk, hkv, dh]
    vc = jnp.moveaxis(vc, 1, 0)
    qi = jnp.arange(S)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        kj = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kb)
        mask = kj[None, :] <= qi[:, None]
        if cfg.sliding_window is not None:
            mask &= kj[None, :] > qi[:, None] - cfg.sliding_window
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhrqk,bkhd->bhrqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, hkv, rep, S), NEG, jnp.float32)
    l0 = jnp.zeros((B, hkv, rep, S), jnp.float32)
    a0 = jnp.zeros((B, hkv, rep, S, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh)
    return out.astype(q.dtype)


def attn_train(params, x, positions, cfg: ArchConfig, shd: Sharder = NOSHARD,
               *, causal: bool = True, chunk: Optional[int] = None,
               d_model: int = 0):
    """Full-sequence attention; returns [B, S, d]."""
    q, k, v = _project_qkv(params, x, cfg, shd)
    q = _rope(q, positions, cfg)
    k = _rope(k, positions, cfg)
    if chunk is not None and causal and x.shape[1] % chunk == 0 \
            and x.shape[1] > chunk:
        out = _chunked_attention(q, k, v, cfg, chunk)
    else:
        out = _full_attention(q, k, v, cfg, causal)
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1) @ params["wo"]
    return shd.btd(out)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.float32,
               quantized: bool = False) -> dict:
    """Ring buffer of W = sliding_window if set, else max_seq.

    quantized=True stores K/V as int8 with per-(token, head) symmetric
    scales (KIVI-style, beyond-paper): halves the cache footprint and the
    decode read traffic.  The scales factor EXACTLY out of both attention
    contractions (s = (q . k_q) * scale_k; out = (p * scale_v) . v_q), so
    the only approximation is the int8 rounding itself.
    """
    W = min(cfg.sliding_window or max_seq, max_seq)
    dh = cfg.head_dim
    if quantized:
        return {
            "k_q": jnp.zeros((batch, W, cfg.n_kv_heads, dh), jnp.int8),
            "v_q": jnp.zeros((batch, W, cfg.n_kv_heads, dh), jnp.int8),
            "k_s": jnp.zeros((batch, W, cfg.n_kv_heads), jnp.float32),
            "v_s": jnp.zeros((batch, W, cfg.n_kv_heads), jnp.float32),
            "slot_pos": jnp.full((W,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, W, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, W, cfg.n_kv_heads, dh), dtype),
        "slot_pos": jnp.full((W,), -1, jnp.int32),
    }


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B, S, h, dh] -> (int8 values, f32 per-(token, head) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def prefill_into_cache(params, x, positions, cfg: ArchConfig,
                       shd: Sharder = NOSHARD, cache: Optional[dict] = None,
                       chunk: Optional[int] = None):
    """Causal attention over the prompt; fills the cache. Returns (out, cache)."""
    q, k, v = _project_qkv(params, x, cfg, shd)
    q = _rope(q, positions, cfg)
    k = _rope(k, positions, cfg)
    if chunk is not None and x.shape[1] % chunk == 0 and x.shape[1] > chunk:
        out = _chunked_attention(q, k, v, cfg, chunk)
    else:
        out = _full_attention(q, k, v, cfg, causal=True)
    B, S = x.shape[:2]
    if cache is not None:
        quant = "k_q" in cache
        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            store = {"k_q": kq, "v_q": vq, "k_s": ks, "v_s": vs}
        else:
            store = {"k": k, "v": v}
        W = cache[next(iter(store))].shape[1]
        if S >= W:
            # keep the last W keys in ring layout: slot i <- position p,
            # p % W == i (prefill positions are contiguous, so this is a
            # permutation of the tail slice)
            last_pos = positions[0, S - W:].astype(jnp.int32)     # [W]
            slots = last_pos % W
            cache = {key: shd.kv_cache(jnp.zeros_like(cache[key])
                                       .at[:, slots].set(
                         val[:, S - W:].astype(cache[key].dtype)))
                     if val.ndim == 4 else
                     jnp.zeros_like(cache[key]).at[:, slots].set(
                         val[:, S - W:].astype(cache[key].dtype))
                     for key, val in store.items()}
            cache["slot_pos"] = jnp.full((W,), -1, jnp.int32) \
                .at[slots].set(last_pos)
        else:
            # prompt shorter than the window: slots [0, S) in order
            new = {}
            for key, val in store.items():
                upd = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cache[key]),
                    val.astype(cache[key].dtype), 0, 1)
                new[key] = shd.kv_cache(upd) if val.ndim == 4 else upd
            new["slot_pos"] = cache["slot_pos"].at[:S].set(
                positions[0].astype(jnp.int32))
            cache = new
    out = out.reshape(B, S, -1) @ params["wo"]
    return shd.btd(out), cache


def attn_decode(params, x, cache: dict, pos, cfg: ArchConfig,
                shd: Sharder = NOSHARD):
    """One-token step. x: [B, 1, d]; pos: scalar int32 (shared by batch).

    Returns (out [B, 1, d], cache').
    """
    B = x.shape[0]
    dh = cfg.head_dim
    q, k, v = _project_qkv(params, x, cfg, shd)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
    q = _rope(q, pos_b, cfg)
    k = _rope(k, pos_b, cfg)
    quant = "k_q" in cache

    W = cache["slot_pos"].shape[0]
    slot = jnp.asarray(pos, jnp.int32) % W
    spos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.asarray(pos, jnp.int32)[None], slot, 0)

    hkv = cfg.n_kv_heads
    rep = cfg.n_heads // hkv
    qf = q.reshape(B, hkv, rep, dh)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ck = shd.kv_cache(jax.lax.dynamic_update_slice_in_dim(
            cache["k_q"], kq, slot, 1))
        cv = shd.kv_cache(jax.lax.dynamic_update_slice_in_dim(
            cache["v_q"], vq, slot, 1))
        cks = jax.lax.dynamic_update_slice_in_dim(cache["k_s"], ks, slot, 1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cache["v_s"], vs, slot, 1)
        # the per-token scale factors EXACTLY out of the contraction
        s = jnp.einsum("bhrd,bkhd->bhrk", qf.astype(jnp.bfloat16),
                       ck.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        s = s * jnp.moveaxis(cks, 1, 2)[:, :, None] * dh ** -0.5
        new_cache = {"k_q": ck, "v_q": cv, "k_s": cks, "v_s": cvs,
                     "slot_pos": spos}
    else:
        ck = shd.kv_cache(jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k, slot, 1))
        cv = shd.kv_cache(jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v, slot, 1))
        # contract in the cache dtype with f32 ACCUMULATION (no material-
        # ized f32 cache copy)
        s = jnp.einsum("bhrd,bkhd->bhrk", qf, ck,
                       preferred_element_type=jnp.float32) * dh ** -0.5
        new_cache = {"k": ck, "v": cv, "slot_pos": spos}

    valid = (spos >= 0) & (spos <= pos)
    if cfg.sliding_window is not None:
        valid &= spos > pos - cfg.sliding_window
    s = jnp.where(valid[None, None, None], s, NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[None, None, None], p, 0.0)
    if quant:
        pv = p * jnp.moveaxis(cvs, 1, 2)[:, :, None]      # fold v scales
        out = jnp.einsum("bhrk,bkhd->bhrd", pv.astype(jnp.bfloat16),
                         cv.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhrk,bkhd->bhrd", p.astype(cv.dtype), cv,
                         preferred_element_type=jnp.float32)
    out = out / p.sum(axis=-1, keepdims=True)
    out = out.reshape(B, 1, cfg.n_heads * dh).astype(x.dtype) @ params["wo"]
    return shd.btd(out), new_cache
