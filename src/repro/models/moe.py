"""Mixture-of-Experts FFN (DeepSeek-V2 style: shared + fine-grained routed).

Dispatch is sort-based with a capacity bound and — critically for the
production mesh — GROUP-LOCAL in the GShard sense: tokens are split into
``groups`` aligned with the data shards, each group routing into its own
[E, cap_g, d] buffer slice.  Both the scatter operand (the group's tokens)
and the target slice (group row of the buffer) live on the same device row,
so dispatch crosses no links; the expert GEMM is batched over (G, E) with
G on 'data' and E on 'model' — the whole mesh computes.  (The naive global
scatter measured 16x replicated expert FLOPs or, with a 2D buffer, ~7x
all-gathered scatter operands — see EXPERIMENTS.md §Perf.)

groups=1 (the default, used by CPU tests) reproduces plain global-capacity
routing.  Per-group capacity adds the standard GShard group-imbalance
dropping; exactness tests set capacity_factor high to disable dropping.

Aux load-balance loss (Switch-style) is returned for the train loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import NOSHARD, Sharder, dense_init, swiglu, \
    swiglu_init


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d = cfg.d_model
    k_r, k_e, k_s = jax.random.split(key, 3)
    ek = jax.random.split(k_e, 3)
    p = {
        "router": dense_init(k_r, d, m.n_routed, jnp.float32),  # fp32 router
        "experts": {
            "w_gate": jax.vmap(
                lambda k: dense_init(k, d, m.d_expert, dtype))(
                jax.random.split(ek[0], m.n_routed)),
            "w_up": jax.vmap(
                lambda k: dense_init(k, d, m.d_expert, dtype))(
                jax.random.split(ek[1], m.n_routed)),
            "w_down": jax.vmap(
                lambda k: dense_init(k, m.d_expert, d, dtype))(
                jax.random.split(ek[2], m.n_routed)),
        },
    }
    if m.n_shared:
        p["shared"] = swiglu_init(k_s, d, m.n_shared * m.d_expert, dtype)
    return p


def _capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(m.capacity_factor * tokens_per_group * m.top_k / m.n_routed)
    return max(8, -(-cap // 8) * 8)        # round up to a lane-friendly size


def moe_ffn(params: dict, x: jax.Array, cfg: ArchConfig,
            shd: Sharder = NOSHARD, groups: int = 1
            ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = groups if T % groups == 0 else 1
    Tg = T // G
    xt = x.reshape(G, Tg, d)

    logits = (xt.astype(jnp.float32) @ params["router"])      # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)                    # [G, Tg, k]
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)       # renormalize

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(ids[..., 0], m.n_routed, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = m.n_routed * jnp.sum(frac_tokens * frac_probs) * m.aux_weight

    # ---- group-local sort-based dispatch
    K = m.top_k
    cap = _capacity(Tg, cfg)
    flat_ids = ids.reshape(G, Tg * K)
    flat_w = w.reshape(G, Tg * K)
    order = jnp.argsort(flat_ids, axis=1, stable=True)        # [G, Tg*K]
    sorted_eids = jnp.take_along_axis(flat_ids, order, axis=1)
    run_start = jax.vmap(
        lambda s: jnp.searchsorted(s, s, side="left"))(sorted_eids)
    pos = jnp.arange(Tg * K, dtype=jnp.int32)[None] \
        - run_start.astype(jnp.int32)
    token_of = (order // K).astype(jnp.int32)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, 0)

    def scatter_group(xg, eids, spos, kp, tok):
        buf = jnp.zeros((m.n_routed, cap, d), x.dtype)
        return buf.at[eids, spos].add(
            jnp.where(kp[:, None], xg[tok], 0).astype(x.dtype))

    buf = jax.vmap(scatter_group)(xt, sorted_eids, safe_pos, keep, token_of)
    buf = shd.expert_buf(buf)                                 # [G, E, cap, d]

    # ---- batched expert SwiGLU: (G, E)-parallel over the whole mesh
    e = params["experts"]
    g = jnp.einsum("gecd,edf->gecf", buf, e["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, e["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = shd.expert_buf(jnp.einsum("gecf,efd->gecd", h, e["w_down"]))

    # ---- group-local combine
    def gather_group(ob, eids, spos, kp, tok, wg):
        vals = ob[eids, spos] * kp[:, None]
        return jnp.zeros((Tg, d), jnp.float32).at[tok].add(
            vals.astype(jnp.float32) * wg[:, None])

    wsorted = jnp.take_along_axis(flat_w, order, axis=1)
    y = jax.vmap(gather_group)(out_buf, sorted_eids, safe_pos, keep,
                               token_of, wsorted)
    y = y.astype(x.dtype).reshape(B, S, d)

    if m.n_shared:
        y = y + swiglu(params["shared"], x, shd)
    return shd.btd(y), aux
