"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the rotary half-dims into (temporal, height, width) sections,
each rotated by its own position stream.  For text tokens the three streams
coincide, so text-only behaviour equals standard RoPE — the structure is kept
so the vision stub's 2D patch positions slot in unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4
               ) -> jax.Array:
    """x: [B, S, H, dh]; positions: [B, S] int32 -> same shape, rotated."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, sections: tuple,
                theta: float = 1e4) -> jax.Array:
    """x: [B, S, H, dh]; positions3: [3, B, S] (t, h, w streams).

    sections: per-stream counts of rotary half-dims, sum == dh // 2.
    """
    dh = x.shape[-1]
    if sum(sections) != dh // 2:
        raise ValueError(f"mrope sections {sections} != dh/2 = {dh // 2}")
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    # choose a position stream per half-dim
    stream = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=dh // 2)    # [dh/2]
    pos = positions3.astype(jnp.float32)                # [3, B, S]
    pos_per_dim = pos[stream]                           # [dh/2, B, S]
    ang = jnp.moveaxis(pos_per_dim, 0, -1) * freqs      # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_positions3(positions: jax.Array) -> jax.Array:
    """[B, S] -> [3, B, S] with identical streams (text-only M-RoPE)."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
