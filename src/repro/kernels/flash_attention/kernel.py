"""Pallas TPU kernel: FlashAttention-style blocked attention forward.

Used by the LM-serving substrate for long prefill (the 32k-token cells):
naive attention materializes an [Sq, Sk] score matrix per head — 4 GiB at
32k^2 fp32 — while this kernel streams K/V blocks through VMEM with the
online-softmax recurrence, so HBM traffic is O(S * dh) per head.

Grid: (B*H, nQ, nK); the LAST grid axis iterates sequentially on TPU, so the
output tile and the running (m, l) statistics are *revisited* across the nK
steps (index maps ignore ki) and act as accumulators — initialized at ki == 0
and normalized at ki == nK-1.  MXU does the two GEMMs (q k^T and p v); block
shapes default to (128, 128) — MXU-aligned in both dims.

Masking (causal / sliding-window / kv padding) is applied *inside* the block:
a fully-masked block contributes p = 0 (explicitly zeroed, not just -inf,
so window attention cannot corrupt the running sum).

VMEM per program: q + k + v + o tiles + stats =
(bq + 2*bk + bq) * dh * 4B + 2 * bq * 4B  ~ 260 KiB at 128/128/d128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  kv_len: int, q_offset: int, block_q: int, block_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                 # [bk, dh]
    v = v_ref[0].astype(jnp.float32)                 # [bk, dh]

    s = jnp.dot(q, k.T) * scale                      # [bq, bk] (MXU)

    # global positions of this tile's rows/cols
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + q_offset
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window

    s = jnp.where(mask, s, NEG)
    m_prev = m_ref[0]                                # [bq]
    l_prev = l_ref[0]
    o_prev = o_ref[0]

    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    o_new = o_prev * alpha[:, None] + jnp.dot(p, v)  # [bq, dh] (MXU)

    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[0]
        o_ref[0] = o_ref[0] / jnp.where(l == 0.0, 1.0, l)[:, None]


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "kv_len", "q_offset",
    "block_q", "block_k", "interpret"))
def flash_mha_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     scale: float, causal: bool, window: int | None,
                     kv_len: int, q_offset: int, block_q: int = 128,
                     block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q [BH, Sq, dh], k/v [BH, Sk, dh] (pre-broadcast GQA) -> o [BH, Sq, dh].

    Sq/Sk must be multiples of block_q/block_k (ops.py pads).
    """
    BH, sq, dh = q.shape
    sk = k.shape[1]
    n_q, n_k = sq // block_q, sk // block_k

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_len=kv_len, q_offset=q_offset, block_q=block_q, block_k=block_k,
        n_k=n_k)

    o, m, l = pl.pallas_call(
        kern,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sq, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, sq), jnp.float32),
            jax.ShapeDtypeStruct((BH, sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o
