"""Pure-jnp oracle for blocked (flash) attention.

Layout convention: q [B, Sq, Hq, dh], k/v [B, Sk, Hkv, dh] with
Hq % Hkv == 0 (GQA).  Query positions are the LAST Sq positions of the
Sk-long key sequence (offset = Sk - Sq), the usual prefill/decode contract.

Masking: ``causal`` hides j > i; ``window`` (sliding-window attention)
additionally hides j <= i - window.  ``kv_len``/``q_len`` support padded
inputs.  Softmax is computed in float32 regardless of input dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_mask(sq: int, sk: int, *, causal: bool, window: int | None,
                   kv_len: int | None = None) -> jax.Array:
    """bool [sq, sk]; True = attend."""
    qi = jnp.arange(sq)[:, None] + (sk - sq)     # global q positions
    kj = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= kj > qi - window
    if kv_len is not None:
        m &= kj < kv_len
    return m


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: int | None = None, scale: float | None = None,
        kv_len: int | None = None) -> jax.Array:
    B, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else dh ** -0.5

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    mask = attention_mask(sq, sk, causal=causal, window=window, kv_len=kv_len)
    scores = jnp.where(mask[None, None], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (fully masked) -> zero output, not NaN
    any_valid = mask.any(axis=-1)
    probs = jnp.where(any_valid[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
