"""Jitted public wrapper for blocked attention.

Accepts the model-layer layout q [B, Sq, Hq, dh], k/v [B, Sk, Hkv, dh]
(GQA allowed), handles padding to block multiples, and dispatches to the
Pallas kernel or the jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: int | None = None, scale: float | None = None,
        backend: str = "pallas", block_q: int = 128, block_k: int = 128,
        interpret: bool = True) -> jax.Array:
    """Attention over the last Sq positions of an Sk-long sequence."""
    if backend == "jnp":
        return _ref.mha(q, k, v, causal=causal, window=window, scale=scale)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")

    B, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = float(scale if scale is not None else dh ** -0.5)

    # [B, S, H, dh] -> [B*H, S, dh]
    qt = q.transpose(0, 2, 1, 3).reshape(B * hq, sq, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * hq, sk, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * hq, sk, dh)

    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))
    qt = _pad_to(qt, 1, bq)
    kt = _pad_to(kt, 1, bk)
    vt = _pad_to(vt, 1, bk)

    o = _kernel.flash_mha_kernel(
        qt, kt, vt, scale=scale, causal=causal, window=window,
        kv_len=sk, q_offset=sk - sq, block_q=bq, block_k=bk,
        interpret=interpret)
    o = o[:, :sq].reshape(B, hq, sq, dh).transpose(0, 2, 1, 3)
    return o.astype(q.dtype)
