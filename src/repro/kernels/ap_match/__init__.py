from repro.kernels.ap_match.ops import run_schedule  # noqa: F401
