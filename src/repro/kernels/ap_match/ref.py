"""Pure-jnp oracle for the AP pass-schedule kernel.

Semantics (paper §2.1/§2.2): for each pass p
    TAG    <- AND_k ( planes[cmp_cols[p,k]] XNOR broadcast(cmp_key[p,k]) )
    planes[w_cols[p,k]] <- (old & ~TAG) | (broadcast(w_key[p,k]) & TAG)
and ``matched[p]`` = number of tagged words (popcount of TAG).

Column padding in a :class:`~repro.core.engine.PassSchedule` repeats entry 0,
which is idempotent for both compare (re-ANDing an identical XNOR term) and
write (re-storing an identical value), so the oracle can ignore kc/kw.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FULL = jnp.uint32(0xFFFFFFFF)


def run_schedule(planes: jax.Array, cmp_cols: jax.Array, cmp_key: jax.Array,
                 w_cols: jax.Array, w_key: jax.Array):
    """Execute all passes sequentially over the full plane array.

    planes: uint32[n_bits, n_lanes]; cmp_*: [P, Kc]; w_*: [P, Kw].
    Returns (planes', matched[int32 P]).
    """

    def body(planes, xs):
        cc, ck, wc, wk = xs
        sel = planes[cc]                                  # [Kc, n_lanes]
        keyb = (ck.astype(jnp.uint32) * FULL)[:, None]
        eq = ~(sel ^ keyb)
        # NOT jnp.bitwise_and.reduce: its identity init np.array(-1, uint32)
        # overflows under numpy>=2 (Kc is small, the unrolled AND is fine)
        tag = _and_reduce(eq)
        matched = jax.lax.population_count(tag).astype(jnp.int32).sum()
        old = planes[wc]
        keyw = (wk.astype(jnp.uint32) * FULL)[:, None]
        new = (old & ~tag[None, :]) | (keyw & tag[None, :])
        planes = planes.at[wc].set(new)
        return planes, matched

    return jax.lax.scan(body, planes, (cmp_cols, cmp_key, w_cols, w_key))


def _and_reduce(eq: jax.Array) -> jax.Array:
    out = eq[0]
    for i in range(1, eq.shape[0]):
        out = out & eq[i]
    return out
