"""Jitted public wrapper for the AP pass-schedule kernel.

``run_schedule`` dispatches to the Pallas kernel (``backend='pallas'``,
interpret-mode on CPU; compiled on TPU) or to the pure-jnp oracle
(``backend='jnp'``).  Both return identical results — see
tests/test_kernel_ap_match.py for the sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ap_match import kernel as _kernel
from repro.kernels.ap_match import ref as _ref


def run_schedule(planes: jax.Array, cmp_cols, cmp_key, w_cols, w_key, *,
                 backend: str = "pallas", block_lanes: int = 512,
                 interpret: bool = True):
    """Execute a full AP pass schedule.

    planes : uint32[n_bits, n_lanes]
    cmp_cols/cmp_key : [P, Kc] int32/uint32;  w_cols/w_key : [P, Kw]
    Returns (planes', matched int32[P]).
    """
    cmp_cols = jnp.asarray(cmp_cols, jnp.int32)
    cmp_key = jnp.asarray(cmp_key, jnp.uint32)
    w_cols = jnp.asarray(w_cols, jnp.int32)
    w_key = jnp.asarray(w_key, jnp.uint32)
    if backend == "pallas":
        return _kernel.run_schedule_kernel(
            planes, cmp_cols, cmp_key, w_cols, w_key,
            block_lanes=block_lanes, interpret=interpret)
    elif backend == "jnp":
        return _ref.run_schedule(planes, cmp_cols, cmp_key, w_cols, w_key)
    raise ValueError(f"unknown backend {backend!r}")
