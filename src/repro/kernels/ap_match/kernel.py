"""Pallas TPU kernel: AP compare + tagged-write pass schedule over bitplanes.

This is the hot loop of the Associative Processor emulation (paper §2.1):
every pass COMPAREs up to Kc bit-columns against a key (AND of per-column
XNORs -> packed TAG) and then WRITEs up to Kw bit-columns of all tagged words.

TPU adaptation of the CAM (DESIGN.md §2): the physical AP activates all
columns of one *word block* simultaneously (columns share match lines).  We
re-block the same layout for the HBM->VMEM hierarchy: the grid tiles the
packed **word axis** (lanes of 32 words), one `(n_bits, BLOCK_LANES)` tile of
the plane array is VMEM-resident per program, and *all* passes stream over it
before it is written back — one HBM round-trip per tile for the entire
schedule, instead of one per pass.  Passes commute across word blocks (all AP
ops are word-parallel; rows never interact), so the loop interchange is exact.

VMEM budget: `n_bits * BLOCK_LANES * 4` bytes for the tile (256 x 512 lanes =
512 KiB) plus the schedule tables — comfortably inside the ~16 MiB/core VMEM
of TPU v5e.  The schedule tables (cmp/write columns & keys) are small int
arrays; on real hardware they belong in SMEM via scalar prefetch — kept as
VMEM blocks here so the kernel also runs under ``interpret=True`` on CPU,
which is how tests validate it against :mod:`ref`.

Padding contract: ``PassSchedule`` pads column tables by repeating entry 0,
which is idempotent for compare and write, so the kernel can loop to the
static Kc/Kw bounds without masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FULL = 0xFFFFFFFF  # python int: avoids capturing a traced const in the kernel


def _pass_kernel(cmp_cols_ref, cmp_key_ref, w_cols_ref, w_key_ref,
                 planes_ref, out_planes_ref, matched_ref, *, n_passes: int,
                 kc: int, kw: int):
    # Bring the word-block tile into the output ref; all passes mutate it
    # in place (VMEM-resident RMW), written back to HBM once at the end.
    out_planes_ref[...] = planes_ref[...]

    def one_pass(p, _):
        # ---- COMPARE: TAG <- AND_k XNOR(plane[col_k], key_k)
        tag = jnp.full((out_planes_ref.shape[1],), FULL, jnp.uint32)
        for k in range(kc):                      # static unroll over columns
            col = cmp_cols_ref[p, k]
            row = out_planes_ref[col, :]
            keyb = cmp_key_ref[p, k].astype(jnp.uint32) * jnp.uint32(FULL)
            tag = tag & ~(row ^ keyb)
        matched_ref[0, p] = jax.lax.population_count(tag).astype(jnp.int32).sum()
        # ---- WRITE: tagged rows take the key bit in each write column
        for k in range(kw):
            col = w_cols_ref[p, k]
            row = out_planes_ref[col, :]
            keyb = w_key_ref[p, k].astype(jnp.uint32) * jnp.uint32(FULL)
            out_planes_ref[col, :] = (row & ~tag) | (keyb & tag)
        return 0

    jax.lax.fori_loop(0, n_passes, one_pass, 0)


@functools.partial(jax.jit, static_argnames=("block_lanes", "interpret"))
def run_schedule_kernel(planes: jax.Array, cmp_cols: jax.Array,
                        cmp_key: jax.Array, w_cols: jax.Array,
                        w_key: jax.Array, *, block_lanes: int = 512,
                        interpret: bool = True):
    """planes: uint32[n_bits, n_lanes] -> (planes', matched int32[P])."""
    n_bits, n_lanes = planes.shape
    P, kc = cmp_cols.shape
    kw = w_cols.shape[1]
    bl = min(block_lanes, n_lanes)
    if n_lanes % bl != 0:
        raise ValueError(f"n_lanes={n_lanes} not a multiple of block={bl}")
    n_blocks = n_lanes // bl

    kern = functools.partial(_pass_kernel, n_passes=P, kc=kc, kw=kw)
    planes_out, matched = pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((P, kc), lambda i: (0, 0)),     # cmp_cols
            pl.BlockSpec((P, kc), lambda i: (0, 0)),     # cmp_key
            pl.BlockSpec((P, kw), lambda i: (0, 0)),     # w_cols
            pl.BlockSpec((P, kw), lambda i: (0, 0)),     # w_key
            pl.BlockSpec((n_bits, bl), lambda i: (0, i)),  # planes tile
        ],
        out_specs=[
            pl.BlockSpec((n_bits, bl), lambda i: (0, i)),
            pl.BlockSpec((1, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_bits, n_lanes), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, P), jnp.int32),
        ],
        interpret=interpret,
    )(cmp_cols, cmp_key, w_cols, w_key, planes)
    return planes_out, matched.sum(axis=0)
