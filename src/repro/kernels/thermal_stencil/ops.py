"""Jitted wrappers: Pallas thermal stencil + CG solve built on it.

``cg_solve`` mirrors :func:`repro.core.thermal._cg_solve` (Jacobi-
preconditioned CG) with the stencil application replaced by the Pallas
kernel; ``repro.core.thermal.steady_state(use_pallas=True)`` routes here.
Conductances may be scalars or per-layer vectors (see core.thermal).
"""
from __future__ import annotations

import functools

import jax

from repro.core.thermal import _vectors
from repro.kernels.thermal_stencil import kernel as _kernel


def apply_operator(T: jax.Array, g_lat, g_vert, g_pkg, *,
                   block_y: int = 32, interpret: bool = True) -> jax.Array:
    """y = G @ T (same contract as core.thermal.apply_operator)."""
    L = T.shape[0]
    g_lat, gv_u, gv_d, g_pkg_vec = _vectors(L, g_lat, g_vert, g_pkg)
    return _kernel.apply_operator_kernel(
        T, g_lat, gv_u, gv_d, g_pkg_vec, block_y=block_y,
        interpret=interpret)


def apply_operator_fields(T: jax.Array, F: dict, *, block_y: int = 32,
                          interpret: bool = True) -> jax.Array:
    """Heterogeneous operator (same contract as
    core.thermal.apply_operator_fields)."""
    return _kernel.apply_operator_fields_kernel(
        T, F["gx_lf"], F["gx_rt"], F["gy_up"], F["gy_dn"], F["gz_up"],
        F["gz_dn"], F["g_pkg"], block_y=block_y, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_iter", "block_y",
                                             "interpret"))
def cg_solve_fields_stats(b: jax.Array, F: dict, tol: float = 1e-8,
                          max_iter: int = 8000, block_y: int = 32,
                          interpret: bool = True):
    """Jacobi-preconditioned CG on the heterogeneous Pallas stencil.

    Returns ``(x, n_iterations)`` like :func:`repro.core.thermal.pcg`.
    """
    from repro.core.thermal import _diag_fields, pcg
    A = lambda v: apply_operator_fields(v, F, block_y=block_y,
                                        interpret=interpret)
    return pcg(A, 1.0 / _diag_fields(F), b, tol, max_iter)


def cg_solve_fields(b: jax.Array, F: dict, tol: float = 1e-8,
                    max_iter: int = 8000, block_y: int = 32,
                    interpret: bool = True) -> jax.Array:
    return cg_solve_fields_stats(b, F, tol, max_iter, block_y,
                                 interpret)[0]


@functools.partial(jax.jit, static_argnames=("max_iter", "block_y",
                                             "interpret"))
def cg_solve(b: jax.Array, diag: jax.Array, g_lat, g_vert, g_pkg,
             tol: float = 1e-8, max_iter: int = 6000,
             block_y: int = 32, interpret: bool = True) -> jax.Array:
    """Jacobi-preconditioned CG for G T = b with the Pallas stencil."""
    from repro.core.thermal import pcg
    L = b.shape[0]
    g_lat, gv_u, gv_d, g_pkg_vec = _vectors(L, g_lat, g_vert, g_pkg)
    A = lambda v: _kernel.apply_operator_kernel(
        v, g_lat, gv_u, gv_d, g_pkg_vec, block_y=block_y,
        interpret=interpret)
    return pcg(A, 1.0 / diag, b, tol, max_iter)[0]


