"""Pallas TPU kernel: one application of the 3D RC thermal operator  y = G T.

The HotSpot-equivalent steady-state solve (paper §4) is CG on the SPD
conductance system; >95% of its time is the 7-point stencil sweep, so that
sweep is the kernel.  T is [L, ny, nx]: the silicon layers plus the
grid-resolved copper spreader, each with its own lateral sheet conductance
``g_lat[l]`` and per-interface vertical conductances (fed in pre-padded as
gv_up/gv_dn/g_pkg per-layer vectors, so the kernel formula is uniform:

    y = g_lat*(4T - N4) + gv_up*(T - T_above) + gv_dn*(T - T_below) + g_pkg*T

with zero entries encoding the adiabatic top and the lump-coupled bottom).

TPU adaptation: the grid tiles the **y axis**; each program holds an
(L, BLOCK_Y, nx) tile in VMEM — full layer depth and full x rows, so the
vertical and x couplings never leave VMEM.  The y halo comes from passing
the SAME array with index maps i-1 / i+1 (clamped); boundary programs
replicate their own edge row (adiabatic = zero difference), matching the
reference operator exactly.  VPU-only elementwise work — memory-bound by
design (arithmetic intensity ~1 flop/byte).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(gl_ref, gu_ref, gd_ref, gp_ref, c_ref, up_ref, dn_ref,
                    y_ref, *, n_blocks: int):
    i = pl.program_id(0)
    C = c_ref[...]                                   # [L, BY, nx]
    gl = gl_ref[...][:, None, None]
    gu = gu_ref[...][:, None, None]
    gd = gd_ref[...][:, None, None]
    gp = gp_ref[...][:, None, None]
    # y-halo rows: neighbor tile edge, or own edge at the global boundary
    above = jnp.where(i > 0, up_ref[:, -1:, :], C[:, :1, :])
    below = jnp.where(i < n_blocks - 1, dn_ref[:, :1, :], C[:, -1:, :])
    t_up = jnp.concatenate([above, C[:, :-1, :]], axis=1)
    t_dn = jnp.concatenate([C[:, 1:, :], below], axis=1)
    t_lf = jnp.concatenate([C[:, :, :1], C[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([C[:, :, 1:], C[:, :, -1:]], axis=2)
    l_up = jnp.concatenate([C[:1], C[:-1]], axis=0)
    l_dn = jnp.concatenate([C[1:], C[-1:]], axis=0)

    y_ref[...] = gl * (4.0 * C - t_up - t_dn - t_lf - t_rt) \
        + gu * (C - l_up) + gd * (C - l_dn) + gp * C


def _field_kernel(c_ref, up_ref, dn_ref, gxl_ref, gxr_ref, gyu_ref, gyd_ref,
                  gzu_ref, gzd_ref, gp_ref, y_ref, *, n_blocks: int):
    """Heterogeneous per-face conductances (zero face = adiabatic):
    the production operator — silicon exists only over the die footprint,
    the spreader spans the full (die + margin) domain."""
    i = pl.program_id(0)
    C = c_ref[...]
    above = jnp.where(i > 0, up_ref[:, -1:, :], C[:, :1, :])
    below = jnp.where(i < n_blocks - 1, dn_ref[:, :1, :], C[:, -1:, :])
    t_up = jnp.concatenate([above, C[:, :-1, :]], axis=1)
    t_dn = jnp.concatenate([C[:, 1:, :], below], axis=1)
    t_lf = jnp.concatenate([C[:, :, :1], C[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([C[:, :, 1:], C[:, :, -1:]], axis=2)
    l_up = jnp.concatenate([C[:1], C[:-1]], axis=0)
    l_dn = jnp.concatenate([C[1:], C[-1:]], axis=0)
    y_ref[...] = gxl_ref[...] * (C - t_lf) + gxr_ref[...] * (C - t_rt) \
        + gyu_ref[...] * (C - t_up) + gyd_ref[...] * (C - t_dn) \
        + gzu_ref[...] * (C - l_up) + gzd_ref[...] * (C - l_dn) \
        + gp_ref[...] * C


@functools.partial(jax.jit, static_argnames=("block_y", "interpret"))
def apply_operator_fields_kernel(T: jax.Array, gx_lf, gx_rt, gy_up, gy_dn,
                                 gz_up, gz_dn, g_pkg, *, block_y: int = 32,
                                 interpret: bool = True) -> jax.Array:
    L, ny, nx = T.shape
    by = min(block_y, ny)
    while ny % by != 0:
        by -= 1
    n_blocks = ny // by

    kern = functools.partial(_field_kernel, n_blocks=n_blocks)
    tile = pl.BlockSpec((L, by, nx), lambda i: (0, i, 0))
    spec_up = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.maximum(i - 1, 0), 0))
    spec_dn = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.minimum(i + 1, n_blocks - 1), 0))
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[tile, spec_up, spec_dn] + [tile] * 7,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((L, ny, nx), T.dtype),
        interpret=interpret,
    )(T, T, T, gx_lf, gx_rt, gy_up, gy_dn, gz_up, gz_dn, g_pkg)


@functools.partial(jax.jit, static_argnames=("block_y", "interpret"))
def apply_operator_kernel(T: jax.Array, g_lat: jax.Array, gv_up: jax.Array,
                          gv_dn: jax.Array, g_pkg_vec: jax.Array, *,
                          block_y: int = 32, interpret: bool = True
                          ) -> jax.Array:
    L, ny, nx = T.shape
    by = min(block_y, ny)
    while ny % by != 0:          # largest divisor <= requested block
        by -= 1
    n_blocks = ny // by

    kern = functools.partial(_stencil_kernel, n_blocks=n_blocks)
    vec = pl.BlockSpec((L,), lambda i: (0,))
    spec_c = pl.BlockSpec((L, by, nx), lambda i: (0, i, 0))
    spec_up = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.maximum(i - 1, 0), 0))
    spec_dn = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.minimum(i + 1, n_blocks - 1), 0))
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[vec, vec, vec, vec, spec_c, spec_up, spec_dn],
        out_specs=pl.BlockSpec((L, by, nx), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, ny, nx), T.dtype),
        interpret=interpret,
    )(g_lat, gv_up, gv_dn, g_pkg_vec, T, T, T)
