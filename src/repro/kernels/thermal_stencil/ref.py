"""Pure-jnp oracle for the 3D RC thermal stencil.

The reference operator *is* :func:`repro.core.thermal.apply_operator` — the
solver the paper-reproduction thermal analysis runs on by default.  Re-export
it so the kernel tests follow the standard kernels/<name>/{kernel,ops,ref}
pattern.
"""
from repro.core.thermal import apply_operator  # noqa: F401
