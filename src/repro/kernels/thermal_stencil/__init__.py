from repro.kernels.thermal_stencil.ops import apply_operator, cg_solve  # noqa: F401
