"""Jitted wrapper: the Pallas red-black z-line multigrid smoother.

``rb_line_sweep`` has the exact contract of
:func:`repro.core.multigrid.rb_line_sweep` (the jnp oracle) and slots
into the V-cycle through ``multigrid._resolve_sweep(use_pallas=True)``
— i.e. ``thermal.steady_state(..., solver="mg"/"mgcg",
use_pallas=True)`` smooths every level with this kernel.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.mg_smooth import kernel as _kernel


@functools.partial(jax.jit, static_argnames=("color", "block_y",
                                             "interpret"))
def rb_line_sweep(T: jax.Array, b: jax.Array, F: dict, d_extra,
                  color: int, *, block_y: int = 32,
                  interpret: bool = True) -> jax.Array:
    """One red-black z-line Gauss-Seidel half-sweep (Pallas path)."""
    import jax.numpy as jnp
    d_extra = jnp.broadcast_to(jnp.asarray(d_extra, T.dtype), T.shape)
    return _kernel.rb_line_sweep_kernel(
        T, b, F["gx_lf"], F["gx_rt"], F["gy_up"], F["gy_dn"],
        F["gz_up"], F["gz_dn"], F["g_pkg"], d_extra, color=color,
        block_y=block_y, interpret=interpret)
