from repro.kernels.mg_smooth.ops import rb_line_sweep  # noqa: F401
