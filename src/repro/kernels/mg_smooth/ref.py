"""Pure-jnp oracle for the red-black z-line multigrid smoother.

The reference *is* :func:`repro.core.multigrid.rb_line_sweep` — the
smoother the V-cycle runs by default.  Re-export it so the kernel tests
follow the standard kernels/<name>/{kernel,ops,ref} pattern.
"""
from repro.core.multigrid import rb_line_sweep  # noqa: F401
