"""Pallas TPU kernel: one red-black z-line Gauss-Seidel half-sweep.

The multigrid smoother (``core/multigrid.rb_line_sweep``) is the hot
loop of every V-cycle: for each in-plane cell of one checkerboard color,
solve the cell's vertical (stack-axis) tridiagonal system exactly with
the lateral neighbors frozen.  This kernel mirrors the jnp oracle
tile-for-tile using the ``kernels/thermal_stencil`` layout: the grid
tiles the y axis, each program holds an (L, BLOCK_Y, nx) tile in VMEM
(full layer depth + full x rows, so the Thomas recursion over the 5-9
layers and the x couplings never leave VMEM), and the y halo comes from
passing T again with clamped i-1 / i+1 index maps.

The Thomas forward/backward recursion unrolls over the static layer
count — short vector ops on [BLOCK_Y, nx] planes, VPU-only, memory-bound
like the stencil kernel.  The checkerboard mask needs the GLOBAL row
index (parity must be consistent across tiles): ``i * BLOCK_Y +
iota_y``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rb_line_kernel(t_ref, up_ref, dn_ref, b_ref, gxl_ref, gxr_ref,
                    gyu_ref, gyd_ref, gzu_ref, gzd_ref, gp_ref, de_ref,
                    y_ref, *, color: int, block_y: int, n_blocks: int):
    i = pl.program_id(0)
    T = t_ref[...]                                   # [L, BY, nx]
    L, by, nx = T.shape

    # y halo rows: neighbor tile edge, or own edge at the global boundary
    above = jnp.where(i > 0, up_ref[:, -1:, :], T[:, :1, :])
    below = jnp.where(i < n_blocks - 1, dn_ref[:, :1, :], T[:, -1:, :])
    t_up = jnp.concatenate([above, T[:, :-1, :]], axis=1)
    t_dn = jnp.concatenate([T[:, 1:, :], below], axis=1)
    t_lf = jnp.concatenate([T[:, :, :1], T[:, :, :-1]], axis=2)
    t_rt = jnp.concatenate([T[:, :, 1:], T[:, :, -1:]], axis=2)

    gxl, gxr = gxl_ref[...], gxr_ref[...]
    gyu, gyd = gyu_ref[...], gyd_ref[...]
    gzu, gzd = gzu_ref[...], gzd_ref[...]
    gp, de = gp_ref[...], de_ref[...]

    rhs = b_ref[...] + gxl * t_lf + gxr * t_rt + gyu * t_up + gyd * t_dn
    diag = gxl + gxr + gyu + gyd + gzu + gzd + gp + de
    diag = jnp.where(diag > 0, diag, 1.0)
    lo = -gzu                      # coupling to layer l-1 (zero at l = 0)
    up = -gzd                      # coupling to layer l+1 (zero at L-1)

    # Thomas over the (small, static) layer axis
    cp = [up[0] / diag[0]]
    dp = [rhs[0] / diag[0]]
    for l in range(1, L):
        denom = diag[l] - lo[l] * cp[-1]
        denom = jnp.where(jnp.abs(denom) > 0, denom, 1.0)
        cp.append(up[l] / denom)
        dp.append((rhs[l] - lo[l] * dp[-1]) / denom)
    u = [dp[-1]]
    for l in range(L - 2, -1, -1):
        u.append(dp[l] - cp[l] * u[-1])
    u = jnp.stack(u[::-1], axis=0)

    # global checkerboard parity: (global_y + x) % 2 == color
    gy = i * block_y + jax.lax.broadcasted_iota(jnp.int32, (by, nx), 0)
    xx = jax.lax.broadcasted_iota(jnp.int32, (by, nx), 1)
    mask = ((gy + xx) % 2 == color)[None]
    y_ref[...] = jnp.where(mask, u, T)


@functools.partial(jax.jit, static_argnames=("color", "block_y",
                                             "interpret"))
def rb_line_sweep_kernel(T: jax.Array, b: jax.Array, gx_lf, gx_rt, gy_up,
                         gy_dn, gz_up, gz_dn, g_pkg, d_extra, *,
                         color: int, block_y: int = 32,
                         interpret: bool = True) -> jax.Array:
    L, ny, nx = T.shape
    by = min(block_y, ny)
    while ny % by != 0:          # largest divisor <= requested block
        by -= 1
    n_blocks = ny // by

    kern = functools.partial(_rb_line_kernel, color=color, block_y=by,
                             n_blocks=n_blocks)
    tile = pl.BlockSpec((L, by, nx), lambda i: (0, i, 0))
    spec_up = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.maximum(i - 1, 0), 0))
    spec_dn = pl.BlockSpec((L, by, nx),
                           lambda i: (0, jnp.minimum(i + 1, n_blocks - 1), 0))
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[tile, spec_up, spec_dn] + [tile] * 9,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((L, ny, nx), T.dtype),
        interpret=interpret,
    )(T, T, T, b, gx_lf, gx_rt, gy_up, gy_dn, gz_up, gz_dn, g_pkg, d_extra)
