"""Pallas megakernel: a whole AP op group in ONE kernel launch.

Where :mod:`repro.kernels.ap_match` fuses a homogeneous pass *schedule*
(compare + tagged write per row), this kernel executes a full
:class:`~repro.kernels.ap_megakernel.ref.OpGroup` micro-program —
PASS / CMP / CMP_TAG / WRITE ops with response-counter conditions —
while the plane tile stays VMEM-resident across every op: match (masked
compare), conditional write, and the popcount accumulate are fused into
a single launch instead of one XLA op chain per pass.

Tiling contract (see DESIGN.md §3.4):

* **Unconditional groups** (``cond == 0`` everywhere, e.g. bucketed
  pass schedules) tile the packed word axis exactly like ap_match: ops
  commute across word blocks, per-block popcounts are summed outside.
* **Conditional groups** (the sort/knn inner loops) branch on *global*
  responder counts, so the whole lane axis must be resident in one
  program instance (``grid=(1,)``): a block-local popcount would make
  block A take a branch block B skips.  The dispatcher
  (:mod:`.ops`) enforces this; VMEM sizing stays comfortable because
  the AP word is narrow — n_bits x n_lanes x 4 B ≈ 2.5 MiB even at
  1M elements x 20 bit-columns.

The schedule/op tables ride as small VMEM blocks (SMEM scalar-prefetch
on real hardware) so the kernel also runs under ``interpret=True`` on
CPU — which is how tier-1 validates it against :func:`ref.group_scan`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ap_megakernel.ref import OP_CMP, OP_CMP_TAG, OP_PASS, OP_WRITE

FULL = 0xFFFFFFFF  # python int: avoids capturing a traced const


def _group_kernel(op_ref, cond_ref, en_ref, cc_ref, ck_ref, wc_ref, wk_ref,
                  planes_ref, tag_ref, out_planes_ref, out_tag_ref,
                  matched_ref, *, n_ops: int, kc: int, kw: int,
                  conditional: bool):
    # Bring the word-block tile (planes AND persistent tag) into the
    # output refs; every op mutates them in place — one HBM round-trip
    # for the entire group.
    out_planes_ref[...] = planes_ref[...]
    out_tag_ref[...] = tag_ref[...]

    def one_op(p, _):
        opc = op_ref[p]
        # ---- COMPARE: fresh tag <- AND_k XNOR(plane[col_k], key_k)
        t = jnp.full((out_planes_ref.shape[1],), FULL, jnp.uint32)
        for k in range(kc):                      # static unroll over columns
            col = cc_ref[p, k]
            row = out_planes_ref[col, :]
            keyb = ck_ref[p, k].astype(jnp.uint32) * jnp.uint32(FULL)
            t = t & ~(row ^ keyb)
        cur = out_tag_ref[0, :]
        t = jnp.where(opc == OP_CMP_TAG, t & cur, t)
        is_wr = opc == OP_WRITE
        wtag = jnp.where(is_wr, cur, t)          # WRITE uses persistent TAG
        m = jax.lax.population_count(wtag).astype(jnp.int32).sum()
        en = en_ref[p] != 0
        if conditional:
            # response-counter predicate: matched_ref holds this very
            # group's earlier results (single block => global counts)
            cnd = cond_ref[p]
            prev = matched_ref[0, jnp.maximum(p - cnd, 0)]
            ex = en & ((cnd == 0) | (prev > 0))
        else:
            ex = en
        matched_ref[0, p] = jnp.where(ex, m, 0)
        # ---- WRITE: tagged rows take the key bit in each write column
        do_w = ex & (is_wr | (opc == OP_PASS))
        for k in range(kw):
            col = wc_ref[p, k]
            row = out_planes_ref[col, :]
            keyb = wk_ref[p, k].astype(jnp.uint32) * jnp.uint32(FULL)
            out_planes_ref[col, :] = jnp.where(do_w,
                                               (row & ~wtag) | (keyb & wtag),
                                               row)
        do_t = ex & ((opc == OP_CMP) | (opc == OP_CMP_TAG))
        out_tag_ref[0, :] = jnp.where(do_t, t, cur)
        return 0

    jax.lax.fori_loop(0, n_ops, one_op, 0)


@functools.partial(jax.jit, static_argnames=("block_lanes", "interpret",
                                             "conditional"))
def run_group_kernel(planes: jax.Array, tag: jax.Array, op: jax.Array,
                     cond: jax.Array, enabled: jax.Array, cmp_cols: jax.Array,
                     cmp_key: jax.Array, w_cols: jax.Array, w_key: jax.Array,
                     *, block_lanes: int = 512, interpret: bool = True,
                     conditional: bool = False):
    """One megakernel launch -> (planes', tag', matched int32[P]).

    ``conditional`` must be True iff any ``cond > 0`` (static: selects
    the single-block lowering).  Callers go through
    :func:`repro.kernels.ap_megakernel.ops.run_group`, which derives it
    from the host-side OpGroup.
    """
    n_bits, n_lanes = planes.shape
    P, kc = cmp_cols.shape
    kw = w_cols.shape[1]
    bl = n_lanes if conditional else min(block_lanes, n_lanes)
    if n_lanes % bl != 0:
        raise ValueError(f"n_lanes={n_lanes} not a multiple of block={bl}")
    n_blocks = n_lanes // bl

    kern = functools.partial(_group_kernel, n_ops=P, kc=kc, kw=kw,
                             conditional=conditional)
    tag2 = tag.reshape(1, n_lanes)
    planes_out, tag_out, matched = pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((P,), lambda i: (0,)),          # op
            pl.BlockSpec((P,), lambda i: (0,)),          # cond
            pl.BlockSpec((P,), lambda i: (0,)),          # enabled
            pl.BlockSpec((P, kc), lambda i: (0, 0)),     # cmp_cols
            pl.BlockSpec((P, kc), lambda i: (0, 0)),     # cmp_key
            pl.BlockSpec((P, kw), lambda i: (0, 0)),     # w_cols
            pl.BlockSpec((P, kw), lambda i: (0, 0)),     # w_key
            pl.BlockSpec((n_bits, bl), lambda i: (0, i)),  # planes tile
            pl.BlockSpec((1, bl), lambda i: (0, i)),       # tag tile
        ],
        out_specs=[
            pl.BlockSpec((n_bits, bl), lambda i: (0, i)),
            pl.BlockSpec((1, bl), lambda i: (0, i)),
            pl.BlockSpec((1, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_bits, n_lanes), jnp.uint32),
            jax.ShapeDtypeStruct((1, n_lanes), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, P), jnp.int32),
        ],
        interpret=interpret,
    )(op, cond, enabled.astype(jnp.int32), cmp_cols, cmp_key, w_cols, w_key,
      planes, tag2)
    return planes_out, tag_out.reshape(n_lanes), matched.sum(axis=0)
