"""The AP megakernel op-group model + pure-jnp reference executor.

A *group* is a static micro-program over one AP array: a table of ops,
each one silicon cycle-accurate against :mod:`repro.core.engine`'s
``state_compare`` / ``state_write`` / ``state_run`` chain:

* ``OP_PASS``     — COMPARE + tagged WRITE with the *fresh* match tag
                    (one schedule pass; the persistent TAG is untouched)
* ``OP_CMP``      — COMPARE into the persistent TAG
* ``OP_CMP_TAG``  — COMPARE ANDed into the persistent TAG
                    (``restrict_to_tag=True``)
* ``OP_WRITE``    — tagged WRITE using the persistent TAG

plus two execution predicates that make data-dependent inner loops
(the sort/knn response-counter branches) expressible as a *static*
table with on-device control flow:

* ``cond[p] == 0`` — always execute;
* ``cond[p] == k`` (k in 1..MAX_COND) — execute iff the op ``k`` slots
  back matched at least one row (``matched[p-k] > 0``, the response
  counter the paper's controller branches on);

and a dynamic ``enabled[p]`` mask for shape-bucketed padding (a
disabled op leaves all state untouched and reports ``matched = 0``).

``matched[p]`` is the popcount of the tag the op acted with — the fresh
compare tag for PASS/CMP ops, the persistent TAG for WRITE — i.e.
exactly what the eager engine's per-cycle host sync would read.  Under
a ``shard_map`` over the packed word-lane axis, popcounts are
``psum``-reduced over ``axis_name`` before any predicate consumes them,
so branch decisions (and therefore every plane/tag bit) are invariant
to the device count: integer addition is exact in any order.

This module is the semantic reference (and the CPU lowering — one
fused ``lax.scan`` program); :mod:`.kernel` is the Pallas TPU kernel
with the plane tile VMEM-resident across the whole group, and
:mod:`.ops` dispatches between them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane as bp

OP_PASS, OP_CMP, OP_CMP_TAG, OP_WRITE = 0, 1, 2, 3

#: deepest conditional lookback a group may use (static scan-carry window)
MAX_COND = 4


@dataclasses.dataclass(frozen=True)
class OpGroup:
    """A static AP micro-program (host-side numpy tables).

    Column tables are padded by repeating entry 0, which is idempotent
    for both compare (re-ANDing an identical XNOR term) and write
    (re-storing the same value) — the :class:`~repro.core.engine.PassSchedule`
    padding contract.  WRITE ops carry a dummy compare column (col 0,
    key 0) and CMP ops a dummy write column; the executors never apply
    the unused half.
    """
    op: np.ndarray        # int32[P]
    cond: np.ndarray      # int32[P]
    cmp_cols: np.ndarray  # int32[P, Kc]
    cmp_key: np.ndarray   # uint32[P, Kc]
    w_cols: np.ndarray    # int32[P, Kw]
    w_key: np.ndarray     # uint32[P, Kw]

    @property
    def n_ops(self) -> int:
        return int(self.op.shape[0])

    @property
    def conditional(self) -> bool:
        return bool(self.cond.max(initial=0) > 0)

    def tables(self) -> tuple:
        """The six device-input arrays, in executor argument order."""
        return (self.op, self.cond, self.cmp_cols, self.cmp_key,
                self.w_cols, self.w_key)

    @staticmethod
    def build(ops: Sequence[tuple]) -> "OpGroup":
        """ops: (opcode, cond, cmp_cols, cmp_key, w_cols, w_key) per op.

        CMP ops may pass empty write lists and WRITE ops empty compare
        lists; dummy entries are substituted.  Raises on an empty group
        and on conditions outside [0, MAX_COND] or reaching before op 0.
        """
        if not ops:
            raise ValueError("empty op group")
        norm = []
        for p, (opc, cond, cc, ck, wc, wk) in enumerate(ops):
            if opc not in (OP_PASS, OP_CMP, OP_CMP_TAG, OP_WRITE):
                raise ValueError(f"unknown opcode {opc!r}")
            if not 0 <= cond <= MAX_COND:
                raise ValueError(f"cond {cond} outside [0, {MAX_COND}]")
            if cond > p:
                raise ValueError(f"op {p} cond {cond} reaches before op 0")
            cc, ck = (list(cc), list(ck)) if len(list(cc)) else ([0], [0])
            wc, wk = (list(wc), list(wk)) if len(list(wc)) else ([cc[0]], [0])
            norm.append((opc, cond, cc, ck, wc, wk))
        Kc = max(len(o[2]) for o in norm)
        Kw = max(len(o[4]) for o in norm)

        def pad(vals, K):
            return vals + [vals[0]] * (K - len(vals))

        return OpGroup(
            np.array([o[0] for o in norm], np.int32),
            np.array([o[1] for o in norm], np.int32),
            np.array([pad(o[2], Kc) for o in norm], np.int32),
            np.array([pad(o[3], Kc) for o in norm], np.uint32),
            np.array([pad(o[4], Kw) for o in norm], np.int32),
            np.array([pad(o[5], Kw) for o in norm], np.uint32),
        )

    @staticmethod
    def from_schedule(cmp_cols, cmp_key, w_cols, w_key) -> "OpGroup":
        """A pass schedule (already shape-bucketed) as all-PASS ops."""
        cmp_cols = np.asarray(cmp_cols, np.int32)
        P = cmp_cols.shape[0]
        if P == 0:
            raise ValueError("empty op group")
        return OpGroup(np.zeros(P, np.int32) + OP_PASS,
                       np.zeros(P, np.int32),
                       cmp_cols, np.asarray(cmp_key, np.uint32),
                       np.asarray(w_cols, np.int32),
                       np.asarray(w_key, np.uint32))

    @staticmethod
    def probes(cols, keys) -> "OpGroup":
        """A batch of plain COMPAREs (hist bins / spmv reductions)."""
        cols = np.atleast_2d(np.asarray(cols, np.int32))
        keys = np.atleast_2d(np.asarray(keys, np.uint32))
        P = cols.shape[0]
        if P == 0:
            raise ValueError("empty op group")
        return OpGroup(np.zeros(P, np.int32) + OP_CMP,
                       np.zeros(P, np.int32),
                       cols, keys, cols[:, :1], np.zeros((P, 1), np.uint32))


# ---------------------------------------------------------------------------
# jnp reference executor
# ---------------------------------------------------------------------------

def _popcount(row, axis_name=None):
    n = jax.lax.population_count(row).astype(jnp.int32).sum()
    if axis_name is not None:
        n = jax.lax.psum(n, axis_name)
    return n


def group_scan(planes, tag, tables, enabled, axis_name=None):
    """Execute a whole op group as one fused scan (the megakernel body).

    planes : uint32[n_bits, n_lanes] (the local lane shard, if sharded)
    tag    : uint32[n_lanes]
    tables : the 6 OpGroup arrays (device or numpy)
    enabled: bool[P] dynamic op mask
    Returns (planes', tag', matched int32[P], executed bool[P]).

    Pure and jit/scan/shard_map-composable: this is both the CPU
    lowering of the megakernel and the oracle the Pallas kernel is
    tested against.
    """
    op, cond, cc, ck, wc, wk = (jnp.asarray(t) for t in tables)
    enabled = jnp.asarray(enabled, jnp.bool_)

    def body(carry, xs):
        planes, tag, hist = carry
        opc, cnd, en, ccp, ckp, wcp, wkp = xs
        t_cmp = bp.compare(planes, ccp, ckp)
        t_cmp = jnp.where(opc == OP_CMP_TAG, t_cmp & tag, t_cmp)
        is_wr = opc == OP_WRITE
        wtag = jnp.where(is_wr, tag, t_cmp)
        m = _popcount(wtag, axis_name)
        # response-counter predicate: hist holds the last MAX_COND
        # matched counts, hist[-1] being the previous op's
        prev = jnp.where(cnd > 0,
                         hist[jnp.clip(MAX_COND - cnd, 0, MAX_COND - 1)],
                         jnp.int32(1))
        ex = en & (prev > 0)
        do_write = ex & (is_wr | (opc == OP_PASS))
        written = bp.tagged_write(planes, wtag, wcp, wkp)
        planes = jnp.where(do_write, written, planes)
        is_cmp = (opc == OP_CMP) | (opc == OP_CMP_TAG)
        tag = jnp.where(ex & is_cmp, t_cmp, tag)
        m_out = jnp.where(ex, m, jnp.int32(0))
        hist = jnp.concatenate([hist[1:], m_out[None]])
        return (planes, tag, hist), (m_out, ex)

    hist0 = jnp.zeros(MAX_COND, jnp.int32)
    (planes, tag, _), (matched, executed) = jax.lax.scan(
        body, (planes, tag, hist0), (op, cond, enabled, cc, ck, wc, wk))
    return planes, tag, matched, executed


def counter_delta(op, matched, executed):
    """Packed int32[N_COUNTERS] delta a group contributes on device.

    Mirrors what the ``state_*`` op chain would accumulate: a PASS is a
    compare + a write cycle, CMP/WRITE one cycle each; every non-WRITE
    op's matched count feeds CTR_MATCH (``state_write`` never does).
    """
    from repro.core import engine as E

    op = jnp.asarray(op)
    ex = executed.astype(jnp.int32)
    is_pass = (op == OP_PASS).astype(jnp.int32)
    is_wr = (op == OP_WRITE).astype(jnp.int32)
    cycles = (ex * (1 + is_pass)).sum()
    compares = (ex * (1 - is_wr)).sum()
    writes = (ex * (is_pass | (op == OP_WRITE)).astype(jnp.int32)).sum()
    match = (matched * (1 - is_wr)).sum()
    delta = jnp.zeros(E.N_COUNTERS, jnp.int32)
    return (delta.at[E.CTR_CYCLES].set(cycles)
            .at[E.CTR_COMPARE].set(compares)
            .at[E.CTR_WRITE].set(writes)
            .at[E.CTR_MATCH].set(match))
