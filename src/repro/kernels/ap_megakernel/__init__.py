from repro.kernels.ap_megakernel.ops import run_group  # noqa: F401
from repro.kernels.ap_megakernel.ref import (  # noqa: F401
    MAX_COND, OP_CMP, OP_CMP_TAG, OP_PASS, OP_WRITE, OpGroup, counter_delta,
    group_scan)
