"""Dispatch layer for the AP megakernel.

``run_group`` is the one entry point: it executes an
:class:`~repro.kernels.ap_megakernel.ref.OpGroup` against (planes, tag)
via

* ``backend="jnp"``     — the fused-scan reference executor (CPU/GPU),
* ``backend="pallas"``  — the VMEM-resident Pallas kernel
  (``interpret=True`` on CPU),

optionally sharded over the packed word-lane axis with ``mesh=`` (a 1D
``'lanes'`` mesh from :func:`repro.parallel.sharding.ap_mesh`): each
device holds a plane/tag slice, responder popcounts are ``psum``-ed
before any conditional consumes them, so results are bitwise invariant
to the device count.

Launch counters: every host-level dispatch bumps
``kernels/launch/ap_megakernel`` (+ per-backend variant) in ``repro.obs``
— that is the kernel-launch budget the megakernel path is meant to
shrink, and benches snapshot it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.kernels.ap_megakernel import ref
from repro.kernels.ap_megakernel.kernel import run_group_kernel
from repro.kernels.ap_megakernel.ref import OpGroup


@jax.jit
def _run_group_jnp(planes, tag, op, cond, enabled, cc, ck, wc, wk):
    obs.count("kernels/retrace/ap_megakernel")
    obs.count(f"kernels/retrace/ap_megakernel[P={op.shape[0]},"
              f"Kc={cc.shape[1]},Kw={wc.shape[1]}]")
    return ref.group_scan(planes, tag, (op, cond, cc, ck, wc, wk), enabled)


@functools.lru_cache(maxsize=None)
def _sharded_runner(mesh):
    """jit(shard_map(group_scan)) over the 'lanes' axis, cached per mesh.

    Plane columns and the tag shard over lanes; the op tables are
    replicated; matched/executed come back replicated (the psum inside
    ``group_scan`` makes every shard compute identical counts — integer
    addition is exact in any order, hence device-count invariance).
    """
    from jax.experimental.shard_map import shard_map

    def body(planes, tag, op, cond, enabled, cc, ck, wc, wk):
        return ref.group_scan(planes, tag, (op, cond, cc, ck, wc, wk),
                              enabled, axis_name="lanes")

    rep = P()
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "lanes"), P("lanes"), rep, rep, rep, rep, rep,
                  rep, rep),
        out_specs=(P(None, "lanes"), P("lanes"), rep, rep),
        check_rep=False)

    @jax.jit
    def run(planes, tag, op, cond, enabled, cc, ck, wc, wk):
        obs.count("kernels/retrace/ap_megakernel_sharded")
        return mapped(planes, tag, op, cond, enabled, cc, ck, wc, wk)

    return run


def run_group(planes, tag, group: OpGroup, enabled=None, *,
              backend: str = "jnp", mesh=None, block_lanes: int = 512,
              interpret: bool = True):
    """Execute one op group -> (planes', tag', matched int32[P]).

    enabled : optional bool[P] dynamic op mask (default: all on)
    mesh    : optional 1D 'lanes' mesh — shards planes/tag over devices
              (jnp backend only; n_lanes must divide evenly)
    """
    obs.count("kernels/launch/ap_megakernel")
    obs.count(f"kernels/launch/ap_megakernel/{backend}"
              + ("_sharded" if mesh is not None else ""))
    op, cond, cc, ck, wc, wk = (jnp.asarray(t) for t in group.tables())
    if enabled is None:
        enabled = jnp.ones(group.n_ops, jnp.bool_)
    else:
        enabled = jnp.asarray(enabled, jnp.bool_)

    if mesh is not None:
        if backend != "jnp":
            raise ValueError(
                f"sharded megakernel execution requires backend='jnp' "
                f"(got {backend!r})")
        n_lanes = planes.shape[1]
        n_shards = mesh.devices.size
        if n_lanes % n_shards != 0:
            raise ValueError(
                f"n_lanes={n_lanes} not divisible by n_shards={n_shards}; "
                f"pick n_words a multiple of {32 * n_shards}")
        planes, tag, matched, _ = _sharded_runner(mesh)(
            planes, tag, op, cond, enabled, cc, ck, wc, wk)
        return planes, tag, matched
    if backend == "pallas":
        return run_group_kernel(
            planes, tag, op, cond, enabled, cc, ck, wc, wk,
            block_lanes=block_lanes, interpret=interpret,
            conditional=group.conditional)
    if backend != "jnp":
        raise ValueError(f"unknown megakernel backend {backend!r}")
    planes, tag, matched, _ = _run_group_jnp(
        planes, tag, op, cond, enabled, cc, ck, wc, wk)
    return planes, tag, matched


#: aliases for scan-embedded use (workloads/_device.py builds its own
#: jitted programs around the raw executor and the cached sharded
#: runner; re-exported so callers don't import ref/privates directly)
group_scan = ref.group_scan
counter_delta = ref.counter_delta
sharded_group_runner = _sharded_runner
