"""Deterministic synthetic LM data pipeline, host-shardable.

Tokens are a stateless function of (seed, step, global position) via
numpy's Philox counter RNG, so every host can generate exactly its shard of
the global batch without communication, any step can be regenerated after a
restart (fault tolerance!), and runs are bit-reproducible.

The stream is a Zipf-ish unigram mix with in-sequence repetition so a tiny
LM actually has something learnable (pure uniform tokens give a flat loss);
labels are next-token shifted.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    repeat_p: float = 0.3        # P(copy an earlier token) — learnable signal

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def _row(self, row_id: int) -> np.ndarray:
        """One sequence, a pure function of (seed, global row id)."""
        S = self.seq_len
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, 0, row_id]))
        u = rng.random(S + 1)
        toks = np.minimum((self.vocab - 1) * u ** 3, self.vocab - 1
                          ).astype(np.int32)
        rep = rng.random(S + 1) < self.repeat_p
        lag = rng.integers(1, 9, S + 1)
        idx = np.clip(np.arange(S + 1) - lag, 0, None)
        return np.where(rep, toks[idx], toks)

    def batch(self, step: int) -> dict:
        """-> {'tokens': [local_B, S] i32, 'labels': [local_B, S] i32}.

        Row r of the GLOBAL batch is a pure function of
        (seed, step * global_batch + r): every host generates exactly its
        shard, and any batch can be regenerated after a restart.
        """
        B = self.local_batch
        first_row = step * self.global_batch + self.host_index * B
        toks = np.stack([self._row(first_row + i) for i in range(B)])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def microbatched(self, step: int, accum: int) -> dict:
        """-> arrays shaped [accum, local_B // accum, S]."""
        b = self.batch(step)
        B = self.local_batch
        assert B % accum == 0
        return {k: v.reshape(accum, B // accum, self.seq_len)
                for k, v in b.items()}


def make_batch(cfg, B: int, S: int, seed: int = 0, accum: int = 0) -> dict:
    """Convenience: full input dict for an arch (stub modality frontends)."""
    pipe = SyntheticLM(cfg.vocab, S, B, seed=seed)
    batch = pipe.microbatched(0, accum) if accum else pipe.batch(0)
    lead = (accum, B // accum) if accum else (B,)
    rng = np.random.default_rng(seed + 1)
    if cfg.family == "encdec":
        batch["audio_embeds"] = rng.normal(
            size=lead + (cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = rng.normal(
            size=lead + (cfg.n_prefix_embeds, cfg.d_model)).astype(np.float32)
    return batch
