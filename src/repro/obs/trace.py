"""Scoped wall-clock spans exported as Chrome trace-event JSON.

A span measures host wall clock between ``__enter__`` and ``__exit__``
(``time.perf_counter``); completed spans accumulate as Chrome
trace-event "complete" (``ph: "X"``) events — the format Perfetto and
``chrome://tracing`` load directly:

    {"traceEvents": [{"name": ..., "cat": "obs", "ph": "X",
                      "ts": <µs>, "dur": <µs>, "pid": ..., "tid": ...,
                      "args": {...}}, ...],
     "displayTimeUnit": "ms"}

Nesting is positional, per thread: a span opened inside another span's
``with`` block lies within the parent's [ts, ts+dur] window on the same
``tid`` row, which is exactly how the Perfetto timeline stacks them.
Each event also carries its stack ``depth`` in ``args`` so consumers
(and the tests) can check parent/child ordering without reconstructing
the interval containment.

Spans measure *host* time only.  Around jitted JAX calls that is
dispatch + any blocking transfers — the quantity the repo's benches
time everywhere else — NOT device execution time; opening a span
*inside* a traced function would measure trace time once and vanish
from the compiled program, so don't put spans in jit bodies.
"""
from __future__ import annotations

import json
import os
import threading
import time

_JSONABLE = (bool, int, float, str)


def _coerce(v):
    return v if isinstance(v, _JSONABLE) or v is None else str(v)


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        local = self._tracer._local
        self._depth = getattr(local, "depth", 0)
        local.depth = self._depth + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        self._tracer._local.depth = self._depth
        self._tracer._record(self.name, self._t0, t1, self._depth,
                             self.args)


class Tracer:
    """Collects completed spans for one process."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self.epoch = time.perf_counter()    # ts origin (µs = 0)
        self.events: list[dict] = []
        self._on_close = None               # duration hook (obs wires it)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name,
                     {k: _coerce(v) for k, v in args.items()})

    def _record(self, name, t0, t1, depth, args) -> None:
        ev = {
            "name": name,
            "cat": "obs",
            "ph": "X",
            "ts": (t0 - self.epoch) * 1e6,
            "dur": (t1 - t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": dict(args, depth=depth),
        }
        with self._lock:
            self.events.append(ev)
        if self._on_close is not None:
            self._on_close(name, t1 - t0)

    def reset(self) -> None:
        with self._lock:
            self.events.clear()
            self.epoch = time.perf_counter()

    def trace_object(self) -> dict:
        """The full Chrome trace-event JSON object."""
        with self._lock:
            return {"traceEvents": list(self.events),
                    "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.trace_object(), f, indent=1)
            f.write("\n")
        return path
