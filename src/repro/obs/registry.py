"""Process-local metrics registry: counters, gauges, histograms.

Three metric kinds, all host-side Python (never device state):

* :class:`Counter`   — monotone integer totals (events, cache hits,
  jit retraces).
* :class:`Gauge`     — last-write-wins floats (the most recent
  residual, the current queue depth).
* :class:`Histogram` — raw float samples summarized at snapshot time
  with count/mean/min/max and p50/p95/p99 (linear-interpolation
  percentiles, matching ``np.percentile``'s default).

Names are flat strings; the repo's convention is a ``/``-separated
hierarchy with an optional ``[...]`` label suffix for per-bucket
variants (``engine/retrace/run_schedule[P=8,Kc=4,Kw=1]``).  The
registry itself carries no enabled/disabled logic — the front-end
(:mod:`repro.obs`) guards every write so the disabled mode is a strict
no-op and never touches these structures.
"""
from __future__ import annotations

import math
import threading

#: hard cap on retained histogram samples; beyond it, new samples
#: overwrite a deterministic striding reservoir so percentile summaries
#: stay meaningful while memory stays bounded
MAX_SAMPLES = 1 << 17


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


def percentile(sorted_vals: list[float], q: float) -> float:
    """q-th percentile of pre-sorted values, linear interpolation
    (``np.percentile`` default: index = q/100 * (n-1))."""
    n = len(sorted_vals)
    if n == 0:
        return math.nan
    pos = q / 100.0 * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class Histogram:
    __slots__ = ("samples", "n_total")

    def __init__(self) -> None:
        self.samples: list[float] = []
        self.n_total = 0          # includes samples evicted past the cap

    def observe(self, v: float) -> None:
        v = float(v)
        if len(self.samples) < MAX_SAMPLES:
            self.samples.append(v)
        else:                     # deterministic striding overwrite
            self.samples[self.n_total % MAX_SAMPLES] = v
        self.n_total += 1

    def extend(self, vs) -> None:
        for v in vs:
            self.observe(v)

    def summary(self) -> dict:
        s = sorted(self.samples)
        if not s:
            return {"count": 0}
        return {
            "count": self.n_total,
            "mean": sum(s) / len(s),
            "min": s[0],
            "max": s[-1],
            "p50": percentile(s, 50.0),
            "p95": percentile(s, 95.0),
            "p99": percentile(s, 99.0),
        }


class Registry:
    """One process-local metric namespace (the singleton lives in
    :mod:`repro.obs`; tests may instantiate their own)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def _get(self, table: dict, name: str, cls):
        m = table.get(name)
        if m is None:
            with self._lock:
                m = table.setdefault(name, cls())
        return m

    def counter(self, name: str) -> Counter:
        return self._get(self.counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self.gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self.histograms, name, Histogram)

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()

    def snapshot(self) -> dict:
        """JSON-serializable state: ``{"counters": {...}, "gauges":
        {...}, "histograms": {name: {count, mean, min, max, p50, p95,
        p99}}}`` (sorted keys for diffable artifacts)."""
        return {
            "counters": {k: self.counters[k].value
                         for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k].value
                       for k in sorted(self.gauges)},
            "histograms": {k: self.histograms[k].summary()
                           for k in sorted(self.histograms)},
        }
