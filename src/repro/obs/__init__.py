"""``repro.obs`` — the repo-wide observability layer.

One process-local metrics registry (counters / gauges / histograms
with p50/p95/p99 summaries, :mod:`repro.obs.registry`) plus scoped
wall-clock spans exported as Chrome trace-event JSON loadable in
Perfetto (:mod:`repro.obs.trace`).  Everything funnels through this
module's functions so call sites stay one line::

    from repro import obs

    obs.count("sweep/cache/hit")
    obs.observe("serving/request_latency_s", 0.132)
    with obs.span("sweep/replay", cases=24):
        ...

**Disabled mode is a strict no-op**: when :func:`is_enabled` is False
(the default; enable with ``REPRO_OBS=1`` or :func:`enable`), every
recording function returns immediately without touching the registry,
and :func:`span` hands back a shared null context manager — no
allocation, no clock read.  The benchmark drivers enable obs
(``benchmarks/_record.Recorder`` does it on construction) and gate the
enabled-vs-disabled overhead at ≤ 1.05× in ``baseline.json``.

**jit-safety rules** (docs/observability.md):

* :func:`count` may be called inside a jitted function — it then runs
  at *trace time* only, which is exactly how the retrace counters work
  (``engine/retrace/*``: one increment per compiled shape bucket).
* :func:`observe`/:func:`gauge` take host numbers; forcing a device
  value with ``float(x)`` blocks, so do it where the value is already
  being synced.
* :func:`span` must never wrap code *inside* a traced function (it
  would time tracing once and vanish from the compiled program); around
  jitted calls it measures host wall clock — dispatch plus blocking
  transfers — like every bench in this repo.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

from repro.obs.registry import Registry
from repro.obs.trace import Tracer

__all__ = [
    "enable", "disable", "is_enabled", "scoped", "reset",
    "count", "value", "values_by_prefix", "gauge", "observe",
    "observe_many",
    "span", "snapshot", "trace_events", "write_trace",
]

_registry = Registry()
_tracer = Tracer()
_tracer._on_close = lambda name, dur_s: \
    _registry.histogram(f"span/{name}").observe(dur_s)

_enabled = os.environ.get("REPRO_OBS", "").lower() in ("1", "true",
                                                       "yes", "on")


# ---------------------------------------------------------------- control

def is_enabled() -> bool:
    return _enabled


def enable(reset: bool = False) -> None:
    """Turn collection on (optionally wiping prior metrics/spans)."""
    global _enabled
    if reset:
        globals()["reset"]()
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


@contextmanager
def scoped(on: bool = True):
    """Temporarily force the enabled state (tests / A-B timing)."""
    global _enabled
    prev = _enabled
    _enabled = bool(on)
    try:
        yield
    finally:
        _enabled = prev


def reset() -> None:
    """Wipe all metrics and spans (the trace clock restarts at 0)."""
    _registry.reset()
    _tracer.reset()


# ---------------------------------------------------------------- metrics

def count(name: str, n: int = 1) -> None:
    """Add ``n`` to a counter.  Safe inside jit: runs at trace time."""
    if _enabled:
        _registry.counter(name).inc(n)


def value(name: str) -> int:
    """Current value of a counter (0 if it never fired)."""
    c = _registry.counters.get(name)
    return 0 if c is None else c.value


def values_by_prefix(prefix: str) -> dict[str, int]:
    """All counters under a name prefix, e.g. ``policy/dvfs-22nm/`` —
    how the policy bench collects per-operating-point residency without
    knowing a table's labels up front (docs/observability.md)."""
    return {name: c.value for name, c in sorted(_registry.counters.items())
            if name.startswith(prefix)}


def gauge(name: str, v: float) -> None:
    """Set a last-write-wins gauge."""
    if _enabled:
        _registry.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    """Add one sample to a histogram."""
    if _enabled:
        _registry.histogram(name).observe(v)


def observe_many(name: str, vs) -> None:
    """Add a batch of samples (any iterable of numbers) to a histogram."""
    if _enabled:
        _registry.histogram(name).extend(vs)


# ---------------------------------------------------------------- spans

class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


def span(name: str, **args):
    """Scoped wall-clock span.  Nested spans stack per thread; each
    completed span becomes a Chrome trace event AND feeds the
    ``span/<name>`` duration histogram (so p50/p95/p99 of any span
    show up in :func:`snapshot`).  Extra keyword arguments land in the
    event's ``args``."""
    if not _enabled:
        return _NULL_SPAN
    return _tracer.span(name, **args)


# ---------------------------------------------------------------- export

def snapshot() -> dict:
    """JSON-serializable registry state (see
    :meth:`repro.obs.registry.Registry.snapshot`)."""
    return _registry.snapshot()


def trace_events() -> dict:
    """The Chrome trace-event JSON object for all completed spans."""
    return _tracer.trace_object()


def write_trace(path: str) -> str:
    """Write the span trace to ``path`` (open it in
    https://ui.perfetto.dev or ``chrome://tracing``)."""
    return _tracer.write(path)
