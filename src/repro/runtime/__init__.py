from repro.runtime.trainer import TrainerConfig, train_loop  # noqa: F401
