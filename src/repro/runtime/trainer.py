"""Fault-tolerant training loop: checkpoint/restart, straggler monitor,
metric logging.

Restart contract: the loop always begins at ``latest_step + 1`` (the data
pipeline regenerates any batch deterministically from the step index), so a
killed job resumes exactly — tests kill a subprocess mid-run and verify the
loss trajectory is identical to an uninterrupted run.

Straggler mitigation (single-host simulation of the fleet policy): per-step
wall time feeds an EWMA; a step exceeding ``straggler_factor`` x EWMA is
counted and logged — on a real fleet this signal triggers the re-issue /
hot-spare path; here it drives the same bookkeeping and tests inject
artificial delays to exercise it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "ckpt"
    keep: int = 3
    log_path: Optional[str] = None
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.2
    ewma: float = 0.0
    n: int = 0
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        is_straggler = self.n > 3 and dt > self.factor * self.ewma
        self.ewma = dt if self.n == 0 else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        self.n += 1
        if is_straggler:
            self.stragglers += 1
        return is_straggler


def train_loop(train_step: Callable, params: Any, opt: Any,
               pipe: SyntheticLM, tcfg: TrainerConfig,
               accum: int = 1, extras_fn: Optional[Callable] = None,
               hook: Optional[Callable] = None) -> dict:
    """Run (or resume) training; returns final state + history."""
    mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
    mon = StragglerMonitor(tcfg.straggler_factor, tcfg.ewma_alpha)
    if tcfg.log_path:
        pathlib.Path(tcfg.log_path).parent.mkdir(parents=True, exist_ok=True)
    log_f = open(tcfg.log_path, "a") if tcfg.log_path else None

    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        state = mgr.restore(latest, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = latest + 1

    history = []
    for step in range(start, tcfg.steps):
        batch = pipe.microbatched(step, accum) if accum > 1 \
            else {k: v[None] for k, v in pipe.batch(step).items()}
        if extras_fn is not None:
            batch.update(extras_fn(step))
        t0 = time.time()
        params, opt, metrics = train_step(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        straggle = mon.observe(dt)
        rec = {"step": step, "loss": loss, "dt_s": round(dt, 4),
               "straggler": straggle,
               "grad_norm": float(metrics.get("grad_norm", np.nan))}
        history.append(rec)
        if log_f:
            log_f.write(json.dumps(rec) + "\n")
            log_f.flush()
        if hook is not None:
            hook(step, params, opt, rec)
        if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.steps:
            mgr.save(step, {"params": params, "opt": opt},
                     extra={"loss": loss})
    mgr.wait()
    if log_f:
        log_f.close()
    return {"params": params, "opt": opt, "history": history,
            "stragglers": mon.stragglers, "final_step": tcfg.steps - 1}
