from repro.parallel.sharding import (cache_specs, make_sharder,  # noqa: F401
                                     param_specs)
