"""Parameter / cache PartitionSpec rules (DP+FSDP over 'data', TP/EP/SP over
'model', 'pod' extending the data axis multi-pod).

The scheme is Megatron-style 2D:

  column-parallel in-projections  [d, out]   -> P(data, model)
  row-parallel out-projections    [out, d]   -> P(model, data)
  experts                         [E, d, f]  -> P(model, data, None)  (EP)
  embeddings                      [V, d]     -> P(model, data)
  norms / scalars                            -> replicated

FSDP: the 'data' entry on the *other* matrix axis shards params and
optimizer state ZeRO-3 style; XLA all-gathers them per-layer inside the
scan (which pipelines with compute).  KV caches shard batch over 'data' and
SEQUENCE over 'model' (flash-decoding layout; see attention.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import Sharder

STACK_KEYS = ("layers", "enc_layers", "dense_layers")


def make_sharder(mesh, multi_pod: bool = False) -> Sharder:
    data_axes = ("pod", "data") if multi_pod else "data"
    return Sharder(mesh=mesh, data_axes=data_axes, model_axes="model")


def _rule(path_keys: list[str], ndim: int, data) -> P:
    """PartitionSpec for one param, BEFORE the stacked-layer prefix."""
    name = path_keys[-1]
    in_experts = "experts" in path_keys

    if in_experts:                       # [E, d, f] / [E, f, d]
        if name in ("w_gate", "w_up"):
            return P("model", data, None)
        if name == "w_down":
            return P("model", None, data)
    col = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj_x", "in_proj_z",
           "wq_b", "wkv_b", "dt_proj"}
    row = {"wo", "w_down", "out_proj"}
    if name == "embed":
        return P("model", data)
    if name == "lm_head":
        return P(data, "model")
    if name in col:
        return P(data, "model") if ndim == 2 else P("model")
    if name in row:
        return P("model", data)
    if name in ("bq", "bk", "bv", "b_up", "conv_b", "norm_w"):
        return P("model")
    if name == "conv_w":                 # [K, din]
        return P(None, "model")
    if name in ("x_proj", "A_log"):      # [din, *]
        return P("model", None)
    if name == "D" and ndim == 1:
        return P("model")
    if name == "dt_bias":
        return P("model")
    if name in ("router", "wq_a", "wkv_a", "in_proj_bc", "in_proj_dt"):
        return P(data, None)
    # norms, small vectors, scalars -> replicated
    return P(*([None] * ndim))


def param_specs(cfg: ArchConfig, params_shape: Any, multi_pod: bool = False
                ) -> Any:
    """PartitionSpec pytree matching a params pytree (arrays or SDS)."""
    data = ("pod", "data") if multi_pod else "data"

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        stacked = any(k in STACK_KEYS for k in keys)
        ndim = len(leaf.shape)
        base_ndim = ndim - 1 if stacked else ndim
        spec = _rule(keys, base_ndim, data)
        # mamba2 dt_bias/A_log/D are [H] per-head (small): replicate
        if keys[-1] in ("dt_bias", "A_log", "D") and cfg.ssm is not None \
                and cfg.ssm.version == 2:
            spec = P(*([None] * base_ndim))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cfg: ArchConfig, cache_shape: Any, multi_pod: bool = False
                ) -> Any:
    """PartitionSpecs for serve caches (stacked layer axis leading)."""
    data = ("pod", "data") if multi_pod else "data"

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        ndim = len(leaf.shape)
        if name in ("k", "v", "k_q", "v_q"):   # [L, B, W, hkv, dh]
            return P(None, data, "model", None, None)
        if name in ("k_s", "v_s"):       # [L, B, W, hkv] quant scales
            return P(None, data, "model", None)
        if name in ("cross_k", "cross_v"):  # [L, B, F, hkv, dh]
            return P(None, data, None, "model", None)
        if name in ("c_kv", "k_rope"):   # [L, B, S, lora]
            return P(None, data, "model", None)
        if name == "conv":               # [L, B, K-1, din]
            return P(None, data, None, "model")
        if name == "h":                  # [L, B, din, N]
            return P(None, data, "model", None)
        if name in ("slot_pos", "len", "step"):
            return P(*([None] * ndim))
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def to_named(mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
