"""Parameter / cache PartitionSpec rules (DP+FSDP over 'data', TP/EP/SP over
'model', 'pod' extending the data axis multi-pod) — plus the sweep-case
batch sharding used by ``repro.sweep.engine`` (bottom of file).

The scheme is Megatron-style 2D:

  column-parallel in-projections  [d, out]   -> P(data, model)
  row-parallel out-projections    [out, d]   -> P(model, data)
  experts                         [E, d, f]  -> P(model, data, None)  (EP)
  embeddings                      [V, d]     -> P(model, data)
  norms / scalars                            -> replicated

FSDP: the 'data' entry on the *other* matrix axis shards params and
optimizer state ZeRO-3 style; XLA all-gathers them per-layer inside the
scan (which pipelines with compute).  KV caches shard batch over 'data' and
SEQUENCE over 'model' (flash-decoding layout; see attention.py).
"""
from __future__ import annotations

import functools as _functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import Sharder

STACK_KEYS = ("layers", "enc_layers", "dense_layers")


def make_sharder(mesh, multi_pod: bool = False) -> Sharder:
    data_axes = ("pod", "data") if multi_pod else "data"
    return Sharder(mesh=mesh, data_axes=data_axes, model_axes="model")


def _rule(path_keys: list[str], ndim: int, data) -> P:
    """PartitionSpec for one param, BEFORE the stacked-layer prefix."""
    name = path_keys[-1]
    in_experts = "experts" in path_keys

    if in_experts:                       # [E, d, f] / [E, f, d]
        if name in ("w_gate", "w_up"):
            return P("model", data, None)
        if name == "w_down":
            return P("model", None, data)
    col = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj_x", "in_proj_z",
           "wq_b", "wkv_b", "dt_proj"}
    row = {"wo", "w_down", "out_proj"}
    if name == "embed":
        return P("model", data)
    if name == "lm_head":
        return P(data, "model")
    if name in col:
        return P(data, "model") if ndim == 2 else P("model")
    if name in row:
        return P("model", data)
    if name in ("bq", "bk", "bv", "b_up", "conv_b", "norm_w"):
        return P("model")
    if name == "conv_w":                 # [K, din]
        return P(None, "model")
    if name in ("x_proj", "A_log"):      # [din, *]
        return P("model", None)
    if name == "D" and ndim == 1:
        return P("model")
    if name == "dt_bias":
        return P("model")
    if name in ("router", "wq_a", "wkv_a", "in_proj_bc", "in_proj_dt"):
        return P(data, None)
    # norms, small vectors, scalars -> replicated
    return P(*([None] * ndim))


def param_specs(cfg: ArchConfig, params_shape: Any, multi_pod: bool = False
                ) -> Any:
    """PartitionSpec pytree matching a params pytree (arrays or SDS)."""
    data = ("pod", "data") if multi_pod else "data"

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        stacked = any(k in STACK_KEYS for k in keys)
        ndim = len(leaf.shape)
        base_ndim = ndim - 1 if stacked else ndim
        spec = _rule(keys, base_ndim, data)
        # mamba2 dt_bias/A_log/D are [H] per-head (small): replicate
        if keys[-1] in ("dt_bias", "A_log", "D") and cfg.ssm is not None \
                and cfg.ssm.version == 2:
            spec = P(*([None] * base_ndim))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cfg: ArchConfig, cache_shape: Any, multi_pod: bool = False
                ) -> Any:
    """PartitionSpecs for serve caches (stacked layer axis leading)."""
    data = ("pod", "data") if multi_pod else "data"

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        ndim = len(leaf.shape)
        if name in ("k", "v", "k_q", "v_q"):   # [L, B, W, hkv, dh]
            return P(None, data, "model", None, None)
        if name in ("k_s", "v_s"):       # [L, B, W, hkv] quant scales
            return P(None, data, "model", None)
        if name in ("cross_k", "cross_v"):  # [L, B, F, hkv, dh]
            return P(None, data, None, "model", None)
        if name in ("c_kv", "k_rope"):   # [L, B, S, lora]
            return P(None, data, "model", None)
        if name == "conv":               # [L, B, K-1, din]
            return P(None, data, None, "model")
        if name == "h":                  # [L, B, din, N]
            return P(None, data, "model", None)
        if name in ("slot_pos", "len", "step"):
            return P(*([None] * ndim))
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def to_named(mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# sweep-case batch sharding (the repro.sweep execution mode)
# ---------------------------------------------------------------------------
#
# A sweep batch is embarrassingly parallel over its leading (case) axis:
# every case is an independent closed-loop replay.  ``shard_case_batch``
# wraps the vmapped replay in a ``shard_map`` over a 1D 'cases' mesh, so
# each device runs the identical per-case program on its slice — results
# are bitwise what the unsharded vmap produces, which is what keeps the
# content-hashed sweep cache device-count-invariant
# (tests/test_shard_sweep.py pins 1 shard vs N shards bit-equal).

def sweep_mesh(n_shards: int | None = None):
    """A 1D mesh of ``n_shards`` local devices over axis 'cases'.

    ``None`` uses every local device.  Raises if more shards are
    requested than devices exist (sharding is an execution detail; it
    must never silently change what runs).
    """
    import numpy as np
    from jax.sharding import Mesh
    devices = jax.devices()
    n = len(devices) if n_shards is None else n_shards
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"n_shards={n} out of range for {len(devices)} local "
            f"device(s)")
    return Mesh(np.asarray(devices[:n]), ("cases",))


@_functools.lru_cache(maxsize=None)
def ap_mesh(n_shards: int | None = None):
    """A 1D mesh of ``n_shards`` local devices over axis 'lanes' — the
    AP bitplane sharding axis (megakernel backend): plane columns and
    the TAG register split over the packed word-lane axis, responder
    popcounts ``psum`` back to every shard.

    Cached so repeated lookups return the *same* Mesh object and the
    jitted sharded runners (``kernels.ap_megakernel.ops``) are reused.
    Validation matches :func:`sweep_mesh`: over-subscription raises.
    """
    import numpy as np
    from jax.sharding import Mesh
    devices = jax.devices()
    n = len(devices) if n_shards is None else n_shards
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"n_shards={n} out of range for {len(devices)} local "
            f"device(s)")
    return Mesh(np.asarray(devices[:n]), ("lanes",))


def pad_case_batch(batch: Any, n_shards: int) -> tuple[Any, int]:
    """Pad every leaf's leading axis to a multiple of ``n_shards`` by
    repeating the last case (dropped again by :func:`unpad_case_batch`).
    Returns ``(padded_batch, original_count)``."""
    counts = {leaf.shape[0] for leaf in jax.tree_util.tree_leaves(batch)}
    if len(counts) != 1:
        raise ValueError(f"inconsistent case counts {sorted(counts)}")
    (n,) = counts
    pad = (-n) % n_shards
    if pad == 0:
        return batch, n
    padded = jax.tree_util.tree_map(
        lambda x: jnp.concatenate([x] + [x[-1:]] * pad, axis=0), batch)
    return padded, n


def unpad_case_batch(out: Any, n: int) -> Any:
    """Drop the padding rows added by :func:`pad_case_batch`."""
    return jax.tree_util.tree_map(lambda x: x[:n], out)


def shard_case_batch(fn, mesh):
    """``shard_map`` a batched-pytree function over the 'cases' axis.

    ``fn`` must take ONE pytree whose leaves all carry the case axis
    first, and return a pytree of case-major outputs; the leading axis
    must already be a multiple of the mesh size (:func:`pad_case_batch`).
    """
    from jax.experimental.shard_map import shard_map
    spec = P("cases")
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
