"""Per-request LLM inference cost from the roofline machinery.

Bridges the repo's two halves: the analytic LM cost model
(``launch/roofline.py`` — parameter counts via cheap ``jax.eval_shape``,
MoE active-parameter discounts, the 2·N flop/token serving rule) and the
paper's AP machine model (``core/models.py``).  For one ``configs/``
entry and a request shape it produces

* per-request prefill/decode FLOPs and the per-decode-step byte
  traffic (active-parameter stream + per-sequence KV/state reads, the
  ``models/serve.py`` batching semantics: one parameter read per step is
  amortized over the whole decode batch);
* the decode arithmetic intensity AI(B) [flop/word] as a function of
  batch size — batching raises AI because the parameter stream is
  shared;
* a :class:`~repro.core.models.Workload` minted from that AI by the
  same inverse-AI anchoring the suite workloads use
  (``models.derived_workload``), which gives the serving scenario its
  same-performance AP/SIMD design pair and DRAM-traffic figure.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core import models as M

BYTES_PER_PARAM = 2.0          # bf16 serving weights (launch/steps.py dtype)
KV_BYTES_PER_EL = 2.0          # bf16 KV cache entries


@dataclasses.dataclass(frozen=True)
class RequestShape:
    """One request class: prompt length in, generated tokens out."""
    prompt_tokens: int = 1024
    output_tokens: int = 128

    def __post_init__(self):
        if self.prompt_tokens < 1 or self.output_tokens < 1:
            raise ValueError("prompt/output tokens must be >= 1")


def kv_bytes_per_token(cfg) -> float:
    """Per-token KV-cache footprint in bytes (what each decode step
    re-reads per sequence per context token).

    MLA configs cache the compressed latent (kv_lora + rope dims);
    attention-free SSM blocks keep O(1) state per sequence, so their
    per-context-token cost is 0; hybrids pay only for the shared
    attention blocks (one per ``attn_every`` layers).
    """
    if cfg.family == "ssm":
        return 0.0
    if cfg.mla is not None:
        per_layer = cfg.mla.kv_lora + cfg.mla.qk_rope
    else:
        per_layer = 2 * cfg.n_kv_heads * cfg.head_dim
    if cfg.family == "hybrid":
        n_attn = max(cfg.n_layers // max(cfg.attn_every, 1), 1)
    else:
        n_attn = cfg.n_layers
    return float(n_attn * per_layer * KV_BYTES_PER_EL)


@dataclasses.dataclass(frozen=True)
class ModelServingCost:
    """Analytic serving cost of one config for one request shape."""
    config: str
    request: RequestShape
    n_params: float             # total parameters
    n_active: float             # active per token (MoE top-k discount)
    kv_bytes_tok: float         # KV bytes per context token per sequence

    # ------------------------------------------------------------- flops
    @property
    def prefill_flops(self) -> float:
        """2·N_active per prompt token (launch/roofline.py serving rule)."""
        return 2.0 * self.n_active * self.request.prompt_tokens

    @property
    def decode_flops_per_token(self) -> float:
        return 2.0 * self.n_active

    @property
    def request_flops(self) -> float:
        """Total useful FLOPs to serve one request end to end."""
        return self.prefill_flops \
            + self.decode_flops_per_token * self.request.output_tokens

    # ------------------------------------------------------------- bytes
    @property
    def param_bytes(self) -> float:
        """Weight stream of one decode step (active parameters, read once
        per step regardless of batch — the batching amortization)."""
        return BYTES_PER_PARAM * self.n_active

    @property
    def mean_context(self) -> float:
        """Average live context length during decode."""
        return self.request.prompt_tokens + self.request.output_tokens / 2.0

    def decode_step_bytes(self, batch: int) -> float:
        """DRAM bytes of one decode step at batch size B: one shared
        parameter read + per-sequence KV/state reads."""
        if batch < 1:
            raise ValueError("batch must be >= 1")
        return self.param_bytes \
            + batch * self.kv_bytes_tok * self.mean_context

    def decode_ai(self, batch: int) -> float:
        """Decode arithmetic intensity at batch B [flop/word] — rises
        with B while the shared parameter read dominates, then saturates
        at the KV-bound ceiling."""
        flops = self.decode_flops_per_token * batch
        words = self.decode_step_bytes(batch) / M.BYTES_PER_WORD
        return flops / words

    # ---------------------------------------------------------- machines
    def workload(self, batch: int) -> M.Workload:
        """The serving Workload at batch B: inverse-AI anchoring off the
        DMM calibration (decode is MAC-dominated, so the per-PU speedup
        keeps the DMM value)."""
        return M.derived_workload(f"serve:{self.config}",
                                  self.decode_ai(batch))

    def traffic_bytes_per_s(self, batch: int, n_ap_pus: int) -> float:
        """Demand DRAM traffic at full utilization for the AP sized to
        ``n_ap_pus`` (shared by the same-performance SIMD pair)."""
        return M.traffic_bytes_per_s(self.decode_ai(batch), n_ap_pus)


@functools.lru_cache(maxsize=None)
def _params(config: str) -> tuple[float, float]:
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import roofline as RF
    from repro.launch.steps import params_sds

    cfg = get_config(config)
    psds = params_sds(cfg, jnp.bfloat16)      # eval_shape only, no compile
    return RF.count_params(psds), RF.count_active_params(cfg, psds)


def serving_cost(config: str,
                 request: RequestShape = RequestShape()) -> ModelServingCost:
    """Build the analytic serving cost for one registered config."""
    from repro.configs import get_config
    n_total, n_active = _params(config)
    return ModelServingCost(
        config=config, request=request, n_params=float(n_total),
        n_active=float(n_active),
        kv_bytes_tok=kv_bytes_per_token(get_config(config)))


__all__ = ["RequestShape", "ModelServingCost", "serving_cost",
           "kv_bytes_per_token", "BYTES_PER_PARAM", "KV_BYTES_PER_EL"]
