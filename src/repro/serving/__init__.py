"""LLM-serving traffic → power → thermal interval co-simulation.

Turns per-request inference cost of the assigned ``configs/`` models
(``serving.cost``, built on ``launch/roofline.py``) and a request-trace
shape (``serving.traffic``) into per-interval stack power, replayed
through the ``stack/feedback`` closed loop with adaptive interval
coarsening (``serving.sim``; docs/serving.md walks the pipeline).
"""
from repro.serving.cost import (ModelServingCost, RequestShape,
                                kv_bytes_per_token, serving_cost)
from repro.serving.sim import (QueueResult, ServingReport, ServingScenario,
                               fluid_queue, run_serving_cosim,
                               verdict_table)
from repro.serving.traffic import SHAPES, TrafficSpec

__all__ = [
    "ModelServingCost", "RequestShape", "kv_bytes_per_token",
    "serving_cost", "QueueResult", "ServingReport", "ServingScenario",
    "fluid_queue", "run_serving_cosim", "verdict_table", "SHAPES",
    "TrafficSpec",
]
