"""Request-trace generation for the serving co-simulation.

A :class:`TrafficSpec` names a traffic *shape* (constant QPS, diurnal
sinusoid, or bursty two-state MMPP), a mean rate, and a base interval
grid; :meth:`TrafficSpec.arrivals` lowers it to a deterministic
per-interval request-count array (seeded ``numpy`` generator, so the
same spec always replays the same trace — the property every cached
artifact and baseline-gated bench metric relies on).

The diurnal period defaults to the horizon, i.e. ONE full day-cycle is
time-compressed onto the simulated window — the same dilation
convention the trace replay itself uses (README §co-simulation): the
shape supplies the load profile, the horizon supplies the wall time.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

SHAPES = ("constant", "diurnal", "bursty")


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """One request-arrival scenario on a uniform base-interval grid.

    ``mean_qps <= 0`` means "auto": the serving scenario scales the rate
    to a target fraction of machine saturation
    (:class:`repro.serving.sim.ServingScenario.load`).
    """
    shape: str = "diurnal"
    mean_qps: float = 0.0       # <= 0 -> scenario-scaled (load fraction)
    horizon_s: float = 3600.0
    interval_s: float = 1.0
    seed: int = 0
    # diurnal knobs
    period_s: float = 0.0       # <= 0 -> one full cycle over the horizon
    swing: float = 0.8          # peak-to-mean modulation depth in [0, 1]
    # bursty (two-state Markov-modulated Poisson) knobs
    burst_ratio: float = 4.0    # burst-state rate / quiet-state rate
    p_enter: float = 0.02       # per-interval P(quiet -> burst)
    p_exit: float = 0.10        # per-interval P(burst -> quiet)

    def __post_init__(self):
        if self.shape not in SHAPES:
            raise ValueError(f"unknown traffic shape {self.shape!r}; "
                             f"expected one of {SHAPES}")
        # every check below is phrased so NaN FAILS it: `nan <= 0` and
        # `nan < 1` are False, so the naive comparisons would silently
        # accept NaN knobs and lower them into NaN rate paths
        if not (math.isfinite(self.horizon_s) and self.horizon_s > 0
                and math.isfinite(self.interval_s)
                and self.interval_s > 0):
            raise ValueError(
                "horizon_s and interval_s must be finite and > 0; got "
                f"({self.horizon_s!r}, {self.interval_s!r})")
        if self.interval_s > self.horizon_s:
            raise ValueError("interval_s must not exceed horizon_s")
        if not math.isfinite(self.mean_qps):
            raise ValueError("mean_qps must be finite (<= 0 means "
                             f"scenario-scaled); got {self.mean_qps!r}")
        if not math.isfinite(self.period_s):
            raise ValueError("period_s must be finite (<= 0 means one "
                             f"cycle per horizon); got {self.period_s!r}")
        if not 0.0 <= self.swing <= 1.0:
            raise ValueError(f"swing must be in [0, 1]; got {self.swing!r}")
        if not (math.isfinite(self.burst_ratio)
                and self.burst_ratio >= 1.0):
            raise ValueError("burst_ratio must be finite and >= 1; got "
                             f"{self.burst_ratio!r}")
        if not (0.0 < self.p_enter <= 1.0 and 0.0 < self.p_exit <= 1.0):
            raise ValueError("p_enter/p_exit must be in (0, 1]")

    @property
    def n_intervals(self) -> int:
        return max(int(round(self.horizon_s / self.interval_s)), 1)

    @property
    def label(self) -> str:
        return f"{self.shape}@{self.mean_qps:g}qps/{self.horizon_s:g}s"

    # ------------------------------------------------------------- lowering
    def rate_qps(self, mean_qps: float | None = None) -> np.ndarray:
        """[T] per-interval Poisson rate.  Deterministic for constant and
        diurnal shapes; for bursty the seeded two-state Markov chain's
        realized rate path (mean-preserving in expectation)."""
        mean = self.mean_qps if mean_qps is None else mean_qps
        # `not (mean > 0)` rather than `mean <= 0`: NaN must raise too
        if not (math.isfinite(mean) and mean > 0):
            raise ValueError("mean_qps must be resolved (finite, > 0) "
                             "before lowering; pass one or set it on "
                             f"the spec; got {mean!r}")
        T = self.n_intervals
        if self.shape == "constant":
            return np.full(T, mean)
        if self.shape == "diurnal":
            period = self.period_s if self.period_s > 0 else self.horizon_s
            t = (np.arange(T) + 0.5) * self.interval_s
            # trough at t=0, peak mid-cycle; mean over a full period = mean
            return mean * (1.0 + self.swing
                           * np.sin(2 * math.pi * t / period - math.pi / 2))
        # bursty: two-state MMPP; stationary split fixes the state rates so
        # the long-run mean is `mean`:  mean = r_lo (pi_lo + ratio pi_hi)
        rng = np.random.default_rng(self.seed)
        pi_hi = self.p_enter / (self.p_enter + self.p_exit)
        r_lo = mean / ((1.0 - pi_hi) + self.burst_ratio * pi_hi)
        state = rng.random() < pi_hi          # start from stationarity
        rates = np.empty(T)
        flips = rng.random(T)
        for t in range(T):
            rates[t] = r_lo * (self.burst_ratio if state else 1.0)
            state = (flips[t] < self.p_enter) if not state \
                else (flips[t] >= self.p_exit)
        return rates

    def arrivals(self, mean_qps: float | None = None) -> np.ndarray:
        """[T] integer request arrivals: Poisson counts at the shape's
        rate path, from the spec's seeded generator."""
        rates = self.rate_qps(mean_qps)
        rng = np.random.default_rng(self.seed + 1)
        return rng.poisson(rates * self.interval_s).astype(np.int64)


__all__ = ["TrafficSpec", "SHAPES"]
