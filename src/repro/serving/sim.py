"""Traffic → power → thermal interval co-simulation (the tentpole).

The CoMeT loop (arXiv 2109.12405) at serving granularity: a fluid FIFO
queue turns the request trace into per-interval machine utilization and
decode-batch state; the interval lowering turns that into logic power
and DRAM activate traffic for the 3D stack; the closed-loop replay
(``stack/feedback``) integrates the thermal network with refresh,
leakage, and DTM feedback; and the DTM throttle flows BACK into the
queue's capacity for the next macro-round.  Two or three rounds
suffice — the throttle→capacity coupling is weak at interval
granularity — and the recorded ``throttle_residual`` certifies it.

Double-counting guard: the replay itself multiplies dynamic power by
its throttle f, so the frames fed to it carry the *busy fraction*
``d = served / (f_prev · C · dt)`` (power demanded if unthrottled).  At
the fixed point ``f = f_prev`` the applied power is ``f · d = served /
(C · dt)`` — exactly the machine's true utilization.

Multi-hour horizons stay cheap through adaptive interval coarsening
(``cosim.coarsen_plan``): base intervals merge while the utilization
and traffic signals move less than ``coarsen_tol``, and the replay runs
the merged variable-dt schedule (``dt_scale``).  The temperature error
this introduces is bounded by ``coarsen_tol`` × the stack's DC thermal
gain (``cosim.dc_peak_rise_C``; property-tested in
tests/test_coarsen_replay.py) and reported per scenario.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import cosim
from repro.core import models as M
from repro.core import thermal
from repro.core.constants import DRAM_LIMIT_C
from repro.core.floorplan import MM, APFloorplan, SIMDFloorplan
from repro.serving.cost import ModelServingCost, RequestShape, serving_cost
from repro.serving.traffic import TrafficSpec
from repro.stack import dram, feedback
from repro.stack.spec import PAPER_STACK, StackParams, dram_on_logic


@dataclasses.dataclass(frozen=True)
class ServingScenario:
    """One serving co-simulation case (per machine)."""
    config: str
    traffic: TrafficSpec
    request: RequestShape = RequestShape()
    load: float = 0.7           # offered load as a fraction of saturation
    # (used when traffic.mean_qps <= 0: mean_qps = load * C / W_request)
    max_batch: int = 32         # decode batch cap (models/serve.py batching)
    n_dram: int = 2
    grid_n: int = 8
    coarsen_tol: float = 0.02   # activity units (busy fraction is in [0,1])
    max_merge: int = 64
    pad_quantum: int = 64       # coarse plans pad up to a multiple of this
    # so scenarios share jitted replay programs (CoarsePlan.pad_to)
    n_rounds: int = 2           # throttle<->queue macro-iterations
    steps_per_interval: int = 1
    n_cg: int = 25
    theta: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.load:
            raise ValueError("load must be > 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.n_rounds < 1:
            raise ValueError("n_rounds must be >= 1")
        if self.coarsen_tol < 0:
            raise ValueError("coarsen_tol must be >= 0")

    @property
    def label(self) -> str:
        return f"{self.config}/{self.traffic.shape}"


# ---------------------------------------------------------------------------
# fluid FIFO queue with continuous decode batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueueResult:
    """Per-interval queue state of one round."""
    served_flops: np.ndarray    # [T] work served per interval
    busy: np.ndarray            # [T] busy fraction of *available* capacity
    batch: np.ndarray           # [T] decode batch size in effect
    backlog_flops: np.ndarray   # [T] work in system at interval END
    latency_s: np.ndarray       # per-request end-to-end latency [n_requests]


def fluid_queue(arrivals: np.ndarray, cost: ModelServingCost,
                cap_flops_per_s: float, throttle: np.ndarray,
                interval_s: float, max_batch: int) -> QueueResult:
    """FIFO fluid queue at interval granularity.

    Work is measured in FLOPs (``cost.request_flops`` per request).
    Interval t offers capacity ``throttle[t] * cap * dt``; the batch in
    effect is the number of requests in system clamped to ``max_batch``
    (continuous batching: every live sequence advances each step, the
    parameter read amortized across them — ``models/serve.py``
    semantics).  Request latency = fluid FIFO finish time − arrival
    time, floored by the request's serialized decode time at the batch
    in effect (B·flops/token per generated token: batching trades
    single-stream latency for shared-weight throughput).
    """
    arrivals = np.asarray(arrivals)
    T = arrivals.shape[0]
    throttle = np.broadcast_to(np.asarray(throttle, np.float64), (T,))
    w_req = cost.request_flops
    cap_dt = cap_flops_per_s * interval_s

    served = np.zeros(T)
    busy = np.zeros(T)
    batch = np.ones(T)
    backlog_end = np.zeros(T)
    backlog = 0.0
    for t in range(T):
        backlog += arrivals[t] * w_req
        avail = throttle[t] * cap_dt
        s = min(backlog, avail)
        served[t] = s
        busy[t] = s / avail if avail > 0 else 0.0
        backlog -= s
        backlog_end[t] = backlog
        n_live = backlog / w_req + arrivals[t]
        batch[t] = min(max_batch, max(1.0, math.ceil(n_live)))

    # ---- per-request latency from cumulative arrived vs served work ----
    n_req = int(arrivals.sum())
    if n_req == 0:
        return QueueResult(served, busy, batch, backlog_end, np.zeros(0))
    # arrival times: uniform within each interval; work positions: FIFO
    t_arr = np.repeat(np.arange(T) * interval_s, arrivals) \
        + np.concatenate([(np.arange(a) + 0.5) / max(a, 1) * interval_s
                          for a in arrivals]) if n_req else np.zeros(0)
    w_pos = (np.arange(n_req) + 1.0) * w_req     # finish needs own work done
    S = np.concatenate([[0.0], np.cumsum(served)])
    t_edge = np.arange(T + 1) * interval_s
    # extrapolate past the horizon at the final capacity so every request
    # finishes and the tail percentile stays meaningful under overload
    tail_rate = max(throttle[-1] * cap_flops_per_s, 1e-6 * cap_flops_per_s)
    extra = max(w_pos[-1] - S[-1], 0.0)
    S_ext = np.concatenate([S, [S[-1] + extra + cap_dt]])
    t_ext = np.concatenate([t_edge, [t_edge[-1]
                                     + (extra + cap_dt) / tail_rate]])
    t_fin = np.interp(w_pos, S_ext, t_ext)
    # serialized-decode floor at the batch in effect on arrival
    b_arr = np.repeat(batch, arrivals)
    floor = (cost.prefill_flops + cost.request.output_tokens
             * cost.decode_flops_per_token * b_arr) / cap_flops_per_s
    lat = np.maximum(t_fin - t_arr, floor)
    return QueueResult(served, busy, batch, backlog_end, lat)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingReport:
    """SLA + thermal outcome of one (scenario, machine) co-simulation."""
    label: str                  # "<config>/<traffic>/<machine>"
    machine: str
    scenario: ServingScenario
    dp: M.DesignPoint
    mean_qps: float             # resolved offered rate
    stack: feedback.StackReport         # coarse-interval thermal record
    durations_s: np.ndarray     # [Tc] coarse interval lengths
    queue: QueueResult          # final-round queue state (base intervals)
    latency_s: np.ndarray       # final-round per-request latencies
    n_base: int
    n_coarse: int
    error_bound_C: float        # coarsening bound: tol x DC gain
    throttle_residual: float    # max |f_k - f_{k-1}| of the last round

    @property
    def coarsen_ratio(self) -> float:
        return self.n_base / self.n_coarse

    @property
    def p50_s(self) -> float:
        return float(np.median(self.latency_s)) if self.latency_s.size \
            else 0.0

    @property
    def p99_s(self) -> float:
        return float(np.percentile(self.latency_s, 99)) \
            if self.latency_s.size else 0.0

    @property
    def dtm_slowdown(self) -> float:
        """Duration-weighted mean 1/f (>= 1)."""
        w = self.durations_s / self.durations_s.sum()
        return float(np.sum(w / self.stack.throttle))

    def time_above(self, limit_C: float = DRAM_LIMIT_C) -> float:
        """Seconds the verdict layers (DRAM dies if any, else all dies)
        spent above ``limit_C``, duration-weighted over the coarse grid."""
        spec = self.stack.spec
        layers = list(spec.dram_layers
                      or range(spec.n_die_layers))
        hot = (self.stack.peak_C[:, layers] > limit_C).any(axis=1)
        return float(self.durations_s[hot].sum())

    @property
    def verdict_ok(self) -> bool:
        return self.time_above() == 0.0

    @property
    def served_qps(self) -> float:
        w_req = serving_cost(self.scenario.config,
                             self.scenario.request).request_flops
        horizon = self.scenario.traffic.horizon_s
        return float(self.queue.served_flops.sum() / w_req / horizon)

    def throttle_curve(self, n_bins: int = 5):
        """Throughput-vs-throttle: (f bin centers, mean served QPS in
        bin, seconds spent in bin) over the coarse intervals."""
        w_req = serving_cost(self.scenario.config,
                             self.scenario.request).request_flops
        f = self.stack.throttle
        plan_served = self.queue.served_flops
        # fold base-interval served work onto the coarse grid
        edges = np.concatenate([[0], np.cumsum(
            np.round(self.durations_s
                     / self.scenario.traffic.interval_s).astype(int))])
        served_c = np.array([plan_served[edges[i]:edges[i + 1]].sum()
                             for i in range(self.n_coarse)])
        qps_c = served_c / w_req / self.durations_s
        bins = np.linspace(f.min(), max(f.max(), f.min() + 1e-9),
                           n_bins + 1)
        idx = np.clip(np.digitize(f, bins) - 1, 0, n_bins - 1)
        centers = 0.5 * (bins[:-1] + bins[1:])
        mean_qps = np.array([qps_c[idx == b].mean() if (idx == b).any()
                             else 0.0 for b in range(n_bins)])
        secs = np.array([self.durations_s[idx == b].sum()
                         for b in range(n_bins)])
        return centers, mean_qps, secs


# ---------------------------------------------------------------------------
# the co-simulation
# ---------------------------------------------------------------------------

def _machine_floorplan(machine: str, dp: M.DesignPoint, wl: M.Workload):
    if machine == "ap":
        fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
        return fp, lambda gn: fp.power_map(gn, dp.ap_power_W), \
            fp.leakage_W()
    if machine == "simd":
        fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))
        return fp, lambda gn: fp.power_map(gn, dp, wl), fp.leakage_W(dp)
    raise ValueError(f"unknown machine {machine!r}")


def _serving_round(scenario: ServingScenario, arrivals, cost, cap, dp,
                   f_base, plan, coarsen, spec, grid, pmap, leak_W, dfp,
                   fb, margin):
    """One throttle↔queue macro-iteration of the serving co-simulation.

    Returns ``(q, plan, f_base, residual, repl)`` with ``repl`` the full
    replay output ``(dyn, peaks, mins, picard_res, f_c, ref_W, leak_Wt,
    dyn_Wt)`` of this round (``dyn`` kept for the coarsening error
    bound).
    """
    tr = scenario.traffic
    T = arrivals.shape[0]
    q = fluid_queue(arrivals, cost, cap, f_base, tr.interval_s,
                    scenario.max_batch)
    # demand traffic at the interval's decode batch (per-batch AI)
    traffic_t = np.array(
        [q.busy[t] * cost.traffic_bytes_per_s(int(q.batch[t]),
                                              dp.ap_n_pus)
         for t in range(T)])
    if plan is None:        # frozen after round 1: stable compile
        if coarsen and scenario.coarsen_tol > 0:
            tref = max(traffic_t.max(), 1e-30)
            joint = np.stack([q.busy, traffic_t / tref], axis=1)
            plan = cosim.coarsen_plan(joint, scenario.coarsen_tol,
                                      scenario.max_merge)
            qmax = scenario.pad_quantum
            plan = plan.pad_to(
                min(-(-plan.n_coarse // qmax) * qmax, T))
        else:
            plan = cosim.CoarsePlan(np.ones(T, np.int64))
    busy_c = plan.merge(q.busy)
    traffic_c = plan.merge(traffic_t)
    dyn, l0, r0, lm = feedback.stack_power_frames(
        spec, grid, busy_c, pmap, leak_W, dfp, traffic_c)
    res = feedback.closed_loop_replay(
        jnp.asarray(dyn), jnp.asarray(l0), jnp.asarray(r0),
        jnp.asarray(lm), grid.fields(), grid.capacity_field(),
        tr.interval_s, scenario.theta, fb=fb,
        die_n=scenario.grid_n, n_die=spec.n_die_layers,
        steps_per_interval=scenario.steps_per_interval,
        n_cg=scenario.n_cg, margin=margin, solver="pcg",
        dt_scale=jnp.asarray(plan.dt_scale()))
    _, peaks, mins, picard_res, f_c, ref_W, leak_Wt, dyn_Wt = res
    f_new = plan.expand(np.asarray(f_c))
    residual = float(np.abs(f_new - f_base).max())
    return q, plan, f_new, residual, (dyn, peaks, mins, picard_res, f_c,
                                      ref_W, leak_Wt, dyn_Wt)


def run_serving_cosim(scenario: ServingScenario,
                      machines=("ap", "simd"),
                      fb: feedback.FeedbackParams = feedback.FeedbackParams(),
                      params: StackParams = PAPER_STACK,
                      coarsen: bool = True) -> dict[str, ServingReport]:
    """Co-simulate one serving scenario on each machine.

    Returns ``{machine: ServingReport}``.  ``coarsen=False`` replays
    every base interval uniformly (the reference the error bound is
    stated against; the property test diffs the two).
    """
    cost = serving_cost(scenario.config, scenario.request)
    # the machine pair: same-performance AP/SIMD at the serving AI of a
    # saturated decode batch (the thermally-binding operating point)
    wl = cost.workload(scenario.max_batch)
    dp = cosim.comparable_design_point(wl)
    cap = M.ap_flops_per_s(dp.ap_n_pus)

    tr = scenario.traffic
    mean_qps = tr.mean_qps if tr.mean_qps > 0 else \
        scenario.load * cap / cost.request_flops
    arrivals = tr.arrivals(mean_qps)
    T = arrivals.shape[0]

    spec = dram_on_logic(scenario.n_dram, params)
    margin = scenario.grid_n // 4
    out: dict[str, ServingReport] = {}
    for machine in machines:
        fp, pmap_of, leak_W = _machine_floorplan(machine, dp, wl)
        grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=scenario.grid_n,
                            nx=scenario.grid_n, params=params, spec=spec,
                            margin=margin)
        pmap = pmap_of(scenario.grid_n)
        dfp = dram.DRAMFloorplan(die_w_mm=fp.die_w_mm)

        f_base = np.ones(T)
        plan = None
        residual = np.inf
        span = obs.span("serving/machine", machine=machine,
                        scenario=scenario.label, n_base=T)
        with span:
            for rnd in range(scenario.n_rounds):
                with obs.span("serving/round", machine=machine, round=rnd):
                    q, plan, f_base, residual, repl = _serving_round(
                        scenario, arrivals, cost, cap, dp, f_base, plan,
                        coarsen, spec, grid, pmap, leak_W, dfp, fb,
                        margin)
        dyn, peaks, mins, picard_res, f_c, ref_W, leak_Wt, dyn_Wt = repl
        if obs.is_enabled():
            w_req = cost.request_flops
            obs.count("serving/requests", q.latency_s.size)
            obs.count("serving/base_intervals", T)
            obs.count("serving/coarse_intervals", plan.n_coarse)
            obs.observe_many("serving/request_latency_s", q.latency_s)
            obs.observe_many("serving/queue_depth_req",
                             q.backlog_flops / w_req)
            obs.observe_many("serving/batch_occupancy",
                             q.batch / scenario.max_batch)
            obs.observe("serving/throttle_residual", residual)

        stack_rep = feedback.StackReport(
            label=f"{scenario.label}/{machine}", interval_s=tr.interval_s,
            spec=spec, peak_C=np.asarray(peaks), min_C=np.asarray(mins),
            residual_C=np.asarray(picard_res), throttle=np.asarray(f_c),
            refresh_W=np.asarray(ref_W), leak_W=np.asarray(leak_Wt),
            base_refresh_W=dfp.base_refresh_W() * len(spec.dram_layers),
            tol_C=fb.picard_tol_C, dyn_W=np.asarray(dyn_Wt))
        bound = scenario.coarsen_tol * cosim.dc_peak_rise_C(
            dyn.max(axis=0), grid.fields()) if coarsen else 0.0
        out[machine] = ServingReport(
            label=f"{scenario.label}/{machine}", machine=machine,
            scenario=scenario, dp=dp, mean_qps=mean_qps, stack=stack_rep,
            durations_s=plan.dt_scale() * tr.interval_s, queue=q,
            latency_s=q.latency_s, n_base=T, n_coarse=plan.n_coarse,
            error_bound_C=bound, throttle_residual=residual)
    return out


def verdict_table(reports: dict[str, dict[str, ServingReport]]) -> str:
    """AP-vs-SIMD SLA/thermal verdict table (CSV-ish, one row per
    (scenario, machine)).  ``reports``: {scenario_label: {machine: rep}}."""
    lines = ["config,traffic,machine,qps,p50_s,p99_s,logic_peak_C,"
             "dram_peak_C,dtm_x,above_85C_s,coarsen_x,verdict"]
    for label, by_machine in reports.items():
        for machine, r in by_machine.items():
            dram_pk = r.stack.dram_peak_C.max() \
                if r.stack.spec.dram_layers else 0.0
            lines.append(
                f"{r.scenario.config},{r.scenario.traffic.shape},{machine},"
                f"{r.mean_qps:.2f},{r.p50_s:.3f},{r.p99_s:.3f},"
                f"{r.stack.logic_peak_C.max():.1f},{dram_pk:.1f},"
                f"{r.dtm_slowdown:.3f},{r.time_above():.1f},"
                f"{r.coarsen_ratio:.1f},"
                f"{'OK' if r.verdict_ok else 'BLOCKED'}")
    return "\n".join(lines)


__all__ = ["ServingScenario", "ServingReport", "QueueResult",
           "fluid_queue", "run_serving_cosim", "verdict_table"]
