# Developer entry points.  CI mirrors these targets; see README §CI.
PY := PYTHONPATH=src python

.PHONY: test bench bench-quick baseline check-bench lint

test:
	$(PY) -m pytest -x -q

# full benchmark suite (writes BENCH_*.json next to the text tables)
bench:
	$(PY) -m benchmarks.run

# the CI smoke lane: thermal (incl. 256^2 solver shoot-out), stack,
# sweep, and the DTM/DVFS policy Pareto shoot-out
bench-quick:
	$(PY) -m benchmarks.run --quick thermal stack sweep policy faults

# refresh the committed perf baseline from a local quick run
# (tolerances in benchmarks/baseline.json are preserved; only the
#  recorded values move)
baseline: bench-quick
	python tools/check_bench.py --update

check-bench:
	python tools/check_bench.py

lint:
	ruff check .
	ruff format --check tools/check_bench.py benchmarks/_record.py
