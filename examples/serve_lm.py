"""Batched serving driver: prefill a batch of prompts, decode with KV cache.

  PYTHONPATH=src python examples/serve_lm.py --batch 8 --prompt-len 64 \
      --gen 32 --arch h2o-danube-3-4b

Uses the reduced config of the chosen arch (CPU-sized) and the same
prefill/decode step builders the dry-run lowers for the production mesh.
Reports prefill latency and decode tokens/s.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models import serve as SV


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)))}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_prefix_embeds, cfg.d_model))
            .astype(np.float32))

    prefill = jax.jit(lambda p, b: SV.prefill(p, b, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, t, c, pos: SV.decode_step(p, t, c, pos, cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"{args.arch} (reduced): prefill {B}x{P} tokens in "
          f"{t_prefill * 1000:.0f} ms (incl. compile)")

    toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [toks]
    t0 = time.time()
    for t in range(P, P + G):
        logits, caches = decode(params, toks, caches, jnp.int32(t))
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    total = B * G
    print(f"decode: {G} steps x {B} sequences = {total} tokens in "
          f"{dt:.2f} s -> {total / dt:.0f} tok/s (greedy)")
    gen = np.asarray(jnp.concatenate(outs, 1))
    print("sample continuation token ids:", gen[0][:16])


if __name__ == "__main__":
    main()
