"""Black-Scholes option pricing on the AP (paper §3.1 workload).

Word-parallel over all option pairs: compute cycles are INDEPENDENT of N —
the paper's embarrassingly-parallel exemplar.

  PYTHONPATH=src python examples/ap_blackscholes.py [N]
"""
import sys

import numpy as np

from repro.workloads import blackscholes as bs


def main(n: int = 128) -> None:
    rng = np.random.default_rng(7)
    S = rng.uniform(0.8, 1.6, n)
    K = rng.uniform(0.8, 1.6, n)
    T = rng.uniform(0.3, 2.0, n)
    sigma = rng.uniform(0.15, 0.6, n)

    prices, ctr = bs.ap_blackscholes(S, K, T, sigma, r=0.05)
    ref = bs.reference(S, K, T, sigma, r=0.05)

    err = np.abs(prices - ref)
    print(f"N = {n} options, one PU each")
    print(f"compute cycles: {ctr['cycles'] - ctr['read_cycles']} "
          f"(independent of N)")
    print(f"energy: {ctr['energy']:.3e} normalized SRAM-write units")
    print(f"price error:  max {err.max():.4f}   mean {err.mean():.4f} "
          f"(Q6.10 + 10-bit LUTs)")
    for i in range(min(5, n)):
        print(f"  S={S[i]:.3f} K={K[i]:.3f} T={T[i]:.2f} sig={sigma[i]:.2f}"
              f"  AP={prices[i]:.4f}  ref={ref[i]:.4f}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 128)
