"""End-to-end training driver: a ~100M-class LM for a few hundred steps on
CPU, with checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  # kill it mid-run and re-invoke: it resumes from the newest checkpoint
  # with a bit-identical trajectory (deterministic data pipeline).

Uses a width-scaled stablelm family config (~26M params by default;
--width 768 --layers 12 gives ~110M) and the same train-step builder the
dry-run lowers for the production mesh — here on a 1-device local mesh.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.configs.base import ShapeCell
from repro.models import model as M
from repro.models.model import PerfConfig
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import TrainerConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="ckpt/train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("stablelm-1.6b"), n_layers=args.layers,
        d_model=args.width, n_heads=args.width // 64,
        n_kv_heads=args.width // 64, d_ff=args.width * 3,
        vocab=args.vocab, d_head=64)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({args.layers}L x {args.width})")

    mesh = make_local_mesh(1, 1)
    cell = ShapeCell("local", args.seq, args.batch, "train")
    perf = PerfConfig(remat="none", accum_steps=1)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    train_step, _ = make_train_step(cfg, cell, mesh, perf=perf,
                                    opt_cfg=opt_cfg, dtype=jnp.float32)

    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(params)
    pipe = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         log_path=f"{args.ckpt_dir}/log.jsonl")

    def hook(step, params, opt, rec):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {rec['loss']:.4f}  "
                  f"({rec['dt_s'] * 1000:.0f} ms)", flush=True)

    out = train_loop(train_step, params, opt, pipe, tcfg, accum=1, hook=hook)
    first = out["history"][0]["loss"] if out["history"] else float("nan")
    last = out["history"][-1]["loss"]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({out['stragglers']} straggler steps)")


if __name__ == "__main__":
    main()
