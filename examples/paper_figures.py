"""Reproduce the paper's figures end-to-end; writes CSVs under artifacts/.

  Fig 6  speedup vs area (3 workloads x {SIMD, AP})
  Fig 7  power vs area
  Figs 10/12/13  thermal maps + T-Cut profiles (HotSpot-equivalent solver)

  PYTHONPATH=src python examples/paper_figures.py
"""
import pathlib

import numpy as np

from repro.core import models as M
from repro.core.floorplan import thermal_comparison

OUT = pathlib.Path("artifacts/figures")


def fig6_fig7() -> None:
    areas = np.geomspace(0.2, 200, 60)          # mm^2
    for name in M.WORKLOADS:
        s_simd, s_ap = M.speedup_vs_area_curves(name, areas)
        p_simd, p_ap = M.power_vs_area_curves(name, areas)
        rows = np.column_stack([areas, s_simd, s_ap, p_simd, p_ap])
        f = OUT / f"fig6_fig7_{name}.csv"
        np.savetxt(f, rows, delimiter=",", header=(
            "area_mm2,speedup_simd,speedup_ap,power_simd_W,power_ap_W"),
            comments="")
        be = M.break_even_area_mm2(name)
        print(f"{name:4s}: break-even area {be:8.2f} mm^2  -> {f}")
    dp = M.paper_design_point("dmm")
    print(f"DMM design point: S={dp.speedup:.0f}  AP {dp.ap_area_mm2:.1f}mm^2"
          f"/{dp.ap_power_W:.2f}W  SIMD {dp.simd_area_mm2:.1f}mm^2"
          f"/{dp.simd_power_W:.2f}W  (power x{dp.power_ratio:.2f}, "
          f"density x{dp.power_density_ratio:.1f})")


def thermal() -> None:
    res = thermal_comparison(grid_ap=256, grid_simd=64, workload="dmm")
    for name in ("ap", "simd"):
        r = res[name]
        print(f"{name.upper():4s}: layer peaks "
              + " ".join(f"{p:.1f}C" for p in r["peak_C"])
              + f"   span(top layer) {r['span_C'][0]:.1f}C")
        np.savetxt(OUT / f"fig13_tcut_{name}.csv",
                   np.column_stack(r["t_cut"]), delimiter=",",
                   header=",".join(f"layer{i}" for i in range(4)),
                   comments="")
        np.save(OUT / f"thermal_map_{name}.npy", r["T"])
    dram_limit = 85.0
    ap_ok = max(res["ap"]["peak_C"]) < dram_limit
    simd_ok = max(res["simd"]["peak_C"]) < dram_limit
    print(f"3D-DRAM stacking (85C limit): AP {'OK' if ap_ok else 'BLOCKED'}, "
          f"SIMD {'OK' if simd_ok else 'BLOCKED'}  (paper: AP OK, SIMD blocked)")


def main() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    fig6_fig7()
    thermal()


if __name__ == "__main__":
    main()
