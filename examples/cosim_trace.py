"""Power-trace -> transient thermal co-simulation of AP vs SIMD.

Replays each workload's power trace (AP: measured from the engine's exact
per-pass energy accounting; SIMD: the eq-14 execute/synchronize phase
model) through the implicit transient solver, and prints the time-resolved
verdict on the paper's central question: can the die sit under 3D DRAM
(85 °C ceiling)?

Run:  PYTHONPATH=src python examples/cosim_trace.py [--grid 32] [--t-end 0.25]
"""
import argparse

import numpy as np

from repro.core import cosim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=32)
    ap.add_argument("--intervals", type=int, default=64)
    ap.add_argument("--t-end", type=float, default=0.25)
    ap.add_argument("--workloads", default="dmm,fft")
    args = ap.parse_args()
    workloads = tuple(args.workloads.split(","))

    res = cosim.run_cosim(workloads=workloads, grid_n=args.grid,
                          n_intervals=args.intervals, t_end=args.t_end)
    print(f"co-sim: {args.intervals} intervals over {args.t_end:.2f}s, "
          f"grid {args.grid}, {cosim.DRAM_LIMIT_C:.0f}C 3D-DRAM ceiling")
    for w in workloads:
        dp = res["design_points"][w]
        print(f"\n=== {w}  (same performance: S={dp.speedup:.0f}; "
              f"AP {dp.ap_power_W:.2f}W/layer vs "
              f"SIMD {dp.simd_power_W:.2f}W/layer)")
        for machine in ("ap", "simd"):
            r = res[w][machine]
            above = r.time_above()
            cross = r.crossing_time()
            print(f"  {machine.upper():4s} layer  peak_max  peak_end  "
                  f"span_max  t>85C[s]  first>85C[s]")
            for l in range(r.peak_C.shape[1]):
                c = f"{cross[l]:.3f}" if np.isfinite(cross[l]) else "never"
                print(f"       {l}      {r.peak_C[:, l].max():7.1f}  "
                      f"{r.peak_C[-1, l]:8.1f}  {r.span_C[:, l].max():8.2f}  "
                      f"{above[l]:8.3f}  {c:>10s}")
        verdict_ap = "OK for 3D DRAM" if res[w]["ap"].time_above().max() == 0 \
            else "BLOCKED"
        verdict_simd = "OK for 3D DRAM" \
            if res[w]["simd"].time_above().max() == 0 else "BLOCKED"
        print(f"  verdict: AP {verdict_ap} / SIMD {verdict_simd}")


if __name__ == "__main__":
    main()
