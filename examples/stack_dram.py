"""3D DRAM-on-logic stack with closed-loop thermal feedback.

Stacks ``--dram`` thinned DRAM dies on top of the paper's 4-layer AP and
same-performance SIMD logic stacks and replays one workload with
temperature feedback: JEDEC refresh-rate bins (2x above 85 °C, 4x above
95 °C), exponential leakage, and a DTM throttle.  Prints the per-interval
timeline and the stacking verdict the paper's abstract argues for.

Run:  PYTHONPATH=src python examples/stack_dram.py [--workload dmm]
      [--dram 2] [--grid 16] [--intervals 32]
"""
import argparse
import sys

from repro.core.constants import DRAM_LIMIT_C
from repro.stack import feedback


def main(argv=None):
    from repro.workloads import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="dmm", choices=registry.names())
    ap.add_argument("--dram", type=int, default=2)
    ap.add_argument("--grid", type=int, default=16)
    ap.add_argument("--intervals", type=int, default=32)
    ap.add_argument("--t-end", type=float, default=0.25)
    args = ap.parse_args(argv if argv is not None else [])

    w = args.workload
    res = feedback.run_stack_cosim(
        workloads=(w,), n_dram=args.dram, grid_n=args.grid,
        n_intervals=args.intervals, t_end=args.t_end)
    spec = res["spec"]
    dp = res["design_points"][w]
    fb = res["fb"]
    print(f"stack: {spec.name}  (top -> bottom: "
          + " | ".join(l.name for l in spec.layers) + ")")
    print(f"{w}: same performance S={dp.speedup:.0f}; "
          f"AP {dp.ap_power_W:.2f}W/layer vs SIMD {dp.simd_power_W:.2f}W/layer; "
          f"DTM trip {fb.dtm_trip_C:.0f}C, refresh bins 85/95C")
    for machine in ("ap", "simd"):
        r = res[w][machine]
        print(f"\n  {machine.upper()}  t[s]   logic_peak  dram_peak  "
              f"refresh_W  throttle  picard_resid")
        step = max(len(r.times) // 8, 1)
        for i in range(0, len(r.times), step):
            print(f"       {r.times[i]:5.3f}  {r.logic_peak_C[i]:9.1f}  "
                  f"{r.dram_peak_C[i]:9.1f}  {r.refresh_W[i]:9.3f}  "
                  f"{r.throttle[i]:8.2f}  {r.residual_C[i]:12.2g}")
        print(f"       summary: refresh overhead {r.refresh_overhead:.2f}x, "
              f"DTM slowdown {r.dtm_slowdown:.2f}x, "
              f"DRAM above {DRAM_LIMIT_C:.0f}C {r.dram_time_above_limit_s:.3f}s "
              f"of {res['t_end']:.2f}s, converged={r.converged}")
    ap_ok = res[w]["ap"].dram_time_above_limit_s == 0.0
    simd_ok = res[w]["simd"].dram_time_above_limit_s == 0.0
    print(f"\nverdict ({args.dram}x DRAM dies): "
          f"AP {'OK for 3D DRAM' if ap_ok else 'BLOCKED'} / "
          f"SIMD {'OK for 3D DRAM' if simd_ok else 'BLOCKED'}")


if __name__ == "__main__":
    main(sys.argv[1:])
