"""Quickstart: associative computing in 5 minutes (paper §2.2 walk-through).

Runs on CPU.  Shows the three silicon ops (COMPARE / tagged WRITE /
broadcast WRITE), the 8m-cycle adder, O(m^2) multiplier, and the paper's
energy accounting.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import arith, isa
from repro.core.engine import APEngine


def main() -> None:
    n = 4096                       # 4096 PUs (words)
    eng = APEngine(n_words=n, n_bits=128)
    rng = np.random.default_rng(0)

    # allocate bit-column fields inside the associative word
    a = eng.alloc.alloc(16, "a")
    b = eng.alloc.alloc(16, "b")
    carry = eng.alloc.alloc(1, "carry")
    prod = eng.alloc.alloc(32, "prod")

    av = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    bv = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    eng.load(a, av)
    eng.load(b, bv)

    # --- word-parallel ADD: 4 passes/bit = 8m cycles, any vector length ---
    c0 = eng.cycles
    isa.run_add(eng, a, b, carry)
    add_cycles = eng.cycles - c0
    got = eng.peek(b)
    assert np.array_equal(got, (av + bv) & 0xFFFF)
    print(f"ADD   16-bit x {n} PUs: {add_cycles} cycles "
          f"(paper: 8m = {8 * 16} + carry clear)")

    # --- word-parallel MUL: O(m^2) ---
    eng.load(b, bv)               # restore b (add overwrote it)
    c0 = eng.cycles
    arith.run_mul(eng, a, b, prod, carry)
    mul_cycles = eng.cycles - c0
    assert np.array_equal(eng.peek(prod), (av * bv) & 0xFFFFFFFF)
    print(f"MUL   16-bit x {n} PUs: {mul_cycles} cycles (O(m^2))")

    # --- the point: cycles are independent of the number of PUs ----------
    eng2 = APEngine(n_words=64, n_bits=128)
    a2, b2 = eng2.alloc.alloc(16), eng2.alloc.alloc(16)
    c2 = eng2.alloc.alloc(1)
    eng2.load(a2, av[:64])
    eng2.load(b2, bv[:64])
    isa.run_add(eng2, a2, b2, c2)
    print(f"ADD   on 64 PUs: {eng2.cycles} cycles — same as on {n} "
          f"(word-parallel)")

    # --- energy accounting (paper eq 16/17, Table 3) ----------------------
    print(f"energy: {eng.energy:.3e} normalized units "
          f"({eng.energy_uJ():.3f} uJ at the 0.5uW SRAM anchor)")
    print(f"events: {eng.events}")


if __name__ == "__main__":
    main()
