"""Docs checks: intra-repo markdown links resolve; doc snippets execute.

1. Scans every tracked ``*.md`` for inline links/images and verifies
   that relative targets exist; for ``#fragment`` links (same-file or
   cross-file) the target heading must exist, using GitHub's slug rules
   (lowercase, drop punctuation, spaces → dashes).
2. Runs ``doctest`` over the snippet-bearing docs (``docs/*.md``).

Exit code 0 = all good.  Run:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# docs/*.md plus the design doc: DESIGN.md §3.4 carries executable
# snippets (the megakernel op-group model) that must stay runnable
DOCTEST_DOCS = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "DESIGN.md"]

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)        # drop punctuation (unicode-aware)
    return h.replace(" ", "-")


def headings_of(path: Path) -> set[str]:
    text = _CODE_FENCE.sub("", path.read_text())
    return {github_slug(m.group(1)) for m in _HEADING.finditer(text)}


def check_links() -> list[str]:
    errors = []
    for md in sorted(ROOT.rglob("*.md")):
        if any(part.startswith(".") or part in ("node_modules",)
               for part in md.relative_to(ROOT).parts):
            continue
        text = _CODE_FENCE.sub("", md.read_text())
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
                continue
            if frag and dest.suffix == ".md":
                if github_slug(frag) not in headings_of(dest):
                    errors.append(f"{md.relative_to(ROOT)}: missing "
                                  f"anchor -> {target}")
    return errors


def check_index() -> list[str]:
    """Every docs/*.md page must be listed in docs/index.md — the map
    is what keeps new pages discoverable (README links only the map)."""
    index = ROOT / "docs" / "index.md"
    if not index.exists():
        return ["docs/index.md is missing (the docs map must exist)"]
    text = index.read_text()
    return [f"docs/index.md: page docs/{md.name} is not listed"
            for md in sorted((ROOT / "docs").glob("*.md"))
            if md.name != "index.md" and md.name not in text]


def run_doctests() -> int:
    failures = 0
    for doc in DOCTEST_DOCS:
        print(f"doctest {doc.relative_to(ROOT)} ...", flush=True)
        res = doctest.testfile(str(doc), module_relative=False,
                               optionflags=doctest.NORMALIZE_WHITESPACE
                               | doctest.ELLIPSIS)
        print(f"  {res.attempted} examples, {res.failed} failures")
        failures += res.failed
    return failures


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"LINK ERROR: {e}")
    index_errors = check_index()
    for e in index_errors:
        print(f"INDEX ERROR: {e}")
    errors += index_errors
    failures = run_doctests()
    if errors or failures:
        return 1
    print("docs OK: links resolve, index complete, doctests pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
