"""CI perf-regression gate over the ``BENCH_*.json`` artifacts.

Compares every metric listed in ``benchmarks/baseline.json`` against the
value the corresponding ``BENCH_<bench>.json`` reports, with per-metric
tolerances, and exits non-zero on any regression — a missing artifact or
a missing metric is a failure too (a bench that silently stops emitting
a gated number must not pass).

Baseline format (per bench, per metric)::

    {"thermal": {"steady_mg_speedup_256": {"value": 30.0, "min": 2.0},
                 "ap_peak_C": {"value": 55.3, "abs_tol": 1.5},
                 "steady_pcg_iters_256": {"value": 3832, "rel_tol": 0.5},
                 "n_cases": {"value": 8}}}

Rules (all that are present must hold; ``value`` alone means exact):

- ``abs_tol``:  |new - value| <= abs_tol
- ``rel_tol``:  |new - value| <= rel_tol * |value|
- ``min`` / ``max``: absolute floor / ceiling on the new value (use for
  ratios like speedups, where the baseline machine's absolute number is
  meaningless on another machine)

Usage::

    python tools/check_bench.py [--baseline benchmarks/baseline.json]
                                [--update] [BENCH_*.json ...]

With no file arguments, ``BENCH_*.json`` in the current directory are
used.  ``--update`` rewrites every baseline ``value`` from the current
artifacts (tolerances are preserved) — the ``make baseline`` refresh
path documented in the README.
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import sys
from pathlib import Path

DEFAULT_BASELINE = (
    Path(__file__).resolve().parent.parent / "benchmarks" / "baseline.json"
)


def load_artifacts(paths: list[str]) -> dict[str, dict]:
    """{bench name: metrics} from BENCH_*.json files.

    Deliberately reads ONLY the flat ``metrics`` section: the schema-2
    ``telemetry`` sub-object (obs registry snapshot) is observability
    payload and must never become a regression surface.
    """
    out: dict[str, dict] = {}
    for p in paths:
        with open(p) as f:
            payload = json.load(f)
        out[payload["bench"]] = payload["metrics"]
    return out


def check_metric(name: str, expect: dict, got: float) -> list[str]:
    """Failure messages for one metric (empty = pass)."""
    # NaN/inf fail loudly and first: every comparison below is False on
    # NaN (|got - value| > tol, got < min, got > max), so without this
    # a non-finite metric would sail through every tolerance band
    if (
        not isinstance(got, (int, float))
        or isinstance(got, bool)
        or not math.isfinite(got)
    ):
        return [f"non-finite or non-numeric metric value {got!r}"]
    fails = []
    value = expect.get("value")
    bounded = not {"abs_tol", "rel_tol", "min", "max"}.isdisjoint(expect)
    if value is not None:
        abs_tol = expect.get("abs_tol")
        rel_tol = expect.get("rel_tol")
        if abs_tol is not None and abs(got - value) > abs_tol:
            fails.append(f"|{got:g} - {value:g}| > abs_tol {abs_tol:g}")
        if rel_tol is not None and abs(got - value) > rel_tol * abs(value):
            fails.append(
                f"|{got:g} - {value:g}| > rel_tol {rel_tol:g} * |{value:g}|"
            )
        if not bounded and got != value:
            fails.append(f"{got:g} != {value:g} (exact)")
    if "min" in expect and got < expect["min"]:
        fails.append(f"{got:g} < min {expect['min']:g}")
    if "max" in expect and got > expect["max"]:
        fails.append(f"{got:g} > max {expect['max']:g}")
    return fails


def run_check(baseline: dict, artifacts: dict[str, dict]) -> int:
    n_checked = n_failed = 0
    for bench, metrics in sorted(baseline.items()):
        got_metrics = artifacts.get(bench)
        if got_metrics is None:
            print(f"FAIL {bench}: no BENCH_{bench}.json artifact found")
            n_failed += len(metrics)
            n_checked += len(metrics)
            continue
        for name, expect in sorted(metrics.items()):
            n_checked += 1
            if name not in got_metrics:
                print(f"FAIL {bench}.{name}: metric missing from artifact")
                n_failed += 1
                continue
            fails = check_metric(name, expect, got_metrics[name])
            if fails:
                print(f"FAIL {bench}.{name}: {'; '.join(fails)}")
                n_failed += 1
            else:
                print(f"  ok {bench}.{name} = {got_metrics[name]:g}")
    print(f"{n_checked - n_failed}/{n_checked} gated metrics pass")
    return 1 if n_failed else 0


def run_update(
    baseline_path: Path, baseline: dict, artifacts: dict[str, dict]
) -> int:
    for bench, metrics in baseline.items():
        got_metrics = artifacts.get(bench)
        if got_metrics is None:
            print(f"skip {bench}: no artifact")
            continue
        for name, expect in metrics.items():
            if name not in got_metrics:
                print(f"skip {bench}.{name}: missing from artifact")
                continue
            if "value" in expect:
                expect["value"] = got_metrics[name]
    with open(baseline_path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"updated {baseline_path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "files",
        nargs="*",
        help="BENCH_*.json artifacts (default: ./BENCH_*.json)",
    )
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite baseline values from the artifacts",
    )
    args = ap.parse_args(argv)

    files = args.files or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("no BENCH_*.json artifacts found")
        return 1
    artifacts = load_artifacts(files)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.update:
        return run_update(Path(args.baseline), baseline, artifacts)
    return run_check(baseline, artifacts)


if __name__ == "__main__":
    sys.exit(main())
