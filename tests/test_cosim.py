"""Co-simulation engine (core/cosim.py + thermal implicit steppers):
implicit-vs-explicit transient agreement, trace-binning energy
conservation, frame synthesis, the vmapped batch driver, and reports."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cosim, thermal


# ------------------------------------------------- implicit transient solver
def test_implicit_matches_explicit_oracle():
    """Acceptance bar: 32x32 grid, peak within 0.1 C of the explicit
    (CFL-bound) oracle at >= 10x fewer time steps."""
    rng = np.random.default_rng(0)
    grid = thermal.Grid(die_w=5e-3, ny=32, nx=32)
    power = grid.pad_power(
        rng.uniform(0, 2e-3, size=(4, 32, 32)).astype(np.float32))
    t_end = 0.05
    n_exp = max(int(t_end / thermal.explicit_dt(grid)), 1)
    T_e, _ = thermal.transient_solve(power, grid, t_end)
    n_imp = max(n_exp // 20, 1)
    assert n_exp / n_imp >= 10
    T_i, peaks = thermal.transient_solve_implicit(power, grid, t_end,
                                                  n_steps=n_imp)
    assert abs(float(jnp.max(T_i)) - float(jnp.max(T_e))) < 0.1
    np.testing.assert_allclose(np.asarray(T_i), np.asarray(T_e), atol=0.1)
    assert peaks.shape == (n_imp,)


def test_implicit_crank_nicolson_also_agrees():
    rng = np.random.default_rng(1)
    grid = thermal.Grid(die_w=4e-3, ny=16, nx=16)
    power = grid.pad_power(
        rng.uniform(0, 1e-3, size=(4, 16, 16)).astype(np.float32))
    t_end = 0.02
    T_e, _ = thermal.transient_solve(power, grid, t_end)
    n_imp = max(int(t_end / thermal.explicit_dt(grid)) // 20, 1)
    T_i, _ = thermal.transient_solve_implicit(power, grid, t_end,
                                              n_steps=n_imp, theta=0.5)
    np.testing.assert_allclose(np.asarray(T_i), np.asarray(T_e), atol=0.1)


def test_transient_implicit_fields_reaches_steady_state():
    """Public fields-operator stepper, driven directly on a margin grid."""
    rng = np.random.default_rng(7)
    grid = thermal.Grid(die_w=3e-3, ny=12, nx=12, margin=3)
    power = rng.uniform(0, 2e-3, size=(4, 12, 12)).astype(np.float32)
    p_dom = jnp.pad(grid.pad_power(power), ((0, 0), (3, 3), (3, 3)))
    T0 = jnp.full(p_dom.shape, thermal.AMBIENT_C, jnp.float32)
    T, peaks = thermal.transient_implicit_fields(
        T0, p_dom, grid.fields(), grid.capacity_field(), dt=0.05,
        n_steps=60, n_cg=60)
    T_ss = np.asarray(thermal.steady_state(power, grid))
    die = np.asarray(T)[:4, 3:15, 3:15]
    np.testing.assert_allclose(die, T_ss, atol=0.05)
    assert peaks.shape == (60,)
    assert float(peaks[0]) == pytest.approx(thermal.AMBIENT_C)  # pre-step


def test_constant_trace_replay_reaches_steady_state():
    """The fields-operator implicit path, end to end: a constant-activity
    replay must land on the steady-state CG solution."""
    rng = np.random.default_rng(2)
    grid_n, margin = 16, 4
    grid = thermal.Grid(die_w=3e-3, ny=grid_n, nx=grid_n, margin=margin)
    pmap = rng.uniform(0, 5e-3, size=(grid_n, grid_n))
    trace = cosim.PowerTrace(np.ones(30))
    frames = cosim.power_frames(trace, pmap, float(pmap.sum()) * 0.3, grid)
    T_end, peaks, mins = cosim.cosim_transient(
        jnp.asarray(frames), grid.fields(), grid.capacity_field(),
        2.0 / 30, steps_per_interval=4, n_cg=60, margin=margin,
        die_n=grid_n)
    power = np.broadcast_to(pmap, (4, grid_n, grid_n)).astype(np.float32)
    T_ss = np.asarray(thermal.steady_state(power, grid))
    for l in range(4):
        assert abs(float(peaks[-1, l]) - T_ss[l].max()) < 0.05
        assert abs(float(mins[-1, l]) - T_ss[l].min()) < 0.05


# ------------------------------------------------------------- power traces
def test_engine_trace_conserves_energy():
    from repro.core.engine import APEngine

    eng = APEngine(n_words=64, n_bits=16)
    eng.bwrite([0, 1], [1, 0])
    eng.compare([0], [1])
    eng.write([1, 2, 3], [1, 1, 0])
    _, bins = eng.power_trace(8)
    assert bins.sum() == pytest.approx(eng.energy)


def test_workload_trace_bins_sum_to_engine_energy():
    """Binned trace == engine.energy for a real pass-schedule workload."""
    from repro.workloads import dmm

    rng = np.random.default_rng(3)
    A = rng.integers(0, 16, (4, 4), dtype=np.uint64)
    B = rng.integers(0, 16, (4, 4), dtype=np.uint64)
    _, ctr = dmm.ap_matmul(A, B, m=4)
    assert ctr["trace_energy"].sum() == pytest.approx(ctr["energy"])
    assert int(ctr["trace_cycles"].max()) <= ctr["cycles"]
    tr = cosim.trace_from_counters(ctr, 16)
    assert tr.activity.shape == (16,)
    assert tr.activity.mean() == pytest.approx(1.0)
    assert (tr.activity >= 0).all()


def test_simd_phase_trace_mean_one():
    dp = cosim.comparable_design_point("dmm")
    from repro.core import models as M
    tr = cosim.simd_phase_trace(M.WORKLOADS["dmm"], dp, 32)
    assert tr.activity.mean() == pytest.approx(1.0)
    assert tr.activity.std() > 0  # it actually alternates


def test_power_frames_conserve_power():
    """mean-over-time of each frame's total == n_si x layer power."""
    grid_n, margin = 8, 2
    grid = thermal.Grid(die_w=2e-3, ny=grid_n, nx=grid_n, margin=margin)
    rng = np.random.default_rng(4)
    pmap = rng.uniform(0, 1e-2, size=(grid_n, grid_n))
    act = rng.uniform(0.2, 2.0, 10)
    trace = cosim.PowerTrace(act / act.mean())
    frames = cosim.power_frames(trace, pmap, float(pmap.sum()) * 0.4, grid)
    n_si = grid.params.n_si_layers
    assert frames.shape == (10, grid.params.n_layers,
                            grid.dom_ny, grid.dom_nx)
    mean_total = frames.sum(axis=(1, 2, 3)).mean()
    assert mean_total == pytest.approx(n_si * pmap.sum(), rel=1e-5)
    assert frames[:, -1].sum() == 0.0        # spreader layer heatless


# --------------------------------------------------------- batched driver
def test_vmapped_cosim_shapes_and_dtypes():
    res = cosim.run_cosim(workloads=("dmm",), grid_n=8, n_intervals=8,
                          t_end=0.1, steps_per_interval=1, n_cg=25)
    for machine in ("ap", "simd"):
        r = res["dmm"][machine]
        assert r.peak_C.shape == (8, 4)
        assert r.min_C.shape == (8, 4)
        assert r.peak_C.dtype == np.float32
        assert np.isfinite(r.peak_C).all() and np.isfinite(r.min_C).all()
        assert (r.peak_C >= r.min_C - 1e-4).all()
        assert (r.min_C > 0).all()
    # AP runs cooler than the same-performance SIMD throughout (Fig 10/12)
    assert res["dmm"]["ap"].peak_C.max() < res["dmm"]["simd"].peak_C.max()


@pytest.mark.pallas
def test_cosim_pallas_route_matches_jnp():
    rng = np.random.default_rng(5)
    grid_n, margin = 8, 2
    grid = thermal.Grid(die_w=3e-3, ny=grid_n, nx=grid_n, margin=margin)
    pmap = rng.uniform(0, 5e-3, size=(grid_n, grid_n))
    act = rng.uniform(0.5, 1.5, 6)
    trace = cosim.PowerTrace(act / act.mean())
    frames = jnp.asarray(cosim.power_frames(trace, pmap, 0.0, grid))
    args = (frames, grid.fields(), grid.capacity_field(), 0.02)
    kw = dict(steps_per_interval=2, n_cg=30, margin=margin, die_n=grid_n)
    _, pk_j, mn_j = cosim.cosim_transient(*args, **kw)
    _, pk_p, mn_p = cosim.cosim_transient(*args, **kw, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pk_j), np.asarray(pk_p),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(mn_j), np.asarray(mn_p),
                               rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------- reports
def test_report_time_above_and_crossing():
    peak = np.array([[50.0, 50.0], [90.0, 60.0], [100.0, 84.9],
                     [80.0, 86.0]], np.float32)
    r = cosim.CosimReport(label="t", interval_s=0.5, peak_C=peak,
                          min_C=peak - 10.0)
    np.testing.assert_allclose(r.time_above(85.0), [1.0, 0.5])
    np.testing.assert_allclose(r.crossing_time(85.0), [1.0, 2.0])
    np.testing.assert_allclose(r.span_C, 10.0)
    never = cosim.CosimReport(label="n", interval_s=0.5,
                              peak_C=peak * 0 + 50.0, min_C=peak * 0 + 49.0)
    assert np.isinf(never.crossing_time(85.0)).all()
    assert never.time_above(85.0).max() == 0.0
