"""Suite workloads (sort / spmv / knn / hist): NumPy-oracle correctness,
cycle-scaling claims, and exact trace-energy accounting — mirroring
tests/test_workloads.py for the paper trio."""
import numpy as np
import pytest

from repro.workloads import histogram as hist
from repro.workloads import knn, registry, sort, spmv


def _check_energy(ctr):
    """Trace events must sum to the engine's energy counter exactly
    (same accounting, same event order; fp tolerance only)."""
    assert ctr["trace_energy"].sum() == pytest.approx(ctr["energy"],
                                                      rel=1e-9)
    assert ctr["trace_cycles"].shape == ctr["trace_energy"].shape


# ------------------------------------------------------------------ sort
def test_sort_exact():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 200, 50, dtype=np.uint64)
    y, ctr = sort.ap_sort(x, m=8)
    np.testing.assert_array_equal(y, sort.reference(x))
    _check_energy(ctr)


def test_sort_with_ties_and_cycles_scale_with_distinct_values():
    """Min-extraction retires a whole tie group at once: duplicating the
    multiset leaves the compare/write cycle count unchanged."""
    rng = np.random.default_rng(1)
    x = rng.integers(0, 32, 32, dtype=np.uint64)
    y1, c1 = sort.ap_sort(x, m=5)
    x4 = np.tile(x, 4)
    y4, c4 = sort.ap_sort(x4, m=5)
    np.testing.assert_array_equal(y1, sort.reference(x))
    np.testing.assert_array_equal(y4, sort.reference(x4))
    assert c4["cycles"] == c1["cycles"]


# ------------------------------------------------------------------ spmv
def test_spmv_exact():
    rng = np.random.default_rng(2)
    n_rows, nnz = 8, 24
    r = rng.integers(0, n_rows, nnz)
    c = rng.integers(0, n_rows, nnz)
    v = rng.integers(0, 50, nnz, dtype=np.uint64)
    x = rng.integers(0, 50, n_rows, dtype=np.uint64)
    y, ctr = spmv.ap_spmv(r, c, v, x, n_rows, m=6)
    np.testing.assert_array_equal(y, spmv.reference(r, c, v, x, n_rows))
    _check_energy(ctr)


def test_spmv_cycles_independent_of_nnz():
    """Products are word-parallel and the reduction scans output rows,
    so cycles do not grow with the number of stored nonzeros (until the
    word count crosses a 32-lane boundary)."""
    rng = np.random.default_rng(3)
    n_rows = 8
    cycles = {}
    for nnz in (16, 32):
        r = rng.integers(0, n_rows, nnz)
        c = rng.integers(0, n_rows, nnz)
        v = rng.integers(0, 30, nnz, dtype=np.uint64)
        x = rng.integers(0, 30, n_rows, dtype=np.uint64)
        y, ctr = spmv.ap_spmv(r, c, v, x, n_rows, m=5)
        np.testing.assert_array_equal(y, spmv.reference(r, c, v, x, n_rows))
        cycles[nnz] = ctr["cycles"]
    assert cycles[16] == cycles[32]


# ------------------------------------------------------------------ knn
def test_knn_exact_with_stable_ties():
    rng = np.random.default_rng(4)
    db = rng.integers(0, 16, (48, 4), dtype=np.uint64)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    idx, ctr = knn.ap_knn(db, q, k=7, m=4)
    np.testing.assert_array_equal(idx, knn.reference(db, q, 7))
    _check_energy(ctr)


def test_knn_distance_cycles_independent_of_db_size():
    """The LUT distance phase is word-parallel: total cycles minus the
    per-responder readout do not grow with the database size."""
    rng = np.random.default_rng(5)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    cyc = {}
    for n in (32, 128):
        db = rng.integers(0, 16, (n, 4), dtype=np.uint64)
        idx, ctr = knn.ap_knn(db, q, k=1, m=4)
        np.testing.assert_array_equal(idx, knn.reference(db, q, 1))
        cyc[n] = ctr["cycles"] - ctr["read_cycles"]
    # min-extraction narrowing adds at most one retire write per bit
    assert abs(cyc[128] - cyc[32]) <= 2 * 8


# ------------------------------------------------------------------ hist
def test_histogram_exact_and_one_cycle_per_bin():
    rng = np.random.default_rng(6)
    x = rng.integers(0, 64, 100, dtype=np.uint64)
    h, ctr = hist.ap_histogram(x, 8, m=6)
    np.testing.assert_array_equal(h, hist.reference(x, 8, m=6))
    assert h.sum() == 100
    assert ctr["cycles"] == 8          # exactly one COMPARE per bin
    _check_energy(ctr)


def test_histogram_rejects_bad_bins():
    with pytest.raises(ValueError):
        hist.ap_histogram(np.zeros(8, np.uint64), 6, m=4)   # not a pow2
    with pytest.raises(ValueError):
        hist.ap_histogram(np.zeros(8, np.uint64), 1, m=4)   # degenerate
    with pytest.raises(ValueError):
        hist.ap_histogram(np.zeros(8, np.uint64), 32, m=4)  # > 2^m


# -------------------------------------------------------------- registry
@pytest.mark.parametrize("name", ["sort", "spmv", "knn", "hist"])
def test_registry_trace_counters_and_model(name):
    """Every suite workload is registered, has a calibrated model entry,
    a comparable design point, and emits a usable energy trace."""
    from repro.core import cosim
    from repro.core import models as M

    wd = registry.get(name)
    assert wd.model is M.WORKLOADS[name]
    assert M.ARITH_INTENSITY[name] > 0
    dp = cosim.comparable_design_point(name)
    assert dp.ap_n_pus >= 1024 and dp.simd_n_pus > 0
    ctr = registry.trace_counters(name, 32)
    assert ctr["trace_energy"].sum() == pytest.approx(ctr["energy"],
                                                      rel=1e-9)
    tr = cosim.ap_workload_trace(name, n_intervals=8, n_elems=32)
    assert tr.activity.shape == (8,)
    assert tr.activity.mean() == pytest.approx(1.0)
