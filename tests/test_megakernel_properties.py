"""Property-based differential harness for the AP megakernel.

Random op groups (word widths 1-64, random element counts, mask
patterns, conditional structure) and random PassSchedules are executed
across every execution path the engine offers and pinned bit-identical:

* an independent pure-numpy oracle (written here, sharing no code with
  the executors) vs the fused-scan jnp reference;
* the jnp reference vs the Pallas megakernel (interpret mode on CPU),
  including multi-block lane tilings;
* the eager engine vs ``backend="megakernel"`` /
  ``"megakernel_pallas"`` at the :class:`~repro.core.engine.APEngine`
  level — planes, tag, cycles, energy, events AND the trace arrays;
* eager vs device vs megakernel full workloads (sort/knn/hist) through
  the registry;
* unsharded vs 1/2/4-device ``shard_map`` execution (subprocess, slow
  lane — XLA host device count must be forced before jax initializes).

Strategies draw only scalars (the vendored fallback shim in
``tests/_fallback`` supports no ``composite``); arrays come from a
``np.random.default_rng`` seeded by a drawn integer, so examples are
reproducible from the hypothesis report alone.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import bitplane as bp
from repro.core.engine import APEngine, PassSchedule
from repro.kernels.ap_megakernel import (MAX_COND, OP_CMP, OP_CMP_TAG,
                                         OP_PASS, OP_WRITE, OpGroup,
                                         run_group)
from repro.workloads import registry

pytestmark = [pytest.mark.megakernel, pytest.mark.pallas]


# ---------------------------------------------------------------------------
# independent numpy oracle (shares no code with ref.group_scan)
# ---------------------------------------------------------------------------

def _np_group_oracle(bits, tag, group, enabled):
    """Sequential bool-matrix executor for an op group.

    bits: bool[n_bits, n_words]; tag: bool[n_words].  Returns
    (bits', tag', matched int64[P], executed bool[P]).
    """
    op, cond, cc, ck, wc, wk = group.tables()
    P = group.n_ops
    bits, tag = bits.copy(), tag.copy()
    matched = np.zeros(P, np.int64)
    executed = np.zeros(P, bool)
    hist = [0] * MAX_COND
    for p in range(P):
        t = np.ones(bits.shape[1], bool)
        for c, k in zip(cc[p], ck[p]):
            t &= bits[c] == bool(k)
        if op[p] == OP_CMP_TAG:
            t &= tag
        wtag = tag if op[p] == OP_WRITE else t
        m = int(wtag.sum())
        prev = hist[MAX_COND - cond[p]] if cond[p] > 0 else 1
        if bool(enabled[p]) and prev > 0:
            if op[p] in (OP_PASS, OP_WRITE):
                for c, k in zip(wc[p], wk[p]):
                    bits[c][wtag] = bool(k)
            if op[p] in (OP_CMP, OP_CMP_TAG):
                tag = t
            matched[p], executed[p] = m, True
        hist = hist[1:] + [int(matched[p])]
    return bits, tag, matched, executed


def _random_group(rng, n_bits, P, conditional):
    ops_ = []
    for p in range(P):
        opc = int(rng.choice([OP_PASS, OP_CMP, OP_CMP_TAG, OP_WRITE]))
        cond = (int(rng.integers(0, min(p, MAX_COND) + 1))
                if conditional else 0)
        nc = int(rng.integers(1, min(n_bits, 3) + 1))
        cc = rng.choice(n_bits, size=nc, replace=False)
        nw = int(rng.integers(1, min(n_bits, 2) + 1))
        wc = rng.choice(n_bits, size=nw, replace=False)
        ops_.append((opc, cond, list(cc),
                     list(rng.integers(0, 2, nc)),
                     list(wc), list(rng.integers(0, 2, nw))))
    return OpGroup.build(ops_)


def _random_state(rng, n_bits, n_words):
    """(planes uint32[n_bits, lanes], tag uint32[lanes], bool mirrors)."""
    bits = rng.integers(0, 2, (n_bits, n_words)).astype(bool)
    tag = rng.integers(0, 2, n_words).astype(bool)
    planes = jnp.stack([bp.pack_bits(row) for row in bits])
    return planes, bp.pack_bits(tag), bits, tag


def _unpack(planes, tag, n_bits, n_words):
    bits = np.stack([np.asarray(bp.unpack_bits(planes[i]), bool)[:n_words]
                     for i in range(n_bits)])
    return bits, np.asarray(bp.unpack_bits(tag), bool)[:n_words]


# word widths 1-64, element counts over 1-3 packed lanes, shapes
# bucketed so the jit cache stays bounded across examples
_SEED = st.integers(0, 2 ** 31 - 1)
_NBITS = st.sampled_from((1, 2, 7, 33, 64))
_NWORDS = st.sampled_from((32, 64, 96))
_P = st.integers(1, 8)


@settings(max_examples=25)
@given(seed=_SEED, n_bits=_NBITS, n_words=_NWORDS, P=_P,
       conditional=st.booleans(), mask=st.booleans())
def test_group_jnp_matches_numpy_oracle(seed, n_bits, n_words, P,
                                        conditional, mask):
    """Fused-scan executor == independent sequential numpy oracle."""
    rng = np.random.default_rng(seed)
    group = _random_group(rng, n_bits, P, conditional)
    planes, tag, bits, tbits = _random_state(rng, n_bits, n_words)
    enabled = rng.integers(0, 2, P).astype(bool) if mask \
        else np.ones(P, bool)

    b_ref, t_ref, m_ref, _ = _np_group_oracle(bits, tbits, group, enabled)
    planes2, tag2, matched = run_group(planes, tag, group, enabled)
    b_got, t_got = _unpack(planes2, tag2, n_bits, n_words)
    np.testing.assert_array_equal(b_got, b_ref)
    np.testing.assert_array_equal(t_got, t_ref)
    np.testing.assert_array_equal(np.asarray(matched, np.int64), m_ref)


@settings(max_examples=25)
@given(seed=_SEED, n_bits=_NBITS, n_words=_NWORDS, P=_P,
       conditional=st.booleans(), block=st.sampled_from((32, 512)))
def test_group_pallas_matches_jnp(seed, n_bits, n_words, P, conditional,
                                  block):
    """Pallas megakernel (interpret mode, incl. multi-block lane
    tilings) == jnp reference, bitwise."""
    rng = np.random.default_rng(seed)
    group = _random_group(rng, n_bits, P, conditional)
    planes, tag, _, _ = _random_state(rng, n_bits, n_words)
    enabled = rng.integers(0, 2, P).astype(bool)

    p_ref, t_ref, m_ref = run_group(planes, tag, group, enabled)
    p_pal, t_pal, m_pal = run_group(planes, tag, group, enabled,
                                    backend="pallas", block_lanes=block)
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pal))
    np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_pal))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_pal))


# ---------------------------------------------------------------------------
# engine-level differential: eager vs megakernel vs megakernel_pallas
# ---------------------------------------------------------------------------

def _random_schedule(rng, n_bits, n_passes):
    passes = []
    for _ in range(n_passes):
        nc = int(rng.integers(1, min(n_bits, 3) + 1))
        cc = rng.choice(n_bits, size=nc, replace=False)
        nw = int(rng.integers(1, min(n_bits, 2) + 1))
        wc = rng.choice(n_bits, size=nw, replace=False)
        passes.append((list(cc), list(rng.integers(0, 2, nc)),
                       list(wc), list(rng.integers(0, 2, nw))))
    return PassSchedule.build(passes)


def assert_counters_identical(a: dict, b: dict):
    assert set(a) == set(b)
    for k in sorted(a):
        va, vb = a[k], b[k]
        if isinstance(va, np.ndarray):
            assert va.dtype == vb.dtype, k
            np.testing.assert_array_equal(va, vb, err_msg=k)
        else:
            assert va == vb, (k, va, vb)


@settings(max_examples=10)
@given(seed=_SEED, n_bits=st.sampled_from((2, 7, 16)),
       n_words=_NWORDS, n_sched=st.integers(1, 3))
def test_engine_run_backends_bit_identical(seed, n_bits, n_words, n_sched):
    """APEngine.run on random schedules: eager jnp vs megakernel vs
    megakernel_pallas give identical planes, tag, counters AND trace."""
    rng = np.random.default_rng(seed)
    scheds = [_random_schedule(rng, n_bits, int(rng.integers(1, 6)))
              for _ in range(n_sched)]
    vals = rng.integers(0, 1 << n_bits, n_words, dtype=np.uint64)

    engines = []
    for be in ("jnp", "megakernel", "megakernel_pallas"):
        eng = APEngine(n_words=n_words, n_bits=n_bits, backend=be)
        f = eng.alloc.alloc(n_bits, "v")
        eng.load(f, vals)
        for sched in scheds:
            eng.run(sched)
        eng.compare([f.col(0)], [1])        # shared non-run op path
        engines.append(eng)

    ref = engines[0]
    for eng in engines[1:]:
        np.testing.assert_array_equal(np.asarray(ref.planes),
                                      np.asarray(eng.planes))
        np.testing.assert_array_equal(np.asarray(ref.tag),
                                      np.asarray(eng.tag))
        a, b = ref.counters(), eng.counters()
        a["trace_cycles"], a["trace_energy"] = ref.trace_events()
        b["trace_cycles"], b["trace_energy"] = eng.trace_events()
        assert_counters_identical(a, b)


# ---------------------------------------------------------------------------
# workload-level differential through the registry
# ---------------------------------------------------------------------------

@settings(max_examples=8)
@given(name=st.sampled_from(("sort", "knn", "hist", "spmv")),
       n=st.sampled_from((33, 48, 64)))
def test_workload_modes_bit_identical(name, n):
    """eager == device == megakernel for full workload runs: values,
    cycles, energy, event counters and both trace arrays."""
    ce = registry.trace_counters(name, n, mode="eager")
    cd = registry.trace_counters(name, n, mode="device")
    cm = registry.trace_counters(name, n, mode="megakernel")
    assert_counters_identical(ce, cd)
    assert_counters_identical(ce, cm)


def test_engine_rejects_bad_shard_config():
    with pytest.raises(ValueError, match="megakernel"):
        APEngine(n_words=64, n_bits=4, backend="jnp", n_shards=2)
    with pytest.raises(ValueError, match="divisible"):
        APEngine(n_words=32, n_bits=4, backend="megakernel", n_shards=3)


# ---------------------------------------------------------------------------
# interpret-mode Pallas coverage: all three kernel families in tier-1
# ---------------------------------------------------------------------------

def test_interpret_mode_kernel_coverage():
    """ap_megakernel + ap_match + mg_smooth all execute under
    ``pl.pallas_call(..., interpret=True)`` and match their oracles —
    the tier-1 suite exercises every Pallas kernel family on CPU."""
    rng = np.random.default_rng(0)

    group = _random_group(rng, 8, 6, conditional=True)
    planes, tag, _, _ = _random_state(rng, 8, 64)
    ref = run_group(planes, tag, group)
    pal = run_group(planes, tag, group, backend="pallas", interpret=True)
    for a, b in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    from repro.kernels.ap_match import ops as match_ops
    sched = _random_schedule(rng, 8, 5)
    p_ref, m_ref = match_ops.run_schedule(
        planes, sched.cmp_cols, sched.cmp_key, sched.w_cols, sched.w_key,
        backend="jnp")
    p_pal, m_pal = match_ops.run_schedule(
        planes, sched.cmp_cols, sched.cmp_key, sched.w_cols, sched.w_key,
        backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pal))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_pal))

    from repro.core import multigrid as mg
    from repro.core import thermal
    from repro.kernels.mg_smooth import ops as mg_ops
    from repro.stack.spec import dram_on_logic
    grid = thermal.Grid(die_w=5e-3, ny=16, nx=16, margin=4,
                        spec=dram_on_logic(1))
    F = grid.fields()
    shape = F["g_pkg"].shape
    T = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    b = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    ref_T = mg.rb_line_sweep(T, b, F, 0.5, 0)
    pal_T = mg_ops.rb_line_sweep(T, b, F, 0.5, 0, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_T), np.asarray(ref_T),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# shard invariance: 1/2/4 forced host devices in a subprocess
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
import jax.numpy as jnp
from repro.core import bitplane as bp
from repro.parallel.sharding import ap_mesh
from repro.kernels.ap_megakernel import run_group, OpGroup
from repro.workloads import sort, histogram
from test_megakernel_properties import (_np_group_oracle, _random_group,
                                        _random_state, _unpack,
                                        assert_counters_identical)

rng = np.random.default_rng(123)
# raw op groups: every shard count == the numpy oracle, bitwise
for trial in range(6):
    n_bits = int(rng.choice([1, 7, 33]))
    n_words = 128                      # 4 lanes: divisible by 1/2/4
    group = _random_group(rng, n_bits, int(rng.integers(1, 8)),
                          conditional=bool(trial % 2))
    planes, tag, bits, tbits = _random_state(rng, n_bits, n_words)
    enabled = rng.integers(0, 2, group.n_ops).astype(bool)
    b_ref, t_ref, m_ref, _ = _np_group_oracle(bits, tbits, group, enabled)
    for ns in (None, 1, 2, 4):
        mesh = None if ns is None else ap_mesh(ns)
        p2, t2, m2 = run_group(planes, tag, group, enabled, mesh=mesh)
        b_got, t_got = _unpack(p2, t2, n_bits, n_words)
        np.testing.assert_array_equal(b_got, b_ref, err_msg=f"ns={ns}")
        np.testing.assert_array_equal(t_got, t_ref, err_msg=f"ns={ns}")
        np.testing.assert_array_equal(np.asarray(m2, np.int64), m_ref,
                                      err_msg=f"ns={ns}")

# full workloads: counters + traces invariant to the shard count
x = rng.integers(0, 256, 128, dtype=np.uint64)
runs = {ns: sort.ap_sort(x, m=8, mode="megakernel", n_shards=ns)
        for ns in (None, 1, 2, 4)}
for ns in (1, 2, 4):
    np.testing.assert_array_equal(runs[None][0], runs[ns][0])
    assert_counters_identical(runs[None][1], runs[ns][1])
h = rng.integers(0, 64, 100, dtype=np.uint64)
hr = {ns: histogram.ap_histogram(h, 8, m=6, mode="megakernel",
                                 n_shards=ns) for ns in (None, 2, 4)}
for ns in (2, 4):
    np.testing.assert_array_equal(hr[None][0], hr[ns][0])
    assert_counters_identical(hr[None][1], hr[ns][1])
print("MEGAKERNEL-SHARD-INVARIANCE-OK")
"""


@pytest.mark.slow
def test_shard_invariance_subprocess():
    """Unsharded vs 1/2/4-device shard_map: op groups match the numpy
    oracle and full workload counters/traces are bitwise invariant."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # tests/ for this module's helpers; _fallback so the subprocess can
    # import hypothesis even where the real package is absent (conftest
    # does this for the in-process suite)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "tests"),
         os.path.join(root, "tests", "_fallback"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MEGAKERNEL-SHARD-INVARIANCE-OK" in proc.stdout
