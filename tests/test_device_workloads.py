"""Device-resident execution vs the eager per-cycle oracle.

The device programs (workloads/_device.py) must be *bit-identical* to
the eager APEngine path — same values, same cycle counters, same energy
float, same (cycle, energy) trace events — on both the jnp and Pallas
schedule backends.  Plus: the shape-bucketed jit cache must not retrace
for two schedules in one bucket, and the width-64 / empty-concat
guards raise clearly.
"""
import numpy as np
import pytest

from repro.core import bitplane as bp
from repro.core import engine as E
from repro.core.engine import APEngine, PassSchedule
from repro.workloads import _device
from repro.workloads import histogram as hist
from repro.workloads import knn, registry, sort, spmv

BACKENDS = ("jnp", "pallas")


def assert_counters_identical(ce: dict, cd: dict) -> None:
    """Counters dicts equal bit-for-bit (ints ==, floats ==, arrays ==)."""
    assert set(ce) == set(cd)
    for k in ce:
        if isinstance(ce[k], np.ndarray):
            assert ce[k].dtype == cd[k].dtype, k
            np.testing.assert_array_equal(ce[k], cd[k], err_msg=k)
        else:
            assert ce[k] == cd[k], (k, ce[k], cd[k])


# ------------------------------------------------------- sort / knn / hist
@pytest.mark.parametrize("backend", BACKENDS)
def test_sort_device_matches_eager(backend):
    rng = np.random.default_rng(0)
    x = rng.integers(0, 200, 150, dtype=np.uint64)  # ties + 2 lane groups
    ye, ce = sort.ap_sort(x, m=8, backend=backend, mode="eager")
    yd, cd = sort.ap_sort(x, m=8, backend=backend, mode="device")
    np.testing.assert_array_equal(ye, yd)
    np.testing.assert_array_equal(yd, sort.reference(x))
    assert_counters_identical(ce, cd)


@pytest.mark.parametrize("backend", BACKENDS)
def test_knn_device_matches_eager(backend):
    rng = np.random.default_rng(1)
    db = rng.integers(0, 16, (96, 4), dtype=np.uint64)
    q = rng.integers(0, 16, 4, dtype=np.uint64)
    ie, ce = knn.ap_knn(db, q, k=7, m=4, backend=backend, mode="eager")
    idd, cd = knn.ap_knn(db, q, k=7, m=4, backend=backend, mode="device")
    np.testing.assert_array_equal(ie, idd)
    np.testing.assert_array_equal(idd, knn.reference(db, q, 7))
    assert_counters_identical(ce, cd)


@pytest.mark.parametrize("backend", BACKENDS)
def test_hist_device_matches_eager(backend):
    rng = np.random.default_rng(2)
    x = rng.integers(0, 64, 300, dtype=np.uint64)
    he, ce = hist.ap_histogram(x, 16, m=6, backend=backend, mode="eager")
    hd, cd = hist.ap_histogram(x, 16, m=6, backend=backend, mode="device")
    np.testing.assert_array_equal(he, hd)
    np.testing.assert_array_equal(hd, hist.reference(x, 16, m=6))
    assert_counters_identical(ce, cd)


@pytest.mark.parametrize("backend", BACKENDS)
def test_spmv_device_matches_eager(backend):
    rng = np.random.default_rng(3)
    n_rows, nnz = 10, 64
    r = rng.integers(0, n_rows, nnz)
    c = rng.integers(0, n_rows, nnz)
    v = rng.integers(0, 50, nnz, dtype=np.uint64)
    x = rng.integers(0, 50, n_rows, dtype=np.uint64)
    ye, ce = spmv.ap_spmv(r, c, v, x, n_rows, m=6, backend=backend,
                          mode="eager")
    yd, cd = spmv.ap_spmv(r, c, v, x, n_rows, m=6, backend=backend,
                          mode="device")
    np.testing.assert_array_equal(ye, yd)
    np.testing.assert_array_equal(yd, spmv.reference(r, c, v, x, n_rows))
    assert_counters_identical(ce, cd)


def test_registry_mode_roundtrip():
    """trace_counters(mode=...) produces identical counters both ways for
    every data-dependent suite workload (registry-level equivalence)."""
    for name in ("sort", "knn", "hist", "spmv"):
        cd = registry.trace_counters(name, 48, mode="device")
        ce = registry.trace_counters(name, 48, mode="eager")
        assert_counters_identical(ce, cd)


def test_registry_equivalence_at_lifted_trace_clamp():
    """The acceptance size: device == eager exactly at n_elems = 2048,
    the new `cosim.trace_elems` ceiling (old clamp: 256)."""
    from repro.core import cosim

    assert cosim.trace_elems(2048 ** 2) == 2048
    for name in ("sort", "knn", "hist", "spmv"):
        cd = registry.trace_counters(name, 2048, mode="device")
        ce = registry.trace_counters(name, 2048, mode="eager")
        assert_counters_identical(ce, cd)


def test_sort_device_handles_early_exhaustion_and_empty():
    """count==0 break and n=0 behave like the eager loop."""
    y, ctr = sort.ap_sort(np.zeros(0, np.uint64), m=4)
    assert y.shape == (0,)
    ye, ce = sort.ap_sort(np.array([7, 7, 7], np.uint64), m=3, mode="eager")
    yd, cd = sort.ap_sort(np.array([7, 7, 7], np.uint64), m=3, mode="device")
    np.testing.assert_array_equal(ye, yd)
    assert_counters_identical(ce, cd)


# ----------------------------------------- on-device counter accumulators
def test_device_counters_cross_check_host_replay():
    """The APState counters a min-extraction program accumulates on
    device equal the host charge_* replay's counter deltas exactly."""
    rng = np.random.default_rng(4)
    x = rng.integers(0, 32, 64, dtype=np.uint64)
    n = x.shape[0]
    eng = APEngine(n_words=64, n_bits=sort.plan_bits(5))
    val = eng.alloc.alloc(5, "val")
    active = eng.alloc.alloc(1, "active")
    cand = eng.alloc.alloc(1, "cand")
    eng.load(val, x)
    eng.load(active, np.ones(n, np.uint64))

    before = eng.counters()
    tr = _device.min_extract_rounds(eng, val, active, cand,
                                    rounds=min(n, 32), remaining=n)
    out: list[int] = []
    r = 0
    while len(out) < n:
        v, count = _device.replay_extract(eng, tr, r, 5)
        if count == 0:
            break
        out.extend([v] * count)
        eng.charge_write(1, count)
        r += 1
    after = eng.counters()
    np.testing.assert_array_equal(np.sort(x), np.asarray(out, np.uint64))

    dc = tr.device_counters
    assert dc[E.CTR_CYCLES] == after["cycles"] - before["cycles"]
    assert dc[E.CTR_COMPARE] == (after["compare_cycles"]
                                 - before["compare_cycles"])
    assert dc[E.CTR_WRITE] == after["write_cycles"] - before["write_cycles"]
    assert dc[E.CTR_READ] == after["read_cycles"] - before["read_cycles"]
    assert dc[E.CTR_MATCH] == after["match"] - before["match"]
    # masked rounds really were masked on device
    assert tr.masked.sum() == tr.masked.shape[0] - r


# --------------------------------------------------- shape-bucketed cache
def test_same_bucket_compiles_once():
    """Two schedules with different (P, Kc) in one power-of-two bucket
    must share a single compiled program (no retrace).

    The jnp runner's obs counter increments at TRACE time only, so with
    obs forced on it counts distinct compiles of ``_run_schedule``."""
    from repro import obs

    def sched_of(n_passes, kc):
        passes = [(list(range(kc)), [1] * kc, [kc], [0])
                  for _ in range(n_passes)]
        return PassSchedule.build(passes)

    # unusual n_bits so no earlier test populated this plane shape
    eng = APEngine(n_words=64, n_bits=23)
    with obs.scoped():
        eng.run(sched_of(5, 3))                # traces the (8, 4, 1) bucket
        baseline = obs.value("engine/retrace/run_schedule")
        eng.run(sched_of(7, 4))                # same (8, 4, 1) bucket: hit
        eng.run(sched_of(8, 2))                # (8, 2, 1): a fresh bucket
        assert obs.value("engine/retrace/run_schedule") == baseline + 1


def test_bucketed_run_results_and_accounting_unpadded():
    """Padding must not change results, cycles, or energy: a bucketed
    run equals pass-by-pass eager execution of the same schedule."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 1 << 6, 64, dtype=np.uint64)
    engs = []
    for _ in range(2):
        eng = APEngine(n_words=64, n_bits=8)
        f = eng.alloc.alloc(6)
        eng.load(f, x)
        engs.append((eng, f))
    (eng_run, f), (eng_eager, f2) = engs
    passes = [([f.col(0), f.col(1)], [1, 0], [f.col(2)], [1]),
              ([f.col(2), f.col(3), f.col(4)], [1, 1, 0], [f.col(5)], [0]),
              ([f.col(5)], [0], [f.col(0), f.col(1)], [1, 1])]
    sched = PassSchedule.build(passes)      # P=3, Kc=3, Kw=2 -> padded
    eng_run.run(sched)
    for cc, ck, wc, wk in passes:
        eng_eager.compare(cc, ck)
        eng_eager.write(wc, wk)
    np.testing.assert_array_equal(eng_run.peek(f), eng_eager.peek(f2))
    assert eng_run.energy == eng_eager.energy
    assert eng_run.cycles == eng_eager.cycles
    assert eng_run.events == eng_eager.events


# ----------------------------------------------------------- guard rails
def test_pack_words_rejects_width_over_64():
    with pytest.raises(ValueError, match="64"):
        bp.pack_words(np.zeros(32, np.uint64), 65)


def test_engine_load_rejects_wide_field():
    eng = APEngine(n_words=32, n_bits=80)
    wide = eng.alloc.alloc(72, "wide")
    with pytest.raises(ValueError, match="64"):
        eng.load(wide, np.zeros(32, np.uint64))


def test_concat_empty_schedule_list_raises():
    with pytest.raises(ValueError, match="empty schedule list"):
        PassSchedule.concat([])
    with pytest.raises(ValueError, match="empty pass schedule"):
        PassSchedule.build([])


def test_bucket_empty_schedule_raises():
    """bucket_schedule(P=0) used to fall through to _next_pow2 and
    produce a nonsense 1-pass bucket; now it refuses up front with a
    pointer at the PassSchedule.build contract."""
    empty = PassSchedule(cmp_cols=np.zeros((0, 1), np.int32),
                         cmp_key=np.zeros((0, 1), np.uint32),
                         w_cols=np.zeros((0, 1), np.int32),
                         w_key=np.zeros((0, 1), np.uint32),
                         kc=np.zeros(0, np.int32),
                         kw=np.zeros(0, np.int32))
    with pytest.raises(ValueError, match="nothing to bucket"):
        E.bucket_schedule(empty)
