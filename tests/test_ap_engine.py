"""Unit tests for the AP engine + ISA: correctness vs numpy and the paper's
cycle-count claims (8m add, O(m^2) multiply)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitplane as bp
from repro.core import isa, arith
from repro.core.engine import APEngine

N = 256  # words per test engine (multiple of 32)


def make_engine(n_bits=128, n=N):
    return APEngine(n_words=n, n_bits=n_bits)


def rand(n, m, seed):
    return np.random.default_rng(seed).integers(0, 1 << m, size=n, dtype=np.uint64)


# ----------------------------------------------------------------- bitplane
def test_pack_unpack_roundtrip():
    v = rand(N, 17, 0)
    planes = bp.pack_words(v, 17)
    assert planes.shape == (17, N // 32)
    out = np.asarray(bp.unpack_words(planes))
    np.testing.assert_array_equal(out, v)


def test_pack_bits_roundtrip():
    rng = np.random.default_rng(1)
    b = rng.integers(0, 2, size=N).astype(bool)
    row = bp.pack_bits(b)
    np.testing.assert_array_equal(np.asarray(bp.unpack_bits(row)), b)


def test_compare_matches_numpy():
    eng = make_engine()
    f = eng.alloc.alloc(8)
    v = rand(N, 8, 2)
    eng.load(f, v)
    # compare bits 1,3,5 against key (1,0,1)
    cols, key = [f.col(1), f.col(3), f.col(5)], [1, 0, 1]
    eng.compare(cols, key)
    got = np.asarray(bp.unpack_bits(eng.tag))
    want = (((v >> 1) & 1) == 1) & (((v >> 3) & 1) == 0) & (((v >> 5) & 1) == 1)
    np.testing.assert_array_equal(got, want)
    assert eng.compare_cycles == 1 and eng.cycles == 1


def test_tagged_write_only_hits_tagged_rows():
    eng = make_engine()
    f = eng.alloc.alloc(4)
    v = rand(N, 4, 3)
    eng.load(f, v)
    eng.compare([f.col(0)], [1])               # tag rows with LSB set
    eng.write([f.col(1), f.col(2)], [1, 0])
    got = eng.peek(f)
    want = v.copy()
    sel = (v & 1) == 1
    want[sel] = (want[sel] & ~np.uint64(0b0110)) | np.uint64(0b0010)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------- add
@pytest.mark.parametrize("m", [4, 8, 32])
def test_add_correct_and_8m_cycles(m):
    eng = make_engine()
    a, b, c = eng.alloc.alloc(m), eng.alloc.alloc(m), eng.alloc.alloc(1)
    va, vb = rand(N, m, 4), rand(N, m, 5)
    eng.load(a, va)
    eng.load(b, vb)
    eng.clear(c)
    base = eng.cycles
    eng.run(isa.add(a, b, c))
    assert eng.cycles - base == 8 * m, "paper claims exactly 8m cycles"
    full = va + vb
    np.testing.assert_array_equal(eng.peek(b), full & ((1 << m) - 1))
    np.testing.assert_array_equal(eng.peek(c), (full >> m) & 1)


@pytest.mark.parametrize("m", [4, 16])
def test_sub_correct(m):
    eng = make_engine()
    a, b, br = eng.alloc.alloc(m), eng.alloc.alloc(m), eng.alloc.alloc(1)
    va, vb = rand(N, m, 6), rand(N, m, 7)
    eng.load(a, va)
    eng.load(b, vb)
    isa.run_sub(eng, a, b, br)
    np.testing.assert_array_equal(eng.peek(b), (vb - va) & ((1 << m) - 1))
    np.testing.assert_array_equal(eng.peek(br), (vb < va).astype(np.uint64))


def test_const_add():
    m, k = 12, 1234
    eng = make_engine()
    b, c = eng.alloc.alloc(m), eng.alloc.alloc(1)
    vb = rand(N, m, 8)
    eng.load(b, vb)
    eng.clear(c)
    base = eng.cycles
    eng.run(isa.const_add(b, k, c))
    assert eng.cycles - base == 4 * m
    np.testing.assert_array_equal(eng.peek(b), (vb + k) & ((1 << m) - 1))


def test_copy_and_cond_copy():
    m = 9
    eng = make_engine()
    src, dst, cond = eng.alloc.alloc(m), eng.alloc.alloc(m), eng.alloc.alloc(1)
    vs, vd = rand(N, m, 9), rand(N, m, 10)
    cnd = rand(N, 1, 11)
    eng.load(src, vs)
    eng.load(dst, vd)
    eng.load(cond, cnd)
    eng.run(isa.cond_copy(dst, src, cond))
    want = np.where(cnd == 1, vs, vd)
    np.testing.assert_array_equal(eng.peek(dst), want)
    eng.run(isa.copy(dst, src))
    np.testing.assert_array_equal(eng.peek(dst), vs)


def test_eq_gt_flags():
    m = 8
    eng = make_engine()
    a, b = eng.alloc.alloc(m), eng.alloc.alloc(m)
    fl, gt, dec = eng.alloc.alloc(1), eng.alloc.alloc(1), eng.alloc.alloc(1)
    va, vb = rand(N, m, 12), rand(N, m, 13)
    va[:16] = vb[:16]  # force some equalities
    eng.load(a, va)
    eng.load(b, vb)
    eng.set_bits(fl, 1)
    eng.run(isa.eq_flag(a, b, fl))
    np.testing.assert_array_equal(eng.peek(fl), (va == vb).astype(np.uint64))
    eng.clear(gt)
    eng.clear(dec)
    eng.run(isa.gt_flag(a, b, gt, dec))
    np.testing.assert_array_equal(eng.peek(gt), (va > vb).astype(np.uint64))


def test_lut():
    eng = make_engine()
    arg, out = eng.alloc.alloc(6), eng.alloc.alloc(12)
    v = rand(N, 6, 14)
    eng.load(arg, v)
    eng.clear(out)
    fn = lambda x: (x * x + 3) & 0xFFF
    eng.run(isa.lut(arg, out, fn))
    np.testing.assert_array_equal(eng.peek(out),
                                  np.array([fn(int(x)) for x in v], np.uint64))


# ---------------------------------------------------------------- mul / div
@pytest.mark.parametrize("m", [4, 8, 16])
def test_mul_correct_and_quadratic_cycles(m):
    eng = make_engine(n_bits=6 * m + 8)
    a, b = eng.alloc.alloc(m), eng.alloc.alloc(m)
    p, c = eng.alloc.alloc(2 * m + 1), eng.alloc.alloc(1)
    va, vb = rand(N, m, 15), rand(N, m, 16)
    eng.load(a, va)
    eng.load(b, vb)
    base = eng.cycles
    arith.run_mul(eng, a, b, p, c)
    took = eng.cycles - base
    assert took <= 10 * m * (m + 2), f"multiply should be O(m^2), took {took}"
    assert took >= 8 * m * m
    np.testing.assert_array_equal(eng.peek(p), va * vb)


def test_mac_accumulates():
    m = 6
    eng = make_engine()
    a, b = eng.alloc.alloc(m), eng.alloc.alloc(m)
    acc, c = eng.alloc.alloc(2 * m + 4), eng.alloc.alloc(1)
    eng.clear(acc)
    total = np.zeros(N, np.uint64)
    for seed in (20, 21, 22):
        va, vb = rand(N, m, seed), rand(N, m, seed + 100)
        eng.load(a, va)
        eng.load(b, vb)
        arith.run_mac(eng, a, b, acc, c)
        total += va * vb
    np.testing.assert_array_equal(eng.peek(acc), total)


@pytest.mark.parametrize("m", [4, 8])
def test_div_correct(m):
    eng = make_engine(n_bits=8 * m + 16)
    a, b = eng.alloc.alloc(m), eng.alloc.alloc(m)
    q = eng.alloc.alloc(m)
    wide = eng.alloc.alloc(2 * m + 1)
    trial = eng.alloc.alloc(m + 1)
    br, qb = eng.alloc.alloc(1), eng.alloc.alloc(1)
    va = rand(N, m, 23)
    vb = np.maximum(rand(N, m, 24), 1)  # avoid div by zero
    eng.load(a, va)
    eng.load(b, vb)
    arith.run_div(eng, a, b, q, wide, trial, br, qb)
    np.testing.assert_array_equal(eng.peek(q), va // vb)
    np.testing.assert_array_equal(eng.peek(wide)[:] & ((1 << m) - 1)
                                  if False else eng.peek(wide.slice(0, m)),
                                  va % vb)


# ------------------------------------------------------------ property tests
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 20), st.integers(0, 2**32 - 1))
def test_add_property(m, seed):
    eng = APEngine(n_words=64, n_bits=3 * m + 2)
    a, b, c = eng.alloc.alloc(m), eng.alloc.alloc(m), eng.alloc.alloc(1)
    va, vb = rand(64, m, seed), rand(64, m, seed + 1)
    eng.load(a, va)
    eng.load(b, vb)
    isa.run_add(eng, a, b, c)
    np.testing.assert_array_equal(eng.peek(b), (va + vb) & ((1 << m) - 1))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 10), st.integers(0, 2**32 - 1))
def test_mul_property(m, seed):
    eng = APEngine(n_words=64, n_bits=4 * m + 4)
    a, b = eng.alloc.alloc(m), eng.alloc.alloc(m)
    p, c = eng.alloc.alloc(2 * m + 1), eng.alloc.alloc(1)
    va, vb = rand(64, m, seed), rand(64, m, seed + 1)
    eng.load(a, va)
    eng.load(b, vb)
    arith.run_mul(eng, a, b, p, c)
    np.testing.assert_array_equal(eng.peek(p), va * vb)
