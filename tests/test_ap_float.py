"""FP32 on the AP: correctness vs numpy float32 and the paper's cycle claims
(~4400-cycle FP32 multiply, length-independent)."""
import numpy as np
import pytest

from repro.core import apfloat
from repro.core.engine import APEngine


def build(n=128, n_bits=352):
    eng = APEngine(n_words=n, n_bits=n_bits)
    x = apfloat.FpField.alloc(eng)
    y = apfloat.FpField.alloc(eng)
    out = apfloat.FpField.alloc(eng)
    scr = apfloat.FpScratch.alloc(eng)
    return eng, x, y, out, scr


def rand_fp(n, seed, lo=-100.0, hi=100.0):
    rng = np.random.default_rng(seed)
    v = rng.uniform(lo, hi, size=n).astype(np.float32)
    v[v == 0] = 1.0
    return v


def ulp_diff(a, b):
    ai = a.view(np.int32).astype(np.int64)
    bi = b.view(np.int32).astype(np.int64)
    # map negative floats to a monotonic integer line
    ai = np.where(ai < 0, np.int64(-2**31) - ai, ai)
    bi = np.where(bi < 0, np.int64(-2**31) - bi, bi)
    return np.abs(ai - bi)


def test_fp_load_read_roundtrip():
    eng, x, _, _, _ = build()
    v = rand_fp(128, 0)
    apfloat.load_fp32(eng, x, v)
    got = apfloat.read_fp32(eng, x)
    np.testing.assert_array_equal(got, v)


def test_fp_mul_correct_and_cycle_count():
    eng, x, y, out, scr = build()
    va, vb = rand_fp(128, 1), rand_fp(128, 2)
    va[:4] = [0.0, 3.5, 0.0, -1.25]
    vb[:4] = [2.0, 0.0, 0.0, -8.0]
    apfloat.load_fp32(eng, x, va)
    apfloat.load_fp32(eng, y, vb)
    base = eng.cycles
    apfloat.fp_mul(eng, x, y, out, scr)
    took = eng.cycles - base
    got = apfloat.read_fp32(eng, out)
    want = va * vb
    assert ulp_diff(got, want).max() <= 2, (got[:8], want[:8])
    # paper claims ~4400 for the optimized direct implementation; ours is the
    # same O(m^2) structure within ~25%
    assert 4000 <= took <= 5800, took


def test_fp_mul_cycles_independent_of_vector_length():
    counts = []
    for n in (64, 1024):
        eng, x, y, out, scr = build(n=n)
        apfloat.load_fp32(eng, x, rand_fp(n, 3))
        apfloat.load_fp32(eng, y, rand_fp(n, 4))
        base = eng.cycles
        apfloat.fp_mul(eng, x, y, out, scr)
        counts.append(eng.cycles - base)
    assert counts[0] == counts[1], "word-parallel: cycles must not depend on N"


@pytest.mark.parametrize("case", ["same_sign", "mixed", "cancel", "far"])
def test_fp_add_correct(case):
    n = 128
    eng, x, y, out, scr = build(n=n, n_bits=512)
    rng = np.random.default_rng(5)
    if case == "same_sign":
        va = rng.uniform(0.5, 50, n).astype(np.float32)
        vb = rng.uniform(0.5, 50, n).astype(np.float32)
    elif case == "mixed":
        va = rng.uniform(-50, 50, n).astype(np.float32)
        vb = rng.uniform(-50, 50, n).astype(np.float32)
    elif case == "cancel":
        va = rng.uniform(1, 2, n).astype(np.float32)
        vb = (-va * rng.choice([1.0, 0.5, 0.9990234375], n)).astype(np.float32)
    else:  # far: exponent gap > mantissa width
        va = rng.uniform(1e10, 1e12, n).astype(np.float32)
        vb = rng.uniform(1e-6, 1e-4, n).astype(np.float32)
    va[0], vb[0] = 0.0, 7.5
    va[1], vb[1] = -7.5, 0.0
    va[2], vb[2] = 0.0, 0.0
    va[3], vb[3] = 1.5, -1.5
    apfloat.load_fp32(eng, x, va)
    apfloat.load_fp32(eng, y, vb)
    apfloat.fp_add(eng, x, y, out, scr)
    got = apfloat.read_fp32(eng, out)
    want = va + vb
    exact_zero = want == 0
    assert np.all(got[exact_zero] == 0), (got[exact_zero][:5])
    nz = ~exact_zero
    # truncation rounding in add + alignment guard of 1 bit: allow 4 ulp
    assert ulp_diff(got[nz], want[nz]).max() <= 4, (
        got[nz][:8], want[nz][:8], ulp_diff(got[nz], want[nz]).max())
