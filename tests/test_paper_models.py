"""Validation of the paper's §3 analytic models against its own numbers.

Every assertion cites the paper location it reproduces (see DESIGN.md table).
"""
import numpy as np
import pytest

from repro.core import models as M


def test_ap_area_53mm2():
    """§3.1: n_AP = 2^20 PUs => A_AP = 53 mm^2."""
    dp = M.paper_design_point("dmm")
    assert dp.ap_area_mm2 == pytest.approx(53.0, rel=0.03), dp.ap_area_mm2


def test_simd_area_5p3mm2_at_768_pus():
    """§3.1: same-performance SIMD has 768 PUs and A_SIMD = 5.3 mm^2."""
    dp = M.paper_design_point("dmm")
    assert dp.simd_n_pus == pytest.approx(768, abs=2), dp.simd_n_pus
    assert dp.simd_area_mm2 == pytest.approx(5.3, rel=0.05), dp.simd_area_mm2


def test_dmm_speedup_350():
    """Fig 6 black dotted line: S = 350 at the comparison point."""
    dp = M.paper_design_point("dmm")
    assert dp.speedup == pytest.approx(350.0, rel=0.01)


def test_power_ratio_exceeds_2x():
    """Fig 7 / §3.2: 'SIMD consumes more than twice the power of AP'."""
    dp = M.paper_design_point("dmm")
    assert 2.0 < dp.power_ratio < 3.0, dp.power_ratio


def test_power_density_ratio_about_25x():
    """§3.2: 'the power density is about twenty five times higher'."""
    dp = M.paper_design_point("dmm")
    assert 20.0 < dp.power_density_ratio < 30.0, dp.power_density_ratio


def test_simd_speedup_saturates_ap_grows():
    """Fig 6 qualitative: SIMD speedup saturates at 1/I_s; AP is linear."""
    for wl in M.WORKLOADS.values():
        areas = np.geomspace(0.5, 20000, 40)  # mm^2 (far past saturation)
        s_simd, s_ap = M.speedup_vs_area_curves(wl.name, areas)
        assert s_simd[-1] <= 1.0 / wl.i_s + 1e-6
        # SIMD gains < 2% over the last decade of area -> saturation
        assert s_simd[-1] / max(s_simd[-10], 1e-9) < 1.05
        # AP speedup is linear in area
        ratio = s_ap[-1] / s_ap[0]
        assert ratio == pytest.approx(areas[-1] / areas[0], rel=1e-6)


def test_break_even_exists_for_every_workload():
    """Fig 6: every paper-band workload has a finite break-even area in
    the plotted range; the CAM-native suite workloads (sort/knn/hist)
    break even BELOW the search window — the AP wins at every area
    (DESIGN.md §3.2)."""
    for name in M.WORKLOADS:
        a = M.break_even_area_mm2(name)
        assert np.isfinite(a), (name, a)
        if name in ("sort", "knn", "hist"):
            assert a <= 0.01, (name, a)
        else:
            assert 0.01 < a < 1000, (name, a)


def test_break_even_ordering_follows_arithmetic_intensity():
    """Higher sync intensity (lower arithmetic intensity) => SIMD saturates
    sooner => AP breaks even at smaller area.  Fig 4: AI(bs) > AI(dmm) > ..."""
    b = {n: M.break_even_area_mm2(n) for n in M.WORKLOADS}
    # BS is embarrassingly parallel (tiny I_s): SIMD stays competitive longest
    assert b["bs"] > b["dmm"]


def test_ap_dynamic_power_bracket_matches_eq17():
    """eq (17) closed form: 1/8 + 7/8*0.1 + 3/16*0.1 + 21/16*0.75."""
    want = 1 / 8 + 7 / 8 * 0.1 + 3 / 16 * 0.1 + 21 / 16 * 0.75
    assert M.ap_dynamic_power_per_pu_norm() == pytest.approx(want)


def test_fft_same_area_same_perf_circle():
    """Fig 6/7 red circles: at FFT's break-even area both machines deliver the
    same speedup, and SIMD burns strictly more power there (§3.2)."""
    a_mm2 = M.break_even_area_mm2("fft")
    wl = M.WORKLOADS["fft"]
    a_norm = a_mm2 / (M.A_SRAM_UM2 * 1e-6)
    s_simd = M.simd_speedup(M.simd_n_pus(a_norm), wl)
    s_ap = M.ap_speedup(M.ap_n_pus(a_norm), wl)
    assert s_simd == pytest.approx(s_ap, rel=0.01)
    p_simd = M.simd_power_W(M.simd_n_pus(a_norm), wl)
    p_ap = M.ap_power_W(M.ap_n_pus(a_norm))
    assert p_simd > p_ap


def test_engine_measured_energy_matches_eq16_expectation():
    """The engine's measured per-pass energy equals the paper's closed-form
    expectation (eq 16) when match probability is 1/8 — i.e. on uniform
    random data through the full-adder pass schedule."""
    from repro.core import isa
    from repro.core.engine import APEngine
    rng = np.random.default_rng(0)
    n, m = 4096, 16
    eng = APEngine(n_words=n, n_bits=64)
    a, b, c = eng.alloc.alloc(m), eng.alloc.alloc(m), eng.alloc.alloc(1)
    eng.load(a, rng.integers(0, 1 << m, n, dtype=np.uint64))
    eng.load(b, rng.integers(0, 1 << m, n, dtype=np.uint64))
    eng.clear(c)
    e0 = eng.energy
    eng.run(isa.add(a, b, c))
    measured = eng.energy - e0
    # eq (16): per pass, 3-bit compare + 2-bit write with p(match)=1/8
    per_pass = 3 * (1 / 8 * M.P_MATCH + 7 / 8 * M.P_MISMATCH) \
        + 2 * (1 / 8 * 1.0 + 7 / 8 * M.P_MISWRITE)
    expected = per_pass * n * 4 * m
    assert measured == pytest.approx(expected, rel=0.08), \
        (measured, expected)


def test_ap_backend_estimate_sane():
    est = M.ap_backend_estimate(total_flops=1e12)
    assert est["seconds"] > 0 and est["joules"] > 0
    # 1 TFLOP of MACs on 2^20 PUs at 5500 cycles/MAC, 1 GHz:
    want_s = (1e12 / 2 / 2**20) * 5500 / 1e9
    assert est["seconds"] == pytest.approx(want_s)
