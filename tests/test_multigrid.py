"""Geometric multigrid (core/multigrid.py): Galerkin-product identity,
exact line smoothing, and mg/mgcg-vs-PCG equivalence on the steady,
transient and closed-loop-sweep paths for every stack family
(ISSUE 4 regression pins)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multigrid as mg
from repro.core import thermal
from repro.stack.spec import PAPER_SPEC, dram_on_logic

STACKS = [PAPER_SPEC, dram_on_logic(1), dram_on_logic(2), dram_on_logic(4)]


def _grid(spec, n=32, margin=8):
    return thermal.Grid(die_w=5e-3, ny=n, nx=n, margin=margin, spec=spec)


def _logic_power(grid, watts=40.0):
    """``watts`` spread over the stack's LOGIC dies (DRAM dies, when
    present, sit at the TOP of the layer order and stay unpowered)."""
    n = grid.ny
    logic = list(grid.stack.logic_layers)
    p = np.zeros((grid.n_die_layers, n, n), np.float32)
    p[logic] = watts / (len(logic) * n * n)
    return p


def test_galerkin_product_identity():
    """The raw coarse operator IS R G P: applying it to any coarse
    vector equals restrict(G(prolong(v))) on the fine grid."""
    grid = _grid(dram_on_logic(2), n=16, margin=4)
    F = grid.fields()
    d = jnp.full(F["g_pkg"].shape, 0.25, jnp.float32)
    Fc, dc = mg.coarsen(F, d)                 # rescale_lateral=False
    rng = np.random.default_rng(0)
    vc = jnp.asarray(rng.normal(size=Fc["g_pkg"].shape).astype(np.float32))
    lhs = mg.operator(vc, Fc, dc)
    rhs = mg.restrict(mg.operator(mg.prolong(vc), F, d))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-5, atol=1e-7)


def test_line_solve_is_exact_per_column():
    """line_solve satisfies its vertical tridiagonal system exactly."""
    grid = _grid(dram_on_logic(1), n=16, margin=4)
    F = grid.fields()
    d = jnp.full(F["g_pkg"].shape, 0.1, jnp.float32)
    rng = np.random.default_rng(1)
    rhs = jnp.asarray(rng.normal(size=F["g_pkg"].shape).astype(np.float32))
    u = mg.line_solve(rhs, F, d)
    diag = jnp.where(mg.diagonal(F, d) > 0, mg.diagonal(F, d), 1.0)
    u_up = jnp.concatenate([jnp.zeros_like(u[:1]), u[:-1]], axis=0)
    u_dn = jnp.concatenate([u[1:], jnp.zeros_like(u[:1])], axis=0)
    resid = diag * u - F["gz_up"] * u_up - F["gz_dn"] * u_dn - rhs
    assert float(jnp.abs(resid).max()) < 1e-4


@pytest.mark.parametrize("spec", STACKS, ids=lambda s: s.name)
@pytest.mark.parametrize("solver", ["mg", "mgcg"])
def test_steady_matches_pcg_all_stacks(spec, solver):
    """Multigrid matches the PCG steady solve within solver tolerance on
    PAPER_SPEC and every DRAM-on-logic stack (the ISSUE 4 pin)."""
    grid = _grid(spec)
    p = _logic_power(grid)
    T_ref = thermal.steady_state(p, grid, solver="pcg")
    T_mg, stats = thermal.steady_state_stats(p, grid, solver=solver)
    assert float(jnp.abs(T_mg - T_ref).max()) < 0.01, spec.name
    # asymptotically faster: a handful of cycles, not hundreds of iters
    assert stats["iterations"] < 40
    # the honest convergence signal: true residual, not iteration count
    assert stats["rel_residual"] < 1e-3


def test_steady_rejects_unknown_solver():
    grid = _grid(PAPER_SPEC, n=8, margin=0)
    with pytest.raises(ValueError, match="unknown solver"):
        thermal.steady_state(_logic_power(grid), grid, solver="bogus")


def test_transient_implicit_mg_matches_pcg():
    """The fixed-cycle MG inner solve reproduces the PCG transient."""
    grid = thermal.Grid(die_w=5e-3, ny=16, nx=16, spec=dram_on_logic(2))
    p = _logic_power(grid)
    T1, pk1 = thermal.transient_solve_implicit(p, grid, t_end=0.2,
                                               n_steps=32, n_cg=80)
    T2, pk2 = thermal.transient_solve_implicit(p, grid, t_end=0.2,
                                               n_steps=32, solver="mg",
                                               n_mg=3)
    assert float(jnp.abs(T1 - T2).max()) < 0.1
    assert float(jnp.abs(pk1 - pk2).max()) < 0.1


def test_sweep_solver_mg_matches_converged_pcg():
    """Closed-loop sweep with solver="mg" (3 V-cycles/step) lands within
    the Picard bar of a heavily-converged PCG replay — at a fraction of
    the inner-iteration budget."""
    from repro.sweep import SweepSpec, run_sweep
    base = dict(workloads=("hist",), sizes=(4096,), n_dram=(1,),
                fb_modes=("open",), grid_n=8, n_intervals=4,
                steps_per_interval=1)
    ref = run_sweep(SweepSpec(**base, n_cg=400), use_cache=False)
    got = run_sweep(SweepSpec(**base, solver="mg", n_mg=3),
                    use_cache=False)
    for a, b in zip(ref.records, got.records):
        np.testing.assert_allclose(b.report.peak_C, a.report.peak_C,
                                   atol=0.05)


def test_sweep_spec_solver_in_hash_and_validated():
    from repro.sweep import SweepSpec
    base = dict(workloads=("hist",), sizes=(4096,))
    a = SweepSpec(**base)
    b = SweepSpec(**base, solver="mg")
    c = SweepSpec(**base, solver="mg", n_mg=5)
    assert len({a.content_hash(), b.content_hash(), c.content_hash()}) == 3
    with pytest.raises(ValueError, match="unknown solver"):
        SweepSpec(**base, solver="cholesky")
    with pytest.raises(ValueError, match="n_mg"):
        SweepSpec(**base, n_mg=0)


def test_mg_solve_reaches_float32_floor():
    """The stand-alone iteration converges to a tiny true residual and
    reports the cycle count it took."""
    grid = _grid(dram_on_logic(2), n=32, margin=8)
    F = grid.fields()
    p = jnp.pad(jnp.asarray(grid.pad_power(_logic_power(grid))),
                ((0, 0), (8, 8), (8, 8)))
    x, cycles = mg.mg_solve_fields(p, F)
    r = p - mg.operator(x, F, jnp.zeros_like(F["g_pkg"]))
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(p))
    assert rel < 1e-3
    assert 1 <= int(cycles) < 40
