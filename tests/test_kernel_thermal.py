"""Pallas thermal_stencil kernel vs jnp oracle + CG equivalence."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import thermal
from repro.kernels.thermal_stencil import ops


GS = [(4, 64, 64), (4, 32, 128), (1, 16, 16), (6, 40, 24)]


@pytest.mark.parametrize("shape", GS)
@pytest.mark.parametrize("block_y", [4, 16, 32])
def test_stencil_matches_oracle(shape, block_y):
    rng = np.random.default_rng(sum(shape))
    T = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    g_lat, g_vert, g_pkg = 5.5e-3, 1.2e-2, 3.1e-4
    ref = thermal.apply_operator(T, g_lat, g_vert, g_pkg)
    got = ops.apply_operator(T, g_lat, g_vert, g_pkg, block_y=block_y)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 16), ny=st.sampled_from([8, 16, 24, 48]),
       nx=st.sampled_from([8, 16, 32]))
def test_property_stencil(seed, ny, nx):
    rng = np.random.default_rng(seed)
    T = jnp.asarray(rng.normal(size=(4, ny, nx)).astype(np.float32))
    g = rng.uniform(1e-4, 1e-1, 3)
    ref = thermal.apply_operator(T, *g)
    got = ops.apply_operator(T, *g, block_y=8)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-5, atol=1e-6)


def test_cg_pallas_equals_cg_jnp():
    """steady_state via the Pallas CG equals the jnp CG to solver tolerance."""
    rng = np.random.default_rng(0)
    grid = thermal.Grid(die_w=5e-3, ny=32, nx=32)
    power = rng.uniform(0, 1e-3, size=(4, 32, 32)).astype(np.float32)
    t_jnp = np.asarray(thermal.steady_state(power, grid, use_pallas=False))
    t_pl = np.asarray(thermal.steady_state(power, grid, use_pallas=True))
    np.testing.assert_allclose(t_jnp, t_pl, rtol=1e-4, atol=1e-3)


def test_operator_is_spd_like():
    """G is symmetric positive definite on the grid (CG's precondition)."""
    rng = np.random.default_rng(1)
    shape = (4, 8, 8)
    g_lat, g_vert, g_pkg = 1e-2, 2e-2, 1e-3
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    y = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    Ax = ops.apply_operator(x, g_lat, g_vert, g_pkg, block_y=4)
    Ay = ops.apply_operator(y, g_lat, g_vert, g_pkg, block_y=4)
    # symmetry: <y, Ax> == <x, Ay>
    assert float(jnp.vdot(y, Ax)) == pytest.approx(float(jnp.vdot(x, Ay)),
                                                   rel=1e-4)
    # positive definiteness on a nonzero vector
    assert float(jnp.vdot(x, Ax)) > 0
