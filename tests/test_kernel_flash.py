"""Pallas flash_attention kernel vs jnp oracle: shape/dtype/mask sweeps."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.flash_attention import ops, ref


def _mk(rng, B, sq, sk, hq, hkv, dh, dtype):
    q = jnp.asarray(rng.normal(size=(B, sq, hq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(B, sk, hkv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(B, sk, hkv, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,sq,sk,hq,hkv,dh", [
    (2, 64, 64, 4, 4, 32),      # MHA square
    (2, 64, 64, 4, 2, 32),      # GQA
    (1, 128, 128, 8, 1, 64),    # MQA
    (2, 1, 96, 4, 4, 32),       # decode: 1 query vs KV cache
    (1, 50, 70, 2, 1, 16),      # ragged -> padding path
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref_fp32(B, sq, sk, hq, hkv, dh, causal):
    rng = np.random.default_rng(B * sq + sk)
    q, k, v = _mk(rng, B, sq, sk, hq, hkv, dh, jnp.float32)
    r = ref.mha(q, k, v, causal=causal)
    g = ops.mha(q, k, v, causal=causal, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(r), np.asarray(g),
                               rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 48, 129])
def test_sliding_window(window):
    rng = np.random.default_rng(window)
    q, k, v = _mk(rng, 1, 128, 128, 4, 2, 32, jnp.float32)
    r = ref.mha(q, k, v, causal=True, window=window)
    g = ops.mha(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(r), np.asarray(g),
                               rtol=1e-5, atol=2e-5)


def test_bf16_inputs():
    rng = np.random.default_rng(0)
    q, k, v = _mk(rng, 2, 64, 64, 4, 4, 32, jnp.bfloat16)
    r = ref.mha(q, k, v, causal=True).astype(jnp.float32)
    g = ops.mha(q, k, v, causal=True, block_q=32, block_k=32).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(r), np.asarray(g),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 16),
       sq=st.sampled_from([1, 17, 32, 64]),
       extra=st.integers(0, 64),
       hkv=st.sampled_from([1, 2, 4]),
       causal=st.booleans())
def test_property_flash(seed, sq, extra, hkv, causal):
    rng = np.random.default_rng(seed)
    sk = sq + extra
    q, k, v = _mk(rng, 1, sq, sk, 4, hkv, 16, jnp.float32)
    r = ref.mha(q, k, v, causal=causal)
    g = ops.mha(q, k, v, causal=causal, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(r), np.asarray(g),
                               rtol=1e-5, atol=2e-5)


def test_probability_mass_is_normalized():
    """Output of attention over constant V equals V (softmax sums to 1)."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16)).astype(np.float32))
    v = jnp.ones((1, 32, 2, 16), jnp.float32) * 3.5
    g = ops.mha(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(g), 3.5, rtol=1e-5)
