"""Heterogeneous 3D-stack subsystem (repro/stack/): spec-built operators
vs the legacy PAPER_STACK path, power-map conservation across grid
resolutions (property tests), JEDEC refresh bins, and the closed-loop
feedback replay (Picard convergence, open-loop equivalence, DTM)."""
import math

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import cosim, thermal
from repro.core import models as M
from repro.core.constants import AMBIENT_C, DRAM_LIMIT_C
from repro.core.floorplan import MM, APFloorplan, SIMDFloorplan
from repro.stack import dram, feedback
from repro.stack.spec import (LOGIC, PAPER_SPEC, SPREADER, Interface, Layer,
                              StackSpec, dram_on_logic, spec_from_params)


# ------------------------------------------------------------ spec structure
def test_paper_spec_reproduces_legacy_formulas():
    """The generalized spec math == the hand-derived PAPER_STACK values."""
    p = thermal.PAPER_STACK
    s = spec_from_params(p)
    assert s.n_layers == p.n_layers and s.n_die_layers == p.n_si_layers
    np.testing.assert_allclose(
        s.lateral_conductances(),
        [p.k_si * p.t_si] * 4 + [p.k_spreader * p.t_spreader], rtol=1e-12)
    cell_area = 1.37e-8
    r_sisi = p.t_si / p.k_si + p.r_bond          # half-Si + bond + half-Si
    r_tim = 0.5 * p.t_si / p.k_si + p.t_tim / p.k_tim \
        + 0.5 * p.t_spreader / p.k_spreader
    np.testing.assert_allclose(
        s.vertical_conductances(cell_area),
        cell_area / np.array([r_sisi] * 3 + [r_tim]), rtol=1e-12)
    np.testing.assert_allclose(
        s.capacities(cell_area),
        [p.c_si * cell_area * p.t_si] * 4
        + [p.c_cu * cell_area * p.t_spreader], rtol=1e-12)
    area = (7.33e-3) ** 2
    assert s.package_resistance(area) == \
        pytest.approx(thermal.package_resistance(area, p), rel=1e-12)


def test_spec_route_matches_params_route_exactly():
    """Grid(spec=PAPER_SPEC) and Grid(params=PAPER_STACK) are bit-equal."""
    g1 = thermal.Grid(die_w=5e-3, ny=12, nx=12, margin=3)
    g2 = thermal.Grid(die_w=5e-3, ny=12, nx=12, margin=3, spec=PAPER_SPEC)
    c1, c2 = g1.conductances(), g2.conductances()
    for k in c1:
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))
    F1, F2 = g1.fields(), g2.fields()
    for k in F1:
        np.testing.assert_array_equal(np.asarray(F1[k]), np.asarray(F2[k]))
    np.testing.assert_array_equal(np.asarray(g1.capacity_field()),
                                  np.asarray(g2.capacity_field()))
    rng = np.random.default_rng(0)
    power = rng.uniform(0, 2e-3, (4, 12, 12)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(thermal.steady_state(power, g1)),
        np.asarray(thermal.steady_state(power, g2)))


def test_dram_on_logic_structure():
    s = dram_on_logic(2)
    assert s.n_layers == 7 and s.n_die_layers == 6
    assert s.dram_layers == (0, 1)
    assert s.logic_layers == (2, 3, 4, 5)
    assert s.layers[-1].kind == SPREADER
    assert [i.name for i in s.interfaces[:2]] == ["tsv", "tsv"]
    assert dram_on_logic(0) is spec_from_params(thermal.PAPER_STACK)
    np.testing.assert_array_equal(s.layer_mask(LOGIC),
                                  [0, 0, 1, 1, 1, 1, 0])
    # DRAM dies are thin: vertical coupling through them stays finite
    assert np.isfinite(s.vertical_conductances(1e-8)).all()


def test_spec_validation_errors():
    si = Layer("si", LOGIC, 250e-6, 110.0, 1.75e6)
    sp = Layer("spr", SPREADER, 1e-3, 400.0, 3.45e6)
    bond = Interface("bond", 0.7e-6)
    with pytest.raises(ValueError):            # wrong interface count
        StackSpec("bad", (si, sp), ())
    with pytest.raises(ValueError):            # spreader not last
        StackSpec("bad", (sp, si), (bond,))
    with pytest.raises(ValueError):            # spreader in the middle
        StackSpec("bad", (si, sp, sp), (bond, bond))
    with pytest.raises(ValueError):            # bad kind
        Layer("x", "copper", 1e-3, 400.0, 3.45e6)
    with pytest.raises(ValueError):            # negative interface R
        Interface("bad", -1e-6)
    with pytest.raises(ValueError):            # non-positive thickness
        Layer("x", LOGIC, 0.0, 110.0, 1.75e6)


# ------------------------------------------------- power-map conservation
@given(act_W=st.floats(0.05, 20.0), ref_W=st.floats(0.005, 2.0),
       leak_W=st.floats(0.005, 2.0),
       grid_n=st.sampled_from([3, 8, 12, 16, 32]))
@settings(max_examples=25, deadline=None)
def test_dram_power_map_conserves_wattage(act_W, ref_W, leak_W, grid_n):
    fp = dram.DRAMFloorplan(die_w_mm=5.0)
    pm = fp.power_map(grid_n, act_W, ref_W, leak_W)
    assert pm.shape == (grid_n, grid_n)
    assert pm.sum() == pytest.approx(act_W + ref_W + leak_W, rel=1e-9)
    assert (pm >= 0).all()
    assert fp.activate_map(grid_n).sum() == pytest.approx(1.0, rel=1e-9)
    assert fp.refresh_map(grid_n).sum() == pytest.approx(1.0, rel=1e-9)


@given(p_layer=st.floats(4.0, 40.0),
       grid_n=st.sampled_from([8, 16, 32, 64, 192]))
@settings(max_examples=25, deadline=None)
def test_ap_power_map_conserves_wattage(p_layer, grid_n):
    fp = APFloorplan()
    pm = fp.power_map(grid_n, p_layer)
    assert pm.sum() == pytest.approx(p_layer, rel=1e-6)


def test_simd_power_map_conserves_wattage():
    dp = cosim.comparable_design_point("dmm")
    fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))
    wl = M.WORKLOADS["dmm"]
    p_exec, p_sync, _ = M.simd_phase_powers(wl, dp.simd_n_pus)
    # 2/4: degenerate grids (no tiles rasterize -> uniform fallback)
    for grid_n in (2, 4, 8, 16, 32):
        pm = fp.power_map(grid_n, dp)
        assert pm.sum() == pytest.approx(
            p_exec + p_sync + fp.leakage_W(dp), rel=1e-6)


def test_stack_power_inputs_conserve_wattage():
    """Time-mean of dyn + static leak/refresh == logic + DRAM totals."""
    grid_n, margin, n_dram = 8, 2, 2
    spec = dram_on_logic(n_dram)
    dp = cosim.comparable_design_point("dmm")
    fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
    pmap = fp.power_map(grid_n, dp.ap_power_W)
    grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=grid_n, nx=grid_n,
                        spec=spec, margin=margin)
    rng = np.random.default_rng(1)
    act = rng.uniform(0.3, 1.8, 10)
    trace = cosim.PowerTrace(act / act.mean())
    dfp = dram.DRAMFloorplan(die_w_mm=fp.die_w_mm)
    traffic = M.mem_traffic_bytes_per_s("dmm", dp.ap_n_pus)
    dyn, leak0, ref0, lmask = feedback.stack_power_inputs(
        spec, grid, trace, pmap, fp.leakage_W(), dfp, traffic)
    n_logic = len(spec.logic_layers)
    exp_dyn = n_logic * (pmap.sum() - fp.leakage_W()) \
        + n_dram * dram.activate_io_W(traffic, n_dram)
    assert dyn.sum(axis=(1, 2, 3)).mean() == pytest.approx(exp_dyn, rel=1e-5)
    assert leak0.sum() == pytest.approx(
        n_logic * fp.leakage_W() + n_dram * dfp.leakage_W(), rel=1e-5)
    assert ref0.sum() == pytest.approx(n_dram * dfp.base_refresh_W(),
                                       rel=1e-5)
    assert dyn[:, -1].sum() == 0.0          # spreader heatless
    np.testing.assert_array_equal(lmask, spec.layer_mask(LOGIC))


def test_power_frames_on_heterogeneous_grid_power_logic_only():
    """cosim.power_frames must NOT deposit logic power on DRAM dies."""
    spec = dram_on_logic(2)
    grid = thermal.Grid(die_w=3e-3, ny=8, nx=8, spec=spec, margin=2)
    pmap = np.full((8, 8), 1e-2)
    trace = cosim.PowerTrace(np.ones(4))
    frames = cosim.power_frames(trace, pmap, 0.1 * pmap.sum(), grid)
    assert frames.shape == (4, 7, 12, 12)
    for i in spec.dram_layers:
        assert frames[:, i].sum() == 0.0
    assert frames[:, -1].sum() == 0.0       # spreader heatless
    assert frames.sum() == pytest.approx(
        4 * len(spec.logic_layers) * pmap.sum(), rel=1e-5)


# --------------------------------------------------------- refresh model
def test_refresh_multiplier_bins():
    T = jnp.array([20.0, 84.9, 85.0, 94.9, 95.0, 120.0])
    np.testing.assert_array_equal(np.asarray(dram.refresh_multiplier(T)),
                                  [1.0, 1.0, 2.0, 2.0, 4.0, 4.0])
    assert float(dram.refresh_multiplier(DRAM_LIMIT_C - 1e-3)) == 1.0


def test_activate_io_power_scales_with_traffic_and_dies():
    w1 = dram.activate_io_W(1e10, 1)
    assert w1 == pytest.approx(1e10 * 8 * dram.E_ACT_PJ_PER_BIT * 1e-12)
    assert dram.activate_io_W(1e10, 4) == pytest.approx(w1 / 4)


# ------------------------------------------------------- closed-loop replay
def _open_loop_case(grid_n=8, margin=2, n_intervals=10):
    dp = cosim.comparable_design_point("dmm")
    fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
    pmap = fp.power_map(grid_n, dp.ap_power_W)
    trace = cosim.ap_workload_trace("dmm", n_intervals)
    spec = dram_on_logic(0)
    grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=grid_n, nx=grid_n,
                        spec=spec, margin=margin)
    return dp, fp, pmap, trace, spec, grid


def test_disabled_feedback_matches_cosim_within_tenth_degree():
    """Acceptance bar: DRAM dies off + feedback off == the homogeneous
    PAPER_STACK cosim replay within 0.1 C."""
    grid_n, margin, n_int = 8, 2, 10
    dp, fp, pmap, trace, spec, grid = _open_loop_case(grid_n, margin, n_int)
    interval_dt = 0.25 / n_int
    kw = dict(steps_per_interval=2, n_cg=40, margin=margin, die_n=grid_n)
    frames = cosim.power_frames(trace, pmap, fp.leakage_W(), grid)
    _, pk_ref, mn_ref = cosim.cosim_transient(
        jnp.asarray(frames), grid.fields(), grid.capacity_field(),
        interval_dt, **kw)
    dyn, leak0, ref0, lmask = feedback.stack_power_inputs(
        spec, grid, trace, pmap, fp.leakage_W(),
        dram.DRAMFloorplan(die_w_mm=fp.die_w_mm), 0.0)
    assert ref0.sum() == 0.0
    _, pk, mn, res, thr, ref_W, leak_W, dyn_W = feedback.closed_loop_replay(
        jnp.asarray(dyn), jnp.asarray(leak0), jnp.asarray(ref0),
        jnp.asarray(lmask), grid.fields(), grid.capacity_field(),
        interval_dt, fb=feedback.FeedbackParams.disabled(),
        n_die=spec.n_die_layers, **kw)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pk_ref), atol=0.1)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(mn_ref), atol=0.1)
    assert (np.asarray(thr) == 1.0).all()       # DTM never tripped
    assert (np.asarray(ref_W) == 0.0).all()     # no DRAM, no refresh
    # T-independent power: the 2nd Picard iterate reproduces the 1st, so
    # the recorded fixed-point residual is exactly zero
    assert (np.asarray(res) == 0.0).all()


def test_closed_loop_converges_and_feedback_heats():
    """Picard residual meets the documented bar; refresh/leakage feedback
    strictly raises the hot die's temperature on the hot (SIMD) stack."""
    fb = feedback.FeedbackParams(dtm_trip_C=math.inf)   # isolate heating
    res = feedback.run_stack_cosim(
        workloads=("dmm",), n_dram=1, grid_n=8, n_intervals=12,
        t_end=0.25, steps_per_interval=1, n_cg=30, fb=fb)
    res0 = feedback.run_stack_cosim(
        workloads=("dmm",), n_dram=1, grid_n=8, n_intervals=12,
        t_end=0.25, steps_per_interval=1, n_cg=30,
        fb=feedback.FeedbackParams.disabled())
    for machine in ("ap", "simd"):
        r = res["dmm"][machine]
        assert r.converged, r.residual_C.max()
        assert r.residual_C.shape == (12,)
    hot, hot0 = res["dmm"]["simd"], res0["dmm"]["simd"]
    assert hot.peak_C.max() > hot0.peak_C.max() + 1.0
    assert hot.refresh_overhead > 1.2           # JEDEC derating engaged
    assert hot0.refresh_overhead == pytest.approx(1.0)
    cool = res["dmm"]["ap"]
    assert cool.refresh_overhead == pytest.approx(1.0, abs=1e-3)
    assert cool.dram_time_above_limit_s == 0.0
    assert hot.dram_time_above_limit_s > 0.0


def test_dtm_throttle_caps_and_costs_runtime():
    """A low trip point must clamp the AP stack and charge a slowdown."""
    fb_hot = feedback.FeedbackParams(dtm_trip_C=48.0, dtm_ramp_C=2.0,
                                     dtm_floor=0.3)
    run = lambda fb: feedback.run_stack_cosim(
        workloads=("dmm",), n_dram=1, grid_n=8, n_intervals=12,
        t_end=0.25, steps_per_interval=1, n_cg=30, fb=fb)["dmm"]["ap"]
    r_dtm = run(fb_hot)
    r_free = run(feedback.FeedbackParams(dtm_trip_C=math.inf))
    assert r_dtm.dtm_slowdown > 1.05
    assert r_free.dtm_slowdown == pytest.approx(1.0)
    assert r_dtm.logic_peak_C.max() < r_free.logic_peak_C.max() - 0.5
    assert (r_dtm.throttle >= fb_hot.dtm_floor - 1e-6).all()


def test_run_stack_cosim_batch_shapes_and_ordering():
    res = feedback.run_stack_cosim(
        workloads=("dmm", "fft"), n_dram=2, grid_n=8, n_intervals=8,
        t_end=0.1, steps_per_interval=1, n_cg=25)
    spec = res["spec"]
    assert spec.n_die_layers == 6
    for w in ("dmm", "fft"):
        for machine in ("ap", "simd"):
            r = res[w][machine]
            assert r.peak_C.shape == (8, 6)
            assert np.isfinite(r.peak_C).all()
            assert (r.peak_C >= r.min_C - 1e-4).all()
            assert (r.peak_C > AMBIENT_C - 1.0).all()
        # AP runs cooler than the same-performance SIMD under DRAM too
        assert res[w]["ap"].dram_peak_C.max() < \
            res[w]["simd"].dram_peak_C.max()


@pytest.mark.pallas
def test_heterogeneous_stack_pallas_matches_jnp():
    """The Pallas stencil is layer-depth generic: a 7-layer DRAM stack
    must solve identically to the jnp oracle."""
    spec = dram_on_logic(2)
    g = thermal.Grid(die_w=5e-3, ny=16, nx=16, margin=4, spec=spec)
    p = np.zeros((6, 16, 16), np.float32)
    p[list(spec.logic_layers)] = 1e-3
    T_j = np.asarray(thermal.steady_state(p, g, use_pallas=False))
    T_p = np.asarray(thermal.steady_state(p, g, use_pallas=True))
    np.testing.assert_allclose(T_j, T_p, rtol=1e-5, atol=1e-3)


def test_steady_state_with_unpowered_dram_dies():
    """DRAM-on-top steady state: DRAM floor temp == top logic die's (heat
    flows down), and the homogeneous result is unchanged underneath."""
    from repro.core.floorplan import thermal_comparison

    res_h = thermal_comparison(grid_ap=32, grid_simd=16, workload="dmm")
    res_d = thermal_comparison(grid_ap=32, grid_simd=16, workload="dmm",
                               stack=dram_on_logic(2))
    spec = dram_on_logic(2)
    for name in ("ap", "simd"):
        peaks_h = res_h[name]["peak_C"]
        peaks_d = res_d[name]["peak_C"]
        assert len(peaks_d) == 6
        # unpowered DRAM adds no heat, only lateral spreading mass on top:
        # it can only COOL the logic peak, and only by a few degrees
        for lh, ld in zip(peaks_h, [peaks_d[i] for i in spec.logic_layers]):
            assert ld <= lh + 0.05
            assert ld > lh - 6.0
        # passive DRAM floats to just under the top logic temperature (it
        # keeps spreading the hot spot laterally, so its own peak is a few
        # degrees BELOW the logic peak, never above)
        top_logic = peaks_d[spec.logic_layers[0]]
        for i in spec.dram_layers:
            assert top_logic - 5.0 < peaks_d[i] <= top_logic + 0.1
        # peaks cool monotonically away from the logic heat source
        assert peaks_d[spec.dram_layers[0]] <= \
            peaks_d[spec.dram_layers[-1]] + 0.1
    # the AP's profile is already near-uniform, so the extra spreader
    # barely matters there — the paper's flatness claim, restated
    assert res_d["ap"]["peak_C"][spec.logic_layers[0]] == \
        pytest.approx(res_h["ap"]["peak_C"][0], abs=0.3)
