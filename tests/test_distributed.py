"""Multi-device tests (subprocess with XLA_FLAGS device_count): sharded
train step vs single-device reference, elastic re-mesh restore, compressed
psum.  Each test launches a python subprocess because the parent pytest
process has already locked jax to 1 device."""
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(script: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/tmp", "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """(data=4, model=2) sharded loss == unsharded loss, same batch."""
    out = _run(r"""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models.model import PerfConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.data import SyntheticLM

cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                          n_layers=2, vocab=512)
cell = ShapeCell("t", 32, 8, "train")
perf = PerfConfig(remat="none", accum_steps=2)
ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
pipe = SyntheticLM(cfg.vocab, 32, 8, seed=0)
batch = {k: jnp.asarray(v) for k, v in pipe.microbatched(0, 2).items()}

losses = {}
for name, mesh in (("multi", make_local_mesh(4, 2)),
                   ("single", make_local_mesh(1, 1))):
    # init per mesh: the train step DONATES params/opt buffers
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    ts, _ = make_train_step(cfg, cell, mesh, perf=perf, opt_cfg=ocfg,
                            dtype=jnp.float32)
    p2, o2, m = ts(params, adamw_init(params), batch)
    losses[name] = float(m["loss"])
print("LOSSES", losses["multi"], losses["single"])
assert abs(losses["multi"] - losses["single"]) < 5e-4, losses
print("OK")
""")
    assert "OK" in out


def test_elastic_remesh_restore():
    """Checkpoint saved on a (4,2) mesh restores onto (2,2) and (1,1)."""
    out = _run(r"""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import save, restore

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "v": jnp.arange(16, dtype=jnp.float32)}
specs = {"w": P("data", "model"), "v": P("model")}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
sharded = {k: jax.device_put(v, NamedSharding(mesh_a, specs[k]))
           for k, v in tree.items()}
d = tempfile.mkdtemp()
save(d, 1, sharded)

mesh_b = jax.make_mesh((2, 2), ("data", "model"))
target = jax.eval_shape(lambda: tree)
out = restore(d, 1, target, mesh=mesh_b, specs=specs)
for k in tree:
    np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))
    assert out[k].sharding.mesh.shape["data"] == 2
out2 = restore(d, 1, target)           # single-device restore
for k in tree:
    np.testing.assert_array_equal(np.asarray(out2[k]), np.asarray(tree[k]))
print("OK")
""")
    assert "OK" in out


def test_compressed_psum_shard_map():
    """int8 EF gradient all-reduce over the data axis ~= exact mean."""
    out = _run(r"""
import functools, numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_psum

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
res = jnp.zeros((8, 128), jnp.float32)

@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P("data", None), P("data", None)),
                   out_specs=(P("data", None), P("data", None)))
def sync(gs, rs):
    mean, new_r = compressed_psum(gs[0], rs[0], "data")
    return mean[None], new_r[None]

mean, new_res = sync(g, res)
true_mean = np.asarray(g).mean(0)
got = np.asarray(mean)[0]
err = np.abs(got - true_mean).max()
scale = np.abs(np.asarray(g)).max() / 127.0
assert err < 2 * scale, (err, scale)
print("OK", err)
""")
    assert "OK" in out


def test_dryrun_entrypoint_single_cell():
    """The dry-run CLI itself (512 devices) on the smallest cell."""
    out = _run(r"""
import subprocess, sys, os, pathlib, tempfile
# direct invocation of the module (it sets its own XLA_FLAGS first)
import runpy
sys.argv = ["dryrun", "--arch", "whisper-base", "--shape", "train_4k",
            "--mesh", "multi", "--out", tempfile.mkdtemp(), "--force"]
runpy.run_module("repro.launch.dryrun", run_name="__main__")
print("OK")
""", devices=512)
    assert "OK" in out
