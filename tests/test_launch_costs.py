"""Smoke the analytic per-model cost paths over EVERY registered config.

``params_sds`` is ``jax.eval_shape`` only — no arrays are materialized —
so even the 236B config is cheap to sweep.  This is the coverage floor
the serving package leans on: every config must yield finite parameter
counts, per-shape reference FLOPs, and a positive serving cost with a
monotone decode-AI curve.
"""
import functools

import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, cell_is_runnable, get_config, list_configs
from repro.launch import roofline as RF
from repro.launch.steps import params_sds
from repro.serving import serving_cost

ALL = list_configs()


@functools.lru_cache(maxsize=None)
def _sds(name):
    return params_sds(get_config(name), jnp.bfloat16)


@pytest.mark.parametrize("name", ALL)
def test_param_counts(name):
    cfg = get_config(name)
    total = RF.count_params(_sds(name))
    active = RF.count_active_params(cfg, _sds(name))
    assert total > 0
    assert 0 < active <= total
    if cfg.moe is None:
        assert active == total
    else:
        assert active < total
    # registry names carry a rough size tag ("-7b") — sanity-band it
    tag = name.rsplit("-", 1)[-1]
    if tag.endswith("b") and tag[:-1].replace(".", "").isdigit():
        claimed = float(tag[:-1]) * 1e9
        assert 0.4 * claimed < total < 2.5 * claimed, (name, total)


@pytest.mark.parametrize("name", ALL)
def test_reference_flops_per_shape(name):
    cfg = get_config(name)
    sds = _sds(name)
    for cell in SHAPES.values():
        ok, _reason = cell_is_runnable(cfg, cell)
        if not ok:
            continue
        flops = RF.model_flops_per_device(cfg, cell, sds, n_chips=16)
        assert flops > 0
        if cell.kind == "train":      # 6N vs 2N per token
            prefill_like = 2.0 / 6.0 * flops
            assert prefill_like < flops


@pytest.mark.parametrize("name", ALL)
def test_serving_cost_every_config(name):
    cost = serving_cost(name)
    assert cost.n_active > 0 and cost.request_flops > 0
    assert cost.kv_bytes_tok >= 0
    ai1, ai32 = cost.decode_ai(1), cost.decode_ai(32)
    assert ai1 > 0 and ai32 >= ai1
    wl = cost.workload(32)
    assert wl.i_s > 0 and wl.s_apu > 0
    assert cost.traffic_bytes_per_s(32, 1 << 20) > 0
