"""Pallas ap_match kernel vs jnp oracle: shape sweeps + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import bitplane as bp, isa
from repro.core.engine import APEngine, PassSchedule
from repro.kernels.ap_match import ops


def _wide_planes(vals, n_bits):
    """Planes of any width from uint64 words (bits >= 64 zero-filled).

    ``bp.pack_words`` itself refuses widths > 64 (uint64 shift overflow
    is UB); wide kernel shapes are built by explicit zero extension.
    """
    packed = bp.pack_words(vals, min(n_bits, 64))
    if n_bits <= 64:
        return packed
    return jnp.concatenate(
        [packed, jnp.zeros((n_bits - 64, packed.shape[1]), jnp.uint32)])


def _random_schedule(rng, n_bits, n_passes, kc, kw):
    passes = []
    for _ in range(n_passes):
        cc = rng.choice(n_bits, size=rng.integers(1, kc + 1), replace=False)
        wc = rng.choice(n_bits, size=rng.integers(1, kw + 1), replace=False)
        passes.append((list(cc), list(rng.integers(0, 2, len(cc))),
                       list(wc), list(rng.integers(0, 2, len(wc)))))
    return PassSchedule.build(passes)


@pytest.mark.parametrize("n_words,n_bits,block", [
    (256, 32, 8), (1024, 64, 32), (2048, 128, 16), (512, 16, 16),
])
def test_random_schedule_matches_oracle(n_words, n_bits, block):
    rng = np.random.default_rng(n_words + n_bits)
    sched = _random_schedule(rng, n_bits, n_passes=12, kc=4, kw=3)
    vals = rng.integers(0, 1 << min(n_bits, 60), n_words, dtype=np.uint64)
    planes = _wide_planes(vals, n_bits)
    p_ref, m_ref = ops.run_schedule(planes, sched.cmp_cols, sched.cmp_key,
                                    sched.w_cols, sched.w_key, backend="jnp")
    p_pl, m_pl = ops.run_schedule(planes, sched.cmp_cols, sched.cmp_key,
                                  sched.w_cols, sched.w_key,
                                  backend="pallas", block_lanes=block)
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pl))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_pl))


def test_add_schedule_on_pallas_backend():
    """End-to-end: the 8m-cycle adder gives identical sums on both backends."""
    rng = np.random.default_rng(7)
    av = rng.integers(0, 1 << 16, 512, dtype=np.uint64)
    bv = rng.integers(0, 1 << 16, 512, dtype=np.uint64)
    outs = {}
    for backend in ("jnp", "pallas"):
        eng = APEngine(n_words=512, n_bits=64, backend=backend)
        a = eng.alloc.alloc(16)
        b = eng.alloc.alloc(16)
        c = eng.alloc.alloc(1)
        eng.load(a, av)
        eng.load(b, bv)
        isa.run_add(eng, a, b, c)
        outs[backend] = (eng.peek(b), eng.cycles, eng.energy)
    np.testing.assert_array_equal(outs["jnp"][0], (av + bv) & 0xFFFF)
    np.testing.assert_array_equal(outs["jnp"][0], outs["pallas"][0])
    assert outs["jnp"][1] == outs["pallas"][1]          # identical cycle count
    assert outs["jnp"][2] == pytest.approx(outs["pallas"][2])  # same energy


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_passes=st.integers(1, 16),
       lanes_pow=st.integers(1, 4))
def test_property_oracle_equivalence(seed, n_passes, lanes_pow):
    """Any random schedule x any block size: kernel == oracle, exactly."""
    n_words = 32 * (2 ** lanes_pow)
    n_bits = 24
    rng = np.random.default_rng(seed)
    sched = _random_schedule(rng, n_bits, n_passes, kc=3, kw=2)
    vals = rng.integers(0, 1 << n_bits, n_words, dtype=np.uint64)
    planes = bp.pack_words(vals, n_bits)
    p_ref, m_ref = ops.run_schedule(planes, sched.cmp_cols, sched.cmp_key,
                                    sched.w_cols, sched.w_key, backend="jnp")
    block = 2 ** rng.integers(0, lanes_pow + 1)
    p_pl, m_pl = ops.run_schedule(planes, sched.cmp_cols, sched.cmp_key,
                                  sched.w_cols, sched.w_key,
                                  backend="pallas", block_lanes=int(block))
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pl))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_pl))


def test_matched_counts_are_exact():
    """matched[p] equals the popcount of the oracle TAG after each compare."""
    n_words, n_bits = 256, 16
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 1 << n_bits, n_words, dtype=np.uint64)
    planes = bp.pack_words(vals, n_bits)
    # single pass comparing bit 3 == 1
    sched = PassSchedule.build([([3], [1], [5], [1])])
    _, matched = ops.run_schedule(planes, sched.cmp_cols, sched.cmp_key,
                                  sched.w_cols, sched.w_key, backend="pallas")
    expect = int(((vals >> 3) & 1).sum())
    assert int(np.asarray(matched)[0]) == expect
