"""Per-architecture smoke tests (reduced configs, CPU): shapes, finiteness,
train grad, and prefill+decode == full forward."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_runnable, get_config, list_configs
from repro.models import model as M
from repro.models import serve as SV

KEY = jax.random.PRNGKey(0)


def _mk_batch(cfg, B, S, seed=0, with_labels=True):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_prefix_embeds, cfg.d_model))
            .astype(np.float32))
    return batch


@pytest.mark.parametrize("name", list_configs())
def test_train_step_shapes_and_finiteness(name):
    cfg = get_config(name).reduced()
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    batch = _mk_batch(cfg, B, S)
    logits, aux = M.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, has_aux=True)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # embedding must receive gradient
    gnorm = float(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in leaves) ** 0.5)
    assert gnorm > 1e-3


@pytest.mark.parametrize("name", list_configs())
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.moe is not None:   # disable capacity dropping for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = M.init_params(cfg, KEY)
    B, S, k = 2, 24, 16
    batch = _mk_batch(cfg, B, S, seed=1, with_labels=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :k]
    logits_all, _ = M.forward(params, batch, cfg)
    lg, caches = SV.prefill(params, pre, cfg, max_seq=S)
    errs = [float(jnp.max(jnp.abs(lg - logits_all[:, k - 1])))]
    for t in range(k, S):
        lg, caches = SV.decode_step(params, batch["tokens"][:, t:t + 1],
                                    caches, jnp.int32(t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg - logits_all[:, t]))))
    assert max(errs) < 5e-4, errs


def test_sliding_window_ring_buffer_drops_old_tokens():
    """danube (SWA): decode attends only within the window; cache is O(W)."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    assert cfg.sliding_window == 64
    params = M.init_params(cfg, KEY)
    B, S = 1, 96                     # longer than the window
    batch = _mk_batch(cfg, B, S, seed=2, with_labels=False)
    logits_all, _ = M.forward(params, batch, cfg)
    k = 80
    pre = {"tokens": batch["tokens"][:, :k]}
    lg, caches = SV.prefill(params, pre, cfg, max_seq=S)
    # ring buffer: cache seq length is the window, not the full sequence
    assert caches["layers"]["k"].shape[2] == cfg.sliding_window
    err = float(jnp.max(jnp.abs(lg - logits_all[:, k - 1])))
    assert err < 5e-4, err
    for t in range(k, S):
        lg, caches = SV.decode_step(params, batch["tokens"][:, t:t + 1],
                                    caches, jnp.int32(t), cfg)
        err = float(jnp.max(jnp.abs(lg - logits_all[:, t])))
        assert err < 5e-4, (t, err)


def test_int8_kv_cache_decode_close_to_exact():
    """KIVI-style int8 KV: scales factor exactly out of the contractions;
    only int8 rounding remains (~1% logit error at random init)."""
    from repro.models.model import PerfConfig
    cfg = get_config("codeqwen1.5-7b").reduced()
    params = M.init_params(cfg, KEY)
    B, S, k = 2, 24, 16
    batch = _mk_batch(cfg, B, S, seed=3, with_labels=False)
    logits_all, _ = M.forward(params, batch, cfg)
    pre = {"tokens": batch["tokens"][:, :k]}
    lg, caches = SV.prefill(params, pre, cfg, perf=PerfConfig(kv_quant=True),
                            max_seq=S)
    assert caches["layers"]["k_q"].dtype == jnp.int8
    errs = [float(jnp.max(jnp.abs(lg - logits_all[:, k - 1])))]
    agree = []
    for t in range(k, S):
        lg, caches = SV.decode_step(params, batch["tokens"][:, t:t + 1],
                                    caches, jnp.int32(t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg - logits_all[:, t]))))
        agree.append(bool(jnp.all(jnp.argmax(lg, -1)
                                  == jnp.argmax(logits_all[:, t], -1))))
    assert max(errs) < 0.15, errs          # int8 rounding envelope
    assert sum(agree) >= len(agree) - 1    # greedy choice ~unchanged


def test_moe_capacity_drops_tokens_gracefully():
    """With a tight capacity factor the layer still runs and stays finite."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = M.init_params(cfg, KEY)
    batch = _mk_batch(cfg, 2, 32)
    (loss, _), grads = jax.value_and_grad(
        M.loss_fn, has_aux=True)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_moe_aux_loss_balances():
    """Aux loss is ~1.0 * weight for a balanced router at init."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params = M.init_params(cfg, KEY)
    batch = _mk_batch(cfg, 2, 64)
    _, aux = M.forward(params, batch, cfg)
    # balanced: E * sum(f_i * p_i) ~ 1.0 (x weight x n_moe_layers)
    n_moe = cfg.n_layers - cfg.moe.first_dense
    expect = cfg.moe.aux_weight * n_moe
    assert 0.5 * expect < float(aux) < 2.0 * expect


def test_long_500k_eligibility_rules():
    """Assignment skip rules: SSM/hybrid/SWA run long_500k, the rest skip."""
    run = {n: cell_is_runnable(get_config(n), SHAPES["long_500k"])[0]
           for n in list_configs()}
    assert run["falcon-mamba-7b"] and run["zamba2-1.2b"] \
        and run["h2o-danube-3-4b"]
    for n in ("whisper-base", "deepseek-v2-236b", "deepseek-v2-lite-16b",
              "stablelm-1.6b", "phi3-medium-14b", "codeqwen1.5-7b",
              "qwen2-vl-72b"):
        assert not run[n], n


def test_mrope_equals_rope_for_text():
    """qwen2-vl M-RoPE with equal position streams == standard RoPE."""
    from repro.models import rope as R
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    std = R.apply_rope(x, pos, 1e4)
    mr = R.apply_mrope(x, R.text_positions3(pos), (4, 6, 6), 1e4)
    np.testing.assert_allclose(np.asarray(std), np.asarray(mr),
                               rtol=1e-6, atol=1e-6)


def test_ssm_chunk_invariance():
    """Chunked scan result is independent of the chunk size."""
    cfg = get_config("falcon-mamba-7b").reduced()
    params = M.init_params(cfg, KEY)
    batch = _mk_batch(cfg, 2, 32, with_labels=False)
    outs = []
    for chunk in (4, 8, 32):
        c2 = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=chunk))
        logits, _ = M.forward(params, batch, c2)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)
