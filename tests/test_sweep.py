"""Sweep subsystem: spec hashing, cache hit/miss semantics, bit-identical
reloads, deterministic record ordering, and registry duplicate rejection."""
import dataclasses

import numpy as np
import pytest

from repro.sweep import SweepSpec, run_sweep
from repro.sweep import cache as sweep_cache
from repro.workloads import registry

_QUICK = dict(workloads=("hist",), sizes=(4096,), n_dram=(1,),
              fb_modes=("open",), grid_n=8, n_intervals=4,
              steps_per_interval=1, n_cg=15)


def test_spec_validation():
    with pytest.raises(ValueError):
        SweepSpec(workloads=("no_such_workload",))
    with pytest.raises(ValueError):
        SweepSpec(workloads=("dmm",), fb_modes=("bogus",))
    with pytest.raises(ValueError):
        SweepSpec(workloads=("dmm",), sizes=(128,))
    with pytest.raises(ValueError):
        SweepSpec(workloads=("dmm",), machines=("gpu",))
    with pytest.raises(ValueError):
        SweepSpec(workloads=("dmm",), ap_backend="bogus")
    with pytest.raises(ValueError, match="unknown policy"):
        SweepSpec(workloads=("dmm",), policies=("bogus",))


def test_spec_hash_sensitivity():
    """The content hash covers EVERY spec field: perturbing any one of
    them must change the key; the identical spec must reproduce it."""
    spec = SweepSpec(**_QUICK)
    assert spec.content_hash() == SweepSpec(**_QUICK).content_hash()
    perturbations = dict(
        workloads=("hist", "sort"), sizes=(8192,), n_dram=(2,),
        fb_modes=("closed",), policies=("ramp", "perdie"),
        machines=("ap",), grid_n=12, n_intervals=8,
        t_end=0.5, steps_per_interval=2, n_cg=16, theta=0.5, n_picard=8,
        solver="mg", n_mg=5, ap_backend="megakernel")
    for field, value in perturbations.items():
        other = dataclasses.replace(spec, **{field: value})
        assert other.content_hash() != spec.content_hash(), field


def test_points_enumeration():
    spec = SweepSpec(workloads=("hist", "sort"), sizes=(4096, 8192),
                     n_dram=(0, 2), fb_modes=("open", "closed"))
    pts = spec.points()
    assert len(pts) == spec.n_points == 16
    assert len(set(pts)) == 16
    assert pts[0].workload == "hist" and pts[-1].workload == "sort"


def test_sweep_cache_roundtrip_bit_identical(tmp_path):
    spec = SweepSpec(**_QUICK)
    res = run_sweep(spec, cache_dir=tmp_path)
    assert not res.from_cache
    assert sweep_cache.path_for(spec, tmp_path).exists()

    res2 = run_sweep(spec, cache_dir=tmp_path)
    assert res2.from_cache
    assert len(res2.records) == len(res.records) \
        == spec.n_points * len(spec.machines)
    for a, b in zip(res.records, res2.records):
        assert a.point == b.point and a.machine == b.machine
        assert a.report.label == b.report.label
        assert a.verdict_ok == b.verdict_ok
        for name in ("peak_C", "min_C", "residual_C", "throttle",
                     "refresh_W", "leak_W", "dyn_W"):
            av = getattr(a.report, name)
            bv = getattr(b.report, name)
            assert av.dtype == bv.dtype
            np.testing.assert_array_equal(av, bv)
    assert res.table() == res2.table()


def test_sweep_cache_misses_on_perturbation(tmp_path):
    spec = SweepSpec(**_QUICK)
    run_sweep(spec, cache_dir=tmp_path)
    other = dataclasses.replace(spec, n_cg=16)
    assert sweep_cache.load(other, tmp_path) is None
    assert sweep_cache.load(spec, tmp_path) is not None


def test_sweep_cache_corrupt_file_is_a_miss(tmp_path):
    """Garbage at the cache path (interrupted writer, disk damage) must
    read as a MISS — the sweep recomputes and overwrites — never raise,
    and the obs counters must attribute it as corrupt."""
    from repro import obs

    spec = SweepSpec(**_QUICK)
    path = sweep_cache.path_for(spec, tmp_path)
    path.parent.mkdir(parents=True, exist_ok=True)

    corruptions = {
        "not_a_zip": b"this is not an npz archive at all",
        "truncated": None,          # filled from a real entry below
        "empty": b"",
    }
    run_sweep(spec, cache_dir=tmp_path)         # write a genuine entry
    corruptions["truncated"] = path.read_bytes()[:200]

    for kind, payload in corruptions.items():
        path.write_bytes(payload)
        with obs.scoped():
            before = obs.value("sweep/cache/corrupt")
            assert sweep_cache.load(spec, tmp_path) is None, kind
            assert obs.value("sweep/cache/corrupt") == before + 1, kind
        # and the full sweep path recovers by recomputing + overwriting
        res = run_sweep(spec, cache_dir=tmp_path)
        assert not res.from_cache
        assert run_sweep(spec, cache_dir=tmp_path).from_cache


def test_sweep_record_order_matches_points(tmp_path):
    spec = SweepSpec(**dict(_QUICK, workloads=("hist", "sort")))
    res = run_sweep(spec, cache_dir=tmp_path)
    expect = [(p, mc) for p in spec.points() for mc in spec.machines]
    assert [(r.point, r.machine) for r in res.records] == expect
    # and every record exposes the DRAM-judged verdict layers
    for r in res.records:
        assert r.limit_layers == r.report.spec.dram_layers


def test_policy_axis_sweeps_distinct_controllers(tmp_path):
    """policies is a first-class grid dimension: closed-mode points run
    one replay group per policy (distinct trajectories once the DTM
    engages), the "ramp" rows are the pre-axis default, and labels carry
    the policy name."""
    spec = SweepSpec(**dict(_QUICK, fb_modes=("closed",),
                            policies=("ramp", "step")))
    res = run_sweep(spec, cache_dir=tmp_path)
    assert len(res.records) == 2 * len(spec.machines)
    assert {r.point.policy for r in res.records} == {"ramp", "step"}
    for r in res.records:
        assert r.label.endswith(f"{r.point.policy}/{r.machine}")
    base = run_sweep(SweepSpec(**dict(_QUICK, fb_modes=("closed",))),
                     cache_dir=tmp_path)
    for a, b in zip([r for r in res.records if r.point.policy == "ramp"],
                    base.records):
        np.testing.assert_array_equal(a.report.peak_C, b.report.peak_C)
        np.testing.assert_array_equal(a.report.throttle,
                                      b.report.throttle)


def test_policy_axis_inert_outside_closed_mode(tmp_path):
    """"nodtm"/"open" disable DTM entirely, so the policy axis is a pure
    label there: both policy rows come from ONE replay and their arrays
    are identical."""
    spec = SweepSpec(**dict(_QUICK, policies=("ramp", "pid")))
    res = run_sweep(spec, cache_dir=tmp_path)
    by_pol = {}
    for r in res.records:
        by_pol.setdefault((r.point.policy, r.machine), r)
    for mc in spec.machines:
        a, b = by_pol[("ramp", mc)], by_pol[("pid", mc)]
        np.testing.assert_array_equal(a.report.peak_C, b.report.peak_C)
        np.testing.assert_array_equal(a.report.dyn_W, b.report.dyn_W)


def test_registry_rejects_duplicates():
    wd = registry.get("dmm")
    with pytest.raises(ValueError, match="already registered"):
        registry.register(wd)
    with pytest.raises(ValueError, match="unknown workload"):
        registry.get("nope")
