"""Sharded sweep execution: device-count invariance (1 vs N shards give
bit-identical records and the same cache key), padding correctness for
non-dividing batch sizes, and mesh validation.

The multi-device cases force 4 XLA host devices in a SUBPROCESS
(``--xla_force_host_platform_device_count`` must be set before jax
initializes, so it cannot run in this process)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.parallel import sharding
from repro.sweep import SweepSpec, run_sweep

_QUICK = dict(workloads=("hist",), sizes=(4096,), n_dram=(1,),
              fb_modes=("open",), grid_n=8, n_intervals=4,
              steps_per_interval=1, n_cg=15)

_SUBPROCESS = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.sweep import SweepSpec, run_sweep

spec = SweepSpec(workloads=("hist", "sort"), sizes=(4096,), n_dram=(1,),
                 fb_modes=("open",), grid_n=8, n_intervals=4,
                 steps_per_interval=1, n_cg=15)
runs = {n: run_sweep(spec, use_cache=False, n_shards=n)
        for n in (None, 1, 3, 4)}   # 4 cases: 3 shards exercises padding
ref = runs[None]
for n, res in runs.items():
    assert [r.label for r in res.records] == [r.label for r in ref.records]
    for a, b in zip(ref.records, res.records):
        for name in ("peak_C", "min_C", "residual_C", "throttle",
                     "refresh_W", "leak_W"):
            np.testing.assert_array_equal(
                getattr(a.report, name), getattr(b.report, name),
                err_msg=f"n_shards={n} field={name}")
print("SHARD-INVARIANCE-OK", spec.content_hash())
"""


def test_single_shard_matches_vmap():
    """n_shards=1 must be bitwise the plain vmap path (runs on the one
    local device; the N-device case is the subprocess test below)."""
    spec = SweepSpec(**_QUICK)
    ref = run_sweep(spec, use_cache=False)
    got = run_sweep(spec, use_cache=False, n_shards=1)
    for a, b in zip(ref.records, got.records):
        for name in ("peak_C", "min_C", "residual_C", "throttle"):
            np.testing.assert_array_equal(getattr(a.report, name),
                                          getattr(b.report, name))


def test_cache_key_ignores_shard_count():
    """Sharding is an execution detail: the spec hash (= cache key) has
    no shard field, so any device count hits the same entry."""
    spec = SweepSpec(**_QUICK)
    assert "shard" not in str(sorted(spec.canonical()))
    assert spec.content_hash() == SweepSpec(**_QUICK).content_hash()


def test_sweep_mesh_validates_device_count():
    import jax
    n_dev = len(jax.devices())
    assert sharding.sweep_mesh(n_dev).shape["cases"] == n_dev
    with pytest.raises(ValueError, match="out of range"):
        sharding.sweep_mesh(n_dev + 1)
    with pytest.raises(ValueError, match="out of range"):
        sharding.sweep_mesh(0)


def test_pad_case_batch_roundtrip():
    import jax.numpy as jnp
    batch = (jnp.arange(10).reshape(5, 2), jnp.ones((5, 3)))
    padded, n = sharding.pad_case_batch(batch, 3)
    assert n == 5
    assert all(leaf.shape[0] == 6 for leaf in padded)
    np.testing.assert_array_equal(np.asarray(padded[0][-1]),
                                  np.asarray(padded[0][-2]))
    out = sharding.unpad_case_batch(padded, n)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(batch[0]))
    with pytest.raises(ValueError, match="inconsistent"):
        sharding.pad_case_batch((jnp.ones((5, 2)), jnp.ones((4, 2))), 3)


@pytest.mark.slow
def test_device_count_invariance_subprocess():
    """1 vs 3 vs 4 shards on 4 forced host devices: bit-identical
    records, identical cache key (the ISSUE 4 invariance pin)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARD-INVARIANCE-OK" in proc.stdout
