"""Paper §4 thermal claims (HotSpot-equivalent solve, calibrated stack).

Reproduction bands (DESIGN.md §7.2 documents the calibration): the paper's
own HotSpot configuration is unpublished, so one explicit constant set
drives BOTH dies; bands below allow a few C of slack around the paper's
numbers.  Our AP comes out even MORE uniform than the paper's ~3C span —
conservative in the direction that favors the paper's conclusion.
"""
import numpy as np
import pytest

from repro.core.constants import DRAM_LIMIT_C  # §4.3 DRAM operating limit
from repro.core.floorplan import thermal_comparison


@pytest.fixture(scope="module")
def comparison():
    return thermal_comparison(grid_ap=128, grid_simd=64, workload="dmm")


def test_ap_peak_band(comparison):
    """Fig 10: AP top-layer peak ~= 55 C."""
    peak = comparison["ap"]["peak_C"][0]
    assert 48.0 < peak < 58.0, peak


def test_ap_near_uniform(comparison):
    """Fig 10: AP span ~3 C (ours is tighter -> still 'close to uniform')."""
    span = comparison["ap"]["span_C"][0]
    assert span < 3.5, span


def test_simd_band(comparison):
    """Fig 12: SIMD top layer ranges 98..128 C."""
    peak = comparison["simd"]["peak_C"][0]
    mn = comparison["simd"]["min_C"][0]
    assert 120.0 < peak < 140.0, peak
    assert 95.0 < mn < 112.0, mn
    assert 20.0 < peak - mn < 40.0     # paper: 30 C span


def test_dram_stacking_verdict(comparison):
    """§4.3: SIMD exceeds the DRAM limit everywhere that matters; AP never."""
    ap_peak = max(comparison["ap"]["peak_C"])
    simd_min = comparison["simd"]["min_C"][0]
    assert ap_peak < DRAM_LIMIT_C               # AP: 3D DRAM stacking OK
    assert simd_min > DRAM_LIMIT_C              # SIMD: blocked outright


def test_layer_ordering(comparison):
    """Top layer (farthest from the sink) is the hottest (Fig 13)."""
    for name in ("ap", "simd"):
        peaks = comparison[name]["peak_C"]
        assert peaks[0] == max(peaks), peaks


def test_same_performance_inputs(comparison):
    """The thermal runs use the paper's same-performance design point."""
    dp = comparison["design_point"]
    assert dp.speedup == pytest.approx(350, rel=0.01)
    assert dp.power_ratio > 2.0


def test_pallas_and_jnp_solvers_agree():
    r1 = thermal_comparison(grid_ap=64, grid_simd=32, workload="dmm",
                            use_pallas=False)
    r2 = thermal_comparison(grid_ap=64, grid_simd=32, workload="dmm",
                            use_pallas=True)
    for n in ("ap", "simd"):
        np.testing.assert_allclose(r1[n]["peak_C"], r2[n]["peak_C"],
                                   rtol=1e-3, atol=0.1)
