"""Unit + smoke coverage for the serving co-simulation package."""
import numpy as np
import pytest

from repro.core import models as M
from repro.serving import (ModelServingCost, RequestShape, TrafficSpec,
                           fluid_queue, kv_bytes_per_token,
                           run_serving_cosim, serving_cost, verdict_table)
from repro.serving.sim import ServingScenario


# ---------------------------------------------------------------- traffic

def test_traffic_is_deterministic_per_seed():
    spec = TrafficSpec(shape="bursty", mean_qps=2.0, horizon_s=300)
    np.testing.assert_array_equal(spec.arrivals(), spec.arrivals())
    other = TrafficSpec(shape="bursty", mean_qps=2.0, horizon_s=300, seed=1)
    assert not np.array_equal(spec.arrivals(), other.arrivals())


@pytest.mark.parametrize("shape", ["constant", "diurnal", "bursty"])
def test_traffic_mean_rate_is_preserved(shape):
    spec = TrafficSpec(shape=shape, mean_qps=5.0, horizon_s=2000.0)
    rates = spec.rate_qps()
    assert rates.shape == (spec.n_intervals,)
    assert (rates >= 0).all()
    # constant/diurnal are mean-exact; bursty only in expectation, so
    # give the Markov chain a loose band
    tol = 0.02 if shape != "bursty" else 0.5
    assert abs(rates.mean() / 5.0 - 1.0) < tol


def test_diurnal_trough_at_start_peak_mid_cycle():
    spec = TrafficSpec(shape="diurnal", mean_qps=10.0, horizon_s=1000.0,
                       swing=0.8)
    rates = spec.rate_qps()
    assert rates.argmin() in (0, len(rates) - 1)
    assert abs(rates.argmax() - len(rates) // 2) <= 1
    assert rates.max() <= 10.0 * 1.8 + 1e-9


def test_traffic_validation():
    with pytest.raises(ValueError, match="unknown traffic shape"):
        TrafficSpec(shape="sawtooth")
    with pytest.raises(ValueError):
        TrafficSpec(horizon_s=-1.0)
    with pytest.raises(ValueError):
        TrafficSpec(swing=1.5)
    with pytest.raises(ValueError, match="resolved"):
        TrafficSpec(mean_qps=0.0).rate_qps()


NAN, INF = float("nan"), float("inf")


@pytest.mark.parametrize("kw", [
    {"horizon_s": NAN}, {"horizon_s": 0.0}, {"horizon_s": INF},
    {"interval_s": NAN}, {"interval_s": 0.0}, {"interval_s": -1.0},
    {"mean_qps": NAN}, {"mean_qps": INF},
    {"period_s": NAN},
    {"burst_ratio": NAN}, {"burst_ratio": 0.5}, {"burst_ratio": INF},
    {"p_enter": 0.0}, {"p_exit": 1.5},
])
def test_traffic_rejects_nonfinite_shape_params(kw):
    """NaN knobs would sail through the naive comparisons (`nan <= 0`
    is False) and lower into NaN rate paths; every guard is phrased so
    NaN raises at construction instead."""
    with pytest.raises(ValueError):
        TrafficSpec(**kw)


def test_rate_qps_rejects_nonfinite_mean():
    spec = TrafficSpec(mean_qps=0.0)       # auto: resolved at lowering
    with pytest.raises(ValueError, match="resolved"):
        spec.rate_qps(NAN)
    with pytest.raises(ValueError, match="resolved"):
        spec.rate_qps(INF)
    assert spec.rate_qps(2.0).shape == (spec.n_intervals,)


# ------------------------------------------------------------------- cost

def test_serving_cost_basics():
    cost = serving_cost("stablelm-1.6b", RequestShape(1024, 128))
    assert cost.n_params > 1e9
    assert 0 < cost.n_active <= cost.n_params
    assert cost.prefill_flops == 2.0 * cost.n_active * 1024
    assert cost.request_flops > cost.prefill_flops
    # one more sequence costs KV reads but shares the parameter stream
    assert cost.decode_step_bytes(2) - cost.decode_step_bytes(1) \
        == pytest.approx(cost.kv_bytes_tok * cost.mean_context)


def test_decode_ai_rises_with_batch_then_saturates():
    cost = serving_cost("stablelm-1.6b")
    ais = [cost.decode_ai(b) for b in (1, 4, 16, 64)]
    assert all(b > a for a, b in zip(ais, ais[1:]))
    # KV-bound ceiling: flops/token over KV words per token
    ceiling = cost.decode_flops_per_token / (
        cost.kv_bytes_tok * cost.mean_context / M.BYTES_PER_WORD)
    assert ais[-1] < ceiling


def test_kv_bytes_family_rules():
    from repro.configs import get_config
    assert kv_bytes_per_token(get_config("falcon-mamba-7b")) == 0.0
    mla = get_config("deepseek-v2-lite-16b")
    assert kv_bytes_per_token(mla) \
        == mla.n_layers * (mla.mla.kv_lora + mla.mla.qk_rope) * 2.0
    hyb = get_config("zamba2-1.2b")
    dense = get_config("stablelm-1.6b")
    assert 0 < kv_bytes_per_token(hyb) < kv_bytes_per_token(dense) * 10


def test_serving_workload_anchoring():
    cost = serving_cost("stablelm-1.6b")
    wl = cost.workload(32)
    assert wl.name == "serve:stablelm-1.6b"
    # inverse-AI anchoring: i_s * AI is the DMM invariant
    dmm = M.WORKLOADS["dmm"]
    assert wl.i_s * cost.decode_ai(32) \
        == pytest.approx(dmm.i_s * M.ARITH_INTENSITY["dmm"])
    with pytest.raises(ValueError):
        M.derived_workload("bad", 0.0)


# ------------------------------------------------------------------ queue

def _cost_stub(w_req=100.0, prompt=1, out=1):
    return ModelServingCost(config="stub", request=RequestShape(prompt, out),
                            n_params=w_req, n_active=w_req / (2 * (prompt + out)),
                            kv_bytes_tok=0.0)


def test_fluid_queue_conserves_work():
    cost = _cost_stub()
    arrivals = np.array([3, 0, 5, 1, 0, 0, 2, 0])
    q = fluid_queue(arrivals, cost, cap_flops_per_s=150.0,
                    throttle=np.ones(8), interval_s=1.0, max_batch=4)
    w = cost.request_flops
    np.testing.assert_allclose(q.served_flops.sum() + q.backlog_flops[-1],
                               arrivals.sum() * w)
    assert (q.busy >= 0).all() and (q.busy <= 1 + 1e-12).all()
    assert (q.batch >= 1).all() and (q.batch <= 4).all()
    assert q.latency_s.shape == (arrivals.sum(),)
    assert (q.latency_s > 0).all()


def test_fluid_queue_throttle_slows_service():
    cost = _cost_stub()
    arrivals = np.array([4, 4, 4, 4])
    fast = fluid_queue(arrivals, cost, 500.0, np.ones(4), 1.0, 8)
    slow = fluid_queue(arrivals, cost, 500.0, np.full(4, 0.5), 1.0, 8)
    assert slow.served_flops.sum() <= fast.served_flops.sum()
    assert np.percentile(slow.latency_s, 99) \
        > np.percentile(fast.latency_s, 99)


def test_fluid_queue_overload_latency_extrapolates():
    cost = _cost_stub()
    # 10x overload: most requests finish past the horizon
    q = fluid_queue(np.full(4, 10), cost, 100.0, np.ones(4), 1.0, 8)
    assert q.backlog_flops[-1] > 0
    assert np.isfinite(q.latency_s).all()
    assert q.latency_s.max() > 4.0      # beyond the simulated window


# ------------------------------------------------------- end-to-end smoke

def test_run_serving_cosim_smoke():
    sc = ServingScenario(
        config="stablelm-1.6b",
        traffic=TrafficSpec(shape="diurnal", horizon_s=120.0),
        load=0.6, grid_n=8, n_rounds=2, coarsen_tol=0.05, pad_quantum=16)
    reps = run_serving_cosim(sc)
    assert set(reps) == {"ap", "simd"}
    for rep in reps.values():
        assert rep.n_base == 120
        assert rep.n_coarse <= rep.n_base
        assert float(rep.durations_s.sum()) == pytest.approx(120.0)
        assert rep.error_bound_C > 0
        # residual is a throttle delta, so it lives in [0, 1 - dtm_floor];
        # the hot SIMD pair may flip a DTM boundary interval between
        # macro-rounds, but the never-throttled AP must be converged
        assert 0.0 <= rep.throttle_residual <= 0.75 + 1e-9
        assert rep.stack.logic_peak_C.max() > 25.0
        assert rep.p99_s >= rep.p50_s > 0
    assert reps["ap"].throttle_residual < 0.05
    # the paper's asymmetry survives under serving load: the AP pair
    # runs no hotter than the dense SIMD pair
    assert reps["ap"].stack.logic_peak_C.max() \
        <= reps["simd"].stack.logic_peak_C.max()
    table = verdict_table({sc.label: reps})
    assert table.count("\n") == 2
    assert "stablelm-1.6b,diurnal,ap," in table
    centers, qps, secs = reps["ap"].throttle_curve()
    assert secs.sum() == pytest.approx(120.0)
    assert (qps >= 0).all()


def test_scenario_validation():
    tr = TrafficSpec(horizon_s=60.0)
    with pytest.raises(ValueError):
        ServingScenario(config="x", traffic=tr, load=0.0)
    with pytest.raises(ValueError):
        ServingScenario(config="x", traffic=tr, n_rounds=0)
    with pytest.raises(ValueError, match="unknown machine"):
        run_serving_cosim(
            ServingScenario(config="stablelm-1.6b",
                            traffic=TrafficSpec(horizon_s=30.0)),
            machines=("tpu",))
