"""Cross-cutting system invariants (property-based where useful)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import isa, thermal
from repro.core.engine import APEngine, PassSchedule


# ----------------------------------------------------- truth-table compiler
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1 << 16), n_in=st.integers(2, 3))
def test_compiled_truth_table_equals_direct_application(seed, n_in):
    """Executing a compiled table on the AP == applying fn row-wise, for
    any function with disjoint output columns (always conflict-free)."""
    rng = np.random.default_rng(seed)
    table = {tuple((x >> i) & 1 for i in range(n_in)):
             tuple(rng.integers(0, 2, 2)) for x in range(1 << n_in)}
    fn = lambda bits: table[tuple(bits)]

    eng = APEngine(n_words=128, n_bits=n_in + 2)
    in_cols = list(range(n_in))
    out_cols = [n_in, n_in + 1]
    vals = rng.integers(0, 1 << n_in, 128, dtype=np.uint64)
    eng.load(isa.Field(0, n_in), vals)
    passes = isa.compile_table(in_cols, out_cols, fn)
    if passes:
        eng.run(isa.schedule(passes))
    got = eng.peek(isa.Field(n_in, 2))
    want = np.array([table[tuple((int(v) >> i) & 1 for i in range(n_in))]
                     for v in vals])
    want_int = want[:, 0] + 2 * want[:, 1]
    np.testing.assert_array_equal(got, want_int)


def test_schedule_concat_equals_sequential_runs():
    rng = np.random.default_rng(0)
    s1 = PassSchedule.build([([0, 1], [1, 0], [2], [1]),
                             ([2], [1], [3], [1])])
    s2 = PassSchedule.build([([3, 0], [1, 1], [1, 2], [0, 0])])
    vals = rng.integers(0, 16, 64, dtype=np.uint64)

    eng_a = APEngine(n_words=64, n_bits=8)
    eng_a.load(isa.Field(0, 4), vals)
    eng_a.run(s1)
    eng_a.run(s2)

    eng_b = APEngine(n_words=64, n_bits=8)
    eng_b.load(isa.Field(0, 4), vals)
    eng_b.run(PassSchedule.concat([s1, s2]))

    np.testing.assert_array_equal(eng_a.peek(isa.Field(0, 8)),
                                  eng_b.peek(isa.Field(0, 8)))
    assert eng_a.cycles == eng_b.cycles
    assert eng_a.energy == pytest.approx(eng_b.energy)


# ------------------------------------------------------------ MoE dispatch
def test_moe_groups_invariance_without_drops():
    """groups=1 vs groups=4 give identical outputs when capacity is ample
    (grouping only changes WHERE tokens sit in the dispatch buffer)."""
    from repro.configs import get_config
    from repro.models import moe as moe_mod

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    key = jax.random.PRNGKey(0)
    params = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, aux1 = moe_mod.moe_ffn(params, x, cfg, groups=1)
    y4, aux4 = moe_mod.moe_ffn(params, x, cfg, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-5, atol=1e-5)
    assert float(aux1) == pytest.approx(float(aux4), rel=1e-5)


def test_moe_identity_experts_preserve_combine_weights():
    """With every expert ~ identity-ish (zero weights -> zero output), the
    routed output is exactly the shared-expert output: combine never
    injects mass for dropped or phantom tokens."""
    from repro.configs import get_config
    from repro.models import moe as moe_mod

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    params["experts"] = jax.tree_util.tree_map(
        jnp.zeros_like, params["experts"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, _ = moe_mod.moe_ffn(params, x, cfg, groups=2)
    from repro.models.layers import swiglu
    want = swiglu(params["shared"], x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- optimizer
def test_adamw_bf16_moments_track_f32():
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    params = {"w": jnp.ones((32, 32)) * 0.5}
    g = {"w": jnp.full((32, 32), 0.01)}
    cfgs = {
        "f32": AdamWConfig(lr=1e-2, warmup_steps=1),
        "bf16": AdamWConfig(lr=1e-2, warmup_steps=1,
                            moments_dtype=jnp.bfloat16),
    }
    outs = {}
    for name, cfg in cfgs.items():
        p, o = params, adamw_init(params, cfg)
        for _ in range(5):
            p, o, _ = adamw_update(p, g, o, cfg)
        outs[name] = np.asarray(p["w"])
    np.testing.assert_allclose(outs["bf16"], outs["f32"], rtol=2e-2)


def test_adamw_schedule_warmup_then_decay():
    from repro.optim import AdamWConfig
    from repro.optim.adamw import schedule

    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(jnp.float32(s), cfg)) for s in range(1, 100, 7)]
    peak = max(lrs)
    assert lrs.index(peak) <= 2            # warmup reaches peak early
    assert lrs[-1] < peak                   # cosine decays
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac - 1e-6


# --------------------------------------------------------------- thermal
def test_steady_state_energy_conservation():
    """At steady state, flux into the package lump equals total power."""
    rng = np.random.default_rng(0)
    grid = thermal.Grid(die_w=5e-3, ny=24, nx=24, margin=6)
    power = rng.uniform(0, 2e-3, size=(4, 24, 24)).astype(np.float32)
    F = grid.fields()
    p_dom = grid.pad_power(power)
    m = grid.margin
    p_dom = jnp.pad(p_dom, ((0, 0), (m, m), (m, m)))
    dT = thermal._cg_solve_fields(p_dom, F, tol=1e-10)
    flux_out = float(jnp.sum(F["g_pkg"] * dT))
    assert flux_out == pytest.approx(float(power.sum()), rel=1e-3)


def test_thermal_superposition():
    """The steady-state operator is linear: T(P1+P2) == T(P1)+T(P2)."""
    rng = np.random.default_rng(1)
    grid = thermal.Grid(die_w=4e-3, ny=16, nx=16)
    p1 = rng.uniform(0, 1e-3, (4, 16, 16)).astype(np.float32)
    p2 = rng.uniform(0, 1e-3, (4, 16, 16)).astype(np.float32)
    t1 = np.asarray(thermal.steady_state(p1, grid)) - thermal.AMBIENT_C
    t2 = np.asarray(thermal.steady_state(p2, grid)) - thermal.AMBIENT_C
    t12 = np.asarray(thermal.steady_state(p1 + p2, grid)) - thermal.AMBIENT_C
    np.testing.assert_allclose(t12, t1 + t2, rtol=1e-3, atol=1e-3)


def test_transient_approaches_steady_state():
    grid = thermal.Grid(die_w=3e-3, ny=8, nx=8)
    power = np.full((4, 8, 8), 1e-3, np.float32)
    t_ss = np.asarray(thermal.steady_state(power, grid))
    t_tr, peaks = thermal.transient_solve(power, grid, t_end=2.0)
    # transient temperature of the silicon layers approaches steady state
    np.testing.assert_allclose(np.asarray(t_tr)[:4], t_ss, atol=1.5)
