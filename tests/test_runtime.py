"""Runtime substrate: data determinism, checkpoint atomicity/restart,
straggler monitor, gradient compression (single-device paths)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import SyntheticLM
from repro.optim import AdamWConfig, adamw_init
from repro.optim.compress import (compress_tree, ef_compress, ef_decompress,
                                  init_residuals)
from repro.runtime.trainer import StragglerMonitor, TrainerConfig, train_loop


# ------------------------------------------------------------------- data
def test_data_deterministic_and_step_addressable():
    p = SyntheticLM(vocab=1000, seq_len=64, global_batch=8, seed=3)
    b1 = p.batch(5)
    b2 = p.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full = SyntheticLM(vocab=1000, seq_len=64, global_batch=8, seed=3)
    b = full.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_host_sharding_consistent():
    """Two hosts generating their shards == one host generating all."""
    whole = SyntheticLM(vocab=500, seq_len=32, global_batch=8, seed=1)
    h0 = SyntheticLM(vocab=500, seq_len=32, global_batch=8, seed=1,
                     host_index=0, host_count=2)
    h1 = SyntheticLM(vocab=500, seq_len=32, global_batch=8, seed=1,
                     host_index=1, host_count=2)
    w = whole.batch(7)["tokens"]
    np.testing.assert_array_equal(w[:4], h0.batch(7)["tokens"])
    np.testing.assert_array_equal(w[4:], h1.batch(7)["tokens"])


def test_data_has_learnable_structure():
    p = SyntheticLM(vocab=1000, seq_len=256, global_batch=4, seed=0)
    b = p.batch(0)
    t = b["tokens"]
    repeats = (t[:, 1:] == t[:, :-1]).mean()
    assert repeats > 0.02    # repetition signal exists


# ------------------------------------------------------------- checkpoints
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": {"w": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
                  "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 12, t)
    assert latest_step(tmp_path) == 12
    out = restore(tmp_path, 12, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    t = _tree()
    save(tmp_path, 3, t)
    # a crashed save leaves a .tmp dir -> must be ignored
    (tmp_path / "step_00000009.tmp").mkdir()
    assert latest_step(tmp_path) == 3


def test_checkpoint_keep_last(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    steps = sorted(int(d.name[5:]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(tmp_path, 1, _tree())
    bad = {"a": {"w": jnp.zeros((5, 8)), "b": jnp.zeros((8,))},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        restore(tmp_path, 1, jax.eval_shape(lambda: bad))


# ------------------------------------------------- trainer fault tolerance
def _mk_train_setup(tmp_path, steps, ckpt_every=4):
    import repro.models.model as M
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_step
    from repro.models.model import PerfConfig

    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              n_layers=2, d_model=64, n_heads=2,
                              n_kv_heads=2, d_ff=128, vocab=512, d_head=32)
    mesh = make_local_mesh(1, 1)
    cell = ShapeCell("t", 32, 4, "train")
    ts, _ = make_train_step(cfg, cell, mesh,
                            perf=PerfConfig(remat="none", accum_steps=1),
                            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=2,
                                                total_steps=steps),
                            dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(params)
    pipe = SyntheticLM(cfg.vocab, 32, 4, seed=0)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path))
    return ts, params, opt, pipe, tcfg


def test_restart_resumes_identical_trajectory(tmp_path):
    """Kill-and-restart == uninterrupted run, bit-for-bit on the loss."""
    ts, params, opt, pipe, tcfg = _mk_train_setup(tmp_path / "full", 10)
    full = train_loop(ts, params, opt, pipe, tcfg)

    ts2, params2, opt2, pipe2, tcfg2 = _mk_train_setup(tmp_path / "int", 10)
    tcfg_first = dataclasses.replace(tcfg2, steps=6)
    train_loop(ts2, params2, opt2, pipe2, tcfg_first)      # "crashes" after 6
    resumed = train_loop(ts2, params2, opt2, pipe2, tcfg2)  # restart

    full_losses = {h["step"]: h["loss"] for h in full["history"]}
    res_losses = {h["step"]: h["loss"] for h in resumed["history"]}
    # resumed run starts after the last checkpoint (step 3) and must match
    for step, loss in res_losses.items():
        assert loss == pytest.approx(full_losses[step], rel=1e-5), step


def test_straggler_monitor_detects_slow_steps():
    mon = StragglerMonitor(factor=3.0, alpha=0.5)
    for _ in range(8):
        mon.observe(0.1)
    assert mon.stragglers == 0
    mon.observe(1.0)        # 10x the EWMA
    assert mon.stragglers == 1
    mon.observe(0.1)
    assert mon.stragglers == 1


# ----------------------------------------------------------- compression
def test_ef_compress_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    r = jnp.zeros_like(g)
    q, scale, r2 = ef_compress(g, r)
    assert q.dtype == jnp.int8
    recon = ef_decompress(q, scale)
    assert float(jnp.max(jnp.abs(recon - g))) <= float(scale) * 0.5 + 1e-6


def test_ef_error_feedback_unbiased_over_time():
    """Sum of decompressed grads converges to sum of true grads (EF)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    got_sum = np.zeros(64, np.float32)
    r = jnp.zeros(64, jnp.float32)
    for i in range(50):
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        q, s, r = ef_compress(g, r)
        true_sum += np.asarray(g)
        got_sum += np.asarray(ef_decompress(q, s))
    # residual carries the outstanding error; totals match within it
    np.testing.assert_allclose(got_sum + np.asarray(r), true_sum, rtol=1e-4,
                               atol=1e-4)


def test_compress_tree_shapes():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    res = init_residuals(params)
    q, s, r = compress_tree(params, res)
    assert q["w"].dtype == jnp.int8 and q["b"].shape == (4,)
