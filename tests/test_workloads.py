"""Paper §3.1 workloads on the AP: correctness + the cycle-count claims."""
import numpy as np
import pytest

from repro.workloads import blackscholes as bs
from repro.workloads import dmm, fft


# ------------------------------------------------------------------ DMM
def test_dmm_exact():
    rng = np.random.default_rng(0)
    A = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    B = rng.integers(0, 64, (8, 8), dtype=np.uint64)
    C, ctr = dmm.ap_matmul(A, B, m=6)
    np.testing.assert_array_equal(C, dmm.reference(A, B))
    assert ctr["mac_cycles"] > 0


def test_dmm_cycles_scale_with_n_not_pus():
    """sqrt(N) sequential MACs: cycles ~ n * O(m^2), NOT n^2 (PU count)."""
    rng = np.random.default_rng(1)
    cycles = {}
    for n in (4, 8):
        A = rng.integers(0, 32, (n, n), dtype=np.uint64)
        B = rng.integers(0, 32, (n, n), dtype=np.uint64)
        C, ctr = dmm.ap_matmul(A, B, m=5)
        np.testing.assert_array_equal(C, dmm.reference(A, B))
        cycles[n] = ctr["mac_cycles"]
    ratio = cycles[8] / cycles[4]
    # linear in n (ratio ~2 with carry-ripple endcaps), far from PU-count x4
    assert 1.8 < ratio < 2.6, ratio


# ------------------------------------------------------------------ FFT
@pytest.mark.parametrize("N", [8, 16])
def test_fft_matches_numpy(N):
    rng = np.random.default_rng(N)
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.4 / np.sqrt(N))
    X, ctr = fft.ap_fft(x, m=16, frac=12)
    ref = fft.reference(x)
    rel = np.max(np.abs(X - ref)) / np.max(np.abs(ref))
    assert rel < 0.01, rel


def test_fft_compute_cycles_length_independent_per_stage():
    """Word-parallel butterflies: per-stage compute cycles do not grow with N
    (only the stage count log2 N does) — eq (7)'s premise."""
    rng = np.random.default_rng(3)
    per_stage = {}
    for N in (8, 32):
        x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.3 / np.sqrt(N))
        X, ctr = fft.ap_fft(x, m=12, frac=9, interconnect="parallel")
        ref = fft.reference(x)
        assert np.max(np.abs(X - ref)) / np.max(np.abs(ref)) < 0.05
        stages = int(np.log2(N))
        per_stage[N] = ctr["cycles"] / stages
    # twiddle broadcast adds 2^s passes/stage; compute dominates => ~flat
    assert per_stage[32] / per_stage[8] < 1.25


def test_fft_serial_interconnect_costs_more():
    rng = np.random.default_rng(4)
    N = 16
    x = (rng.normal(size=N) + 1j * rng.normal(size=N)) * (0.3 / np.sqrt(N))
    _, c_par = fft.ap_fft(x, m=12, frac=9, interconnect="parallel")
    _, c_ser = fft.ap_fft(x, m=12, frac=9, interconnect="serial")
    assert c_ser["cycles"] > c_par["cycles"]


# ------------------------------------------------------------ Black-Scholes
def test_blackscholes_accuracy():
    rng = np.random.default_rng(5)
    n = 32
    S = rng.uniform(0.8, 1.6, n)
    K = rng.uniform(0.8, 1.6, n)
    T = rng.uniform(0.3, 2.0, n)
    sig = rng.uniform(0.15, 0.6, n)
    C, ctr = bs.ap_blackscholes(S, K, T, sig, r=0.05)
    ref = bs.reference(S, K, T, sig, r=0.05)
    assert np.max(np.abs(C - ref)) < 0.01  # Q6.10 + 10-bit LUT envelope
    assert ctr["cycles"] > 0


def test_blackscholes_cycles_independent_of_n():
    """The paper's embarrassingly-parallel case: same cycles for any N."""
    rng = np.random.default_rng(6)
    cyc = {}
    for n in (32, 128):
        S = rng.uniform(0.9, 1.4, n)
        K = rng.uniform(0.9, 1.4, n)
        T = rng.uniform(0.5, 1.5, n)
        sig = rng.uniform(0.2, 0.5, n)
        _, ctr = bs.ap_blackscholes(S, K, T, sig)
        # exclude the sequential result read-out (1 cycle/word, §2.1)
        cyc[n] = ctr["cycles"] - ctr["read_cycles"]
    assert cyc[32] == cyc[128]


def test_blackscholes_monotone_in_spot():
    """Sanity: call price increases with S (no sign/LUT pathologies)."""
    n = 32
    S = np.linspace(0.8, 1.6, n)
    K = np.full(n, 1.0)
    T = np.full(n, 1.0)
    sig = np.full(n, 0.3)
    C, _ = bs.ap_blackscholes(S, K, T, sig)
    assert (np.diff(C) > -0.01).all()
