"""Pallas mg_smooth kernel vs the jnp oracle (core/multigrid.py), and
the full multigrid solve on the Pallas smoother path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multigrid as mg
from repro.core import thermal
from repro.kernels.mg_smooth import ops
from repro.stack.spec import dram_on_logic


def _fixture(n=32, margin=8, n_dram=2, seed=0):
    grid = thermal.Grid(die_w=5e-3, ny=n, nx=n, margin=margin,
                        spec=dram_on_logic(n_dram))
    F = grid.fields()
    rng = np.random.default_rng(seed)
    shape = F["g_pkg"].shape
    T = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    b = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return grid, F, T, b


@pytest.mark.parametrize("color", [0, 1])
@pytest.mark.parametrize("block_y", [8, 16, 64])
def test_kernel_matches_oracle(color, block_y):
    _, F, T, b = _fixture()
    d = jnp.full(F["g_pkg"].shape, 0.5, jnp.float32)
    ref = mg.rb_line_sweep(T, b, F, d, color)
    ker = ops.rb_line_sweep(T, b, F, d, color, block_y=block_y)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_handles_scalar_d_extra():
    _, F, T, b = _fixture(n=16, margin=4, n_dram=1, seed=2)
    ref = mg.rb_line_sweep(T, b, F, 0.0, 1)
    ker = ops.rb_line_sweep(T, b, F, 0.0, 1)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_steady_mg_pallas_path_matches_jnp():
    """steady_state(solver="mg"/"mgcg", use_pallas=True) smooths with
    this kernel and must agree with the jnp smoother path."""
    grid, _, _, _ = _fixture()
    n = grid.ny
    logic = list(grid.stack.logic_layers)
    p = np.zeros((grid.n_die_layers, n, n), np.float32)
    p[logic] = 40.0 / (len(logic) * n * n)
    for solver in ("mg", "mgcg"):
        T_jnp = thermal.steady_state(p, grid, solver=solver)
        T_pal = thermal.steady_state(p, grid, solver=solver,
                                     use_pallas=True)
        assert float(jnp.abs(T_pal - T_jnp).max()) < 1e-3, solver
