"""Fault injection & graceful degradation (the ISSUE 10 pins).

Four stories, each with its acceptance hook:

- **Fault models** (`repro.faults.models`): spec validation rejects
  nonsense (NaN knobs included), seeded replay is bitwise reproducible
  (hypothesis property + full-replay determinism), and the fault-free
  path is bit-identical to the pre-faults program — pinned by a jaxpr
  test (no ``random`` ops traced when ``FeedbackParams.faults`` is
  None).
- **GuardedPolicy** (`repro.faults.guard`): median-of-K rejects a stuck
  minority, NaN readings hold the last good value, sustained blindness
  on a die layer panics to the fail-safe floor — and a replay-level
  rescue: the naive per-die controller blows the 85 °C ceiling under a
  stuck primary sensor, the guarded wrapper holds it.
- **Solver fallback** (`repro.core.thermal`): a poisoned (forced-NaN)
  multigrid solve is detected by the true-residual health check and
  retried down the chain, with retry counters in the obs registry;
  exhausting the chain is loud, never silent.
- **Failure-isolated sweeps** (`repro.sweep.engine`): a group whose
  replay raises is demoted to NaN records marked FAILED; the other
  groups' results survive and nothing is persisted to the cache.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.core import cosim, thermal
from repro.faults import (GuardedPolicy, PowerFaultSpec, SensorFaultSpec,
                          inject_power_spikes, poison_solver,
                          solver_poisoned)
from repro.policy import POLICIES, PerDiePolicy
from repro.policy.base import Policy, PolicyContext
from repro.stack import feedback
from repro.stack.spec import PAPER_STACK, dram_on_logic
from repro.sweep import SweepSpec, engine, run_sweep

pytestmark = pytest.mark.faults


# ------------------------------------------------------------ spec validation

@pytest.mark.parametrize("kw", [
    {"n_sensors": 0},
    {"noise_C": -1.0},
    {"noise_C": float("nan")},
    {"offset_C": float("inf")},
    {"drift_C": float("nan")},
    {"quant_C": -0.5},
    {"n_stuck": -1},
    {"n_stuck": 4},                      # > n_sensors (default 3)
    {"p_dropout": 1.5},
    {"p_dropout": float("nan")},
])
def test_sensor_spec_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        SensorFaultSpec(**kw)


@pytest.mark.parametrize("kw", [
    {"n_spikes": -1},
    {"width": 0},
    {"magnitude": float("nan")},
    {"magnitude": -2.0},
])
def test_power_spec_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        PowerFaultSpec(**kw)


def test_spec_is_hashable_static():
    """The spec rides FeedbackParams as a jit static arg: frozen and
    hashable, equal specs hash equal (one compilation per regime)."""
    import dataclasses
    a = SensorFaultSpec(seed=3, noise_C=0.5)
    assert hash(a) == hash(SensorFaultSpec(seed=3, noise_C=0.5))
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.seed = 4
    assert not SensorFaultSpec().randomized
    assert SensorFaultSpec(noise_C=0.1).randomized
    assert SensorFaultSpec(p_dropout=0.1).randomized


# --------------------------------------------------- read() fault semantics

def _scan_read(spec, T_path):
    """Scan spec.read over a [T, L] true-temperature path -> [T, K, L]."""
    def step(state, T):
        state, r = spec.read(state, T)
        return state, r
    _, out = jax.lax.scan(step, spec.init_state(T_path.shape[1]),
                          jnp.asarray(T_path, jnp.float32))
    return np.asarray(out)


def test_stuck_at_latches_first_reading():
    spec = SensorFaultSpec(n_sensors=3, n_stuck=1)
    path = np.stack([np.full(4, 30.0), np.full(4, 90.0)])
    out = _scan_read(spec, path)
    np.testing.assert_array_equal(out[1, 0], 30.0)   # sensor 0 latched
    np.testing.assert_array_equal(out[1, 1:], 90.0)  # the rest track


def test_quantization_snaps_to_step():
    spec = SensorFaultSpec(n_sensors=2, quant_C=0.5)
    out = _scan_read(spec, np.array([[31.26, 47.13]]))
    np.testing.assert_array_equal(out % 0.5, 0.0)
    np.testing.assert_allclose(out[0, 0], [31.5, 47.0])


def test_dropout_returns_nan():
    heavy = _scan_read(SensorFaultSpec(n_sensors=3, p_dropout=0.5),
                       np.full((20, 2), 50.0))
    clean = _scan_read(SensorFaultSpec(n_sensors=3),
                       np.full((20, 2), 50.0))
    assert np.isnan(heavy).any()
    assert np.isfinite(clean).all()
    np.testing.assert_array_equal(clean, 50.0)


def test_drift_and_offset_compose():
    spec = SensorFaultSpec(n_sensors=2, drift_C=0.5, offset_C=1.0)
    out = _scan_read(spec, np.full((3, 1), 40.0))
    off = np.asarray(spec.init_state(1).offset)
    # interval t reads true + offset + drift*t, per sensor
    for t in range(3):
        np.testing.assert_allclose(out[t, :, 0], 40.0 + off + 0.5 * t,
                                   rtol=1e-6)


@settings(max_examples=25)
@given(seed=st.integers(0, 2**31 - 1),
       noise=st.floats(0.0, 5.0, allow_nan=False),
       p_drop=st.floats(0.0, 0.9, allow_nan=False),
       n_stuck=st.integers(0, 3))
def test_seeded_read_is_bitwise_reproducible(seed, noise, p_drop, n_stuck):
    """The property the cache/baselines rely on: same spec -> bitwise
    identical fault realizations, replay after replay."""
    spec = SensorFaultSpec(seed=seed, n_sensors=3, noise_C=noise,
                           p_dropout=p_drop, n_stuck=n_stuck)
    path = np.linspace(25.0, 95.0, 6 * 4).reshape(6, 4)
    np.testing.assert_array_equal(_scan_read(spec, path),
                                  _scan_read(spec, path))


def test_different_seeds_differ_when_randomized():
    path = np.full((8, 2), 60.0)
    a = _scan_read(SensorFaultSpec(seed=0, noise_C=1.0), path)
    b = _scan_read(SensorFaultSpec(seed=1, noise_C=1.0), path)
    assert not np.array_equal(a, b)


# ----------------------------------------------------- power-spike injection

def test_power_spikes_deterministic_and_pure():
    dyn = np.ones((10, 2, 3, 3), np.float32)
    spec = PowerFaultSpec(seed=7, n_spikes=3, magnitude=2.5)
    out = inject_power_spikes(dyn, spec)
    np.testing.assert_array_equal(out, inject_power_spikes(dyn, spec))
    np.testing.assert_array_equal(dyn, 1.0)          # input untouched
    spiked = (out[:, 0, 0, 0] == 2.5).sum()
    assert spiked == 3
    np.testing.assert_array_equal(np.unique(out), [1.0, 2.5])
    # n_spikes=0 is the identity; spikes cap at the trace length
    np.testing.assert_array_equal(inject_power_spikes(
        dyn, PowerFaultSpec(n_spikes=0)), dyn)
    all_hit = inject_power_spikes(dyn, PowerFaultSpec(n_spikes=99))
    np.testing.assert_array_equal(all_hit, 2.0)


# ----------------------------------------------------------- GuardedPolicy

def _ctx(layer_T, sensor_T=None, n_layers=None):
    L = len(layer_T) if n_layers is None else n_layers
    return PolicyContext(
        layer_T=jnp.asarray(layer_T, jnp.float32),
        logic_mask=jnp.ones(L, jnp.float32),
        dram_mask=jnp.zeros(L, jnp.float32),
        predict_hot=lambda duty: jnp.zeros_like(jnp.asarray(duty)),
        sensor_T=None if sensor_T is None
        else jnp.asarray(sensor_T, jnp.float32))


def test_guard_needs_n_layers():
    with pytest.raises(ValueError, match="n_layers"):
        GuardedPolicy().init_state()
    st3 = GuardedPolicy().init_state(3)
    assert st3[1].shape == (3,) and st3[2].shape == (3,)


@pytest.mark.parametrize("kw", [
    {"floor": 0.0}, {"floor": 1.5}, {"hold_max": 0},
    {"max_step_C": 0.0}, {"max_step_C": float("nan")},
    {"lo_C": 50.0, "hi_C": 40.0}, {"hi_C": float("inf")},
])
def test_guard_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        GuardedPolicy(**kw)


def test_guard_median_rejects_stuck_minority():
    g = GuardedPolicy()
    state = g.init_state(2)
    # primary stuck at ambient, two healthy sensors read 80 C
    sensors = [[25.0, 25.0], [80.0, 80.0], [80.0, 80.0]]
    state, _, _ = g.act(state, _ctx([25.0, 25.0], sensors))
    np.testing.assert_allclose(np.asarray(state[1]), 80.0)
    np.testing.assert_array_equal(np.asarray(state[2]), 0)


def test_guard_nan_holds_last_good_then_panics():
    g = GuardedPolicy(hold_max=2)
    state = g.init_state(1)
    state, _, _ = g.act(state, _ctx([70.0], [[70.0]]))    # good: holds 70
    nan_ctx = _ctx([np.nan], [[np.nan]])
    state, f_p, f = g.act(state, nan_ctx)                 # bad #1: hold
    assert float(state[1][0]) == 70.0 and int(state[2][0]) == 1
    assert float(f) == 1.0
    state, f_p, f = g.act(state, nan_ctx)                 # bad #2: panic
    assert int(state[2][0]) == 2
    assert float(f_p) == float(f) == g.floor


def test_guard_implausible_jump_is_held():
    g = GuardedPolicy(max_step_C=60.0)
    state = g.init_state(1)
    state, _, _ = g.act(state, _ctx([30.0], [[30.0]]))
    state, _, _ = g.act(state, _ctx([130.0], [[130.0]]))  # +100 C in one dt
    assert float(state[1][0]) == 30.0                     # held, not trusted
    state, _, _ = g.act(state, _ctx([140.0], [[140.0]]))  # out of range hi_C?
    assert int(state[2][0]) == 2


def test_guard_fault_free_passthrough():
    """Without sensor_T the guard fuses the one true reading: T_used is
    layer_T exactly, and the inner policy sees the same context."""
    g = GuardedPolicy(inner=PerDiePolicy())
    state = g.init_state(2)
    state, f_p, f = g.act(state, _ctx([50.0, 60.0]))
    np.testing.assert_array_equal(np.asarray(state[1]), [50.0, 60.0])
    ref_state = PerDiePolicy().init_state(2)
    _, rf_p, rf = PerDiePolicy().act(ref_state, _ctx([50.0, 60.0]))
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(rf_p))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(rf))


def test_guarded_registered_in_policy_registry():
    pol = POLICIES["guarded"]()
    assert isinstance(pol, GuardedPolicy)
    assert pol.name == "guarded-perdie"


# ------------------------------------------------- replay-level integration

_GRID_N = 8
_N_INT = 16


def _sort_ap_case(spec):
    dp = cosim.comparable_design_point("sort", 2 ** 20)
    trace = cosim.ap_workload_trace("sort", _N_INT,
                                   cosim.trace_elems(2 ** 20))
    return [("sort/ap", feedback.assemble_case(
        dp, "sort", "ap", spec, PAPER_STACK, _GRID_N, trace,
        _GRID_N // 4))]


def _replay(case, spec, fb):
    return feedback.replay_cases(
        case, spec, fb, _GRID_N, 0.25 / _N_INT, steps_per_interval=1,
        n_cg=25, margin=_GRID_N // 4)["sort/ap"]


def test_no_spec_traces_no_random_ops():
    """FeedbackParams.faults=None must keep the traced program free of
    PRNG ops (the zero-cost pin: the fault-free path is the pre-faults
    program, not a disabled-fault program)."""
    spec = dram_on_logic(1, PAPER_STACK)
    case = _sort_ap_case(spec)
    _, leaves = case[0]
    dyn, l0, r0, lm, F, cap3 = leaves
    kw = dict(die_n=_GRID_N, n_die=spec.n_die_layers,
              steps_per_interval=1, n_cg=5, margin=_GRID_N // 4)
    clean = str(jax.make_jaxpr(
        lambda *a: feedback.closed_loop_replay(
            *a, 0.02, fb=feedback.FeedbackParams(), **kw))(
        dyn, l0, r0, lm, F, cap3))
    assert "random" not in clean
    faulted = str(jax.make_jaxpr(
        lambda *a: feedback.closed_loop_replay(
            *a, 0.02,
            fb=feedback.FeedbackParams(
                faults=SensorFaultSpec(noise_C=0.5)), **kw))(
        dyn, l0, r0, lm, F, cap3))
    assert "random" in faulted


def test_faulted_replay_is_deterministic():
    spec = dram_on_logic(2, PAPER_STACK)
    case = _sort_ap_case(spec)
    fb = feedback.FeedbackParams(
        policy=PerDiePolicy(),
        faults=SensorFaultSpec(seed=5, noise_C=1.0, p_dropout=0.1))
    a, b = _replay(case, spec, fb), _replay(case, spec, fb)
    np.testing.assert_array_equal(a.peak_C, b.peak_C)
    np.testing.assert_array_equal(a.throttle, b.throttle)


def test_stuck_sensor_rescue():
    """THE acceptance scenario: a stuck-at-ambient primary sensor blinds
    the naive per-die controller (DRAM blows the 85 C ceiling) while the
    guarded wrapper's median still sees the true temperature and holds
    the fault-free trajectory."""
    spec = dram_on_logic(2, PAPER_STACK)
    case = _sort_ap_case(spec)
    stuck = SensorFaultSpec(seed=0, n_sensors=3, n_stuck=1)
    naive = _replay(case, spec, feedback.FeedbackParams(
        policy=PerDiePolicy(), faults=stuck))
    guarded = _replay(case, spec, feedback.FeedbackParams(
        policy=GuardedPolicy(inner=PerDiePolicy()), faults=stuck))
    clean = _replay(case, spec, feedback.FeedbackParams(
        policy=PerDiePolicy()))
    assert clean.dram_time_above_limit_s == 0.0
    assert naive.dram_time_above_limit_s > 0.0          # blind -> blows it
    assert float(naive.throttle.min()) == 1.0           # never throttled
    assert guarded.dram_time_above_limit_s == 0.0       # rescued
    assert float(guarded.dram_peak_C.max()) \
        == pytest.approx(float(clean.dram_peak_C.max()), abs=0.5)


# ------------------------------------------------------- solver fallback

def test_fallback_chain_shapes():
    assert thermal.fallback_chain("mg") == (
        ("mg", 1.0), ("mgcg", 1.0), ("pcg", 1.0), ("pcg", 0.1))
    assert thermal.fallback_chain("pcg") == (("pcg", 1.0), ("pcg", 0.1))
    with pytest.raises(ValueError, match="unknown solver"):
        thermal.fallback_chain("sor")


def test_poison_solver_scoping():
    assert not solver_poisoned("mg")
    with poison_solver("mg", "mgcg"):
        assert solver_poisoned("mg") and solver_poisoned("mgcg")
        with poison_solver("mg"):       # re-entrant: no double-remove
            assert solver_poisoned("mg")
        assert solver_poisoned("mg")
    assert not solver_poisoned("mg") and not solver_poisoned("mgcg")


def _hot_plate():
    g = thermal.Grid(die_w=3e-3, ny=16, nx=16, margin=4)
    p = np.zeros((g.n_die_layers, 16, 16), np.float32)
    p[0, 4:12, 4:12] = 0.05
    return p, g


def test_fallback_recovers_poisoned_solve_with_counters():
    p, g = _hot_plate()
    dT_ref, ref = thermal.steady_state_stats(p, g, solver="mg")
    assert ref["attempts"] == 1 and ref["solved_by"] == "mg"
    with obs.scoped():
        with poison_solver("mg"):
            dT, stats = thermal.steady_state_stats(p, g, solver="mg")
        snap = obs.snapshot()["counters"]
    assert stats["solved_by"] == "mgcg" and stats["attempts"] == 2
    assert stats["solver"] == "mg"               # the REQUESTED solver
    assert stats["rel_residual"] <= thermal.HEALTH_RTOL
    np.testing.assert_allclose(dT, dT_ref, atol=1e-3)
    assert snap["thermal/fallback/engaged"] == 1
    assert snap["thermal/fallback/retries"] == 1
    assert snap["thermal/fallback/recovered"] == 1
    assert snap["thermal/fallback/unhealthy[mg]"] == 1


def test_fallback_exhaustion_is_loud_not_silent():
    p, g = _hot_plate()
    with obs.scoped():
        with poison_solver("mg", "mgcg", "pcg"):
            dT, stats = thermal.steady_state_stats(p, g, solver="mg")
        snap = obs.snapshot()["counters"]
    assert stats["attempts"] == len(thermal.fallback_chain("mg"))
    assert not np.isfinite(np.asarray(dT)).all()  # NaN result, flagged...
    assert not np.isfinite(stats["rel_residual"])
    assert snap["thermal/fallback/exhausted"] == 1


def test_steady_state_rejects_nonfinite_power():
    p, g = _hot_plate()
    p[0, 0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        thermal.steady_state(p, g)


def test_check_finite_power_names_offender():
    with pytest.raises(ValueError, match="dyn_frames.*2 non-finite"):
        feedback.check_finite_power(
            "unit", dyn_frames=np.array([np.nan, np.inf, 1.0]),
            leak0=np.ones(3))
    feedback.check_finite_power("unit", ok=np.ones(3))   # no raise


# ------------------------------------------------- sweep failure isolation

_SWEEP = dict(workloads=("hist",), sizes=(4096,), n_dram=(1,),
              fb_modes=("open", "nodtm"), grid_n=8, n_intervals=4,
              steps_per_interval=1, n_cg=15)


def test_sweep_isolates_failed_group(monkeypatch, tmp_path):
    spec = SweepSpec(**_SWEEP)
    real = engine._run_group

    def sabotaged(spec, points, n_dram, fb_mode, policy, params,
                  n_shards=None):
        if fb_mode == "open":
            raise ValueError("injected group failure")
        return real(spec, points, n_dram, fb_mode, policy, params,
                    n_shards)

    monkeypatch.setattr(engine, "_run_group", sabotaged)
    with obs.scoped():
        res = run_sweep(spec, cache_dir=str(tmp_path), use_cache=True)
        snap = obs.snapshot()["counters"]
    assert snap["sweep/groups_failed"] == 1
    by_mode = {r.point.fb_mode: r for r in res.records}
    assert by_mode["open"].failed and not by_mode["open"].verdict_ok
    assert not by_mode["nodtm"].failed           # isolation: others live
    assert res.n_failed == 2                     # 2 machines x 1 point
    table = res.table()
    assert table.count("FAILED") == 2
    # a failed sweep is never persisted: a rerun must not be served the
    # NaN placeholders from disk
    from repro.sweep import cache
    assert cache.load(spec, str(tmp_path)) is None


def test_sweep_failed_records_never_read_ok():
    rec = engine._failed_group(
        SweepSpec(**_SWEEP), list(SweepSpec(**_SWEEP).points())[:1], 1,
        "open", "ramp", PAPER_STACK, "unit reason")
    for r in rec.values():
        assert r.failed and not r.verdict_ok
        assert not np.isfinite(r.report.peak_C).any()


# -------------------------------------------- device-count invariance (slow)

_SUBPROCESS = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.faults import SensorFaultSpec
from repro.policy import PerDiePolicy
from repro.core import cosim
from repro.stack import feedback
from repro.stack.spec import PAPER_STACK, dram_on_logic

spec = dram_on_logic(2, PAPER_STACK)
dp = cosim.comparable_design_point("sort", 2 ** 20)
trace = cosim.ap_workload_trace("sort", 8, cosim.trace_elems(2 ** 20))
case = [("sort/ap", feedback.assemble_case(
    dp, "sort", "ap", spec, PAPER_STACK, 8, trace, 2))]
fb = feedback.FeedbackParams(
    policy=PerDiePolicy(),
    faults=SensorFaultSpec(seed=3, n_sensors=3, noise_C=0.8,
                           n_stuck=1, p_dropout=0.1))
runs = {n: feedback.replay_cases(case, spec, fb, 8, 0.02,
                                steps_per_interval=1, n_cg=15, margin=2,
                                n_shards=n)["sort/ap"]
        for n in (None, 1, 3, 4)}
# device-count invariance: every sharded run is bitwise the 1-shard run
ref = runs[1]
for n in (3, 4):
    for name in ("peak_C", "min_C", "residual_C", "throttle"):
        np.testing.assert_array_equal(
            getattr(runs[n], name), getattr(ref, name),
            err_msg=f"n_shards={n} field={name}")
# and the seeded fault realization (the throttle decisions it drives) is
# invariant even against the UNSHARDED vmap program, whose solver
# arithmetic may round differently under a different XLA fusion
np.testing.assert_array_equal(runs[None].throttle, ref.throttle)
print("FAULT-SHARD-INVARIANCE-OK")
"""


@pytest.mark.slow
def test_faulted_replay_is_device_count_invariant():
    """Seeded faults ride the scan carry, so sharding the case batch
    over 1/3/4 forced host devices must reproduce the single-device
    fault realization bit-for-bit (the test_shard_sweep.py invariance,
    now under an active SensorFaultSpec)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                          capture_output=True, text=True, env=env,
                          cwd=root, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "FAULT-SHARD-INVARIANCE-OK" in proc.stdout


# ---------------------------------------------------------- Policy protocol

def test_all_policies_accept_n_layers():
    """Every registered policy must tolerate the widened init_state
    protocol (n_layers positional) — scalar-state controllers ignore
    it, per-layer ones shape their state with it."""
    for name, factory in POLICIES.items():
        factory().init_state(3)                     # no raise is the pin
    assert Policy().init_state() == ()
    assert Policy().init_state(5) == ()
