"""Minimal deterministic stand-in for `hypothesis` (activated by conftest.py
ONLY when the real package is not installed — e.g. hermetic containers where
pip is unavailable).  CI installs real hypothesis via pyproject's `test`
extra and never sees this module.

Scope: exactly the API surface this repo's property tests use —
``@given`` with positional/keyword strategies, ``@settings(max_examples,
deadline, ...)``, profile registration, and the strategies in
``strategies.py``.  Examples are drawn from a PRNG seeded by the test's
qualified name, so runs are reproducible (the fallback is always
"derandomized"); there is no shrinking or example database.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies`)

__version__ = "0.0-fallback"
__all__ = ["given", "settings", "assume", "note", "example", "HealthCheck",
           "strategies"]

_DEFAULT_MAX_EXAMPLES = 20


class HealthCheck:
    """Accepted-and-ignored placeholders for `suppress_health_check=`."""
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    all = classmethod(lambda cls: [cls.too_slow, cls.data_too_large,
                                   cls.filter_too_much])


class _Unsatisfied(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


def note(_msg) -> None:
    pass


def example(*_args, **_kwargs):
    """@example is metadata for shrinking reports; a no-op pass-through."""
    return lambda fn: fn


class settings:
    """Both the `@settings(...)` decorator and the profile registry."""

    _profiles: dict[str, dict] = {"default": {}}
    _active: dict = {}

    def __init__(self, parent=None, **kwargs):
        self.kwargs = dict(parent.kwargs) if isinstance(parent, settings) else {}
        self.kwargs.update(kwargs)

    def __call__(self, fn):
        # applied above @given: annotate the wrapper; below: the raw test.
        fn._fallback_settings = self
        return fn

    @property
    def max_examples(self) -> int:
        return self.kwargs.get(
            "max_examples",
            settings._active.get("max_examples", _DEFAULT_MAX_EXAMPLES))

    @classmethod
    def register_profile(cls, name: str, parent=None, **kwargs) -> None:
        merged = dict(parent.kwargs) if isinstance(parent, settings) else {}
        merged.update(kwargs)
        cls._profiles[name] = merged

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._active = dict(cls._profiles.get(name, {}))

    @classmethod
    def get_profile(cls, name: str) -> "settings":
        return settings(**cls._profiles.get(name, {}))


def given(*pos_strategies, **kw_strategies):
    """Run the test for N deterministic examples drawn from the strategies.

    Positional strategies bind to the test's parameters in declaration
    order (skipping names claimed by keyword strategies); any remaining
    parameters stay visible to pytest as fixtures.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        pos_names = [n for n in names if n not in kw_strategies]
        pos_names = pos_names[: len(pos_strategies)]
        if len(pos_names) < len(pos_strategies):
            raise TypeError(f"too many positional strategies for {fn.__name__}")
        supplied = set(pos_names) | set(kw_strategies)
        missing = supplied - set(names)
        if missing:
            raise TypeError(f"{fn.__name__} has no parameters {missing}")
        binds = list(zip(pos_names, pos_strategies)) + list(kw_strategies.items())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None) \
                or getattr(fn, "_fallback_settings", None)
            n = cfg.max_examples if cfg is not None else settings().max_examples
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(max(4 * n, n + 16)):
                if ran >= n:
                    break
                drawn = {name: s.draw(rng) for name, s in binds}
                try:
                    fn(*args, **{**kwargs, **drawn})
                except _Unsatisfied:
                    continue  # assume() rejected this example
                ran += 1

        # hide strategy-supplied parameters from pytest's fixture resolution
        rest = [p for n, p in sig.parameters.items() if n not in supplied]
        wrapper.__signature__ = sig.replace(parameters=rest)
        del wrapper.__wrapped__
        wrapper.hypothesis = type("Meta", (), {"inner_test": fn})()
        return wrapper

    return decorate
