"""Strategies for the fallback `hypothesis` shim (see __init__.py).

Each strategy is just a draw(rng) callable plus the combinators the repo's
tests use.  Draws are uniform — no bias toward boundary values — which is
weaker than real hypothesis but sufficient for deterministic CI-less runs.
"""
from __future__ import annotations

import numpy as np


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self.draw(rng)))

    def filter(self, pred) -> "SearchStrategy":
        def draw(rng):
            for _ in range(1000):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter() rejected 1000 consecutive draws")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    lo, hi = int(min_value), int(max_value)
    # rng.integers caps at int64; draw wide ranges via python-int arithmetic
    span = hi - lo
    if span < (1 << 62):
        return SearchStrategy(lambda rng: lo + int(rng.integers(0, span + 1)))
    return SearchStrategy(
        lambda rng: lo + (int(rng.integers(0, 1 << 31)) << 31
                          | int(rng.integers(0, 1 << 31))) % (span + 1))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def floats(min_value: float = 0.0, max_value: float = 1.0,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64) -> SearchStrategy:
    lo, hi = float(min_value), float(max_value)
    return SearchStrategy(lambda rng: float(rng.uniform(lo, hi)))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])
    return SearchStrategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))].draw(rng))
