"""Test-session config: hypothesis availability + profiles, marker wiring.

Two concerns live here:

1. **Hypothesis bootstrap.**  Property tests import `hypothesis` directly.
   When the real package is installed (CI: `pip install -e '.[test]'`) it is
   used untouched.  In hermetic environments without it, `tests/_fallback`
   provides a small deterministic shim so the suite still collects and runs
   (see its docstring for scope).

2. **Deterministic CI profile.**  `HYPOTHESIS_PROFILE=ci` (set by the CI
   workflow) fixes derandomization and disables deadlines so property tests
   cannot flake under loaded shared runners.
"""
import os
import sys

_FALLBACK = os.path.join(os.path.dirname(__file__), "_fallback")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, _FALLBACK)
    import hypothesis  # noqa: F401

from hypothesis import settings  # noqa: E402

settings.register_profile("dev", deadline=None)
settings.register_profile("ci", deadline=None, derandomize=True,
                          max_examples=25, print_blob=True)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))


def pytest_collection_modifyitems(config, items):
    """Auto-mark kernel-exercising tests `pallas` so CI lanes can select."""
    import pytest

    pallas_mark = pytest.mark.pallas
    for item in items:
        mod = item.module.__name__ if item.module else ""
        if mod.startswith("test_kernel_"):
            item.add_marker(pallas_mark)
