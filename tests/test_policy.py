"""The DVFS/DTM policy engine (repro/policy/ + the rewired closed loop).

The load-bearing pin: ``policy="ramp"`` (the default FeedbackParams)
must reproduce the PRE-policy-engine sampled-ramp trajectories
BIT-IDENTICALLY — ``_legacy_closed_loop`` below is that historical scan
body copied verbatim, and every output of the rewired replay is
asserted bitwise equal against it, including a case where the DTM is
actively tripping.  Plus: the ramp_C == 0 step-trip guard, the
FeedbackParams validation contract, controller edge cases (trip at inf,
floor = 1, hysteresis hold band), the DVFS table, and the per-die
rescue that feeds the Pareto bench's verdict flip.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.policy as P
from repro.core import cosim, thermal
from repro.core import models as M
from repro.core.floorplan import MM, APFloorplan, SIMDFloorplan
from repro.policy import PolicyContext
from repro.stack import dram, feedback
from repro.stack.spec import dram_on_logic

GRID_N, MARGIN, N_INT, DT = 8, 2, 10, 0.25 / 10


# ---------------------------------------------------------------------------
# the historical closed loop, copied verbatim from the pre-policy engine
# (git 15aaa8f stack/feedback.py) — the regression oracle
# ---------------------------------------------------------------------------

def _legacy_closed_loop(dyn_frames, leak0, refresh0, logic_mask, F, cap3,
                        interval_dt, theta, t_amb, *,
                        fb: feedback.FeedbackParams,
                        steps_per_interval: int, n_cg: int, n_die: int,
                        margin: int, die_n: int, dt_scale=None):
    A = lambda v: thermal.apply_operator_fields(v, F)
    if dt_scale is None:
        dt = interval_dt / steps_per_interval
        solve = thermal.implicit_lhs_solver(A, F, cap3, dt, theta,
                                            solver="pcg", n_cg=n_cg)
        solve_for = lambda _scale: solve
    else:
        diagA = thermal._diag_fields(F)

        def solve_for(scale):
            dt = interval_dt * scale / steps_per_interval
            lhs = lambda v: cap3 / dt * v + theta * A(v)
            Minv = 1.0 / (cap3 / dt + theta * diagA)
            return lambda rhs: thermal.pcg_fixed(lhs, Minv, rhs, n_cg)
    lm3 = logic_mask[:, None, None]

    def interval(dTc, xs):
        P_dyn, scale = xs
        solve = solve_for(scale)
        t_logic = jnp.max(jnp.where(lm3 > 0, dTc + t_amb, -jnp.inf))
        f = jnp.clip(1.0 - (t_logic - fb.dtm_trip_C) / fb.dtm_ramp_C,
                     fb.dtm_floor, 1.0)
        P_base = f * P_dyn

        def picard(_, st):
            dTk, _res, _aux = st
            T = dTk + t_amb
            p_leak = leak0 * jnp.exp(fb.leak_beta * (T - fb.t_ref_C))
            p_ref = refresh0 * dram.refresh_multiplier(T) \
                if fb.refresh_feedback else refresh0
            P = P_base + p_leak + p_ref

            def one(d, _):
                rhs = P - A(d)
                return d + solve(rhs), None

            dTn, _ = jax.lax.scan(one, dTc, None,
                                  length=steps_per_interval)
            return dTn, jnp.max(jnp.abs(dTn - dTk)), \
                (jnp.sum(p_ref), jnp.sum(p_leak))

        init = (dTc, jnp.float32(jnp.inf),
                (jnp.float32(0.0), jnp.float32(0.0)))
        dTn, res, (ref_W, leak_W) = jax.lax.fori_loop(
            0, fb.n_picard, picard, init)
        die = dTn[:n_die, margin:margin + die_n, margin:margin + die_n]
        return dTn, (jnp.max(die, axis=(1, 2)), jnp.min(die, axis=(1, 2)),
                     res, f, ref_W, leak_W)

    dT0 = jnp.zeros_like(dyn_frames[0])
    scales = jnp.ones(dyn_frames.shape[0], dyn_frames.dtype) \
        if dt_scale is None else jnp.asarray(dt_scale, dyn_frames.dtype)
    dT_end, (mx, mn, res, f, ref_W, leak_W) = \
        jax.lax.scan(interval, dT0, (dyn_frames, scales))
    return dT_end + t_amb, mx + t_amb, mn + t_amb, res, f, ref_W, leak_W


# ------------------------------------------------------------ case builders

def _case(machine: str, n_dram: int = 2):
    """Replay inputs for one (machine, stack) case; "simd" runs hot
    enough that the default DTM ramp actively trips."""
    spec = dram_on_logic(n_dram)
    w = "dmm"
    dp = cosim.comparable_design_point(w)
    if machine == "ap":
        fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
        pmap = fp.power_map(GRID_N, dp.ap_power_W)
        leak_W = fp.leakage_W()
        trace = cosim.ap_workload_trace(w, N_INT)
    else:
        fp = SIMDFloorplan(die_w_mm=math.sqrt(dp.simd_area_mm2))
        pmap = fp.power_map(GRID_N, dp)
        leak_W = fp.leakage_W(dp)
        trace = cosim.simd_phase_trace(M.WORKLOADS[w], dp, N_INT)
    grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=GRID_N, nx=GRID_N,
                        spec=spec, margin=MARGIN)
    dfp = dram.DRAMFloorplan(die_w_mm=fp.die_w_mm)
    traffic = M.mem_traffic_bytes_per_s(w, dp.ap_n_pus)
    dyn, l0, r0, lm = feedback.stack_power_inputs(
        spec, grid, trace, pmap, leak_W, dfp, traffic)
    return spec, grid, (jnp.asarray(dyn), jnp.asarray(l0),
                        jnp.asarray(r0), jnp.asarray(lm))


def _replay(spec, grid, frames, fb, dt_scale=None, **kw):
    return feedback.closed_loop_replay(
        *frames, grid.fields(), grid.capacity_field(), DT, fb=fb,
        die_n=GRID_N, n_die=spec.n_die_layers, steps_per_interval=1,
        n_cg=20, margin=MARGIN, dt_scale=dt_scale, **kw)


# ---------------------------------------------------------------------------
# THE pin: default policy == historical ramp, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("machine,fb,scaled", [
    ("simd", feedback.FeedbackParams(), False),       # DTM actively trips
    ("ap", feedback.FeedbackParams.disabled(), False),
    ("simd", feedback.FeedbackParams(), True),        # variable-dt path
], ids=["tripping", "disabled", "dt_scale"])
def test_ramp_policy_bit_identical_to_legacy(machine, fb, scaled):
    spec, grid, frames = _case(machine)
    dt_scale = jnp.ones(N_INT) if scaled else None
    new = _replay(spec, grid, frames, fb, dt_scale=dt_scale)
    old = _legacy_closed_loop(
        *frames, grid.fields(), grid.capacity_field(), DT, 1.0,
        feedback.AMBIENT_C, fb=fb, steps_per_interval=1, n_cg=20,
        n_die=spec.n_die_layers, margin=MARGIN, die_n=GRID_N,
        dt_scale=dt_scale)
    assert len(new) == 8 and len(old) == 7
    if machine == "simd" and not scaled:        # the pin must have teeth
        assert float(np.asarray(new[4]).min()) < 1.0
    for x, y in zip(old, new):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_explicit_ramp_policy_matches_default():
    """policy=RampPolicy(dtm fields) is the same controller as
    policy=None — resolved_policy() is a pure re-labeling."""
    spec, grid, frames = _case("simd")
    a = _replay(spec, grid, frames, feedback.FeedbackParams())
    b = _replay(spec, grid, frames, feedback.FeedbackParams(
        policy=P.RampPolicy(trip_C=95.0, ramp_C=10.0, floor=0.25)))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# satellite: ramp_C == 0 is a step trip, not a NaN factory
# ---------------------------------------------------------------------------

def test_step_trip_zero_ramp_is_finite_bang_bang():
    spec, grid, frames = _case("simd")
    fb = feedback.FeedbackParams(dtm_ramp_C=0.0, dtm_trip_C=60.0)
    out = _replay(spec, grid, frames, fb)
    thr = np.asarray(out[4])
    assert np.isfinite(thr).all()
    # bang-bang: every decision is the floor or full duty, and the hot
    # SIMD stack must actually trip
    assert set(np.unique(thr)) <= {np.float32(0.25), np.float32(1.0)}
    assert (thr == 0.25).any()
    for x in out[:4]:
        assert np.isfinite(np.asarray(x)).all()


def test_ramp_duty_step_limit():
    """ramp_duty at ramp_C=0: duty is 1 AT the trip, floor above it —
    the limit of the linear ramp, where the old expression went 0/0."""
    duty = P.ramp_duty(jnp.float32(95.0), 95.0, 0.0, 0.25)
    assert float(duty) == 1.0
    assert float(P.ramp_duty(jnp.float32(95.1), 95.0, 0.0, 0.25)) == 0.25


# ---------------------------------------------------------------------------
# satellite: FeedbackParams / policy parameter validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [dict(dtm_floor=0.0), dict(dtm_floor=-0.1),
                                dict(dtm_floor=1.5)])
def test_feedback_params_rejects_bad_floor(kw):
    with pytest.raises(ValueError, match="dtm_floor"):
        feedback.FeedbackParams(**kw)


@pytest.mark.parametrize("trip", [math.nan, -math.inf])
def test_feedback_params_rejects_non_real_trip(trip):
    with pytest.raises(ValueError, match="dtm_trip_C"):
        feedback.FeedbackParams(dtm_trip_C=trip)


def test_feedback_params_accepts_inf_trip_and_rejects_negative_ramp():
    feedback.FeedbackParams(dtm_trip_C=math.inf)    # legal: never trips
    with pytest.raises(ValueError, match="dtm_ramp_C"):
        feedback.FeedbackParams(dtm_ramp_C=-1.0)


def test_policy_constructors_validate():
    with pytest.raises(ValueError, match="floor"):
        P.RampPolicy(floor=0.0)
    with pytest.raises(ValueError, match="trip_C"):
        P.HysteresisPolicy(trip_C=math.nan)
    with pytest.raises(ValueError, match="band_C"):
        P.DVFSPolicy(band_C=-1.0)
    with pytest.raises(ValueError, match="n_cands"):
        P.PredictivePolicy(n_cands=1)
    with pytest.raises(ValueError, match="unknown policy"):
        P.get("nope")


# ---------------------------------------------------------------------------
# satellite: controller edge cases
# ---------------------------------------------------------------------------

def test_trip_at_inf_never_throttles():
    spec, grid, frames = _case("simd")
    out = _replay(spec, grid, frames,
                  feedback.FeedbackParams(dtm_trip_C=math.inf))
    assert (np.asarray(out[4]) == 1.0).all()


def test_floor_one_is_a_noop_throttle():
    """floor=1.0 clamps the duty to exactly 1 — bitwise the trip-at-inf
    replay (the throttle multiplies by literal 1.0 either way)."""
    spec, grid, frames = _case("simd")
    a = _replay(spec, grid, frames,
                feedback.FeedbackParams(dtm_floor=1.0, dtm_trip_C=50.0))
    b = _replay(spec, grid, frames,
                feedback.FeedbackParams(dtm_trip_C=math.inf))
    assert (np.asarray(a[4]) == 1.0).all()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _hys_ctx(t):
    mask = jnp.array([1.0, 0.0])
    return PolicyContext(layer_T=jnp.array([t, 0.0]), logic_mask=mask,
                         dram_mask=1.0 - mask, predict_hot=None)


def test_hysteresis_holds_inside_band():
    """Within (trip-band, trip] the latch HOLDS: a temperature dwelling
    inside the band cannot flip the duty in either direction."""
    pol = P.HysteresisPolicy(trip_C=90.0, band_C=5.0, floor=0.25)
    s = pol.init_state()
    s, f, _ = pol.act(s, _hys_ctx(80.0))
    assert float(f) == 1.0
    s, f, _ = pol.act(s, _hys_ctx(91.0))        # trips
    assert float(f) == 0.25
    for t in (88.0, 86.0, 89.9, 85.1):          # dwell inside the band
        s, f, _ = pol.act(s, _hys_ctx(t))
        assert float(f) == 0.25                 # held, no oscillation
    s, f, _ = pol.act(s, _hys_ctx(84.9))        # below trip - band
    assert float(f) == 1.0
    for t in (86.0, 89.0):                      # band from below: held
        s, f, _ = pol.act(s, _hys_ctx(t))
        assert float(f) == 1.0


def test_pid_regulates_toward_target():
    """Sustained over-temperature drives the duty down; cooling releases
    it (integral anti-windup keeps it within [floor, 1])."""
    pol = P.PIDPolicy(target_C=90.0, floor=0.25)
    s = pol.init_state()
    duties = []
    for _ in range(10):
        s, f, _ = pol.act(s, _hys_ctx(100.0))
        duties.append(float(f))
    assert duties[-1] <= duties[0] and duties[-1] == 0.25
    for _ in range(60):
        s, f, _ = pol.act(s, _hys_ctx(40.0))
    assert float(f) == 1.0


# ---------------------------------------------------------------------------
# DVFS tables
# ---------------------------------------------------------------------------

def test_dvfs_table_structure():
    for node in P.nodes():
        t = P.build_dvfs_table(node)
        f = [op.f_mhz for op in t.points]
        assert f == sorted(f) and len(set(f)) == len(f)
        ps, fs = t.power_scales(), t.perf_scales()
        assert ps[-1] == 1.0 and fs[-1] == 1.0
        # voltage scaling: power falls FASTER than frequency at every
        # lower operating point — the lever the Pareto bench exploits
        assert all(p < s for p, s in zip(ps[:-1], fs[:-1]))


def test_dvfs_table_validation():
    op = P.OperatingPoint
    with pytest.raises(ValueError, match=">= 2 operating points"):
        P.DVFSTable("x", (op(1000, 1.0),))
    with pytest.raises(ValueError, match="sorted"):
        P.DVFSTable("x", (op(2000, 1.0), op(1000, 0.8)))
    with pytest.raises(ValueError, match="unknown technology node"):
        P.build_dvfs_table("7nm")


def test_dvfs_residency_attribution():
    pol = P.DVFSPolicy()
    fs = pol.table.perf_scales()
    duty = np.array([fs[-1], fs[-1], fs[0], fs[1] + 1e-4])
    res = pol.residency(duty)
    labels = pol.table.labels()
    assert res[labels[-1]] == 2 and res[labels[0]] == 1 \
        and res[labels[1]] == 1
    assert P.RampPolicy().residency(duty) is None


def test_dvfs_policy_steps_one_op_per_interval():
    pol = P.DVFSPolicy(trip_C=85.0, band_C=4.0)
    s = pol.init_state()
    top = pol.table.n_ops - 1
    s, fp, ff = pol.act(s, _hys_ctx(100.0))     # hot: step down once
    assert int(s) == top - 1
    assert float(fp) < float(ff) < 1.0          # f·V² < f at a lower OP
    s, _, _ = pol.act(s, _hys_ctx(83.0))        # in band: hold
    assert int(s) == top - 1
    s, _, _ = pol.act(s, _hys_ctx(60.0))        # cool: step back up
    assert int(s) == top


# ---------------------------------------------------------------------------
# policies inside the replay: per-die rescue + predictive lookahead
# ---------------------------------------------------------------------------

def test_perdie_policy_cools_dram_below_ramp():
    """The per-die controller senses the DRAM dies directly (trip 83 °C)
    and drags logic down with them — the DRAM hot spot must come out
    cooler than under the logic-sensed default ramp."""
    spec, grid, frames = _case("simd")
    dram_l = list(spec.dram_layers)
    pk_ramp = np.asarray(_replay(
        spec, grid, frames, feedback.FeedbackParams())[1])[:, dram_l]
    pk_pd = np.asarray(_replay(
        spec, grid, frames,
        feedback.FeedbackParams(policy=P.PerDiePolicy()))[1])[:, dram_l]
    # compare where control has settled (the final interval): phase
    # spikes land identically under ANY sampled policy — one interval of
    # lag is irreducible — but the regulated level must come out cooler
    assert pk_pd[-1].max() < pk_ramp[-1].max() - 1.0


def test_predictive_policy_cuts_peak_overshoot():
    """Acting on the forecast instead of the measurement shaves the
    reactive ramp's overshoot on the hot stack."""
    spec, grid, frames = _case("simd")
    pk_ramp = np.asarray(_replay(spec, grid, frames,
                                 feedback.FeedbackParams())[1])
    out = _replay(spec, grid, frames, feedback.FeedbackParams(
        policy=P.PredictivePolicy(trip_C=95.0)))
    assert np.asarray(out[1]).max() < pk_ramp.max() - 5.0
    thr = np.asarray(out[4])
    assert (thr >= 0.25).all() and (thr <= 1.0).all()


def test_policy_state_threads_through_scan():
    """A stateful policy (hysteresis) runs jit-compiled end-to-end and
    latches: once tripped on the monotone heat-up it stays at the floor
    until a genuine release crossing."""
    spec, grid, frames = _case("simd")
    fb = feedback.FeedbackParams(policy=P.HysteresisPolicy(
        trip_C=70.0, band_C=5.0, floor=0.25))
    thr = np.asarray(_replay(spec, grid, frames, fb)[4])
    assert set(np.unique(thr)) <= {np.float32(0.25), np.float32(1.0)}
    assert (thr == 0.25).any()


def test_energy_accounting():
    """dyn_W: full duty dissipates the frame power exactly; throttling
    strictly reduces it; energy_per_work_J penalizes the slowdown."""
    spec, grid, frames = _case("simd")
    free = _replay(spec, grid, frames,
                   feedback.FeedbackParams(dtm_trip_C=math.inf))
    hot = _replay(spec, grid, frames, feedback.FeedbackParams())
    dyn_free = np.asarray(free[7])
    np.testing.assert_allclose(
        dyn_free, np.asarray(frames[0]).sum(axis=(1, 2, 3)), rtol=1e-5)
    assert np.asarray(hot[7]).sum() < dyn_free.sum()
    rep = feedback.StackReport(
        label="x", interval_s=DT, spec=spec,
        peak_C=np.asarray(hot[1]), min_C=np.asarray(hot[2]),
        residual_C=np.asarray(hot[3]), throttle=np.asarray(hot[4]),
        refresh_W=np.asarray(hot[5]), leak_W=np.asarray(hot[6]),
        base_refresh_W=1.0, dyn_W=np.asarray(hot[7]))
    assert rep.energy_per_work_J > rep.energy_J > 0.0


# ---------------------------------------------------------------------------
# pareto helpers (doctests cover the arithmetic; pin the API contract)
# ---------------------------------------------------------------------------

def test_pareto_front_mixed():
    pts = [(1.0, 95.0, 5.0),     # fast, hot
           (2.0, 80.0, 4.0),     # slow, cool, efficient
           (2.5, 96.0, 6.0),     # dominated by 0 AND 1? no: hotter+slower
           (1.0, 95.0, 5.0)]     # duplicate of 0 — kept
    assert P.pareto_front(pts) == (0, 1, 3)
    assert P.dominates((1, 1, 1), (2, 2, 2))
    with pytest.raises(ValueError, match="dimension"):
        P.dominates((1.0,), (1.0, 2.0))
