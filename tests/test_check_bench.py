"""The benchmark-JSON regression gate (tools/check_bench.py): passing
baselines pass, synthetic regressions fail the run (the CI acceptance
demonstration), and --update refreshes values without touching
tolerances."""
import importlib.util
import json
import os

import pytest

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")


@pytest.fixture(scope="module")
def check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(_TOOLS, "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write(tmp_path, name, payload):
    path = tmp_path / name
    path.write_text(json.dumps(payload))
    return str(path)


def _baseline(tmp_path, baseline):
    return _write(tmp_path, "baseline.json", baseline)


def _artifact(tmp_path, bench, metrics):
    return _write(tmp_path, f"BENCH_{bench}.json",
                  {"bench": bench, "schema": 1, "metrics": metrics})


BASELINE = {
    "thermal": {
        "peak_C": {"value": 50.0, "abs_tol": 1.0},
        "iters": {"value": 100, "rel_tol": 0.5},
        "speedup": {"min": 2.0},
        "maxdiff": {"max": 0.05},
        "n_cases": {"value": 4},
    }
}

GOOD = {"peak_C": 50.5, "iters": 120, "speedup": 30.0, "maxdiff": 1e-4,
        "n_cases": 4}


def test_passing_metrics_pass(check_bench, tmp_path):
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "thermal", GOOD)
    assert check_bench.main([a, "--baseline", b]) == 0


@pytest.mark.parametrize("bad", [
    {"peak_C": 52.0},          # outside abs_tol
    {"iters": 300},            # outside rel_tol
    {"speedup": 0.8},          # regressed below the floor
    {"maxdiff": 0.2},          # solver agreement broke
    {"n_cases": 3},            # exact-count mismatch
])
def test_synthetic_regression_fails(check_bench, tmp_path, bad):
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "thermal", dict(GOOD, **bad))
    assert check_bench.main([a, "--baseline", b]) == 1


@pytest.mark.parametrize("bad", [float("nan"), float("inf"),
                                 float("-inf"), "50.5", None, True])
def test_nonfinite_or_nonnumeric_metric_fails(check_bench, tmp_path, bad):
    """The NaN hole: every tolerance comparison is False on NaN
    (|nan - v| > tol, nan < min, nan > max), so without the explicit
    finiteness guard a diverged bench would PASS every band it
    regressed.  Non-numeric values (including bool) must fail too."""
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "thermal", dict(GOOD, peak_C=bad))
    assert check_bench.main([a, "--baseline", b]) == 1


def test_nonfinite_fails_every_rule_kind(check_bench, tmp_path):
    """NaN must fail min-only, max-only, and exact-value rules alike —
    not just the tolerance-band ones."""
    nan = float("nan")
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "thermal",
                  dict(GOOD, speedup=nan, maxdiff=nan, n_cases=nan))
    assert check_bench.main([a, "--baseline", b]) == 1


def test_check_metric_messages_name_the_value(check_bench):
    fails = check_bench.check_metric("x", {"min": 1.0}, float("nan"))
    assert fails and "non-finite" in fails[0]
    assert check_bench.check_metric("x", {"min": 1.0}, 2.0) == []


def test_missing_metric_fails(check_bench, tmp_path):
    b = _baseline(tmp_path, BASELINE)
    metrics = dict(GOOD)
    del metrics["speedup"]
    a = _artifact(tmp_path, "thermal", metrics)
    assert check_bench.main([a, "--baseline", b]) == 1


def test_missing_artifact_fails(check_bench, tmp_path):
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "other", GOOD)
    assert check_bench.main([a, "--baseline", b]) == 1


def test_update_refreshes_values_not_tolerances(check_bench, tmp_path):
    b = _baseline(tmp_path, BASELINE)
    a = _artifact(tmp_path, "thermal", GOOD)
    assert check_bench.main([a, "--baseline", b, "--update"]) == 0
    new = json.loads(open(b).read())
    assert new["thermal"]["peak_C"] == {"value": 50.5, "abs_tol": 1.0}
    assert new["thermal"]["iters"]["value"] == 120
    assert new["thermal"]["speedup"] == {"min": 2.0}   # no value key
    # and the refreshed baseline passes against the same artifact
    assert check_bench.main([a, "--baseline", b]) == 0


def test_telemetry_section_is_never_gated(check_bench, tmp_path):
    """A schema-2 artifact's ``telemetry`` sub-object is observability
    payload: values in it that would fail every rule must not be read by
    the gate, and telemetry keys never satisfy a gated metric."""
    b = _baseline(tmp_path, BASELINE)
    telemetry = {
        "counters": {"peak_C": 10_000, "speedup": 0},   # would fail if read
        "gauges": {"maxdiff": 99.0},
        "histograms": {"iters": {"count": 1, "p50": 1e9}},
    }
    a = _write(tmp_path, "BENCH_thermal.json",
               {"bench": "thermal", "schema": 2, "metrics": GOOD,
                "telemetry": telemetry})
    assert check_bench.main([a, "--baseline", b]) == 0

    # a gated metric present ONLY in telemetry is still a missing metric
    metrics = dict(GOOD)
    del metrics["peak_C"]
    a = _write(tmp_path, "BENCH_thermal.json",
               {"bench": "thermal", "schema": 2, "metrics": metrics,
                "telemetry": telemetry})
    assert check_bench.main([a, "--baseline", b]) == 1


def test_recorder_writes_schema2_with_telemetry(tmp_path, monkeypatch):
    """The Recorder attaches the obs snapshot as ``telemetry`` and writes
    the Perfetto span trace alongside, without polluting ``metrics``."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(_TOOLS), "benchmarks"))
    try:
        from _record import Recorder
    finally:
        sys.path.pop(0)
    from repro import obs

    monkeypatch.setenv("BENCH_JSON_DIR", str(tmp_path))
    rec = Recorder("unit")
    obs.count("unit/events", 3)
    with obs.span("unit/section"):
        pass
    rec.add(answer=42)
    rec.finish()
    obs.disable()

    payload = json.loads((tmp_path / "BENCH_unit.json").read_text())
    assert payload["schema"] == 2
    assert payload["metrics"]["answer"] == 42.0
    assert payload["telemetry"]["counters"]["unit/events"] == 3
    assert "unit/events" not in payload["metrics"]
    trace = json.loads((tmp_path / "TRACE_unit.json").read_text())
    assert any(e["name"] == "unit/section"
               for e in trace["traceEvents"])


def test_repo_baseline_is_wellformed(check_bench):
    """The committed baseline parses and only uses known rule keys."""
    path = os.path.join(os.path.dirname(_TOOLS), "benchmarks",
                        "baseline.json")
    baseline = json.loads(open(path).read())
    assert set(baseline) >= {"thermal", "stack", "sweep"}
    for bench, metrics in baseline.items():
        for name, expect in metrics.items():
            assert set(expect) <= {"value", "abs_tol", "rel_tol", "min",
                                   "max"}, (bench, name)
    # the multigrid acceptance evidence is gated
    assert "steady_mg_speedup_256" in baseline["thermal"]
