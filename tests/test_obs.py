"""The observability layer: registry math, spans, disabled-mode no-op,
Chrome trace-event export."""
import json

import numpy as np
import pytest

from repro import obs
from repro.obs.registry import Histogram, Registry, percentile
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test sees a fresh, disabled obs state and restores none of
    its own residue on the module singletons."""
    prev = obs.is_enabled()
    obs.disable()
    obs.reset()
    yield
    obs.reset()
    (obs.enable if prev else obs.disable)()


# ------------------------------------------------------------- disabled

def test_disabled_mode_is_strict_noop():
    obs.count("x")
    obs.gauge("g", 3.0)
    obs.observe("h", 1.0)
    obs.observe_many("h", [2.0, 3.0])
    with obs.span("s", k=1):
        pass
    snap = obs.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert obs.trace_events()["traceEvents"] == []
    assert obs.value("x") == 0


def test_disabled_span_is_shared_null_singleton():
    a, b = obs.span("a"), obs.span("b", attr=1)
    assert a is b                   # no per-call allocation when off


def test_scoped_restores_prior_state():
    assert not obs.is_enabled()
    with obs.scoped():
        assert obs.is_enabled()
        with obs.scoped(on=False):
            assert not obs.is_enabled()
        assert obs.is_enabled()
    assert not obs.is_enabled()


# -------------------------------------------------------------- metrics

def test_counter_gauge_roundtrip():
    with obs.scoped():
        obs.count("c")
        obs.count("c", 4)
        obs.gauge("g", 2.0)
        obs.gauge("g", 7.5)         # last write wins
    snap = obs.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 7.5
    assert obs.value("c") == 5      # readable even while disabled


def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    vals = rng.exponential(size=501)
    h = Histogram()
    h.extend(vals)
    s = h.summary()
    assert s["count"] == 501
    np.testing.assert_allclose(s["p50"], np.percentile(vals, 50))
    np.testing.assert_allclose(s["p95"], np.percentile(vals, 95))
    np.testing.assert_allclose(s["p99"], np.percentile(vals, 99))
    np.testing.assert_allclose(s["mean"], vals.mean())
    assert s["min"] == vals.min() and s["max"] == vals.max()


def test_percentile_edge_cases():
    assert np.isnan(percentile([], 50))
    assert percentile([4.0], 99) == 4.0
    assert percentile([1.0, 2.0], 50) == 1.5


def test_empty_histogram_summary():
    assert Histogram().summary() == {"count": 0}


def test_registry_snapshot_is_json_serializable_and_sorted():
    r = Registry()
    r.counter("b").inc()
    r.counter("a").inc(2)
    r.histogram("h").observe(1.0)
    snap = json.loads(json.dumps(r.snapshot()))
    assert list(snap["counters"]) == ["a", "b"]
    assert snap["histograms"]["h"]["count"] == 1


# ---------------------------------------------------------------- spans

def test_nested_span_parent_child_ordering():
    tr = Tracer()
    with tr.span("outer", case="x"):
        with tr.span("inner"):
            pass
    by_name = {e["name"]: e for e in tr.trace_object()["traceEvents"]}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["args"]["depth"] == 0 and inner["args"]["depth"] == 1
    # child lies within the parent's [ts, ts+dur] window (same tid row)
    assert inner["tid"] == outer["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"]["case"] == "x"


def test_span_durations_feed_histograms():
    with obs.scoped():
        with obs.span("work"):
            pass
        with obs.span("work"):
            pass
    assert obs.snapshot()["histograms"]["span/work"]["count"] == 2


def test_span_depth_restored_after_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError
    with tr.span("after"):
        pass
    by_name = {e["name"]: e for e in tr.trace_object()["traceEvents"]}
    assert by_name["after"]["args"]["depth"] == 0


def test_chrome_trace_event_json_validity(tmp_path):
    """The exported file is valid Chrome trace-event JSON: the object
    form with a traceEvents list of complete ('X') events carrying the
    required keys with the right types (ts/dur in microseconds)."""
    with obs.scoped():
        with obs.span("phase", n=3, label="a b"):
            with obs.span("leaf"):
                pass
    path = tmp_path / "trace.json"
    obs.write_trace(str(path))
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert ev["ph"] == "X" and ev["cat"] == "obs"
        assert isinstance(ev["name"], str)
        assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
        assert ev["ts"] >= 0 and ev["dur"] >= 0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["args"], dict)
    # non-JSON-native span args were coerced to strings at record time
    phase = next(e for e in events if e["name"] == "phase")
    assert phase["args"]["n"] == 3 and phase["args"]["label"] == "a b"


def test_reset_restarts_trace_clock():
    with obs.scoped():
        with obs.span("one"):
            pass
        obs.reset()
        with obs.span("two"):
            pass
        events = obs.trace_events()["traceEvents"]
    assert [e["name"] for e in events] == ["two"]


# ------------------------------------------------- jit trace-time counts

def test_count_inside_jit_fires_per_trace_not_per_call():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        obs.count("test/retrace/f")
        return x + 1

    with obs.scoped():
        f(jnp.zeros(3))
        f(jnp.ones(3))              # same shape: cached, no retrace
        assert obs.value("test/retrace/f") == 1
        f(jnp.zeros(5))             # new shape: one more trace
        assert obs.value("test/retrace/f") == 2
