"""Property: coarsened (variable-dt) replay peak-temperature error stays
within the advertised tolerance — ``tol x dc_peak_rise_C`` — against the
exact uniform replay, on both the paper stack and a DRAM-on-logic stack.

The bound is the linear-RC argument of DESIGN.md §9.3: merging intervals
whose activity range is <= tol perturbs the power trajectory pointwise by
at most tol x the modulated map, and a passive RC network's response to a
bounded input perturbation is bounded by its DC gain.  The open-loop
(disabled-feedback) replay IS that linear system, so the property is
exact there; a closed-loop companion test documents that the DTM/refresh
couplings keep the error the same order in practice.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cosim, thermal
from repro.core.floorplan import MM, APFloorplan
from repro.stack import dram, feedback
from repro.stack.spec import PAPER_SPEC, PAPER_STACK, dram_on_logic

GRID_N, MARGIN, T_BASE, T_COARSE = 8, 2, 48, 12
DT = 0.05


def _activity(seed: int, tol: float) -> np.ndarray:
    """Piecewise plateaus + sub-tolerance jitter: mergeable by design,
    with genuine level changes the plan must NOT merge across."""
    rng = np.random.default_rng(seed)
    act = np.repeat(rng.uniform(0.1, 1.0, 6), T_BASE // 6)
    act = act + rng.uniform(-0.3, 0.3, T_BASE) * tol
    return np.clip(act, 0.0, 1.2)


def _case(spec, act):
    dp = cosim.comparable_design_point("dmm")
    fp = APFloorplan(die_w_mm=math.sqrt(dp.ap_area_mm2))
    grid = thermal.Grid(die_w=fp.die_w_mm * MM, ny=GRID_N, nx=GRID_N,
                        params=PAPER_STACK, spec=spec, margin=MARGIN)
    dfp = dram.DRAMFloorplan(die_w_mm=fp.die_w_mm)
    pmap = fp.power_map(GRID_N, dp.ap_power_W)
    build = lambda a, traffic=1e10: feedback.stack_power_frames(
        spec, grid, a, pmap, fp.leakage_W(), dfp, traffic)
    return grid, build


def _replay(spec, grid, frames, fb, *, steps, dt_scale=None):
    dyn, l0, r0, lm = frames
    return feedback.closed_loop_replay(
        jnp.asarray(dyn), jnp.asarray(l0), jnp.asarray(r0),
        jnp.asarray(lm), grid.fields(), grid.capacity_field(), DT,
        fb=fb, die_n=GRID_N, n_die=spec.n_die_layers,
        steps_per_interval=steps, n_cg=25, margin=MARGIN,
        dt_scale=dt_scale)


def _coarse_vs_exact(spec, act, tol, fb):
    grid, build = _case(spec, act)
    exact = _replay(spec, grid, build(act), fb, steps=1)
    plan = cosim.coarsen_plan(act, tol, max_merge=8).pad_to(T_COARSE)
    coarse = _replay(spec, grid, build(plan.merge(act)), fb, steps=4,
                     dt_scale=jnp.asarray(plan.dt_scale()))
    frames = build(act)[0]
    bound = tol * cosim.dc_peak_rise_C(frames.max(axis=0), grid.fields())
    err = abs(float(np.asarray(exact[1]).max())
              - float(np.asarray(coarse[1]).max()))
    return err, bound, plan


@pytest.mark.parametrize("spec", [PAPER_SPEC, dram_on_logic(2)],
                         ids=["paper", "dram2"])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1 << 16),
       tol=st.sampled_from((0.05, 0.1, 0.2)))
def test_coarsened_peak_error_within_advertised_bound(spec, seed, tol):
    err, bound, plan = _coarse_vs_exact(
        spec, _activity(seed, tol), tol,
        feedback.FeedbackParams.disabled())
    assert plan.n_base == T_BASE and plan.n_coarse == T_COARSE
    assert err <= bound, (err, bound)


def test_closed_loop_coarsening_stays_small():
    """With DTM/refresh/leakage active the system is no longer linear,
    so the DC bound is not a theorem — but the couplings are weak per
    interval and the error stays the same order (documented §9.3)."""
    tol = 0.1
    err, bound, _ = _coarse_vs_exact(
        dram_on_logic(2), _activity(7, tol), tol,
        feedback.FeedbackParams())
    assert err <= 2.0 * bound, (err, bound)


def test_plan_invariants_and_padding():
    act = _activity(3, 0.1)
    plan = cosim.coarsen_plan(act, 0.1, max_merge=8)
    assert plan.n_base == T_BASE
    assert (plan.reps >= 1).all() and (plan.reps <= 8).all()
    # within-run range respects the tolerance
    edges = np.concatenate([[0], np.cumsum(plan.reps)])
    for i in range(plan.n_coarse):
        seg = act[edges[i]:edges[i + 1]]
        assert seg.max() - seg.min() <= 0.1 + 1e-12
    # merging conserves energy: duration-weighted mean is the plain mean
    merged = plan.merge(act)
    np.testing.assert_allclose(merged @ plan.reps / plan.n_base,
                               act.mean(), rtol=1e-12)
    # expand is the right inverse on run-constant signals
    np.testing.assert_array_equal(plan.merge(plan.expand(merged)), merged)
    # padding only splits runs — same coverage, finer plan
    padded = plan.pad_to(T_BASE)
    assert padded.n_coarse == T_BASE and (padded.reps == 1).all()
    with pytest.raises(ValueError):
        cosim.coarsen_plan(act, -0.1)
    with pytest.raises(ValueError):
        cosim.CoarsePlan(np.array([0, 3]))


def test_variable_dt_matches_fixed_dt_at_unit_scale():
    """dt_scale=ones must reproduce the fixed-step replay bitwise — the
    guarantee that lets the serving path share one code path."""
    spec = dram_on_logic(2)
    act = _activity(1, 0.1)
    grid, build = _case(spec, act)
    fb = feedback.FeedbackParams()
    a = _replay(spec, grid, build(act), fb, steps=1)
    b = _replay(spec, grid, build(act), fb, steps=1,
                dt_scale=jnp.ones(T_BASE))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_variable_dt_rejects_multigrid():
    spec = dram_on_logic(2)
    act = _activity(1, 0.1)
    grid, build = _case(spec, act)
    dyn, l0, r0, lm = build(act)
    with pytest.raises(ValueError, match="solver='pcg'"):
        feedback.closed_loop_replay(
            jnp.asarray(dyn), jnp.asarray(l0), jnp.asarray(r0),
            jnp.asarray(lm), grid.fields(), grid.capacity_field(), DT,
            fb=feedback.FeedbackParams(), die_n=GRID_N,
            n_die=spec.n_die_layers, steps_per_interval=1, n_cg=10,
            margin=MARGIN, solver="mg", dt_scale=jnp.ones(T_BASE))
