"""Unit tests for the HLO collective parser + roofline term arithmetic."""
import pytest

from repro.launch import roofline as RF

HLO = """
ENTRY %main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[256,512]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = f32[64,32]{1,0} reduce-scatter(%y), replica_groups=[8,32]<=[256], dimensions={0}
  %cp = bf16[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %tup = (f32[256]{0}, f32[256]{0}) all-reduce(%a, %b), replica_groups=[16,16]<=[256]
  %dot = bf16[16,16]{1,0} dot(%p0, %p0)
}
"""


def test_parse_collectives_counts_and_kinds():
    out = RF.parse_collectives(HLO)
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 2          # incl. tuple-typed
    assert out["counts"]["reduce-scatter"] == 1
    assert out["counts"]["collective-permute"] == 1
    assert out["counts"]["all-to-all"] == 0


def test_parse_collectives_wire_formulas():
    out = RF.parse_collectives(HLO)
    # all-gather: result 256*512*2 bytes, group 16 -> R*(n-1)/n
    ag = 256 * 512 * 2 * 15 / 16
    assert out["all-gather"] == pytest.approx(ag)
    # all-reduce #1: f32[1024], explicit group of 4 -> 2R*3/4;
    # tuple all-reduce: 2 x f32[256], group 16 -> 2*(2048)*15/16
    ar = 2 * 1024 * 4 * 3 / 4 + 2 * (2 * 256 * 4) * 15 / 16
    assert out["all-reduce"] == pytest.approx(ar)
    # reduce-scatter: result f32[64,32] is the shard; group 32 -> R*(n-1)
    rs = 64 * 32 * 4 * 31
    assert out["reduce-scatter"] == pytest.approx(rs)
    # collective-permute: R
    assert out["collective-permute"] == pytest.approx(128 * 2)
    assert out["total_wire_bytes"] == pytest.approx(
        ag + ar + rs + 128 * 2)


def test_parse_ignores_non_collectives():
    out = RF.parse_collectives("%d = bf16[8,8]{1,0} dot(%a, %b)\n")
    assert out["total_wire_bytes"] == 0.0


def test_roofline_terms_and_dominance():
    terms = RF.roofline(
        {"flops": RF.PEAK_FLOPS, "bytes accessed": RF.HBM_BW * 2},
        {"total_wire_bytes": RF.ICI_BW * 0.5},
        model_flops=RF.PEAK_FLOPS * 0.75)
    assert terms.compute_s == pytest.approx(1.0)
    assert terms.memory_s == pytest.approx(2.0)
    assert terms.collective_s == pytest.approx(0.5)
    assert terms.dominant == "memory"
    assert terms.bound_s == pytest.approx(2.0)
    assert terms.useful_ratio == pytest.approx(0.75)
    assert terms.roofline_fraction == pytest.approx(0.5)


def test_model_flops_train_vs_decode():
    import jax.numpy as jnp
    from repro.configs import SHAPES, get_config
    from repro.launch.steps import params_sds

    cfg = get_config("stablelm-1.6b")
    psds = params_sds(cfg, jnp.bfloat16)
    n = RF.count_params(psds)
    assert 1.5e9 < n < 2.1e9          # 1.6B class (+ padded vocab rows)
    train = RF.model_flops_per_device(cfg, SHAPES["train_4k"], psds, 256)
    dec = RF.model_flops_per_device(cfg, SHAPES["decode_32k"], psds, 256)
    # train: 6*N*B*S/chips; decode: 2*N*B/chips
    assert train == pytest.approx(6 * n * 256 * 4096 / 256)
    assert dec == pytest.approx(2 * n * 128 / 256)


def test_moe_active_params_discounted():
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.steps import params_sds

    cfg = get_config("deepseek-v2-lite-16b")
    psds = params_sds(cfg, jnp.bfloat16)
    total = RF.count_params(psds)
    active = RF.count_active_params(cfg, psds)
    assert active < 0.35 * total       # 6/64 routed utilization dominates
